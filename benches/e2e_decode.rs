//! Bench: end-to-end decode across implementation profiles — the tiny
//! config executed for real through the substrate + PJRT.

use wdb::engine::{run_protocol, Engine, EngineConfig};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::webgpu::ImplementationProfile;

fn main() {
    let registry = Registry::open().expect("run `make artifacts` first");
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let (tokens, warmup, runs) = (20, 2, 5);

    println!("E2E decode bench: tiny config, {tokens} tokens x {runs} runs\n");
    println!(
        "{:<28} {:>9} {:>11} {:>8} {:>14}",
        "profile", "tok/s", "TTFT(ms)", "CV", "wall(ms/run)"
    );
    println!("{}", "-".repeat(76));
    for profile in [
        ImplementationProfile::dawn_vulkan_rtx5090(),
        ImplementationProfile::wgpu_vulkan_rtx5090(),
        ImplementationProfile::wgpu_metal_m2(),
        ImplementationProfile::safari_metal_m2(),
        ImplementationProfile::firefox_metal_m2(),
        ImplementationProfile::cuda_rtx5090(),
    ] {
        let name = profile.name;
        let mut engine = Engine::new(
            &registry,
            EngineConfig { profile, ..EngineConfig::tiny_fused() },
        )
        .expect("engine");
        let r = run_protocol(&mut engine, &prompt, tokens, warmup, runs).expect("protocol");
        println!(
            "{:<28} {:>9.1} {:>11.1} {:>7.1}% {:>14.1}",
            name,
            r.tok_per_s.mean,
            r.ttft_ms.mean,
            r.tok_per_s.cv * 100.0,
            r.real_wall_ns_total as f64 / 1e6 / runs as f64
        );
    }
    println!(
        "\nShape check vs paper: Vulkan > Metal > rate-limited Firefox; the \
         CUDA profile's 7.4 us launch overhead beats every WebGPU profile."
    );
}

//! Shared micro-bench harness (criterion is unavailable in the offline
//! build; this provides warmup + timed iterations + mean/std/min/max in a
//! criterion-like report format). Included by each bench via `#[path]`.

use std::time::Instant;

pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchReport {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "std", "min", "max"
    );
    println!("{}", "-".repeat(100));
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchReport {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / (samples.len().max(2) - 1) as f64;
    let report = BenchReport {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ns: samples.iter().cloned().fold(0.0, f64::max),
    };
    report.print();
    report
}

//! Bench: Table 20 — per-phase dispatch timeline. Virtual (calibrated)
//! costs regenerate the paper's breakdown; real per-phase costs quantify
//! our substrate's own validation/encoding work.

use wdb::profiler::{measure_dispatch_overhead, timeline_rows};
use wdb::webgpu::ImplementationProfile;

fn main() {
    let n = 1000;
    for profile in [
        ImplementationProfile::wgpu_vulkan_rtx5090(),
        ImplementationProfile::dawn_vulkan_rtx5090(),
        ImplementationProfile::zero_overhead(),
    ] {
        let name = profile.name;
        let m = measure_dispatch_overhead(profile, n).expect("measure");
        println!("== {name} ({n} dispatches) ==");
        println!(
            "{:<16} {:>14} {:>16} {:>14}",
            "phase", "virt total", "virt per-disp", "real per-disp"
        );
        for (i, (phase, total_us, per_us)) in timeline_rows(&m.timeline).iter().enumerate() {
            println!(
                "{:<16} {:>11.1} us {:>13.2} us {:>11.3} us",
                phase,
                total_us,
                per_us,
                m.timeline.real_ns[i] as f64 / 1e3 / n as f64
            );
        }
        let total = m.timeline.total_virtual_ns() as f64 / 1e3;
        println!(
            "{:<16} {:>11.1} us {:>13.2} us {:>11.3} us  (submit = {:.0}%)\n",
            "TOTAL",
            total,
            total / n as f64,
            m.timeline.total_real_ns() as f64 / 1e3 / n as f64,
            m.timeline.virtual_ns[7] as f64 / m.timeline.total_virtual_ns().max(1) as f64
                * 100.0
        );
    }
}

//! Bench: Table 5 — the progressive fusion ablation executed FOR REAL on
//! the tiny config: every dispatch goes through the WebGPU substrate and
//! the PJRT CPU client. Prints virtual tok/s + TTFT (Dawn profile) and the
//! real wall time per run on this host.

use wdb::engine::{run_protocol, Engine, EngineConfig};
use wdb::fx::builder::FusionConfig;
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;

fn main() {
    let registry = Registry::open().expect("run `make artifacts` first");
    let tok = ByteTokenizer::new(512);
    let prompt = tok.paper_prompt();
    let (tokens, warmup, runs) = (20, 2, 5);

    println!(
        "Table 5 bench: progressive fusion, tiny config, {tokens} tokens x {runs} runs\n"
    );
    println!(
        "{:<22} {:>10} {:>9} {:>10} {:>9} {:>14}",
        "configuration", "disp/step", "tok/s", "TTFT(ms)", "CV", "wall(ms/run)"
    );
    println!("{}", "-".repeat(80));

    let mut first = 0.0;
    let mut last = 0.0;
    for (name, fusion) in [
        ("no fusion", FusionConfig::unfused()),
        ("+ RMSNorm (6->1)", FusionConfig::rmsnorm_only()),
        ("+ MLP gate+up+silu", FusionConfig::rmsnorm_mlp()),
        ("+ K+V projection", FusionConfig::rmsnorm_mlp_kv()),
        ("+ rotary (ours)", FusionConfig::fused()),
    ] {
        let mut engine = Engine::new(
            &registry,
            EngineConfig { fusion, ..EngineConfig::tiny_fused() },
        )
        .expect("engine");
        let r = run_protocol(&mut engine, &prompt, tokens, warmup, runs).expect("protocol");
        if first == 0.0 {
            first = r.tok_per_s.mean;
        }
        last = r.tok_per_s.mean;
        println!(
            "{:<22} {:>10} {:>9.1} {:>10.1} {:>8.1}% {:>14.1}",
            name,
            r.dispatches_per_step,
            r.tok_per_s.mean,
            r.ttft_ms.mean,
            r.tok_per_s.cv * 100.0,
            r.real_wall_ns_total as f64 / 1e6 / runs as f64
        );
    }
    println!(
        "\ntotal fusion speedup: {:.2}x (paper: 1.56x at 0.5B; the tiny \
         config fuses a larger fraction of its ops per layer)",
        last / first
    );
}

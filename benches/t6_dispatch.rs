//! Bench: Table 6 — per-dispatch cost, single-op vs sequential, across all
//! eleven implementation profiles. Reports both the calibrated virtual cost
//! (the paper's numbers) and the real CPU cost of our substrate's
//! validation + encoding work on this host.

#[path = "harness.rs"]
mod harness;

use wdb::profiler::measure_dispatch_overhead;
use wdb::webgpu::ImplementationProfile;

fn main() {
    let n = 500;
    println!("Table 6 bench: {n} dispatches per mode\n");
    println!(
        "{:<28} {:>14} {:>14} {:>10} {:>16}",
        "implementation", "single-op", "sequential", "ratio", "substrate-real"
    );
    println!("{}", "-".repeat(88));
    for p in ImplementationProfile::table6_catalog() {
        let m = measure_dispatch_overhead(p, n).expect("measure");
        println!(
            "{:<28} {:>11.1} us {:>11.1} us {:>9.1}x {:>13.2} us",
            m.profile_name,
            m.single_op_us,
            m.sequential_us,
            m.overestimate_ratio(),
            m.real_sequential_us
        );
    }

    // Raw substrate throughput: how many validated dispatch sequences per
    // second can this host record (zero-overhead profile)?
    println!();
    harness::header();
    harness::bench("substrate dispatch sequence (zero profile)", 100, 2000, || {
        let m = measure_dispatch_overhead(ImplementationProfile::zero_overhead(), 1)
            .expect("measure");
        std::hint::black_box(m.sequential_us);
    });
}

//! Bench: Table 8/12 — kernel compute efficiency of the REAL Pallas
//! kernels through PJRT on this host. Reports GFLOP/s; the paper-shape
//! %-of-peak table is `wdb table 8` (calibrated RTX 5090 profile).

#[path = "harness.rs"]
mod harness;

use wdb::model::rng::XorShiftRng;
use wdb::runtime::Registry;
use wdb::tensor::Tensor;

fn rand_t(rng: &mut XorShiftRng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::f32(shape, rng.normal_vec_f32(n, 0.1)).unwrap()
}

fn main() {
    let registry = Registry::open().expect("run `make artifacts` first");
    let mut rng = XorShiftRng::new(88);

    // (kernel, m, k, n, iters) — production dims get few iters (CPU host).
    let cases = [
        ("matmul_256_256_256", 256, 256, 256, 10),
        ("matmul_naive_256", 256, 256, 256, 10),
        ("matmul_896_896_4864", 896, 896, 4864, 3),
        ("matmul_896_4864_896", 896, 4864, 896, 3),
        ("matmul_1_896_4864", 1, 896, 4864, 20),
        ("matmul_1_4864_896", 1, 4864, 896, 20),
    ];
    println!("Table 8/12 bench: real Pallas matmul kernels via PJRT CPU\n");
    println!(
        "{:<24} {:>18} {:>12} {:>12}",
        "kernel", "dims", "mean", "GFLOP/s"
    );
    println!("{}", "-".repeat(72));
    for (name, m, k, n, iters) in cases {
        let x = rand_t(&mut rng, vec![m, k]);
        let w = rand_t(&mut rng, vec![k, n]);
        registry.ensure_loaded(name).expect("load");
        let _ = registry.execute(name, &[x.clone(), w.clone()]).unwrap(); // warmup
        let mut total_ns = 0u64;
        for _ in 0..iters {
            let (_, ns) = registry.execute(name, &[x.clone(), w.clone()]).unwrap();
            total_ns += ns;
        }
        let mean_ns = total_ns as f64 / iters as f64;
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        println!(
            "{:<24} {:>18} {:>12} {:>12.2}",
            name,
            format!("{m}x{k}x{n}"),
            harness::fmt_ns(mean_ns),
            flops / mean_ns
        );
    }

    // RMSNorm + softmax/argmax at paper dims.
    println!();
    harness::header();
    let x896 = rand_t(&mut rng, vec![1, 896]);
    let w896 = rand_t(&mut rng, vec![896]);
    registry.ensure_loaded("rmsnorm_896").unwrap();
    harness::bench("rmsnorm_896 (fused)", 3, 30, || {
        let _ = registry.execute("rmsnorm_896", &[x896.clone(), w896.clone()]).unwrap();
    });
    let logits = rand_t(&mut rng, vec![1, 151_936]);
    for name in ["softmax_151936", "softmax_naive_151936", "argmax_151936"] {
        registry.ensure_loaded(name).unwrap();
        harness::bench(name, 2, 10, || {
            let _ = registry.execute(name, &[logits.clone()]).unwrap();
        });
    }
}

//! Bench: eager vs planned execution — real host wall time of driving the
//! substrate plus the virtual-clock framework-overhead delta (table P1's
//! bench twin). The eager walk pays HashMap lookups, per-op allocations
//! and a host round-trip per intermediate; the planned replay walks a
//! flat pre-resolved step array, so both the virtual model *and* the real
//! host cost of a decode step should drop.

#[path = "harness.rs"]
mod harness;

use wdb::engine::{Engine, EngineConfig, ExecMode};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};

fn main() {
    const SEED: u64 = 0x91A4;
    let registry = Registry::open().expect("registry");
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 8;

    harness::header();
    let mut results = Vec::new();
    for (name, exec) in [("eager", ExecMode::Eager), ("planned", ExecMode::Planned)] {
        let cfg = EngineConfig { exec, ..EngineConfig::tiny_fused() };
        let mut engine = Engine::new(&registry, cfg).expect("engine");
        let r = harness::bench(&format!("decode/{name}/8tok"), 2, 8, || {
            engine.reseed(SEED);
            engine.generate(&prompt, tokens).expect("generate");
        });
        let fw = engine.executor.framework_virtual_ns;
        let ops = engine.executor.dispatch_count;
        results.push((name, r.mean_ns, fw as f64 / 1e3 / ops.max(1) as f64));
    }
    println!();
    for (name, wall, fw_us) in &results {
        println!(
            "{name:<8} real {} / run, framework {fw_us:.2} us/op (virtual)",
            harness::fmt_ns(*wall)
        );
    }
    if let [(_, _, eager_fw), (_, _, planned_fw)] = results.as_slice() {
        println!(
            "framework overhead ratio (eager/planned): {:.1}x",
            eager_fw / planned_fw.max(1e-9)
        );
    }

    // Plan-build vs replay attribution at N=1 serving.
    let mut se = ServingEngine::new(
        &registry,
        ServeConfig { engine: EngineConfig::tiny_planned(), max_concurrent: 1 },
    )
    .expect("serving engine");
    se.reseed(SEED);
    se.submit(&prompt, tokens).expect("submit");
    let report = se.run_to_completion().expect("serve");
    let runner = se.executor.plan_runner().expect("planned");
    println!(
        "plan build: {:.3} ms virtual / {:.3} ms real; replay {:.1} us/step over {} steps",
        runner.build_virtual_ns as f64 / 1e6,
        runner.build_real_ns as f64 / 1e6,
        report.encode_virtual_ns as f64 / 1e3 / report.steps.max(1) as f64,
        report.steps
    );
}

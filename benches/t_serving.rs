//! Bench: serving-throughput scaling — how aggregate tok/s grows with
//! concurrent session count as the fixed per-step sync amortizes across
//! the interleaved round (the serving-side analogue of the paper's fusion
//! table). Runs the REAL engine path for every step; virtual-clock numbers
//! are deterministic per seed, real wall time is this host's cost of
//! driving the substrate.

#[path = "harness.rs"]
#[allow(dead_code)] // shared bench harness; this bin only uses fmt_ns
mod harness;

use wdb::engine::{Engine, EngineConfig};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};
use wdb::tables::serving::{phase_attribution_table, scaling_table};
use wdb::webgpu::ImplementationProfile;

fn main() {
    const SEED: u64 = 0x5EBE;
    let registry = Registry::open().expect("registry");
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 16;

    for profile in [
        ImplementationProfile::dawn_vulkan_rtx5090(),
        ImplementationProfile::wgpu_metal_m2(),
    ] {
        let name = profile.name;
        let ec = EngineConfig { profile, ..EngineConfig::tiny_fused() };

        // Single-session baseline for the N=1 parity check.
        let mut engine = Engine::new(&registry, ec.clone()).expect("engine");
        engine.reseed(SEED);
        let base = engine.generate(&prompt, tokens).expect("generate");

        let mut rows = Vec::new();
        let wall0 = std::time::Instant::now();
        for n in [1usize, 2, 4, 8] {
            let mut se = ServingEngine::new(
                &registry,
                ServeConfig { engine: ec.clone(), max_concurrent: n },
            )
            .expect("serving engine");
            se.reseed(SEED);
            for _ in 0..n {
                se.submit(&prompt, tokens).expect("submit");
            }
            let report = se.run_to_completion().expect("serve");
            rows.push((n, report));
        }

        println!("== {name} ==\n");
        println!("{}", scaling_table(&rows).to_markdown());
        println!("{}", phase_attribution_table(&rows).to_markdown());
        println!(
            "N=1 parity: engine {:.2} tok/s vs serving {:.2} tok/s",
            base.tok_per_s, rows[0].1.agg_tok_per_s
        );
        println!(
            "real wall for the sweep: {}\n",
            harness::fmt_ns(wall0.elapsed().as_nanos() as f64)
        );
    }
}

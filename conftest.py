"""Root conftest: make `pytest python/tests/` work from the repo root by
putting the python/ package directory on sys.path."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))

//! Crossover analysis (Appendix F / Table 14): when does batching move an
//! operation from overhead-bound to compute-bound? Prints the paper's B*
//! table plus an overhead-vs-compute sweep curve for the MLP up projection.

use wdb::crossover::{b_star_sensitivity, table14_rows, CrossoverModel};

fn main() {
    let model = CrossoverModel::paper();
    println!(
        "== Dispatch-bound crossover (T_overhead = {} us, {} TFLOP/s) ==\n",
        model.overhead_us, model.throughput_tflops
    );
    for (group, rows) in table14_rows(&model) {
        println!("{group}");
        for r in rows {
            println!(
                "  {:<24} {:>12} B* = {:>4}   {} at B=1",
                r.operation,
                format!("{}x{}", r.d_in, r.d_out),
                r.b_star,
                r.regime_b1
            );
        }
        println!();
    }

    println!("== Sweep: MLP up projection (896x4864) ==\n");
    println!("{:>6} {:>14} {:>14} {:>16}", "batch", "compute (us)", "overhead (us)", "regime");
    for b in [1, 2, 4, 8, 16, 22, 32, 64, 128] {
        let t = model.compute_time_us(b, 896, 4864);
        println!(
            "{b:>6} {t:>14.1} {:>14.1} {:>16}",
            model.overhead_us,
            model.regime_at(b, 896, 4864)
        );
    }

    let (lo, hi) = b_star_sensitivity(&model, 896, 4864, 0.2);
    println!(
        "\nB* sensitivity (+/-20% overhead): {lo}..{hi} — batch=1 decode stays \
         deeply overhead-bound under any plausible parameterization."
    );
}

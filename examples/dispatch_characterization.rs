//! Dispatch-overhead characterization across every implementation profile —
//! the paper's headline methodology (Table 6) as a runnable walkthrough.
//!
//! Demonstrates WHY single-op benchmarks overestimate: `queue.submit` is
//! asynchronous, so a sync after every dispatch charges the full round-trip
//! to each one; syncing once after N dispatches amortizes it away.

use wdb::profiler::{measure_dispatch_overhead, timeline_rows};
use wdb::webgpu::ImplementationProfile;

fn main() -> wdb::Result<()> {
    println!("== The ~20x single-op overestimate, mechanistically ==\n");
    let dawn = measure_dispatch_overhead(ImplementationProfile::dawn_vulkan_rtx5090(), 200)?;
    println!("Dawn/Vulkan, 200 dispatches:");
    println!("  single-op (sync per dispatch):  {:>8.1} us/dispatch", dawn.single_op_us);
    println!("  sequential (one final sync):    {:>8.1} us/dispatch", dawn.sequential_us);
    println!("  overestimate:                   {:>8.1}x", dawn.overestimate_ratio());
    println!("  -> ~473 us of the naive number is GPU-CPU sync, not dispatch.\n");

    println!("== Full cross-implementation sweep (Table 6) ==\n");
    println!("{:<28} {:>12} {:>12} {:>8}", "implementation", "single (us)", "seq (us)", "ratio");
    for p in ImplementationProfile::table6_catalog() {
        let m = measure_dispatch_overhead(p, 200)?;
        println!(
            "{:<28} {:>12.1} {:>12.1} {:>7.1}x",
            m.profile_name, m.single_op_us, m.sequential_us, m.overestimate_ratio()
        );
    }

    println!("\n== Where the time goes (Table 20, wgpu/Vulkan) ==\n");
    let m = measure_dispatch_overhead(ImplementationProfile::wgpu_vulkan_rtx5090(), 100)?;
    for (phase, _total, per) in timeline_rows(&m.timeline) {
        let bar = "#".repeat((per * 4.0) as usize);
        println!("  {phase:<16} {per:>6.2} us  {bar}");
    }
    println!("\nSubmit dominates (~40%) — command buffer submission is the");
    println!("primary per-dispatch bottleneck, which is why batching 16");
    println!("dispatches per submit helps microbenchmarks but not E2E decode");
    println!("(the per-token sync flushes every batch anyway).");
    Ok(())
}

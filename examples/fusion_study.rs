//! Fusion study: the paper's Table 5 ablation, twice —
//!
//! 1. the published 0.5B dispatch arithmetic (876 -> 564, +53%), and
//! 2. the same progressive fusions executed FOR REAL on the tiny config
//!    through the WebGPU substrate + PJRT, verifying tokens are unchanged
//!    (fusion is numerics-preserving, Appendix N).

use wdb::engine::{Engine, EngineConfig};
use wdb::fx::builder::{FusionConfig, GraphDims};
use wdb::fx::census::Census;
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;

fn main() -> wdb::Result<()> {
    // --- 1. the published arithmetic ---
    let census = Census::for_dims(&GraphDims::qwen25_05b());
    let s = census.paper_fusion_savings();
    println!("== Qwen2.5-0.5B fusion arithmetic (Table 5) ==\n");
    println!("unfused dispatches:  {}", census.unfused_dispatches());
    println!("RMSNorm fusion:     -{}  (24 layers x 2 norms x 5 saved)", s.rmsnorm);
    println!("MLP fusion:         -{}", s.mlp);
    println!("K+V fusion:         -{}", s.kv);
    println!("fused dispatches:    {}\n", census.fused_dispatches());

    // --- 2. executed for real on the tiny config ---
    let registry = Registry::open()?;
    let prompt = ByteTokenizer::new(512).paper_prompt();
    println!("== Executed ablation (tiny config, 15 tokens, Dawn profile) ==\n");
    println!(
        "{:<24} {:>10} {:>9} {:>10} {:>9}",
        "configuration", "disp/step", "tok/s", "TTFT(ms)", "speedup"
    );

    let mut baseline = 0.0;
    let mut baseline_tokens: Vec<usize> = Vec::new();
    for (name, fusion) in [
        ("no fusion", FusionConfig::unfused()),
        ("+ RMSNorm (6->1)", FusionConfig::rmsnorm_only()),
        ("+ MLP gate+up+silu", FusionConfig::rmsnorm_mlp()),
        ("+ K+V projection", FusionConfig::rmsnorm_mlp_kv()),
        ("+ rotary (ours)", FusionConfig::fused()),
    ] {
        let mut engine = Engine::new(
            &registry,
            EngineConfig { fusion, ..EngineConfig::tiny_fused() },
        )?;
        let r = engine.generate(&prompt, 15)?;
        if baseline == 0.0 {
            baseline = r.tok_per_s;
            baseline_tokens = r.tokens.clone();
        }
        assert_eq!(
            r.tokens, baseline_tokens,
            "fusion must not change the token stream (Appendix N)"
        );
        println!(
            "{:<24} {:>10} {:>9.1} {:>10.1} {:>8.2}x",
            name,
            r.dispatches_per_step,
            r.tok_per_s,
            r.ttft_ns as f64 / 1e6,
            r.tok_per_s / baseline
        );
    }
    println!("\ntoken streams identical across all four configurations — the");
    println!("speedup is pure per-operation-overhead elimination.");
    Ok(())
}

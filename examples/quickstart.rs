//! Quickstart: load the AOT artifacts, build the engine, generate tokens.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This is the full three-layer stack end-to-end: the tiny Qwen2.5-
//! architecture model decodes autoregressively; every compute op is one
//! WebGPU-substrate dispatch executing an AOT-compiled Pallas kernel on the
//! PJRT CPU client, under the Dawn/Vulkan cost profile.

use wdb::engine::{Engine, EngineConfig};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;

fn main() -> wdb::Result<()> {
    // 1. Open the artifact registry (compiles kernels lazily).
    let registry = Registry::open()?;
    println!("artifacts: {} kernels on {}", registry.kernels.len(),
             registry.runtime.platform());

    // 2. Build the engine: tiny config, fully fused flow, Dawn profile.
    let mut engine = Engine::new(&registry, EngineConfig::tiny_fused())?;
    println!(
        "engine: {} layers, {} dispatches/step (fused)",
        engine.dims.layers,
        engine.graph.dispatch_count()
    );

    // 3. Generate from the paper's prompt.
    let tok = ByteTokenizer::new(engine.dims.vocab);
    let prompt = tok.paper_prompt();
    let result = engine.generate(&prompt, 30)?;

    println!("\nprompt tokens:    {:?}", prompt);
    println!("generated tokens: {:?}", result.tokens);
    println!("decoded (synthetic weights => arbitrary bytes): {:?}",
             tok.decode(&result.tokens));
    println!("\n--- timing (virtual clock, Dawn/Vulkan profile) ---");
    println!("TTFT:       {:.1} ms", result.ttft_ns as f64 / 1e6);
    println!("throughput: {:.1} tok/s", result.tok_per_s);
    println!("dispatches: {} per decode step", result.dispatches_per_step);
    println!("real wall:  {:.0} ms on this host", result.real_wall_ns as f64 / 1e6);
    Ok(())
}

"""AOT export: lower every kernel (and the whole decode step) to HLO *text*
artifacts the Rust coordinator loads via ``HloModuleProto::from_text_file``.

HLO text — NOT ``lowered.compile().serialize()`` and NOT serialized protos —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6
crate links) rejects (``proto.id() <= INT_MAX``). The text parser reassigns
ids, so text round-trips cleanly. Lowering goes StableHLO -> XlaComputation
with ``return_tuple=True``; the Rust side unwraps with ``to_tupleN``.

Python runs ONCE (``make artifacts``); nothing here is on the request path.

Usage:  python -m compile.aot --out ../artifacts [--only tag] [--list]
"""

import argparse
import hashlib
import json
import time
from dataclasses import dataclass, field
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, QWEN25_05B, QWEN_TINY
from .kernels import (
    argmax,
    attention,
    concat,
    elementwise,
    fused_kv,
    fused_mlp,
    matmul,
    mega_mlp,
    rmsnorm,
    rotary,
    softmax,
)

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclass
class KernelEntry:
    """One exportable kernel: a jax-traceable fn + example input specs."""

    name: str
    fn: object
    in_specs: list
    tags: tuple = ()
    flops: float = 0.0
    notes: str = ""
    out_specs: list = field(default_factory=list)

    def lower(self):
        wrapped = self.fn
        lowered = jax.jit(wrapped).lower(*self.in_specs)
        out = jax.eval_shape(wrapped, *self.in_specs)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        self.out_specs = list(out)
        return lowered


def _tup(fn):
    """Ensure the exported computation returns a tuple (rust unwraps it)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def matmul_flops(m, k, n):
    return 2.0 * m * k * n


# ---------------------------------------------------------------------------
# Kernel registry
# ---------------------------------------------------------------------------
def build_registry() -> list[KernelEntry]:
    t = QWEN_TINY
    b = QWEN25_05B
    ks: list[KernelEntry] = []

    def add(name, fn, in_specs, tags=(), flops=0.0, notes=""):
        ks.append(KernelEntry(name, _tup(fn), list(in_specs), tuple(tags), flops, notes))

    H, QD, KV, I, V, S, NH, KVH, D = (
        t.hidden, t.q_dim, t.kv_dim, t.intermediate, t.vocab,
        t.max_seq, t.heads, t.kv_heads, t.head_dim,
    )
    half = D // 2

    # ---- tiny-config decode kernels (one per distinct op x shape) ----
    add("matmul_64_64", matmul.matmul, [spec((1, H)), spec((H, QD))],
        tags=("tiny", "matmul"), flops=matmul_flops(1, H, QD),
        notes="q/o projection")
    add("matmul_64_32", matmul.matmul, [spec((1, H)), spec((H, KV))],
        tags=("tiny", "matmul"), flops=matmul_flops(1, H, KV),
        notes="separate k or v projection (unfused flow)")
    add("matmul_64_176", matmul.matmul, [spec((1, H)), spec((H, I))],
        tags=("tiny", "matmul"), flops=matmul_flops(1, H, I),
        notes="gate/up projection (unfused flow)")
    add("matmul_176_64", matmul.matmul, [spec((1, I)), spec((I, H))],
        tags=("tiny", "matmul"), flops=matmul_flops(1, I, H),
        notes="down projection")
    add("matmul_64_512", matmul.matmul, [spec((1, H)), spec((H, V))],
        tags=("tiny", "matmul"), flops=matmul_flops(1, H, V),
        notes="lm head")
    add("kv_fused_64_64", fused_kv.kv_proj_fused,
        [spec((1, H)), spec((H, 2 * KV))],
        tags=("tiny", "fused"), flops=matmul_flops(1, H, 2 * KV),
        notes="K+V fusion (2 dispatches -> 1)")

    add("rmsnorm_64", partial(rmsnorm.rmsnorm, eps=t.rms_eps),
        [spec((1, H)), spec((H,))], tags=("tiny", "fused", "rmsnorm"),
        notes="fused RMSNorm (6 -> 1)")
    add("rms_pow_64", rmsnorm.rms_pow, [spec((1, H))], tags=("tiny", "rmsnorm"))
    add("rms_mean_64", rmsnorm.rms_mean, [spec((1, H))], tags=("tiny", "rmsnorm"))
    add("rms_add_eps_1", partial(rmsnorm.rms_add_eps, eps=t.rms_eps),
        [spec((1, 1))], tags=("tiny", "rmsnorm"))
    add("rms_rsqrt_1", rmsnorm.rms_rsqrt, [spec((1, 1))], tags=("tiny", "rmsnorm"))
    add("rms_mul_x_64", rmsnorm.rms_mul_x, [spec((1, H)), spec((1, 1))],
        tags=("tiny", "rmsnorm"))
    add("rms_mul_w_64", rmsnorm.rms_mul_w, [spec((1, H)), spec((H,))],
        tags=("tiny", "rmsnorm"))

    add("rope_cos_sin_16", rotary.rope_cos_sin,
        [spec((1,)), spec((half,))], tags=("tiny", "rotary"))
    add("rotary_4_16", rotary.rotary,
        [spec((NH, D)), spec((D,)), spec((D,))], tags=("tiny", "rotary", "fused"))
    add("rotary_2_16", rotary.rotary,
        [spec((KVH, D)), spec((D,)), spec((D,))], tags=("tiny", "rotary", "fused"))
    # unfused rotary pieces
    add("neg_4_8", elementwise.neg, [spec((NH, half))], tags=("tiny", "rotary"))
    add("neg_2_8", elementwise.neg, [spec((KVH, half))], tags=("tiny", "rotary"))
    add("concat_4_8", concat.concat_last,
        [spec((NH, half)), spec((NH, half))], tags=("tiny", "rotary"))
    add("concat_2_8", concat.concat_last,
        [spec((KVH, half)), spec((KVH, half))], tags=("tiny", "rotary"))
    add("mul_vec_4_16", rmsnorm.rms_mul_w, [spec((NH, D)), spec((D,))],
        tags=("tiny", "rotary"))
    add("mul_vec_2_16", rmsnorm.rms_mul_w, [spec((KVH, D)), spec((D,))],
        tags=("tiny", "rotary"))
    add("add_4_16", elementwise.add, [spec((NH, D)), spec((NH, D))],
        tags=("tiny", "rotary"))
    add("add_2_16", elementwise.add, [spec((KVH, D)), spec((KVH, D))],
        tags=("tiny", "rotary"))

    add("cache_update_tiny", concat.cache_update,
        [spec((S, KVH, D)), spec((KVH, D)), spec((1,), I32)],
        tags=("tiny", "cache"))
    add("sdpa_tiny", attention.sdpa_gqa,
        [spec((NH, D)), spec((S, KVH, D)), spec((S, KVH, D)), spec((1,), I32)],
        tags=("tiny", "attention"),
        flops=2.0 * NH * D * S * 2)

    add("silu_176", elementwise.silu, [spec((1, I))], tags=("tiny", "mlp"))
    add("mul_176", elementwise.mul, [spec((1, I)), spec((1, I))], tags=("tiny", "mlp"))
    add("add_64", elementwise.add, [spec((1, H)), spec((1, H))], tags=("tiny",))
    add("gate_up_silu_tiny", fused_mlp.mlp_gate_up_silu,
        [spec((1, H)), spec((H, I)), spec((H, I))],
        tags=("tiny", "fused", "mlp"), flops=2 * matmul_flops(1, H, I),
        notes="MLP gate+up+silu fusion (3 -> 1)")

    add("argmax_512", argmax.argmax_device, [spec((1, V))], tags=("tiny", "argmax"))
    add("softmax_512", softmax.softmax, [spec((1, V))], tags=("tiny", "softmax"))
    add("softmax_naive_512", softmax.softmax_naive, [spec((1, V))],
        tags=("tiny", "softmax"))
    add("mega_mlp_tiny", partial(mega_mlp.mega_mlp, eps=t.rms_eps),
        [spec((1, H)), spec((H,)), spec((H, I)), spec((H, I)), spec((I, H))],
        tags=("tiny", "mega"),
        flops=2 * matmul_flops(1, H, I) + matmul_flops(1, I, H))

    # ---- whole decode step as one HLO (graph-compiled baseline) ----
    L = t.layers
    add(
        "decode_step_tiny",
        model.decode_step_fused_fn(t),
        [
            spec((1, H)),                     # x
            spec((L, S, KVH, D)),             # k caches
            spec((L, S, KVH, D)),             # v caches
            spec((1,), I32),                  # pos
            spec((L, H)),                     # norm1
            spec((L, H, QD)),                 # wq
            spec((L, H, 2 * KV)),             # wkv
            spec((L, QD, H)),                 # wo
            spec((L, H)),                     # norm2
            spec((L, H, I)),                  # wg
            spec((L, H, I)),                  # wu
            spec((L, I, H)),                  # wd
            spec((H,)),                       # norm_f
            spec((H, V)),                     # w_lm
        ],
        tags=("tiny", "graph"),
        notes="entire forward in one module — XLA/TVM/WebLLM-style baseline",
    )

    # ---- bench kernels at paper dimensions (Tables 7/8/11/12/16/19) ----
    bH, bI = b.hidden, b.intermediate
    add("matmul_896_896_4864", matmul.matmul,
        [spec((bH, bH)), spec((bH, bI))], tags=("bench", "matmul"),
        flops=matmul_flops(bH, bH, bI), notes="Table 8/12 MLP up projection")
    add("matmul_896_4864_896", matmul.matmul,
        [spec((bH, bI)), spec((bI, bH))], tags=("bench", "matmul"),
        flops=matmul_flops(bH, bI, bH), notes="Table 8/12 MLP down projection")
    add("matmul_256_256_256", matmul.matmul,
        [spec((256, 256)), spec((256, 256))], tags=("bench", "matmul"),
        flops=matmul_flops(256, 256, 256), notes="Table 8/12 toy matmul")
    add("matmul_naive_256", matmul.matmul_naive,
        [spec((256, 256)), spec((256, 256))], tags=("bench", "matmul"),
        flops=matmul_flops(256, 256, 256), notes="untiled baseline")

    add("rmsnorm_896", partial(rmsnorm.rmsnorm, eps=b.rms_eps),
        [spec((1, bH)), spec((bH,))], tags=("bench", "rmsnorm"),
        notes="Table 7 fused RMSNorm at 0.5B hidden")
    add("rms_pow_896", rmsnorm.rms_pow, [spec((1, bH))], tags=("bench", "rmsnorm"))
    add("rms_mean_896", rmsnorm.rms_mean, [spec((1, bH))], tags=("bench", "rmsnorm"))
    add("rms_mul_x_896", rmsnorm.rms_mul_x, [spec((1, bH)), spec((1, 1))],
        tags=("bench", "rmsnorm"))
    add("rms_mul_w_896", rmsnorm.rms_mul_w, [spec((1, bH)), spec((bH,))],
        tags=("bench", "rmsnorm"))

    add("matmul_1_896_4864", matmul.matmul,
        [spec((1, bH)), spec((bH, bI))], tags=("bench", "mlp"),
        flops=matmul_flops(1, bH, bI), notes="decode-shape up/gate projection")
    add("matmul_1_4864_896", matmul.matmul,
        [spec((1, bI)), spec((bI, bH))], tags=("bench", "mlp"),
        flops=matmul_flops(1, bI, bH), notes="decode-shape down projection")
    add("gate_up_silu_05b", fused_mlp.mlp_gate_up_silu,
        [spec((1, bH)), spec((bH, bI)), spec((bH, bI))],
        tags=("bench", "mlp", "fused"), flops=2 * matmul_flops(1, bH, bI),
        notes="Table 19 tiled strategy stage 1")
    add("silu_4864", elementwise.silu, [spec((1, bI))], tags=("bench", "mlp"))
    add("mul_4864", elementwise.mul, [spec((1, bI)), spec((1, bI))],
        tags=("bench", "mlp"))
    add("add_896", elementwise.add, [spec((1, bH)), spec((1, bH))],
        tags=("bench", "mlp"))
    add("mega_mlp_05b", partial(mega_mlp.mega_mlp, eps=b.rms_eps),
        [spec((1, bH)), spec((bH,)), spec((bH, bI)), spec((bH, bI)),
         spec((bI, bH))],
        tags=("bench", "mega"),
        flops=2 * matmul_flops(1, bH, bI) + matmul_flops(1, bI, bH),
        notes="Table 11 mega-kernel at 0.5B dims")

    # Batched decode shapes for the empirical crossover sweep (Appendix F's
    # "highest-priority future work": validate B* beyond batch=1).
    for bsz in (1, 4, 8, 16, 32, 64):
        add(f"matmul_b{bsz}_896_4864", matmul.matmul,
            [spec((bsz, bH)), spec((bH, bI))], tags=("bench", "batch"),
            flops=matmul_flops(bsz, bH, bI),
            notes=f"MLP up projection at batch={bsz} (crossover sweep)")

    add("softmax_151936", softmax.softmax, [spec((1, b.vocab))],
        tags=("bench", "softmax"), notes="Table 16 optimized softmax at vocab")
    add("softmax_naive_151936", softmax.softmax_naive, [spec((1, b.vocab))],
        tags=("bench", "softmax"), notes="Table 16 naive softmax at vocab")
    add("argmax_151936", argmax.argmax_device, [spec((1, b.vocab))],
        tags=("bench", "argmax"), notes="Table 15 device-side argmax at vocab")

    return ks


# ---------------------------------------------------------------------------
# Export driver
# ---------------------------------------------------------------------------
def dtype_tag(d) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(d)]


def export_all(out_dir: Path, only: str | None = None) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    registry = build_registry()
    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "configs": {name: cfg.to_dict() for name, cfg in CONFIGS.items()},
        "kernels": [],
    }
    for entry in registry:
        if only and only not in entry.tags:
            continue
        t0 = time.time()
        lowered = entry.lower()
        text = to_hlo_text(lowered)
        fname = f"k_{entry.name}.hlo.txt"
        (out_dir / fname).write_text(text)
        manifest["kernels"].append(
            {
                "name": entry.name,
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": dtype_tag(s.dtype)}
                    for s in entry.in_specs
                ],
                "outputs": [
                    {"shape": list(s.shape), "dtype": dtype_tag(s.dtype)}
                    for s in entry.out_specs
                ],
                "tags": list(entry.tags),
                "flops": entry.flops,
                "notes": entry.notes,
                "hlo_sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "hlo_bytes": len(text),
            }
        )
        print(f"  exported {entry.name:<28} {len(text):>9} B  "
              f"({time.time() - t0:.2f}s)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(manifest['kernels'])} kernels + manifest.json -> {out_dir}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--only", default=None, help="export only kernels with tag")
    p.add_argument("--list", action="store_true", help="list registry and exit")
    args = p.parse_args()
    if args.list:
        for e in build_registry():
            print(f"{e.name:<28} tags={','.join(e.tags):<24} {e.notes}")
        return
    export_all(Path(args.out), args.only)


if __name__ == "__main__":
    main()

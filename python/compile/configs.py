"""Model configurations for the Qwen2.5 architecture family.

The paper benchmarks Qwen2.5-0.5B-Instruct and Qwen2.5-1.5B-Instruct. We keep
those configs for graph-census and analytic tables (their dispatch counts are
what Tables 4/5/10/18 depend on), and add ``qwen-tiny`` — the same
architecture at small dimensions — for *executed* end-to-end decoding through
the PJRT CPU client. Overhead characterization is dispatch-count driven, so
the tiny config exercises the identical op stream shape per layer.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    hidden: int
    layers: int
    heads: int
    kv_heads: int
    head_dim: int
    intermediate: int
    vocab: int
    max_seq: int
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim

    def to_dict(self) -> dict:
        d = asdict(self)
        d["kv_dim"] = self.kv_dim
        d["q_dim"] = self.q_dim
        return d


# Qwen2.5-0.5B-Instruct: 24 layers, 896 hidden, 14 heads / 2 KV heads,
# 4864 intermediate, 151936 vocab (paper §3.3).
QWEN25_05B = ModelConfig(
    name="qwen2.5-0.5b",
    hidden=896,
    layers=24,
    heads=14,
    kv_heads=2,
    head_dim=64,
    intermediate=4864,
    vocab=151936,
    max_seq=32768,
    rope_theta=1000000.0,
)

# Qwen2.5-1.5B-Instruct: 28 layers, 1536 hidden, 12 heads / 2 KV heads,
# 8960 intermediate (paper §3.3 and Appendix K).
QWEN25_15B = ModelConfig(
    name="qwen2.5-1.5b",
    hidden=1536,
    layers=28,
    heads=12,
    kv_heads=2,
    head_dim=128,
    intermediate=8960,
    vocab=151936,
    max_seq=32768,
    rope_theta=1000000.0,
)

# Executed-E2E config: same architecture, laptop-scale dims. One HLO artifact
# per distinct (op, shape); decoding runs the same per-layer op stream as the
# 0.5B model (7 matmuls, 2 norms, SDPA, SwiGLU, rotary, cache update).
QWEN_TINY = ModelConfig(
    name="qwen-tiny",
    hidden=64,
    layers=4,
    heads=4,
    kv_heads=2,
    head_dim=16,
    intermediate=176,
    vocab=512,
    # 160 rows so prompt-heavy serving benches (prompt 128 + 16 generated
    # tokens) fit the tiny KV capacity (mirrored by the Rust builtin).
    max_seq=160,
)

CONFIGS = {c.name: c for c in (QWEN25_05B, QWEN25_15B, QWEN_TINY)}


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown model config {name!r}; have {sorted(CONFIGS)}")

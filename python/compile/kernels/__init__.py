"""L1 — Pallas kernels for the paper's compute ops, plus pure-jnp oracles.

Every kernel is lowered with ``interpret=True`` (CPU-PJRT executable HLO) and
validated against ``ref.py`` by pytest + hypothesis. The Rust coordinator
issues one WebGPU-substrate dispatch per kernel execution.
"""

from . import (  # noqa: F401
    argmax,
    attention,
    common,
    concat,
    elementwise,
    fused_kv,
    fused_mlp,
    matmul,
    mega_mlp,
    ref,
    rmsnorm,
    rotary,
    softmax,
)

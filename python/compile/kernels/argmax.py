"""Argmax kernels — token selection.

``argmax_host_style`` mirrors the production path: the full logits row is
read back to the host which argmaxes there (the paper's ~11 ms/token sync
overhead, §5.1). In our stack the *kernel* is identity-less: the Rust engine
maps the logits buffer and argmaxes host-side.

``argmax_device`` is the Appendix H device-side variant: the reduction runs
on-device and only 4 bytes are read back. The paper found this inconclusive
on both backends (p = 0.35 Vulkan / 0.62 Metal); Table 15 reproduces that.
"""

from .common import jax, jnp, pl, INTERPRET


def _argmax_kernel(x_ref, o_ref):
    o_ref[...] = jnp.argmax(x_ref[...], axis=-1).astype(jnp.int32)


def argmax_device(x):
    """x: [M, V] -> [M] int32 indices."""
    m = x.shape[0]
    return pl.pallas_call(
        _argmax_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=INTERPRET,
    )(x)

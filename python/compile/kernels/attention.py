"""SDPA Pallas kernel: grouped-query attention over a fixed-capacity masked
KV cache (one dispatch per layer — the paper's FX census counts 24 SDPA nodes
for Qwen2.5-0.5B, Table 10).

The cache is padded to ``max_seq`` and masked by the current position so the
kernel shape is static — the WebGPU analogue of pre-allocated storage buffers
(dynamic shapes would force pipeline re-creation per token, which the paper's
torch-webgpu avoids the same way).

Grid: one program per query head; the BlockSpec index map routes each query
head to its GQA KV head (h // group), expressing the HBM->VMEM schedule the
paper expressed with workgroups. VMEM per program: S*D*2 + D floats.
"""

from .common import jax, jnp, pl, INTERPRET


def _sdpa_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref):
    # q_ref: [1, D]; k_ref/v_ref: [S, 1, D] (this head's KV slice).
    q = q_ref[0, :]
    k = k_ref[:, 0, :]
    v = v_ref[:, 0, :]
    seq, dim = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dim))
    scores = jnp.sum(k * q[None, :], axis=-1) * scale  # [S]
    mask = jnp.arange(seq) < pos_ref[0]
    scores = jnp.where(mask, scores, -1e30)
    mx = jnp.max(scores)
    e = jnp.exp(scores - mx)
    probs = e / jnp.sum(e)
    o_ref[0, :] = jnp.sum(probs[:, None] * v, axis=0)


def sdpa_gqa(q, k_cache, v_cache, pos):
    """q: [H, D]; k_cache/v_cache: [S, KVH, D]; pos: [1] int32."""
    heads, dim = q.shape
    seq, kv_heads, _ = k_cache.shape
    group = heads // kv_heads
    return pl.pallas_call(
        _sdpa_kernel,
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1, dim), lambda h: (h, 0)),
            pl.BlockSpec((seq, 1, dim), lambda h: (0, h // group, 0)),
            pl.BlockSpec((seq, 1, dim), lambda h: (0, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, dim), jnp.float32),
        interpret=INTERPRET,
    )(pos, q, k_cache, v_cache)

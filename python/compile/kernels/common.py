"""Shared helpers for the Pallas kernel layer.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the kernel body to
plain HLO that any backend (including the Rust ``xla`` crate's CPU client)
runs with identical numerics. Real-TPU performance is estimated from the
BlockSpec VMEM footprint in DESIGN.md, not measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # see module docstring — required for CPU-PJRT execution

__all__ = ["jax", "jnp", "pl", "INTERPRET", "pick_block", "vmem_bytes"]


def pick_block(dim: int, preferred: int = 16) -> int:
    """Largest block size <= preferred that divides ``dim``.

    The paper's WGSL matmul uses 16x16 tiles; our shapes are all multiples of
    16, but hypothesis sweeps feed arbitrary dims, so degrade gracefully.
    """
    for b in range(min(preferred, dim), 0, -1):
        if dim % b == 0:
            return b
    return 1


def vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of a kernel instance (for DESIGN.md notes)."""
    total = 0
    for shape in block_shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * dtype_bytes
    return total


def named_call(fn, name):
    """Wrap ``fn`` so its jaxpr (and HLO) carries a stable name."""
    return functools.wraps(fn)(jax.named_call(fn, name=name))

"""Concatenation-category kernels (Table 10 counts 97 concat nodes: KV-cache
appends and rotary rotate-half concats).

``concat_last`` is the generic last-axis concat dispatch used by the unfused
rotary flow. ``cache_update`` writes one token's K or V row into the
fixed-capacity cache at a dynamic position — the WebGPU analogue is a small
copy dispatch into a pre-allocated storage buffer.
"""

from .common import jax, jnp, pl, INTERPRET


def _concat_kernel(a_ref, b_ref, o_ref):
    na = a_ref.shape[-1]
    o_ref[:, :na] = a_ref[...]
    o_ref[:, na:] = b_ref[...]


def concat_last(a, b):
    """a: [M, Na], b: [M, Nb] -> [M, Na+Nb]."""
    m, na = a.shape
    _, nb = b.shape
    return pl.pallas_call(
        _concat_kernel,
        out_shape=jax.ShapeDtypeStruct((m, na + nb), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


def _cache_update_kernel(pos_ref, cache_ref, row_ref, o_ref):
    o_ref[...] = cache_ref[...]
    pos = pos_ref[0]
    o_ref[pl.dslice(pos, 1), :, :] = row_ref[...][None, ...]


def cache_update(cache, row, pos):
    """cache: [S, KVH, D]; row: [KVH, D]; pos: [1] int32 -> updated cache."""
    return pl.pallas_call(
        _cache_update_kernel,
        out_shape=jax.ShapeDtypeStruct(cache.shape, jnp.float32),
        interpret=INTERPRET,
    )(pos, cache, row)

"""Elementwise Pallas kernels: silu / add / mul / neg and the paper's small
elementwise fusions (fused_mul_silu, fused_add_silu, fused_add_gelu — §6.1,
which yielded <5% because they save only 10-20 dispatches per forward)."""

from .common import jax, jnp, pl, INTERPRET


def _unary(kernel_body):
    def run(x):
        return pl.pallas_call(
            kernel_body,
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            interpret=INTERPRET,
        )(x)

    return run


def _binary(kernel_body):
    def run(a, b):
        assert a.shape == b.shape, f"shape mismatch {a.shape} vs {b.shape}"
        return pl.pallas_call(
            kernel_body,
            out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
            interpret=INTERPRET,
        )(a, b)

    return run


def _silu_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = x * jax.lax.logistic(x)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _mul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * b_ref[...]


def _neg_kernel(x_ref, o_ref):
    o_ref[...] = -x_ref[...]


def _mul_silu_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]
    o_ref[...] = a * jax.lax.logistic(a) * b_ref[...]


def _add_silu_kernel(a_ref, b_ref, o_ref):
    x = a_ref[...] + b_ref[...]
    o_ref[...] = x * jax.lax.logistic(x)


def _add_gelu_kernel(a_ref, b_ref, o_ref):
    x = a_ref[...] + b_ref[...]
    o_ref[...] = (
        0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))
    )


silu = _unary(_silu_kernel)
neg = _unary(_neg_kernel)
add = _binary(_add_kernel)
mul = _binary(_mul_kernel)
mul_silu = _binary(_mul_silu_kernel)
add_silu = _binary(_add_silu_kernel)
add_gelu = _binary(_add_gelu_kernel)

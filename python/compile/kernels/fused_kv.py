"""Fused K+V projection kernel (§6.1, K+V fusion).

Both projections have identical [H, KV] dimensions under grouped-query
attention, so the paper merges them into one tiled matmul against the
column-concatenated weight [H, 2*KV], saving 1 dispatch per layer (24 per
forward on 0.5B; +0.5%, p = 0.42 — reported as a negative result in Table 5,
and we reproduce it as such).
"""

from .common import jax, jnp, pl, INTERPRET, pick_block


def _kv_kernel(x_ref, wkv_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], wkv_ref[...], preferred_element_type=jnp.float32
    )


def kv_proj_fused(x, w_kv, bn: int | None = None):
    """x: [M, H]; w_kv: [H, 2*KV] (K and V weights column-concatenated)."""
    m, h = x.shape
    _, n2 = w_kv.shape
    bn = bn or pick_block(n2, 32)
    return pl.pallas_call(
        _kv_kernel,
        grid=(n2 // bn,),
        in_specs=[
            pl.BlockSpec((m, h), lambda j: (0, 0)),
            pl.BlockSpec((h, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n2), jnp.float32),
        interpret=INTERPRET,
    )(x, w_kv)

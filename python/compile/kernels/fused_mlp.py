"""Fused MLP kernels.

``mlp_gate_up_silu`` is the paper's MLP fusion (§6.1): gate projection, up
projection and SiLU in a single dispatch — silu(x Wg) * (x Wu) — saving 2
dispatches per layer (48 per forward on 0.5B, +6% tok/s, p < 0.001).

``mlp_tiled_*`` implement the Appendix L 3-dispatch tiled strategy: the MLP
block as (gate+up+silu fused, down projection, residual add) = 3 dispatches
instead of 7, preserving multi-workgroup parallelism (2.0x on Metal, 1.17x
on Vulkan, Table 19) where the 1-dispatch mega-kernel cannot.
"""

from .common import jax, jnp, pl, INTERPRET, pick_block


def _gate_up_silu_kernel(x_ref, wg_ref, wu_ref, o_ref):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = g * jax.lax.logistic(g) * u


def mlp_gate_up_silu(x, w_gate, w_up, bn: int | None = None):
    """x: [M, H]; w_gate/w_up: [H, I] -> [M, I]. Tiled over the I dim."""
    m, h = x.shape
    _, inter = w_gate.shape
    bn = bn or pick_block(inter, 64)
    return pl.pallas_call(
        _gate_up_silu_kernel,
        grid=(inter // bn,),
        in_specs=[
            pl.BlockSpec((m, h), lambda j: (0, 0)),
            pl.BlockSpec((h, bn), lambda j: (0, j)),
            pl.BlockSpec((h, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, inter), jnp.float32),
        interpret=INTERPRET,
    )(x, w_gate, w_up)

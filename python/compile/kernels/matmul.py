"""Tiled matmul Pallas kernel — the analogue of the paper's 16x16-tile WGSL
matmul shader (Table 8: "16x16 tiling without bank-conflict-free shared
memory access").

Two variants:

- ``matmul``        — tiled: grid over (M/bm, N/bn) output tiles, full-K
                      blocks staged through VMEM (the BlockSpec expresses the
                      HBM->VMEM schedule the paper expressed via workgroups).
- ``matmul_naive``  — single-program whole-array kernel, the unoptimized
                      baseline used for the kernel-efficiency floor (Table 8
                      reports 1-2% of peak for the unoptimized shader).
"""

from .common import jax, jnp, pl, INTERPRET, pick_block


def _matmul_tile_kernel(x_ref, w_ref, o_ref):
    # One (bm, bn) output tile; K is not blocked (fits VMEM at our sizes).
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x, w, bm: int | None = None, bn: int | None = None):
    """Tiled x @ w. x: [M, K], w: [K, N] -> [M, N] float32.

    Default blocks are 128x256 (PERF: the original 16x64 tiles produced
    4256-iteration interpret-mode grids that serialize on CPU — see
    EXPERIMENTS.md §Perf L1; 128x256 also matches MXU-aligned tiling with a
    ~1.5 MiB VMEM footprint at K=896). When the grid degenerates to a
    single tile, emit the whole-array kernel: a 1x1 grid only adds loop
    scaffolding.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {k} vs {k2}"
    # PERF: skinny (m < 128, i.e. below one full output tile) matmuls gain
    # nothing from output tiling — each grid step copies a [K, bn] weight
    # block, which at small m costs more than the whole dot (95 ms vs ~3 ms
    # for 1x896x4864 on the CPU interpreter). A GPU would tile these across
    # workgroups; on the CPU-lowered path a single program is the
    # faithful-throughput choice.
    if bm is None and bn is None and m < 128:
        return matmul_naive(x, w)
    bm = bm or pick_block(m, 128)
    bn = bn or pick_block(n, 256)
    grid = (m // bm, n // bn)
    if grid == (1, 1):
        return matmul_naive(x, w)
    return pl.pallas_call(
        _matmul_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w)


def _matmul_naive_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_naive(x, w):
    """Whole-array single-program matmul (no tiling) — efficiency baseline."""
    m, _ = x.shape
    _, n = w.shape
    return pl.pallas_call(
        _matmul_naive_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w)

"""Mega-kernel (Appendix C): RMSNorm + SwiGLU MLP + residual add as a single
dispatch.

WebGPU lacks cross-workgroup synchronization (workgroupBarrier() is
intra-workgroup only), so the paper's mega-kernel is forced into a single
workgroup and under-utilizes the GPU at production dimensions. Our Pallas
analogue is a grid=() single-program kernel — the same structural property:
no parallel grid, everything serialized in one program instance. The paper
found it inconclusive (p > 0.38, Table 11); Table 11's regeneration uses the
calibrated single-workgroup serialization model.
"""

from .common import jax, jnp, pl, INTERPRET


def _mega_mlp_kernel(x_ref, w_ref, eps_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    h = x * jax.lax.rsqrt(var + eps_ref[0]) * w_ref[...]
    g = jnp.dot(h, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(h, wu_ref[...], preferred_element_type=jnp.float32)
    act = g * jax.lax.logistic(g) * u
    o_ref[...] = x + jnp.dot(act, wd_ref[...], preferred_element_type=jnp.float32)


def mega_mlp(x, rms_weight, w_gate, w_up, w_down, eps=1e-6):
    """Whole MLP block in one dispatch. x: [M, H]."""
    eps_arr = jnp.asarray([eps], dtype=jnp.float32)
    return pl.pallas_call(
        _mega_mlp_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x, rms_weight, eps_arr, w_gate, w_up, w_down)

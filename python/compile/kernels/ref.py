"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each L1 kernel in this package must
match its oracle under ``assert_allclose`` (pytest, hypothesis sweeps). They
are also used by the L2 model tests to validate fused-vs-unfused equivalence,
mirroring the paper's Appendix N precision validation (max abs diff < 2e-4
within float32 limits).
"""

import jax.numpy as jnp


# ---------------------------------------------------------------- matmul ----
def matmul(x, w):
    """x @ w, float32 accumulate."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


# --------------------------------------------------------------- rmsnorm ----
def rmsnorm(x, weight, eps=1e-6):
    """Fused RMSNorm: x / sqrt(mean(x^2) + eps) * weight."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(var + eps)) * weight


# The paper's unfused RMSNorm decomposition is 6 dispatches:
# pow, mean, add(eps), rsqrt, mul(x), mul(weight)  (§6.1).
def rms_pow(x):
    return jnp.square(x)


def rms_mean(x2):
    return jnp.mean(x2, axis=-1, keepdims=True)


def rms_add_eps(m, eps=1e-6):
    return m + eps


def rms_rsqrt(m):
    return jnp.reciprocal(jnp.sqrt(m))


def rms_mul_x(x, r):
    return x * r  # r broadcasts over the hidden dim


def rms_mul_w(x, weight):
    return x * weight


# --------------------------------------------------------------- softmax ----
def softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


# ----------------------------------------------------------- elementwise ----
def silu(x):
    return x * jnp.reciprocal(1.0 + jnp.exp(-x))


def add(a, b):
    return a + b


def mul(a, b):
    return a * b


def neg(x):
    return -x


def mul_silu(a, b):
    """Paper's fused_mul_silu: silu(a) * b."""
    return silu(a) * b


def add_silu(a, b):
    return silu(a + b)


def add_gelu(a, b):
    x = a + b
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


# -------------------------------------------------------------- fused MLP ---
def mlp_gate_up_silu(x, w_gate, w_up):
    """Paper's MLP fusion: silu(x @ Wg) * (x @ Wu)  (3 dispatches -> 1)."""
    return silu(matmul(x, w_gate)) * matmul(x, w_up)


def mlp_full(x, w_gate, w_up, w_down):
    return matmul(mlp_gate_up_silu(x, w_gate, w_up), w_down)


# -------------------------------------------------------------- fused K+V ---
def kv_proj_fused(x, w_kv):
    """Paper's K+V fusion: both projections in one concatenated matmul."""
    return matmul(x, w_kv)


# ----------------------------------------------------------------- rotary ---
def rotate_half(x):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([-x2, x1], axis=-1)


def rotary(x, cos, sin):
    """Apply rotary embedding; x: [H, D], cos/sin: [D]."""
    return x * cos + rotate_half(x) * sin


def rope_cos_sin(pos, head_dim, theta=10000.0):
    """cos/sin vectors for one position (Qwen half-rotation layout)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    freqs = pos * inv
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    return jnp.cos(emb), jnp.sin(emb)


# ------------------------------------------------------------------ sdpa ----
def sdpa_gqa(q, k_cache, v_cache, pos, kv_heads):
    """Grouped-query attention over a fixed-capacity masked KV cache.

    q:        [H, D]
    k_cache:  [S, KVH, D]
    v_cache:  [S, KVH, D]
    pos:      scalar int — number of valid cache rows (positions 0..pos-1,
              inclusive of the current token already written at pos-1).
    """
    heads, dim = q.shape
    seq = k_cache.shape[0]
    group = heads // kv_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(dim))
    kv_idx = jnp.arange(heads) // group  # which KV head serves each Q head
    k = k_cache[:, kv_idx, :]  # [S, H, D]
    v = v_cache[:, kv_idx, :]
    scores = jnp.einsum("hd,shd->hs", q, k) * scale
    mask = jnp.arange(seq)[None, :] < pos
    scores = jnp.where(mask, scores, -1e30)
    probs = softmax(scores)
    return jnp.einsum("hs,shd->hd", probs, v)


# ----------------------------------------------------------------- concat ---
def concat_last(a, b):
    return jnp.concatenate([a, b], axis=-1)


def cache_update(cache, new_row, pos):
    """Write new_row at cache[pos] (the paper's KV-cache concatenation)."""
    import jax

    return jax.lax.dynamic_update_slice(cache, new_row[None, ...], (pos, 0, 0))


# ----------------------------------------------------------------- argmax ---
def argmax(x):
    return jnp.argmax(x, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------- mega MLP ---
def mega_mlp(x, rms_weight, w_gate, w_up, w_down, eps=1e-6):
    """Appendix C mega-kernel: RMSNorm + SwiGLU MLP + residual in one op."""
    h = rmsnorm(x, rms_weight, eps)
    return x + mlp_full(h, w_gate, w_up, w_down)

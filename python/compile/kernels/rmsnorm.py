"""RMSNorm kernels — both the fused single-dispatch kernel and the paper's
6-dispatch decomposition (pow, mean, add-eps, rsqrt, mul-x, mul-w; §6.1).

The fusion of this decomposition is the paper's single most impactful
optimization: 240 dispatches saved per forward pass on Qwen2.5-0.5B
(24 layers x 2 norms x 5 saved dispatches), +44% tok/s, p < 0.001 (Table 5).
Each decomposed stage is its own Pallas kernel so the Rust coordinator can
issue them as distinct dispatches in the unfused flow.
"""

from .common import jax, jnp, pl, INTERPRET


# ------------------------------------------------------------------ fused ---
def _rmsnorm_kernel(x_ref, w_ref, eps_ref, o_ref):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps_ref[0]) * w_ref[...]


def rmsnorm(x, weight, eps=1e-6):
    """Fused RMSNorm. x: [M, H], weight: [H]."""
    eps_arr = jnp.asarray([eps], dtype=jnp.float32)
    return pl.pallas_call(
        _rmsnorm_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x, weight, eps_arr)


# ----------------------------------------------------------- decomposition --
def _pow_kernel(x_ref, o_ref):
    o_ref[...] = jnp.square(x_ref[...])


def rms_pow(x):
    return pl.pallas_call(
        _pow_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x)


def _mean_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=-1, keepdims=True)


def rms_mean(x2):
    m = x2.shape[0]
    return pl.pallas_call(
        _mean_kernel,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=INTERPRET,
    )(x2)


def _add_eps_kernel(m_ref, eps_ref, o_ref):
    o_ref[...] = m_ref[...] + eps_ref[0]


def rms_add_eps(m, eps=1e-6):
    eps_arr = jnp.asarray([eps], dtype=jnp.float32)
    return pl.pallas_call(
        _add_eps_kernel,
        out_shape=jax.ShapeDtypeStruct(m.shape, jnp.float32),
        interpret=INTERPRET,
    )(m, eps_arr)


def _rsqrt_kernel(m_ref, o_ref):
    o_ref[...] = jax.lax.rsqrt(m_ref[...])


def rms_rsqrt(m):
    return pl.pallas_call(
        _rsqrt_kernel,
        out_shape=jax.ShapeDtypeStruct(m.shape, jnp.float32),
        interpret=INTERPRET,
    )(m)


def _mul_bcast_kernel(x_ref, r_ref, o_ref):
    o_ref[...] = x_ref[...] * r_ref[...]  # r: [M, 1] broadcasts over hidden


def rms_mul_x(x, r):
    return pl.pallas_call(
        _mul_bcast_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x, r)


def _mul_w_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] * w_ref[...]  # w: [H] broadcasts over rows


def rms_mul_w(x, weight):
    return pl.pallas_call(
        _mul_w_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x, weight)


def rmsnorm_unfused(x, weight, eps=1e-6):
    """The full 6-dispatch chain, used to validate fused == unfused."""
    x2 = rms_pow(x)
    m = rms_mean(x2)
    me = rms_add_eps(m, eps)
    r = rms_rsqrt(me)
    xn = rms_mul_x(x, r)
    return rms_mul_w(xn, weight)

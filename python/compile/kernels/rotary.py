"""Rotary position embedding kernels.

``rotary`` is the fused single-dispatch variant. The unfused flow (matching
the FX census, where rotary contributes muls/adds/neg/concat nodes) issues
``neg`` + ``concat`` (rotate-half) + two ``mul`` + one ``add`` as separate
dispatches via the elementwise/concat kernels.
"""

from .common import jax, jnp, pl, INTERPRET


def _rotary_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...]  # [H, D]
    half = x.shape[-1] // 2
    x1 = x[:, :half]
    x2 = x[:, half:]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    o_ref[...] = x * cos_ref[...] + rot * sin_ref[...]


def rotary(x, cos, sin):
    """x: [H, D], cos/sin: [D] -> [H, D]."""
    return pl.pallas_call(
        _rotary_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x, cos, sin)


def _rope_table_kernel(pos_ref, inv_ref, cos_ref, sin_ref):
    # pos: [1] f32; inv: [half] precomputed inverse frequencies.
    freqs = pos_ref[0] * inv_ref[...]
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    cos_ref[...] = jnp.cos(emb)
    sin_ref[...] = jnp.sin(emb)


def rope_cos_sin(pos, inv_freq):
    """Cos/sin vectors for one position. pos: [1] f32, inv_freq: [D/2]."""
    half = inv_freq.shape[0]
    return pl.pallas_call(
        _rope_table_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((2 * half,), jnp.float32),
            jax.ShapeDtypeStruct((2 * half,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(pos, inv_freq)

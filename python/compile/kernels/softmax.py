"""Softmax kernels: naive (three explicit passes through scratch memory, the
analogue of the paper's original slow shader) and parallel (single fused
online pass — the paper's shared-memory 256-thread rewrite that produced the
84x isolated speedup, Table 16)."""

from .common import jax, jnp, pl, INTERPRET


def _softmax_naive_kernel(x_ref, o_ref, m_scr, e_scr):
    # Pass 1: row max into scratch.
    m_scr[...] = jnp.max(x_ref[...], axis=-1, keepdims=True)
    # Pass 2: exponentials into scratch (materialized, like the original
    # shader that round-tripped intermediates through storage buffers).
    e_scr[...] = jnp.exp(x_ref[...] - m_scr[...])
    # Pass 3: normalize.
    o_ref[...] = e_scr[...] / jnp.sum(e_scr[...], axis=-1, keepdims=True)


def softmax_naive(x):
    m, n = x.shape
    return pl.pallas_call(
        _softmax_naive_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pl.MemoryRef(jax.core.ShapedArray((m, 1), jnp.float32), pl.MemorySpace.ANY),
            pl.MemoryRef(jax.core.ShapedArray((m, n), jnp.float32), pl.MemorySpace.ANY),
        ],
        interpret=INTERPRET,
    )(x)


def _softmax_parallel_kernel(x_ref, o_ref):
    x = x_ref[...]
    mx = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - mx)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax(x):
    """Fused single-pass softmax (the optimized variant)."""
    return pl.pallas_call(
        _softmax_parallel_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=INTERPRET,
    )(x)

"""L2 — Qwen2.5-architecture forward pass in JAX, calling the L1 Pallas
kernels so everything lowers into the same HLO.

Two op flows mirror the paper's torch-webgpu backend:

- **unfused**: RMSNorm decomposed into 6 dispatches, K/V projected
  separately, rotary decomposed — the dispatch stream whose census matches
  Table 10 (876 compute ops for Qwen2.5-0.5B).
- **fused**: RMSNorm 6→1, MLP gate+up+silu 3→1, K+V 2→1 (Table 5's 312
  dispatches saved).

The Rust engine normally executes these op-by-op (one PJRT execution per FX
node, one WebGPU dispatch each). ``decode_step_fused`` additionally exports
the *whole* forward as a single HLO module — the graph-compilation baseline
(XLA/TVM/WebLLM-style) that eliminates per-dispatch overhead entirely.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import (
    attention,
    concat,
    elementwise,
    fused_kv,
    fused_mlp,
    matmul,
    rmsnorm,
    rotary,
)


def rope_inv_freq(cfg: ModelConfig):
    half = cfg.head_dim // 2
    return 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )


# --------------------------------------------------------------------------
# Single-layer forward (fused flow), pure function over explicit weights.
# --------------------------------------------------------------------------
def layer_fused(cfg: ModelConfig, x, k_cache, v_cache, pos_i, pos_f, w):
    """One transformer layer, fused op flow.

    x: [1, H]; k_cache/v_cache: [S, KVH, D]; pos_i: [1] i32; pos_f: [1] f32;
    w: dict of this layer's weights.
    """
    h = rmsnorm.rmsnorm(x, w["norm1"], cfg.rms_eps)

    q = matmul.matmul(h, w["wq"])  # [1, QD]
    kv = fused_kv.kv_proj_fused(h, w["wkv"])  # [1, 2*KV]
    k = kv[:, : cfg.kv_dim]
    v = kv[:, cfg.kv_dim :]

    cos, sin = rotary.rope_cos_sin(pos_f, rope_inv_freq(cfg))
    qh = rotary.rotary(q.reshape(cfg.heads, cfg.head_dim), cos, sin)
    kh = rotary.rotary(k.reshape(cfg.kv_heads, cfg.head_dim), cos, sin)

    k_cache = concat.cache_update(k_cache, kh, pos_i)
    v_cache = concat.cache_update(
        v_cache, v.reshape(cfg.kv_heads, cfg.head_dim), pos_i
    )

    attn = attention.sdpa_gqa(qh, k_cache, v_cache, pos_i + 1)
    attn_out = matmul.matmul(attn.reshape(1, cfg.q_dim), w["wo"])
    x = elementwise.add(x, attn_out)

    h2 = rmsnorm.rmsnorm(x, w["norm2"], cfg.rms_eps)
    act = fused_mlp.mlp_gate_up_silu(h2, w["wg"], w["wu"])
    mlp_out = matmul.matmul(act, w["wd"])
    x = elementwise.add(x, mlp_out)
    return x, k_cache, v_cache


def layer_unfused(cfg: ModelConfig, x, k_cache, v_cache, pos_i, pos_f, w):
    """One transformer layer, unfused op flow (paper's baseline stream)."""

    def rms_unfused(t, weight):
        return rmsnorm.rmsnorm_unfused(t, weight, cfg.rms_eps)

    h = rms_unfused(x, w["norm1"])

    q = matmul.matmul(h, w["wq"])
    k = matmul.matmul(h, w["wk"])
    v = matmul.matmul(h, w["wv"])

    cos, sin = rotary.rope_cos_sin(pos_f, rope_inv_freq(cfg))

    def rotary_unfused(t, heads):
        th = t.reshape(heads, cfg.head_dim)
        half = cfg.head_dim // 2
        x2n = elementwise.neg(th[:, half:])
        rot = concat.concat_last(x2n, th[:, :half])
        a = rmsnorm.rms_mul_w(th, cos)  # mul by row vector
        b = rmsnorm.rms_mul_w(rot, sin)
        return elementwise.add(a, b)

    qh = rotary_unfused(q, cfg.heads)
    kh = rotary_unfused(k, cfg.kv_heads)

    k_cache = concat.cache_update(k_cache, kh, pos_i)
    v_cache = concat.cache_update(
        v_cache, v.reshape(cfg.kv_heads, cfg.head_dim), pos_i
    )

    attn = attention.sdpa_gqa(qh, k_cache, v_cache, pos_i + 1)
    attn_out = matmul.matmul(attn.reshape(1, cfg.q_dim), w["wo"])
    x = elementwise.add(x, attn_out)

    h2 = rms_unfused(x, w["norm2"])
    g = matmul.matmul(h2, w["wg"])
    u = matmul.matmul(h2, w["wu"])
    act = elementwise.mul(elementwise.silu(g), u)
    mlp_out = matmul.matmul(act, w["wd"])
    x = elementwise.add(x, mlp_out)
    return x, k_cache, v_cache


# --------------------------------------------------------------------------
# Whole-forward single-HLO export (graph-compiled baseline).
# --------------------------------------------------------------------------
def decode_step_fused(
    cfg: ModelConfig,
    x,           # [1, H] embedded token
    k_caches,    # [L, S, KVH, D]
    v_caches,    # [L, S, KVH, D]
    pos_i,       # [1] int32
    norm1, wq, wkv, wo, norm2, wg, wu, wd,  # stacked per-layer weights [L,...]
    norm_f, w_lm,
):
    pos_f = pos_i.astype(jnp.float32)

    def body(carry, per_layer):
        xc = carry
        n1, q_, kv_, o_, n2, g_, u_, d_, kc, vc = per_layer
        w = {
            "norm1": n1, "wq": q_, "wkv": kv_, "wo": o_,
            "norm2": n2, "wg": g_, "wu": u_, "wd": d_,
        }
        xc, kc, vc = layer_fused(cfg, xc, kc, vc, pos_i, pos_f, w)
        return xc, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (norm1, wq, wkv, wo, norm2, wg, wu, wd, k_caches, v_caches)
    )
    h = rmsnorm.rmsnorm(x, norm_f, cfg.rms_eps)
    logits = matmul.matmul(h, w_lm)
    return logits, new_k, new_v


def decode_step_fused_fn(cfg: ModelConfig):
    """Partially-applied, jit-lowerable decode step for AOT export."""
    return partial(decode_step_fused, cfg)

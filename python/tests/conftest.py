"""Shared pytest fixtures for the L1/L2 test suite."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.configs import QWEN_TINY


@pytest.fixture(scope="session")
def cfg():
    return QWEN_TINY


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)


def randf(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, shape), jnp.float32)


@pytest.fixture()
def tiny_weights(rng, cfg):
    """Deterministic synthetic weights for one tiny layer."""
    w = {
        "norm1": jnp.abs(randf(rng, cfg.hidden, scale=0.5)) + 0.5,
        "wq": randf(rng, cfg.hidden, cfg.q_dim, scale=0.05),
        "wk": randf(rng, cfg.hidden, cfg.kv_dim, scale=0.05),
        "wv": randf(rng, cfg.hidden, cfg.kv_dim, scale=0.05),
        "wo": randf(rng, cfg.q_dim, cfg.hidden, scale=0.05),
        "norm2": jnp.abs(randf(rng, cfg.hidden, scale=0.5)) + 0.5,
        "wg": randf(rng, cfg.hidden, cfg.intermediate, scale=0.05),
        "wu": randf(rng, cfg.hidden, cfg.intermediate, scale=0.05),
        "wd": randf(rng, cfg.intermediate, cfg.hidden, scale=0.05),
    }
    w["wkv"] = jnp.concatenate([w["wk"], w["wv"]], axis=1)
    return w

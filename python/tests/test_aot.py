"""AOT exporter tests: registry sanity, HLO text round-trip, manifest
integrity. The HLO-text interchange is the load-bearing bridge to Rust."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import matmul

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def registry():
    return aot.build_registry()


def test_registry_names_unique(registry):
    names = [e.name for e in registry]
    assert len(names) == len(set(names))


def test_registry_covers_required_kernels(registry):
    names = {e.name for e in registry}
    required = {
        # tiny decode stream
        "matmul_64_64", "matmul_64_32", "matmul_64_176", "matmul_176_64",
        "matmul_64_512", "kv_fused_64_64", "rmsnorm_64", "rms_pow_64",
        "rms_mean_64", "rms_add_eps_1", "rms_rsqrt_1", "rms_mul_x_64",
        "rms_mul_w_64", "rope_cos_sin_16", "rotary_4_16", "rotary_2_16",
        "cache_update_tiny", "sdpa_tiny", "silu_176", "mul_176", "add_64",
        "gate_up_silu_tiny", "argmax_512", "decode_step_tiny",
        # paper-dimension bench kernels
        "matmul_896_896_4864", "matmul_896_4864_896", "matmul_256_256_256",
        "rmsnorm_896", "gate_up_silu_05b", "mega_mlp_05b",
        "softmax_151936", "softmax_naive_151936", "argmax_151936",
    }
    missing = required - names
    assert not missing, f"registry missing {sorted(missing)}"


def test_lower_produces_hlo_text(registry):
    entry = next(e for e in registry if e.name == "rmsnorm_64")
    text = aot.to_hlo_text(entry.lower())
    assert "HloModule" in text
    assert "ENTRY" in text
    # outputs recorded by lower()
    assert entry.out_specs and entry.out_specs[0].shape == (1, 64)


def test_exported_hlo_is_tuple_rooted(registry):
    """Rust unwraps with to_tupleN — the root must be a tuple."""
    entry = next(e for e in registry if e.name == "add_64")
    text = aot.to_hlo_text(entry.lower())
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert any("tuple" in l for l in root_lines), root_lines


def test_flops_annotations(registry):
    e = next(e for e in registry if e.name == "matmul_896_896_4864")
    assert e.flops == 2 * 896 * 896 * 4864


def test_export_single_kernel_roundtrip(tmp_path):
    """Export one kernel and re-execute its HLO through jax's own client —
    the same text the Rust PJRT client consumes."""
    from jax._src.lib import xla_client as xc

    x = jnp.asarray(np.arange(8, dtype=np.float32).reshape(2, 4)) / 10
    w = jnp.asarray(np.arange(12, dtype=np.float32).reshape(4, 3)) / 10
    lowered = jax.jit(lambda a, b: (matmul.matmul(a, b),)).lower(
        jax.ShapeDtypeStruct((2, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 3), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    (tmp_path / "k.hlo.txt").write_text(text)
    # re-parse: the text parser must accept what we emitted
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=True,
    )
    assert "HloModule" in comp.as_hlo_text()


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    def test_manifest_matches_files(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        assert manifest["version"] == 1
        for k in manifest["kernels"]:
            f = ARTIFACTS / k["file"]
            assert f.exists(), f"missing {k['file']}"
            assert f.stat().st_size == k["hlo_bytes"]

    def test_manifest_configs_present(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        for name in ("qwen2.5-0.5b", "qwen2.5-1.5b", "qwen-tiny"):
            assert name in manifest["configs"]
        tiny = manifest["configs"]["qwen-tiny"]
        assert tiny["q_dim"] == tiny["heads"] * tiny["head_dim"]

    def test_manifest_io_specs_complete(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        for k in manifest["kernels"]:
            assert k["inputs"], k["name"]
            assert k["outputs"], k["name"]
            for s in k["inputs"] + k["outputs"]:
                assert s["dtype"] in ("f32", "i32")

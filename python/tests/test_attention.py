"""SDPA GQA kernel vs oracle, masking semantics, cache padding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import attention, ref


def _setup(seed, heads=4, kv_heads=2, dim=16, seq=32):
    k0 = jax.random.PRNGKey(seed)
    ks = jax.random.split(k0, 3)
    q = jax.random.normal(ks[0], (heads, dim), jnp.float32)
    kc = jax.random.normal(ks[1], (seq, kv_heads, dim), jnp.float32)
    vc = jax.random.normal(ks[2], (seq, kv_heads, dim), jnp.float32)
    return q, kc, vc


@pytest.mark.parametrize("pos", [1, 3, 17, 32])
def test_sdpa_matches_oracle(pos):
    q, kc, vc = _setup(pos)
    got = attention.sdpa_gqa(q, kc, vc, jnp.asarray([pos], jnp.int32))
    want = ref.sdpa_gqa(q, kc, vc, pos, kv_heads=2)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("heads,kv_heads", [(4, 4), (4, 2), (8, 2), (4, 1)])
def test_gqa_group_routing(heads, kv_heads):
    q, kc, vc = _setup(9, heads=heads, kv_heads=kv_heads)
    got = attention.sdpa_gqa(q, kc, vc, jnp.asarray([10], jnp.int32))
    want = ref.sdpa_gqa(q, kc, vc, 10, kv_heads=kv_heads)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-5)


def test_mask_ignores_padding():
    """Garbage beyond pos must not leak into the output (fixed-capacity
    cache semantics — the WebGPU pre-allocated storage buffer analogue)."""
    q, kc, vc = _setup(11)
    pos = 5
    poisoned_k = kc.at[pos:].set(1e6)
    poisoned_v = vc.at[pos:].set(-1e6)
    clean = attention.sdpa_gqa(q, kc, vc, jnp.asarray([pos], jnp.int32))
    dirty = attention.sdpa_gqa(
        q, poisoned_k, poisoned_v, jnp.asarray([pos], jnp.int32)
    )
    np.testing.assert_allclose(np.array(clean), np.array(dirty), rtol=1e-6)


def test_single_position_attends_fully():
    """pos=1: output must equal v[0] exactly (softmax over one element)."""
    q, kc, vc = _setup(13)
    out = np.array(attention.sdpa_gqa(q, kc, vc, jnp.asarray([1], jnp.int32)))
    v0 = np.array(vc[0])  # [KVH, D]
    group = 4 // 2
    for h in range(4):
        np.testing.assert_allclose(out[h], v0[h // group], rtol=1e-5)

"""KV-cache update and argmax kernels."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import argmax, concat, ref


def test_cache_update_writes_row():
    cache = jnp.zeros((16, 2, 8), jnp.float32)
    row = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    out = np.array(concat.cache_update(cache, row, jnp.asarray([5], jnp.int32)))
    np.testing.assert_allclose(out[5], np.array(row), rtol=1e-6)
    assert np.all(out[:5] == 0) and np.all(out[6:] == 0)


@pytest.mark.parametrize("pos", [0, 7, 15])
def test_cache_update_matches_oracle(pos):
    cache = jax.random.normal(jax.random.PRNGKey(1), (16, 2, 8))
    row = jax.random.normal(jax.random.PRNGKey(2), (2, 8))
    got = concat.cache_update(cache, row, jnp.asarray([pos], jnp.int32))
    want = ref.cache_update(cache, row, pos)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-6)


def test_cache_update_sequence_fills_in_order():
    cache = jnp.zeros((8, 1, 4), jnp.float32)
    for p in range(8):
        row = jnp.full((1, 4), float(p + 1), jnp.float32)
        cache = concat.cache_update(cache, row, jnp.asarray([p], jnp.int32))
    out = np.array(cache)
    for p in range(8):
        assert np.all(out[p] == p + 1)


def test_concat_last():
    a = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
    b = -jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    out = np.array(concat.concat_last(a, b))
    np.testing.assert_allclose(out, np.concatenate([a, b], axis=-1))


@pytest.mark.parametrize("v", [16, 512, 151936])
def test_argmax_matches_oracle(v):
    x = jax.random.normal(jax.random.PRNGKey(v), (1, v))
    got = int(argmax.argmax_device(x)[0])
    assert got == int(jnp.argmax(x))


def test_argmax_ties_take_first():
    x = jnp.asarray([[1.0, 3.0, 3.0, 0.0]], jnp.float32)
    assert int(argmax.argmax_device(x)[0]) == 1


def test_argmax_peak_position():
    x = jnp.zeros((1, 100), jnp.float32).at[0, 63].set(10.0)
    assert int(argmax.argmax_device(x)[0]) == 63

"""Elementwise kernels + the paper's small elementwise fusions (§6.1)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import elementwise as ew, ref


def _pair(rng, shape=(2, 64)):
    a = jnp.asarray(rng.normal(0, 2, shape), jnp.float32)
    b = jnp.asarray(rng.normal(0, 2, shape), jnp.float32)
    return a, b


@pytest.mark.parametrize(
    "name", ["silu", "neg", "add", "mul", "mul_silu", "add_silu", "add_gelu"]
)
def test_matches_oracle(name):
    rng = np.random.default_rng(hash(name) % 2**31)
    a, b = _pair(rng)
    kern = getattr(ew, name)
    oracle = getattr(ref, name)
    got = kern(a) if name in ("silu", "neg") else kern(a, b)
    want = oracle(a) if name in ("silu", "neg") else oracle(a, b)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-6)


def test_binary_shape_mismatch_raises():
    rng = np.random.default_rng(1)
    a = jnp.zeros((2, 4), jnp.float32)
    b = jnp.zeros((2, 5), jnp.float32)
    with pytest.raises(AssertionError):
        ew.add(a, b)


def test_silu_properties():
    x = jnp.asarray(np.linspace(-10, 10, 101), jnp.float32).reshape(1, -1)
    y = np.array(ew.silu(x))
    # silu(0) = 0; silu(x) -> x for large x; silu(x) -> 0 for very negative x
    assert abs(y[0, 50]) < 1e-6
    np.testing.assert_allclose(y[0, -1], 10.0, rtol=1e-3)
    assert abs(y[0, 0]) < 1e-3


def test_fused_mul_silu_equals_composition():
    """fused_mul_silu(a, b) == mul(silu(a), b) — dispatch fusion only."""
    rng = np.random.default_rng(5)
    a, b = _pair(rng)
    np.testing.assert_allclose(
        np.array(ew.mul_silu(a, b)), np.array(ew.mul(ew.silu(a), b)),
        rtol=1e-6, atol=1e-7,
    )

"""Fused kernels (MLP gate+up+silu, K+V projection, mega-MLP) vs their
unfused compositions — fusion must be numerics-preserving (Appendix N)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import (
    elementwise,
    fused_kv,
    fused_mlp,
    matmul,
    mega_mlp,
    ref,
    rmsnorm,
)


def _w(seed, *shape, scale=0.08):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@pytest.mark.parametrize("h,i", [(64, 176), (32, 64), (896, 4864)])
def test_mlp_fusion_matches_oracle(h, i):
    x = _w(1, 1, h, scale=1.0)
    wg, wu = _w(2, h, i), _w(3, h, i)
    got = fused_mlp.mlp_gate_up_silu(x, wg, wu)
    want = ref.mlp_gate_up_silu(x, wg, wu)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=1e-5)


def test_mlp_fusion_matches_unfused_dispatches():
    """fused == matmul + matmul + silu + mul (3 dispatches saved -> 1)."""
    x = _w(4, 1, 64, scale=1.0)
    wg, wu = _w(5, 64, 176), _w(6, 64, 176)
    g = matmul.matmul(x, wg)
    u = matmul.matmul(x, wu)
    unfused = elementwise.mul(elementwise.silu(g), u)
    fused = fused_mlp.mlp_gate_up_silu(x, wg, wu)
    assert np.max(np.abs(np.array(fused) - np.array(unfused))) < 2e-4


def test_kv_fusion_matches_separate_projections():
    """Concatenated-weight KV matmul == separate K and V matmuls."""
    x = _w(7, 1, 64, scale=1.0)
    wk, wv = _w(8, 64, 32), _w(9, 64, 32)
    wkv = jnp.concatenate([wk, wv], axis=1)
    fused = np.array(fused_kv.kv_proj_fused(x, wkv))
    k = np.array(matmul.matmul(x, wk))
    v = np.array(matmul.matmul(x, wv))
    np.testing.assert_allclose(fused[:, :32], k, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fused[:, 32:], v, rtol=1e-5, atol=1e-6)


def test_mega_mlp_matches_oracle():
    x = _w(10, 1, 64, scale=1.0)
    w = jnp.abs(_w(11, 64, scale=0.5)) + 0.5
    wg, wu, wd = _w(12, 64, 176), _w(13, 64, 176), _w(14, 176, 64)
    got = mega_mlp.mega_mlp(x, w, wg, wu, wd)
    want = ref.mega_mlp(x, w, wg, wu, wd)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=1e-5)


def test_mega_mlp_matches_7_dispatch_chain():
    """mega (1 dispatch) == rmsnorm + gate + up + silu + mul + down + add."""
    x = _w(15, 1, 64, scale=1.0)
    w = jnp.abs(_w(16, 64, scale=0.5)) + 0.5
    wg, wu, wd = _w(17, 64, 176), _w(18, 64, 176), _w(19, 176, 64)
    h = rmsnorm.rmsnorm(x, w)
    g = matmul.matmul(h, wg)
    u = matmul.matmul(h, wu)
    act = elementwise.mul(elementwise.silu(g), u)
    down = matmul.matmul(act, wd)
    unfused = elementwise.add(x, down)
    fused = mega_mlp.mega_mlp(x, w, wg, wu, wd)
    assert np.max(np.abs(np.array(fused) - np.array(unfused))) < 2e-4

"""Hypothesis sweeps: kernels vs oracles across randomized shapes/values.

The system prompt for this reproduction requires hypothesis-driven shape
sweeps on the Pallas kernels with assert_allclose against ref.py — these are
the property-based analogue of the paper's Appendix N precision validation.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    elementwise,
    matmul,
    ref,
    rmsnorm,
    softmax,
)

_dims = st.integers(min_value=1, max_value=96)
_small = st.integers(min_value=1, max_value=8)
_seed = st.integers(min_value=0, max_value=2**31 - 1)

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(seed, *shape, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)


@given(m=_small, k=_dims, n=_dims, seed=_seed)
@settings(**SETTINGS)
def test_matmul_any_shape(m, k, n, seed):
    x, w = _arr(seed, m, k), _arr(seed + 1, k, n)
    got = np.array(matmul.matmul(x, w))
    want = np.array(ref.matmul(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(m=_small, h=_dims, seed=_seed)
@settings(**SETTINGS)
def test_rmsnorm_any_shape(m, h, seed):
    x = _arr(seed, m, h)
    w = jnp.asarray(np.random.default_rng(seed + 2).uniform(0.5, 1.5, (h,)),
                    jnp.float32)
    got = np.array(rmsnorm.rmsnorm(x, w))
    want = np.array(ref.rmsnorm(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(m=_small, h=_dims, seed=_seed)
@settings(**SETTINGS)
def test_rmsnorm_fused_equals_unfused(m, h, seed):
    x = _arr(seed, m, h)
    w = jnp.asarray(np.random.default_rng(seed + 3).uniform(0.5, 1.5, (h,)),
                    jnp.float32)
    fused = np.array(rmsnorm.rmsnorm(x, w))
    unfused = np.array(rmsnorm.rmsnorm_unfused(x, w))
    assert np.max(np.abs(fused - unfused)) < 2e-4  # paper Appendix N bound


@given(m=_small, n=_dims, seed=_seed, shift=st.floats(-50, 50))
@settings(**SETTINGS)
def test_softmax_any_shape(m, n, seed, shift):
    x = _arr(seed, m, n) + shift
    got = np.array(softmax.softmax(x))
    np.testing.assert_allclose(got, np.array(ref.softmax(x)), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(got.sum(axis=-1), np.ones(m), rtol=1e-4)


@given(m=_small, n=_dims, seed=_seed)
@settings(**SETTINGS)
def test_elementwise_fusions(m, n, seed):
    a, b = _arr(seed, m, n), _arr(seed + 1, m, n)
    np.testing.assert_allclose(
        np.array(elementwise.mul_silu(a, b)), np.array(ref.mul_silu(a, b)),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.array(elementwise.add_silu(a, b)), np.array(ref.add_silu(a, b)),
        rtol=1e-4, atol=1e-5,
    )


@given(
    kv_heads=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    dim=st.sampled_from([8, 16, 32]),
    seq=st.sampled_from([8, 32]),
    data=st.data(),
)
@settings(max_examples=15, deadline=None)
def test_sdpa_any_config(kv_heads, group, dim, seq, data):
    heads = kv_heads * group
    pos = data.draw(st.integers(1, seq))
    seed = data.draw(_seed)
    q = _arr(seed, heads, dim, scale=1.0)
    kc = _arr(seed + 1, seq, kv_heads, dim, scale=1.0)
    vc = _arr(seed + 2, seq, kv_heads, dim, scale=1.0)
    got = np.array(
        attention.sdpa_gqa(q, kc, vc, jnp.asarray([pos], jnp.int32))
    )
    want = np.array(ref.sdpa_gqa(q, kc, vc, pos, kv_heads=kv_heads))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)

"""Tiled Pallas matmul vs pure-jnp oracle (paper Table 8's kernel)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import matmul, ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape), jnp.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 64, 64),     # q/o projection (tiny)
        (1, 64, 32),     # k/v projection
        (1, 64, 176),    # gate/up
        (1, 176, 64),    # down
        (1, 64, 512),    # lm head
        (2, 48, 80),     # non-square, even M
        (16, 16, 16),    # single tile exactly
        (3, 5, 7),       # primes — forces 1-wide blocks
    ],
)
def test_matmul_matches_oracle(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    got = matmul.matmul(x, w)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-5)


def test_matmul_naive_matches_oracle():
    rng = np.random.default_rng(7)
    x, w = _rand(rng, 8, 32), _rand(rng, 32, 24)
    np.testing.assert_allclose(
        np.array(matmul.matmul_naive(x, w)), np.array(ref.matmul(x, w)),
        rtol=2e-5, atol=1e-5,
    )


def test_matmul_tiled_equals_naive():
    rng = np.random.default_rng(8)
    x, w = _rand(rng, 4, 64), _rand(rng, 64, 176)
    np.testing.assert_allclose(
        np.array(matmul.matmul(x, w)), np.array(matmul.matmul_naive(x, w)),
        rtol=1e-6, atol=1e-6,
    )


def test_matmul_block_override():
    rng = np.random.default_rng(9)
    x, w = _rand(rng, 4, 64), _rand(rng, 64, 64)
    for bn in (8, 16, 32, 64):
        got = matmul.matmul(x, w, bm=2, bn=bn)
        np.testing.assert_allclose(
            np.array(got), np.array(ref.matmul(x, w)), rtol=2e-5, atol=1e-5
        )


def test_matmul_shape_mismatch_raises():
    rng = np.random.default_rng(10)
    with pytest.raises(AssertionError):
        matmul.matmul(_rand(rng, 2, 8), _rand(rng, 9, 4))


def test_matmul_identity():
    eye = jnp.eye(32, dtype=jnp.float32)
    rng = np.random.default_rng(11)
    x = _rand(rng, 4, 32)
    np.testing.assert_allclose(
        np.array(matmul.matmul(x, eye)), np.array(x), rtol=1e-6
    )

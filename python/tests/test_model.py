"""L2 model tests: fused/unfused layer equivalence, decode-step consistency,
KV-cache autoregression over the tiny config."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import QWEN_TINY, get_config


def _caches(cfg):
    shape = (cfg.max_seq, cfg.kv_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _pos(p):
    return jnp.asarray([p], jnp.int32), jnp.asarray([float(p)], jnp.float32)


def test_layer_fused_equals_unfused(cfg, tiny_weights, rng):
    x = jnp.asarray(rng.normal(0, 1, (1, cfg.hidden)), jnp.float32)
    kc, vc = _caches(cfg)
    pi, pf = _pos(0)
    xf, kf, vf = model.layer_fused(cfg, x, kc, vc, pi, pf, tiny_weights)
    xu, ku, vu = model.layer_unfused(cfg, x, kc, vc, pi, pf, tiny_weights)
    np.testing.assert_allclose(np.array(xf), np.array(xu), rtol=1e-4, atol=2e-5)
    np.testing.assert_allclose(np.array(kf), np.array(ku), rtol=1e-4, atol=2e-5)
    np.testing.assert_allclose(np.array(vf), np.array(vu), rtol=1e-4, atol=2e-5)


def test_layer_updates_cache_at_pos(cfg, tiny_weights, rng):
    x = jnp.asarray(rng.normal(0, 1, (1, cfg.hidden)), jnp.float32)
    kc, vc = _caches(cfg)
    pi, pf = _pos(3)
    _, kf, vf = model.layer_fused(cfg, x, kc, vc, pi, pf, tiny_weights)
    kf, vf = np.array(kf), np.array(vf)
    assert np.any(kf[3] != 0) and np.any(vf[3] != 0)
    assert np.all(kf[:3] == 0) and np.all(kf[4:] == 0)


def test_layer_output_deterministic(cfg, tiny_weights, rng):
    x = jnp.asarray(rng.normal(0, 1, (1, cfg.hidden)), jnp.float32)
    kc, vc = _caches(cfg)
    pi, pf = _pos(0)
    a, _, _ = model.layer_fused(cfg, x, kc, vc, pi, pf, tiny_weights)
    b, _, _ = model.layer_fused(cfg, x, kc, vc, pi, pf, tiny_weights)
    np.testing.assert_array_equal(np.array(a), np.array(b))


def test_decode_step_shapes(cfg, tiny_weights, rng):
    L, S = cfg.layers, cfg.max_seq
    stack = lambda a: jnp.stack([a] * L)
    kc, vc = _caches(cfg)
    x = jnp.asarray(rng.normal(0, 1, (1, cfg.hidden)), jnp.float32)
    pi, _ = _pos(0)
    logits, nk, nv = model.decode_step_fused(
        cfg, x, stack(kc), stack(vc), pi,
        stack(tiny_weights["norm1"]), stack(tiny_weights["wq"]),
        stack(tiny_weights["wkv"]), stack(tiny_weights["wo"]),
        stack(tiny_weights["norm2"]), stack(tiny_weights["wg"]),
        stack(tiny_weights["wu"]), stack(tiny_weights["wd"]),
        jnp.ones((cfg.hidden,), jnp.float32),
        jnp.asarray(rng.normal(0, 0.05, (cfg.hidden, cfg.vocab)), jnp.float32),
    )
    assert logits.shape == (1, cfg.vocab)
    assert nk.shape == (L, S, cfg.kv_heads, cfg.head_dim)
    assert nv.shape == nk.shape
    assert np.isfinite(np.array(logits)).all()


def test_configs_registered():
    for name in ("qwen2.5-0.5b", "qwen2.5-1.5b", "qwen-tiny"):
        c = get_config(name)
        assert c.q_dim == c.heads * c.head_dim
        assert c.kv_dim == c.kv_heads * c.head_dim
        assert c.heads % c.kv_heads == 0


def test_paper_config_dims():
    """Table 10's census depends on these exact dims — pin them."""
    c05 = get_config("qwen2.5-0.5b")
    assert (c05.layers, c05.hidden, c05.intermediate) == (24, 896, 4864)
    assert c05.vocab == 151936
    c15 = get_config("qwen2.5-1.5b")
    assert (c15.layers, c15.hidden, c15.intermediate) == (28, 1536, 8960)


def test_unknown_config_raises():
    with pytest.raises(KeyError):
        get_config("qwen-99b")


def test_rope_inv_freq_monotone():
    inv = np.array(model.rope_inv_freq(QWEN_TINY))
    assert inv.shape == (QWEN_TINY.head_dim // 2,)
    assert np.all(np.diff(inv) < 0) and inv[0] == 1.0

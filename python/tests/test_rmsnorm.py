"""RMSNorm kernels: fused, 6-op decomposition, and their equivalence —
the paper's highest-impact fusion (§6.1, +44%, p<0.001)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref, rmsnorm


def _xw(rng, m=1, h=64):
    x = jnp.asarray(rng.normal(0, 1, (m, h)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, (h,)), jnp.float32)
    return x, w


@pytest.mark.parametrize("m,h", [(1, 64), (1, 896), (4, 128), (2, 32)])
def test_fused_matches_oracle(m, h):
    rng = np.random.default_rng(h + m)
    x, w = _xw(rng, m, h)
    np.testing.assert_allclose(
        np.array(rmsnorm.rmsnorm(x, w)), np.array(ref.rmsnorm(x, w)),
        rtol=2e-5, atol=1e-6,
    )


def test_unfused_chain_matches_oracle():
    rng = np.random.default_rng(42)
    x, w = _xw(rng)
    np.testing.assert_allclose(
        np.array(rmsnorm.rmsnorm_unfused(x, w)), np.array(ref.rmsnorm(x, w)),
        rtol=2e-5, atol=1e-6,
    )


def test_fused_equals_unfused():
    """The paper's fusion must not change numerics (Appendix N)."""
    rng = np.random.default_rng(43)
    x, w = _xw(rng, 1, 896)
    fused = np.array(rmsnorm.rmsnorm(x, w))
    unfused = np.array(rmsnorm.rmsnorm_unfused(x, w))
    assert np.max(np.abs(fused - unfused)) < 2e-4  # paper's threshold


def test_each_stage_matches_oracle():
    rng = np.random.default_rng(44)
    x, w = _xw(rng)
    x2 = rmsnorm.rms_pow(x)
    np.testing.assert_allclose(np.array(x2), np.array(ref.rms_pow(x)), rtol=1e-6)
    m = rmsnorm.rms_mean(x2)
    np.testing.assert_allclose(np.array(m), np.array(ref.rms_mean(x2)), rtol=1e-6)
    me = rmsnorm.rms_add_eps(m)
    np.testing.assert_allclose(np.array(me), np.array(ref.rms_add_eps(m)), rtol=1e-6)
    r = rmsnorm.rms_rsqrt(me)
    np.testing.assert_allclose(np.array(r), np.array(ref.rms_rsqrt(me)), rtol=1e-5)
    xn = rmsnorm.rms_mul_x(x, r)
    np.testing.assert_allclose(np.array(xn), np.array(ref.rms_mul_x(x, r)), rtol=1e-6)
    out = rmsnorm.rms_mul_w(xn, w)
    np.testing.assert_allclose(np.array(out), np.array(ref.rms_mul_w(xn, w)), rtol=1e-6)


def test_scale_invariance():
    """RMSNorm(c*x) == RMSNorm(x) for c > 0 (up to float error)."""
    rng = np.random.default_rng(45)
    x, w = _xw(rng)
    a = np.array(rmsnorm.rmsnorm(x, w))
    b = np.array(rmsnorm.rmsnorm(x * 7.5, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_unit_weight_gives_unit_rms():
    rng = np.random.default_rng(46)
    x = jnp.asarray(rng.normal(0, 3, (1, 256)), jnp.float32)
    w = jnp.ones((256,), jnp.float32)
    out = np.array(rmsnorm.rmsnorm(x, w))
    rms = np.sqrt(np.mean(out**2))
    assert abs(rms - 1.0) < 1e-3

"""Rotary embedding kernels: fused kernel, cos/sin table kernel, and the
unfused neg/concat/mul/add decomposition used by the unfused op flow."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import concat, elementwise, ref, rmsnorm, rotary


@pytest.mark.parametrize("pos", [0.0, 1.0, 17.0, 63.0])
def test_rope_table_matches_oracle(pos):
    dim = 16
    half = dim // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    cos, sin = rotary.rope_cos_sin(jnp.asarray([pos], jnp.float32), inv)
    rc, rs = ref.rope_cos_sin(pos, dim)
    np.testing.assert_allclose(np.array(cos), np.array(rc), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.array(sin), np.array(rs), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("heads,dim", [(4, 16), (2, 16), (8, 32)])
def test_rotary_matches_oracle(heads, dim):
    x = jax.random.normal(jax.random.PRNGKey(heads * dim), (heads, dim))
    cos, sin = ref.rope_cos_sin(5.0, dim)
    got = rotary.rotary(x, cos, sin)
    want = ref.rotary(x, cos, sin)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


def test_unfused_decomposition_matches_fused():
    """neg + concat + 2 mul + add (5 dispatches) == fused rotary kernel."""
    heads, dim = 4, 16
    half = dim // 2
    x = jax.random.normal(jax.random.PRNGKey(0), (heads, dim))
    cos, sin = ref.rope_cos_sin(9.0, dim)
    # unfused flow, each step a separate Pallas dispatch:
    x2n = elementwise.neg(x[:, half:])
    rot = concat.concat_last(x2n, x[:, :half])
    a = rmsnorm.rms_mul_w(x, cos)
    b = rmsnorm.rms_mul_w(rot, sin)
    unfused = elementwise.add(a, b)
    fused = rotary.rotary(x, cos, sin)
    np.testing.assert_allclose(
        np.array(unfused), np.array(fused), rtol=1e-6, atol=1e-7
    )


def test_rotation_preserves_norm():
    """Rotary is a rotation: per-head L2 norm is preserved."""
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    cos, sin = ref.rope_cos_sin(21.0, 16)
    y = np.array(rotary.rotary(x, cos, sin))
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=-1), np.linalg.norm(np.array(x), axis=-1),
        rtol=1e-5,
    )


def test_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16))
    cos, sin = ref.rope_cos_sin(0.0, 16)
    y = rotary.rotary(x, cos, sin)
    np.testing.assert_allclose(np.array(y), np.array(x), rtol=1e-6, atol=1e-7)

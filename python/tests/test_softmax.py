"""Softmax kernels: naive 3-pass vs fused single-pass (paper's 84x-speedup
experiment, Table 16). Both must agree with the oracle and each other."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref, softmax


@pytest.mark.parametrize("m,n", [(1, 64), (1, 512), (4, 128), (1, 151936)])
def test_softmax_matches_oracle(m, n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, 2, (m, n)), jnp.float32)
    got = np.array(softmax.softmax(x))
    want = np.array(ref.softmax(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("m,n", [(1, 64), (2, 512)])
def test_naive_matches_parallel(m, n):
    rng = np.random.default_rng(n + 1)
    x = jnp.asarray(rng.normal(0, 2, (m, n)), jnp.float32)
    np.testing.assert_allclose(
        np.array(softmax.softmax_naive(x)), np.array(softmax.softmax(x)),
        rtol=1e-6, atol=1e-8,
    )


def test_rows_sum_to_one():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 5, (8, 200)), jnp.float32)
    s = np.array(softmax.softmax(x)).sum(axis=-1)
    np.testing.assert_allclose(s, np.ones(8), rtol=1e-5)


def test_large_logits_stable():
    """Max-subtraction must prevent overflow (the naive shader got this
    right too — instability was not the paper's concern, speed was)."""
    x = jnp.asarray([[1000.0, 999.0, 998.0, -1000.0]], jnp.float32)
    out = np.array(softmax.softmax(x))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-6)


def test_shift_invariance():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (1, 64)), jnp.float32)
    np.testing.assert_allclose(
        np.array(softmax.softmax(x)), np.array(softmax.softmax(x + 123.0)),
        rtol=1e-4, atol=1e-6,
    )

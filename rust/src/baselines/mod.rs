//! Baseline backend models for the end-to-end context tables (2, 3, 13, 17).
//!
//! The paper's baselines (CUDA, MPS, CPU, ONNX Runtime, WebLLM) ran on
//! hardware we do not have. Each becomes an analytic per-token model
//!
//! ```text
//! t_token = max(ops x per_op, kernel) - overlap + sync      [ms]
//! ```
//!
//! with parameters calibrated so the modeled tok/s lands on the paper's
//! reported value — and, crucially, the parameters are *mechanistically
//! consistent*: CUDA's 185.5 tok/s at fp16 emerges from 876 eager ops x
//! 7.4 us launch overhead (the paper's Appendix J launch measurement), and
//! unfused torch-webgpu lands within 4% of ONNX Runtime with identical
//! per-op overhead (the paper's §6.3 observation). Simulated runs add the
//! profile's jitter so CI/CV columns are populated the same way the paper's
//! are.

use crate::model::rng::XorShiftRng;
use crate::stats::{summarize, Summary};

#[derive(Debug, Clone)]
pub struct E2EModel {
    pub name: String,
    pub platform: String,
    pub processor: String,
    pub accelerator: String,
    pub dtype: &'static str,
    /// Dispatches (or kernel launches) per token.
    pub ops_per_token: usize,
    /// Per-operation overhead in us (launch/dispatch + framework).
    pub per_op_us: f64,
    /// GPU/CPU kernel time per token (ms) — the compute floor.
    pub kernel_ms: f64,
    /// Pipelining overlap credit (ms).
    pub overlap_ms: f64,
    /// Per-token synchronization (readback/argmax) cost (ms).
    pub sync_ms: f64,
    /// Run-to-run jitter (relative).
    pub jitter_pct: f64,
}

impl E2EModel {
    /// Mean per-token latency (ms).
    pub fn t_token_ms(&self) -> f64 {
        let cpu = self.ops_per_token as f64 * self.per_op_us / 1e3;
        (cpu.max(self.kernel_ms) - self.overlap_ms).max(0.05) + self.sync_ms
    }

    pub fn tok_per_s(&self) -> f64 {
        1e3 / self.t_token_ms()
    }

    /// TTFT for a 5-token prompt + first decode (ms).
    pub fn ttft_ms(&self) -> f64 {
        // Prefill processes the prompt as one extra forward in our
        // token-by-token engine; the paper's TTFT is prefill + first decode.
        self.t_token_ms() * 1.0
    }

    /// Simulate `n` jittered runs of tok/s.
    pub fn simulate(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShiftRng::new(seed);
        (0..n)
            .map(|_| {
                let f = 1.0 + self.jitter_pct * (2.0 * rng.uniform() - 1.0);
                self.tok_per_s() / f
            })
            .collect()
    }

    pub fn summary(&self, n: usize, seed: u64) -> Summary {
        summarize(&self.simulate(n, seed))
    }
}

fn m(
    name: &str,
    platform: &str,
    processor: &str,
    accelerator: &str,
    dtype: &'static str,
    ops: usize,
    per_op_us: f64,
    kernel_ms: f64,
    overlap_ms: f64,
    sync_ms: f64,
    jitter: f64,
) -> E2EModel {
    E2EModel {
        name: name.into(),
        platform: platform.into(),
        processor: processor.into(),
        accelerator: accelerator.into(),
        dtype,
        ops_per_token: ops,
        per_op_us,
        kernel_ms,
        overlap_ms,
        sync_ms,
        jitter_pct: jitter,
    }
}

/// Table 2 rows, Qwen2.5-0.5B block (ops counts from the census: 876
/// unfused, 564 fused).
pub fn table2_05b() -> Vec<E2EModel> {
    vec![
        // CUDA fp16: launch-bound at 7.4 us x 876 eager launches.
        m("CUDA (compiled, RTX 5090)", "Linux", "RTX 5090", "CUDA", "fp16",
          564, 7.4, 3.2, 0.0, 1.2, 0.009),
        m("CUDA (eager, RTX 5090)", "Linux", "RTX 5090", "CUDA", "fp16",
          876, 7.4, 3.2, 2.2, 1.2, 0.004),
        // MPS fp16: higher launch overhead + slower kernels.
        m("MPS (Apple M2)", "macOS", "Apple M2", "MPS", "fp16",
          876, 20.0, 8.0, 0.0, 3.4, 0.009),
        // torch-webgpu fused: 564 ops x ~95 us/op, ~12 ms overlap, sync.
        m("torch-webgpu (fused, RTX 5090)", "Linux", "RTX 5090", "WebGPU/Dawn", "fp32",
          564, 95.0, 14.0, 12.0, 6.0, 0.04),
        m("CPU (AMD Ryzen, eager)", "Linux", "AMD Ryzen 9800X3D", "CPU", "fp32",
          876, 2.0, 71.5, 0.0, 0.0, 0.032),
        // ONNX-RT WebGPU: unfused-count dispatches, same per-op regime.
        m("ONNX Runtime (WebGPU, RTX 5090)", "Linux", "RTX 5090", "WebGPU/ORT", "fp32",
          876, 95.0, 14.0, 12.9, 5.9, 0.011),
    ]
}

/// Table 2 rows, Qwen2.5-1.5B block (ops: 1020 unfused, 656 fused).
pub fn table2_15b() -> Vec<E2EModel> {
    vec![
        m("CUDA (eager, RTX 5090)", "Linux", "RTX 5090", "CUDA", "fp16",
          1020, 7.4, 4.5, 2.1, 1.0, 0.006),
        m("MPS (Apple M2)", "macOS", "Apple M2", "MPS", "fp16",
          1020, 20.0, 41.4, 0.0, 7.1, 0.029),
        m("torch-webgpu (fused, RTX 5090)", "Linux", "RTX 5090", "WebGPU/Dawn", "fp32",
          656, 99.0, 22.0, 15.0, 6.0, 0.038),
        m("torch-webgpu (unfused, RTX 5090)", "Linux", "RTX 5090", "WebGPU/Dawn", "fp32",
          1020, 99.0, 22.0, 11.0, 6.2, 0.009),
    ]
}

/// Table 3: cross-platform (Qwen2.5-0.5B).
pub fn table3() -> (Vec<E2EModel>, Vec<E2EModel>) {
    let gpu = vec![
        m("Linux (primary)", "Linux", "RTX 5090", "CUDA", "fp16",
          876, 7.4, 3.2, 2.2, 1.2, 0.009),
        m("macOS", "macOS", "Apple M2", "MPS", "fp32",
          876, 20.0, 74.0, 0.0, 3.6, 0.055),
        m("Windows 11 (laptop)", "Windows", "RTX PRO 2000", "CUDA", "fp32",
          876, 7.4, 32.5, 0.0, 0.7, 0.033),
    ];
    let cpu = vec![
        m("Linux (primary)", "Linux", "AMD Ryzen 9800X3D", "CPU", "fp32",
          876, 2.0, 71.5, 0.0, 0.0, 0.032),
        m("Windows 11 (laptop)", "Windows", "Intel Core Ultra 7", "CPU", "fp32",
          876, 2.0, 121.7, 0.0, 0.0, 0.087),
        m("macOS", "macOS", "Apple M2", "CPU", "fp32",
          876, 2.0, 159.6, 0.0, 0.0, 0.047),
    ];
    (gpu, cpu)
}

/// Table 13: WebLLM browser decode (q4f16, aggressive TVM fusion -> ~200
/// fused dispatches, zero Python framework overhead).
pub struct WebLlmRow {
    pub model: E2EModel,
    pub browser: String,
    pub qwen: &'static str,
    pub backend: &'static str,
    pub prefill_tok_s: f64,
}

pub fn table13() -> Vec<WebLlmRow> {
    let row = |platform: &str, browser: &str, qwen, backend, ops, per_op, kernel,
               sync, jitter, prefill| WebLlmRow {
        model: m(&format!("{browser} {qwen}"), platform, "", "WebGPU", "q4f16",
                 ops, per_op, kernel, 0.0, sync, jitter),
        browser: browser.into(),
        qwen,
        backend,
        prefill_tok_s: prefill,
    };
    vec![
        // Windows 11 (RTX PRO 2000, D3D12): Chrome dispatch 58.7 us.
        row("Windows", "Chrome 144", "Qwen2.5-0.5B", "D3D12", 200, 58.7, 19.2, 0.4, 0.115, 650.0),
        row("Windows", "Chrome 144", "Qwen2.5-1.5B", "D3D12", 232, 58.7, 21.5, 0.3, 0.138, 350.0),
        row("Windows", "Firefox 147", "Qwen2.5-0.5B", "D3D12", 100, 1036.7, 5.0, 2.2, 0.003, 73.0),
        row("Windows", "Firefox 147", "Qwen2.5-1.5B", "D3D12", 100, 1036.7, 5.0, 2.2, 0.003, 55.0),
        // macOS (Apple M2, Metal): Chrome ~ Safari Metal dispatch ~32 us.
        row("macOS", "Chrome 143", "Qwen2.5-0.5B", "Metal", 200, 32.0, 20.3, 1.2, 0.004, 510.0),
        row("macOS", "Chrome 143", "Qwen2.5-1.5B", "Metal", 232, 32.0, 26.4, 1.4, 0.011, 225.0),
        row("macOS", "Safari 26.2", "Qwen2.5-0.5B", "Metal", 200, 31.7, 22.7, 1.3, 0.012, 257.0),
        row("macOS", "Safari 26.2", "Qwen2.5-1.5B", "Metal", 232, 31.7, 32.3, 1.4, 0.010, 93.0),
        row("macOS", "Firefox 147", "Qwen2.5-0.5B", "Metal", 100, 1038.7, 0.3, 0.0, 0.004, 77.0),
        row("macOS", "Firefox 147", "Qwen2.5-1.5B", "Metal", 100, 1038.7, 0.3, 0.0, 0.007, 58.0),
    ]
}

/// Table 17: CUDA vs WebGPU overhead + fusion comparison (Appendix J).
#[derive(Debug, Clone)]
pub struct CudaComparison {
    pub cuda_launch_us: f64,
    pub cuda_launch_std_us: f64,
    pub webgpu_dispatch_lo_us: f64,
    pub webgpu_dispatch_hi_us: f64,
    pub cuda_rmsnorm_unfused_us: f64,
    pub cuda_rmsnorm_fused_us: f64,
    pub cuda_rmsnorm_compiled_us: f64,
}

impl CudaComparison {
    pub fn paper() -> Self {
        CudaComparison {
            cuda_launch_us: 7.4,
            cuda_launch_std_us: 9.2,
            webgpu_dispatch_lo_us: 24.0,
            webgpu_dispatch_hi_us: 36.0,
            cuda_rmsnorm_unfused_us: 21.3,
            cuda_rmsnorm_fused_us: 23.2,
            cuda_rmsnorm_compiled_us: 20.9,
        }
    }

    /// CUDA fusion speedup (0.92x in the paper — no benefit).
    pub fn cuda_fusion_speedup(&self) -> f64 {
        self.cuda_rmsnorm_unfused_us / self.cuda_rmsnorm_fused_us
    }

    pub fn overhead_ratio(&self) -> (f64, f64) {
        (
            self.webgpu_dispatch_lo_us / self.cuda_launch_us,
            self.webgpu_dispatch_hi_us / self.cuda_launch_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol_pct: f64) -> bool {
        (a - b).abs() / b < tol_pct
    }

    #[test]
    fn table2_05b_matches_paper_tok_s() {
        let want = [185.5, 182.9, 47.8, 21.0, 13.7, 13.1];
        for (model, w) in table2_05b().iter().zip(want) {
            assert!(
                close(model.tok_per_s(), w, 0.05),
                "{}: {} vs {}",
                model.name,
                model.tok_per_s(),
                w
            );
        }
    }

    #[test]
    fn table2_15b_matches_paper_tok_s() {
        let want = [155.3, 20.6, 17.9, 10.4];
        for (model, w) in table2_15b().iter().zip(want) {
            assert!(
                close(model.tok_per_s(), w, 0.05),
                "{}: {} vs {}",
                model.name,
                model.tok_per_s(),
                w
            );
        }
    }

    #[test]
    fn table3_matches_paper() {
        let (gpu, cpu) = table3();
        let want_gpu = [185.5, 12.9, 30.1];
        let want_cpu = [13.7, 8.1, 6.2];
        for (model, w) in gpu.iter().zip(want_gpu) {
            assert!(close(model.tok_per_s(), w, 0.05), "{}: {}", model.name, model.tok_per_s());
        }
        for (model, w) in cpu.iter().zip(want_cpu) {
            assert!(close(model.tok_per_s(), w, 0.05), "{}: {}", model.name, model.tok_per_s());
        }
    }

    #[test]
    fn cuda_number_is_launch_overhead_consistent() {
        // The mechanistic check: 876 launches x 7.4 us - overlap + sync
        // lands on the paper's 182.9 tok/s without a fudge factor.
        let eager = &table2_05b()[1];
        assert_eq!(eager.ops_per_token, 876);
        assert!((eager.per_op_us - 7.4).abs() < 1e-9);
        assert!(close(eager.tok_per_s(), 182.9, 0.03));
    }

    #[test]
    fn unfused_webgpu_matches_onnx_rt() {
        // Paper §6.3: without fusion torch-webgpu (13.5) ~ ONNX RT (13.1).
        let onnx = &table2_05b()[5];
        let unfused_webgpu = m("x", "", "", "", "fp32", 876, 95.0, 14.0, 12.0, 6.0, 0.0);
        let ratio = unfused_webgpu.tok_per_s() / onnx.tok_per_s();
        assert!((0.95..=1.10).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn webllm_chrome_beats_firefox() {
        let rows = table13();
        let chrome = rows[0].model.tok_per_s();
        let firefox = rows[2].model.tok_per_s();
        assert!(close(chrome, 51.1, 0.06), "chrome {chrome}");
        assert!(close(firefox, 9.1, 0.06), "firefox {firefox}");
        assert!(chrome > 5.0 * firefox);
    }

    #[test]
    fn cuda_comparison_ratios() {
        let c = CudaComparison::paper();
        let (lo, hi) = c.overhead_ratio();
        assert!(lo > 3.0 && hi < 5.0, "{lo} {hi}"); // paper: 3-5x
        let f = c.cuda_fusion_speedup();
        assert!((f - 0.92).abs() < 0.01, "cuda fusion {f}");
    }

    #[test]
    fn simulated_runs_have_requested_variance() {
        let model = &table2_05b()[3];
        let s = model.summary(30, 42);
        assert!(close(s.mean, 21.0, 0.08), "mean {}", s.mean);
        assert!(s.cv < 0.05, "cv {}", s.cv);
        assert!(s.ci95_lo < s.mean && s.mean < s.ci95_hi);
    }
}

//! `wdb` command-line interface (hand-rolled parsing — no clap offline).
//!
//! ```text
//! wdb census [--model NAME]          FX census (Table 10 / Appendix B)
//! wdb table <1..20>                  regenerate one paper table
//! wdb all-tables [--out DIR]         regenerate everything + JSON dumps
//! wdb characterize [--n N]           dispatch overhead sweep (Table 6)
//! wdb profile                        per-phase timeline (Table 20)
//! wdb crossover                      batch crossover analysis (Table 14)
//! wdb sensitivity                    Appendix G sensitivity analysis
//! wdb e2e [options]                  run the REAL tiny engine through PJRT
//!   --fusion unfused|rmsnorm|rmsnorm+mlp|fused   (default fused)
//!   --profile dawn|wgpu|wgpu-metal|safari|firefox|chrome|cuda
//!   --tokens N --runs N --warmup N
//!   --device-argmax                  Appendix H variant
//!   --compare-fusion                 run the Table 5 ablation for real
//!   --measured-kernel-time           feed real PJRT time into the clock
//! ```

use std::collections::HashMap;

use crate::engine::{run_protocol, Engine, EngineConfig};
use crate::fx::builder::{FusionConfig, GraphDims};
use crate::fx::census::Census;
use crate::model::ByteTokenizer;
use crate::profiler::{measure_dispatch_overhead, timeline_rows};
use crate::report::write_results;
use crate::runtime::Registry;
use crate::webgpu::device::KernelTimePolicy;
use crate::webgpu::ImplementationProfile;
use crate::{Error, Result};

pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

pub fn parse_args(argv: &[String]) -> Args {
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
            if takes_value {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { cmd, positional, flags }
}

impl Args {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

pub fn profile_by_name(name: &str) -> Result<ImplementationProfile> {
    Ok(match name {
        "dawn" => ImplementationProfile::dawn_vulkan_rtx5090(),
        "wgpu" => ImplementationProfile::wgpu_vulkan_rtx5090(),
        "wgpu-amd" => ImplementationProfile::wgpu_vulkan_amd_igpu(),
        "wgpu-metal" => ImplementationProfile::wgpu_metal_m2(),
        "chrome" => ImplementationProfile::chrome_vulkan_rtx5090(),
        "safari" => ImplementationProfile::safari_metal_m2(),
        "firefox" => ImplementationProfile::firefox_metal_m2(),
        "cuda" => ImplementationProfile::cuda_rtx5090(),
        "zero" => ImplementationProfile::zero_overhead(),
        other => {
            return Err(Error::Graph(format!(
                "unknown profile '{other}' (dawn|wgpu|wgpu-amd|wgpu-metal|\
                 chrome|safari|firefox|cuda|zero)"
            )))
        }
    })
}

pub fn exec_mode_by_name(name: &str) -> Result<crate::engine::ExecMode> {
    use crate::engine::ExecMode;
    Ok(match name {
        "eager" => ExecMode::Eager,
        "planned" => ExecMode::Planned,
        other => {
            return Err(Error::Graph(format!(
                "unknown exec mode '{other}' (eager|planned)"
            )))
        }
    })
}

pub fn fusion_by_name(name: &str) -> Result<FusionConfig> {
    Ok(match name {
        "unfused" => FusionConfig::unfused(),
        "rmsnorm" => FusionConfig::rmsnorm_only(),
        "rmsnorm+mlp" => FusionConfig::rmsnorm_mlp(),
        "rmsnorm+mlp+kv" => FusionConfig::rmsnorm_mlp_kv(),
        "fused" => FusionConfig::fused(),
        other => {
            return Err(Error::Graph(format!(
                "unknown fusion '{other}' \
                 (unfused|rmsnorm|rmsnorm+mlp|rmsnorm+mlp+kv|fused)"
            )))
        }
    })
}

pub fn run(args: Args) -> Result<()> {
    match args.cmd.as_str() {
        "census" => cmd_census(&args),
        "table" => cmd_table(&args),
        "all-tables" => cmd_all_tables(&args),
        "characterize" => cmd_characterize(&args),
        "profile" => cmd_profile(),
        "crossover" => cmd_table_n(14),
        "sensitivity" => cmd_sensitivity(),
        "e2e" => cmd_e2e(&args),
        "workloads" => cmd_workloads(&args),
        "batch-sweep" => cmd_batch_sweep(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "trace-summary" => cmd_trace_summary(&args),
        "plan-bench" => cmd_plan_bench(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Graph(format!("unknown command '{other}'; see `wdb help`"))),
    }
}

const HELP: &str = "wdb - WebGPU dispatch-overhead characterization stack

Commands:
  census [--model qwen2.5-0.5b]   FX census (Table 10)
  table <1..20>                   regenerate one paper table
  all-tables [--out results]      regenerate every table + JSON dumps
  characterize [--n 200]          dispatch overhead sweep (Table 6)
  profile                         per-phase timeline (Table 20)
  crossover                       batch crossover analysis (Table 14)
  sensitivity                     Appendix G sensitivity analysis
  e2e [--fusion fused] [--profile dawn] [--tokens 50] [--runs 10]
      [--warmup 5] [--device-argmax] [--compare-fusion]
      [--measured-kernel-time]    run the real tiny engine through PJRT
  workloads                       CNN/ViT/U-Net dispatch streams (Table 1*)
  batch-sweep [--reps 5]          empirical crossover validation (App. F)
  serve [--requests 16] [--tokens 10] [--concurrent 4] [--profile dawn]
        [--exec-mode planned] [--batch-width 4 | --no-batch]
        [--prefill-chunk 16] [--no-unified]
        [--kv-block 16 | --no-paged] [--pool-cap-kv N]
        [--speculate K | --no-speculate] [--inject-faults SEED]
        [--trace-out FILE.json] [--trace-ring N]
                                  FIFO request loop over the serving engine
                                  (planned replay + resident KV caches +
                                  UNIFIED continuous-batching rounds — one
                                  [W*C, H] replay per mixed prefill/decode
                                  round — is the serving default; eager /
                                  interleaved / token-by-token prefill /
                                  split prefill-then-decode scheduling
                                  opt-in via --exec-mode eager / --no-batch
                                  / --prefill-chunk 0 / --no-unified;
                                  --speculate K drafts up to K tokens per
                                  session per round via n-gram self-drafting
                                  and verifies them in ONE chunk replay,
                                  default off; --inject-faults SEED arms
                                  a deterministic transient-fault schedule
                                  in the device layer — recovery rolls the
                                  hit sessions back to their last committed
                                  token and replays, never changing the
                                  streams; paged KV residency — fixed
                                  kv_block-token blocks from a shared pool
                                  + per-slot block tables, with a per-block
                                  LRU pager — is the planned default:
                                  --kv-block N picks the block size,
                                  --no-paged restores PR 3 contiguous
                                  sets, --pool-cap-kv N caps the KV pool
                                  at N contiguous sets' bytes in either
                                  layout). The report header prints the
                                  mode that ran plus block-pool high-water
                                  and page-in/out counts, and histogram-
                                  backed TTFT/ITL p50/p99. --trace-out
                                  FILE.json exports the full span trace
                                  (round > chunk > replay > dispatch,
                                  per-slot lanes) as Chrome-trace JSON;
                                  --trace-ring N keeps the most recent N
                                  events in a fixed ring instead (default
                                  sink discards events; histograms always
                                  record). Tracing never perturbs the
                                  virtual clock — token streams are
                                  bit-identical with it on or off.
  serve-bench [--sessions 1,2,4,8] [--tokens 16] [--profile dawn]
              [--exec-mode planned] [--batch-width 4 | --no-batch]
              [--prefill-chunk 16] [--prompt 128] [--no-unified]
              [--kv-block 16 | --no-paged] [--pool-cap-kv N]
              [--speculate K | --no-speculate] [--inject-faults SEED]
              [--trace-out FILE.json] [--trace-ring N]
              [--out DIR]         multi-session serving scaling table:
                                  aggregate tok/s + per-phase attribution
                                  + dispatches/round + tok/round +
                                  acceptance + prefill disp/tok +
                                  upload/resident bytes vs session
                                  count. With batching on, hard-gates
                                  batched dispatches/round <=
                                  interleaved/2 at every N >= 2; with
                                  chunked prefill on and prompt >= 32,
                                  hard-gates chunked prefill dispatches
                                  <= token-by-token/4; with unified
                                  rounds on and prompt >= 2 chunks,
                                  hard-gates mixed-round dispatches/round
                                  <= split scheduling/2 at every N >= 4
                                  under mid-run prompt arrivals; with
                                  --speculate K, hard-gates token-stream
                                  identity vs a --no-speculate twin at
                                  every N (plus tokens/round >= 1.5x the
                                  twin on the repetitive workload:
                                  --prompt 32 with --tokens >= 96); with
                                  --inject-faults SEED, hard-gates token-
                                  stream identity vs a fault-free twin at
                                  every N (faults may cost time, never
                                  tokens) and zero failed sessions; with
                                  paged KV on (the planned default),
                                  hard-gates token-stream identity vs a
                                  --no-paged contiguous twin at every N
                                  and ZERO failed sessions even when
                                  --pool-cap-kv oversubscribes the pool
                                  (admission defers and pages, never
                                  fails). --trace-out FILE.json re-runs
                                  the largest N with the Chrome sink,
                                  hard-gates token-stream + dispatch-count
                                  identity vs the untraced row, and writes
                                  the span trace for `wdb trace-summary`.
  trace-summary FILE.json         validate an exported Chrome trace
                                  (field shape + balanced B/E spans) and
                                  print table T1: the per-phase / per-op
                                  time breakdown reconstructed from spans
                                  alone, plus the tiling proof — sum of
                                  round spans must reproduce the report's
                                  wall clock within 1% (hard error past
                                  that).
  plan-bench [--tokens 8] [--dps 16] [--profile dawn] [--out DIR]
                                  table P1: eager vs planned per-op
                                  framework overhead across workloads x
                                  {fused, unfused}, plan-build vs replay
                                  cost attribution, token-parity check,
                                  plus the batched-vs-interleaved N=4
                                  framework-overhead delta row";

fn dims_by_model(name: &str) -> Result<GraphDims> {
    Ok(match name {
        "qwen2.5-0.5b" => GraphDims::qwen25_05b(),
        "qwen2.5-1.5b" => GraphDims::qwen25_15b(),
        "qwen-tiny" => GraphDims::qwen_tiny(),
        other => return Err(Error::Graph(format!("unknown model '{other}'"))),
    })
}

fn cmd_census(args: &Args) -> Result<()> {
    let model = args.flag("model").unwrap_or("qwen2.5-0.5b");
    let dims = dims_by_model(model)?;
    let c = Census::for_dims(&dims);
    println!("FX census for {model} ({} layers):", c.layers);
    println!("  compute ops          {}", c.compute.total());
    println!("    linear             {}", c.compute.linear);
    println!("    multiply           {}", c.compute.multiply);
    println!("    add                {}", c.compute.add);
    println!("    sdpa               {}", c.compute.sdpa);
    println!("    silu               {}", c.compute.silu);
    println!("    rmsnorm components {}", c.compute.rms_components);
    println!("    concat             {}", c.compute.concat);
    println!("    other              {}", c.compute.other);
    println!("  shape ops            {}", c.shape_ops);
    println!("  placeholders/outputs {}", c.placeholders_outputs);
    println!("  metadata             {}", c.metadata);
    println!("  TOTAL NODES          {}", c.total_nodes());
    println!();
    println!("  unfused dispatches   {}", c.unfused_dispatches());
    let s = c.paper_fusion_savings();
    println!("  fusion savings       rmsnorm {} + mlp {} + kv {} = {}",
             s.rmsnorm, s.mlp, s.kv, s.total());
    println!("  fused dispatches     {}", c.fused_dispatches());
    Ok(())
}

fn cmd_table_n(id: usize) -> Result<()> {
    let t = crate::tables::generate(id)?;
    println!("{}", t.to_markdown());
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id: usize = args
        .positional
        .first()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Graph("usage: wdb table <1..20>".into()))?;
    cmd_table_n(id)
}

fn cmd_all_tables(args: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.flag("out").unwrap_or("results"));
    for id in crate::tables::all_ids() {
        let t = crate::tables::generate(id)?;
        println!("{}", t.to_markdown());
        let path = write_results(&out, &format!("table_{id:02}"), &t.to_json())?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_characterize(args: &Args) -> Result<()> {
    let n = args.flag_usize("n", 200);
    println!("Dispatch-overhead characterization ({n} dispatches per mode)\n");
    println!("{:<28} {:>12} {:>12} {:>9} {:>14}",
             "Implementation", "single (us)", "seq (us)", "ratio", "substrate (us)");
    for p in ImplementationProfile::table6_catalog() {
        let m = measure_dispatch_overhead(p, n)?;
        println!(
            "{:<28} {:>12.1} {:>12.1} {:>8.1}x {:>14.2}",
            m.profile_name, m.single_op_us, m.sequential_us,
            m.overestimate_ratio(), m.real_sequential_us
        );
    }
    Ok(())
}

fn cmd_profile() -> Result<()> {
    let m = measure_dispatch_overhead(ImplementationProfile::wgpu_vulkan_rtx5090(), 100)?;
    println!("Per-dispatch timeline (wgpu/Vulkan profile, 100 dispatches)\n");
    println!("{:<16} {:>12} {:>16} {:>16}", "Phase", "total (us)", "per-disp (us)", "real (us)");
    for (i, (name, total, per)) in timeline_rows(&m.timeline).iter().enumerate() {
        println!(
            "{:<16} {:>12.1} {:>16.2} {:>16.3}",
            name, total, per,
            m.timeline.real_ns[i] as f64 / 1e3 / 100.0
        );
    }
    println!("\nsubmit fraction: {:.0}%",
             m.timeline.virtual_ns[7] as f64 / m.timeline.total_virtual_ns() as f64 * 100.0);
    Ok(())
}

fn cmd_sensitivity() -> Result<()> {
    use crate::crossover::{b_star_sensitivity, CrossoverModel};
    use crate::engine::overhead::OverheadAccounting;
    let a = OverheadAccounting::derive(41.6, 71.4, 564, 876, 23.8);
    println!("Sensitivity analysis (Appendix G)\n");
    println!("per-op overhead: {:.1} us (well-constrained)", a.per_op_overhead_us);
    let (lo, hi) = a.sensitivity(0.20);
    println!("framework component at +/-20%: {lo:.0} - {hi:.0} ms");
    let hi_dispatch = OverheadAccounting::derive(41.6, 71.4, 564, 876, 36.0);
    println!(
        "framework:dispatch ratio: {:.1}x (24 us) .. {:.1}x (36 us)",
        a.framework_component_ms / a.dispatch_component_ms,
        hi_dispatch.framework_component_ms / hi_dispatch.dispatch_component_ms
    );
    let m = CrossoverModel::paper();
    let (blo, bhi) = b_star_sensitivity(&m, 896, 896, 0.20);
    println!("B* for 896x896 at +/-20% overhead: {blo} - {bhi}");
    println!("\nQualitative conclusions stable: per-operation overhead dominates; \
              fusion is the effective intervention.");
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let registry = Registry::open()?;
    let fusion = fusion_by_name(args.flag("fusion").unwrap_or("fused"))?;
    let profile = profile_by_name(args.flag("profile").unwrap_or("dawn"))?;
    let tokens = args.flag_usize("tokens", 50);
    let runs = args.flag_usize("runs", 10);
    let warmup = args.flag_usize("warmup", 5);
    let policy = if args.has("measured-kernel-time") {
        KernelTimePolicy::Measured
    } else {
        KernelTimePolicy::Calibrated
    };

    let tok = ByteTokenizer::new(registry.config("qwen-tiny")?.vocab);
    let prompt = tok.paper_prompt();

    let fusions: Vec<(&str, FusionConfig)> = if args.has("compare-fusion") {
        vec![
            ("unfused", FusionConfig::unfused()),
            ("+rmsnorm", FusionConfig::rmsnorm_only()),
            ("+mlp", FusionConfig::rmsnorm_mlp()),
            ("+kv", FusionConfig::rmsnorm_mlp_kv()),
            ("+rotary", FusionConfig::fused()),
        ]
    } else {
        vec![("selected", fusion)]
    };

    println!(
        "E2E tiny-Qwen decode through PJRT ({} tokens x {} runs, warmup {}, profile {})\n",
        tokens, runs, warmup, profile.name
    );
    println!("{:<12} {:>10} {:>9} {:>18} {:>7} {:>10} {:>11}",
             "config", "disp/step", "tok/s", "95% CI", "CV", "TTFT(ms)", "wall(ms/run)");
    for (name, f) in fusions {
        let cfg = EngineConfig {
            model: "qwen-tiny".into(),
            fusion: f,
            profile: profile.clone(),
            framework_ns_per_op: crate::engine::inference::TORCH_WEBGPU_FRAMEWORK_NS,
            device_argmax: args.has("device-argmax"),
            weight_seed: 0xC0FFEE,
            kernel_time_policy: policy,
            ..EngineConfig::tiny_fused()
        };
        let mut engine = Engine::new(&registry, cfg)?;
        let r = run_protocol(&mut engine, &prompt, tokens, warmup, runs)?;
        println!(
            "{:<12} {:>10} {:>9.1} {:>18} {:>6.1}% {:>10.1} {:>11.1}",
            name,
            r.dispatches_per_step,
            r.tok_per_s.mean,
            format!("[{:.1}, {:.1}]", r.tok_per_s.ci95_lo, r.tok_per_s.ci95_hi),
            r.tok_per_s.cv * 100.0,
            r.ttft_ms.mean,
            r.real_wall_ns_total as f64 / 1e6 / r.runs as f64,
        );
    }
    Ok(())
}

fn cmd_workloads(args: &Args) -> Result<()> {
    use crate::fx::workloads::Workload;
    let _ = args;
    println!("Non-LLM dispatch workloads (paper exp9/exp11/exp13):\n");
    println!(
        "{:<18} {:>11} {:>14} {:>14} {:>14}",
        "workload", "dispatches", "Dawn (us)", "wgpu (us)", "Chrome-D3D12"
    );
    for wl in Workload::all() {
        let n = wl.total_dispatches();
        let mut cells = Vec::new();
        for p in [
            ImplementationProfile::dawn_vulkan_rtx5090(),
            ImplementationProfile::wgpu_vulkan_rtx5090(),
            ImplementationProfile::chrome_d3d12_rtx2000(),
        ] {
            let m = measure_dispatch_overhead(p, n)?;
            cells.push(format!("{:>14.1}", m.sequential_us));
        }
        println!("{:<18} {:>11} {}", wl.name, n, cells.join(" "));
    }
    println!(
        "\nPer-dispatch cost is architecture-independent (24-58 us across \
         these configs) — the paper's Table 1 footnote."
    );
    Ok(())
}

fn cmd_batch_sweep(args: &Args) -> Result<()> {
    use crate::crossover::CrossoverModel;
    use crate::model::rng::XorShiftRng;
    use crate::tensor::Tensor;

    let reps = args.flag_usize("reps", 5);
    let registry = Registry::open()?;
    let mut rng = XorShiftRng::new(0xBA7C);
    let (d_in, d_out) = (896usize, 4864usize);
    let overhead_us = 95.0;

    println!(
        "Empirical crossover sweep (Appendix F future work): MLP up \
         projection {d_in}x{d_out}, real Pallas kernel on this host\n"
    );
    println!(
        "{:>6} {:>14} {:>16} {:>16}",
        "batch", "kernel (us)", "kernel/batch-row", "regime vs 95 us"
    );
    let mut rows = Vec::new();
    for bsz in [1usize, 4, 8, 16, 32, 64] {
        let name = format!("matmul_b{bsz}_896_4864");
        registry.ensure_loaded(&name)?;
        let x = Tensor::f32(vec![bsz, d_in], rng.normal_vec_f32(bsz * d_in, 0.1)).unwrap();
        let w = Tensor::f32(vec![d_in, d_out], rng.normal_vec_f32(d_in * d_out, 0.1)).unwrap();
        let _ = registry.execute(&name, &[x.clone(), w.clone()])?; // warmup
        let mut total = 0u64;
        for _ in 0..reps {
            let (_, ns) = registry.execute(&name, &[x.clone(), w.clone()])?;
            total += ns;
        }
        let us = total as f64 / reps as f64 / 1e3;
        rows.push((bsz, us));
        println!(
            "{:>6} {:>14.1} {:>16.2} {:>16}",
            bsz,
            us,
            us / bsz as f64,
            if us < overhead_us { "overhead-bound" } else { "compute-bound" }
        );
    }
    // Host-throughput-adjusted analytic B*: use the largest batch's
    // incremental throughput as the host's effective rate.
    let (b_last, t_last) = rows[rows.len() - 1];
    let host_tflops = 2.0 * b_last as f64 * d_in as f64 * d_out as f64 / (t_last * 1e-6) / 1e12;
    let host_model = CrossoverModel { overhead_us, throughput_tflops: host_tflops };
    let empirical = rows.iter().find(|(_, us)| *us >= overhead_us).map(|(b, _)| *b);
    println!(
        "\nhost effective throughput: {host_tflops:.3} TFLOP/s -> analytic \
         B* = {}; first compute-bound batch measured: {}",
        host_model.crossover_batch(d_in, d_out),
        empirical.map(|b| b.to_string()).unwrap_or_else(|| ">64".into()),
    );
    println!(
        "paper model (2 TFLOP/s WGSL): B* = {} — same functional form, \
         throughput-scaled.",
        CrossoverModel::paper().crossover_batch(d_in, d_out)
    );
    Ok(())
}

/// Resolve the chunked-prefill size from `--prefill-chunk` (default:
/// [`crate::engine::DEFAULT_PREFILL_CHUNK`]). 0 disables chunking —
/// prompts feed one token per round, the pre-chunking behavior.
fn prefill_chunk_from_flags(args: &Args) -> Result<usize> {
    match args.flag("prefill-chunk") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| Error::Graph(format!("bad --prefill-chunk '{v}'"))),
        None => Ok(crate::engine::DEFAULT_PREFILL_CHUNK),
    }
}

/// Resolve the benchmark prompt: `--prompt N` synthesizes an N-token
/// prompt (deterministic byte pattern); absent, the paper's 5-token
/// prompt is used.
fn prompt_from_flags(args: &Args, tok: &ByteTokenizer) -> Result<Vec<usize>> {
    match args.flag("prompt") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| Error::Graph(format!("bad --prompt '{v}'")))?;
            if n == 0 {
                return Err(Error::Graph("--prompt needs a positive token count".into()));
            }
            Ok((0..n).map(|i| 32 + (i * 7) % 200).collect())
        }
        None => Ok(tok.paper_prompt()),
    }
}

/// Resolve the batched-decode width from `--batch-width` / `--no-batch`
/// (default: [`crate::engine::DEFAULT_BATCH_WIDTH`]). 0 disables batching.
fn batch_width_from_flags(args: &Args) -> Result<usize> {
    if args.has("no-batch") {
        if args.has("batch-width") {
            return Err(Error::Graph(
                "--no-batch conflicts with --batch-width".into(),
            ));
        }
        return Ok(0);
    }
    match args.flag("batch-width") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| Error::Graph(format!("bad --batch-width '{v}'"))),
        None => Ok(crate::engine::DEFAULT_BATCH_WIDTH),
    }
}

/// Resolve the speculative draft length from `--speculate K` /
/// `--no-speculate` (default: 0, off). K >= 1 drafts up to K tokens per
/// session per round and verifies them in one chunk replay; the engine
/// clamps K to `prefill_chunk - 1` and only engages it on the unified
/// scheduling path.
fn speculate_from_flags(args: &Args) -> Result<usize> {
    if args.has("no-speculate") {
        if args.has("speculate") {
            return Err(Error::Graph(
                "--no-speculate conflicts with --speculate".into(),
            ));
        }
        return Ok(0);
    }
    match args.flag("speculate") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| Error::Graph(format!("bad --speculate '{v}'"))),
        None => Ok(0),
    }
}

/// Resolve the paged-KV layout from `--kv-block N` / `--no-paged`
/// (default: paged on at [`crate::engine::DEFAULT_KV_BLOCK`] tokens per
/// block). Returns `(paged, kv_block)` for [`EngineConfig`]; block-size
/// validity (membership in [`crate::fx::KV_BLOCKS`], divides `max_seq`)
/// is enforced by `ServingEngine::new` so every entry point fails the
/// same way.
fn paged_from_flags(args: &Args) -> Result<(bool, usize)> {
    if args.has("no-paged") {
        if args.has("kv-block") {
            return Err(Error::Graph("--no-paged conflicts with --kv-block".into()));
        }
        return Ok((false, 0));
    }
    match args.flag("kv-block") {
        Some(v) => v
            .parse::<usize>()
            .map(|b| (true, b))
            .map_err(|_| Error::Graph(format!("bad --kv-block '{v}'"))),
        None => Ok((true, crate::engine::DEFAULT_KV_BLOCK)),
    }
}

/// Contiguous bytes of one session's full KV-cache set (K + V planes x
/// layers x max_seq rows of f32): the unit `--pool-cap-kv` counts in, so
/// `--pool-cap-kv N` means "device memory for N PR 3 contiguous sessions"
/// in both layouts — equal N is an equal-cap density comparison.
fn kv_set_bytes(dims: &GraphDims) -> usize {
    2 * dims.layers * dims.max_seq * dims.kv_heads * dims.head_dim * 4
}

/// Resolve `--pool-cap-kv N` (default: uncapped). Paged runs translate
/// the cap into a block-group budget the per-block LRU pager spills past
/// (admission defers and pages, never fails); contiguous runs cap the
/// BufferPool the PR 3 way (whole-set evict-to-host).
fn pool_cap_from_flags(args: &Args, dims: &GraphDims) -> Result<Option<usize>> {
    match args.flag("pool-cap-kv") {
        Some(v) => {
            let n = v
                .parse::<usize>()
                .map_err(|_| Error::Graph(format!("bad --pool-cap-kv '{v}'")))?;
            if n == 0 {
                return Err(Error::Graph(
                    "--pool-cap-kv needs a positive contiguous-set count".into(),
                ));
            }
            Ok(Some(n * kv_set_bytes(dims)))
        }
        None => Ok(None),
    }
}

/// Resolve the fault-injection seed from `--inject-faults SEED` (default:
/// off). A seed arms a deterministic transient-fault schedule (dispatch
/// failures, allocation failures, map timeouts) in the device layer;
/// quarantine + snapshot-replay recovery must keep every token stream
/// byte-identical, which `serve-bench` hard-gates against a no-fault twin.
fn fault_seed_from_flags(args: &Args) -> Result<Option<u64>> {
    match args.flag("inject-faults") {
        Some(v) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|_| Error::Graph(format!("bad --inject-faults '{v}' (needs a u64 seed)"))),
        None => Ok(None),
    }
}

/// Resolve the tracer flags: `--trace-out FILE.json` selects the Chrome
/// sink (retain everything, export on exit), `--trace-ring N` alone
/// selects the fixed-capacity ring sink, neither leaves the default Null
/// sink (histograms still record). Returns the config plus the export
/// path, if any.
fn trace_config_from_flags(args: &Args) -> Result<(crate::trace::TraceConfig, Option<String>)> {
    use crate::trace::{TraceConfig, TraceSinkKind};
    let out = args.flag("trace-out").map(str::to_string);
    let ring = match args.flag("trace-ring") {
        Some(v) => {
            let n = v
                .parse::<usize>()
                .map_err(|_| Error::Graph(format!("bad --trace-ring '{v}'")))?;
            if n == 0 {
                return Err(Error::Graph("--trace-ring needs a positive event count".into()));
            }
            Some(n)
        }
        None => None,
    };
    let mut cfg = TraceConfig::default();
    if let Some(n) = ring {
        cfg.sink = TraceSinkKind::Ring;
        cfg.ring = n;
    }
    // --trace-out wins: export needs the full stream retained.
    if out.is_some() {
        cfg.sink = TraceSinkKind::Chrome;
    }
    Ok((cfg, out))
}

/// Write an exported Chrome-trace document, creating parent directories
/// so `--trace-out DIR/trace.json` works before any `--out` dump ran.
fn write_trace_file(path: &str, doc: &crate::report::json::Value) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, crate::report::json::to_string_pretty(doc))?;
    Ok(())
}

/// Fixed seed every serve-bench engine (rows and twins) is reseeded with,
/// so twin runs are comparable call-for-call.
const SERVE_BENCH_SEED: u64 = 0x5EBE;

/// The serve-bench twin-run primitive: build a fresh serving engine with
/// `cfg`, reseed it with the bench seed, submit every `(prompt, tokens)`
/// request in order, and run it dry. Returns the per-request token streams
/// (submission order) plus the report — every delta/gate in the bench
/// compares runs through this one path so twins differ ONLY in config.
fn run_twin(
    registry: &Registry,
    cfg: EngineConfig,
    max_concurrent: usize,
    requests: &[(Vec<usize>, usize)],
) -> Result<(Vec<Vec<usize>>, crate::serve::ServeReport)> {
    use crate::serve::{ServeConfig, ServingEngine};
    let mut se =
        ServingEngine::new(registry, ServeConfig { engine: cfg, max_concurrent })?;
    se.reseed(SERVE_BENCH_SEED);
    let mut ids = Vec::with_capacity(requests.len());
    for (prompt, tokens) in requests {
        ids.push(se.submit(prompt, *tokens)?);
    }
    let report = se.run_to_completion()?;
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| done.iter().find(|s| s.id == *id).unwrap().tokens.clone())
        .collect();
    Ok((toks, report))
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::serve::{ServeConfig, ServingEngine};
    use std::time::Instant;
    let registry = Registry::open()?;
    let n_requests = args.flag_usize("requests", 16);
    let tokens = args.flag_usize("tokens", 10);
    let concurrent = args.flag_usize("concurrent", 4).max(1);
    let profile = profile_by_name(args.flag("profile").unwrap_or("dawn"))?;
    // Planned replay with device-resident KV caches is the serving
    // default; --exec-mode eager keeps the pathology path benchmarkable.
    // Batched rounds are the default above 1 active session; --no-batch
    // restores interleaved per-session replays. With batching AND chunked
    // prefill on, unified rounds subsume both; --no-unified restores the
    // split prefill-then-decode scheduling.
    let exec = match args.flag("exec-mode") {
        Some(m) => exec_mode_by_name(m)?,
        None => crate::engine::ExecMode::serving_default(),
    };
    let batch_width = batch_width_from_flags(args)?;
    let prefill_chunk = prefill_chunk_from_flags(args)?;
    let speculate = speculate_from_flags(args)?;
    let fault_seed = fault_seed_from_flags(args)?;
    let (paged, kv_block) = paged_from_flags(args)?;
    let dims = GraphDims::from_manifest(registry.config("qwen-tiny")?);
    let pool_cap_bytes = pool_cap_from_flags(args, &dims)?;
    let (trace, trace_out) = trace_config_from_flags(args)?;
    let mut se = ServingEngine::new(
        &registry,
        ServeConfig {
            engine: EngineConfig {
                profile: profile.clone(),
                exec,
                batch_width,
                prefill_chunk,
                unified: !args.has("no-unified"),
                speculate,
                fault_seed,
                paged,
                kv_block,
                pool_cap_bytes,
                trace,
                ..EngineConfig::tiny_fused()
            },
            max_concurrent: concurrent,
        },
    )?;
    se.reseed(0x5E11);
    let tok = ByteTokenizer::new(registry.config("qwen-tiny")?.vocab);
    for i in 0..n_requests {
        let prompt =
            tok.encode(&format!("request {i}: the capital of France is"))[..5 + i % 4].to_vec();
        se.submit(&prompt, tokens)?;
    }

    let wall0 = Instant::now();
    let report = se.run_to_completion()?;
    // Self-describing report header: exec mode (and batch width) come from
    // the ServeReport itself, so bench artifacts and logs name the path
    // that actually ran.
    println!(
        "serve report: exec mode {} | {} requests x {tokens} tokens | \
         {} concurrent | profile {}",
        report.mode_label(),
        report.sessions,
        concurrent,
        profile.name
    );
    println!(
        "rounds: {} ({:.1} dispatches/round)",
        report.rounds,
        report.dispatches_per_round()
    );
    if fault_seed.is_some() {
        println!(
            "faults: {} injected, {} retries, {} sessions recovered, {} failed, \
             {} pool evictions",
            report.faults_injected,
            report.retries,
            report.recovered_sessions,
            report.failed_sessions,
            report.pool_evictions
        );
    }
    if report.kv_block > 0 {
        println!(
            "paged KV: block {} tokens ({} B/group), pool high-water {} groups \
             ({:.0} KiB), {} page-ins / {} page-outs, {} sessions resident at peak",
            report.kv_block,
            report.kv_group_bytes,
            report.kv_pool_high_water_groups,
            (report.kv_pool_high_water_groups * report.kv_group_bytes) as f64 / 1024.0,
            report.kv_page_ins,
            report.kv_page_outs,
            report.resident_sessions_hw
        );
    }
    let done = se.drain_finished();
    let mut sorted: Vec<f64> = done
        .iter()
        .map(|s| s.metrics.finished_ns.saturating_sub(s.metrics.enqueued_ns) as f64 / 1e6)
        .collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[(p * (sorted.len() - 1) as f64).round() as usize];
    println!("requests completed: {} ({} tokens)", report.sessions, report.total_tokens);
    if !sorted.is_empty() {
        println!(
            "request latency p50 / p95 / max: {:.1} / {:.1} / {:.1} ms",
            pct(0.50),
            pct(0.95),
            sorted[sorted.len() - 1]
        );
    }
    println!(
        "aggregate throughput: {:.1} tok/s (virtual); mean TTFT {:.1} ms",
        report.agg_tok_per_s, report.mean_ttft_ms
    );
    // Histogram-backed percentiles (log-bucketed, +/-6.25%): the means
    // above stay the pre-v7 compat surface, these are the tail view.
    println!(
        "TTFT p50 / p90 / p99: {:.2} / {:.2} / {:.2} ms | ITL p50 / p99: \
         {:.2} / {:.2} ms (histogram-backed)",
        report.ttft_p50_ms(),
        report.ttft_p90_ms(),
        report.ttft_p99_ms(),
        report.itl_p50_ms(),
        report.itl_p99_ms()
    );
    if trace.sink != crate::trace::TraceSinkKind::Null {
        println!(
            "trace: {} events retained ({} dropped), sink {:?}",
            se.tracer().total_events() - se.tracer().dropped_events(),
            se.tracer().dropped_events(),
            trace.sink
        );
    }
    if let Some(path) = &trace_out {
        let doc = se.export_chrome_trace(&report);
        write_trace_file(path, &doc)?;
        eprintln!("wrote {path}");
    }
    println!("real wall: {:.1} s on this host", wall0.elapsed().as_secs_f64());
    Ok(())
}

/// Parse "1,2,4,8"-style session-count lists.
fn parse_session_counts(s: &str) -> Result<Vec<usize>> {
    let counts: Vec<usize> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            p.trim()
                .parse::<usize>()
                .map_err(|_| Error::Graph(format!("bad session count '{p}'")))
        })
        .collect::<Result<_>>()?;
    if counts.is_empty() || counts.iter().any(|&n| n == 0) {
        return Err(Error::Graph("--sessions needs positive counts".into()));
    }
    Ok(counts)
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let registry = Registry::open()?;
    let tokens = args.flag_usize("tokens", 16);
    let profile = profile_by_name(args.flag("profile").unwrap_or("dawn"))?;
    let counts = parse_session_counts(args.flag("sessions").unwrap_or("1,2,4,8"))?;
    let exec = match args.flag("exec-mode") {
        Some(m) => exec_mode_by_name(m)?,
        None => crate::engine::ExecMode::serving_default(),
    };
    let batch_width = batch_width_from_flags(args)?;
    let prefill_chunk = prefill_chunk_from_flags(args)?;
    let speculate = speculate_from_flags(args)?;
    let tok = ByteTokenizer::new(registry.config("qwen-tiny")?.vocab);
    let prompt = prompt_from_flags(args, &tok)?;
    let unified = !args.has("no-unified");
    let fault_seed = fault_seed_from_flags(args)?;
    let (paged, kv_block) = paged_from_flags(args)?;
    let dims = GraphDims::from_manifest(registry.config("qwen-tiny")?);
    let pool_cap_bytes = pool_cap_from_flags(args, &dims)?;
    let (trace_cfg, trace_out) = trace_config_from_flags(args)?;
    // Bench rows and twins keep the ring/null sink (their engines are
    // throwaway); the Chrome sink runs once in the dedicated --trace-out
    // pass below, gated for identity against its untraced row.
    let row_trace = if trace_cfg.sink == crate::trace::TraceSinkKind::Chrome {
        crate::trace::TraceConfig::default()
    } else {
        trace_cfg
    };
    let ec = EngineConfig {
        profile: profile.clone(),
        exec,
        batch_width,
        prefill_chunk,
        unified,
        speculate,
        fault_seed,
        paged,
        kv_block,
        pool_cap_bytes,
        trace: row_trace,
        ..EngineConfig::tiny_fused()
    };
    // Uniform bench workload: every row/twin submits n copies of this.
    let uniform = |n: usize| vec![(prompt.clone(), tokens); n];

    println!(
        "Serving scaling bench: {} tokens/session, prompt {} tokens, profile {}, \
         exec mode {exec:?}, batch width {batch_width}, prefill chunk {prefill_chunk}, \
         unified rounds {}, paged KV {}, pool cap {}, speculate {speculate}, \
         fault injection {}\n",
        tokens,
        prompt.len(),
        profile.name,
        if unified && batch_width >= 2 && prefill_chunk >= 2 { "on" } else { "off" },
        if paged { format!("block {kv_block}") } else { "off".into() },
        pool_cap_bytes
            .map(|b| format!("{} contiguous sets ({} KiB)", b / kv_set_bytes(&dims), b / 1024))
            .unwrap_or_else(|| "uncapped".into()),
        fault_seed
            .map(|s| format!("seed {s}"))
            .unwrap_or_else(|| "off".into())
    );

    // Single-session engine baseline: the N=1 serving row must match it
    // (same shared-substrate path, same seed, same call sequence).
    let mut engine = Engine::new(&registry, ec.clone())?;
    engine.reseed(SERVE_BENCH_SEED);
    let base = engine.generate(&prompt, tokens)?;

    let mut rows = Vec::with_capacity(counts.len());
    let mut row_toks = Vec::with_capacity(counts.len());
    for &n in &counts {
        let (toks, report) = run_twin(&registry, ec.clone(), n, &uniform(n))?;
        rows.push((n, report));
        row_toks.push(toks);
    }

    let scaling = crate::tables::serving::scaling_table(&rows);
    let phases = crate::tables::serving::phase_attribution_table(&rows);
    println!("{}", scaling.to_markdown());
    println!("{}", phases.to_markdown());
    if rows[0].0 == 1 {
        println!(
            "single-session Engine baseline: {:.1} tok/s — serving N=1 row: \
             {:.1} tok/s (identical substrate path)",
            base.tok_per_s, rows[0].1.agg_tok_per_s
        );
    } else {
        println!(
            "single-session Engine baseline: {:.1} tok/s (add 1 to --sessions \
             for the parity row)",
            base.tok_per_s
        );
    }

    // Self-describing paged-pool summary: block-pool high water, pager
    // traffic, and peak session density per row.
    let paged_b = rows.iter().map(|(_, r)| r.kv_block).max().unwrap_or(0);
    if paged_b > 0 {
        println!();
        for (n, r) in &rows {
            println!(
                "N={n}: block pool high-water {} groups ({:.0} KiB), {} page-ins \
                 / {} page-outs, {} sessions resident at peak, spilled-block HW {}",
                r.kv_pool_high_water_groups,
                (r.kv_pool_high_water_groups * r.kv_group_bytes) as f64 / 1024.0,
                r.kv_page_ins,
                r.kv_page_outs,
                r.resident_sessions_hw,
                r.kv_blocks_spilled_hw,
            );
        }
    }

    if let Some(out) = args.flag("out") {
        let dir = std::path::PathBuf::from(out);
        // Mode-qualified names: planned (batched or interleaved) + eager
        // runs into one --out dir must not overwrite each other's trends;
        // prompt-heavy runs (--prompt) get a _p{len} suffix for the same
        // reason.
        let mode = match exec {
            crate::engine::ExecMode::Eager => "eager",
            crate::engine::ExecMode::Planned
                if unified && batch_width >= 2 && prefill_chunk >= 2 && speculate >= 1 =>
            {
                "planned_spec"
            }
            crate::engine::ExecMode::Planned
                if unified && batch_width >= 2 && prefill_chunk >= 2 =>
            {
                "planned_unified"
            }
            crate::engine::ExecMode::Planned if batch_width >= 2 => "planned_batched",
            crate::engine::ExecMode::Planned => "planned",
        };
        // Paged KV is the planned serving default, but it changes what
        // the residency columns mean — qualify the artifact so paged and
        // --no-paged trends never overwrite each other.
        let mode = if paged_b > 0 { format!("{mode}_paged") } else { mode.to_string() };
        let prompt_tag = if args.has("prompt") {
            format!("_p{}", prompt.len())
        } else {
            String::new()
        };
        // Capped (oversubscription) runs are a different experiment from
        // uncapped density runs: tag them with the set-count cap.
        let cap_tag = args
            .flag("pool-cap-kv")
            .map(|n| format!("_cap{n}"))
            .unwrap_or_default();
        // Fault-injected runs are a different experiment: tag the artifact
        // so a +faults trend never overwrites the fault-free one.
        let fault_tag = fault_seed.map(|s| format!("_f{s}")).unwrap_or_default();
        for t in [&scaling, &phases] {
            let path = write_results(
                &dir,
                &format!("serve_bench_{}_{mode}{prompt_tag}{cap_tag}{fault_tag}", t.id),
                &t.to_json(),
            )?;
            eprintln!("wrote {}", path.display());
        }
    }

    // Dedicated traced run for --trace-out: re-run the largest N with
    // the Chrome sink and hard-gate that tracing changed NOTHING —
    // token streams and dispatch counts must match the untraced row
    // bit-for-bit (instrumentation only reads the virtual clock). The
    // export carries the report's wall clock so `wdb trace-summary` can
    // prove the round spans tile it. Runs before the scheduling gates so
    // a failing gate still leaves the trace for diagnosis.
    if let Some(path) = &trace_out {
        use crate::serve::{ServeConfig, ServingEngine};
        let idx = (0..counts.len()).max_by_key(|&i| counts[i]).unwrap();
        let n_max = counts[idx];
        let mut tcfg = ec.clone();
        tcfg.trace = crate::trace::TraceConfig {
            sink: crate::trace::TraceSinkKind::Chrome,
            ..Default::default()
        };
        let mut se =
            ServingEngine::new(&registry, ServeConfig { engine: tcfg, max_concurrent: n_max })?;
        se.reseed(SERVE_BENCH_SEED);
        let reqs = uniform(n_max);
        let mut ids = Vec::with_capacity(reqs.len());
        for (p, t) in &reqs {
            ids.push(se.submit(p, *t)?);
        }
        let report = se.run_to_completion()?;
        let done = se.drain_finished();
        let toks: Vec<Vec<usize>> = ids
            .iter()
            .map(|id| done.iter().find(|s| s.id == *id).unwrap().tokens.clone())
            .collect();
        if toks != row_toks[idx] {
            return Err(Error::Graph(format!(
                "traced run token streams diverged from the untraced N={n_max} \
                 row — tracing must not perturb the schedule"
            )));
        }
        if report.dispatches != rows[idx].1.dispatches {
            return Err(Error::Graph(format!(
                "traced run dispatch count {} != untraced {} at N={n_max} — \
                 tracing must not add or drop dispatches",
                report.dispatches, rows[idx].1.dispatches
            )));
        }
        let doc = se.export_chrome_trace(&report);
        write_trace_file(path, &doc)?;
        println!(
            "\ntrace identity gate: OK (token streams + dispatch counts \
             bit-identical with the Chrome sink at N={n_max}); {} events retained",
            se.tracer().total_events()
        );
        eprintln!("wrote {path}");
    }

    // Batched-vs-interleaved delta + the HARD dispatch gate: for every
    // multi-session row, an interleaved (--no-batch) twin must pay at
    // least 2x the batched DECODE dispatches. The gate excludes prompt
    // ingestion: with chunked prefill (the default), the prompt phase
    // replays identical per-session prefill chunks in both twins, which
    // would dilute a whole-run ratio below 2x without any decode
    // regression — prompt amortization is owned by the chunked-prefill
    // gate below. Runs after the artifact dump so a failing gate still
    // leaves the JSON for diagnosis. Dispatch-ratio gates only run
    // fault-free: retry replays add dispatches, so a fault-injected run
    // measures recovery (its own gate below), not amortization.
    if exec == crate::engine::ExecMode::Planned && batch_width >= 2 && fault_seed.is_none() {
        println!();
        for (n, r) in &rows {
            if *n < 2 {
                continue;
            }
            // Gate scoping for unified mode: the bench rows then replay
            // the unified graph, which carries one extra last-row
            // dispatch per round vs the batched graph — enough to tip
            // this exact-equality gate at N=2 without any batched-path
            // regression. The batched-vs-interleaved gate measures the
            // BATCHED path, so under unified the batched side re-runs as
            // a `--no-unified` twin (decode-equivalent dispatches); the
            // unified mode has its own mixed-round gate below.
            let br_owned;
            let br = if unified && prefill_chunk >= 2 {
                let mut bcfg = ec.clone();
                bcfg.unified = false;
                br_owned = run_twin(&registry, bcfg, *n, &uniform(*n))?.1;
                &br_owned
            } else {
                r
            };
            let mut twin_cfg = ec.clone();
            twin_cfg.batch_width = 0;
            let (_, ir) = run_twin(&registry, twin_cfg, *n, &uniform(*n))?;
            let b_decode = br.dispatches - br.prefill_dispatches;
            let i_decode = ir.dispatches - ir.prefill_dispatches;
            println!(
                "N={n}: batched {:.1} vs interleaved {:.1} dispatches/round \
                 ({:.1}x fewer; decode-only {b_decode} vs {i_decode}), \
                 framework {:.2} -> {:.2} us/tok",
                br.dispatches_per_round(),
                ir.dispatches_per_round(),
                ir.dispatches_per_round() / br.dispatches_per_round().max(1e-9),
                ir.us_per_token(ir.framework_virtual_ns),
                br.us_per_token(br.framework_virtual_ns),
            );
            if b_decode * 2 > i_decode {
                return Err(Error::Graph(format!(
                    "batched dispatch gate failed at N={n}: {b_decode} decode \
                     dispatches > interleaved {i_decode} / 2"
                )));
            }
        }
        println!(
            "batched dispatch gate: OK (batched decode dispatches <= \
             interleaved/2 at every N >= 2)"
        );
    }

    // Chunked-prefill delta + HARD gate: for long prompts (>= 32 tokens,
    // where the amortization is unambiguous), chunked prefill must issue
    // at most 1/4 of the dispatches a pure token-by-token twin
    // (--prefill-chunk 0 AND --no-batch, so prompt steps are un-amortized
    // per-session decode steps) spends on prompt ingestion.
    if exec == crate::engine::ExecMode::Planned
        && prefill_chunk >= 2
        && prompt.len() >= 32
        && fault_seed.is_none()
    {
        println!();
        for (n, r) in &rows {
            let mut twin_cfg = ec.clone();
            twin_cfg.prefill_chunk = 0;
            twin_cfg.batch_width = 0;
            let (_, tr) = run_twin(&registry, twin_cfg, *n, &uniform(*n))?;
            println!(
                "N={n}: prefill dispatches chunked {} vs token-by-token {} \
                 ({:.1}x fewer; {:.2} vs {:.2} disp per prompt token), \
                 mean prefill {:.2} -> {:.2} ms",
                r.prefill_dispatches,
                tr.prefill_dispatches,
                tr.prefill_dispatches as f64 / r.prefill_dispatches.max(1) as f64,
                tr.prefill_dispatches_per_prompt_token(),
                r.prefill_dispatches_per_prompt_token(),
                tr.mean_prefill_ms,
                r.mean_prefill_ms,
            );
            if r.prefill_dispatches * 4 > tr.prefill_dispatches {
                return Err(Error::Graph(format!(
                    "chunked-prefill dispatch gate failed at N={n}: {} dispatches \
                     > token-by-token {} / 4",
                    r.prefill_dispatches, tr.prefill_dispatches
                )));
            }
        }
        println!(
            "chunked-prefill dispatch gate: OK (chunked <= token-by-token/4 \
             at prompt {})",
            prompt.len()
        );
    }

    // Unified mixed-round delta + HARD gate: under continuous arrivals
    // (2N requests over N slots with staggered generation lengths, so
    // prompts keep entering mid-run while other sessions decode), a
    // unified round must encode at most HALF the dispatches of the split
    // prefill-then-decode scheduling (`--no-unified` twin) per round —
    // the point of merging the graphs: the split twin replays one prefill
    // chunk PER ingesting session PLUS a batched decode chunk per mixed
    // round, where unified packs them all into one [W*C, H] replay.
    // Short, staggered generation lengths keep the round mix prompt-heavy
    // (the regime the gate targets); token streams must stay identical.
    if exec == crate::engine::ExecMode::Planned
        && batch_width >= 2
        && prefill_chunk >= 2
        && unified
        && prompt.len() >= 2 * prefill_chunk
        && counts.iter().any(|&n| n >= 4)
        && fault_seed.is_none()
    {
        let max_seq = GraphDims::from_manifest(registry.config("qwen-tiny")?).max_seq;
        if prompt.len() + 6 <= max_seq {
            println!();
            for &n in counts.iter().filter(|&&n| n >= 4) {
                // Staggered gen lengths retire sessions at different
                // rounds, so backlog prompts arrive mid-run — the mixed
                // rounds the gate measures.
                let mixed: Vec<(Vec<usize>, usize)> =
                    (0..2 * n).map(|i| (prompt.clone(), 4 + i % 3)).collect();
                let run_mixed = |uni: bool| {
                    let mut cfg = ec.clone();
                    cfg.unified = uni;
                    run_twin(&registry, cfg, n, &mixed)
                };
                let (u_toks, ur) = run_mixed(true)?;
                let (s_toks, sr) = run_mixed(false)?;
                if u_toks != s_toks {
                    return Err(Error::Graph(format!(
                        "mixed-arrival unified token streams diverged from split \
                         scheduling at N={n}"
                    )));
                }
                println!(
                    "N={n} mixed arrivals: unified {:.1} vs split {:.1} \
                     dispatches/round ({:.1}x fewer; {} vs {} dispatches over \
                     {} vs {} rounds)",
                    ur.dispatches_per_round(),
                    sr.dispatches_per_round(),
                    sr.dispatches_per_round() / ur.dispatches_per_round().max(1e-9),
                    ur.dispatches,
                    sr.dispatches,
                    ur.rounds,
                    sr.rounds,
                );
                if ur.dispatches_per_round() * 2.0 > sr.dispatches_per_round() {
                    return Err(Error::Graph(format!(
                        "unified mixed-round dispatch gate failed at N={n}: {:.1} \
                         dispatches/round > split {:.1} / 2",
                        ur.dispatches_per_round(),
                        sr.dispatches_per_round()
                    )));
                }
            }
            println!(
                "unified mixed-round dispatch gate: OK (unified <= split/2 \
                 dispatches/round at every N >= 4 with mid-run prompts)"
            );
        }
    }

    // Speculative-decode delta + HARD gates: with --speculate on and the
    // unified path engaged, every row's token streams must be
    // BIT-IDENTICAL to a --no-speculate twin — speculation is a
    // scheduling change, never a sampling change. On top of that, on the
    // canonical repetitive workload (--prompt 32 with tokens >= 96, where
    // greedy decode settles into a short cycle the n-gram drafter
    // predicts) each row must emit at least 1.5x the tokens per round of
    // its twin; other workloads print the delta but only the identity
    // gate is hard (acceptance is workload-dependent by design).
    if exec == crate::engine::ExecMode::Planned
        && speculate >= 1
        && batch_width >= 2
        && prefill_chunk >= 2
        && unified
        && fault_seed.is_none()
    {
        println!();
        let gate_throughput = args.has("prompt") && prompt.len() == 32 && tokens >= 96;
        for ((n, sr), s_toks) in rows.iter().zip(&row_toks) {
            let mut twin_cfg = ec.clone();
            twin_cfg.speculate = 0;
            let (t_toks, tr) = run_twin(&registry, twin_cfg, *n, &uniform(*n))?;
            if *s_toks != t_toks {
                return Err(Error::Graph(format!(
                    "speculative token streams diverged from the \
                     --no-speculate twin at N={n}"
                )));
            }
            println!(
                "N={n}: speculative {:.2} vs plain {:.2} tokens/round \
                 ({:.2}x; acceptance {:.2}, {} drafted / {} accepted over \
                 {} vs {} rounds)",
                sr.tokens_per_round(),
                tr.tokens_per_round(),
                sr.tokens_per_round() / tr.tokens_per_round().max(1e-9),
                sr.acceptance_rate(),
                sr.drafted,
                sr.accepted,
                sr.rounds,
                tr.rounds,
            );
            if gate_throughput && sr.tokens_per_round() < 1.5 * tr.tokens_per_round() {
                return Err(Error::Graph(format!(
                    "speculative tokens/round gate failed at N={n}: {:.2} < \
                     1.5 * plain {:.2}",
                    sr.tokens_per_round(),
                    tr.tokens_per_round()
                )));
            }
        }
        println!(
            "speculative identity gate: OK (token streams bit-identical to \
             --no-speculate at every N){}",
            if gate_throughput {
                "; tokens/round gate: OK (>= 1.5x plain at every N)"
            } else {
                "; tokens/round gate: skipped (needs the repetitive \
                 workload: --prompt 32 with --tokens >= 96)"
            }
        );
    }

    // Paged-residency delta + HARD gates: with the paged layout engaged
    // (the planned serving default) every row's token streams must be
    // BYTE-IDENTICAL to a --no-paged contiguous twin at the same pool
    // cap — the block table is a pure layout indirection, never a
    // numerics or scheduling change — and no session may fail: under
    // memory pressure (--pool-cap-kv below the working set) paged
    // admission DEFERS AND PAGES, it never rejects, so a failed session
    // under oversubscription is a pager bug. The identity twin only runs
    // fault-free (fault rows already gate identity against their own
    // fault-free twin below, which inherits the paged layout).
    if paged_b > 0 {
        println!();
        if fault_seed.is_none() {
            for ((n, pr), p_toks) in rows.iter().zip(&row_toks) {
                let mut twin_cfg = ec.clone();
                twin_cfg.paged = false;
                let (c_toks, cr) = run_twin(&registry, twin_cfg, *n, &uniform(*n))?;
                if *p_toks != c_toks {
                    return Err(Error::Graph(format!(
                        "paged token streams diverged from the --no-paged twin \
                         at N={n}"
                    )));
                }
                println!(
                    "N={n}: paged {} sessions resident at peak vs contiguous {} \
                     (pool HW {} groups, {} page-ins / {} page-outs) — token \
                     streams identical to --no-paged",
                    pr.resident_sessions_hw,
                    cr.resident_sessions_hw,
                    pr.kv_pool_high_water_groups,
                    pr.kv_page_ins,
                    pr.kv_page_outs,
                );
            }
        }
        for (n, r) in &rows {
            if r.failed_sessions > 0 {
                return Err(Error::Graph(format!(
                    "paged admission gate failed at N={n}: {} session(s) failed \
                     — oversubscribed paged serving must defer and page, never \
                     fail",
                    r.failed_sessions
                )));
            }
        }
        println!(
            "paged admission gate: OK (zero failed sessions at every N{}){}",
            if pool_cap_bytes.is_some() { " under the KV pool cap" } else { "" },
            if fault_seed.is_none() {
                "; paged identity gate: OK (token streams byte-identical to \
                 --no-paged at every N)"
            } else {
                ""
            }
        );
    }

    // Fault-injection recovery delta + HARD gate: with --inject-faults
    // SEED every row above ran under a seeded deterministic transient
    // fault schedule (dispatch failures, allocation failures, map-read
    // timeouts injected at the device layer). Recovery is per-session
    // quarantine + snapshot-replay off the evict-to-host checkpoint; the
    // gate demands every row's token streams stay BYTE-IDENTICAL to a
    // fault-free twin — faults may cost time and retries, never tokens —
    // and that no session exhausts its retry budget under a schedule that
    // is transient by construction.
    if let Some(seed) = fault_seed {
        println!();
        for ((n, fr), f_toks) in rows.iter().zip(&row_toks) {
            let mut twin_cfg = ec.clone();
            twin_cfg.fault_seed = None;
            let (c_toks, _) = run_twin(&registry, twin_cfg, *n, &uniform(*n))?;
            if *f_toks != c_toks {
                return Err(Error::Graph(format!(
                    "fault-injected token streams diverged from the fault-free \
                     twin at N={n} (seed {seed})"
                )));
            }
            println!(
                "N={n}: {} faults injected (seed {seed}), {} retries, {} \
                 sessions recovered, {} pool evictions — token streams \
                 identical to the fault-free twin",
                fr.faults_injected, fr.retries, fr.recovered_sessions, fr.pool_evictions
            );
            if fr.failed_sessions > 0 {
                return Err(Error::Graph(format!(
                    "fault recovery gate failed at N={n}: {} session(s) \
                     exhausted the retry budget under a transient-only \
                     schedule (seed {seed})",
                    fr.failed_sessions
                )));
            }
        }
        println!(
            "fault recovery gate: OK (token streams byte-identical to the \
             fault-free twin at every N; zero failed sessions)"
        );
    }
    Ok(())
}

/// `wdb trace-summary FILE.json`: validate an exported Chrome trace and
/// print table T1 — the per-phase / per-op breakdown reconstructed from
/// spans alone — plus the tiling proof (sum of `round` spans must
/// reproduce the report's wall clock within 1%).
fn cmd_trace_summary(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.flag("file"))
        .ok_or_else(|| Error::Graph("usage: wdb trace-summary FILE.json".into()))?;
    let text = std::fs::read_to_string(path)?;
    let doc = crate::report::json::parse(&text)?;
    let stats = crate::trace::chrome::validate(&doc)?;
    println!(
        "trace shape: OK ({} events over {} tracks, {} slot lanes; {} span \
         pairs, {} complete, {} instants)",
        stats.events,
        stats.tracks,
        stats.slot_tracks,
        stats.span_pairs,
        stats.complete_events,
        stats.instant_events
    );
    let sum = crate::trace::summary::summarize(&doc)?;
    if sum.dropped_events > 0 {
        println!(
            "note: {} events were dropped at capture (ring overflow) — span \
             totals undercount",
            sum.dropped_events
        );
    }
    println!();
    println!("{}", sum.table().to_markdown());
    match sum.tiling_delta() {
        Some(delta) => {
            println!(
                "tiling check: round spans {:.3} ms vs report wall {:.3} ms \
                 (delta {:.3}%)",
                sum.round_span_ns / 1e6,
                sum.wall_virtual_ns.unwrap_or(0.0) / 1e6,
                delta * 100.0
            );
            if delta > 0.01 {
                return Err(Error::Graph(format!(
                    "tiling check failed: round spans reconstruct {:.3} ms but \
                     the report wall was {:.3} ms ({:.3}% > 1%)",
                    sum.round_span_ns / 1e6,
                    sum.wall_virtual_ns.unwrap_or(0.0) / 1e6,
                    delta * 100.0
                )));
            }
            println!("tiling check: OK (round spans tile the serving wall within 1%)");
        }
        None => println!(
            "tiling check: skipped (trace carries no otherData.wall_virtual_ns)"
        ),
    }
    Ok(())
}

/// One plan-bench cell: run a workload x fusion through one exec mode on
/// a fresh 1-session serving engine. Returns (token stream, report,
/// submits, plan build (virtual ns, real ns) when planned).
#[allow(clippy::type_complexity, clippy::too_many_arguments)]
fn plan_bench_run(
    registry: &Registry,
    dims: GraphDims,
    fusion: FusionConfig,
    exec: crate::engine::ExecMode,
    profile: &ImplementationProfile,
    dps: usize,
    prompt: &[usize],
    tokens: usize,
    seed: u64,
) -> Result<(Vec<usize>, crate::serve::ServeReport, u64, Option<(u64, u64)>)> {
    use crate::serve::{ServeConfig, ServingEngine};
    let cfg = EngineConfig {
        fusion,
        profile: profile.clone(),
        exec,
        dispatches_per_submit: dps,
        dims_override: Some(dims),
        ..EngineConfig::tiny_fused()
    };
    let mut se = ServingEngine::new(registry, ServeConfig { engine: cfg, max_concurrent: 1 })?;
    se.reseed(seed);
    se.submit(prompt, tokens)?;
    let report = se.run_to_completion()?;
    let submits = se.executor.device.stats.submits;
    let build = se
        .executor
        .plan_runner()
        .map(|r| (r.build_virtual_ns, r.build_real_ns));
    let mut done = se.drain_finished();
    let toks = done.remove(0).tokens;
    Ok((toks, report, submits, build))
}

fn cmd_plan_bench(args: &Args) -> Result<()> {
    use crate::engine::overhead::PlannedOverheadDelta;
    use crate::engine::ExecMode;
    use crate::fx::workloads::decode_workloads;
    use crate::fx::PassManager;
    use crate::tables::plan::{plan_table, PlanBenchRow};

    const SEED: u64 = 0x91A4;
    let registry = Registry::open()?;
    let tokens = args.flag_usize("tokens", 8).max(1);
    let dps = args.flag_usize("dps", 16).max(1);
    let profile = profile_by_name(args.flag("profile").unwrap_or("dawn"))?;
    let tok = ByteTokenizer::new(registry.config("qwen-tiny")?.vocab);
    let prompt = tok.paper_prompt();

    println!(
        "Plan bench: eager vs planned execution ({} tokens, {} dispatches/submit, \
         profile {})\n",
        tokens, dps, profile.name
    );

    // The pass-manager pipeline that feeds the planner, shown once.
    let g = crate::fx::build_decode_graph(&GraphDims::qwen_tiny(), FusionConfig::unfused());
    let (_, reports) = PassManager::for_fusion(FusionConfig::fused(), "tiny").run(&g)?;
    println!("fusion pass pipeline (qwen-tiny, feeds the planner):");
    for r in &reports {
        println!(
            "  {:<14} {:>4} -> {:<4} dispatches (-{})",
            r.name,
            r.dispatches_before,
            r.dispatches_after,
            r.saved()
        );
    }
    println!();

    let mut rows = Vec::new();
    for wl in decode_workloads() {
        for (fname, fusion) in
            [("unfused", FusionConfig::unfused()), ("fused", FusionConfig::fused())]
        {
            let (e_toks, e_rep, e_submits, _) = plan_bench_run(
                &registry, wl.dims, fusion, ExecMode::Eager, &profile, dps, &prompt,
                tokens, SEED,
            )?;
            let (p_toks, p_rep, p_submits, build) = plan_bench_run(
                &registry, wl.dims, fusion, ExecMode::Planned, &profile, dps, &prompt,
                tokens, SEED,
            )?;
            let (build_v, build_r) = build.unwrap_or((0, 0));
            let steps = e_rep.steps.max(1) as f64;
            // One implementation of the per-op framework math for the
            // table, the summary, and the unit-tested helper.
            let delta = PlannedOverheadDelta::derive(
                e_rep.framework_virtual_ns,
                e_rep.dispatches,
                p_rep.framework_virtual_ns,
                p_rep.dispatches,
            );
            rows.push(PlanBenchRow {
                workload: wl.name.to_string(),
                fusion: fname,
                dispatches_per_step: e_rep.dispatches_per_step,
                eager_fw_us_per_op: delta.eager_fw_us_per_op,
                planned_fw_us_per_op: delta.planned_fw_us_per_op,
                eager_submits_per_step: e_submits as f64 / steps,
                planned_submits_per_step: p_submits as f64 / p_rep.steps.max(1) as f64,
                plan_build_virtual_ms: build_v as f64 / 1e6,
                plan_build_real_ms: build_r as f64 / 1e6,
                planned_replay_us_per_step: p_rep.encode_virtual_ns as f64
                    / 1e3
                    / p_rep.steps.max(1) as f64,
                eager_upload_bytes_per_step: e_rep.upload_bytes_per_step(),
                planned_upload_bytes_per_step: p_rep.upload_bytes_per_step(),
                resident_kib: p_rep.resident_bytes as f64 / 1024.0,
                kv_block: p_rep.kv_block,
                kv_blocks_resident_hw: p_rep.kv_pool_high_water_groups,
                kv_blocks_spilled_hw: p_rep.kv_blocks_spilled_hw,
                kv_bytes_per_tok: p_rep.kv_bytes_per_token(),
                eager_tok_per_s: e_rep.agg_tok_per_s,
                planned_tok_per_s: p_rep.agg_tok_per_s,
                tokens_match: e_toks == p_toks,
            });
        }
    }

    // Batched vs interleaved framework-overhead delta at N=4 sessions:
    // both runs are PLANNED; the delta is per-round dispatch count and
    // per-token framework cost, the Appendix F amortization.
    let run_n4 = |bw: usize| -> Result<(Vec<Vec<usize>>, crate::serve::ServeReport)> {
        use crate::serve::{ServeConfig, ServingEngine};
        let cfg = EngineConfig {
            profile: profile.clone(),
            exec: ExecMode::Planned,
            dispatches_per_submit: dps,
            batch_width: bw,
            ..EngineConfig::tiny_fused()
        };
        let mut se =
            ServingEngine::new(&registry, ServeConfig { engine: cfg, max_concurrent: 4 })?;
        se.reseed(SEED);
        for _ in 0..4 {
            se.submit(&prompt, tokens)?;
        }
        let report = se.run_to_completion()?;
        let toks = se.drain_finished().into_iter().map(|s| s.tokens).collect();
        Ok((toks, report))
    };
    let (i_toks, i_rep) = run_n4(0)?;
    let (b_toks, b_rep) = run_n4(crate::engine::DEFAULT_BATCH_WIDTH)?;
    let batched_match = i_toks == b_toks;

    let mut table = plan_table(&rows);
    table.section("batched vs interleaved (planned serving, N=4 sessions)");
    table.row(vec![
        "qwen-tiny N=4".into(),
        "batched".into(),
        format!("{:.0}->{:.0}/rnd", i_rep.dispatches_per_round(), b_rep.dispatches_per_round()),
        format!("{:.2}", i_rep.us_per_token(i_rep.framework_virtual_ns)),
        format!("{:.2}", b_rep.us_per_token(b_rep.framework_virtual_ns)),
        format!(
            "{:.1}x",
            i_rep.us_per_token(i_rep.framework_virtual_ns)
                / b_rep.us_per_token(b_rep.framework_virtual_ns).max(1e-9)
        ),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", i_rep.agg_tok_per_s),
        format!("{:.1}", b_rep.agg_tok_per_s),
        format!("{:.2}x", b_rep.agg_tok_per_s / i_rep.agg_tok_per_s.max(1e-9)),
        if batched_match { "identical".into() } else { "DIVERGED".into() },
    ]);
    table.note(
        "batched-vs-interleaved row: both runs are planned at N=4 concurrent \
         sessions; the 'eager' columns hold the interleaved run and the \
         'planned' columns the batched run. Its framework cells are us per \
         TOKEN (per-op cost is flat — issuing ~1/4 the dispatches per round \
         is the win) and disp/step shows dispatches per ROUND.",
    );
    println!("{}", table.to_markdown());

    // Persist the trend artifacts BEFORE the acceptance gates: a failing
    // run is exactly when CI needs the JSON to diagnose the regression.
    if let Some(out) = args.flag("out") {
        let dir = std::path::PathBuf::from(out);
        let path = write_results(&dir, "plan_bench_P1", &table.to_json())?;
        eprintln!("wrote {}", path.display());
    }

    for r in &rows {
        if !r.tokens_match {
            return Err(Error::Graph(format!(
                "{} ({}): planned token stream diverged from eager",
                r.workload, r.fusion
            )));
        }
    }
    if !batched_match {
        return Err(Error::Graph(
            "N=4 batched serving token streams diverged from interleaved planned".into(),
        ));
    }
    // Acceptance summary on the reference (fused qwen-tiny) row.
    if let Some(r) = rows.iter().find(|r| r.workload == "qwen-tiny" && r.fusion == "fused") {
        let d = r.overhead_delta();
        println!(
            "reference profile ({}): planned framework overhead {:.2} us/op vs eager \
             {:.1} us/op — {:.1}x lower (acceptance bar: >= 2x)",
            profile.name,
            d.planned_fw_us_per_op,
            d.eager_fw_us_per_op,
            d.ratio()
        );
        println!(
            "resident KV caches: per-step host upload {:.0} B -> {:.0} B — {:.0}x \
             smaller (acceptance bar: >= 10x), {:.0} KiB resident per session",
            r.eager_upload_bytes_per_step,
            r.planned_upload_bytes_per_step,
            r.upload_shrink(),
            r.resident_kib
        );
        if r.upload_shrink() < 10.0 {
            return Err(Error::Graph(format!(
                "upload-bytes shrink {:.1}x below the 10x acceptance bar",
                r.upload_shrink()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn session_counts_parse() {
        assert_eq!(parse_session_counts("1,2,4,8").unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(parse_session_counts("3").unwrap(), vec![3]);
        assert!(parse_session_counts("0").is_err());
        assert!(parse_session_counts("a,b").is_err());
        assert!(parse_session_counts("").is_err());
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse_args(&argv(&["table", "6", "--out", "res", "--verbose"]));
        assert_eq!(a.cmd, "table");
        assert_eq!(a.positional, vec!["6"]);
        assert_eq!(a.flag("out"), Some("res"));
        assert!(a.has("verbose"));
        assert_eq!(a.flag_usize("n", 7), 7);
    }

    #[test]
    fn profile_names_resolve() {
        for name in ["dawn", "wgpu", "wgpu-amd", "wgpu-metal", "chrome", "safari",
                     "firefox", "cuda", "zero"] {
            assert!(profile_by_name(name).is_ok(), "{name}");
        }
        assert!(profile_by_name("opera").is_err());
    }

    #[test]
    fn exec_mode_names_resolve_and_serving_defaults_planned() {
        use crate::engine::ExecMode;
        assert_eq!(exec_mode_by_name("eager").unwrap(), ExecMode::Eager);
        assert_eq!(exec_mode_by_name("planned").unwrap(), ExecMode::Planned);
        assert!(exec_mode_by_name("jit").is_err());
        assert_eq!(ExecMode::serving_default(), ExecMode::Planned);
    }

    #[test]
    fn batch_width_flags_resolve() {
        let a = parse_args(&argv(&["serve"]));
        assert_eq!(
            batch_width_from_flags(&a).unwrap(),
            crate::engine::DEFAULT_BATCH_WIDTH
        );
        let a = parse_args(&argv(&["serve", "--batch-width", "6"]));
        assert_eq!(batch_width_from_flags(&a).unwrap(), 6);
        let a = parse_args(&argv(&["serve", "--no-batch"]));
        assert_eq!(batch_width_from_flags(&a).unwrap(), 0);
        let a = parse_args(&argv(&["serve", "--no-batch", "--batch-width", "2"]));
        assert!(batch_width_from_flags(&a).is_err());
        let a = parse_args(&argv(&["serve", "--batch-width", "wide"]));
        assert!(batch_width_from_flags(&a).is_err());
    }

    #[test]
    fn prefill_chunk_and_prompt_flags_resolve() {
        let a = parse_args(&argv(&["serve"]));
        assert_eq!(
            prefill_chunk_from_flags(&a).unwrap(),
            crate::engine::DEFAULT_PREFILL_CHUNK
        );
        let a = parse_args(&argv(&["serve", "--prefill-chunk", "8"]));
        assert_eq!(prefill_chunk_from_flags(&a).unwrap(), 8);
        let a = parse_args(&argv(&["serve", "--prefill-chunk", "0"]));
        assert_eq!(prefill_chunk_from_flags(&a).unwrap(), 0);
        let a = parse_args(&argv(&["serve", "--prefill-chunk", "wide"]));
        assert!(prefill_chunk_from_flags(&a).is_err());

        let tok = ByteTokenizer::new(512);
        let a = parse_args(&argv(&["serve-bench"]));
        assert_eq!(prompt_from_flags(&a, &tok).unwrap().len(), 5);
        let a = parse_args(&argv(&["serve-bench", "--prompt", "128"]));
        let p = prompt_from_flags(&a, &tok).unwrap();
        assert_eq!(p.len(), 128);
        assert!(p.iter().all(|&t| t < 512));
        let a = parse_args(&argv(&["serve-bench", "--prompt", "0"]));
        assert!(prompt_from_flags(&a, &tok).is_err());
    }

    #[test]
    fn speculate_flags_resolve() {
        let a = parse_args(&argv(&["serve"]));
        assert_eq!(speculate_from_flags(&a).unwrap(), 0);
        let a = parse_args(&argv(&["serve", "--speculate", "4"]));
        assert_eq!(speculate_from_flags(&a).unwrap(), 4);
        let a = parse_args(&argv(&["serve-bench", "--no-speculate"]));
        assert_eq!(speculate_from_flags(&a).unwrap(), 0);
        let a = parse_args(&argv(&["serve", "--no-speculate", "--speculate", "2"]));
        assert!(speculate_from_flags(&a).is_err());
        let a = parse_args(&argv(&["serve", "--speculate", "many"]));
        assert!(speculate_from_flags(&a).is_err());
    }

    #[test]
    fn paged_flags_resolve() {
        let a = parse_args(&argv(&["serve"]));
        assert_eq!(
            paged_from_flags(&a).unwrap(),
            (true, crate::engine::DEFAULT_KV_BLOCK)
        );
        let a = parse_args(&argv(&["serve", "--kv-block", "8"]));
        assert_eq!(paged_from_flags(&a).unwrap(), (true, 8));
        let a = parse_args(&argv(&["serve", "--no-paged"]));
        assert_eq!(paged_from_flags(&a).unwrap(), (false, 0));
        let a = parse_args(&argv(&["serve", "--no-paged", "--kv-block", "8"]));
        assert!(paged_from_flags(&a).is_err());
        let a = parse_args(&argv(&["serve", "--kv-block", "wide"]));
        assert!(paged_from_flags(&a).is_err());
    }

    #[test]
    fn pool_cap_flag_resolves() {
        let dims = GraphDims::qwen_tiny();
        let a = parse_args(&argv(&["serve"]));
        assert_eq!(pool_cap_from_flags(&a, &dims).unwrap(), None);
        let a = parse_args(&argv(&["serve", "--pool-cap-kv", "4"]));
        assert_eq!(
            pool_cap_from_flags(&a, &dims).unwrap(),
            Some(4 * kv_set_bytes(&dims))
        );
        // qwen-tiny contiguous set: 2 planes x 4 layers x 160 rows x
        // 2 kv heads x 16 head dim x 4 B = 160 KiB.
        assert_eq!(kv_set_bytes(&dims), 163_840);
        let a = parse_args(&argv(&["serve", "--pool-cap-kv", "0"]));
        assert!(pool_cap_from_flags(&a, &dims).is_err());
        let a = parse_args(&argv(&["serve", "--pool-cap-kv", "tiny"]));
        assert!(pool_cap_from_flags(&a, &dims).is_err());
    }

    #[test]
    fn fault_seed_flags_resolve() {
        let a = parse_args(&argv(&["serve-bench"]));
        assert_eq!(fault_seed_from_flags(&a).unwrap(), None);
        let a = parse_args(&argv(&["serve-bench", "--inject-faults", "7"]));
        assert_eq!(fault_seed_from_flags(&a).unwrap(), Some(7));
        let a = parse_args(&argv(&["serve-bench", "--inject-faults", "nope"]));
        assert!(fault_seed_from_flags(&a).is_err());
        // Bare flag (no seed) parses as the literal "true" -> rejected.
        let a = parse_args(&argv(&["serve-bench", "--inject-faults"]));
        assert!(fault_seed_from_flags(&a).is_err());
    }

    #[test]
    fn fusion_names_resolve() {
        assert!(fusion_by_name("fused").is_ok());
        assert!(fusion_by_name("unfused").is_ok());
        assert!(fusion_by_name("rmsnorm+mlp").is_ok());
        assert!(fusion_by_name("everything").is_err());
    }
}

//! Dispatch-bound crossover analysis (Appendix F, Table 14).
//!
//! For a linear layer [B, d_in] x [d_in, d_out]:
//!
//! ```text
//! T_compute(B) = 2 B d_in d_out / throughput
//! B*           = T_overhead * throughput / (2 d_in d_out)
//! ```
//!
//! Below B* the operation is overhead-bound; above, compute-bound. This is
//! the roofline-style model showing batch=1 LLM decode is deeply
//! overhead-bound (B* >= 7 even for the largest matmuls).

/// Model parameters (paper values: 95 us per-op overhead, 2 TFLOP/s WGSL).
#[derive(Debug, Clone, Copy)]
pub struct CrossoverModel {
    pub overhead_us: f64,
    pub throughput_tflops: f64,
}

impl CrossoverModel {
    pub fn paper() -> Self {
        CrossoverModel { overhead_us: 95.0, throughput_tflops: 2.0 }
    }

    /// Compute time of [B, d_in] x [d_in, d_out] in microseconds.
    pub fn compute_time_us(&self, batch: usize, d_in: usize, d_out: usize) -> f64 {
        2.0 * batch as f64 * d_in as f64 * d_out as f64
            / (self.throughput_tflops * 1e12)
            * 1e6
    }

    /// Crossover batch size B* (ceiling, min 1).
    pub fn crossover_batch(&self, d_in: usize, d_out: usize) -> usize {
        let b = self.overhead_us * 1e-6 * self.throughput_tflops * 1e12
            / (2.0 * d_in as f64 * d_out as f64);
        b.ceil().max(1.0) as usize
    }

    pub fn regime_at(&self, batch: usize, d_in: usize, d_out: usize) -> Regime {
        if batch < self.crossover_batch(d_in, d_out) {
            Regime::OverheadBound
        } else {
            Regime::ComputeBound
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    OverheadBound,
    ComputeBound,
}

impl std::fmt::Display for Regime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Regime::OverheadBound => write!(f, "Overhead-bound"),
            Regime::ComputeBound => write!(f, "Compute-bound"),
        }
    }
}

/// One Table 14 row.
#[derive(Debug, Clone)]
pub struct CrossoverRow {
    pub operation: String,
    pub d_in: usize,
    pub d_out: usize,
    pub b_star: usize,
    pub regime_b1: Regime,
}

/// Table 14's operations for both model sizes.
pub fn table14_rows(model: &CrossoverModel) -> Vec<(String, Vec<CrossoverRow>)> {
    let specs: [(&str, &[(&str, usize, usize)]); 2] = [
        (
            "Qwen2.5-0.5B (896 hidden, 4864 intermediate)",
            &[
                ("Attention Q/K/V proj", 896, 896),
                ("MLP up projection", 896, 4864),
                ("MLP down projection", 4864, 896),
            ],
        ),
        (
            "Qwen2.5-1.5B (1536 hidden, 8960 intermediate)",
            &[
                ("Attention Q/K/V proj", 1536, 1536),
                ("MLP up projection", 1536, 8960),
                ("MLP down projection", 8960, 1536),
            ],
        ),
    ];
    specs
        .iter()
        .map(|(group, ops)| {
            let rows = ops
                .iter()
                .map(|(name, din, dout)| CrossoverRow {
                    operation: name.to_string(),
                    d_in: *din,
                    d_out: *dout,
                    b_star: model.crossover_batch(*din, *dout),
                    regime_b1: model.regime_at(1, *din, *dout),
                })
                .collect();
            (group.to_string(), rows)
        })
        .collect()
}

/// Appendix G sensitivity: vary overhead by +/- pct and report the B* range
/// for one operation.
pub fn b_star_sensitivity(
    model: &CrossoverModel,
    d_in: usize,
    d_out: usize,
    pct: f64,
) -> (usize, usize) {
    let lo = CrossoverModel { overhead_us: model.overhead_us * (1.0 - pct), ..*model };
    let hi = CrossoverModel { overhead_us: model.overhead_us * (1.0 + pct), ..*model };
    (lo.crossover_batch(d_in, d_out), hi.crossover_batch(d_in, d_out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table14_b_stars_match_paper() {
        let m = CrossoverModel::paper();
        // Paper: 119 / 22 / 22 for 0.5B; 40 / 7 / 7 for 1.5B.
        assert_eq!(m.crossover_batch(896, 896), 119);
        assert_eq!(m.crossover_batch(896, 4864), 22);
        assert_eq!(m.crossover_batch(4864, 896), 22);
        assert_eq!(m.crossover_batch(1536, 1536), 41); // paper rounds to 40
        assert_eq!(m.crossover_batch(1536, 8960), 7);
        assert_eq!(m.crossover_batch(8960, 1536), 7);
    }

    #[test]
    fn batch1_is_always_overhead_bound() {
        let m = CrossoverModel::paper();
        for (_, rows) in table14_rows(&m) {
            for r in rows {
                assert_eq!(r.regime_b1, Regime::OverheadBound, "{}", r.operation);
                assert!(r.b_star >= 7);
            }
        }
    }

    #[test]
    fn compute_time_scales_linearly_with_batch() {
        let m = CrossoverModel::paper();
        let t1 = m.compute_time_us(1, 896, 4864);
        let t8 = m.compute_time_us(8, 896, 4864);
        assert!((t8 / t1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn crossover_balances_overhead_and_compute() {
        let m = CrossoverModel::paper();
        let b = m.crossover_batch(896, 4864);
        let t = m.compute_time_us(b, 896, 4864);
        // At B*, compute time ~= overhead (within one batch quantum).
        assert!(t >= m.overhead_us && t <= m.overhead_us * 1.1, "t {t}");
    }

    #[test]
    fn sensitivity_moves_b_star_proportionally() {
        let m = CrossoverModel::paper();
        let (lo, hi) = b_star_sensitivity(&m, 896, 896, 0.2);
        assert!(lo < 119 && hi > 119);
        assert!((lo as f64 - 119.0 * 0.8).abs() <= 1.0);
    }
}

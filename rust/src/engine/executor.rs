//! Graph executor: eager per-node execution plus planned replay.
//!
//! **Eager mode** (default) is the torch-webgpu analogue the paper
//! characterizes: it walks the FX graph per token, paying (1) the per-op
//! framework cost (~59-71 us of interpreter/metadata work — a virtual-
//! clock constant), (2) the full 8-phase dispatch sequence per kernel
//! node (one encoder + submit each), and (3) kernel execution, with every
//! intermediate activation round-tripped through a host tensor.
//!
//! **Planned mode** delegates to a [`PlanRunner`]: the graph is compiled
//! once by the [`Planner`] into an [`ExecutionPlan`] (pre-resolved
//! bindings, device-resident values, lifetime-aliased arena, encoder
//! batching) and the per-token hot loop is an allocation-free replay.
//! `wdb plan-bench` measures the framework-overhead delta between the two
//! modes (table P1).
//!
//! Everything a `GraphExecutor` owns is **session-independent** and shared
//! by the multi-session serving engine (`crate::serve`): the device, the
//! prepared-pipeline pool, the bounded size-class buffer pool, the
//! bind-group cache, the pinned weight buffers, and (in planned mode) the
//! plan runner's arena. Per-session decode state lives in
//! `crate::serve::SessionState`.

use std::collections::HashMap;
use std::time::Instant;

use crate::fx::graph::FxGraph;
use crate::fx::node::{HostOp, OpKind, ValueId};
use crate::plan::{
    validate_paged_persistent, BatchedRunner, BlockArena, CacheArena, DeviceKvCache,
    ExecutionPlan, PipelinePool, PlanConfig, PlanRunner, Planner, PrefillRunner,
    ReplayDelta, UnifiedRunner,
};
use crate::runtime::hostops;
use crate::runtime::registry::Registry;
use crate::tensor::Tensor;
use crate::webgpu::queue::bind_buffers;
use crate::webgpu::{
    BufferDesc, BufferId, BufferPool, BufferUsage, Device, KernelIoSpec,
};
use crate::{Error, Result};

/// Eager bind-group cache: layout id -> bound-buffer key -> group. The
/// nested map lets the hot path probe with a borrowed scratch slice.
type BindGroupCache = HashMap<u64, HashMap<Vec<BufferId>, crate::webgpu::BindGroupId>>;

/// Shared paged-KV pool state: the pool planes every paged plan binds as
/// its persistent set, plus the physical block-group allocator the
/// serving pager drives. Created once by
/// [`GraphExecutor::enable_paged_pool`], OUTSIDE the size-class
/// [`BufferPool`] — the planes are permanent device residents like pinned
/// weights; the *logical* residency budget lives in the [`BlockArena`],
/// not the pool byte cap.
pub struct PagedPool {
    /// Pool planes as a registered cache set (layer-major
    /// `pool.l{l}.k_cache`, `pool.l{l}.v_cache`).
    pub set: DeviceKvCache,
    /// Physical block-group ids + pager counters.
    pub arena: BlockArena,
    /// Tokens per KV block (the `kv_block` uniform's value).
    pub kv_block: usize,
    /// Bytes of one group's slice of ONE plane
    /// (`kv_block * kv_heads * head_dim * 4`).
    pub plane_slice_bytes: usize,
}

pub struct GraphExecutor<'r> {
    pub device: Device,
    registry: &'r Registry,
    /// Shared prepared-pipeline + layout pool (compiles once per kernel
    /// name, off the request path — Dawn-style pipeline caching).
    pipelines: PipelinePool,
    /// Bounded size-class pool for eager-mode activation buffers (the
    /// paper's buffer-pooling experiment). Shared across sessions.
    pub pool: BufferPool,
    /// PERF (§Perf L3): weights pinned into persistent device buffers at
    /// prepare time — uploaded once, bound directly per dispatch. One copy
    /// serves every session and both execution modes.
    pinned: HashMap<ValueId, BufferId>,
    /// The same pinned weight buffers keyed by graph-input NAME, so other
    /// graphs over the same weights (the batched decode variant) can bind
    /// the one uploaded copy instead of duplicating it. ValueIds are
    /// graph-local; names are the cross-graph identity.
    pinned_by_name: HashMap<String, BufferId>,
    /// PERF: eager bind-group cache (the paper's "bind group caching"
    /// experiment), probed with a reusable scratch key instead of building
    /// a fresh `Vec` per dispatch.
    bind_cache: BindGroupCache,
    /// Reusable hot-path scratch (no per-dispatch allocations).
    key_scratch: Vec<BufferId>,
    in_scratch: Vec<BufferId>,
    out_scratch: Vec<BufferId>,
    borrowed_scratch: Vec<(usize, BufferId)>,
    /// Planned-mode state: present after [`GraphExecutor::enable_plan`].
    planned: Option<PlanRunner>,
    /// Batched-round state: present after
    /// [`GraphExecutor::enable_batched_plan`]. Coexists with `planned` —
    /// the serving engine uses the single-session plan for 1-active-session
    /// rounds and the batched plan above that.
    batched: Option<BatchedRunner>,
    /// Chunked-prefill state: present after
    /// [`GraphExecutor::enable_prefill_plan`]. Shares the session's
    /// `DeviceKvCache` with the single-session decode plan (identical
    /// persistent layout, checked at enable time); the serving engine
    /// replays it once per prompt chunk per session.
    prefill: Option<PrefillRunner>,
    /// Unified-round state: present after
    /// [`GraphExecutor::enable_unified_plan`]. Binds the SAME slot-major
    /// cache-set table as the batched plan (identical persistent layout,
    /// checked at enable time); the serving engine replays it once per
    /// MIXED prefill/decode round — one dispatch per layer op covers
    /// prompts and generations together.
    unified: Option<UnifiedRunner>,
    /// Session KV-cache allocator (planned mode with persistent values):
    /// allocates each session's device-resident cache set from `pool`.
    kv_arena: Option<CacheArena>,
    /// Paged-KV state: present after [`GraphExecutor::enable_paged_pool`].
    /// When set, the paged plan variants bind these shared pool planes and
    /// sessions hold block tables instead of contiguous cache sets.
    paged: Option<PagedPool>,
    /// Per-op framework overhead (virtual ns) charged in eager mode — the
    /// "Python/framework" component of the paper's ~95 us per-op cost.
    pub framework_ns_per_op: u64,
    /// Dispatches issued since construction (both modes).
    pub dispatch_count: u64,
    /// Accumulated framework-overhead virtual ns (both modes; serving
    /// attribution diffs this around each session's encode).
    pub framework_virtual_ns: u64,
}

impl<'r> GraphExecutor<'r> {
    pub fn new(device: Device, registry: &'r Registry, framework_ns_per_op: u64) -> Self {
        GraphExecutor {
            device,
            registry,
            pipelines: PipelinePool::new(),
            pool: BufferPool::new(None),
            pinned: HashMap::new(),
            pinned_by_name: HashMap::new(),
            bind_cache: HashMap::new(),
            key_scratch: Vec::new(),
            in_scratch: Vec::new(),
            out_scratch: Vec::new(),
            borrowed_scratch: Vec::new(),
            planned: None,
            batched: None,
            prefill: None,
            unified: None,
            kv_arena: None,
            paged: None,
            framework_ns_per_op,
            dispatch_count: 0,
            framework_virtual_ns: 0,
        }
    }

    /// Upload weight tensors into persistent device buffers, once. Inputs
    /// named in `weights` are bound directly at dispatch time instead of
    /// being re-uploaded per use.
    pub fn pin_inputs(
        &mut self,
        graph: &FxGraph,
        weights: &HashMap<String, Tensor>,
    ) -> Result<usize> {
        let mut pinned = 0;
        for (name, &vid) in &graph.inputs {
            let Some(t) = weights.get(name) else { continue };
            let buf = self.device.create_buffer(BufferDesc {
                label: format!("weight-{name}"),
                size: t.size_bytes(),
                usage: BufferUsage::STORAGE | BufferUsage::COPY_DST,
            })?;
            self.device.write_buffer(buf, 0, t.data.as_bytes())?;
            self.pinned.insert(vid, buf);
            self.pinned_by_name.insert(name.clone(), buf);
            pinned += 1;
        }
        Ok(pinned)
    }

    /// Derive a ValueId -> pinned-buffer map for ANY graph over the same
    /// weight names (graphs have their own ValueId spaces; the uploaded
    /// buffers are shared by name).
    fn pinned_for(&self, graph: &FxGraph) -> HashMap<ValueId, BufferId> {
        let mut map = HashMap::with_capacity(self.pinned_by_name.len());
        for (name, &vid) in &graph.inputs {
            if let Some(&buf) = self.pinned_by_name.get(name) {
                map.insert(vid, buf);
            }
        }
        map
    }

    /// Create pipelines for every kernel a graph uses (off the request
    /// path; shared across all sessions and both execution modes).
    pub fn prepare(&mut self, graph: &FxGraph) -> Result<()> {
        self.pipelines.prepare(&mut self.device, self.registry, graph)
    }

    /// Compile `graph` into an [`ExecutionPlan`] and materialize its
    /// runner: subsequent `run` calls replay the plan instead of
    /// interpreting the graph. Build cost (compile + arena + bind groups)
    /// is tracked on the runner, separate from replay cost.
    pub fn enable_plan(&mut self, graph: &FxGraph, cfg: PlanConfig) -> Result<()> {
        let t0 = Instant::now();
        let v0 = self.device.clock.now_ns();
        let plan = {
            let GraphExecutor { device, registry, pipelines, pinned, .. } = &mut *self;
            Planner::new(*registry).compile(device, pipelines, graph, pinned, &cfg)?
        };
        let mut runner = PlanRunner::materialize(&mut self.device, plan)?;
        runner.build_virtual_ns = self.device.clock.now_ns() - v0;
        runner.build_real_ns = t0.elapsed().as_nanos() as u64;
        self.kv_arena = Some(CacheArena::new(runner.plan.persistent.clone()));
        self.planned = Some(runner);
        Ok(())
    }

    /// Create the shared paged-KV pool planes and block allocator from the
    /// decode plan's persistent specs (`pool.l{l}.{k,v}_cache`, shape
    /// `[POOL_ROWS, kv_heads, head_dim]`). The planes are raw device
    /// buffers outside the size-class pool; physical capacity is fixed at
    /// `POOL_ROWS / kv_block` groups and `budget_groups` is the logical
    /// residency budget the serving pager enforces. Registers the planes
    /// with the decode plan runner and installs them as its default cache
    /// set, so paged decode replays pass `kv: None`. Requires
    /// `enable_plan` with a PAGED decode graph first; the paged batched /
    /// prefill / unified enables then bind the same planes automatically.
    pub fn enable_paged_pool(&mut self, kv_block: usize, budget_groups: usize) -> Result<()> {
        let planned = self.planned.as_ref().ok_or_else(|| {
            Error::Graph("enable_paged_pool requires the paged decode plan first".into())
        })?;
        validate_paged_persistent(&planned.plan)?;
        let specs = planned.plan.persistent.clone();
        let rows = specs[0].shape.first().copied().unwrap_or(0);
        if rows == 0 || kv_block == 0 || rows % kv_block != 0 {
            return Err(Error::Graph(format!(
                "paged pool: {rows} pool rows not divisible by kv_block {kv_block}"
            )));
        }
        for spec in &specs {
            if spec.shape != specs[0].shape || spec.size != specs[0].size {
                return Err(Error::Graph(format!(
                    "paged pool: plane '{}' layout differs from '{}'",
                    spec.name, specs[0].name
                )));
            }
        }
        let usage = BufferUsage::STORAGE
            | BufferUsage::COPY_DST
            | BufferUsage::COPY_SRC
            | BufferUsage::MAP_READ;
        let mut buffers = Vec::with_capacity(specs.len());
        let mut total = 0usize;
        for spec in &specs {
            buffers.push(self.device.create_buffer(BufferDesc {
                label: format!("paged-{}", spec.name),
                size: spec.size,
                usage,
            })?);
            total += spec.size;
        }
        let set = DeviceKvCache { buffers, resident_bytes: total };
        {
            let GraphExecutor { device, planned, .. } = self;
            let runner = planned.as_mut().ok_or_else(|| {
                Error::Graph("enable_paged_pool requires the paged decode plan first".into())
            })?;
            runner.register_cache(device, &set)?;
            runner.set_default_cache(set.clone())?;
        }
        let plane_slice_bytes = specs[0].size / rows * kv_block;
        let capacity = rows / kv_block;
        let group_bytes = plane_slice_bytes * specs.len();
        self.paged = Some(PagedPool {
            set,
            arena: BlockArena::new(capacity, budget_groups, group_bytes),
            kv_block,
            plane_slice_bytes,
        });
        Ok(())
    }

    pub fn paged_pool(&self) -> Option<&PagedPool> {
        self.paged.as_ref()
    }

    pub fn paged_pool_mut(&mut self) -> Option<&mut PagedPool> {
        self.paged.as_mut()
    }

    pub fn paged_enabled(&self) -> bool {
        self.paged.is_some()
    }

    /// Read whole block-groups off the pool planes: for each group, its
    /// slice of EVERY plane in persistent order, concatenated plane-major
    /// — the host layout `PagedSlot::Host` parks. ONE `map_read_ranges`
    /// sync covers all groups, so a pager round or full-session evict pays
    /// one synchronization point however many blocks it spills.
    pub fn read_paged_groups(&mut self, groups: &[u32]) -> Result<Vec<Vec<u8>>> {
        let GraphExecutor { device, paged, .. } = self;
        let pool = paged
            .as_ref()
            .ok_or_else(|| Error::Graph("paged pool not enabled".into()))?;
        let sl = pool.plane_slice_bytes;
        let planes = pool.set.buffers.len();
        let mut ranges = Vec::with_capacity(groups.len() * planes);
        for &g in groups {
            let off = g as usize * sl;
            for &buf in &pool.set.buffers {
                ranges.push((buf, off, sl));
            }
        }
        let chunks = device.map_read_ranges(&ranges)?;
        let mut out = Vec::with_capacity(groups.len());
        for gi in 0..groups.len() {
            let mut bytes = Vec::with_capacity(planes * sl);
            for p in 0..planes {
                bytes.extend_from_slice(&chunks[gi * planes + p]);
            }
            out.push(bytes);
        }
        Ok(out)
    }

    /// Upload one block-group's plane-major host bytes back into the pool
    /// planes — the restore half of a page-out (and of hydrate-from-host).
    pub fn write_paged_group(&mut self, group: u32, bytes: &[u8]) -> Result<()> {
        let GraphExecutor { device, paged, .. } = self;
        let pool = paged
            .as_ref()
            .ok_or_else(|| Error::Graph("paged pool not enabled".into()))?;
        let sl = pool.plane_slice_bytes;
        if bytes.len() != sl * pool.set.buffers.len() {
            return Err(Error::Graph(format!(
                "paged group upload: {} bytes != {} planes x {sl} B",
                bytes.len(),
                pool.set.buffers.len()
            )));
        }
        let off = group as usize * sl;
        for (p, &buf) in pool.set.buffers.iter().enumerate() {
            device.write_buffer(buf, off, &bytes[p * sl..(p + 1) * sl])?;
        }
        Ok(())
    }

    /// Compile the BATCHED decode graph into a plan and materialize its
    /// [`BatchedRunner`] (cache-set-table binding, padding set, `[W,vocab]`
    /// logits ring). Coexists with the single-session plan: the serving
    /// engine replays this one when a round has >= 2 active sessions.
    /// Weight inputs bind the buffers already pinned for the primary graph
    /// (matched by name) — no duplicate weight uploads.
    pub fn enable_batched_plan(
        &mut self,
        graph: &FxGraph,
        cfg: PlanConfig,
        width: usize,
    ) -> Result<()> {
        let t0 = Instant::now();
        let v0 = self.device.clock.now_ns();
        let pinned_map = self.pinned_for(graph);
        let plan = {
            let GraphExecutor { device, registry, pipelines, .. } = &mut *self;
            Planner::new(*registry).compile(device, pipelines, graph, &pinned_map, &cfg)?
        };
        let mut runner = if let Some(pp) = &self.paged {
            BatchedRunner::materialize_paged(&mut self.device, plan, width, &pp.set)?
        } else {
            BatchedRunner::materialize(&mut self.device, plan, width)?
        };
        runner.inner_mut().build_virtual_ns = self.device.clock.now_ns() - v0;
        runner.inner_mut().build_real_ns = t0.elapsed().as_nanos() as u64;
        self.batched = Some(runner);
        Ok(())
    }

    pub fn batched_runner(&self) -> Option<&BatchedRunner> {
        self.batched.as_ref()
    }

    /// Compile the chunked PREFILL graph into a plan and materialize its
    /// [`PrefillRunner`]. Requires the single-session decode plan first:
    /// both plans bind the SAME session cache sets, so their persistent
    /// layouts must match exactly — checked here so a drifted builder
    /// fails at engine construction, not mid-prompt. Weight inputs bind
    /// the buffers already pinned for the primary graph (matched by
    /// name) — no duplicate weight uploads.
    pub fn enable_prefill_plan(
        &mut self,
        graph: &FxGraph,
        cfg: PlanConfig,
        chunk: usize,
    ) -> Result<()> {
        let t0 = Instant::now();
        let v0 = self.device.clock.now_ns();
        let pinned_map = self.pinned_for(graph);
        let plan = {
            let GraphExecutor { device, registry, pipelines, .. } = &mut *self;
            Planner::new(*registry).compile(device, pipelines, graph, &pinned_map, &cfg)?
        };
        let primary = self.planned.as_ref().ok_or_else(|| {
            Error::Graph("enable_prefill_plan requires the decode plan to exist first".into())
        })?;
        if plan.persistent != primary.plan.persistent {
            return Err(Error::Graph(
                "prefill plan's persistent cache layout differs from the decode plan's \
                 (the session cache set must plug into both)"
                    .into(),
            ));
        }
        let mut runner = if let Some(pp) = &self.paged {
            PrefillRunner::materialize_paged(&mut self.device, plan, chunk, &pp.set)?
        } else {
            PrefillRunner::materialize(&mut self.device, plan, chunk)?
        };
        runner.inner_mut().build_virtual_ns = self.device.clock.now_ns() - v0;
        runner.inner_mut().build_real_ns = t0.elapsed().as_nanos() as u64;
        self.prefill = Some(runner);
        Ok(())
    }

    pub fn prefill_runner(&self) -> Option<&PrefillRunner> {
        self.prefill.as_ref()
    }

    /// Compile the UNIFIED round graph into a plan and materialize its
    /// [`UnifiedRunner`] (cache-set-table binding, padding set, `[W,vocab]`
    /// logits ring). Requires the batched plan first: both bind the SAME
    /// slot-major cache-set table, so their persistent layouts must match
    /// exactly — checked here so a drifted builder fails at engine
    /// construction, not mid-round. Weight inputs bind the buffers already
    /// pinned for the primary graph (matched by name) — no duplicate
    /// weight uploads.
    pub fn enable_unified_plan(
        &mut self,
        graph: &FxGraph,
        cfg: PlanConfig,
        width: usize,
        chunk: usize,
    ) -> Result<()> {
        let t0 = Instant::now();
        let v0 = self.device.clock.now_ns();
        let pinned_map = self.pinned_for(graph);
        let plan = {
            let GraphExecutor { device, registry, pipelines, .. } = &mut *self;
            Planner::new(*registry).compile(device, pipelines, graph, &pinned_map, &cfg)?
        };
        let batched = self.batched.as_ref().ok_or_else(|| {
            Error::Graph("enable_unified_plan requires the batched plan to exist first".into())
        })?;
        if plan.persistent != batched.plan().persistent {
            return Err(Error::Graph(
                "unified plan's persistent cache-set table differs from the batched \
                 plan's (session cache sets must plug into both)"
                    .into(),
            ));
        }
        let mut runner = if let Some(pp) = &self.paged {
            UnifiedRunner::materialize_paged(&mut self.device, plan, width, chunk, &pp.set)?
        } else {
            UnifiedRunner::materialize(&mut self.device, plan, width, chunk)?
        };
        runner.inner_mut().build_virtual_ns = self.device.clock.now_ns() - v0;
        runner.inner_mut().build_real_ns = t0.elapsed().as_nanos() as u64;
        self.unified = Some(runner);
        Ok(())
    }

    pub fn unified_runner(&self) -> Option<&UnifiedRunner> {
        self.unified.as_ref()
    }

    /// Replay the unified plan once over a cache-set table: one dispatch
    /// per layer op covers every active slot's prefill chunk or decode
    /// step. `None` slots bind the padding set and must be masked via the
    /// `slot_mask` input. `ring_idx` selects the chunk-of-slots'
    /// logits-ring buffer so every chunk of a round survives until the
    /// round's single coalesced readback. Fails loudly if `graph` is not
    /// the one the unified plan was compiled from.
    pub fn run_unified(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        table: &[Option<&DeviceKvCache>],
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let GraphExecutor {
            device, registry, unified, dispatch_count, framework_virtual_ns, ..
        } = self;
        let runner = unified.as_mut().ok_or_else(|| {
            Error::Graph("no unified plan enabled: call enable_unified_plan first".into())
        })?;
        let fp = crate::plan::GraphFingerprint::of(graph);
        if fp != runner.plan().fingerprint {
            return Err(Error::Graph(format!(
                "unified executor got a different graph ({fp:?}) than the compiled \
                 plan ({:?})",
                runner.plan().fingerprint
            )));
        }
        let (outs, logits_buf, delta) =
            runner.replay(device, *registry, inputs, ring_idx, table)?;
        *dispatch_count += delta.dispatches;
        *framework_virtual_ns += delta.framework_ns;
        Ok((outs, logits_buf, delta))
    }

    /// Replay the prefill plan once over a session's resident cache set:
    /// one `[C, H]` prompt chunk, C cache rows scattered per layer per
    /// dispatch. `ring_idx` selects the prefill logits-ring buffer (final
    /// chunks join the round's coalesced readback). Fails loudly if
    /// `graph` is not the one the prefill plan was compiled from.
    pub fn run_prefill(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        kv: Option<&DeviceKvCache>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let GraphExecutor {
            device, registry, prefill, dispatch_count, framework_virtual_ns, ..
        } = self;
        let runner = prefill.as_mut().ok_or_else(|| {
            Error::Graph("no prefill plan enabled: call enable_prefill_plan first".into())
        })?;
        let fp = crate::plan::GraphFingerprint::of(graph);
        if fp != runner.plan().fingerprint {
            return Err(Error::Graph(format!(
                "prefill executor got a different graph ({fp:?}) than the compiled \
                 plan ({:?})",
                runner.plan().fingerprint
            )));
        }
        let (outs, logits_buf, delta) =
            runner.replay(device, *registry, inputs, ring_idx, kv)?;
        *dispatch_count += delta.dispatches;
        *framework_virtual_ns += delta.framework_ns;
        Ok((outs, logits_buf, delta))
    }

    /// Replay the batched plan once over a cache-set table (slot ->
    /// session cache set; `None` slots bind the padding set and must be
    /// masked via the `slot_mask` input). `ring_idx` selects the chunk's
    /// logits-ring buffer so every chunk of a round survives until the
    /// round's single coalesced readback. Fails loudly if `graph` is not
    /// the one the batched plan was compiled from.
    pub fn run_batched(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        table: &[Option<&DeviceKvCache>],
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let GraphExecutor {
            device, registry, batched, dispatch_count, framework_virtual_ns, ..
        } = self;
        let runner = batched.as_mut().ok_or_else(|| {
            Error::Graph("no batched plan enabled: call enable_batched_plan first".into())
        })?;
        let fp = crate::plan::GraphFingerprint::of(graph);
        if fp != runner.plan().fingerprint {
            return Err(Error::Graph(format!(
                "batched executor got a different graph ({fp:?}) than the compiled \
                 plan ({:?})",
                runner.plan().fingerprint
            )));
        }
        let (outs, logits_buf, delta) =
            runner.replay(device, *registry, inputs, ring_idx, table)?;
        *dispatch_count += delta.dispatches;
        *framework_virtual_ns += delta.framework_ns;
        Ok((outs, logits_buf, delta))
    }

    pub fn plan_runner(&self) -> Option<&PlanRunner> {
        self.planned.as_ref()
    }

    pub fn kv_arena(&self) -> Option<&CacheArena> {
        self.kv_arena.as_ref()
    }

    /// Allocate a zeroed device-resident cache set for one session from
    /// the shared bounded pool and register its bind groups with the plan
    /// runner. Planned mode only.
    pub fn alloc_kv_cache(&mut self) -> Result<DeviceKvCache> {
        if self.paged.is_some() {
            return Err(Error::Graph(
                "paged mode: sessions hold block tables, not contiguous cache sets".into(),
            ));
        }
        let GraphExecutor { device, pool, kv_arena, planned, prefill, .. } = self;
        let arena = kv_arena
            .as_mut()
            .ok_or_else(|| Error::Graph("no plan enabled: cannot allocate KV cache".into()))?;
        let cache = arena.allocate(device, pool)?;
        if let Some(runner) = planned.as_mut() {
            runner.register_cache(device, &cache)?;
        }
        // The prefill plan binds the SAME set (identical persistent
        // layout): register its bind groups too, so a session's first
        // prompt chunk replays without a registration stall.
        if let Some(runner) = prefill.as_mut() {
            runner.register_cache(device, &cache)?;
        }
        Ok(cache)
    }

    /// Return a session's cache set to the pool (retire/reset path). The
    /// runner's bind groups stay cached so a recycled set is free to
    /// re-register.
    pub fn release_kv_cache(&mut self, cache: DeviceKvCache) -> Result<()> {
        let arena = self
            .kv_arena
            .as_mut()
            .ok_or_else(|| Error::Graph("no plan enabled: cannot release KV cache".into()))?;
        arena.release(&mut self.pool, cache)
    }

    /// Spill a session's device-resident caches to host tensors (spec
    /// order) — the evict half of the spill path. Pays the coalesced
    /// readback's sync + transfer cost.
    pub fn spill_kv_cache(&mut self, cache: &DeviceKvCache) -> Result<Vec<Tensor>> {
        let GraphExecutor { device, kv_arena, .. } = self;
        let arena = kv_arena
            .as_ref()
            .ok_or_else(|| Error::Graph("no plan enabled: cannot spill KV cache".into()))?;
        arena.spill_to_host(device, cache)
    }

    /// Upload host cache tensors (spec order) into a session's cache set —
    /// the restore half of the spill path. By reference: no host-side copy
    /// of the KV state, just the upload.
    pub fn hydrate_kv_cache(&mut self, cache: &DeviceKvCache, tensors: &[&Tensor]) -> Result<()> {
        let GraphExecutor { device, kv_arena, .. } = self;
        let arena = kv_arena
            .as_ref()
            .ok_or_else(|| Error::Graph("no plan enabled: cannot hydrate KV cache".into()))?;
        arena.upload_from_host(device, cache, tensors)
    }

    pub fn plan(&self) -> Option<&ExecutionPlan> {
        self.planned.as_ref().map(|r| &r.plan)
    }

    pub fn is_planned(&self) -> bool {
        self.planned.is_some()
    }

    /// Execute the graph. `inputs` must cover every non-pinned graph input.
    /// Returns (named outputs, the logits output's live buffer id) — the
    /// caller `map_read`s that buffer to model the per-token sync.
    pub fn run(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>)> {
        self.run_with_ring(graph, inputs, 0)
    }

    /// `run` with an explicit logits-ring index (planned mode): sessions
    /// replayed in the same scheduler round pass distinct indices so their
    /// logits survive until the round's coalesced readback. Eager mode
    /// ignores the index.
    pub fn run_with_ring(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>)> {
        self.run_with_session(graph, inputs, ring_idx, None)
    }

    /// `run_with_ring` plus the session's device-resident cache set —
    /// required in planned mode when the plan carries persistent values
    /// (KV caches). Eager mode ignores both extras.
    pub fn run_with_session(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        kv: Option<&DeviceKvCache>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>)> {
        if self.planned.is_some() {
            let GraphExecutor {
                device, registry, planned, dispatch_count, framework_virtual_ns, ..
            } = self;
            let runner = planned.as_mut().expect("planned mode checked above");
            // Fail loudly if the caller's graph is not the one the plan
            // was compiled from — replaying a stale plan would silently
            // produce the wrong outputs.
            let fp = crate::plan::GraphFingerprint::of(graph);
            if fp != runner.plan.fingerprint {
                return Err(Error::Graph(format!(
                    "planned executor got a different graph ({fp:?}) than the \
                     compiled plan ({:?}); call enable_plan for it first",
                    runner.plan.fingerprint
                )));
            }
            let (outs, logits_buf, delta) =
                runner.replay(device, *registry, inputs, ring_idx, kv)?;
            *dispatch_count += delta.dispatches;
            *framework_virtual_ns += delta.framework_ns;
            return Ok((outs, logits_buf));
        }
        self.run_eager(graph, inputs)
    }

    /// The eager per-node walk (the torch-webgpu pathology the plan
    /// removes): per-op framework cost, per-op encoder + submit, host
    /// round-trip per intermediate.
    fn run_eager(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>)> {
        let GraphExecutor {
            device,
            registry,
            pipelines,
            pool,
            pinned,
            bind_cache,
            key_scratch,
            in_scratch,
            out_scratch,
            borrowed_scratch,
            framework_ns_per_op,
            dispatch_count,
            framework_virtual_ns,
            ..
        } = self;

        let mut values: Vec<Option<Tensor>> = vec![None; graph.n_values];
        for (name, &vid) in &graph.inputs {
            if pinned.contains_key(&vid) {
                continue; // weight lives in its persistent device buffer
            }
            let t = inputs
                .get(name)
                .ok_or_else(|| Error::Graph(format!("missing graph input '{name}'")))?;
            values[vid.0] = Some(t.clone());
        }

        let logits_value = graph.outputs.get("logits").copied();
        let mut logits_buffer: Option<BufferId> = None;

        for node in &graph.nodes {
            match &node.op {
                OpKind::Host(op) => {
                    run_host(&node.name, *op, &node.inputs, &node.outputs, &mut values)?;
                }
                // Eager mode executes in-place kernels functionally: the
                // output materializes in a fresh pooled buffer and round-
                // trips through the host like any other value — exactly
                // the per-step cache traffic the paper's pathology pays.
                OpKind::Kernel(kname) | OpKind::InPlaceKernel(kname) => {
                    let t_op = device.clock.now_ns();
                    // (1) framework overhead — Python interpreter / tensor
                    // metadata cost in torch-webgpu (drifted per run).
                    let fw = device.drifted_cost(*framework_ns_per_op);
                    device.clock.advance_cpu(fw);
                    *framework_virtual_ns += fw;

                    let prep = pipelines.get(kname).ok_or_else(|| {
                        Error::Graph(format!("kernel '{kname}' not prepared"))
                    })?;

                    // (2) bind inputs: pinned weights directly, activations
                    // via pooled upload. Scratch vecs are reused — no
                    // per-dispatch allocation on the steady state.
                    in_scratch.clear();
                    out_scratch.clear();
                    borrowed_scratch.clear();
                    for (i, spec) in prep.inputs.iter().enumerate() {
                        if let Some(&buf) = pinned.get(&node.inputs[i]) {
                            in_scratch.push(buf);
                            continue;
                        }
                        let t = values[node.inputs[i].0].as_ref().ok_or_else(|| {
                            Error::Graph(format!("{}: input {i} missing", node.name))
                        })?;
                        if t.shape != spec.shape {
                            return Err(Error::Graph(format!(
                                "{}: input {i} shape {:?} != kernel spec {:?}",
                                node.name, t.shape, spec.shape
                            )));
                        }
                        let size = spec.size_bytes();
                        let buf = pool.acquire(device, size)?;
                        device.write_buffer(buf, 0, t.data.as_bytes())?;
                        in_scratch.push(buf);
                        borrowed_scratch.push((size, buf));
                    }
                    for spec in &prep.outputs {
                        let size = spec.size_bytes();
                        let buf = pool.acquire(device, size)?;
                        out_scratch.push(buf);
                        borrowed_scratch.push((size, buf));
                    }

                    // (3) the 8-phase dispatch sequence. Bind groups are
                    // cached by (layout, buffers); the probe borrows the
                    // scratch key, cloning only on the insert (miss) path.
                    key_scratch.clear();
                    key_scratch.extend_from_slice(in_scratch.as_slice());
                    key_scratch.extend_from_slice(out_scratch.as_slice());
                    let by_layout = bind_cache.entry(prep.layout.0).or_default();
                    let group = match by_layout.get(key_scratch.as_slice()) {
                        Some(&g) => g,
                        None => {
                            let g = bind_buffers(
                                device,
                                &node.name,
                                prep.layout,
                                in_scratch.as_slice(),
                                out_scratch.as_slice(),
                            )?;
                            by_layout.insert(key_scratch.clone(), g);
                            g
                        }
                    };
                    let enc = device.create_command_encoder(&node.name);
                    device.begin_compute_pass(enc)?;
                    device.set_pipeline(enc, prep.pipeline)?;
                    device.set_bind_group(enc, group)?;
                    device.dispatch_workgroups(enc, prep.grid.0, prep.grid.1, prep.grid.2)?;
                    device.end_compute_pass(enc)?;
                    let cb = device.finish(enc)?;
                    device.submit(&[cb], *registry)?;
                    *dispatch_count += 1;
                    if device.trace.on() {
                        // Retroactive per-op span with the fx node name:
                        // framework + upload + 8-phase encode + submit.
                        let op = device.trace.intern(&node.name);
                        let now = device.clock.now_ns();
                        device.trace.complete(
                            op,
                            crate::trace::TRACK_ENGINE,
                            t_op,
                            now - t_op,
                            0,
                        );
                    }

                    // (4) chain outputs GPU-side (peek: no sync cost).
                    for (j, spec) in prep.outputs.iter().enumerate() {
                        let bytes = device.peek_buffer(out_scratch[j])?.to_vec();
                        let t = bytes_to_tensor(spec, &bytes)?;
                        values[node.outputs[j].0] = Some(t);
                    }

                    // Keep the logits buffer alive for the caller's map_read.
                    let produces_logits =
                        logits_value.is_some_and(|lv| node.outputs.contains(&lv));
                    let last_out = out_scratch.last().copied();
                    for &(size, buf) in borrowed_scratch.iter() {
                        if produces_logits && Some(buf) == last_out {
                            logits_buffer = Some(buf);
                        } else {
                            pool.release(size, buf);
                        }
                    }
                }
            }
        }

        let mut outs = HashMap::with_capacity(graph.outputs.len());
        for (name, &vid) in &graph.outputs {
            let t = values[vid.0]
                .take()
                .or_else(|| values[vid.0].clone())
                .ok_or_else(|| Error::Graph(format!("output '{name}' not produced")))?;
            outs.insert(name.clone(), t);
        }
        Ok((outs, logits_buffer))
    }

    pub fn registry(&self) -> &'r Registry {
        self.registry
    }

    pub fn registry_spec(&self, name: &str) -> Result<&crate::runtime::registry::KernelSpec> {
        self.registry.spec(name)
    }

    /// Return the logits buffer to the pool once the caller is done with
    /// it. Plan-owned ring buffers (single-session, batched, and prefill)
    /// are permanent and stay put.
    pub fn release_logits(&mut self, buf: BufferId) -> Result<()> {
        if let Some(runner) = &self.planned {
            if runner.owns_buffer(buf) {
                return Ok(());
            }
        }
        if let Some(runner) = &self.batched {
            if runner.owns_buffer(buf) {
                return Ok(());
            }
        }
        if let Some(runner) = &self.prefill {
            if runner.owns_buffer(buf) {
                return Ok(());
            }
        }
        if let Some(runner) = &self.unified {
            if runner.owns_buffer(buf) {
                return Ok(());
            }
        }
        let size = self.device.buffer_size(buf)?;
        self.pool.release(size, buf);
        Ok(())
    }
}

fn run_host(
    node_name: &str,
    op: HostOp,
    inputs: &[ValueId],
    outputs: &[ValueId],
    values: &mut [Option<Tensor>],
) -> Result<()> {
    let get = |v: ValueId, values: &[Option<Tensor>]| -> Result<Tensor> {
        values[v.0]
            .clone()
            .ok_or_else(|| Error::Graph(format!("{node_name}: host op input {v:?} missing")))
    };
    match op {
        HostOp::Embed => {
            // Engine performs embedding before run(); unused in graphs.
            Err(Error::Graph("Embed host op not graph-executable".into()))
        }
        HostOp::SplitKv => {
            let kv = get(inputs[0], values)?;
            let (k, v) = hostops::split_kv(&kv)?;
            values[outputs[0].0] = Some(k);
            values[outputs[1].0] = Some(v);
            Ok(())
        }
        HostOp::ToHeads { heads, head_dim } => {
            let x = get(inputs[0], values)?;
            values[outputs[0].0] = Some(hostops::to_heads(&x, heads, head_dim)?);
            Ok(())
        }
        HostOp::FromHeads => {
            let x = get(inputs[0], values)?;
            values[outputs[0].0] = Some(hostops::from_heads(&x)?);
            Ok(())
        }
        HostOp::Halves => {
            let x = get(inputs[0], values)?;
            let (a, b) = hostops::halves(&x)?;
            values[outputs[0].0] = Some(a);
            values[outputs[1].0] = Some(b);
            Ok(())
        }
    }
}

fn bytes_to_tensor(spec: &KernelIoSpec, bytes: &[u8]) -> Result<Tensor> {
    Tensor::from_le_bytes(spec.shape.clone(), spec.dtype, bytes)
}

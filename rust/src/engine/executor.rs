//! Graph executor: one WebGPU dispatch per kernel node, host ops in
//! between, buffer pooling, per-op framework-overhead accounting.
//!
//! This is the torch-webgpu eager executor analogue: it walks the FX graph
//! in order, paying (1) the per-op framework cost (Python interpreter /
//! tensor metadata in the paper, ~59-71 us — a virtual-clock constant
//! here), (2) the full 8-phase dispatch sequence per kernel node, and
//! (3) kernel execution on the kernel runtime. Intermediate values chain
//! GPU-side (no sync); only the caller's explicit `map_read` on the logits
//! buffer synchronizes.
//!
//! Everything a `GraphExecutor` owns is **session-independent** and shared
//! by the multi-session serving engine (`crate::serve`): the device, the
//! prepared-pipeline cache, the bind-group-layout cache, the size-class
//! buffer pool, the bind-group cache, and the pinned weight buffers.
//! Per-session decode state (KV caches, position, generated tokens) lives
//! in `crate::serve::SessionState` — the executor never sees it except as
//! the `inputs` of one `run` call.

use std::collections::HashMap;

use crate::fx::graph::FxGraph;
use crate::fx::node::{HostOp, OpKind, ValueId};
use crate::runtime::hostops;
use crate::runtime::registry::Registry;
use crate::tensor::Tensor;
use crate::webgpu::queue::{bind_buffers, kernel_layout};
use crate::webgpu::{
    BindGroupLayoutId, BufferDesc, BufferId, BufferUsage, ComputePipelineId,
    Device, KernelIoSpec, ShaderModuleDesc,
};
use crate::{Error, Result};

/// A prepared pipeline: compiled-pipeline id + its layout + IO specs.
#[derive(Debug, Clone)]
struct Prepared {
    pipeline: ComputePipelineId,
    layout: BindGroupLayoutId,
    inputs: Vec<KernelIoSpec>,
    outputs: Vec<KernelIoSpec>,
    workgroups: (u32, u32, u32),
}

/// Shared prepared-pipeline + bind-group-layout cache. Pipelines compile
/// once per kernel name (off the request path, like Dawn pipeline caching)
/// and are reused by every session the serving engine interleaves.
#[derive(Default)]
struct PipelineCache {
    prepared: HashMap<String, Prepared>,
    layouts: HashMap<(usize, usize), BindGroupLayoutId>,
}

impl PipelineCache {
    /// Create pipelines for every kernel a graph uses and compile the AOT
    /// modules.
    fn prepare(&mut self, device: &mut Device, registry: &Registry, graph: &FxGraph) -> Result<()> {
        for name in graph.kernel_names() {
            if self.prepared.contains_key(&name) {
                continue;
            }
            registry.ensure_loaded(&name)?;
            let spec = registry.spec(&name)?;
            let key = (spec.inputs.len(), spec.outputs.len());
            let layout = match self.layouts.get(&key) {
                Some(&l) => l,
                None => {
                    let l = kernel_layout(device, &name, key.0, key.1)?;
                    self.layouts.insert(key, l);
                    l
                }
            };
            let module = device.create_shader_module(ShaderModuleDesc {
                label: name.clone(),
                kernel: name.clone(),
                inputs: spec.inputs.clone(),
                outputs: spec.outputs.clone(),
            })?;
            let pipeline = device.create_compute_pipeline(&name, module, layout)?;
            // Workgroup count: ceil(out elements / 256) — matches the WGSL
            // convention of 256-thread workgroups.
            let out_elems: usize = spec.outputs.iter().map(KernelIoSpec::numel).sum();
            let wg = ((out_elems + 255) / 256).max(1) as u32;
            self.prepared.insert(
                name.clone(),
                Prepared {
                    pipeline,
                    layout,
                    inputs: spec.inputs.clone(),
                    outputs: spec.outputs.clone(),
                    workgroups: (wg.min(65_535), 1, 1),
                },
            );
        }
        Ok(())
    }
}

pub struct GraphExecutor<'r> {
    pub device: Device,
    registry: &'r Registry,
    pipelines: PipelineCache,
    /// Size-class buffer pool (the paper's buffer-pooling experiment; on by
    /// default because re-creating buffers per dispatch is purely hostile).
    /// Shared across sessions: a retired session's buffers are recycled by
    /// whichever session dispatches next.
    pool: HashMap<usize, Vec<BufferId>>,
    /// PERF (§Perf L3): weights pinned into persistent device buffers at
    /// prepare time — uploaded once, bound directly per dispatch. This is
    /// also the faithful WebGPU pattern: weight buffers live on the GPU for
    /// the model's lifetime; only activations move. One copy serves every
    /// session.
    pinned: HashMap<ValueId, BufferId>,
    /// PERF: bind-group cache keyed by (layout, bound buffers) — the
    /// paper's "bind group caching" experiment (hash-based lookup, §5.1).
    /// With pinned weights + pooled activations the key set is small, so
    /// bind-group creation cost is paid O(distinct bindings), not O(steps).
    bind_cache: HashMap<(u64, Vec<BufferId>), crate::webgpu::BindGroupId>,
    /// Per-op framework overhead (virtual ns) — the "Python/framework"
    /// component of the paper's ~95 us per-operation overhead.
    pub framework_ns_per_op: u64,
    /// Dispatches issued since construction.
    pub dispatch_count: u64,
    /// Accumulated framework-overhead virtual ns (for per-session and
    /// per-phase attribution in the serving metrics).
    pub framework_virtual_ns: u64,
}

impl<'r> GraphExecutor<'r> {
    pub fn new(device: Device, registry: &'r Registry, framework_ns_per_op: u64) -> Self {
        GraphExecutor {
            device,
            registry,
            pipelines: PipelineCache::default(),
            pool: HashMap::new(),
            pinned: HashMap::new(),
            bind_cache: HashMap::new(),
            framework_ns_per_op,
            dispatch_count: 0,
            framework_virtual_ns: 0,
        }
    }

    /// Upload weight tensors into persistent device buffers, once. Inputs
    /// named in `weights` are bound directly at dispatch time instead of
    /// being re-uploaded per use.
    pub fn pin_inputs(
        &mut self,
        graph: &FxGraph,
        weights: &HashMap<String, Tensor>,
    ) -> Result<usize> {
        let mut pinned = 0;
        for (name, &vid) in &graph.inputs {
            let Some(t) = weights.get(name) else { continue };
            let buf = self.device.create_buffer(BufferDesc {
                label: format!("weight-{name}"),
                size: t.size_bytes(),
                usage: BufferUsage::STORAGE | BufferUsage::COPY_DST,
            })?;
            self.device.write_buffer(buf, 0, t.data.as_bytes())?;
            self.pinned.insert(vid, buf);
            pinned += 1;
        }
        Ok(pinned)
    }

    /// Create pipelines for every kernel a graph uses (off the request
    /// path; shared across all sessions).
    pub fn prepare(&mut self, graph: &FxGraph) -> Result<()> {
        self.pipelines.prepare(&mut self.device, self.registry, graph)
    }

    fn acquire(&mut self, size: usize) -> Result<BufferId> {
        if let Some(free) = self.pool.get_mut(&size) {
            if let Some(b) = free.pop() {
                return Ok(b);
            }
        }
        self.device.create_buffer(BufferDesc {
            label: format!("pool-{size}"),
            size,
            usage: BufferUsage::STORAGE
                | BufferUsage::COPY_DST
                | BufferUsage::COPY_SRC
                | BufferUsage::MAP_READ,
        })
    }

    fn release(&mut self, size: usize, id: BufferId) {
        self.pool.entry(size).or_default().push(id);
    }

    /// Execute the graph. `inputs` must cover every graph input.
    /// Returns (named outputs, the logits output's live buffer id) — the
    /// caller `map_read`s that buffer to model the per-token sync.
    pub fn run(
        &mut self,
        graph: &FxGraph,
        inputs: &HashMap<String, Tensor>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>)> {
        let mut values: Vec<Option<Tensor>> = vec![None; graph.n_values];
        for (name, &vid) in &graph.inputs {
            if self.pinned.contains_key(&vid) {
                continue; // weight lives in its persistent device buffer
            }
            let t = inputs
                .get(name)
                .ok_or_else(|| Error::Graph(format!("missing graph input '{name}'")))?;
            values[vid.0] = Some(t.clone());
        }

        let logits_value = graph.outputs.get("logits").copied();
        let mut logits_buffer: Option<BufferId> = None;
        let mut borrowed: Vec<(usize, BufferId)> = Vec::with_capacity(8);

        for node in &graph.nodes {
            match &node.op {
                OpKind::Host(op) => {
                    self.run_host(*op, node.inputs.as_slice(), &node.outputs, &mut values)?;
                }
                OpKind::Kernel(kname) => {
                    // (1) framework overhead — Python interpreter / tensor
                    // metadata cost in torch-webgpu (drifted per run).
                    let fw = self.device.drifted_cost(self.framework_ns_per_op);
                    self.device.clock.advance_cpu(fw);
                    self.framework_virtual_ns += fw;

                    let prep = self
                        .pipelines
                        .prepared
                        .get(kname)
                        .ok_or_else(|| {
                            Error::Graph(format!("kernel '{kname}' not prepared"))
                        })?
                        .clone();

                    // (2) bind inputs: pinned weights directly, activations
                    // via pooled upload.
                    borrowed.clear();
                    let mut in_bufs = Vec::with_capacity(prep.inputs.len());
                    for (i, spec) in prep.inputs.iter().enumerate() {
                        if let Some(&buf) = self.pinned.get(&node.inputs[i]) {
                            in_bufs.push(buf);
                            continue;
                        }
                        let t = values[node.inputs[i].0].as_ref().ok_or_else(|| {
                            Error::Graph(format!("{}: input {i} missing", node.name))
                        })?;
                        if t.shape != spec.shape {
                            return Err(Error::Graph(format!(
                                "{}: input {i} shape {:?} != kernel spec {:?}",
                                node.name, t.shape, spec.shape
                            )));
                        }
                        let size = spec.size_bytes();
                        let buf = self.acquire(size)?;
                        self.device.write_buffer(buf, 0, t.data.as_bytes())?;
                        in_bufs.push(buf);
                        borrowed.push((size, buf));
                    }
                    let mut out_bufs = Vec::with_capacity(prep.outputs.len());
                    for spec in &prep.outputs {
                        let size = spec.size_bytes();
                        let buf = self.acquire(size)?;
                        out_bufs.push(buf);
                        borrowed.push((size, buf));
                    }

                    // (3) the 8-phase dispatch sequence. Bind groups are
                    // cached by (layout, buffers) — hash-based lookup.
                    let mut key_bufs = in_bufs.clone();
                    key_bufs.extend_from_slice(&out_bufs);
                    let cache_key = (prep.layout.0, key_bufs);
                    let group = match self.bind_cache.get(&cache_key) {
                        Some(&g) => g,
                        None => {
                            let g = bind_buffers(
                                &mut self.device, &node.name, prep.layout, &in_bufs, &out_bufs,
                            )?;
                            self.bind_cache.insert(cache_key, g);
                            g
                        }
                    };
                    let enc = self.device.create_command_encoder(&node.name);
                    self.device.begin_compute_pass(enc)?;
                    self.device.set_pipeline(enc, prep.pipeline)?;
                    self.device.set_bind_group(enc, group)?;
                    self.device.dispatch_workgroups(
                        enc,
                        prep.workgroups.0,
                        prep.workgroups.1,
                        prep.workgroups.2,
                    )?;
                    self.device.end_compute_pass(enc)?;
                    let cb = self.device.finish(enc)?;
                    self.device.submit(&[cb], self.registry)?;
                    self.dispatch_count += 1;

                    // (4) chain outputs GPU-side (peek: no sync cost).
                    for (j, spec) in prep.outputs.iter().enumerate() {
                        let bytes = self.device.peek_buffer(out_bufs[j])?.to_vec();
                        let t = bytes_to_tensor(spec, &bytes)?;
                        values[node.outputs[j].0] = Some(t);
                    }

                    // Keep the logits buffer alive for the caller's map_read.
                    let produces_logits =
                        logits_value.is_some_and(|lv| node.outputs.contains(&lv));
                    for &(size, buf) in &borrowed {
                        if produces_logits && Some(buf) == out_bufs.last().copied() {
                            logits_buffer = Some(buf);
                        } else {
                            self.release(size, buf);
                        }
                    }
                }
            }
        }

        let mut outs = HashMap::with_capacity(graph.outputs.len());
        for (name, &vid) in &graph.outputs {
            let t = values[vid.0]
                .take()
                .or_else(|| values[vid.0].clone())
                .ok_or_else(|| Error::Graph(format!("output '{name}' not produced")))?;
            outs.insert(name.clone(), t);
        }
        Ok((outs, logits_buffer))
    }

    pub fn registry(&self) -> &'r Registry {
        self.registry
    }

    pub fn registry_spec(&self, name: &str) -> Result<&crate::runtime::registry::KernelSpec> {
        self.registry.spec(name)
    }

    /// Return the logits buffer to the pool once the caller is done with it.
    pub fn release_logits(&mut self, buf: BufferId) -> Result<()> {
        let size = self.device.buffer_size(buf)?;
        self.release(size, buf);
        Ok(())
    }

    fn run_host(
        &mut self,
        op: HostOp,
        inputs: &[ValueId],
        outputs: &[ValueId],
        values: &mut [Option<Tensor>],
    ) -> Result<()> {
        let get = |v: ValueId, values: &[Option<Tensor>]| -> Result<Tensor> {
            values[v.0]
                .clone()
                .ok_or_else(|| Error::Graph(format!("host op input {v:?} missing")))
        };
        match op {
            HostOp::Embed => {
                // Engine performs embedding before run(); unused in graphs.
                return Err(Error::Graph("Embed host op not graph-executable".into()));
            }
            HostOp::SplitKv => {
                let kv = get(inputs[0], values)?;
                let (k, v) = hostops::split_kv(&kv)?;
                values[outputs[0].0] = Some(k);
                values[outputs[1].0] = Some(v);
            }
            HostOp::ToHeads { heads, head_dim } => {
                let x = get(inputs[0], values)?;
                values[outputs[0].0] = Some(hostops::to_heads(&x, heads, head_dim)?);
            }
            HostOp::FromHeads => {
                let x = get(inputs[0], values)?;
                values[outputs[0].0] = Some(hostops::from_heads(&x)?);
            }
            HostOp::Halves => {
                let x = get(inputs[0], values)?;
                let (a, b) = hostops::halves(&x)?;
                values[outputs[0].0] = Some(a);
                values[outputs[1].0] = Some(b);
            }
        }
        Ok(())
    }
}

fn bytes_to_tensor(spec: &KernelIoSpec, bytes: &[u8]) -> Result<Tensor> {
    use crate::tensor::DType;
    let n = spec.numel();
    if bytes.len() < n * 4 {
        return Err(Error::Shape(format!(
            "buffer {} B too small for spec {:?}",
            bytes.len(),
            spec.shape
        )));
    }
    match spec.dtype {
        DType::F32 => {
            let v: Vec<f32> = bytes[..n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::f32(spec.shape.clone(), v)
        }
        DType::I32 => {
            let v: Vec<i32> = bytes[..n * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::i32(spec.shape.clone(), v)
        }
    }
}

//! Autoregressive single-request engine — a 1-session wrapper over the
//! multi-session [`ServingEngine`](crate::serve::ServingEngine).
//!
//! Per token: host embedding gather -> full decode-step graph (one WebGPU
//! dispatch per kernel node) -> logits readback via `map_read` (the paper's
//! per-token GPU->CPU sync, ~11 ms) -> host argmax -> next token. The
//! device-side-argmax variant (Appendix H) replaces the full-logits
//! readback with an extra dispatch plus a 4-byte readback.
//!
//! The engine owns exactly one [`SessionState`] and drives it through the
//! serving engine's encode/finish path, so a `generate()` here is cost-
//! and token-identical to a 1-session serving run (`wdb serve-bench`'s
//! N=1 row checks this).

use std::ops::{Deref, DerefMut};
use std::time::Instant;

use crate::fx::builder::FusionConfig;
use crate::runtime::registry::Registry;
use crate::serve::{ServeConfig, ServingEngine, SessionState};
use crate::webgpu::ImplementationProfile;
use crate::{Error, Result};

/// Default torch-webgpu framework overhead: per-operation overhead (~95 us)
/// minus Dawn's per-dispatch cost (~24 us) -> ~71 us of Python/framework
/// cost per op (paper §4.4).
pub const TORCH_WEBGPU_FRAMEWORK_NS: u64 = 71_000;

/// Default batched-decode slot width for the serving engine. Rounds with
/// >= 2 active planned sessions replay the batched graph (one dispatch per
/// layer op for up to this many sessions); wider rounds run in chunks.
/// `wdb serve`/`serve-bench` override with `--batch-width` / `--no-batch`.
pub const DEFAULT_BATCH_WIDTH: usize = 4;

/// Default tokens-per-block for paged KV residency (planned serving).
/// `wdb serve`/`serve-bench` override with `--kv-block`; `--no-paged`
/// restores the contiguous per-session cache sets.
pub const DEFAULT_KV_BLOCK: usize = 16;

/// Default chunked-prefill size for the serving engine: planned-mode
/// sessions ingest their prompt in seq-dim batched chunks of this many
/// tokens (one dispatch per layer op per chunk) instead of one decode
/// step per prompt token. `wdb serve`/`serve-bench` override with
/// `--prefill-chunk` (0 disables — token-by-token prompt ingestion).
pub const DEFAULT_PREFILL_CHUNK: usize = 16;

/// How the engine executes the decode graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Per-node graph interpretation with per-op framework cost — the
    /// torch-webgpu pathology the paper characterizes.
    Eager,
    /// Compile-once [`crate::plan::ExecutionPlan`] replayed per token:
    /// device-resident values + per-session KV caches, lifetime-aliased
    /// arena, encoder batching.
    Planned,
}

impl ExecMode {
    /// The serving-path default (`wdb serve` / `serve-bench`): planned
    /// replay with device-resident caches. The single-request bench path
    /// (`wdb e2e`) stays eager so the paper's pathology stays measurable.
    pub fn serving_default() -> Self {
        ExecMode::Planned
    }
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub fusion: FusionConfig,
    pub profile: ImplementationProfile,
    /// Per-op framework overhead (virtual ns).
    pub framework_ns_per_op: u64,
    /// Appendix H: argmax on-device (readback 4 bytes) instead of the full
    /// logits row.
    pub device_argmax: bool,
    pub weight_seed: u64,
    /// How kernel time advances the virtual GPU frontier. `Calibrated`
    /// (default) keeps benchmark CV at the profile's jitter; `Measured`
    /// feeds real kernel wall time into the clock (the real-system mode).
    pub kernel_time_policy: crate::webgpu::device::KernelTimePolicy,
    /// Eager interpretation (default) or compile-once plan replay.
    pub exec: ExecMode,
    /// Planned mode: dispatches carried per encoder/submit (the paper's
    /// encoder-batching axis).
    pub dispatches_per_submit: usize,
    /// Planned mode: framework cost charged per replayed step (virtual
    /// ns) — the replay loop's residual bookkeeping.
    pub planned_framework_ns_per_step: u64,
    /// Byte cap for the eager activation pool: `None` grows on demand,
    /// `Some(cap)` errors past the cap instead of growing silently.
    pub pool_cap_bytes: Option<usize>,
    /// Batched-decode slot width for multi-session serving rounds
    /// (planned mode only). `0` or `1` disables batching: every round
    /// interleaves per-session replays (the pre-batching behavior).
    /// `>= 2` makes rounds with that many active sessions replay the
    /// batched graph — one dispatch per layer op per round. Capped by the
    /// serving engine at `max_concurrent`; requesting a width above
    /// [`crate::fx::MAX_BATCH_WIDTH`] (the built-in kernel coverage)
    /// fails at engine construction, regardless of `max_concurrent`.
    /// Ignored by single-session engines.
    pub batch_width: usize,
    /// Chunked-prefill size for planned serving (`0` or `1` disables:
    /// prompts feed one token per round, the pre-chunking behavior).
    /// `>= 2` makes prompt ingestion replay the seq-dim prefill graph in
    /// chunks of this many tokens — one dispatch per layer op per chunk,
    /// the TTFT twin of `batch_width`'s decode amortization. Must be one
    /// of [`crate::fx::PREFILL_CHUNKS`] (the built-in kernel coverage);
    /// other values fail at engine construction. Ignored in eager mode
    /// and by the device-argmax finish variant.
    pub prefill_chunk: usize,
    /// Unified continuous-batching rounds (planned serving only, default
    /// on): when both `batch_width >= 2` and `prefill_chunk >= 2` are in
    /// effect, EVERY serving round replays the unified `[W*C, H]`
    /// seq-x-batch graph — prefill chunks and decode steps share one
    /// dispatch per layer op, so prompts arriving mid-run no longer cost
    /// a separate prefill round. `false` falls back to the PR-4/PR-5
    /// split scheduling (prefill rounds, then batched decode rounds) —
    /// the comparison twin `wdb serve-bench --no-unified` measures.
    pub unified: bool,
    /// Speculative decode draft depth: up to this many n-gram-drafted
    /// tokens per session are verified in ONE unified chunk replay
    /// (`valid_len = accepted + 1` instead of 1). `0` disables. Only the
    /// unified planned path speculates (it needs the multi-row logits
    /// tail); token streams stay bit-identical to non-speculative greedy
    /// decode at every acceptance rate — rejected rows are rolled back by
    /// rewinding the session position. `wdb serve`/`serve-bench` override
    /// with `--speculate K`.
    pub speculate: usize,
    /// Paged KV residency (planned serving only, default on): session KV
    /// lives in fixed-size blocks of shared pool planes routed by per-slot
    /// block tables, instead of one contiguous per-session cache set.
    /// Sessions admit as long as scheduling allows — under memory
    /// pressure the pager spills cold blocks to the host (LRU, coldest
    /// prompt-prefix blocks first) rather than rejecting admits. Token
    /// streams stay byte-identical to contiguous caching.
    /// `wdb serve`/`serve-bench` override with `--no-paged`.
    pub paged: bool,
    /// Tokens per KV block in paged mode. Must be one of
    /// [`crate::fx::KV_BLOCKS`] (and divide `max_seq`); other values fail
    /// at engine construction. `wdb serve`/`serve-bench` override with
    /// `--kv-block`.
    pub kv_block: usize,
    /// Deterministic fault injection: `Some(seed)` installs a seeded
    /// [`crate::webgpu::FaultPlan`] (transient dispatch failures,
    /// allocation failures, readback timeouts) on the serving engine's
    /// device at construction. The recovery layer (per-session quarantine
    /// + snapshot-replay) must keep token streams byte-identical to the
    /// uninjected twin — `wdb serve-bench --inject-faults` gates on it.
    /// `None` (default) injects nothing.
    pub fault_seed: Option<u64>,
    /// Span tracer configuration for the serving engine's device: `Null`
    /// (default) discards events, `Ring` keeps the most recent
    /// `trace.ring` events in a fixed-capacity buffer, `Chrome` retains
    /// everything for `--trace-out` export. Tracing never perturbs the
    /// virtual clock or the jitter stream, so token streams are
    /// bit-identical across sinks. `wdb serve`/`serve-bench` override
    /// with `--trace-out` / `--trace-ring`.
    pub trace: crate::trace::TraceConfig,
    /// Override the manifest dims (executable workload variants — e.g.
    /// tiny-kernel graphs at different layer counts).
    pub dims_override: Option<crate::fx::builder::GraphDims>,
}

impl EngineConfig {
    pub fn tiny_fused() -> Self {
        EngineConfig {
            model: "qwen-tiny".into(),
            fusion: FusionConfig::fused(),
            profile: ImplementationProfile::dawn_vulkan_rtx5090(),
            framework_ns_per_op: TORCH_WEBGPU_FRAMEWORK_NS,
            device_argmax: false,
            weight_seed: 0xC0FFEE,
            kernel_time_policy: crate::webgpu::device::KernelTimePolicy::Calibrated,
            exec: ExecMode::Eager,
            dispatches_per_submit: 16,
            planned_framework_ns_per_step: crate::plan::PLANNED_FRAMEWORK_NS,
            pool_cap_bytes: None,
            batch_width: DEFAULT_BATCH_WIDTH,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            unified: true,
            speculate: 0,
            paged: true,
            kv_block: DEFAULT_KV_BLOCK,
            fault_seed: None,
            trace: crate::trace::TraceConfig::default(),
            dims_override: None,
        }
    }

    pub fn tiny_unfused() -> Self {
        EngineConfig { fusion: FusionConfig::unfused(), ..Self::tiny_fused() }
    }

    /// Planned-execution twin of [`EngineConfig::tiny_fused`].
    pub fn tiny_planned() -> Self {
        EngineConfig { exec: ExecMode::Planned, ..Self::tiny_fused() }
    }

    /// The serving default: planned replay with device-resident KV caches.
    /// Eager stays [`EngineConfig::tiny_fused`]'s default so the paper's
    /// per-op pathology remains directly measurable (`wdb e2e`).
    pub fn tiny_serving() -> Self {
        EngineConfig { exec: ExecMode::serving_default(), ..Self::tiny_fused() }
    }
}

/// One generation run's measurements.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<usize>,
    /// Virtual ns from start to the first generated token (prefill + first
    /// decode step + sync) — the paper's TTFT.
    pub ttft_ns: u64,
    /// Virtual ns for the whole generation.
    pub total_ns: u64,
    /// Virtual ns per generated token (decode steps only).
    pub per_token_ns: Vec<u64>,
    /// Dispatches per decode step.
    pub dispatches_per_step: u64,
    /// Real wall time of the whole run on this host.
    pub real_wall_ns: u64,
    /// tok/s in virtual time (the paper's headline metric).
    pub tok_per_s: f64,
}

pub struct Engine<'r> {
    /// The underlying 1-session serving engine (shared device, prepared
    /// pipelines, buffer pool, pinned weights). `Deref` exposes its
    /// `executor`/`dims`/`graph`/`weights`/`config` fields directly; the
    /// engine's `EngineConfig` lives at `serving.config.engine` (single
    /// source of truth — no duplicated copy to drift).
    pub serving: ServingEngine<'r>,
    session: SessionState,
}

impl<'r> Deref for Engine<'r> {
    type Target = ServingEngine<'r>;

    fn deref(&self) -> &ServingEngine<'r> {
        &self.serving
    }
}

impl<'r> DerefMut for Engine<'r> {
    fn deref_mut(&mut self) -> &mut ServingEngine<'r> {
        &mut self.serving
    }
}

impl<'r> Engine<'r> {
    pub fn new(registry: &'r Registry, config: EngineConfig) -> Result<Self> {
        let serving = ServingEngine::new(
            registry,
            ServeConfig { engine: config, max_concurrent: 1 },
        )?;
        // An open-ended session for manual `step()` driving; `generate`
        // replaces it per run.
        let session = serving.create_session(Vec::new(), usize::MAX, 0);
        Ok(Engine { serving, session })
    }

    /// Drop all decode state (KV caches, position, token history). A
    /// device-resident cache set goes back to the shared pool first — a
    /// fresh session re-allocates a zeroed set from the recycled buffers.
    pub fn reset(&mut self) -> Result<()> {
        let mut old = std::mem::replace(
            &mut self.session,
            self.serving.create_session(Vec::new(), usize::MAX, 0),
        );
        self.serving.release_session_cache(&mut old)
    }

    /// Reseed the virtual-cost jitter (independent benchmark runs).
    pub fn reseed(&mut self, seed: u64) {
        self.serving.reseed(seed);
    }

    /// One decode step: returns the argmax token of the logits.
    pub fn step(&mut self, token: usize) -> Result<usize> {
        let h = self.serving.encode_session(&mut self.session, token, false)?;
        self.serving.finish_session(&mut self.session, h)
    }

    /// Full generation: prefill the prompt token-by-token (seq=1 steps, the
    /// paper's 5-token prompt contributes <5% of time), then decode.
    pub fn generate(&mut self, prompt: &[usize], n_new: usize) -> Result<GenResult> {
        if prompt.is_empty() || n_new == 0 {
            return Err(Error::Graph("prompt and n_new must be non-empty".into()));
        }
        let wall0 = Instant::now();
        // Release the previous session's device cache set before replacing
        // it, so back-to-back generates recycle the same pooled buffers
        // instead of leaking a cache set per run.
        let mut old = std::mem::replace(
            &mut self.session,
            self.serving.create_session(prompt.to_vec(), n_new, 0),
        );
        self.serving.release_session_cache(&mut old)?;
        while !self.session.finished() {
            let (token, was_prompt) = self
                .session
                .take_input()
                .ok_or_else(|| Error::Graph("session has no input token".into()))?;
            let h = self
                .serving
                .encode_session(&mut self.session, token, was_prompt)?;
            self.serving.finish_session(&mut self.session, h)?;
        }
        let m = &self.session.metrics;
        let ttft_ns = m.first_token_ns.saturating_sub(m.admitted_ns);
        let total_ns = m.finished_ns.saturating_sub(m.admitted_ns);
        Ok(GenResult {
            tokens: self.session.tokens.clone(),
            ttft_ns,
            total_ns,
            per_token_ns: m.per_token_ns.clone(),
            dispatches_per_step: m.prefill_dispatches / m.prefill_steps.max(1),
            real_wall_ns: wall0.elapsed().as_nanos() as u64,
            tok_per_s: n_new as f64 / (total_ns as f64 / 1e9),
        })
    }
}

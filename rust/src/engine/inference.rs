//! Autoregressive inference engine.
//!
//! Per token: host embedding gather -> full decode-step graph (one WebGPU
//! dispatch per kernel node) -> logits readback via `map_read` (the paper's
//! per-token GPU->CPU sync, ~11 ms) -> host argmax -> next token. The
//! device-side-argmax variant (Appendix H) replaces the full-logits
//! readback with an extra dispatch plus a 4-byte readback.

use std::collections::HashMap;
use std::time::Instant;

use crate::fx::builder::{build_decode_graph, FusionConfig, GraphDims};
use crate::fx::graph::FxGraph;
use crate::model::weights::ModelWeights;
use crate::runtime::hostops;
use crate::runtime::registry::Registry;
use crate::tensor::Tensor;
use crate::webgpu::queue::{bind_buffers, kernel_layout};
use crate::webgpu::{Device, ImplementationProfile, ShaderModuleDesc};
use crate::{Error, Result};

use super::executor::GraphExecutor;

/// Default torch-webgpu framework overhead: per-operation overhead (~95 us)
/// minus Dawn's per-dispatch cost (~24 us) -> ~71 us of Python/framework
/// cost per op (paper §4.4).
pub const TORCH_WEBGPU_FRAMEWORK_NS: u64 = 71_000;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub fusion: FusionConfig,
    pub profile: ImplementationProfile,
    /// Per-op framework overhead (virtual ns).
    pub framework_ns_per_op: u64,
    /// Appendix H: argmax on-device (readback 4 bytes) instead of the full
    /// logits row.
    pub device_argmax: bool,
    pub weight_seed: u64,
    /// How kernel time advances the virtual GPU frontier. `Calibrated`
    /// (default) keeps benchmark CV at the profile's jitter; `Measured`
    /// feeds real PJRT wall time into the clock (the real-system mode).
    pub kernel_time_policy: crate::webgpu::device::KernelTimePolicy,
}

impl EngineConfig {
    pub fn tiny_fused() -> Self {
        EngineConfig {
            model: "qwen-tiny".into(),
            fusion: FusionConfig::fused(),
            profile: ImplementationProfile::dawn_vulkan_rtx5090(),
            framework_ns_per_op: TORCH_WEBGPU_FRAMEWORK_NS,
            device_argmax: false,
            weight_seed: 0xC0FFEE,
            kernel_time_policy: crate::webgpu::device::KernelTimePolicy::Calibrated,
        }
    }

    pub fn tiny_unfused() -> Self {
        EngineConfig { fusion: FusionConfig::unfused(), ..Self::tiny_fused() }
    }
}

/// One generation run's measurements.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<usize>,
    /// Virtual ns from start to the first generated token (prefill + first
    /// decode step + sync) — the paper's TTFT.
    pub ttft_ns: u64,
    /// Virtual ns for the whole generation.
    pub total_ns: u64,
    /// Virtual ns per generated token (decode steps only).
    pub per_token_ns: Vec<u64>,
    /// Dispatches per decode step.
    pub dispatches_per_step: u64,
    /// Real wall time of the whole run on this host.
    pub real_wall_ns: u64,
    /// tok/s in virtual time (the paper's headline metric).
    pub tok_per_s: f64,
}

/// Pre-created device-argmax pipeline (Appendix H variant).
struct ArgmaxPrepared {
    #[allow(dead_code)] // kept for diagnostics/logging
    kernel: String,
    pipeline: crate::webgpu::ComputePipelineId,
    layout: crate::webgpu::BindGroupLayoutId,
}

pub struct Engine<'r> {
    pub config: EngineConfig,
    pub dims: GraphDims,
    pub graph: FxGraph,
    pub executor: GraphExecutor<'r>,
    pub weights: ModelWeights,
    caches: Vec<(Tensor, Tensor)>,
    pos: usize,
    argmax: Option<ArgmaxPrepared>,
}

impl<'r> Engine<'r> {
    pub fn new(registry: &'r Registry, config: EngineConfig) -> Result<Self> {
        let mc = registry.config(&config.model)?;
        let dims = GraphDims::from_manifest(mc);
        let graph = build_decode_graph(&dims, config.fusion);
        graph.validate()?;
        let mut device = Device::new(config.profile.clone());
        device.kernel_time_policy = config.kernel_time_policy;
        let mut executor = GraphExecutor::new(device, registry, config.framework_ns_per_op);
        executor.prepare(&graph)?;

        let argmax = if config.device_argmax {
            let name = format!("argmax_{}", dims.vocab);
            registry.ensure_loaded(&name)?;
            let spec = registry.spec(&name)?;
            let layout = kernel_layout(&mut executor.device, &name, 1, 1)?;
            let module = executor.device.create_shader_module(ShaderModuleDesc {
                label: name.clone(),
                kernel: name.clone(),
                inputs: spec.inputs.clone(),
                outputs: spec.outputs.clone(),
            })?;
            let pipeline = executor.device.create_compute_pipeline(&name, module, layout)?;
            Some(ArgmaxPrepared { kernel: name, pipeline, layout })
        } else {
            None
        };

        let weights = ModelWeights::synthesize(&dims, config.weight_seed);
        // PERF (§Perf L3): weights live in persistent device buffers —
        // uploaded once here, bound directly on every dispatch.
        executor.pin_inputs(&graph, &weights.by_name)?;
        let caches = (0..dims.layers)
            .map(|_| {
                let shape = vec![dims.max_seq, dims.kv_heads, dims.head_dim];
                (Tensor::zeros_f32(shape.clone()), Tensor::zeros_f32(shape))
            })
            .collect();

        Ok(Engine {
            config,
            dims,
            graph,
            executor,
            weights,
            caches,
            pos: 0,
            argmax,
        })
    }

    pub fn reset(&mut self) {
        let shape = vec![self.dims.max_seq, self.dims.kv_heads, self.dims.head_dim];
        for c in &mut self.caches {
            *c = (Tensor::zeros_f32(shape.clone()), Tensor::zeros_f32(shape.clone()));
        }
        self.pos = 0;
    }

    /// Reseed the virtual-cost jitter (independent benchmark runs).
    pub fn reseed(&mut self, seed: u64) {
        self.executor.device.reseed_jitter(seed);
    }

    /// One decode step: returns the argmax token of the logits.
    pub fn step(&mut self, token: usize) -> Result<usize> {
        if self.pos >= self.dims.max_seq {
            return Err(Error::Graph(format!(
                "KV cache capacity {} exhausted",
                self.dims.max_seq
            )));
        }
        // Host embedding gather (Table 10 "Other": embedding).
        let x = hostops::embed(&self.weights.embedding, token)?;

        let mut inputs: HashMap<String, Tensor> = HashMap::new();
        inputs.insert("x".into(), x);
        inputs.insert("pos_i".into(), Tensor::scalar_i32(self.pos as i32));
        inputs.insert("pos_ip1".into(), Tensor::scalar_i32(self.pos as i32 + 1));
        inputs.insert("pos_f".into(), Tensor::scalar_f32(self.pos as f32));
        inputs.insert("inv_freq".into(), self.weights.inv_freq.clone());
        for (l, (k, v)) in self.caches.iter().enumerate() {
            inputs.insert(format!("l{l}.k_cache"), k.clone());
            inputs.insert(format!("l{l}.v_cache"), v.clone());
        }
        // Weights are NOT passed per step: they were pinned into persistent
        // device buffers at engine construction (executor.pin_inputs).

        let (mut outs, logits_buf) = self.executor.run(&self.graph, &inputs)?;

        // Update caches for the next step.
        for l in 0..self.dims.layers {
            let k = outs
                .remove(&format!("l{l}.k_cache"))
                .ok_or_else(|| Error::Graph(format!("missing l{l}.k_cache output")))?;
            let v = outs
                .remove(&format!("l{l}.v_cache"))
                .ok_or_else(|| Error::Graph(format!("missing l{l}.v_cache output")))?;
            self.caches[l] = (k, v);
        }
        self.pos += 1;

        // Token selection: the per-token sync point.
        let logits = outs
            .remove("logits")
            .ok_or_else(|| Error::Graph("missing logits output".into()))?;
        let next = if self.argmax.is_some() {
            // Device-side argmax: one more dispatch, then a 4-byte readback.
            let idx = self.device_argmax(&logits)?;
            if let Some(buf) = logits_buf {
                self.executor.release_logits(buf)?;
            }
            idx
        } else {
            // Full-logits readback (map_read pays sync + per-byte transfer),
            // then host argmax — the production path.
            if let Some(buf) = logits_buf {
                let bytes = self.executor.device.map_read(buf)?;
                self.executor.release_logits(buf)?;
                let mut best = 0usize;
                let mut bestv = f32::NEG_INFINITY;
                for (i, c) in bytes.chunks_exact(4).enumerate() {
                    let x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    if x > bestv {
                        bestv = x;
                        best = i;
                    }
                }
                best
            } else {
                logits.argmax_row()?
            }
        };
        Ok(next)
    }

    fn device_argmax(&mut self, logits: &Tensor) -> Result<usize> {
        use crate::webgpu::{BufferDesc, BufferUsage};
        let prep = self.argmax.as_ref().expect("device_argmax without pipeline");
        let (pipeline, layout) = (prep.pipeline, prep.layout);
        let dev = &mut self.executor.device;
        let in_buf = dev.create_buffer(BufferDesc {
            label: "argmax-in".into(),
            size: logits.size_bytes(),
            usage: BufferUsage::STORAGE | BufferUsage::COPY_DST,
        })?;
        dev.write_buffer(in_buf, 0, logits.data.as_bytes())?;
        let out_buf = dev.create_buffer(BufferDesc {
            label: "argmax-out".into(),
            size: 4,
            usage: BufferUsage::STORAGE | BufferUsage::MAP_READ,
        })?;
        let group = bind_buffers(dev, "argmax", layout, &[in_buf], &[out_buf])?;
        let enc = dev.create_command_encoder("argmax");
        dev.begin_compute_pass(enc)?;
        dev.set_pipeline(enc, pipeline)?;
        dev.set_bind_group(enc, group)?;
        dev.dispatch_workgroups(enc, 1, 1, 1)?;
        dev.end_compute_pass(enc)?;
        let cb = dev.finish(enc)?;
        let registry = self.executor.registry();
        self.executor.device.submit(&[cb], registry)?;
        // Only 4 bytes cross the bus — the Appendix H point.
        let bytes = self.executor.device.map_read(out_buf)?;
        let idx = i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        self.executor.device.destroy_buffer(in_buf)?;
        self.executor.device.destroy_buffer(out_buf)?;
        Ok(idx)
    }

    /// Full generation: prefill the prompt token-by-token (seq=1 steps, the
    /// paper's 5-token prompt contributes <5% of time), then decode.
    pub fn generate(&mut self, prompt: &[usize], n_new: usize) -> Result<GenResult> {
        if prompt.is_empty() || n_new == 0 {
            return Err(Error::Graph("prompt and n_new must be non-empty".into()));
        }
        self.reset();
        let wall0 = Instant::now();
        let t0 = self.executor.device.clock.now_ns();
        let d0 = self.executor.dispatch_count;

        // Prefill: feed prompt tokens; logits of intermediate tokens unused.
        let mut next = 0usize;
        for &tok in prompt {
            next = self.step(tok)?;
        }
        let ttft = self.executor.device.clock.now_ns() - t0;
        let steps_so_far = prompt.len() as u64;
        let dispatches_per_step =
            (self.executor.dispatch_count - d0) / steps_so_far.max(1);

        let mut tokens = Vec::with_capacity(n_new);
        tokens.push(next);
        let mut per_token_ns = vec![ttft];
        for _ in 1..n_new {
            let t_tok = self.executor.device.clock.now_ns();
            next = self.step(next)?;
            tokens.push(next);
            per_token_ns.push(self.executor.device.clock.now_ns() - t_tok);
        }
        let total_ns = self.executor.device.clock.now_ns() - t0;
        Ok(GenResult {
            tokens,
            ttft_ns: ttft,
            total_ns,
            per_token_ns,
            dispatches_per_step,
            real_wall_ns: wall0.elapsed().as_nanos() as u64,
            tok_per_s: n_new as f64 / (total_ns as f64 / 1e9),
        })
    }
}

//! The inference engine: executes FX decode graphs through the WebGPU
//! substrate + PJRT runtime, autoregressively, with the paper's benchmark
//! protocol (warmup -> timed runs -> mean/CI/CV) and overhead accounting.

pub mod executor;
pub mod inference;
pub mod overhead;
pub mod protocol;

pub use executor::GraphExecutor;
pub use inference::{Engine, EngineConfig, GenResult};
pub use protocol::{run_protocol, ProtocolResult};

//! The inference engine: executes FX decode graphs through the WebGPU
//! substrate + kernel runtime, autoregressively, with the paper's
//! benchmark protocol (warmup -> timed runs -> mean/CI/CV) and overhead
//! accounting.
//!
//! Per-session decode state lives in [`crate::serve::SessionState`]; the
//! [`Engine`] here is the single-request wrapper over the multi-session
//! [`crate::serve::ServingEngine`].

pub mod executor;
pub mod inference;
pub mod overhead;
pub mod protocol;

pub use executor::{GraphExecutor, PagedPool};
pub use inference::{
    Engine, EngineConfig, ExecMode, GenResult, DEFAULT_BATCH_WIDTH, DEFAULT_KV_BLOCK,
    DEFAULT_PREFILL_CHUNK,
};
pub use protocol::{run_protocol, ProtocolResult};

//! Overhead accounting (paper §3.5 / §4.4, Table 4).
//!
//! Two derived quantities anchor the paper:
//!
//! ```text
//! per-operation overhead = (TTFT_unfused - TTFT_fused) / dispatches saved
//! sync overhead          = T_token - T_forward
//! ```
//!
//! plus the three-factor decomposition of fused TTFT: WebGPU dispatch
//! component (ops x per-dispatch cost), framework component
//! (ops x (per-op - per-dispatch)), and the GPU/CPU overlap residual.

#[derive(Debug, Clone, Copy)]
pub struct OverheadAccounting {
    pub ttft_fused_ms: f64,
    pub ttft_unfused_ms: f64,
    pub dispatches_fused: usize,
    pub dispatches_unfused: usize,
    /// (TTFT_u - TTFT_f) / saved — the well-constrained ~95 us.
    pub per_op_overhead_us: f64,
    /// Directly-measured per-dispatch cost (profile sequential value).
    pub per_dispatch_us: f64,
    /// per_op - per_dispatch — the Python/framework residual (~59-71 us).
    pub framework_us: f64,
    /// ops x per-dispatch (ms).
    pub dispatch_component_ms: f64,
    /// ops x framework (ms).
    pub framework_component_ms: f64,
    /// components - measured TTFT (attributed to GPU/CPU pipelining).
    pub overlap_residual_ms: f64,
}

impl OverheadAccounting {
    pub fn derive(
        ttft_fused_ms: f64,
        ttft_unfused_ms: f64,
        dispatches_fused: usize,
        dispatches_unfused: usize,
        per_dispatch_us: f64,
    ) -> Self {
        let saved = (dispatches_unfused - dispatches_fused).max(1);
        let per_op_overhead_us =
            (ttft_unfused_ms - ttft_fused_ms) * 1e3 / saved as f64;
        let framework_us = (per_op_overhead_us - per_dispatch_us).max(0.0);
        let dispatch_component_ms = dispatches_fused as f64 * per_dispatch_us / 1e3;
        let framework_component_ms = dispatches_fused as f64 * framework_us / 1e3;
        let overlap_residual_ms =
            (dispatch_component_ms + framework_component_ms - ttft_fused_ms).max(0.0);
        OverheadAccounting {
            ttft_fused_ms,
            ttft_unfused_ms,
            dispatches_fused,
            dispatches_unfused,
            per_op_overhead_us,
            per_dispatch_us,
            framework_us,
            dispatch_component_ms,
            framework_component_ms,
            overlap_residual_ms,
        }
    }

    /// Sensitivity analysis (Appendix G): vary per-op overhead by +/- pct,
    /// return the framework-component range (ms).
    pub fn sensitivity(&self, pct: f64) -> (f64, f64) {
        let lo = self.per_op_overhead_us * (1.0 - pct);
        let hi = self.per_op_overhead_us * (1.0 + pct);
        let f = |per_op: f64| {
            self.dispatches_fused as f64 * (per_op - self.per_dispatch_us).max(0.0) / 1e3
        };
        (f(lo), f(hi))
    }
}

/// Eager-vs-planned framework overhead — the measurable delta the
/// Planner/PlanRunner split exists to expose (table P1). Derived from the
/// accumulated framework virtual ns and dispatch counts of two runs of
/// the same workload.
#[derive(Debug, Clone, Copy)]
pub struct PlannedOverheadDelta {
    pub eager_fw_us_per_op: f64,
    pub planned_fw_us_per_op: f64,
}

impl PlannedOverheadDelta {
    pub fn derive(
        eager_fw_ns: u64,
        eager_ops: u64,
        planned_fw_ns: u64,
        planned_ops: u64,
    ) -> Self {
        PlannedOverheadDelta {
            eager_fw_us_per_op: eager_fw_ns as f64 / 1e3 / eager_ops.max(1) as f64,
            planned_fw_us_per_op: planned_fw_ns as f64 / 1e3 / planned_ops.max(1) as f64,
        }
    }

    /// How many times cheaper the planned replay's per-op framework cost
    /// is (the acceptance bar is >= 2x).
    pub fn ratio(&self) -> f64 {
        if self.planned_fw_us_per_op <= 0.0 {
            return f64::INFINITY;
        }
        self.eager_fw_us_per_op / self.planned_fw_us_per_op
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planned_delta_ratio() {
        let d = PlannedOverheadDelta::derive(71_000 * 59, 59, 2_000 * 59, 59);
        assert!((d.eager_fw_us_per_op - 71.0).abs() < 1e-9);
        assert!((d.planned_fw_us_per_op - 2.0).abs() < 1e-9);
        assert!((d.ratio() - 35.5).abs() < 1e-9);
        assert!(PlannedOverheadDelta::derive(1, 1, 0, 1).ratio().is_infinite());
    }

    #[test]
    fn paper_numbers_reproduce_table4() {
        // Paper: 71.4 ms unfused / 41.6 ms fused, 876 -> 564, Dawn 23.8 us.
        let a = OverheadAccounting::derive(41.6, 71.4, 564, 876, 23.8);
        assert!((a.per_op_overhead_us - 95.5).abs() < 0.2, "{}", a.per_op_overhead_us);
        assert!((a.framework_us - 71.7).abs() < 0.3);
        assert!((a.dispatch_component_ms - 13.4).abs() < 0.2);
        assert!((a.framework_component_ms - 40.4).abs() < 0.5);
        // residual ~12 ms (the paper's GPU/CPU overlap attribution)
        assert!((a.overlap_residual_ms - 12.2).abs() < 1.0, "{}", a.overlap_residual_ms);
    }

    #[test]
    fn sensitivity_brackets_framework_estimate() {
        let a = OverheadAccounting::derive(41.6, 71.4, 564, 876, 23.8);
        let (lo, hi) = a.sensitivity(0.20);
        // Paper Appendix G: ~22-45 ms range at +/-20%
        assert!(lo > 20.0 && lo < 35.0, "lo {lo}");
        assert!(hi > 40.0 && hi < 55.0, "hi {hi}");
        assert!(lo < a.framework_component_ms && a.framework_component_ms < hi);
    }

    #[test]
    fn degenerate_no_savings_is_safe() {
        let a = OverheadAccounting::derive(40.0, 40.0, 500, 500, 24.0);
        assert_eq!(a.per_op_overhead_us, 0.0);
        assert_eq!(a.framework_us, 0.0);
    }
}

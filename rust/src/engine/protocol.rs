//! The paper's benchmark protocol (§3.3): warmup runs (JIT/caches settle,
//! CV < 5% post-warmup), then 10-30 timed runs; report mean ± std, 95% CI
//! (t-distribution) and CV.

use crate::stats::{summarize, Summary};
use crate::Result;

use super::inference::{Engine, GenResult};

#[derive(Debug, Clone)]
pub struct ProtocolResult {
    pub tok_per_s: Summary,
    pub ttft_ms: Summary,
    pub runs: usize,
    pub warmup: usize,
    pub dispatches_per_step: u64,
    pub all_tps: Vec<f64>,
    pub all_ttft_ms: Vec<f64>,
    pub real_wall_ns_total: u64,
}

/// Run `warmup` untimed + `runs` timed generations of `n_new` tokens.
pub fn run_protocol(
    engine: &mut Engine,
    prompt: &[usize],
    n_new: usize,
    warmup: usize,
    runs: usize,
) -> Result<ProtocolResult> {
    for i in 0..warmup {
        engine.reseed(0xAAAA + i as u64);
        let _ = engine.generate(prompt, n_new)?;
    }
    let mut tps = Vec::with_capacity(runs);
    let mut ttfts = Vec::with_capacity(runs);
    let mut dispatches = 0;
    let mut wall = 0u64;
    for i in 0..runs {
        engine.reseed(0xBEEF + 7 * i as u64);
        let r: GenResult = engine.generate(prompt, n_new)?;
        tps.push(r.tok_per_s);
        ttfts.push(r.ttft_ns as f64 / 1e6);
        dispatches = r.dispatches_per_step;
        wall += r.real_wall_ns;
    }
    Ok(ProtocolResult {
        tok_per_s: summarize(&tps),
        ttft_ms: summarize(&ttfts),
        runs,
        warmup,
        dispatches_per_step: dispatches,
        all_tps: tps,
        all_ttft_ms: ttfts,
        real_wall_ns_total: wall,
    })
}

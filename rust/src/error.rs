//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — no `thiserror` in the offline
//! build (the crate is dependency-free by default; see Cargo.toml).

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// WebGPU-substrate validation failure (the paper's per-operation
    /// validation cost exists because these checks run on every call).
    Validation(String),

    /// A resource id that does not exist (destroyed or never created).
    InvalidResource(String),

    /// Device limit exceeded (bind group count, buffer size, dispatch dims).
    LimitExceeded(String),

    /// Kernel runtime failure (reference interpreter or PJRT compile/execute).
    Runtime(String),

    /// Artifact loading / manifest problems.
    Artifact(String),

    /// FX graph construction or execution problems.
    Graph(String),

    Shape(String),

    Io(std::io::Error),

    /// JSON parse/serialize failure (in-tree parser, `report::json`).
    Json(String),

    Xla(String),

    /// A transient, retryable fault (injected or environmental): the
    /// operation failed but the device is still usable and an identical
    /// retry is expected to succeed. Session-scoped recovery (rollback +
    /// replay) applies; the fault never needs to abort healthy sessions.
    Transient(String),

    /// The device itself is gone (WebGPU device loss). Fatal and
    /// device-scoped: no retry on this device can succeed, every
    /// session's device state is invalid.
    DeviceLost(String),

    /// An internal invariant was violated — the typed replacement for
    /// `unwrap()`/`expect()` in the serving and plan layers. Always a
    /// bug, never retryable.
    Internal(String),
}

impl Error {
    /// Session-scoped, retryable classification: rollback-and-replay
    /// recovery applies. `LimitExceeded` counts as transient because
    /// allocation pressure is relieved by eviction/retirement — the
    /// serving layer defers or evicts instead of failing (ROADMAP item
    /// 1's "admission defers, never fails").
    pub fn is_transient(&self) -> bool {
        matches!(self, Error::Transient(_) | Error::LimitExceeded(_))
    }

    /// Device-scoped, fatal classification: the whole engine must stop.
    pub fn is_device_lost(&self) -> bool {
        matches!(self, Error::DeviceLost(_))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Validation(m) => write!(f, "validation error: {m}"),
            Error::InvalidResource(m) => write!(f, "invalid resource: {m}"),
            Error::LimitExceeded(m) => write!(f, "limit exceeded: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Transient(m) => write!(f, "transient fault: {m}"),
            Error::DeviceLost(m) => write!(f, "device lost: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    /// WebGPU-substrate validation failure (the paper's per-operation
    /// validation cost exists because these checks run on every call).
    #[error("validation error: {0}")]
    Validation(String),

    /// A resource id that does not exist (destroyed or never created).
    #[error("invalid resource: {0}")]
    InvalidResource(String),

    /// Device limit exceeded (bind group count, buffer size, dispatch dims).
    #[error("limit exceeded: {0}")]
    LimitExceeded(String),

    /// PJRT runtime failure (compile or execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact loading / manifest problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// FX graph construction or execution problems.
    #[error("graph error: {0}")]
    Graph(String),

    #[error("shape error: {0}")]
    Shape(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// JSON parse/serialize failure (in-tree parser, `report::json`).
    #[error("json error: {0}")]
    Json(String),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

//! Executable decode-step graph builder.
//!
//! Builds the per-token op stream for a Qwen2.5-architecture config in the
//! unfused or (partially) fused flow, naming the AOT kernels exported by
//! `python/compile/aot.py`. One kernel node = one WebGPU dispatch; host
//! nodes (reshape/slice/embed) dispatch nothing — the same classification
//! torch-webgpu applies to FX shape ops.

use super::graph::FxGraph;
use super::node::{Category, HostOp, ValueId};
use crate::runtime::registry::ManifestConfig;

/// The dims a graph needs (mirrors `ModelConfig` on the python side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphDims {
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub vocab: usize,
    pub max_seq: usize,
    /// True when the per-config kernels carry the "tiny" suffix.
    pub tiny_names: bool,
}

impl GraphDims {
    pub fn qwen_tiny() -> Self {
        GraphDims {
            hidden: 64,
            layers: 4,
            heads: 4,
            kv_heads: 2,
            head_dim: 16,
            intermediate: 176,
            vocab: 512,
            // 160 rows so the prompt-heavy serving benches (prompt 128 +
            // 16 generated tokens) fit the tiny KV capacity.
            max_seq: 160,
            tiny_names: true,
        }
    }

    pub fn qwen25_05b() -> Self {
        GraphDims {
            hidden: 896,
            layers: 24,
            heads: 14,
            kv_heads: 2,
            head_dim: 64,
            intermediate: 4864,
            vocab: 151_936,
            max_seq: 32_768,
            tiny_names: false,
        }
    }

    pub fn qwen25_15b() -> Self {
        GraphDims {
            hidden: 1536,
            layers: 28,
            heads: 12,
            kv_heads: 2,
            head_dim: 128,
            intermediate: 8960,
            vocab: 151_936,
            max_seq: 32_768,
            tiny_names: false,
        }
    }

    pub fn from_manifest(c: &ManifestConfig) -> Self {
        GraphDims {
            hidden: c.hidden,
            layers: c.layers,
            heads: c.heads,
            kv_heads: c.kv_heads,
            head_dim: c.head_dim,
            intermediate: c.intermediate,
            vocab: c.vocab,
            max_seq: c.max_seq,
            tiny_names: c.name == "qwen-tiny",
        }
    }

    pub fn q_dim(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    fn suffix(&self) -> &'static str {
        if self.tiny_names {
            "tiny"
        } else {
            "full"
        }
    }
}

/// Which of the paper's fusions are applied (Table 5's progressive ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionConfig {
    /// RMSNorm 6 -> 1 (the +44% fusion).
    pub rmsnorm: bool,
    /// MLP gate+up+silu (+mul) -> 1 (+6%).
    pub mlp: bool,
    /// K+V projection 2 -> 1 (+0.5%, n.s.).
    pub kv: bool,
    /// Rotary neg/concat/mul/mul/add -> 1 per application.
    pub rotary: bool,
}

impl FusionConfig {
    pub fn unfused() -> Self {
        FusionConfig { rmsnorm: false, mlp: false, kv: false, rotary: false }
    }

    pub fn fused() -> Self {
        FusionConfig { rmsnorm: true, mlp: true, kv: true, rotary: true }
    }

    /// Table 5 progression rows.
    pub fn rmsnorm_only() -> Self {
        FusionConfig { rmsnorm: true, mlp: false, kv: false, rotary: false }
    }

    pub fn rmsnorm_mlp() -> Self {
        FusionConfig { rmsnorm: true, mlp: true, kv: false, rotary: false }
    }

    /// The paper's fully-fused Table 5 endpoint (no rotary fusion — rotary
    /// fusion is our extension beyond the paper's three).
    pub fn rmsnorm_mlp_kv() -> Self {
        FusionConfig { rmsnorm: true, mlp: true, kv: true, rotary: false }
    }
}

/// Single-row RMSNorm emitter (fused `rmsnorm_{H}` or the paper's
/// 6-dispatch decomposition, §6.1) — ONE source for the single-row
/// kernel-name contract, shared by the decode builder's norms and the
/// prefill builder's final norm over the selected last row.
fn emit_rmsnorm_row(
    g: &mut FxGraph,
    hidden: usize,
    tag: &str,
    x: ValueId,
    w: ValueId,
    fused: bool,
) -> ValueId {
    let h = hidden;
    if fused {
        return g.kernel(
            &format!("{tag}.rmsnorm"),
            &format!("rmsnorm_{h}"),
            Category::Other,
            vec![x, w],
        );
    }
    let x2 = g.kernel(
        &format!("{tag}.pow"),
        &format!("rms_pow_{h}"),
        Category::RmsComponent,
        vec![x],
    );
    let m = g.kernel(
        &format!("{tag}.mean"),
        &format!("rms_mean_{h}"),
        Category::RmsComponent,
        vec![x2],
    );
    let me = g.kernel(
        &format!("{tag}.add_eps"),
        "rms_add_eps_1",
        Category::Add,
        vec![m],
    );
    let r = g.kernel(
        &format!("{tag}.rsqrt"),
        "rms_rsqrt_1",
        Category::RmsComponent,
        vec![me],
    );
    let xn = g.kernel(
        &format!("{tag}.mul_x"),
        &format!("rms_mul_x_{h}"),
        Category::Multiply,
        vec![x, r],
    );
    g.kernel(
        &format!("{tag}.mul_w"),
        &format!("rms_mul_w_{h}"),
        Category::Multiply,
        vec![xn, w],
    )
}

struct B<'a> {
    g: FxGraph,
    d: &'a GraphDims,
}

impl<'a> B<'a> {
    fn rmsnorm(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        emit_rmsnorm_row(&mut self.g, self.d.hidden, tag, x, w, fused)
    }

    fn rotary(
        &mut self,
        tag: &str,
        xh: ValueId,
        cos: ValueId,
        sin: ValueId,
        heads: usize,
        fused: bool,
    ) -> ValueId {
        let dim = self.d.head_dim;
        if fused {
            return self.g.kernel(
                &format!("{tag}.rotary"),
                &format!("rotary_{heads}_{dim}"),
                Category::Other,
                vec![xh, cos, sin],
            );
        }
        let half = dim / 2;
        let parts = self.g.host(
            &format!("{tag}.halves"),
            HostOp::Halves,
            Category::Shape,
            vec![xh],
            2,
        );
        let (x1, x2) = (parts[0], parts[1]);
        let x2n = self.g.kernel(
            &format!("{tag}.neg"),
            &format!("neg_{heads}_{half}"),
            Category::Other,
            vec![x2],
        );
        let rot = self.g.kernel(
            &format!("{tag}.rot_concat"),
            &format!("concat_{heads}_{half}"),
            Category::Concat,
            vec![x2n, x1],
        );
        let a = self.g.kernel(
            &format!("{tag}.mul_cos"),
            &format!("mul_vec_{heads}_{dim}"),
            Category::Multiply,
            vec![xh, cos],
        );
        let b = self.g.kernel(
            &format!("{tag}.mul_sin"),
            &format!("mul_vec_{heads}_{dim}"),
            Category::Multiply,
            vec![rot, sin],
        );
        self.g.kernel(
            &format!("{tag}.add"),
            &format!("add_{heads}_{dim}"),
            Category::Add,
            vec![a, b],
        )
    }
}

/// Build the one-token decode-step graph.
///
/// Inputs: `x` ([1,H] embedded token), `pos_i`/`pos_ip1` ([1] i32),
/// `pos_f` ([1] f32), `inv_freq` ([D/2]), per-layer weights
/// (`l{i}.{norm1,wq,wk,wv,wkv,wo,norm2,wg,wu,wd}`), per-layer caches
/// (`l{i}.k_cache`, `l{i}.v_cache`), `norm_f`, `w_lm`.
/// Outputs: `logits`, updated `l{i}.k_cache` / `l{i}.v_cache`.
pub fn build_decode_graph(dims: &GraphDims, fusion: FusionConfig) -> FxGraph {
    build_decode_graph_impl(dims, fusion, false)
}

/// Paged-KV variant of [`build_decode_graph`]: the per-layer contiguous
/// caches become shared pool planes (`pool.l{l}.{k,v}_cache`,
/// `[POOL_ROWS, kvh, d]`), and the step inputs gain the session's
/// `block_table` plus the `kv_block` scalar; `cache_update_paged` /
/// `sdpa_paged` resolve logical rows through the two-level lookup. Same
/// node count as the contiguous graph (1-for-1 kernel swap).
pub fn build_decode_graph_paged(dims: &GraphDims, fusion: FusionConfig) -> FxGraph {
    build_decode_graph_impl(dims, fusion, true)
}

fn build_decode_graph_impl(dims: &GraphDims, fusion: FusionConfig, paged: bool) -> FxGraph {
    let mut b = B { g: FxGraph::new(), d: dims };
    let (h, qd, kv, inter) = (dims.hidden, dims.q_dim(), dims.kv_dim(), dims.intermediate);
    let suffix = dims.suffix();
    b.g.kv_paged = paged;

    let x0 = b.g.input("x");
    let pos_i = b.g.input("pos_i");
    let pos_ip1 = b.g.input("pos_ip1");
    let pos_f = b.g.input("pos_f");
    let inv_freq = b.g.input("inv_freq");
    let paged_uniforms = paged.then(|| {
        (b.g.input("block_table"), b.g.input("kv_block"))
    });

    // Rope table, once per forward (cos/sin shared by all layers).
    let cs = b.g.kernel_multi(
        "rope_table",
        &format!("rope_cos_sin_{}", dims.head_dim),
        Category::Other,
        vec![pos_f, inv_freq],
        2,
    );
    let (cos, sin) = (cs[0], cs[1]);

    let mut x = x0;
    for l in 0..dims.layers {
        let p = format!("l{l}");
        let norm1_w = b.g.input(&format!("{p}.norm1"));
        let wo = b.g.input(&format!("{p}.wo"));
        let norm2_w = b.g.input(&format!("{p}.norm2"));
        let wd = b.g.input(&format!("{p}.wd"));
        // KV caches are persistent state, not per-step I/O: planners bind
        // them to device buffers and append in place. Contiguous graphs own
        // a per-session [max_seq, kvh, d] pair per layer; paged graphs
        // share ONE [POOL_ROWS, kvh, d] pool plane pair per layer across
        // every session, addressed through the block table.
        let (k_name, v_name) = if paged {
            (format!("pool.{p}.k_cache"), format!("pool.{p}.v_cache"))
        } else {
            (format!("{p}.k_cache"), format!("{p}.v_cache"))
        };
        let k_cache_in = b.g.input(&k_name);
        let v_cache_in = b.g.input(&v_name);
        b.g.mark_persistent(&k_name);
        b.g.mark_persistent(&v_name);

        // ---- attention ----
        let hn = b.rmsnorm(&format!("{p}.norm1"), x, norm1_w, fusion.rmsnorm);

        let wq = b.g.input(&format!("{p}.wq"));
        let q = b.g.kernel(
            &format!("{p}.q_proj"),
            &format!("matmul_{h}_{qd}"),
            Category::Linear,
            vec![hn, wq],
        );
        let (k, v) = if fusion.kv {
            let wkv = b.g.input(&format!("{p}.wkv"));
            let kvv = b.g.kernel(
                &format!("{p}.kv_proj"),
                &format!("kv_fused_{h}_{}", 2 * kv),
                Category::Linear,
                vec![hn, wkv],
            );
            let parts = b.g.host(
                &format!("{p}.kv_split"),
                HostOp::SplitKv,
                Category::Shape,
                vec![kvv],
                2,
            );
            (parts[0], parts[1])
        } else {
            let wk = b.g.input(&format!("{p}.wk"));
            let wv = b.g.input(&format!("{p}.wv"));
            let k = b.g.kernel(
                &format!("{p}.k_proj"),
                &format!("matmul_{h}_{kv}"),
                Category::Linear,
                vec![hn, wk],
            );
            let v = b.g.kernel(
                &format!("{p}.v_proj"),
                &format!("matmul_{h}_{kv}"),
                Category::Linear,
                vec![hn, wv],
            );
            (k, v)
        };

        let qh = b.g.host(
            &format!("{p}.q_heads"),
            HostOp::ToHeads { heads: dims.heads, head_dim: dims.head_dim },
            Category::Shape,
            vec![q],
            1,
        )[0];
        let kh = b.g.host(
            &format!("{p}.k_heads"),
            HostOp::ToHeads { heads: dims.kv_heads, head_dim: dims.head_dim },
            Category::Shape,
            vec![k],
            1,
        )[0];
        let vh = b.g.host(
            &format!("{p}.v_heads"),
            HostOp::ToHeads { heads: dims.kv_heads, head_dim: dims.head_dim },
            Category::Shape,
            vec![v],
            1,
        )[0];

        let q_rot = b.rotary(&format!("{p}.rope_q"), qh, cos, sin, dims.heads, fusion.rotary);
        let k_rot = b.rotary(&format!("{p}.rope_k"), kh, cos, sin, dims.kv_heads, fusion.rotary);

        let (cu_kernel, sd_kernel) = if paged {
            (format!("cache_update_paged_{suffix}"), format!("sdpa_paged_{suffix}"))
        } else {
            (format!("cache_update_{suffix}"), format!("sdpa_{suffix}"))
        };
        let mut k_ins = vec![k_cache_in, k_rot, pos_i];
        let mut v_ins = vec![v_cache_in, vh, pos_i];
        if let Some((table, kvb)) = paged_uniforms {
            k_ins.extend([table, kvb]);
            v_ins.extend([table, kvb]);
        }
        let k_cache = b.g.in_place_kernel(
            &format!("{p}.k_cache_update"),
            &cu_kernel,
            Category::Concat,
            k_ins,
        );
        let v_cache = b.g.in_place_kernel(
            &format!("{p}.v_cache_update"),
            &cu_kernel,
            Category::Concat,
            v_ins,
        );
        b.g.mark_output(&k_name, k_cache);
        b.g.mark_output(&v_name, v_cache);

        let mut sd_ins = vec![q_rot, k_cache, v_cache, pos_ip1];
        if let Some((table, kvb)) = paged_uniforms {
            sd_ins.extend([table, kvb]);
        }
        let attn = b.g.kernel(
            &format!("{p}.sdpa"),
            &sd_kernel,
            Category::Sdpa,
            sd_ins,
        );
        let attn_flat = b.g.host(
            &format!("{p}.attn_flat"),
            HostOp::FromHeads,
            Category::Shape,
            vec![attn],
            1,
        )[0];
        let attn_out = b.g.kernel(
            &format!("{p}.o_proj"),
            &format!("matmul_{qd}_{h}"),
            Category::Linear,
            vec![attn_flat, wo],
        );
        x = b.g.kernel(
            &format!("{p}.resid1"),
            &format!("add_{h}"),
            Category::Add,
            vec![x, attn_out],
        );

        // ---- MLP ----
        let h2 = b.rmsnorm(&format!("{p}.norm2"), x, norm2_w, fusion.rmsnorm);
        let act = if fusion.mlp {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            b.g.kernel(
                &format!("{p}.gate_up_silu"),
                &format!("gate_up_silu_{suffix}"),
                Category::Silu,
                vec![h2, wg, wu],
            )
        } else {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            let g_ = b.g.kernel(
                &format!("{p}.gate_proj"),
                &format!("matmul_{h}_{inter}"),
                Category::Linear,
                vec![h2, wg],
            );
            let u = b.g.kernel(
                &format!("{p}.up_proj"),
                &format!("matmul_{h}_{inter}"),
                Category::Linear,
                vec![h2, wu],
            );
            let s = b.g.kernel(
                &format!("{p}.silu"),
                &format!("silu_{inter}"),
                Category::Silu,
                vec![g_],
            );
            b.g.kernel(
                &format!("{p}.gate_mul"),
                &format!("mul_{inter}"),
                Category::Multiply,
                vec![s, u],
            )
        };
        let down = b.g.kernel(
            &format!("{p}.down_proj"),
            &format!("matmul_{inter}_{h}"),
            Category::Linear,
            vec![act, wd],
        );
        x = b.g.kernel(
            &format!("{p}.resid2"),
            &format!("add_{h}"),
            Category::Add,
            vec![x, down],
        );
    }

    // ---- final norm + lm head ----
    let norm_f = b.g.input("norm_f");
    // The paper's fused configuration leaves the final norm unfused only in
    // the dispatch arithmetic (240 = 24 layers x 2 norms); the executable
    // graph fuses it whenever rmsnorm fusion is on.
    let hf = b.rmsnorm("final_norm", x, norm_f, fusion.rmsnorm);
    let w_lm = b.g.input("w_lm");
    let logits = b.g.kernel(
        "lm_head",
        &format!("matmul_{h}_{}", dims.vocab),
        Category::Linear,
        vec![hf, w_lm],
    );
    b.g.mark_output("logits", logits);

    debug_assert!(b.g.validate().is_ok());
    b.g
}

/// Widest batched decode graph the built-in kernel manifest can execute
/// (`runtime::builtin` registers batched kernel specs for widths
/// `2..=MAX_BATCH_WIDTH`).
pub const MAX_BATCH_WIDTH: usize = 8;

/// Smallest supported paged-KV block size (tokens per block). The per-slot
/// block table is sized for this worst case — `max_seq / KV_BLOCK_MIN`
/// entries — so every block size `b` with `KV_BLOCK_MIN <= b`, `b` dividing
/// `max_seq`, replays the SAME static kernel specs: the table stride is a
/// compile-time constant and `kv_block` arrives as a scalar uniform.
pub const KV_BLOCK_MIN: usize = 4;

/// Paged-KV block sizes the engine accepts for `--kv-block`: multiples of
/// [`KV_BLOCK_MIN`] that divide qwen-tiny's `max_seq` (160) and keep the
/// fixed table stride exact. All replay the same static kernel specs.
pub const KV_BLOCKS: [usize; 4] = [4, 8, 16, 32];

/// Rows in each shared paged pool plane (`pool.l{l}.{k,v}_cache`,
/// `[POOL_ROWS, kv_heads, head_dim]`): one full cache set per batch slot,
/// so the worst-case working set of one encode round — `MAX_BATCH_WIDTH`
/// sessions at `max_seq` tokens — always fits physically, whatever the
/// logical pool budget. The plane byte size equals `MAX_BATCH_WIDTH`
/// contiguous per-session planes; density comes from blocks being granted
/// by ACTUAL tokens, not capacity.
pub fn paged_pool_rows(dims: &GraphDims) -> usize {
    MAX_BATCH_WIDTH * dims.max_seq
}

/// Fixed per-slot block-table stride (entries). Entries are physical block
/// ids into the pool planes (`-1` = unallocated); logical row `p` of a slot
/// resolves to pool row `table[p / kv_block] * kv_block + p % kv_block`.
pub fn paged_table_len(dims: &GraphDims) -> usize {
    dims.max_seq / KV_BLOCK_MIN
}

struct BB<'a> {
    g: FxGraph,
    d: &'a GraphDims,
    w: usize,
}

impl<'a> BB<'a> {
    /// Batched RMSNorm over `[W, H]`: row-wise identical to the
    /// single-session kernels (fused or the 6-dispatch decomposition).
    fn rmsnorm(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        let (h, bw) = (self.d.hidden, self.w);
        if fused {
            return self.g.kernel(
                &format!("{tag}.rmsnorm"),
                &format!("rmsnorm_b{bw}_{h}"),
                Category::Other,
                vec![x, w],
            );
        }
        let x2 = self.g.kernel(
            &format!("{tag}.pow"),
            &format!("rms_pow_b{bw}_{h}"),
            Category::RmsComponent,
            vec![x],
        );
        let m = self.g.kernel(
            &format!("{tag}.mean"),
            &format!("rms_mean_b{bw}_{h}"),
            Category::RmsComponent,
            vec![x2],
        );
        let me = self.g.kernel(
            &format!("{tag}.add_eps"),
            &format!("rms_add_eps_b{bw}"),
            Category::Add,
            vec![m],
        );
        let r = self.g.kernel(
            &format!("{tag}.rsqrt"),
            &format!("rms_rsqrt_b{bw}"),
            Category::RmsComponent,
            vec![me],
        );
        let xn = self.g.kernel(
            &format!("{tag}.mul_x"),
            &format!("rms_mul_x_b{bw}_{h}"),
            Category::Multiply,
            vec![x, r],
        );
        self.g.kernel(
            &format!("{tag}.mul_w"),
            &format!("rms_mul_w_b{bw}_{h}"),
            Category::Multiply,
            vec![xn, w],
        )
    }
}

/// Build the batched decode-step graph at slot width `width`.
///
/// One serving round with up to `width` active sessions replays this graph
/// ONCE: every layer op is a single dispatch over `[W, ...]`-shaped values
/// instead of `W` per-session dispatches — the Appendix F amortization.
///
/// Step inputs carry a leading batch dimension: `x` (`[W, H]` packed token
/// embeddings), `pos_i`/`pos_ip1` (`[W]` i32 per-slot positions), `pos_f`
/// (`[W]` f32), `slot_mask` (`[W]` i32; 0 = inactive slot, masked out of
/// cache writes and attention), `slot_idx` (`[W]` i32; the per-slot
/// cache-set index uniform — batch row `b` gathers/scatters cache set
/// `slot_idx[b]`; the serving engine passes the identity mapping), and the
/// width-independent `inv_freq`.
///
/// Per-slot KV cache sets stay isolated: slot `j`'s caches are the
/// persistent inputs `s{j}.l{l}.k_cache` / `s{j}.l{l}.v_cache`, declared
/// slot-major so each slot's slice of the plan's persistent list is
/// exactly one session's layer-major cache set. The batched `cache_update`
/// is one in-place dispatch per layer whose output `j` updates slot `j`'s
/// state in place; the batched `sdpa` gathers per-slot K/V through the
/// same cache-set bindings.
///
/// `fusion.rmsnorm` / `fusion.mlp` / `fusion.kv` select batched fused or
/// decomposed kernels exactly like the single-session builder. Rotary is
/// always the fused batched kernel: the unfused rotate-half chain needs a
/// per-slot cos/sin broadcast that has no decomposed batched kernel (the
/// fused reference kernel is the exact float32 composition of the unfused
/// chain, so token streams are unaffected).
pub fn build_batched_decode_graph(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
) -> FxGraph {
    build_batched_decode_graph_impl(dims, fusion, width, false)
}

/// Paged-KV variant of [`build_batched_decode_graph`]: the W slot-major
/// cache sets and the `slot_idx` cache-set-index uniform collapse into ONE
/// shared pool plane pair per layer (`pool.l{l}.{k,v}_cache`, layer-major —
/// the SAME persistent layout as [`build_decode_graph_paged`], so all paged
/// plans share one pool) plus per-slot `block_table` rows (`[W * stride]`
/// i32) and the `kv_block` scalar. The `slot_idx` gather generalizes to the
/// two-level `(table[p / b], p % b)` lookup. Same node count (1-for-1
/// kernel swap), so the dispatch census is unchanged.
pub fn build_batched_decode_graph_paged(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
) -> FxGraph {
    build_batched_decode_graph_impl(dims, fusion, width, true)
}

fn build_batched_decode_graph_impl(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    paged: bool,
) -> FxGraph {
    assert!(width >= 2, "batched decode graphs need width >= 2 (got {width})");
    let mut b = BB { g: FxGraph::new(), d: dims, w: width };
    b.g.batch_width = width;
    b.g.kv_paged = paged;
    let (h, qd, kv, inter) = (dims.hidden, dims.q_dim(), dims.kv_dim(), dims.intermediate);
    let (nh, kvh, d) = (dims.heads, dims.kv_heads, dims.head_dim);
    let suffix = dims.suffix();
    let bw = width;

    let x0 = b.g.input("x");
    let pos_i = b.g.input("pos_i");
    let pos_ip1 = b.g.input("pos_ip1");
    let pos_f = b.g.input("pos_f");
    let slot_mask = b.g.input("slot_mask");
    let slot_idx = if paged { None } else { Some(b.g.input("slot_idx")) };
    let inv_freq = b.g.input("inv_freq");
    let paged_uniforms = paged.then(|| {
        (b.g.input("block_table"), b.g.input("kv_block"))
    });

    if paged {
        // ONE shared pool plane pair per layer, layer-major — identical to
        // the paged decode builder's persistent list, so every paged plan
        // binds the same pool buffers.
        for l in 0..dims.layers {
            for kind in ["k", "v"] {
                let name = format!("pool.l{l}.{kind}_cache");
                b.g.input(&name);
                b.g.mark_persistent(&name);
            }
        }
    } else {
        // Per-slot cache sets, declared SLOT-major so the plan's persistent
        // list is a cache-set table: entries [j*2L .. (j+1)*2L) are slot j's
        // layer-major set — the same layout a single session's DeviceKvCache
        // uses, so sessions plug straight into slots.
        for j in 0..width {
            for l in 0..dims.layers {
                for kind in ["k", "v"] {
                    let name = format!("s{j}.l{l}.{kind}_cache");
                    b.g.input(&name);
                    b.g.mark_persistent(&name);
                }
            }
        }
    }

    // Per-slot rope table: each slot decodes at its own position.
    let cs = b.g.kernel_multi(
        "rope_table",
        &format!("rope_cos_sin_b{bw}_{d}"),
        Category::Other,
        vec![pos_f, inv_freq],
        2,
    );
    let (cos, sin) = (cs[0], cs[1]);

    let mut x = x0;
    for l in 0..dims.layers {
        let p = format!("l{l}");
        let norm1_w = b.g.input(&format!("{p}.norm1"));
        let wo = b.g.input(&format!("{p}.wo"));
        let norm2_w = b.g.input(&format!("{p}.norm2"));
        let wd = b.g.input(&format!("{p}.wd"));

        // ---- attention ----
        let hn = b.rmsnorm(&format!("{p}.norm1"), x, norm1_w, fusion.rmsnorm);

        let wq = b.g.input(&format!("{p}.wq"));
        let q = b.g.kernel(
            &format!("{p}.q_proj"),
            &format!("matmul_b{bw}_{h}_{qd}"),
            Category::Linear,
            vec![hn, wq],
        );
        let (k, v) = if fusion.kv {
            let wkv = b.g.input(&format!("{p}.wkv"));
            // Two outputs (K rows, V rows): the [W, 2KV] row split is
            // strided, so no host byte-window alias can represent it.
            let parts = b.g.kernel_multi(
                &format!("{p}.kv_proj"),
                &format!("kv_fused_b{bw}_{h}_{}", 2 * kv),
                Category::Linear,
                vec![hn, wkv],
                2,
            );
            (parts[0], parts[1])
        } else {
            let wk = b.g.input(&format!("{p}.wk"));
            let wv = b.g.input(&format!("{p}.wv"));
            let k = b.g.kernel(
                &format!("{p}.k_proj"),
                &format!("matmul_b{bw}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wk],
            );
            let v = b.g.kernel(
                &format!("{p}.v_proj"),
                &format!("matmul_b{bw}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wv],
            );
            (k, v)
        };

        // Rotary stays [W, heads*dim]-shaped: the batched kernels index
        // heads internally, so no host reshape nodes are needed.
        let q_rot = b.g.kernel(
            &format!("{p}.rope_q.rotary"),
            &format!("rotary_b{bw}_{nh}_{d}"),
            Category::Other,
            vec![q, cos, sin],
        );
        let k_rot = b.g.kernel(
            &format!("{p}.rope_k.rotary"),
            &format!("rotary_b{bw}_{kvh}_{d}"),
            Category::Other,
            vec![k, cos, sin],
        );

        // One gather/scatter cache append per layer per K/V. Unpaged:
        // inputs are the W per-slot states, then rows + per-slot uniforms;
        // output j updates state j in place. Paged: ONE shared pool plane
        // updated in place, with rows scattered through each slot's block
        // table row.
        let attn = if let Some((table, kvb)) = paged_uniforms {
            let k_plane = b.g.inputs[&format!("pool.{p}.k_cache")];
            let k_cache = b.g.in_place_kernel(
                &format!("{p}.k_cache_update"),
                &format!("cache_update_paged_b{bw}_{suffix}"),
                Category::Concat,
                vec![k_plane, k_rot, pos_i, slot_mask, table, kvb],
            );
            b.g.mark_output(&format!("pool.{p}.k_cache"), k_cache);
            let v_plane = b.g.inputs[&format!("pool.{p}.v_cache")];
            let v_cache = b.g.in_place_kernel(
                &format!("{p}.v_cache_update"),
                &format!("cache_update_paged_b{bw}_{suffix}"),
                Category::Concat,
                vec![v_plane, v, pos_i, slot_mask, table, kvb],
            );
            b.g.mark_output(&format!("pool.{p}.v_cache"), v_cache);
            // One attention dispatch per layer, gathering every slot's
            // prefix rows through its block-table row.
            b.g.kernel(
                &format!("{p}.sdpa"),
                &format!("sdpa_paged_b{bw}_{suffix}"),
                Category::Sdpa,
                vec![q_rot, k_cache, v_cache, pos_ip1, slot_mask, table, kvb],
            )
        } else {
            let slot_idx = slot_idx.expect("unpaged batched graph has slot_idx");
            let k_states: Vec<ValueId> = (0..width)
                .map(|j| b.g.inputs[&format!("s{j}.{p}.k_cache")])
                .collect();
            let mut k_ins = k_states;
            k_ins.extend([k_rot, pos_i, slot_mask, slot_idx]);
            let k_caches = b.g.in_place_kernel_multi(
                &format!("{p}.k_cache_update"),
                &format!("cache_update_b{bw}_{suffix}"),
                Category::Concat,
                k_ins,
                width,
            );
            let v_states: Vec<ValueId> = (0..width)
                .map(|j| b.g.inputs[&format!("s{j}.{p}.v_cache")])
                .collect();
            let mut v_ins = v_states;
            v_ins.extend([v, pos_i, slot_mask, slot_idx]);
            let v_caches = b.g.in_place_kernel_multi(
                &format!("{p}.v_cache_update"),
                &format!("cache_update_b{bw}_{suffix}"),
                Category::Concat,
                v_ins,
                width,
            );
            for j in 0..width {
                b.g.mark_output(&format!("s{j}.{p}.k_cache"), k_caches[j]);
                b.g.mark_output(&format!("s{j}.{p}.v_cache"), v_caches[j]);
            }

            // One attention dispatch per layer, gathering every slot's K/V.
            let mut sdpa_ins = vec![q_rot];
            sdpa_ins.extend(k_caches.iter().copied());
            sdpa_ins.extend(v_caches.iter().copied());
            sdpa_ins.extend([pos_ip1, slot_mask, slot_idx]);
            b.g.kernel(
                &format!("{p}.sdpa"),
                &format!("sdpa_b{bw}_{suffix}"),
                Category::Sdpa,
                sdpa_ins,
            )
        };
        let attn_out = b.g.kernel(
            &format!("{p}.o_proj"),
            &format!("matmul_b{bw}_{qd}_{h}"),
            Category::Linear,
            vec![attn, wo],
        );
        x = b.g.kernel(
            &format!("{p}.resid1"),
            &format!("add_b{bw}_{h}"),
            Category::Add,
            vec![x, attn_out],
        );

        // ---- MLP ----
        let h2 = b.rmsnorm(&format!("{p}.norm2"), x, norm2_w, fusion.rmsnorm);
        let act = if fusion.mlp {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            b.g.kernel(
                &format!("{p}.gate_up_silu"),
                &format!("gate_up_silu_b{bw}_{suffix}"),
                Category::Silu,
                vec![h2, wg, wu],
            )
        } else {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            let g_ = b.g.kernel(
                &format!("{p}.gate_proj"),
                &format!("matmul_b{bw}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wg],
            );
            let u = b.g.kernel(
                &format!("{p}.up_proj"),
                &format!("matmul_b{bw}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wu],
            );
            let s = b.g.kernel(
                &format!("{p}.silu"),
                &format!("silu_b{bw}_{inter}"),
                Category::Silu,
                vec![g_],
            );
            b.g.kernel(
                &format!("{p}.gate_mul"),
                &format!("mul_b{bw}_{inter}"),
                Category::Multiply,
                vec![s, u],
            )
        };
        let down = b.g.kernel(
            &format!("{p}.down_proj"),
            &format!("matmul_b{bw}_{inter}_{h}"),
            Category::Linear,
            vec![act, wd],
        );
        x = b.g.kernel(
            &format!("{p}.resid2"),
            &format!("add_b{bw}_{h}"),
            Category::Add,
            vec![x, down],
        );
    }

    // ---- final norm + lm head ----
    let norm_f = b.g.input("norm_f");
    let hf = b.rmsnorm("final_norm", x, norm_f, fusion.rmsnorm);
    let w_lm = b.g.input("w_lm");
    let logits = b.g.kernel(
        "lm_head",
        &format!("matmul_b{bw}_{h}_{}", dims.vocab),
        Category::Linear,
        vec![hf, w_lm],
    );
    b.g.mark_output("logits", logits);

    debug_assert!(b.g.validate().is_ok());
    b.g
}

/// Prefill chunk sizes the built-in kernel manifest can execute
/// (`runtime::builtin` registers seq-dim `*_c{C}_*` kernel specs for each).
pub const PREFILL_CHUNKS: [usize; 3] = [8, 16, 32];

struct CB<'a> {
    g: FxGraph,
    d: &'a GraphDims,
    c: usize,
}

impl<'a> CB<'a> {
    /// Chunked RMSNorm over `[C, H]`: row-wise identical to the
    /// single-token kernels (fused or the 6-dispatch decomposition).
    fn rmsnorm_chunk(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        let (h, c) = (self.d.hidden, self.c);
        if fused {
            return self.g.kernel(
                &format!("{tag}.rmsnorm"),
                &format!("rmsnorm_c{c}_{h}"),
                Category::Other,
                vec![x, w],
            );
        }
        let x2 = self.g.kernel(
            &format!("{tag}.pow"),
            &format!("rms_pow_c{c}_{h}"),
            Category::RmsComponent,
            vec![x],
        );
        let m = self.g.kernel(
            &format!("{tag}.mean"),
            &format!("rms_mean_c{c}_{h}"),
            Category::RmsComponent,
            vec![x2],
        );
        let me = self.g.kernel(
            &format!("{tag}.add_eps"),
            &format!("rms_add_eps_c{c}"),
            Category::Add,
            vec![m],
        );
        let r = self.g.kernel(
            &format!("{tag}.rsqrt"),
            &format!("rms_rsqrt_c{c}"),
            Category::RmsComponent,
            vec![me],
        );
        let xn = self.g.kernel(
            &format!("{tag}.mul_x"),
            &format!("rms_mul_x_c{c}_{h}"),
            Category::Multiply,
            vec![x, r],
        );
        self.g.kernel(
            &format!("{tag}.mul_w"),
            &format!("rms_mul_w_c{c}_{h}"),
            Category::Multiply,
            vec![xn, w],
        )
    }

    /// Single-row RMSNorm (the selected last prompt row): exactly the
    /// decode builder's kernels via the shared emitter, so the final
    /// norm + lm head are shared with the single-token plan.
    fn rmsnorm_row(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        emit_rmsnorm_row(&mut self.g, self.d.hidden, tag, x, w, fused)
    }
}

/// Build the chunked PREFILL graph at sequence chunk `chunk`.
///
/// One replay ingests up to `chunk` consecutive prompt tokens of ONE
/// session: every layer op is a single dispatch over `[C, ...]`-shaped
/// values instead of `C` per-token decode steps — the prompt-phase twin of
/// the batched decode amortization, and the reason chunked prefill
/// collapses TTFT's dispatch bill by ~C×.
///
/// Step inputs carry a leading *sequence* dimension: `x` (`[C, H]` packed
/// token embeddings for positions `pos_base..pos_base+C`), `pos_f` (`[C]`
/// f32 per-position rotary angles), `pos_base` (`[1]` i32, the cache row
/// of chunk row 0), `valid_len` (`[1]` i32; rows `>= valid_len` are a
/// ragged tail — masked out of cache scatters and attention, so short
/// final chunks replay the SAME plan with no recompile), and `inv_freq`.
///
/// The per-layer caches are the same layer-major persistent inputs as
/// [`build_decode_graph`] (`l{l}.{k,v}_cache`), so a session's
/// [`DeviceKvCache`](crate::plan::DeviceKvCache) plugs into both plans:
/// `cache_update_c{C}` is ONE in-place dispatch scattering C rows at
/// `pos_base..`, and `sdpa_prefill_c{C}` is the causal multi-token
/// attention — chunk row `i` attends cache positions `0..pos_base+i+1`
/// (cache history plus the preceding in-chunk rows, which the scatter has
/// already written).
///
/// Only the LAST valid row's logits matter (intermediate prompt logits are
/// discarded): `chunk_last_row` selects row `valid_len-1`, and the final
/// norm + lm head run at single-row shapes — the logits output is the same
/// `[1, vocab]` contract as the decode plan, so one coalesced readback
/// serves mixed prefill/decode rounds.
///
/// Rotary is always the fused chunk kernel, exactly like the batched
/// builder (the fused reference kernel is the exact float32 composition of
/// the unfused chain, so token streams are unaffected); `fusion.rmsnorm` /
/// `fusion.mlp` / `fusion.kv` select chunked fused or decomposed kernels
/// like the other builders.
pub fn build_prefill_graph(dims: &GraphDims, fusion: FusionConfig, chunk: usize) -> FxGraph {
    build_prefill_graph_impl(dims, fusion, chunk, false, false)
}

/// Paged-KV variant of [`build_prefill_graph`]: the session cache set
/// (`l{l}.{k,v}_cache`) becomes the shared pool plane pair
/// (`pool.l{l}.{k,v}_cache`, the SAME persistent layout as
/// [`build_decode_graph_paged`]) plus a `block_table` (`[stride]` i32) and
/// `kv_block` (`[1]` i32) uniform pair: the chunk scatter and the causal
/// attention both route cache rows through `(table[p / b], p % b)`. Same
/// node count (1-for-1 kernel swap), so the dispatch census is unchanged.
pub fn build_prefill_graph_paged(
    dims: &GraphDims,
    fusion: FusionConfig,
    chunk: usize,
) -> FxGraph {
    build_prefill_graph_impl(dims, fusion, chunk, false, true)
}

/// Multi-row (speculative verify) variant of [`build_prefill_graph`]: the
/// tail keeps rows `0..valid_len` (`chunk_rows` instead of
/// `chunk_last_row`), runs the final norm at the chunked `[C, H]` shapes,
/// and scores EVERY row through a `[C, vocab]` lm head — so one chunk
/// replay verifies `valid_len` drafted tokens instead of emitting one.
/// Same dispatch count as the last-row tail (1-for-1 kernel swap); rows
/// `< valid_len` are bit-identical to what `chunk_last_row` would select
/// at each prefix length, because every tail op is row-wise.
pub fn build_prefill_graph_multi_row(
    dims: &GraphDims,
    fusion: FusionConfig,
    chunk: usize,
) -> FxGraph {
    build_prefill_graph_impl(dims, fusion, chunk, true, false)
}

/// Paged multi-row variant: [`build_prefill_graph_multi_row`]'s every-row
/// lm head on [`build_prefill_graph_paged`]'s pooled cache planes.
pub fn build_prefill_graph_multi_row_paged(
    dims: &GraphDims,
    fusion: FusionConfig,
    chunk: usize,
) -> FxGraph {
    build_prefill_graph_impl(dims, fusion, chunk, true, true)
}

fn build_prefill_graph_impl(
    dims: &GraphDims,
    fusion: FusionConfig,
    chunk: usize,
    multi_row: bool,
    paged: bool,
) -> FxGraph {
    assert!(chunk >= 2, "prefill graphs need chunk >= 2 (got {chunk})");
    let mut b = CB { g: FxGraph::new(), d: dims, c: chunk };
    b.g.seq_chunk = chunk;
    b.g.kv_paged = paged;
    let (h, qd, kv, inter) = (dims.hidden, dims.q_dim(), dims.kv_dim(), dims.intermediate);
    let (nh, kvh, d) = (dims.heads, dims.kv_heads, dims.head_dim);
    let suffix = dims.suffix();
    let c = chunk;

    let x0 = b.g.input("x");
    let pos_f = b.g.input("pos_f");
    let pos_base = b.g.input("pos_base");
    let valid_len = b.g.input("valid_len");
    let inv_freq = b.g.input("inv_freq");
    let paged_uniforms = paged.then(|| {
        (b.g.input("block_table"), b.g.input("kv_block"))
    });

    // Per-position rope table: one cos/sin row per chunk position.
    let cs = b.g.kernel_multi(
        "rope_table",
        &format!("rope_cos_sin_c{c}_{d}"),
        Category::Other,
        vec![pos_f, inv_freq],
        2,
    );
    let (cos, sin) = (cs[0], cs[1]);

    let mut x = x0;
    for l in 0..dims.layers {
        let p = format!("l{l}");
        let norm1_w = b.g.input(&format!("{p}.norm1"));
        let wo = b.g.input(&format!("{p}.wo"));
        let norm2_w = b.g.input(&format!("{p}.norm2"));
        let wd = b.g.input(&format!("{p}.wd"));
        // The SAME layer-major persistent layout as the matching decode
        // graph (session cache set unpaged, shared pool planes paged), so
        // one cache binding serves both plans.
        let (k_name, v_name) = if paged {
            (format!("pool.{p}.k_cache"), format!("pool.{p}.v_cache"))
        } else {
            (format!("{p}.k_cache"), format!("{p}.v_cache"))
        };
        let k_cache_in = b.g.input(&k_name);
        let v_cache_in = b.g.input(&v_name);
        b.g.mark_persistent(&k_name);
        b.g.mark_persistent(&v_name);

        // ---- attention ----
        let hn = b.rmsnorm_chunk(&format!("{p}.norm1"), x, norm1_w, fusion.rmsnorm);

        let wq = b.g.input(&format!("{p}.wq"));
        let q = b.g.kernel(
            &format!("{p}.q_proj"),
            &format!("matmul_c{c}_{h}_{qd}"),
            Category::Linear,
            vec![hn, wq],
        );
        let (k, v) = if fusion.kv {
            let wkv = b.g.input(&format!("{p}.wkv"));
            // Two outputs (K rows, V rows): the [C, 2KV] row split is
            // strided, so no host byte-window alias can represent it.
            let parts = b.g.kernel_multi(
                &format!("{p}.kv_proj"),
                &format!("kv_fused_c{c}_{h}_{}", 2 * kv),
                Category::Linear,
                vec![hn, wkv],
                2,
            );
            (parts[0], parts[1])
        } else {
            let wk = b.g.input(&format!("{p}.wk"));
            let wv = b.g.input(&format!("{p}.wv"));
            let k = b.g.kernel(
                &format!("{p}.k_proj"),
                &format!("matmul_c{c}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wk],
            );
            let v = b.g.kernel(
                &format!("{p}.v_proj"),
                &format!("matmul_c{c}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wv],
            );
            (k, v)
        };

        // Rotary stays [C, heads*dim]-shaped: the chunk kernels index
        // heads internally, so no host reshape nodes are needed.
        let q_rot = b.g.kernel(
            &format!("{p}.rope_q.rotary"),
            &format!("rotary_c{c}_{nh}_{d}"),
            Category::Other,
            vec![q, cos, sin],
        );
        let k_rot = b.g.kernel(
            &format!("{p}.rope_k.rotary"),
            &format!("rotary_c{c}_{kvh}_{d}"),
            Category::Other,
            vec![k, cos, sin],
        );

        // ONE multi-row in-place scatter per layer per K/V: rows
        // 0..valid_len land at cache positions pos_base.. in place —
        // routed through the block table when paged.
        let (cu_kernel, sd_kernel) = if paged {
            (
                format!("cache_update_paged_c{c}_{suffix}"),
                format!("sdpa_prefill_paged_c{c}_{suffix}"),
            )
        } else {
            (
                format!("cache_update_c{c}_{suffix}"),
                format!("sdpa_prefill_c{c}_{suffix}"),
            )
        };
        let mut k_ins = vec![k_cache_in, k_rot, pos_base, valid_len];
        let mut v_ins = vec![v_cache_in, v, pos_base, valid_len];
        if let Some((table, kvb)) = paged_uniforms {
            k_ins.extend([table, kvb]);
            v_ins.extend([table, kvb]);
        }
        let k_cache = b.g.in_place_kernel(
            &format!("{p}.k_cache_update"),
            &cu_kernel,
            Category::Concat,
            k_ins,
        );
        let v_cache = b.g.in_place_kernel(
            &format!("{p}.v_cache_update"),
            &cu_kernel,
            Category::Concat,
            v_ins,
        );
        b.g.mark_output(&k_name, k_cache);
        b.g.mark_output(&v_name, v_cache);

        // Causal multi-token attention: row i attends cache 0..base+i+1.
        let mut sd_ins = vec![q_rot, k_cache, v_cache, pos_base, valid_len];
        if let Some((table, kvb)) = paged_uniforms {
            sd_ins.extend([table, kvb]);
        }
        let attn = b.g.kernel(
            &format!("{p}.sdpa"),
            &sd_kernel,
            Category::Sdpa,
            sd_ins,
        );
        let attn_out = b.g.kernel(
            &format!("{p}.o_proj"),
            &format!("matmul_c{c}_{qd}_{h}"),
            Category::Linear,
            vec![attn, wo],
        );
        x = b.g.kernel(
            &format!("{p}.resid1"),
            &format!("add_c{c}_{h}"),
            Category::Add,
            vec![x, attn_out],
        );

        // ---- MLP ----
        let h2 = b.rmsnorm_chunk(&format!("{p}.norm2"), x, norm2_w, fusion.rmsnorm);
        let act = if fusion.mlp {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            b.g.kernel(
                &format!("{p}.gate_up_silu"),
                &format!("gate_up_silu_c{c}_{suffix}"),
                Category::Silu,
                vec![h2, wg, wu],
            )
        } else {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            let g_ = b.g.kernel(
                &format!("{p}.gate_proj"),
                &format!("matmul_c{c}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wg],
            );
            let u = b.g.kernel(
                &format!("{p}.up_proj"),
                &format!("matmul_c{c}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wu],
            );
            let s = b.g.kernel(
                &format!("{p}.silu"),
                &format!("silu_c{c}_{inter}"),
                Category::Silu,
                vec![g_],
            );
            b.g.kernel(
                &format!("{p}.gate_mul"),
                &format!("mul_c{c}_{inter}"),
                Category::Multiply,
                vec![s, u],
            )
        };
        let down = b.g.kernel(
            &format!("{p}.down_proj"),
            &format!("matmul_c{c}_{inter}_{h}"),
            Category::Linear,
            vec![act, wd],
        );
        x = b.g.kernel(
            &format!("{p}.resid2"),
            &format!("add_c{c}_{h}"),
            Category::Add,
            vec![x, down],
        );
    }

    // ---- tail: row selection -> final norm + lm head ----
    // Last-row tail: intermediate prompt positions' logits are never read,
    // so only the chunk's last valid row pays the final-norm/lm-head
    // compute, and the logits output keeps the decode plan's [1, vocab]
    // contract. Multi-row tail (speculative verify): rows 0..valid_len all
    // reach the lm head at the chunked [C, ...] shapes, logits [C, vocab],
    // so one replay scores every drafted position.
    let norm_f = b.g.input("norm_f");
    let w_lm = b.g.input("w_lm");
    let logits = if multi_row {
        let rows = b.g.kernel(
            "last_row",
            &format!("chunk_rows_c{c}_{h}"),
            Category::Other,
            vec![x, valid_len],
        );
        let hf = b.rmsnorm_chunk("final_norm", rows, norm_f, fusion.rmsnorm);
        b.g.kernel(
            "lm_head",
            &format!("matmul_c{c}_{h}_{}", dims.vocab),
            Category::Linear,
            vec![hf, w_lm],
        )
    } else {
        let last = b.g.kernel(
            "last_row",
            &format!("chunk_last_row_c{c}_{h}"),
            Category::Other,
            vec![x, valid_len],
        );
        let hf = b.rmsnorm_row("final_norm", last, norm_f, fusion.rmsnorm);
        b.g.kernel(
            "lm_head",
            &format!("matmul_{h}_{}", dims.vocab),
            Category::Linear,
            vec![hf, w_lm],
        )
    };
    b.g.mark_output("logits", logits);

    debug_assert!(b.g.validate().is_ok());
    b.g
}

struct UB<'a> {
    g: FxGraph,
    d: &'a GraphDims,
    w: usize,
    c: usize,
}

impl<'a> UB<'a> {
    /// Unified RMSNorm over `[W*C, H]`: row-wise identical to the
    /// single-token kernels (fused or the 6-dispatch decomposition).
    fn rmsnorm(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        let (h, bw, c) = (self.d.hidden, self.w, self.c);
        if fused {
            return self.g.kernel(
                &format!("{tag}.rmsnorm"),
                &format!("rmsnorm_b{bw}c{c}_{h}"),
                Category::Other,
                vec![x, w],
            );
        }
        let x2 = self.g.kernel(
            &format!("{tag}.pow"),
            &format!("rms_pow_b{bw}c{c}_{h}"),
            Category::RmsComponent,
            vec![x],
        );
        let m = self.g.kernel(
            &format!("{tag}.mean"),
            &format!("rms_mean_b{bw}c{c}_{h}"),
            Category::RmsComponent,
            vec![x2],
        );
        let me = self.g.kernel(
            &format!("{tag}.add_eps"),
            &format!("rms_add_eps_b{bw}c{c}"),
            Category::Add,
            vec![m],
        );
        let r = self.g.kernel(
            &format!("{tag}.rsqrt"),
            &format!("rms_rsqrt_b{bw}c{c}"),
            Category::RmsComponent,
            vec![me],
        );
        let xn = self.g.kernel(
            &format!("{tag}.mul_x"),
            &format!("rms_mul_x_b{bw}c{c}_{h}"),
            Category::Multiply,
            vec![x, r],
        );
        self.g.kernel(
            &format!("{tag}.mul_w"),
            &format!("rms_mul_w_b{bw}c{c}_{h}"),
            Category::Multiply,
            vec![xn, w],
        )
    }

    /// Batched RMSNorm over `[W, H]` (the per-slot last rows): exactly the
    /// batched decode builder's kernels, so the unified tail shares the
    /// batched plan's final-norm + lm-head contract.
    fn rmsnorm_slots(&mut self, tag: &str, x: ValueId, w: ValueId, fused: bool) -> ValueId {
        let (h, bw) = (self.d.hidden, self.w);
        if fused {
            return self.g.kernel(
                &format!("{tag}.rmsnorm"),
                &format!("rmsnorm_b{bw}_{h}"),
                Category::Other,
                vec![x, w],
            );
        }
        let x2 = self.g.kernel(
            &format!("{tag}.pow"),
            &format!("rms_pow_b{bw}_{h}"),
            Category::RmsComponent,
            vec![x],
        );
        let m = self.g.kernel(
            &format!("{tag}.mean"),
            &format!("rms_mean_b{bw}_{h}"),
            Category::RmsComponent,
            vec![x2],
        );
        let me = self.g.kernel(
            &format!("{tag}.add_eps"),
            &format!("rms_add_eps_b{bw}"),
            Category::Add,
            vec![m],
        );
        let r = self.g.kernel(
            &format!("{tag}.rsqrt"),
            &format!("rms_rsqrt_b{bw}"),
            Category::RmsComponent,
            vec![me],
        );
        let xn = self.g.kernel(
            &format!("{tag}.mul_x"),
            &format!("rms_mul_x_b{bw}_{h}"),
            Category::Multiply,
            vec![x, r],
        );
        self.g.kernel(
            &format!("{tag}.mul_w"),
            &format!("rms_mul_w_b{bw}_{h}"),
            Category::Multiply,
            vec![xn, w],
        )
    }
}

/// Build the UNIFIED round graph at slot width `width` and sequence chunk
/// `chunk`: the seq x batch merge of [`build_batched_decode_graph`] and
/// [`build_prefill_graph`].
///
/// One serving round with up to `width` active sessions — any mix of
/// prompt-ingesting (prefill) and generating (decode) sessions — replays
/// this graph ONCE: every layer op is a single dispatch over
/// `[W*C, ...]`-shaped values. Slot `j` owns rows `j*C .. (j+1)*C` and
/// carries `valid_len[j]` live tokens starting at cache row `pos_base[j]`;
/// a decode slot is simply a `valid_len = 1` prefill chunk, and a masked
/// padding slot is `valid_len = 0`. That is continuous batching in the
/// WebLLM sense: prefill chunks and decode steps share one dispatch
/// stream instead of one batched-decode replay per chunk PLUS one prefill
/// replay per prefill-phase session.
///
/// Step inputs: `x` (`[W*C, H]` packed token embeddings), `pos_f`
/// (`[W*C]` f32 per-row rotary angles), and the per-SLOT i32 uniforms
/// `pos_base` / `valid_len` / `slot_mask` / `slot_idx` (`[W]` each;
/// `slot_idx[j]` is the cache-set index slot `j` gathers/scatters —
/// the serving engine passes the identity mapping), plus the shared
/// `inv_freq`.
///
/// Per-slot KV cache sets are declared SLOT-major exactly like the
/// batched decode builder (`s{j}.l{l}.{k,v}_cache`), so the unified plan's
/// persistent layout is the SAME cache-set table and sessions plug into
/// slots unchanged. `cache_update_b{W}c{C}` is one in-place dispatch per
/// layer per K/V scattering each slot's `valid_len` rows at `pos_base..`
/// into that slot's cache; `sdpa_b{W}c{C}` is the causal per-slot
/// multi-token attention (slot `j` row `i` attends cache positions
/// `0..pos_base[j]+i+1`).
///
/// Only each slot's LAST valid row feeds the lm head:
/// `slot_last_row_b{W}c{C}` selects row `valid_len[j]-1` of every live
/// slot (zero rows for masked/empty slots), and the final norm + lm head
/// run at the batched `[W, ...]` shapes — the logits output keeps the
/// batched plan's `[W, vocab]` contract, so the round-level coalesced
/// readback and logits ring are unchanged.
///
/// Rotary is always the fused kernel, exactly like the batched and
/// prefill builders; `fusion.rmsnorm` / `fusion.mlp` / `fusion.kv` select
/// fused or decomposed kernels like the other builders.
pub fn build_unified_round_graph(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    chunk: usize,
) -> FxGraph {
    build_unified_round_graph_impl(dims, fusion, width, chunk, false, false)
}

/// Paged-KV variant of [`build_unified_round_graph`]: the W slot-major
/// cache sets and the `slot_idx` uniform collapse into the shared pool
/// plane pair per layer (`pool.l{l}.{k,v}_cache`, the SAME persistent
/// layout as [`build_decode_graph_paged`]) plus per-slot `block_table`
/// rows (`[W * stride]` i32) and the `kv_block` scalar. Same node count
/// (1-for-1 kernel swap), so the dispatch census is unchanged.
pub fn build_unified_round_graph_paged(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    chunk: usize,
) -> FxGraph {
    build_unified_round_graph_impl(dims, fusion, width, chunk, false, true)
}

/// Multi-row (speculative verify) variant of [`build_unified_round_graph`]:
/// the tail keeps each slot's rows `0..valid_len[j]` (`slot_rows` instead
/// of `slot_last_row`), runs the final norm at the unified `[W*C, H]`
/// shapes, and scores every row through a `[W*C, vocab]` lm head — slot
/// `j`'s verified positions are logits rows `j*C..j*C+valid_len[j]`. Same
/// dispatch count as the last-row tail (1-for-1 kernel swap); kept rows
/// are bit-identical to the last-row tail's selection at each prefix
/// length, because every tail op is row-wise.
pub fn build_unified_round_graph_multi_row(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    chunk: usize,
) -> FxGraph {
    build_unified_round_graph_impl(dims, fusion, width, chunk, true, false)
}

/// Paged multi-row variant: [`build_unified_round_graph_multi_row`]'s
/// every-row lm head on [`build_unified_round_graph_paged`]'s pooled
/// cache planes.
pub fn build_unified_round_graph_multi_row_paged(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    chunk: usize,
) -> FxGraph {
    build_unified_round_graph_impl(dims, fusion, width, chunk, true, true)
}

fn build_unified_round_graph_impl(
    dims: &GraphDims,
    fusion: FusionConfig,
    width: usize,
    chunk: usize,
    multi_row: bool,
    paged: bool,
) -> FxGraph {
    assert!(width >= 2, "unified round graphs need width >= 2 (got {width})");
    assert!(chunk >= 2, "unified round graphs need chunk >= 2 (got {chunk})");
    let mut b = UB { g: FxGraph::new(), d: dims, w: width, c: chunk };
    b.g.batch_width = width;
    b.g.seq_chunk = chunk;
    b.g.kv_paged = paged;
    let (h, qd, kv, inter) = (dims.hidden, dims.q_dim(), dims.kv_dim(), dims.intermediate);
    let (nh, kvh, d) = (dims.heads, dims.kv_heads, dims.head_dim);
    let suffix = dims.suffix();
    let (bw, c) = (width, chunk);

    let x0 = b.g.input("x");
    let pos_f = b.g.input("pos_f");
    let pos_base = b.g.input("pos_base");
    let valid_len = b.g.input("valid_len");
    let slot_mask = b.g.input("slot_mask");
    let slot_idx = if paged { None } else { Some(b.g.input("slot_idx")) };
    let inv_freq = b.g.input("inv_freq");
    let paged_uniforms = paged.then(|| {
        (b.g.input("block_table"), b.g.input("kv_block"))
    });

    if paged {
        // ONE shared pool plane pair per layer, layer-major — identical to
        // the paged decode builder's persistent list, so every paged plan
        // binds the same pool buffers.
        for l in 0..dims.layers {
            for kind in ["k", "v"] {
                let name = format!("pool.l{l}.{kind}_cache");
                b.g.input(&name);
                b.g.mark_persistent(&name);
            }
        }
    } else {
        // Per-slot cache sets, SLOT-major — identical to the batched decode
        // builder's persistent layout, so the two plans share one cache-set
        // table and sessions plug straight into slots.
        for j in 0..width {
            for l in 0..dims.layers {
                for kind in ["k", "v"] {
                    let name = format!("s{j}.l{l}.{kind}_cache");
                    b.g.input(&name);
                    b.g.mark_persistent(&name);
                }
            }
        }
    }

    // Per-row rope table: each of the W*C rows rotates at its own position.
    let cs = b.g.kernel_multi(
        "rope_table",
        &format!("rope_cos_sin_b{bw}c{c}_{d}"),
        Category::Other,
        vec![pos_f, inv_freq],
        2,
    );
    let (cos, sin) = (cs[0], cs[1]);

    let mut x = x0;
    for l in 0..dims.layers {
        let p = format!("l{l}");
        let norm1_w = b.g.input(&format!("{p}.norm1"));
        let wo = b.g.input(&format!("{p}.wo"));
        let norm2_w = b.g.input(&format!("{p}.norm2"));
        let wd = b.g.input(&format!("{p}.wd"));

        // ---- attention ----
        let hn = b.rmsnorm(&format!("{p}.norm1"), x, norm1_w, fusion.rmsnorm);

        let wq = b.g.input(&format!("{p}.wq"));
        let q = b.g.kernel(
            &format!("{p}.q_proj"),
            &format!("matmul_b{bw}c{c}_{h}_{qd}"),
            Category::Linear,
            vec![hn, wq],
        );
        let (k, v) = if fusion.kv {
            let wkv = b.g.input(&format!("{p}.wkv"));
            // Two outputs (K rows, V rows): the [W*C, 2KV] row split is
            // strided, so no host byte-window alias can represent it.
            let parts = b.g.kernel_multi(
                &format!("{p}.kv_proj"),
                &format!("kv_fused_b{bw}c{c}_{h}_{}", 2 * kv),
                Category::Linear,
                vec![hn, wkv],
                2,
            );
            (parts[0], parts[1])
        } else {
            let wk = b.g.input(&format!("{p}.wk"));
            let wv = b.g.input(&format!("{p}.wv"));
            let k = b.g.kernel(
                &format!("{p}.k_proj"),
                &format!("matmul_b{bw}c{c}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wk],
            );
            let v = b.g.kernel(
                &format!("{p}.v_proj"),
                &format!("matmul_b{bw}c{c}_{h}_{kv}"),
                Category::Linear,
                vec![hn, wv],
            );
            (k, v)
        };

        // Rotary stays [W*C, heads*dim]-shaped: the kernels index heads
        // internally, so no host reshape nodes are needed.
        let q_rot = b.g.kernel(
            &format!("{p}.rope_q.rotary"),
            &format!("rotary_b{bw}c{c}_{nh}_{d}"),
            Category::Other,
            vec![q, cos, sin],
        );
        let k_rot = b.g.kernel(
            &format!("{p}.rope_k.rotary"),
            &format!("rotary_b{bw}c{c}_{kvh}_{d}"),
            Category::Other,
            vec![k, cos, sin],
        );

        // One gather/scatter cache append per layer per K/V. Unpaged:
        // inputs are the W per-slot states, then rows + per-slot uniforms;
        // output j scatters slot j's valid_len rows at pos_base[j].. in
        // place. Paged: ONE shared pool plane updated in place, each
        // slot's rows routed through its block-table row.
        let attn = if let Some((table, kvb)) = paged_uniforms {
            let k_plane = b.g.inputs[&format!("pool.{p}.k_cache")];
            let k_cache = b.g.in_place_kernel(
                &format!("{p}.k_cache_update"),
                &format!("cache_update_paged_b{bw}c{c}_{suffix}"),
                Category::Concat,
                vec![k_plane, k_rot, pos_base, valid_len, slot_mask, table, kvb],
            );
            b.g.mark_output(&format!("pool.{p}.k_cache"), k_cache);
            let v_plane = b.g.inputs[&format!("pool.{p}.v_cache")];
            let v_cache = b.g.in_place_kernel(
                &format!("{p}.v_cache_update"),
                &format!("cache_update_paged_b{bw}c{c}_{suffix}"),
                Category::Concat,
                vec![v_plane, v, pos_base, valid_len, slot_mask, table, kvb],
            );
            b.g.mark_output(&format!("pool.{p}.v_cache"), v_cache);
            // One attention dispatch per layer: slot j's rows run the
            // causal prefill attention against its block-table prefix.
            b.g.kernel(
                &format!("{p}.sdpa"),
                &format!("sdpa_paged_b{bw}c{c}_{suffix}"),
                Category::Sdpa,
                vec![q_rot, k_cache, v_cache, pos_base, valid_len, slot_mask, table, kvb],
            )
        } else {
            let slot_idx = slot_idx.expect("unpaged unified graph has slot_idx");
            let k_states: Vec<ValueId> = (0..width)
                .map(|j| b.g.inputs[&format!("s{j}.{p}.k_cache")])
                .collect();
            let mut k_ins = k_states;
            k_ins.extend([k_rot, pos_base, valid_len, slot_mask, slot_idx]);
            let k_caches = b.g.in_place_kernel_multi(
                &format!("{p}.k_cache_update"),
                &format!("cache_update_b{bw}c{c}_{suffix}"),
                Category::Concat,
                k_ins,
                width,
            );
            let v_states: Vec<ValueId> = (0..width)
                .map(|j| b.g.inputs[&format!("s{j}.{p}.v_cache")])
                .collect();
            let mut v_ins = v_states;
            v_ins.extend([v, pos_base, valid_len, slot_mask, slot_idx]);
            let v_caches = b.g.in_place_kernel_multi(
                &format!("{p}.v_cache_update"),
                &format!("cache_update_b{bw}c{c}_{suffix}"),
                Category::Concat,
                v_ins,
                width,
            );
            for j in 0..width {
                b.g.mark_output(&format!("s{j}.{p}.k_cache"), k_caches[j]);
                b.g.mark_output(&format!("s{j}.{p}.v_cache"), v_caches[j]);
            }

            // One attention dispatch per layer: slot j's rows run the causal
            // prefill attention against cache set slot_idx[j].
            let mut sdpa_ins = vec![q_rot];
            sdpa_ins.extend(k_caches.iter().copied());
            sdpa_ins.extend(v_caches.iter().copied());
            sdpa_ins.extend([pos_base, valid_len, slot_mask, slot_idx]);
            b.g.kernel(
                &format!("{p}.sdpa"),
                &format!("sdpa_b{bw}c{c}_{suffix}"),
                Category::Sdpa,
                sdpa_ins,
            )
        };
        let attn_out = b.g.kernel(
            &format!("{p}.o_proj"),
            &format!("matmul_b{bw}c{c}_{qd}_{h}"),
            Category::Linear,
            vec![attn, wo],
        );
        x = b.g.kernel(
            &format!("{p}.resid1"),
            &format!("add_b{bw}c{c}_{h}"),
            Category::Add,
            vec![x, attn_out],
        );

        // ---- MLP ----
        let h2 = b.rmsnorm(&format!("{p}.norm2"), x, norm2_w, fusion.rmsnorm);
        let act = if fusion.mlp {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            b.g.kernel(
                &format!("{p}.gate_up_silu"),
                &format!("gate_up_silu_b{bw}c{c}_{suffix}"),
                Category::Silu,
                vec![h2, wg, wu],
            )
        } else {
            let wg = b.g.input(&format!("{p}.wg"));
            let wu = b.g.input(&format!("{p}.wu"));
            let g_ = b.g.kernel(
                &format!("{p}.gate_proj"),
                &format!("matmul_b{bw}c{c}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wg],
            );
            let u = b.g.kernel(
                &format!("{p}.up_proj"),
                &format!("matmul_b{bw}c{c}_{h}_{inter}"),
                Category::Linear,
                vec![h2, wu],
            );
            let s = b.g.kernel(
                &format!("{p}.silu"),
                &format!("silu_b{bw}c{c}_{inter}"),
                Category::Silu,
                vec![g_],
            );
            b.g.kernel(
                &format!("{p}.gate_mul"),
                &format!("mul_b{bw}c{c}_{inter}"),
                Category::Multiply,
                vec![s, u],
            )
        };
        let down = b.g.kernel(
            &format!("{p}.down_proj"),
            &format!("matmul_b{bw}c{c}_{inter}_{h}"),
            Category::Linear,
            vec![act, wd],
        );
        x = b.g.kernel(
            &format!("{p}.resid2"),
            &format!("add_b{bw}c{c}_{h}"),
            Category::Add,
            vec![x, down],
        );
    }

    // ---- tail: per-slot row selection -> final norm + lm head ----
    // Last-row tail: row j of the selection is slot j's row valid_len[j]-1
    // (zeros for masked/empty slots), and the tail runs at the batched
    // [W, ...] shapes so the logits output keeps the batched plan's
    // [W, vocab] contract. Multi-row tail (speculative verify): each
    // slot's rows 0..valid_len[j] all reach the lm head at the unified
    // [W*C, ...] shapes, logits [W*C, vocab] — slot j's drafted positions
    // are rows j*C..j*C+valid_len[j] of the logits block.
    let norm_f = b.g.input("norm_f");
    let w_lm = b.g.input("w_lm");
    let logits = if multi_row {
        let rows = b.g.kernel(
            "last_row",
            &format!("slot_rows_b{bw}c{c}_{h}"),
            Category::Other,
            vec![x, valid_len, slot_mask],
        );
        let hf = b.rmsnorm("final_norm", rows, norm_f, fusion.rmsnorm);
        b.g.kernel(
            "lm_head",
            &format!("matmul_b{bw}c{c}_{h}_{}", dims.vocab),
            Category::Linear,
            vec![hf, w_lm],
        )
    } else {
        let last = b.g.kernel(
            "last_row",
            &format!("slot_last_row_b{bw}c{c}_{h}"),
            Category::Other,
            vec![x, valid_len, slot_mask],
        );
        let hf = b.rmsnorm_slots("final_norm", last, norm_f, fusion.rmsnorm);
        b.g.kernel(
            "lm_head",
            &format!("matmul_b{bw}_{h}_{}", dims.vocab),
            Category::Linear,
            vec![hf, w_lm],
        )
    };
    b.g.mark_output("logits", logits);

    debug_assert!(b.g.validate().is_ok());
    b.g
}

/// Expected dispatch count per prefill chunk: the batched-decode
/// arithmetic (rotary always fused) plus the last-row selection dispatch.
/// Chunk-size-independent — the amortization: one dispatch per layer op
/// regardless of how many prompt positions the chunk carries.
pub fn expected_prefill_dispatches(dims: &GraphDims, fusion: FusionConfig) -> usize {
    expected_batched_dispatches(dims, fusion) + 1
}

/// Expected dispatch count per UNIFIED round: the batched-decode
/// arithmetic (rotary always fused) plus the per-slot last-row selection
/// dispatch. Width- AND chunk-independent — the whole point: one dispatch
/// per layer op regardless of how many sessions the round packs or how
/// many prompt tokens each slot carries.
pub fn expected_unified_dispatches(dims: &GraphDims, fusion: FusionConfig) -> usize {
    expected_batched_dispatches(dims, fusion) + 1
}

/// Expected dispatch count per batched serving round. Width-independent —
/// the whole point: one dispatch per layer op regardless of how many
/// sessions the round packs. Rotary is always fused in the batched graph
/// (see [`build_batched_decode_graph`]).
pub fn expected_batched_dispatches(dims: &GraphDims, fusion: FusionConfig) -> usize {
    let f = FusionConfig { rotary: true, ..fusion };
    expected_dispatches(dims, f)
}

/// Expected dispatch count per decode step for tiny-config graphs (used by
/// tests and the engine's accounting).
pub fn expected_dispatches(dims: &GraphDims, fusion: FusionConfig) -> usize {
    let l = dims.layers;
    let per_layer_unfused = 6 + 3 + 5 + 5 + 2 + 1 + 1 + 1 + 6 + 4 + 1 + 1; // 36
    let mut n = l * per_layer_unfused + 1 /* rope table */ + 6 /* final norm */ + 1 /* lm */;
    if fusion.rmsnorm {
        n -= (2 * l + 1) * 5; // 6 -> 1 per norm incl. final
    }
    if fusion.mlp {
        n -= 3 * l; // gate+up+silu+mul -> 1
    }
    if fusion.kv {
        n -= l; // k,v -> kv
    }
    if fusion.rotary {
        n -= 2 * l * 4; // 5 -> 1 per application, 2 applications
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_graph_validates_and_counts() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [
            FusionConfig::unfused(),
            FusionConfig::rmsnorm_only(),
            FusionConfig::rmsnorm_mlp(),
            FusionConfig::fused(),
        ] {
            let g = build_decode_graph(&dims, fusion);
            g.validate().unwrap();
            assert_eq!(
                g.dispatch_count(),
                expected_dispatches(&dims, fusion),
                "fusion {fusion:?}"
            );
        }
    }

    #[test]
    fn tiny_unfused_dispatch_count() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        // 4 layers x 36 + rope 1 + final norm 6 + lm 1 = 152
        assert_eq!(g.dispatch_count(), 152);
    }

    #[test]
    fn tiny_fused_dispatch_count() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::fused());
        // per layer: norm 1 + q 1 + kv 1 + rot 2 + cache 2 + sdpa 1 + o 1
        //            + add 1 + norm 1 + gus 1 + down 1 + add 1 = 14
        // + rope 1 + final norm 1 + lm 1
        assert_eq!(g.dispatch_count(), 4 * 14 + 3);
    }

    #[test]
    fn fusion_reduces_monotonically() {
        let dims = GraphDims::qwen_tiny();
        let u = build_decode_graph(&dims, FusionConfig::unfused()).dispatch_count();
        let r = build_decode_graph(&dims, FusionConfig::rmsnorm_only()).dispatch_count();
        let rm = build_decode_graph(&dims, FusionConfig::rmsnorm_mlp()).dispatch_count();
        let f = build_decode_graph(&dims, FusionConfig::fused()).dispatch_count();
        assert!(u > r && r > rm && rm > f);
    }

    #[test]
    fn kernel_names_match_aot_registry_convention() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::fused());
        let names = g.kernel_names();
        for expected in [
            "matmul_64_64", "kv_fused_64_64", "rmsnorm_64", "rotary_4_16",
            "rotary_2_16", "cache_update_tiny", "sdpa_tiny",
            "gate_up_silu_tiny", "matmul_176_64", "add_64", "matmul_64_512",
            "rope_cos_sin_16",
        ] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
    }

    #[test]
    fn caches_are_both_inputs_and_outputs() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::fused());
        for l in 0..dims.layers {
            assert!(g.inputs.contains_key(&format!("l{l}.k_cache")));
            assert!(g.outputs.contains_key(&format!("l{l}.k_cache")));
            assert!(g.outputs.contains_key(&format!("l{l}.v_cache")));
        }
        assert!(g.outputs.contains_key("logits"));
    }

    #[test]
    fn batched_graph_validates_and_dispatches_are_width_independent() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let mut counts = Vec::new();
            for width in [2usize, 3, 4, 8] {
                let g = build_batched_decode_graph(&dims, fusion, width);
                g.validate().unwrap();
                assert_eq!(g.batch_width, width);
                assert_eq!(
                    g.dispatch_count(),
                    expected_batched_dispatches(&dims, fusion),
                    "{fusion:?} width {width}"
                );
                counts.push(g.dispatch_count());
            }
            // One dispatch per layer op, NOT per session: constant in W.
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "{fusion:?}: {counts:?}");
        }
    }

    #[test]
    fn batched_fused_graph_is_one_dispatch_per_layer_op() {
        let dims = GraphDims::qwen_tiny();
        let g = build_batched_decode_graph(&dims, FusionConfig::fused(), 4);
        // per layer: norm 1 + q 1 + kv 1 + rot 2 + cache 2 + sdpa 1 + o 1
        //            + add 1 + norm 1 + gus 1 + down 1 + add 1 = 14
        // + rope 1 + final norm 1 + lm 1 — same arithmetic as the
        // single-session fused graph, amortized over up to 4 sessions.
        assert_eq!(g.dispatch_count(), 4 * 14 + 3);
        assert_eq!(
            g.dispatch_count(),
            build_decode_graph(&dims, FusionConfig::fused()).dispatch_count()
        );
    }

    #[test]
    fn batched_cache_sets_are_slot_major_and_isolated() {
        let dims = GraphDims::qwen_tiny();
        let width = 3;
        let g = build_batched_decode_graph(&dims, FusionConfig::fused(), width);
        // Slot-major persistent declaration: s0's full layer-major set,
        // then s1's, ... — each slot's slice IS one session's cache set.
        let expect: Vec<String> = (0..width)
            .flat_map(|j| {
                (0..dims.layers).flat_map(move |l| {
                    [format!("s{j}.l{l}.k_cache"), format!("s{j}.l{l}.v_cache")]
                })
            })
            .collect();
        assert_eq!(g.persistent, expect);
        // Every per-slot cache is both input and (updated) output.
        for name in &expect {
            assert!(g.inputs.contains_key(name), "{name} not an input");
            assert!(g.outputs.contains_key(name), "{name} not an output");
        }
        // In-place cache updates carry one state per slot.
        for n in g.nodes.iter().filter(|n| n.in_place()) {
            assert_eq!(n.outputs.len(), width, "{}", n.name);
            assert!(n.inputs.len() == width + 4, "{}: states + rows/pos/mask/idx", n.name);
        }
        assert_eq!(
            g.nodes.iter().filter(|n| n.in_place()).count(),
            2 * dims.layers
        );
    }

    #[test]
    fn batched_kernel_names_carry_width_and_slot_uniforms_exist() {
        let dims = GraphDims::qwen_tiny();
        let g = build_batched_decode_graph(&dims, FusionConfig::fused(), 4);
        let names = g.kernel_names();
        for expected in [
            "matmul_b4_64_64", "kv_fused_b4_64_64", "rmsnorm_b4_64",
            "rotary_b4_4_16", "rotary_b4_2_16", "cache_update_b4_tiny",
            "sdpa_b4_tiny", "gate_up_silu_b4_tiny", "matmul_b4_176_64",
            "add_b4_64", "matmul_b4_64_512", "rope_cos_sin_b4_16",
        ] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
        for input in ["x", "pos_i", "pos_ip1", "pos_f", "slot_mask", "slot_idx", "inv_freq"] {
            assert!(g.inputs.contains_key(input), "missing step input {input}");
        }
    }

    #[test]
    fn prefill_graph_validates_and_dispatches_are_chunk_independent() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let mut counts = Vec::new();
            for chunk in PREFILL_CHUNKS {
                let g = build_prefill_graph(&dims, fusion, chunk);
                g.validate().unwrap();
                assert_eq!(g.seq_chunk, chunk);
                assert_eq!(g.batch_width, 1);
                assert_eq!(
                    g.dispatch_count(),
                    expected_prefill_dispatches(&dims, fusion),
                    "{fusion:?} chunk {chunk}"
                );
                counts.push(g.dispatch_count());
            }
            // One dispatch per layer op, NOT per prompt token: constant
            // in C — a C-token chunk costs one decode step + last_row.
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "{fusion:?}: {counts:?}");
        }
        // Fused: the decode step's 14/layer + rope + last_row + norm + lm.
        let g = build_prefill_graph(&dims, FusionConfig::fused(), 16);
        assert_eq!(g.dispatch_count(), 4 * 14 + 4);
    }

    #[test]
    fn prefill_cache_layout_matches_decode_plan() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let pg = build_prefill_graph(&dims, fusion, 16);
            let dg = build_decode_graph(&dims, fusion);
            // Identical layer-major persistent declaration order: one
            // session's DeviceKvCache plugs into both plans.
            assert_eq!(pg.persistent, dg.persistent, "{fusion:?}");
            for name in &pg.persistent {
                assert!(pg.inputs.contains_key(name) && pg.outputs.contains_key(name));
            }
            // One multi-row in-place scatter per layer per K/V.
            assert_eq!(
                pg.nodes.iter().filter(|n| n.in_place()).count(),
                2 * dims.layers,
                "{fusion:?}"
            );
            for n in pg.nodes.iter().filter(|n| n.in_place()) {
                assert_eq!(n.outputs.len(), 1, "{}", n.name);
                assert_eq!(n.inputs.len(), 4, "{}: state + rows + base + valid", n.name);
            }
        }
    }

    #[test]
    fn prefill_kernel_names_carry_chunk_and_step_inputs_exist() {
        let dims = GraphDims::qwen_tiny();
        let g = build_prefill_graph(&dims, FusionConfig::fused(), 16);
        let names = g.kernel_names();
        for expected in [
            "matmul_c16_64_64", "kv_fused_c16_64_64", "rmsnorm_c16_64",
            "rotary_c16_4_16", "rotary_c16_2_16", "cache_update_c16_tiny",
            "sdpa_prefill_c16_tiny", "gate_up_silu_c16_tiny",
            "matmul_c16_176_64", "add_c16_64", "rope_cos_sin_c16_16",
            "chunk_last_row_c16_64", "rmsnorm_64", "matmul_64_512",
        ] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
        for input in ["x", "pos_f", "pos_base", "valid_len", "inv_freq"] {
            assert!(g.inputs.contains_key(input), "missing step input {input}");
        }
    }

    #[test]
    fn unified_graph_validates_and_dispatches_are_width_and_chunk_independent() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let mut counts = Vec::new();
            for width in [2usize, 4, 8] {
                for chunk in PREFILL_CHUNKS {
                    let g = build_unified_round_graph(&dims, fusion, width, chunk);
                    g.validate().unwrap();
                    assert_eq!(g.batch_width, width);
                    assert_eq!(g.seq_chunk, chunk);
                    assert_eq!(
                        g.dispatch_count(),
                        expected_unified_dispatches(&dims, fusion),
                        "{fusion:?} width {width} chunk {chunk}"
                    );
                    counts.push(g.dispatch_count());
                }
            }
            // One dispatch per layer op, NOT per session or per prompt
            // token: constant in both W and C.
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "{fusion:?}: {counts:?}");
        }
    }

    #[test]
    fn unified_fused_graph_is_one_dispatch_per_layer_op() {
        let dims = GraphDims::qwen_tiny();
        let g = build_unified_round_graph(&dims, FusionConfig::fused(), 4, 16);
        // per layer: norm 1 + q 1 + kv 1 + rot 2 + cache 2 + sdpa 1 + o 1
        //            + add 1 + norm 1 + gus 1 + down 1 + add 1 = 14
        // + rope 1 + slot_last_row 1 + final norm 1 + lm 1 — the prefill
        // arithmetic, now amortized over up to 4 MIXED prefill/decode
        // sessions instead of one prefill session.
        assert_eq!(g.dispatch_count(), 4 * 14 + 4);
        assert_eq!(
            g.dispatch_count(),
            build_prefill_graph(&dims, FusionConfig::fused(), 16).dispatch_count()
        );
    }

    #[test]
    fn unified_cache_sets_match_batched_layout() {
        let dims = GraphDims::qwen_tiny();
        let (width, chunk) = (3usize, 8usize);
        let g = build_unified_round_graph(&dims, FusionConfig::fused(), width, chunk);
        let bg = build_batched_decode_graph(&dims, FusionConfig::fused(), width);
        // The unified plan's persistent layout IS the batched cache-set
        // table: slot-major then layer-major, so sessions plug into the
        // same slots and the cache arena needs no new layout.
        assert_eq!(g.persistent, bg.persistent);
        for name in &g.persistent {
            assert!(g.inputs.contains_key(name), "{name} not an input");
            assert!(g.outputs.contains_key(name), "{name} not an output");
        }
        // In-place cache updates carry one state per slot, plus packed
        // rows and the four per-slot uniforms.
        for n in g.nodes.iter().filter(|n| n.in_place()) {
            assert_eq!(n.outputs.len(), width, "{}", n.name);
            assert_eq!(
                n.inputs.len(),
                width + 5,
                "{}: states + rows/base/valid/mask/idx",
                n.name
            );
        }
        assert_eq!(
            g.nodes.iter().filter(|n| n.in_place()).count(),
            2 * dims.layers
        );
    }

    #[test]
    fn unified_kernel_names_carry_width_chunk_and_step_inputs_exist() {
        let dims = GraphDims::qwen_tiny();
        let g = build_unified_round_graph(&dims, FusionConfig::fused(), 4, 16);
        let names = g.kernel_names();
        for expected in [
            "matmul_b4c16_64_64", "kv_fused_b4c16_64_64", "rmsnorm_b4c16_64",
            "rotary_b4c16_4_16", "rotary_b4c16_2_16", "cache_update_b4c16_tiny",
            "sdpa_b4c16_tiny", "gate_up_silu_b4c16_tiny", "matmul_b4c16_176_64",
            "add_b4c16_64", "rope_cos_sin_b4c16_16", "slot_last_row_b4c16_64",
            // The tail is the batched [W, ...] contract: batched final
            // norm + batched lm head, logits [W, vocab].
            "rmsnorm_b4_64", "matmul_b4_64_512",
        ] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
        for input in ["x", "pos_f", "pos_base", "valid_len", "slot_mask", "slot_idx", "inv_freq"]
        {
            assert!(g.inputs.contains_key(input), "missing step input {input}");
        }
    }

    #[test]
    fn multi_row_graphs_validate_and_keep_single_row_dispatch_counts() {
        // The speculative-verify tail is a 1-for-1 kernel swap: row-keep
        // instead of row-select, widened final norm + lm head. Dispatch
        // arithmetic must be untouched — the expected_* helpers stay valid
        // for both variants.
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            for chunk in PREFILL_CHUNKS {
                let m = build_prefill_graph_multi_row(&dims, fusion, chunk);
                m.validate().unwrap();
                assert_eq!(m.seq_chunk, chunk);
                assert_eq!(
                    m.dispatch_count(),
                    build_prefill_graph(&dims, fusion, chunk).dispatch_count(),
                    "{fusion:?} chunk {chunk}"
                );
                for width in [2usize, 4, 8] {
                    let u = build_unified_round_graph_multi_row(&dims, fusion, width, chunk);
                    u.validate().unwrap();
                    assert_eq!((u.batch_width, u.seq_chunk), (width, chunk));
                    assert_eq!(
                        u.dispatch_count(),
                        build_unified_round_graph(&dims, fusion, width, chunk).dispatch_count(),
                        "{fusion:?} width {width} chunk {chunk}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_row_tails_swap_row_keep_and_widened_lm_head() {
        let dims = GraphDims::qwen_tiny();
        let p = build_prefill_graph_multi_row(&dims, FusionConfig::fused(), 16);
        let names = p.kernel_names();
        for expected in ["chunk_rows_c16_64", "rmsnorm_c16_64", "matmul_c16_64_512"] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
        assert!(!names.iter().any(|n| n == "chunk_last_row_c16_64"), "{names:?}");
        assert!(!names.iter().any(|n| n == "matmul_64_512"), "{names:?}");

        let u = build_unified_round_graph_multi_row(&dims, FusionConfig::fused(), 4, 16);
        let names = u.kernel_names();
        for expected in ["slot_rows_b4c16_64", "rmsnorm_b4c16_64", "matmul_b4c16_64_512"] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}: {names:?}");
        }
        assert!(!names.iter().any(|n| n == "slot_last_row_b4c16_64"), "{names:?}");
        assert!(!names.iter().any(|n| n == "matmul_b4_64_512"), "{names:?}");
        // Same step inputs as the single-row unified graph — the engine's
        // packing code is shared between the two.
        for input in ["x", "pos_f", "pos_base", "valid_len", "slot_mask", "slot_idx", "inv_freq"]
        {
            assert!(u.inputs.contains_key(input), "missing step input {input}");
        }
    }

    #[test]
    fn caches_are_persistent_and_updated_in_place() {
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let g = build_decode_graph(&dims, fusion);
            // Layer-major persistent declaration order: l0.k, l0.v, l1.k, ...
            let expect: Vec<String> = (0..dims.layers)
                .flat_map(|l| [format!("l{l}.k_cache"), format!("l{l}.v_cache")])
                .collect();
            assert_eq!(g.persistent, expect, "{fusion:?}");
            let in_place = g.nodes.iter().filter(|n| n.in_place()).count();
            assert_eq!(in_place, 2 * dims.layers, "{fusion:?}");
            // In-place nodes do not change the dispatch arithmetic.
            assert_eq!(g.dispatch_count(), expected_dispatches(&dims, fusion));
        }
    }
}

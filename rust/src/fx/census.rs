//! FX graph census — reproduces Table 10 (and Appendix B) structurally.
//!
//! The compute categories are derived from the architecture: for L layers,
//!
//! ```text
//! Linear        7L + 1      q,k,v,o,gate,up,down per layer + lm head
//! Multiply      9L + 4      RMSNorm muls (4L+2), MLP gate mul (L),
//!                           rotary muls (4L), rope-frequency + attention
//!                           scale scalars (2)
//! Add           6L + 1      residuals (2L), eps adds (2L+1), rotary (2L)
//! SDPA          L
//! SiLU          L
//! RMS comps     6L + 3      pow/mean/rsqrt per norm (2L+1 norms)
//! Concat        4L + 1      rotate-half (2L), KV cache (2L), rope table (1)
//! Other         2L + 2      neg (2L), embedding, index
//! ```
//!
//! At L = 24 (Qwen2.5-0.5B) these give exactly the published census:
//! 169 / 220 / 145 / 24 / 24 / 147 / 97 / 50 = 876 compute ops.
//! Shape ops are 10L + 1 = 241; placeholders/outputs 12L + 5 = 293.
//! The `metadata` row (501 at L = 24, i.e. 21L - 3) is trace-level
//! bookkeeping pinned to the published census — it carries no dispatches.

use super::builder::GraphDims;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CategoryCounts {
    pub linear: usize,
    pub multiply: usize,
    pub add: usize,
    pub sdpa: usize,
    pub silu: usize,
    pub rms_components: usize,
    pub concat: usize,
    pub other: usize,
}

impl CategoryCounts {
    pub fn total(&self) -> usize {
        self.linear
            + self.multiply
            + self.add
            + self.sdpa
            + self.silu
            + self.rms_components
            + self.concat
            + self.other
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Census {
    pub layers: usize,
    pub compute: CategoryCounts,
    pub shape_ops: usize,
    pub placeholders_outputs: usize,
    pub metadata: usize,
}

impl Census {
    pub fn for_dims(d: &GraphDims) -> Self {
        let l = d.layers;
        Census {
            layers: l,
            compute: CategoryCounts {
                linear: 7 * l + 1,
                multiply: 9 * l + 4,
                add: 6 * l + 1,
                sdpa: l,
                silu: l,
                rms_components: 6 * l + 3,
                concat: 4 * l + 1,
                other: 2 * l + 2,
            },
            shape_ops: 10 * l + 1,
            placeholders_outputs: 12 * l + 5,
            metadata: 21 * l - 3,
        }
    }

    pub fn total_nodes(&self) -> usize {
        self.compute.total() + self.shape_ops + self.placeholders_outputs + self.metadata
    }

    /// Upper-bound dispatch count (no backend fusion): every compute op.
    pub fn unfused_dispatches(&self) -> usize {
        self.compute.total()
    }

    /// The paper's fusion arithmetic (Table 5): RMSNorm saves 5 per fused
    /// norm across 2L norms (the final norm is excluded in the paper's
    /// count of 240 = 24 x 2 x 5); MLP saves 2 per layer; K+V saves 1.
    pub fn paper_fusion_savings(&self) -> FusionSavings {
        let l = self.layers;
        FusionSavings { rmsnorm: 10 * l, mlp: 2 * l, kv: l }
    }

    pub fn fused_dispatches(&self) -> usize {
        self.unfused_dispatches() - self.paper_fusion_savings().total()
    }

    /// KV-cache appends per decode step (2 per layer, inside the Concat
    /// row). In the executable graph these are the *in-place*
    /// `cache_update` dispatches: they stay dispatches in every fusion
    /// config (no fusion removes them), but with device-resident caches
    /// they stop generating any per-step host traffic.
    pub fn cache_appends(&self) -> usize {
        2 * self.layers
    }

    /// Batched-round dispatch arithmetic (Appendix F): a serving round
    /// that steps `sessions` active sessions interleaved issues
    /// `sessions x d` dispatches, while the batched graph replays
    /// `ceil(sessions / width)` chunks of `d` dispatches — each batched
    /// dispatch covers a whole chunk, so the per-replay count is
    /// batch-width-INDEPENDENT (the batch-shape consistency the builder
    /// tests pin). Returns `(interleaved, batched)` per-round dispatch
    /// counts at the paper's fused dispatch census.
    pub fn batched_round_dispatches(&self, sessions: usize, width: usize) -> (usize, usize) {
        assert!(sessions > 0 && width > 0);
        let d = self.fused_dispatches();
        let chunks = (sessions + width - 1) / width;
        (sessions * d, chunks * d)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionSavings {
    pub rmsnorm: usize,
    pub mlp: usize,
    pub kv: usize,
}

impl FusionSavings {
    pub fn total(&self) -> usize {
        self.rmsnorm + self.mlp + self.kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_05b_census_matches_table10() {
        let c = Census::for_dims(&GraphDims::qwen25_05b());
        assert_eq!(c.compute.linear, 169);
        assert_eq!(c.compute.multiply, 220);
        assert_eq!(c.compute.add, 145);
        assert_eq!(c.compute.sdpa, 24);
        assert_eq!(c.compute.silu, 24);
        assert_eq!(c.compute.rms_components, 147);
        assert_eq!(c.compute.concat, 97);
        assert_eq!(c.compute.other, 50);
        assert_eq!(c.compute.total(), 876);
        assert_eq!(c.shape_ops, 241);
        assert_eq!(c.placeholders_outputs, 293);
        assert_eq!(c.metadata, 501);
        assert_eq!(c.total_nodes(), 1911);
    }

    #[test]
    fn qwen_05b_fusion_arithmetic_matches_table5() {
        let c = Census::for_dims(&GraphDims::qwen25_05b());
        let s = c.paper_fusion_savings();
        assert_eq!(s.rmsnorm, 240);
        assert_eq!(s.mlp, 48);
        assert_eq!(s.kv, 24);
        assert_eq!(s.total(), 312);
        assert_eq!(c.fused_dispatches(), 564);
    }

    #[test]
    fn qwen_15b_scales_with_layers() {
        let c = Census::for_dims(&GraphDims::qwen25_15b());
        assert_eq!(c.layers, 28);
        assert_eq!(c.compute.total(), 1020);
        // dispatch count scales ~1.17x with layers (Table 18)
        let c05 = Census::for_dims(&GraphDims::qwen25_05b());
        let ratio = c.fused_dispatches() as f64 / c05.fused_dispatches() as f64;
        assert!((ratio - 28.0 / 24.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn cache_appends_match_executable_in_place_nodes() {
        use crate::fx::builder::{build_decode_graph, FusionConfig};
        let dims = GraphDims::qwen_tiny();
        let c = Census::for_dims(&dims);
        let g = build_decode_graph(&dims, FusionConfig::fused());
        let in_place = g.nodes.iter().filter(|n| n.in_place()).count();
        assert_eq!(c.cache_appends(), in_place);
        // They are a strict subset of the Concat census row.
        assert!(c.cache_appends() <= c.compute.concat);
    }

    #[test]
    fn batched_round_arithmetic_halves_dispatches_at_n4_w4() {
        let c = Census::for_dims(&GraphDims::qwen25_05b());
        let (interleaved, batched) = c.batched_round_dispatches(4, 4);
        assert_eq!(interleaved, 4 * 564);
        assert_eq!(batched, 564);
        // The serve-bench acceptance gate's shape: batched <= interleaved/2.
        assert!(batched * 2 <= interleaved);
        // Ragged round: 5 sessions at width 4 need two chunks.
        let (i5, b5) = c.batched_round_dispatches(5, 4);
        assert_eq!((i5, b5), (5 * 564, 2 * 564));
        // Per-replay count is width-independent for full chunks.
        assert_eq!(c.batched_round_dispatches(2, 2).1, c.batched_round_dispatches(8, 8).1);
    }

    #[test]
    fn rms_components_are_49_each_of_three() {
        // "The 49 occurrences each of pow, mean, and rsqrt" (Appendix B).
        let c = Census::for_dims(&GraphDims::qwen25_05b());
        assert_eq!(c.compute.rms_components / 3, 49);
    }
}

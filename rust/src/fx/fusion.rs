//! Fusion passes: real pattern-matching graph rewrites implementing the
//! paper's three structural fusions (§6.1) plus rotary fusion.
//!
//! Each pass scans the node list for its dataflow pattern, checks that the
//! intermediate values have no external uses, and splices in the fused
//! kernel node. Passes are semantics-preserving: integration tests execute
//! fused and unfused graphs and require allclose outputs (the paper's
//! Appendix N property).

use std::collections::HashMap;

use super::graph::FxGraph;
use super::node::{Category, HostOp, Node, NodeId, OpKind, ValueId};

/// Count uses of every value across node inputs and graph outputs.
fn use_counts(g: &FxGraph) -> HashMap<ValueId, usize> {
    let mut uses: HashMap<ValueId, usize> = HashMap::new();
    for n in &g.nodes {
        for &v in &n.inputs {
            *uses.entry(v).or_insert(0) += 1;
        }
    }
    for &v in g.outputs.values() {
        *uses.entry(v).or_insert(0) += 1;
    }
    uses
}

/// Map: value -> index of the node producing it.
fn producers(g: &FxGraph) -> HashMap<ValueId, usize> {
    let mut p = HashMap::new();
    for (i, n) in g.nodes.iter().enumerate() {
        for &v in &n.outputs {
            p.insert(v, i);
        }
    }
    p
}

fn kernel_name(n: &Node) -> &str {
    match &n.op {
        // In-place kernels (cache updates) are never fusion candidates:
        // return "" so no pattern matches them.
        OpKind::Kernel(k) => k,
        OpKind::InPlaceKernel(_) | OpKind::Host(_) => "",
    }
}

/// Rebuild the graph without the nodes in `dead`, inserting `replacements`
/// (index -> nodes to emit *instead of* the node at that index).
fn splice(g: &FxGraph, dead: &[bool], replacements: HashMap<usize, Vec<Node>>) -> FxGraph {
    let mut out = FxGraph {
        nodes: Vec::with_capacity(g.nodes.len()),
        n_values: g.n_values,
        inputs: g.inputs.clone(),
        outputs: g.outputs.clone(),
        persistent: g.persistent.clone(),
        batch_width: g.batch_width,
        seq_chunk: g.seq_chunk,
    };
    for (i, n) in g.nodes.iter().enumerate() {
        if let Some(reps) = replacements.get(&i) {
            for r in reps {
                let mut r = r.clone();
                r.id = NodeId(out.nodes.len());
                out.nodes.push(r);
            }
        }
        if !dead[i] {
            let mut n = n.clone();
            n.id = NodeId(out.nodes.len());
            out.nodes.push(n);
        }
    }
    out
}

/// RMSNorm fusion: pow -> mean -> add_eps -> rsqrt -> mul_x -> mul_w
/// becomes one `rmsnorm_{H}` dispatch (6 -> 1, the +44% fusion).
pub fn fuse_rmsnorm(g: &FxGraph) -> FxGraph {
    let uses = use_counts(g);
    let prod = producers(g);
    let mut dead = vec![false; g.nodes.len()];
    let mut reps: HashMap<usize, Vec<Node>> = HashMap::new();

    for (i, n) in g.nodes.iter().enumerate() {
        if !kernel_name(n).starts_with("rms_mul_w_") || dead[i] {
            continue;
        }
        // Walk the chain backwards from mul_w(xn, w).
        let (xn, w) = (n.inputs[0], n.inputs[1]);
        let Some(&i_mul_x) = prod.get(&xn) else { continue };
        let mul_x = &g.nodes[i_mul_x];
        if !kernel_name(mul_x).starts_with("rms_mul_x_") {
            continue;
        }
        let (x, r) = (mul_x.inputs[0], mul_x.inputs[1]);
        let Some(&i_rsqrt) = prod.get(&r) else { continue };
        let rsqrt = &g.nodes[i_rsqrt];
        if !kernel_name(rsqrt).starts_with("rms_rsqrt") {
            continue;
        }
        let Some(&i_adde) = prod.get(&rsqrt.inputs[0]) else { continue };
        let adde = &g.nodes[i_adde];
        if !kernel_name(adde).starts_with("rms_add_eps") {
            continue;
        }
        let Some(&i_mean) = prod.get(&adde.inputs[0]) else { continue };
        let mean = &g.nodes[i_mean];
        if !kernel_name(mean).starts_with("rms_mean_") {
            continue;
        }
        let Some(&i_pow) = prod.get(&mean.inputs[0]) else { continue };
        let pw = &g.nodes[i_pow];
        if !kernel_name(pw).starts_with("rms_pow_") || pw.inputs[0] != x {
            continue;
        }
        // Intermediates must have no external consumers.
        let internals = [
            (pw.outputs[0], 1),
            (mean.outputs[0], 1),
            (adde.outputs[0], 1),
            (rsqrt.outputs[0], 1),
            (mul_x.outputs[0], 1),
        ];
        if internals.iter().any(|(v, n)| uses.get(v).copied().unwrap_or(0) != *n) {
            continue;
        }
        let hidden = kernel_name(pw).trim_start_matches("rms_pow_").to_string();
        for idx in [i_pow, i_mean, i_adde, i_rsqrt, i_mul_x, i] {
            dead[idx] = true;
        }
        reps.insert(
            i,
            vec![Node {
                id: NodeId(0),
                name: n.name.replace(".mul_w", ".rmsnorm_fused"),
                op: OpKind::Kernel(format!("rmsnorm_{hidden}")),
                category: Category::Other,
                inputs: vec![x, w],
                outputs: vec![n.outputs[0]],
            }],
        );
    }
    splice(g, &dead, reps)
}

/// MLP fusion: gate matmul + up matmul + silu + mul -> `gate_up_silu_*`
/// (the paper's "gate+up+SiLU in one kernel").
pub fn fuse_mlp(g: &FxGraph, suffix: &str) -> FxGraph {
    let uses = use_counts(g);
    let prod = producers(g);
    let mut dead = vec![false; g.nodes.len()];
    let mut reps: HashMap<usize, Vec<Node>> = HashMap::new();

    for (i, n) in g.nodes.iter().enumerate() {
        // Anchor on the gate mul: mul(silu(gate), up).
        if !kernel_name(n).starts_with("mul_") || dead[i] || n.inputs.len() != 2 {
            continue;
        }
        let Some(&i_silu) = prod.get(&n.inputs[0]) else { continue };
        let silu = &g.nodes[i_silu];
        if !kernel_name(silu).starts_with("silu_") {
            continue;
        }
        let Some(&i_gate) = prod.get(&silu.inputs[0]) else { continue };
        let Some(&i_up) = prod.get(&n.inputs[1]) else { continue };
        let gate = &g.nodes[i_gate];
        let up = &g.nodes[i_up];
        if gate.category != Category::Linear || up.category != Category::Linear {
            continue;
        }
        // Both projections must share the normed input.
        if gate.inputs[0] != up.inputs[0] {
            continue;
        }
        let internals = [gate.outputs[0], up.outputs[0], silu.outputs[0]];
        if internals.iter().any(|v| uses.get(v).copied().unwrap_or(0) != 1) {
            continue;
        }
        let (h2, wg, wu) = (gate.inputs[0], gate.inputs[1], up.inputs[1]);
        for idx in [i_gate, i_up, i_silu, i] {
            dead[idx] = true;
        }
        reps.insert(
            i,
            vec![Node {
                id: NodeId(0),
                name: n.name.replace(".gate_mul", ".gate_up_silu"),
                op: OpKind::Kernel(format!("gate_up_silu_{suffix}")),
                category: Category::Silu,
                inputs: vec![h2, wg, wu],
                outputs: vec![n.outputs[0]],
            }],
        );
    }
    splice(g, &dead, reps)
}

/// K+V fusion: two same-shape projections off the same input merge into one
/// concatenated-weight matmul + a host split. Requires the fused weight to
/// be available as the graph input `<layer>.wkv`.
///
/// Batch- and seq-safe: in a batched (`matmul_b{W}_{H}_{KV}`) or chunked-
/// prefill (`matmul_c{C}_{H}_{KV}`) graph the fused kernel emits the K and
/// V rows as TWO outputs directly (`kv_fused_b{W}_…` / `kv_fused_c{C}_…`)
/// — the `[rows, 2KV] -> 2 x [rows, KV]` row split is strided, so the host
/// `SplitKv` byte-window alias the single-session rewrite uses cannot
/// represent it.
pub fn fuse_kv(g: &FxGraph) -> FxGraph {
    let prod = producers(g);
    let mut dead = vec![false; g.nodes.len()];
    let mut reps: HashMap<usize, Vec<Node>> = HashMap::new();
    let mut g2 = g.clone();

    // Find (k_proj, v_proj) pairs by node name convention lX.k_proj/lX.v_proj.
    let names: Vec<String> = g.nodes.iter().map(|n| n.name.clone()).collect();
    for (i, name) in names.iter().enumerate() {
        let Some(layer) = name.strip_suffix(".k_proj") else { continue };
        let v_name = format!("{layer}.v_proj");
        let Some(j) = names.iter().position(|m| m == &v_name) else { continue };
        let (kn, vn) = (&g.nodes[i], &g.nodes[j]);
        if kn.inputs[0] != vn.inputs[0] || dead[i] || dead[j] {
            continue;
        }
        let Some(kname) = kn.kernel() else { continue };
        // matmul_{H}_{KV} -> kv_fused_{H}_{2KV}, or the multi-row forms:
        // batched matmul_b{W}_{H}_{KV} -> kv_fused_b{W}_{H}_{2KV},
        // chunked-prefill matmul_c{C}_{H}_{KV} -> kv_fused_c{C}_{H}_{2KV},
        // and unified matmul_b{W}c{C}_{H}_{KV} -> kv_fused_b{W}c{C}_{H}_{2KV}.
        let parts: Vec<&str> = kname.split('_').collect();
        let (batched_prefix, h, kv): (Option<String>, usize, usize) = if parts.len() == 3
            && parts[0] == "matmul"
        {
            match (parts[1].parse::<usize>(), parts[2].parse::<usize>()) {
                (Ok(a), Ok(b)) => (None, a, b),
                _ => continue,
            }
        } else if parts.len() == 4
            && parts[0] == "matmul"
            && (parts[1].starts_with('b') || parts[1].starts_with('c'))
        {
            let seg = &parts[1][1..];
            // "4" (b4/c16) or the unified "4c16" (b4c16).
            let rows_ok = seg.parse::<usize>().is_ok()
                || (parts[1].starts_with('b')
                    && seg
                        .split_once('c')
                        .map(|(w, ch)| {
                            w.parse::<usize>().is_ok() && ch.parse::<usize>().is_ok()
                        })
                        .unwrap_or(false));
            match (rows_ok, parts[2].parse::<usize>(), parts[3].parse::<usize>()) {
                (true, Ok(a), Ok(b)) => (Some(parts[1].to_string()), a, b),
                _ => continue,
            }
        } else {
            continue;
        };
        let _ = prod; // producers not needed beyond here; keep for clarity
        let wkv = g2.input(&format!("{layer}.wkv"));
        dead[i] = true;
        dead[j] = true;
        let nodes = match &batched_prefix {
            None => {
                let fused_out = g2.new_value();
                vec![
                    Node {
                        id: NodeId(0),
                        name: format!("{layer}.kv_proj"),
                        op: OpKind::Kernel(format!("kv_fused_{h}_{}", 2 * kv)),
                        category: Category::Linear,
                        inputs: vec![kn.inputs[0], wkv],
                        outputs: vec![fused_out],
                    },
                    Node {
                        id: NodeId(0),
                        name: format!("{layer}.kv_split"),
                        op: OpKind::Host(HostOp::SplitKv),
                        category: Category::Shape,
                        inputs: vec![fused_out],
                        outputs: vec![kn.outputs[0], vn.outputs[0]],
                    },
                ]
            }
            Some(b) => vec![Node {
                id: NodeId(0),
                name: format!("{layer}.kv_proj"),
                op: OpKind::Kernel(format!("kv_fused_{b}_{h}_{}", 2 * kv)),
                category: Category::Linear,
                inputs: vec![kn.inputs[0], wkv],
                outputs: vec![kn.outputs[0], vn.outputs[0]],
            }],
        };
        reps.insert(i, nodes);
    }
    let out = splice(&g2, &dead, reps);
    out
}

/// Rotary fusion: neg + concat + mul_cos + mul_sin + add (5 dispatches)
/// plus the host halves-split collapse into one `rotary_{h}_{d}` dispatch.
pub fn fuse_rotary(g: &FxGraph) -> FxGraph {
    let uses = use_counts(g);
    let prod = producers(g);
    let mut dead = vec![false; g.nodes.len()];
    let mut reps: HashMap<usize, Vec<Node>> = HashMap::new();

    for (i, n) in g.nodes.iter().enumerate() {
        // Anchor on the final add: add(mul_cos(xh,cos), mul_sin(rot,sin)).
        if !n.name.ends_with(".add") || dead[i] || !kernel_name(n).starts_with("add_") {
            continue;
        }
        let (Some(&i_a), Some(&i_b)) = (prod.get(&n.inputs[0]), prod.get(&n.inputs[1]))
        else {
            continue;
        };
        let (a, b) = (&g.nodes[i_a], &g.nodes[i_b]);
        if !kernel_name(a).starts_with("mul_vec_") || !kernel_name(b).starts_with("mul_vec_") {
            continue;
        }
        let (xh, cos) = (a.inputs[0], a.inputs[1]);
        let (rot, sin) = (b.inputs[0], b.inputs[1]);
        let Some(&i_cat) = prod.get(&rot) else { continue };
        let cat = &g.nodes[i_cat];
        if !kernel_name(cat).starts_with("concat_") {
            continue;
        }
        let Some(&i_neg) = prod.get(&cat.inputs[0]) else { continue };
        let neg = &g.nodes[i_neg];
        if !kernel_name(neg).starts_with("neg_") {
            continue;
        }
        let Some(&i_halves) = prod.get(&neg.inputs[0]) else { continue };
        let halves = &g.nodes[i_halves];
        if !matches!(halves.op, OpKind::Host(HostOp::Halves)) || halves.inputs[0] != xh {
            continue;
        }
        // x1 (second concat input) must be the halves' first output.
        if cat.inputs[1] != halves.outputs[0] || neg.inputs[0] != halves.outputs[1] {
            continue;
        }
        let internals = [neg.outputs[0], cat.outputs[0], a.outputs[0], b.outputs[0]];
        if internals.iter().any(|v| uses.get(v).copied().unwrap_or(0) != 1) {
            continue;
        }
        // mul_vec_{h}_{d} -> rotary_{h}_{d}
        let dims = kernel_name(a).trim_start_matches("mul_vec_").to_string();
        for idx in [i_halves, i_neg, i_cat, i_a, i_b, i] {
            dead[idx] = true;
        }
        reps.insert(
            i,
            vec![Node {
                id: NodeId(0),
                name: n.name.replace(".add", ".rotary_fused"),
                op: OpKind::Kernel(format!("rotary_{dims}")),
                category: Category::Other,
                inputs: vec![xh, cos, sin],
                outputs: vec![n.outputs[0]],
            }],
        );
    }
    splice(g, &dead, reps)
}

/// Apply every pass (the fully-fused configuration) through the
/// [`PassManager`](crate::fx::passes::PassManager), which validates SSA
/// after each rewrite.
pub fn fuse_all(g: &FxGraph, suffix: &str) -> FxGraph {
    use crate::fx::builder::FusionConfig;
    let (out, _reports) = crate::fx::passes::PassManager::for_fusion(FusionConfig::fused(), suffix)
        .run(g)
        .expect("fusion passes preserve SSA");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::builder::{build_decode_graph, FusionConfig, GraphDims};

    #[test]
    fn rmsnorm_pass_saves_5_per_norm() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let fused = fuse_rmsnorm(&g);
        fused.validate().unwrap();
        // 2L+1 = 9 norms, 5 saved each
        assert_eq!(g.dispatch_count() - fused.dispatch_count(), 45);
    }

    #[test]
    fn mlp_pass_saves_3_per_layer() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let fused = fuse_mlp(&g, "tiny");
        fused.validate().unwrap();
        assert_eq!(g.dispatch_count() - fused.dispatch_count(), 3 * dims.layers);
    }

    #[test]
    fn kv_pass_saves_1_per_layer() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let fused = fuse_kv(&g);
        fused.validate().unwrap();
        assert_eq!(g.dispatch_count() - fused.dispatch_count(), dims.layers);
        // the fused weight inputs appear
        assert!(fused.inputs.contains_key("l0.wkv"));
    }

    #[test]
    fn rotary_pass_saves_4_per_application() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let fused = fuse_rotary(&g);
        fused.validate().unwrap();
        // 2 applications per layer, 5 kernel nodes -> 1
        assert_eq!(g.dispatch_count() - fused.dispatch_count(), 8 * dims.layers);
    }

    #[test]
    fn all_passes_reach_builder_fused_count() {
        let dims = GraphDims::qwen_tiny();
        let unfused = build_decode_graph(&dims, FusionConfig::unfused());
        let by_passes = fuse_all(&unfused, "tiny");
        by_passes.validate().unwrap();
        let direct = build_decode_graph(&dims, FusionConfig::fused());
        assert_eq!(by_passes.dispatch_count(), direct.dispatch_count());
        // identical kernel usage
        assert_eq!(by_passes.kernel_names(), direct.kernel_names());
    }

    #[test]
    fn fusion_passes_are_batch_safe() {
        // Running the rewrite pipeline on a batched unfused graph must
        // reach exactly the batched fused builder's graph (dispatch count
        // and kernel set) and keep it valid — the batch-safety proof the
        // batched planner relies on. Rotary is excluded: the batched
        // builder always emits the fused rotary kernel.
        use crate::fx::builder::build_batched_decode_graph;
        use crate::fx::passes::PassManager;
        let dims = GraphDims::qwen_tiny();
        for width in [2usize, 4] {
            let unfused = build_batched_decode_graph(&dims, FusionConfig::unfused(), width);
            let (by_passes, reports) = PassManager::for_fusion(
                FusionConfig::rmsnorm_mlp_kv(),
                &format!("b{width}_tiny"),
            )
            .run(&unfused)
            .unwrap();
            let direct = build_batched_decode_graph(&dims, FusionConfig::fused(), width);
            assert_eq!(by_passes.dispatch_count(), direct.dispatch_count(), "w={width}");
            assert_eq!(by_passes.kernel_names(), direct.kernel_names(), "w={width}");
            assert_eq!(by_passes.batch_width, width, "splice must preserve batch width");
            assert!(reports.iter().all(|r| r.saved() > 0), "{reports:?}");
        }
    }

    #[test]
    fn batched_kv_fusion_emits_two_output_kernel_without_host_split() {
        use crate::fx::builder::build_batched_decode_graph;
        let dims = GraphDims::qwen_tiny();
        let g = build_batched_decode_graph(&dims, FusionConfig::unfused(), 4);
        let fused = fuse_kv(&g);
        fused.validate().unwrap();
        assert_eq!(g.dispatch_count() - fused.dispatch_count(), dims.layers);
        assert!(fused.inputs.contains_key("l0.wkv"));
        // No SplitKv host nodes: the batched row split is strided, the
        // fused kernel emits K and V directly.
        assert!(!fused
            .nodes
            .iter()
            .any(|n| matches!(n.op, OpKind::Host(HostOp::SplitKv))));
        assert!(fused.kernel_names().iter().any(|n| n == "kv_fused_b4_64_64"));
    }

    #[test]
    fn fusion_passes_are_seq_safe() {
        // Running the rewrite pipeline on an unfused chunked-prefill graph
        // must reach exactly the fused prefill builder's graph (dispatch
        // count and kernel set) and keep it valid — the seq-safety proof
        // the prefill planner relies on. Rotary is excluded: the prefill
        // builder always emits the fused rotary kernel.
        use crate::fx::builder::build_prefill_graph;
        use crate::fx::passes::PassManager;
        let dims = GraphDims::qwen_tiny();
        for chunk in [8usize, 16] {
            let unfused = build_prefill_graph(&dims, FusionConfig::unfused(), chunk);
            let (by_passes, reports) = PassManager::for_fusion(
                FusionConfig::rmsnorm_mlp_kv(),
                &format!("c{chunk}_tiny"),
            )
            .run(&unfused)
            .unwrap();
            let direct = build_prefill_graph(&dims, FusionConfig::fused(), chunk);
            assert_eq!(by_passes.dispatch_count(), direct.dispatch_count(), "c={chunk}");
            assert_eq!(by_passes.kernel_names(), direct.kernel_names(), "c={chunk}");
            assert_eq!(by_passes.seq_chunk, chunk, "splice must preserve the chunk");
            assert!(reports.iter().all(|r| r.saved() > 0), "{reports:?}");
        }
    }

    #[test]
    fn fusion_passes_are_seq_batch_safe() {
        // Running the rewrite pipeline on an unfused UNIFIED round graph
        // must reach exactly the fused unified builder's graph (dispatch
        // count and kernel set) and keep it valid — the combined-shape
        // safety proof the unified planner relies on. Rotary is excluded:
        // the unified builder always emits the fused rotary kernel.
        use crate::fx::builder::build_unified_round_graph;
        use crate::fx::passes::PassManager;
        let dims = GraphDims::qwen_tiny();
        for (width, chunk) in [(2usize, 8usize), (4, 16)] {
            let unfused = build_unified_round_graph(&dims, FusionConfig::unfused(), width, chunk);
            let (by_passes, reports) = PassManager::for_fusion(
                FusionConfig::rmsnorm_mlp_kv(),
                &format!("b{width}c{chunk}_tiny"),
            )
            .run(&unfused)
            .unwrap();
            let direct = build_unified_round_graph(&dims, FusionConfig::fused(), width, chunk);
            assert_eq!(
                by_passes.dispatch_count(),
                direct.dispatch_count(),
                "w={width} c={chunk}"
            );
            assert_eq!(by_passes.kernel_names(), direct.kernel_names(), "w={width} c={chunk}");
            assert_eq!(by_passes.batch_width, width, "splice must preserve batch width");
            assert_eq!(by_passes.seq_chunk, chunk, "splice must preserve the chunk");
            assert!(reports.iter().all(|r| r.saved() > 0), "{reports:?}");
        }
    }

    #[test]
    fn passes_are_idempotent() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let once = fuse_rmsnorm(&g);
        let twice = fuse_rmsnorm(&once);
        assert_eq!(once.dispatch_count(), twice.dispatch_count());
    }

    #[test]
    fn pass_on_fused_graph_is_noop() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::fused());
        let f = fuse_all(&g, "tiny");
        assert_eq!(f.dispatch_count(), g.dispatch_count());
    }
}

//! The FX graph container: SSA nodes in execution order, named ports.

use std::collections::HashMap;

use super::node::{Category, HostOp, Node, NodeId, OpKind, ValueId};
use crate::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct FxGraph {
    pub nodes: Vec<Node>,
    pub n_values: usize,
    /// External inputs (weights, caches, token embedding, pos scalars).
    pub inputs: HashMap<String, ValueId>,
    /// Named outputs (logits, updated caches).
    pub outputs: HashMap<String, ValueId>,
}

impl FxGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_value(&mut self) -> ValueId {
        let v = ValueId(self.n_values);
        self.n_values += 1;
        v
    }

    pub fn input(&mut self, name: &str) -> ValueId {
        if let Some(&v) = self.inputs.get(name) {
            return v;
        }
        let v = self.new_value();
        self.inputs.insert(name.to_string(), v);
        v
    }

    pub fn mark_output(&mut self, name: &str, v: ValueId) {
        self.outputs.insert(name.to_string(), v);
    }

    /// Append a kernel node with one output value.
    pub fn kernel(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
    ) -> ValueId {
        let out = self.new_value();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: vec![out],
        });
        out
    }

    /// Append a kernel node with N output values.
    pub fn kernel_multi(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Append a host (non-dispatch) node.
    pub fn host(
        &mut self,
        name: &str,
        op: HostOp,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Host(op),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Number of nodes that become WebGPU dispatches.
    pub fn dispatch_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.dispatches()).count()
    }

    /// Per-category node counts.
    pub fn category_counts(&self) -> HashMap<Category, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.category).or_insert(0) += 1;
        }
        m
    }

    /// SSA validation: every node input must be an external input or a
    /// value produced by an earlier node; every output defined exactly once.
    pub fn validate(&self) -> Result<()> {
        let mut defined = vec![false; self.n_values];
        for &v in self.inputs.values() {
            defined[v.0] = true;
        }
        for node in &self.nodes {
            for &inp in &node.inputs {
                if inp.0 >= self.n_values {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} out of range",
                        node.name, inp
                    )));
                }
                if !defined[inp.0] {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} used before definition",
                        node.name, inp
                    )));
                }
            }
            for &out in &node.outputs {
                if defined[out.0] {
                    return Err(Error::Graph(format!(
                        "{}: output {:?} defined twice",
                        node.name, out
                    )));
                }
                defined[out.0] = true;
            }
        }
        for (name, &v) in &self.outputs {
            if !defined[v.0] {
                return Err(Error::Graph(format!("output '{name}' never produced")));
            }
        }
        Ok(())
    }

    /// Kernel names used by this graph (for registry preloading).
    pub fn kernel_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .nodes
            .iter()
            .filter_map(|n| n.kernel().map(str::to_string))
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssa_validation_catches_use_before_def() {
        let mut g = FxGraph::new();
        let dangling = g.new_value(); // never produced, not an input
        g.kernel("bad", "k", Category::Add, vec![dangling]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn ssa_validation_accepts_chain() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "k1", Category::Add, vec![x]);
        let z = g.kernel("b", "k2", Category::Multiply, vec![y, x]);
        g.mark_output("out", z);
        assert!(g.validate().is_ok());
        assert_eq!(g.dispatch_count(), 2);
    }

    #[test]
    fn host_nodes_do_not_dispatch() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        g.host("r", HostOp::FromHeads, Category::Shape, vec![x], 1);
        assert_eq!(g.dispatch_count(), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn kernel_names_deduped() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "same", Category::Add, vec![x]);
        g.kernel("b", "same", Category::Add, vec![y]);
        assert_eq!(g.kernel_names(), vec!["same".to_string()]);
    }
}

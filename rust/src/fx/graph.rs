//! The FX graph container: SSA nodes in execution order, named ports.

use std::collections::HashMap;

use super::node::{Category, HostOp, Node, NodeId, OpKind, ValueId};
use crate::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct FxGraph {
    pub nodes: Vec<Node>,
    pub n_values: usize,
    /// External inputs (weights, caches, token embedding, pos scalars).
    pub inputs: HashMap<String, ValueId>,
    /// Named outputs (logits, updated caches).
    pub outputs: HashMap<String, ValueId>,
    /// Inputs that are *persistent state* (KV caches): they survive across
    /// decode steps and may be kept device-resident by a planner instead of
    /// being re-uploaded per step. Declaration order is preserved — it
    /// defines the layout of a session's cache set (layer-major for the
    /// decode builder). Eager executors ignore this and treat them as
    /// ordinary per-step inputs.
    pub persistent: Vec<String>,
}

impl FxGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_value(&mut self) -> ValueId {
        let v = ValueId(self.n_values);
        self.n_values += 1;
        v
    }

    pub fn input(&mut self, name: &str) -> ValueId {
        if let Some(&v) = self.inputs.get(name) {
            return v;
        }
        let v = self.new_value();
        self.inputs.insert(name.to_string(), v);
        v
    }

    pub fn mark_output(&mut self, name: &str, v: ValueId) {
        self.outputs.insert(name.to_string(), v);
    }

    /// Declare an existing input as persistent state (see [`FxGraph::persistent`]).
    pub fn mark_persistent(&mut self, name: &str) {
        debug_assert!(self.inputs.contains_key(name), "persistent '{name}' is not an input");
        if !self.persistent.iter().any(|n| n == name) {
            self.persistent.push(name.to_string());
        }
    }

    /// Value ids of the persistent inputs, in declaration order.
    pub fn persistent_values(&self) -> Vec<ValueId> {
        self.persistent.iter().map(|n| self.inputs[n]).collect()
    }

    /// Append a kernel node with one output value.
    pub fn kernel(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
    ) -> ValueId {
        let out = self.new_value();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: vec![out],
        });
        out
    }

    /// Append an in-place kernel node: one dispatch whose single output
    /// updates `inputs[0]`'s storage in place (see
    /// [`OpKind::InPlaceKernel`]). SSA-wise the output is a fresh value.
    pub fn in_place_kernel(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
    ) -> ValueId {
        let out = self.new_value();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::InPlaceKernel(kernel.to_string()),
            category,
            inputs,
            outputs: vec![out],
        });
        out
    }

    /// Append a kernel node with N output values.
    pub fn kernel_multi(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Append a host (non-dispatch) node.
    pub fn host(
        &mut self,
        name: &str,
        op: HostOp,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Host(op),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Number of nodes that become WebGPU dispatches.
    pub fn dispatch_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.dispatches()).count()
    }

    /// Per-category node counts.
    pub fn category_counts(&self) -> HashMap<Category, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.category).or_insert(0) += 1;
        }
        m
    }

    /// SSA validation: every node input must be an external input or a
    /// value produced by an earlier node; every output defined exactly once.
    pub fn validate(&self) -> Result<()> {
        let mut defined = vec![false; self.n_values];
        for &v in self.inputs.values() {
            defined[v.0] = true;
        }
        for node in &self.nodes {
            for &inp in &node.inputs {
                if inp.0 >= self.n_values {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} out of range",
                        node.name, inp
                    )));
                }
                if !defined[inp.0] {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} used before definition",
                        node.name, inp
                    )));
                }
            }
            for &out in &node.outputs {
                if defined[out.0] {
                    return Err(Error::Graph(format!(
                        "{}: output {:?} defined twice",
                        node.name, out
                    )));
                }
                defined[out.0] = true;
            }
        }
        for (name, &v) in &self.outputs {
            if !defined[v.0] {
                return Err(Error::Graph(format!("output '{name}' never produced")));
            }
        }
        // In-place discipline: the state operand (input 0) is overwritten by
        // the node's output, so it must be dead afterwards — no later node
        // may read it and it must not be a named graph output. (Its SSA
        // successor — the node's output — carries the updated state.)
        for (i, node) in self.nodes.iter().enumerate() {
            if !node.in_place() {
                continue;
            }
            if node.inputs.is_empty() || node.outputs.len() != 1 {
                return Err(Error::Graph(format!(
                    "{}: in-place node needs >= 1 input and exactly 1 output",
                    node.name
                )));
            }
            let state = node.inputs[0];
            for later in &self.nodes[i + 1..] {
                if later.inputs.contains(&state) {
                    return Err(Error::Graph(format!(
                        "{}: in-place state {:?} read by later node '{}'",
                        node.name, state, later.name
                    )));
                }
            }
            if let Some((name, _)) = self.outputs.iter().find(|(_, &v)| v == state) {
                return Err(Error::Graph(format!(
                    "{}: in-place state {:?} is graph output '{name}'",
                    node.name, state
                )));
            }
        }
        for name in &self.persistent {
            if !self.inputs.contains_key(name) {
                return Err(Error::Graph(format!(
                    "persistent '{name}' is not a graph input"
                )));
            }
        }
        Ok(())
    }

    /// Kernel names used by this graph (for registry preloading).
    pub fn kernel_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .nodes
            .iter()
            .filter_map(|n| n.kernel().map(str::to_string))
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssa_validation_catches_use_before_def() {
        let mut g = FxGraph::new();
        let dangling = g.new_value(); // never produced, not an input
        g.kernel("bad", "k", Category::Add, vec![dangling]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn ssa_validation_accepts_chain() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "k1", Category::Add, vec![x]);
        let z = g.kernel("b", "k2", Category::Multiply, vec![y, x]);
        g.mark_output("out", z);
        assert!(g.validate().is_ok());
        assert_eq!(g.dispatch_count(), 2);
    }

    #[test]
    fn host_nodes_do_not_dispatch() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        g.host("r", HostOp::FromHeads, Category::Shape, vec![x], 1);
        assert_eq!(g.dispatch_count(), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn in_place_state_must_be_dead_after_update() {
        let mut g = FxGraph::new();
        let cache = g.input("cache");
        let row = g.input("row");
        let updated = g.in_place_kernel("upd", "cache_update_t", Category::Concat, vec![cache, row]);
        // Reading the updated value is fine...
        let y = g.kernel("use", "sdpa_t", Category::Sdpa, vec![updated]);
        g.mark_output("out", y);
        assert!(g.validate().is_ok());
        // ...but reading the stale pre-update value is not.
        let mut bad = g.clone();
        bad.kernel("stale", "k", Category::Other, vec![cache]);
        assert!(bad.validate().is_err());
        // Nor is exposing the stale value as a graph output.
        let mut bad2 = g.clone();
        bad2.mark_output("stale_cache", cache);
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn in_place_nodes_dispatch_and_report_kernels() {
        let mut g = FxGraph::new();
        let c = g.input("c");
        let v = g.in_place_kernel("u", "cache_update_t", Category::Concat, vec![c]);
        g.mark_output("c_next", v);
        assert_eq!(g.dispatch_count(), 1);
        assert_eq!(g.kernel_names(), vec!["cache_update_t".to_string()]);
        assert!(g.nodes[0].in_place());
    }

    #[test]
    fn persistent_inputs_keep_declaration_order() {
        let mut g = FxGraph::new();
        for name in ["l0.k", "l0.v", "l1.k", "l1.v"] {
            g.input(name);
            g.mark_persistent(name);
        }
        g.mark_persistent("l0.k"); // idempotent
        assert_eq!(g.persistent, vec!["l0.k", "l0.v", "l1.k", "l1.v"]);
        assert_eq!(g.persistent_values().len(), 4);
        let mut bad = g.clone();
        bad.persistent.push("ghost".into());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn kernel_names_deduped() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "same", Category::Add, vec![x]);
        g.kernel("b", "same", Category::Add, vec![y]);
        assert_eq!(g.kernel_names(), vec!["same".to_string()]);
    }
}

//! The FX graph container: SSA nodes in execution order, named ports.

use std::collections::HashMap;

use super::node::{Category, HostOp, Node, NodeId, OpKind, ValueId};
use crate::{Error, Result};

#[derive(Debug, Clone)]
pub struct FxGraph {
    pub nodes: Vec<Node>,
    pub n_values: usize,
    /// External inputs (weights, caches, token embedding, pos scalars).
    pub inputs: HashMap<String, ValueId>,
    /// Named outputs (logits, updated caches).
    pub outputs: HashMap<String, ValueId>,
    /// Inputs that are *persistent state* (KV caches): they survive across
    /// decode steps and may be kept device-resident by a planner instead of
    /// being re-uploaded per step. Declaration order is preserved — it
    /// defines the layout of a session's cache set (layer-major for the
    /// decode builder; slot-major-then-layer-major for the batched builder).
    /// Eager executors ignore this and treat them as ordinary per-step
    /// inputs.
    pub persistent: Vec<String>,
    /// Leading batch dimension of the graph's step inputs. `1` for the
    /// ordinary single-session decode graph; `W >= 2` for the batched
    /// decode variant, whose step inputs pack `W` session slots and whose
    /// cache ops gather/scatter across `W` per-slot cache sets in one
    /// dispatch. Validation enforces the batched in-place discipline
    /// (pairwise output-j-aliases-input-j) for every graph; `batch_width`
    /// additionally lets planners check batch-shape consistency.
    pub batch_width: usize,
    /// Leading *sequence* dimension of the graph's step inputs. `1` for
    /// decode-step graphs (one token per replay); `C >= 2` for the chunked
    /// PREFILL variant, whose step inputs pack `C` consecutive prompt
    /// positions of ONE session and whose cache ops scatter `C` rows per
    /// layer per dispatch. Orthogonal to `batch_width` (slots batch across
    /// sessions; chunks batch along one session's sequence).
    pub seq_chunk: usize,
    /// True for paged-KV graphs: per-slot cache sets are replaced by ONE
    /// shared pool plane per (layer, K/V), addressed through per-slot block
    /// tables. Cache ops then update a single state (the plane) regardless
    /// of `batch_width`, so the one-state-per-slot in-place rule becomes a
    /// one-state-per-plane rule.
    pub kv_paged: bool,
}

// Manual Default so `FxGraph::default()` honors the batch_width >= 1
// invariant validate() enforces (a derived default would be 0: malformed).
impl Default for FxGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl FxGraph {
    pub fn new() -> Self {
        FxGraph {
            nodes: Vec::new(),
            n_values: 0,
            inputs: HashMap::new(),
            outputs: HashMap::new(),
            persistent: Vec::new(),
            batch_width: 1,
            seq_chunk: 1,
            kv_paged: false,
        }
    }

    pub fn new_value(&mut self) -> ValueId {
        let v = ValueId(self.n_values);
        self.n_values += 1;
        v
    }

    pub fn input(&mut self, name: &str) -> ValueId {
        if let Some(&v) = self.inputs.get(name) {
            return v;
        }
        let v = self.new_value();
        self.inputs.insert(name.to_string(), v);
        v
    }

    pub fn mark_output(&mut self, name: &str, v: ValueId) {
        self.outputs.insert(name.to_string(), v);
    }

    /// Declare an existing input as persistent state (see [`FxGraph::persistent`]).
    pub fn mark_persistent(&mut self, name: &str) {
        debug_assert!(self.inputs.contains_key(name), "persistent '{name}' is not an input");
        if !self.persistent.iter().any(|n| n == name) {
            self.persistent.push(name.to_string());
        }
    }

    /// Value ids of the persistent inputs, in declaration order.
    pub fn persistent_values(&self) -> Vec<ValueId> {
        self.persistent.iter().map(|n| self.inputs[n]).collect()
    }

    /// Append a kernel node with one output value.
    pub fn kernel(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
    ) -> ValueId {
        let out = self.new_value();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: vec![out],
        });
        out
    }

    /// Append an in-place kernel node: one dispatch whose single output
    /// updates `inputs[0]`'s storage in place (see
    /// [`OpKind::InPlaceKernel`]). SSA-wise the output is a fresh value.
    pub fn in_place_kernel(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
    ) -> ValueId {
        self.in_place_kernel_multi(name, kernel, category, inputs, 1)[0]
    }

    /// Append an in-place kernel node with N outputs: one dispatch where
    /// output `j` updates `inputs[j]`'s storage in place, for every
    /// `j < n_out` (the batched cache-update shape: W per-slot cache
    /// states followed by the packed rows and per-slot uniforms). SSA-wise
    /// every output is a fresh value.
    pub fn in_place_kernel_multi(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::InPlaceKernel(kernel.to_string()),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Append a kernel node with N output values.
    pub fn kernel_multi(
        &mut self,
        name: &str,
        kernel: &str,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Kernel(kernel.to_string()),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Append a host (non-dispatch) node.
    pub fn host(
        &mut self,
        name: &str,
        op: HostOp,
        category: Category,
        inputs: Vec<ValueId>,
        n_out: usize,
    ) -> Vec<ValueId> {
        let outs: Vec<ValueId> = (0..n_out).map(|_| self.new_value()).collect();
        self.nodes.push(Node {
            id: NodeId(self.nodes.len()),
            name: name.to_string(),
            op: OpKind::Host(op),
            category,
            inputs,
            outputs: outs.clone(),
        });
        outs
    }

    /// Number of nodes that become WebGPU dispatches.
    pub fn dispatch_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.dispatches()).count()
    }

    /// Per-category node counts.
    pub fn category_counts(&self) -> HashMap<Category, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.category).or_insert(0) += 1;
        }
        m
    }

    /// SSA validation: every node input must be an external input or a
    /// value produced by an earlier node; every output defined exactly once.
    pub fn validate(&self) -> Result<()> {
        let mut defined = vec![false; self.n_values];
        for &v in self.inputs.values() {
            defined[v.0] = true;
        }
        for node in &self.nodes {
            for &inp in &node.inputs {
                if inp.0 >= self.n_values {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} out of range",
                        node.name, inp
                    )));
                }
                if !defined[inp.0] {
                    return Err(Error::Graph(format!(
                        "{}: input {:?} used before definition",
                        node.name, inp
                    )));
                }
            }
            for &out in &node.outputs {
                if defined[out.0] {
                    return Err(Error::Graph(format!(
                        "{}: output {:?} defined twice",
                        node.name, out
                    )));
                }
                defined[out.0] = true;
            }
        }
        for (name, &v) in &self.outputs {
            if !defined[v.0] {
                return Err(Error::Graph(format!("output '{name}' never produced")));
            }
        }
        // In-place discipline, pairwise: output `j` overwrites input `j`'s
        // storage, so every state operand (inputs 0..n_out) must be dead
        // afterwards — no later node may read it and it must not be a named
        // graph output. (Its SSA successor — output `j` — carries the
        // updated state.) The single-output cache_update is the n_out = 1
        // case; the batched cache_update updates W per-slot states at once.
        for (i, node) in self.nodes.iter().enumerate() {
            if !node.in_place() {
                continue;
            }
            let n_out = node.outputs.len();
            if n_out == 0 || node.inputs.len() < n_out {
                return Err(Error::Graph(format!(
                    "{}: in-place node needs >= 1 output and one state input per output",
                    node.name
                )));
            }
            for &state in &node.inputs[..n_out] {
                for later in &self.nodes[i + 1..] {
                    if later.inputs.contains(&state) {
                        return Err(Error::Graph(format!(
                            "{}: in-place state {:?} read by later node '{}'",
                            node.name, state, later.name
                        )));
                    }
                }
                if let Some((name, _)) = self.outputs.iter().find(|(_, &v)| v == state) {
                    return Err(Error::Graph(format!(
                        "{}: in-place state {:?} is graph output '{name}'",
                        node.name, state
                    )));
                }
            }
        }
        // Batch-shape consistency: a batched graph declares a uniform slot
        // width; its batched in-place cache ops must update one state per
        // slot (exactly `batch_width` outputs).
        if self.batch_width == 0 {
            return Err(Error::Graph("batch_width must be >= 1".into()));
        }
        if self.seq_chunk == 0 {
            return Err(Error::Graph("seq_chunk must be >= 1".into()));
        }
        // batch_width > 1 && seq_chunk > 1 is the UNIFIED round graph:
        // step inputs pack W slots x C sequence positions ([W*C, ...] rows
        // plus per-slot uniforms), and the in-place rule below still holds
        // — one state output per SLOT, positions share the slot's scatter.
        // Paged graphs scatter every slot through ONE shared plane: their
        // in-place nodes always carry exactly one state output, whatever
        // the slot width.
        if self.kv_paged {
            for node in &self.nodes {
                if node.in_place() && node.outputs.len() != 1 {
                    return Err(Error::Graph(format!(
                        "{}: paged in-place node has {} outputs, expected 1 (the pool plane)",
                        node.name,
                        node.outputs.len()
                    )));
                }
            }
        } else if self.batch_width > 1 {
            for node in &self.nodes {
                if node.in_place() && node.outputs.len() != self.batch_width {
                    return Err(Error::Graph(format!(
                        "{}: batched in-place node has {} outputs, batch width is {}",
                        node.name,
                        node.outputs.len(),
                        self.batch_width
                    )));
                }
            }
        }
        for name in &self.persistent {
            if !self.inputs.contains_key(name) {
                return Err(Error::Graph(format!(
                    "persistent '{name}' is not a graph input"
                )));
            }
        }
        Ok(())
    }

    /// Kernel names used by this graph (for registry preloading).
    pub fn kernel_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .nodes
            .iter()
            .filter_map(|n| n.kernel().map(str::to_string))
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssa_validation_catches_use_before_def() {
        let mut g = FxGraph::new();
        let dangling = g.new_value(); // never produced, not an input
        g.kernel("bad", "k", Category::Add, vec![dangling]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn ssa_validation_accepts_chain() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "k1", Category::Add, vec![x]);
        let z = g.kernel("b", "k2", Category::Multiply, vec![y, x]);
        g.mark_output("out", z);
        assert!(g.validate().is_ok());
        assert_eq!(g.dispatch_count(), 2);
    }

    #[test]
    fn host_nodes_do_not_dispatch() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        g.host("r", HostOp::FromHeads, Category::Shape, vec![x], 1);
        assert_eq!(g.dispatch_count(), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn in_place_state_must_be_dead_after_update() {
        let mut g = FxGraph::new();
        let cache = g.input("cache");
        let row = g.input("row");
        let updated = g.in_place_kernel("upd", "cache_update_t", Category::Concat, vec![cache, row]);
        // Reading the updated value is fine...
        let y = g.kernel("use", "sdpa_t", Category::Sdpa, vec![updated]);
        g.mark_output("out", y);
        assert!(g.validate().is_ok());
        // ...but reading the stale pre-update value is not.
        let mut bad = g.clone();
        bad.kernel("stale", "k", Category::Other, vec![cache]);
        assert!(bad.validate().is_err());
        // Nor is exposing the stale value as a graph output.
        let mut bad2 = g.clone();
        bad2.mark_output("stale_cache", cache);
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn in_place_nodes_dispatch_and_report_kernels() {
        let mut g = FxGraph::new();
        let c = g.input("c");
        let v = g.in_place_kernel("u", "cache_update_t", Category::Concat, vec![c]);
        g.mark_output("c_next", v);
        assert_eq!(g.dispatch_count(), 1);
        assert_eq!(g.kernel_names(), vec!["cache_update_t".to_string()]);
        assert!(g.nodes[0].in_place());
    }

    #[test]
    fn multi_output_in_place_pairwise_discipline() {
        // Output j aliases input j: every state operand must be dead after.
        let mut g = FxGraph::new();
        let c0 = g.input("c0");
        let c1 = g.input("c1");
        let rows = g.input("rows");
        let outs = g.in_place_kernel_multi(
            "upd", "cache_update_b2_t", Category::Concat, vec![c0, c1, rows], 2,
        );
        let y = g.kernel("use", "sdpa_b2_t", Category::Sdpa, vec![outs[0], outs[1]]);
        g.mark_output("out", y);
        assert!(g.validate().is_ok());
        assert_eq!(g.dispatch_count(), 2);
        // Reading either stale state afterwards breaks the discipline.
        for stale in [c0, c1] {
            let mut bad = g.clone();
            bad.kernel("stale", "k", Category::Other, vec![stale]);
            assert!(bad.validate().is_err(), "{stale:?}");
        }
        // Fewer state inputs than outputs is malformed.
        let mut bad = FxGraph::new();
        let c = bad.input("c");
        bad.in_place_kernel_multi("u", "k", Category::Concat, vec![c], 2);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn batched_graphs_require_one_state_per_slot() {
        let mut g = FxGraph::new();
        g.batch_width = 3;
        let c0 = g.input("c0");
        let c1 = g.input("c1");
        // 2 outputs on a width-3 graph: batch-shape inconsistency.
        let outs = g.in_place_kernel_multi("u", "k", Category::Concat, vec![c0, c1], 2);
        g.mark_output("o", outs[0]);
        g.mark_output("o2", outs[1]);
        assert!(g.validate().is_err());
        g.batch_width = 2;
        assert!(g.validate().is_ok());
        g.batch_width = 0;
        assert!(g.validate().is_err(), "zero width is malformed");
    }

    #[test]
    fn seq_chunk_validation() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "k1", Category::Add, vec![x]);
        g.mark_output("out", y);
        g.seq_chunk = 16;
        assert!(g.validate().is_ok());
        g.seq_chunk = 0;
        assert!(g.validate().is_err(), "zero chunk is malformed");
        // Slot batching and sequence chunking COMPOSE (the unified round
        // graph batches both); the in-place one-state-per-slot discipline
        // still applies to the combined shape.
        g.seq_chunk = 8;
        g.batch_width = 4;
        assert!(g.validate().is_ok(), "unified seq x batch graphs must validate");
    }

    #[test]
    fn persistent_inputs_keep_declaration_order() {
        let mut g = FxGraph::new();
        for name in ["l0.k", "l0.v", "l1.k", "l1.v"] {
            g.input(name);
            g.mark_persistent(name);
        }
        g.mark_persistent("l0.k"); // idempotent
        assert_eq!(g.persistent, vec!["l0.k", "l0.v", "l1.k", "l1.v"]);
        assert_eq!(g.persistent_values().len(), 4);
        let mut bad = g.clone();
        bad.persistent.push("ghost".into());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn kernel_names_deduped() {
        let mut g = FxGraph::new();
        let x = g.input("x");
        let y = g.kernel("a", "same", Category::Add, vec![x]);
        g.kernel("b", "same", Category::Add, vec![y]);
        assert_eq!(g.kernel_names(), vec!["same".to_string()]);
    }
}

//! FX-style op graphs: the torch.compile IR analogue torch-webgpu consumes.
//!
//! Two roles:
//!
//! 1. **Executable graphs** (`builder`): the per-decode-step op stream for a
//!    config whose kernels exist in `artifacts/` (qwen-tiny). Each compute
//!    node names an AOT kernel and becomes one WebGPU dispatch; shape ops
//!    are host ops and dispatch nothing (the paper's 241-shape-op point).
//! 2. **Census** (`census`): the structural node count of the Qwen2.5-0.5B /
//!    1.5B graphs — reproduces Table 10's 876 compute ops / 1,911 total
//!    nodes, which every overhead table depends on.
//!
//! `fusion` implements the paper's three fusion passes as real
//! pattern-matching graph rewrites (RMSNorm 6->1, MLP gate+up+silu -> 1,
//! K+V -> 1) plus the rotary fusion, with the paper's dispatch arithmetic
//! exposed separately for the tables.

pub mod builder;
pub mod census;
pub mod fusion;
pub mod graph;
pub mod node;
pub mod passes;
pub mod workloads;

pub use builder::{
    build_batched_decode_graph, build_batched_decode_graph_paged, build_decode_graph,
    build_decode_graph_paged, build_prefill_graph, build_prefill_graph_multi_row,
    build_prefill_graph_multi_row_paged, build_prefill_graph_paged,
    build_unified_round_graph, build_unified_round_graph_multi_row,
    build_unified_round_graph_multi_row_paged, build_unified_round_graph_paged,
    paged_pool_rows, paged_table_len, FusionConfig, GraphDims, KV_BLOCKS, KV_BLOCK_MIN,
    MAX_BATCH_WIDTH, PREFILL_CHUNKS,
};
pub use census::{Census, CategoryCounts};
pub use graph::FxGraph;
pub use node::{Category, HostOp, Node, NodeId, ValueId};
pub use passes::{PassManager, PassReport};

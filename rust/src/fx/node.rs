//! Graph nodes: SSA ops over value ids.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub usize);

/// FX census category (Table 10's rows plus the non-compute classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Linear projections (matmul).
    Linear,
    Multiply,
    Add,
    Sdpa,
    Silu,
    /// pow / mean / rsqrt (the RMSNorm decomposition's non-mul/add pieces).
    RmsComponent,
    /// KV-cache appends + rotate-half concats.
    Concat,
    /// neg, embedding, index, trig — the census's "Other" bucket.
    Other,
    /// view/reshape/slice — no dispatch required.
    Shape,
}

impl Category {
    /// Compute categories potentially become WebGPU dispatches.
    pub fn is_compute(self) -> bool {
        !matches!(self, Category::Shape)
    }
}

/// Host-side (non-dispatch) operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostOp {
    /// table[token] -> [1, H]
    Embed,
    /// [1, 2k] -> ([1, k], [1, k])
    SplitKv,
    /// [1, h*d] -> [h, d]
    ToHeads { heads: usize, head_dim: usize },
    /// [h, d] -> [1, h*d]
    FromHeads,
    /// [h, 2k] -> ([h, k], [h, k])
    Halves,
}

/// The executable body of a node.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// One WebGPU dispatch running the named AOT kernel.
    Kernel(String),
    /// One WebGPU dispatch whose *output `j` updates input `j`'s storage
    /// in place* (pairwise, for every output): the SSA outputs are fresh
    /// values, but executors may bind each output to its state input's
    /// buffer instead of materializing copies. The single-output form is
    /// how KV-cache appends stay device-resident in planned mode; the
    /// multi-output form is the BATCHED cache append, one state per batch
    /// slot. Eager mode executes it exactly like [`OpKind::Kernel`].
    /// Every state operand must be dead after this node (checked by
    /// [`super::graph::FxGraph::validate`]).
    InPlaceKernel(String),
    /// Host/metadata op — no dispatch.
    Host(HostOp),
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Human-readable name, e.g. "l2.norm1.pow".
    pub name: String,
    pub op: OpKind,
    pub category: Category,
    pub inputs: Vec<ValueId>,
    pub outputs: Vec<ValueId>,
}

impl Node {
    pub fn dispatches(&self) -> bool {
        matches!(self.op, OpKind::Kernel(_) | OpKind::InPlaceKernel(_))
    }

    pub fn kernel(&self) -> Option<&str> {
        match &self.op {
            OpKind::Kernel(k) | OpKind::InPlaceKernel(k) => Some(k),
            OpKind::Host(_) => None,
        }
    }

    /// True when output `j` updates input `j`'s storage in place (for
    /// every output — see [`OpKind::InPlaceKernel`]).
    pub fn in_place(&self) -> bool {
        matches!(self.op, OpKind::InPlaceKernel(_))
    }
}

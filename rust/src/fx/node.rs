//! Graph nodes: SSA ops over value ids.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub usize);

/// FX census category (Table 10's rows plus the non-compute classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Linear projections (matmul).
    Linear,
    Multiply,
    Add,
    Sdpa,
    Silu,
    /// pow / mean / rsqrt (the RMSNorm decomposition's non-mul/add pieces).
    RmsComponent,
    /// KV-cache appends + rotate-half concats.
    Concat,
    /// neg, embedding, index, trig — the census's "Other" bucket.
    Other,
    /// view/reshape/slice — no dispatch required.
    Shape,
}

impl Category {
    /// Compute categories potentially become WebGPU dispatches.
    pub fn is_compute(self) -> bool {
        !matches!(self, Category::Shape)
    }
}

/// Host-side (non-dispatch) operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostOp {
    /// table[token] -> [1, H]
    Embed,
    /// [1, 2k] -> ([1, k], [1, k])
    SplitKv,
    /// [1, h*d] -> [h, d]
    ToHeads { heads: usize, head_dim: usize },
    /// [h, d] -> [1, h*d]
    FromHeads,
    /// [h, 2k] -> ([h, k], [h, k])
    Halves,
}

/// The executable body of a node.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// One WebGPU dispatch running the named AOT kernel.
    Kernel(String),
    /// One WebGPU dispatch whose *first output updates the first input's
    /// storage in place*: the SSA output is a fresh value (validation is
    /// unchanged), but executors may bind output 0 to input 0's buffer
    /// instead of materializing a copy. This is how KV-cache appends stay
    /// device-resident in planned mode; eager mode executes it exactly
    /// like [`OpKind::Kernel`]. The state operand must be dead after this
    /// node (checked by [`super::graph::FxGraph::validate`]).
    InPlaceKernel(String),
    /// Host/metadata op — no dispatch.
    Host(HostOp),
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Human-readable name, e.g. "l2.norm1.pow".
    pub name: String,
    pub op: OpKind,
    pub category: Category,
    pub inputs: Vec<ValueId>,
    pub outputs: Vec<ValueId>,
}

impl Node {
    pub fn dispatches(&self) -> bool {
        matches!(self.op, OpKind::Kernel(_) | OpKind::InPlaceKernel(_))
    }

    pub fn kernel(&self) -> Option<&str> {
        match &self.op {
            OpKind::Kernel(k) | OpKind::InPlaceKernel(k) => Some(k),
            OpKind::Host(_) => None,
        }
    }

    /// True when output 0 updates input 0's storage in place.
    pub fn in_place(&self) -> bool {
        matches!(self.op, OpKind::InPlaceKernel(_))
    }
}

//! PassManager: a named, validated graph-rewrite pipeline.
//!
//! Generalizes the four hand-chained `fuse_*` calls into a registry of
//! passes run in order, with SSA validation after every pass (a broken
//! rewrite fails at the pass that broke it, not downstream in the
//! executor) and a per-pass dispatch-savings report. This is the front
//! half of the compile pipeline: `build graph -> PassManager -> Planner`.

use super::builder::FusionConfig;
use super::fusion;
use super::graph::FxGraph;
use crate::{Error, Result};

/// What one pass did to the graph.
#[derive(Debug, Clone)]
pub struct PassReport {
    pub name: String,
    pub dispatches_before: usize,
    pub dispatches_after: usize,
}

impl PassReport {
    pub fn saved(&self) -> usize {
        self.dispatches_before.saturating_sub(self.dispatches_after)
    }
}

type PassFn = Box<dyn Fn(&FxGraph) -> FxGraph>;

#[derive(Default)]
pub struct PassManager {
    passes: Vec<(String, PassFn)>,
}

impl PassManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a pass; passes run in registration order.
    pub fn add<F>(&mut self, name: &str, pass: F) -> &mut Self
    where
        F: Fn(&FxGraph) -> FxGraph + 'static,
    {
        self.passes.push((name.to_string(), Box::new(pass)));
        self
    }

    /// The canonical fusion pipeline for a [`FusionConfig`], in the same
    /// order the hand-chained `fuse_all` applied: rmsnorm, mlp, kv,
    /// rotary. `suffix` selects the per-config fused-kernel names.
    pub fn for_fusion(cfg: FusionConfig, suffix: &str) -> Self {
        let mut pm = Self::new();
        if cfg.rmsnorm {
            pm.add("fuse_rmsnorm", fusion::fuse_rmsnorm);
        }
        if cfg.mlp {
            let s = suffix.to_string();
            pm.add("fuse_mlp", move |g| fusion::fuse_mlp(g, &s));
        }
        if cfg.kv {
            pm.add("fuse_kv", fusion::fuse_kv);
        }
        if cfg.rotary {
            pm.add("fuse_rotary", fusion::fuse_rotary);
        }
        pm
    }

    pub fn names(&self) -> Vec<&str> {
        self.passes.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.passes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Run every pass in order, validating SSA after each; returns the
    /// rewritten graph plus per-pass reports.
    pub fn run(&self, graph: &FxGraph) -> Result<(FxGraph, Vec<PassReport>)> {
        let mut cur = graph.clone();
        let mut reports = Vec::with_capacity(self.passes.len());
        for (name, pass) in &self.passes {
            let before = cur.dispatch_count();
            let next = pass(&cur);
            next.validate().map_err(|e| {
                Error::Graph(format!("pass '{name}' produced an invalid graph: {e}"))
            })?;
            reports.push(PassReport {
                name: name.clone(),
                dispatches_before: before,
                dispatches_after: next.dispatch_count(),
            });
            cur = next;
        }
        Ok((cur, reports))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::builder::{build_decode_graph, GraphDims};
    use crate::fx::node::{Category, NodeId, OpKind, ValueId};

    #[test]
    fn for_fusion_matches_hand_chained_fuse_all() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let (by_pm, reports) = PassManager::for_fusion(FusionConfig::fused(), "tiny")
            .run(&g)
            .unwrap();
        let direct = build_decode_graph(&dims, FusionConfig::fused());
        assert_eq!(by_pm.dispatch_count(), direct.dispatch_count());
        assert_eq!(by_pm.kernel_names(), direct.kernel_names());
        assert_eq!(reports.len(), 4);
        assert!(reports.iter().all(|r| r.saved() > 0), "{reports:?}");
        // Savings compose: sum of per-pass savings equals the total.
        let total: usize = reports.iter().map(PassReport::saved).sum();
        assert_eq!(total, g.dispatch_count() - by_pm.dispatch_count());
    }

    #[test]
    fn partial_configs_register_matching_passes() {
        let pm = PassManager::for_fusion(FusionConfig::rmsnorm_mlp(), "tiny");
        assert_eq!(pm.names(), vec!["fuse_rmsnorm", "fuse_mlp"]);
        let pm = PassManager::for_fusion(FusionConfig::unfused(), "tiny");
        assert!(pm.is_empty());
    }

    #[test]
    fn broken_pass_fails_at_the_pass_not_downstream() {
        // A "pass" that emits a use-before-def graph must be caught by the
        // post-pass validation with the pass's name in the error.
        let mut pm = PassManager::new();
        pm.add("break_ssa", |g| {
            let mut out = g.clone();
            let dangling = ValueId(out.n_values);
            out.n_values += 1;
            out.nodes.push(crate::fx::node::Node {
                id: NodeId(out.nodes.len()),
                name: "bad".into(),
                op: OpKind::Kernel("k".into()),
                category: Category::Other,
                inputs: vec![dangling],
                outputs: vec![],
            });
            out
        });
        let g = build_decode_graph(&GraphDims::qwen_tiny(), FusionConfig::fused());
        let err = pm.run(&g).unwrap_err();
        assert!(format!("{err}").contains("break_ssa"), "{err}");
    }

    #[test]
    fn empty_manager_is_identity() {
        let g = build_decode_graph(&GraphDims::qwen_tiny(), FusionConfig::fused());
        let (out, reports) = PassManager::new().run(&g).unwrap();
        assert_eq!(out.dispatch_count(), g.dispatch_count());
        assert!(reports.is_empty());
    }
}

//! Non-LLM dispatch workloads: CNN / ViT / U-Net op streams (the paper's
//! exp9/exp11/exp13 — Table 1's footnote: "all show 24-58 us, consistent
//! with LLM results").
//!
//! Dispatch overhead is architecture-independent: these generators produce
//! each architecture's per-forward dispatch census so the profiler can
//! replay them through any implementation profile and confirm the same
//! per-dispatch band the LLM stream shows.

use super::builder::GraphDims;

/// One *executable* decode workload: a dims variant whose kernels all
/// exist in the built-in manifest (tiny kernels are layer-count-agnostic,
/// so varying `layers` yields distinct graph shapes that still execute
/// hermetically). `wdb plan-bench` and the plan-parity property tests
/// sweep these x {fused, unfused} x session counts.
#[derive(Debug, Clone)]
pub struct DecodeWorkload {
    pub name: &'static str,
    pub dims: GraphDims,
}

/// The executable decode-workload sweep.
pub fn decode_workloads() -> Vec<DecodeWorkload> {
    let tiny = GraphDims::qwen_tiny();
    vec![
        DecodeWorkload { name: "qwen-tiny-l1", dims: GraphDims { layers: 1, ..tiny } },
        DecodeWorkload { name: "qwen-tiny-l2", dims: GraphDims { layers: 2, ..tiny } },
        DecodeWorkload { name: "qwen-tiny", dims: tiny },
    ]
}

/// One executable BATCHED decode workload: a dims variant plus the slot
/// width its batched graph packs. Unit tests sweep these to exercise
/// batch widths (graph build, planning, kernel coverage) without standing
/// up the serving engine.
#[derive(Debug, Clone)]
pub struct BatchedDecodeWorkload {
    pub name: &'static str,
    pub dims: GraphDims,
    pub width: usize,
}

/// The executable batched decode-workload sweep: tiny dims x the widths
/// the property tests and the serving default use.
pub fn batched_decode_workloads() -> Vec<BatchedDecodeWorkload> {
    let tiny = GraphDims::qwen_tiny();
    vec![
        BatchedDecodeWorkload { name: "qwen-tiny-b2", dims: tiny, width: 2 },
        BatchedDecodeWorkload { name: "qwen-tiny-b3", dims: tiny, width: 3 },
        BatchedDecodeWorkload { name: "qwen-tiny-b4", dims: tiny, width: 4 },
        BatchedDecodeWorkload {
            name: "qwen-tiny-l2-b4",
            dims: GraphDims { layers: 2, ..tiny },
            width: 4,
        },
    ]
}

/// One synthetic workload: name + dispatches per forward pass, by category.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    /// (op kind, dispatches per forward).
    pub ops: Vec<(&'static str, usize)>,
}

impl Workload {
    pub fn total_dispatches(&self) -> usize {
        self.ops.iter().map(|(_, n)| n).sum()
    }

    /// ResNet-50-shaped stream: 53 convs + batchnorm + relu + adds.
    pub fn cnn_resnet50() -> Self {
        Workload {
            name: "CNN (ResNet-50)",
            ops: vec![
                ("conv", 53),
                ("batchnorm", 53),
                ("relu", 49),
                ("residual_add", 16),
                ("pool", 2),
                ("fc", 1),
            ],
        }
    }

    /// ViT-B/16-shaped stream: 12 encoder blocks, unfused norms/attention.
    pub fn vit_b16() -> Self {
        Workload {
            name: "ViT-B/16",
            ops: vec![
                ("patch_embed", 1),
                ("layernorm", 25),    // 2 per block + final
                ("qkv_proj", 36),     // 3 per block
                ("attention", 12),
                ("attn_out_proj", 12),
                ("mlp_fc", 24),       // 2 per block
                ("gelu", 12),
                ("residual_add", 24),
                ("head", 1),
            ],
        }
    }

    /// U-Net-shaped stream: 4 down + 4 up stages, double convs + skips.
    pub fn unet() -> Self {
        Workload {
            name: "U-Net",
            ops: vec![
                ("conv", 23),
                ("batchnorm", 23),
                ("relu", 23),
                ("downsample", 4),
                ("upsample", 4),
                ("skip_concat", 4),
                ("head", 1),
            ],
        }
    }

    pub fn all() -> Vec<Workload> {
        vec![Self::cnn_resnet50(), Self::vit_b16(), Self::unet()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::measure_dispatch_overhead;
    use crate::webgpu::ImplementationProfile;

    #[test]
    fn decode_workloads_build_executable_graphs() {
        use crate::fx::builder::{build_decode_graph, FusionConfig};
        let reg = crate::runtime::Registry::builtin().unwrap();
        for wl in decode_workloads() {
            for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                let g = build_decode_graph(&wl.dims, fusion);
                g.validate().unwrap();
                for name in g.kernel_names() {
                    assert!(
                        reg.kernels.contains_key(&name),
                        "{}: kernel '{name}' not in builtin manifest",
                        wl.name
                    );
                }
            }
        }
    }

    #[test]
    fn batched_decode_workloads_build_executable_graphs() {
        use crate::fx::builder::{build_batched_decode_graph, FusionConfig};
        let reg = crate::runtime::Registry::builtin().unwrap();
        for wl in batched_decode_workloads() {
            for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                let g = build_batched_decode_graph(&wl.dims, fusion, wl.width);
                g.validate().unwrap();
                assert_eq!(g.batch_width, wl.width, "{}", wl.name);
                for name in g.kernel_names() {
                    assert!(
                        reg.kernels.contains_key(&name),
                        "{}: kernel '{name}' not in builtin manifest",
                        wl.name
                    );
                }
            }
        }
    }

    #[test]
    fn dispatch_counts_are_architecture_shaped() {
        assert_eq!(Workload::cnn_resnet50().total_dispatches(), 174);
        assert_eq!(Workload::vit_b16().total_dispatches(), 147);
        assert_eq!(Workload::unet().total_dispatches(), 82);
    }

    #[test]
    fn per_dispatch_cost_is_architecture_independent() {
        // The paper's footnote: CNN/ViT/U-Net dispatch overhead sits in the
        // same 24-58 us band as the LLM stream. Replay each workload's
        // dispatch count through the desktop/laptop profiles.
        for wl in Workload::all() {
            for (profile, lo, hi) in [
                (ImplementationProfile::dawn_vulkan_rtx5090(), 20.0, 30.0),
                (ImplementationProfile::wgpu_vulkan_rtx5090(), 30.0, 42.0),
                (ImplementationProfile::chrome_d3d12_rtx2000(), 50.0, 65.0),
            ] {
                let m = measure_dispatch_overhead(profile, wl.total_dispatches()).unwrap();
                assert!(
                    m.sequential_us > lo && m.sequential_us < hi,
                    "{}: {} us outside [{lo}, {hi}]",
                    wl.name,
                    m.sequential_us
                );
            }
        }
    }
}

//! # wdb — WebGPU dispatch-overhead characterization stack
//!
//! Reproduction of *"Characterizing WebGPU Dispatch Overhead for LLM
//! Inference Across Four GPU Vendors, Three Backends, and Three Browsers"*
//! (Maczan, 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (build time): Pallas kernels in `python/compile/kernels/`,
//!   AOT-lowered to HLO text artifacts.
//! - **L2** (build time): the Qwen2.5-architecture forward pass in JAX
//!   (`python/compile/model.py`), fused and unfused op flows.
//! - **L3** (this crate): the coordinator — a WebGPU-shaped dispatch
//!   substrate with real per-call validation and calibrated per-backend
//!   cost profiles, a kernel runtime that executes the AOT kernels (PJRT
//!   with `--features pjrt`, a pure-Rust reference interpreter otherwise),
//!   an FX-style op graph with the paper's fusion passes, an
//!   autoregressive inference engine, a **multi-session serving engine**
//!   ([`serve`]) that interleaves concurrent decode streams over one
//!   shared substrate, a **compile-once execution-plan pipeline**
//!   ([`plan`]: Planner -> ExecutionPlan -> PlanRunner, with
//!   device-resident values and buffer-lifetime aliasing), and the
//!   benchmark harness that regenerates every table in the paper plus the
//!   serving-scaling (S1/S2) and eager-vs-planned (P1) tables.
//!
//! Python never runs on the request path: with artifacts the `wdb` binary
//! is self-contained, and without them the built-in manifest + host
//! reference runtime keep the whole stack (tests, benches, `serve-bench`)
//! hermetic.
//!
//! ## Serving
//!
//! [`serve::ServingEngine`] owns one device, one prepared-pipeline cache,
//! one buffer pool and one pinned copy of the weights, and round-robins
//! decode steps across up to `max_concurrent` sessions with FIFO admission
//! beyond that. Fixed per-step synchronization cost is paid once per round
//! (coalesced readback) instead of once per session, and in the planned
//! serving default rounds with >= 2 active sessions replay a BATCHED plan
//! (`fx::build_batched_decode_graph` + `plan::BatchedRunner`): one
//! dispatch per layer op covers a whole chunk of sessions, so the
//! per-dispatch + framework overheads the paper shows interleaving cannot
//! amortize fall by the batch factor (Appendix F). See
//! `rust/src/serve/mod.rs` for the scheduling model and `wdb serve-bench`
//! for the scaling table (`disp/round` column + batched-vs-interleaved
//! gate).

pub mod baselines;
pub mod cli;
pub mod crossover;
pub mod engine;
pub mod error;
pub mod fx;
pub mod model;
pub mod plan;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tables;
pub mod tensor;
pub mod trace;
pub mod webgpu;

pub use error::{Error, Result};

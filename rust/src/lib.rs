//! # wdb — WebGPU dispatch-overhead characterization stack
//!
//! Reproduction of *"Characterizing WebGPU Dispatch Overhead for LLM
//! Inference Across Four GPU Vendors, Three Backends, and Three Browsers"*
//! (Maczan, 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (build time): Pallas kernels in `python/compile/kernels/`,
//!   AOT-lowered to HLO text artifacts.
//! - **L2** (build time): the Qwen2.5-architecture forward pass in JAX
//!   (`python/compile/model.py`), fused and unfused op flows.
//! - **L3** (this crate): the coordinator — a WebGPU-shaped dispatch
//!   substrate with real per-call validation and calibrated per-backend
//!   cost profiles, a PJRT runtime that executes the AOT kernels, an
//!   FX-style op graph with the paper's fusion passes, an autoregressive
//!   inference engine, and the benchmark harness that regenerates every
//!   table in the paper.
//!
//! Python never runs on the request path: after `make artifacts` the `wdb`
//! binary is self-contained.

pub mod baselines;
pub mod cli;
pub mod crossover;
pub mod engine;
pub mod error;
pub mod fx;
pub mod model;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod stats;
pub mod tables;
pub mod tensor;
pub mod webgpu;

pub use error::{Error, Result};

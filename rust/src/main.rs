//! `wdb` — the L3 coordinator binary.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = wdb::cli::parse_args(&argv);
    if let Err(e) = wdb::cli::run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

//! Model-side utilities: deterministic synthetic weights, the byte-level
//! tokenizer, and the seeded RNG shared by weight init and tests.

pub mod rng;
pub mod tokenizer;
pub mod weights;

pub use rng::XorShiftRng;
pub use tokenizer::ByteTokenizer;
pub use weights::ModelWeights;

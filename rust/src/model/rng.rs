//! Seeded xorshift64* RNG with a Box-Muller normal sampler — no external
//! rand crates in the offline build, and determinism is required anyway
//! (synthetic weights must be reproducible across runs for EXPERIMENTS.md).

#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
    spare: Option<f64>,
}

impl XorShiftRng {
    pub fn new(seed: u64) -> Self {
        XorShiftRng { state: seed.max(1), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShiftRng::new(9);
        let mut b = XorShiftRng::new(9);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = XorShiftRng::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShiftRng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..100 {
            assert!(r.below(10) < 10);
        }
    }
}

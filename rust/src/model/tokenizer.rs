//! Byte-level toy tokenizer.
//!
//! The paper's throughput characterization is content-independent (synthetic
//! weights produce arbitrary-but-deterministic token streams); what matters
//! is the *op stream per token*. A byte tokenizer keeps prompts real
//! ("The capital of France is", §3.3) without shipping a BPE vocab.

#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab >= 256, "byte tokenizer needs vocab >= 256, got {vocab}");
        ByteTokenizer { vocab }
    }

    pub fn encode(&self, text: &str) -> Vec<usize> {
        text.bytes().map(|b| b as usize).collect()
    }

    pub fn decode(&self, tokens: &[usize]) -> String {
        tokens
            .iter()
            .map(|&t| if t < 256 { t as u8 as char } else { '\u{fffd}' })
            .collect()
    }

    /// The paper's benchmark prompt.
    pub fn paper_prompt(&self) -> Vec<usize> {
        // 5-token analogue: first 5 bytes of the paper's prompt.
        self.encode("The capital of France is")[..5].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new(512);
        let ids = t.encode("hello");
        assert_eq!(ids, vec![104, 101, 108, 108, 111]);
        assert_eq!(t.decode(&ids), "hello");
    }

    #[test]
    fn paper_prompt_is_five_tokens() {
        let t = ByteTokenizer::new(512);
        assert_eq!(t.paper_prompt().len(), 5);
    }

    #[test]
    #[should_panic(expected = "vocab >= 256")]
    fn rejects_tiny_vocab() {
        ByteTokenizer::new(100);
    }

    #[test]
    fn out_of_range_decodes_replacement() {
        let t = ByteTokenizer::new(512);
        assert_eq!(t.decode(&[400]), "\u{fffd}");
    }
}

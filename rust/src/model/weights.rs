//! Deterministic synthetic weights for a Qwen2.5-architecture config.
//!
//! The paper's overhead characterization is weight-independent ("dtype-
//! independent and API-inherent", §11); we only need *some* deterministic
//! float32 weights so the decode loop produces a stable token stream and
//! the fused/unfused flows can be compared bit-for-bit. Scales follow the
//! usual 1/sqrt(fan_in) so activations stay well-conditioned over layers.

use std::collections::HashMap;

use super::rng::XorShiftRng;
use crate::fx::builder::GraphDims;
use crate::tensor::Tensor;

#[derive(Debug)]
pub struct ModelWeights {
    /// Graph input name -> tensor (everything `build_decode_graph` expects
    /// except the per-step x/pos/caches).
    pub by_name: HashMap<String, Tensor>,
    /// Token embedding table [V, H] (host-side gather source).
    pub embedding: Tensor,
    /// Rope inverse frequencies [D/2].
    pub inv_freq: Tensor,
    pub dims: GraphDims,
}

fn normal(rng: &mut XorShiftRng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::f32(shape, rng.normal_vec_f32(n, scale)).expect("shape/data agree")
}

fn norm_weight(rng: &mut XorShiftRng, h: usize) -> Tensor {
    let data: Vec<f32> = (0..h)
        .map(|_| 0.5 + rng.uniform_in(0.0, 1.0) as f32)
        .collect();
    Tensor::f32(vec![h], data).expect("shape/data agree")
}

impl ModelWeights {
    pub fn synthesize(dims: &GraphDims, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let (h, qd, kv, inter, v) =
            (dims.hidden, dims.q_dim(), dims.kv_dim(), dims.intermediate, dims.vocab);
        let s_h = 1.0 / (h as f32).sqrt();
        let s_i = 1.0 / (inter as f32).sqrt();
        let s_q = 1.0 / (qd as f32).sqrt();

        let mut by_name = HashMap::new();
        for l in 0..dims.layers {
            let p = format!("l{l}");
            by_name.insert(format!("{p}.norm1"), norm_weight(&mut rng, h));
            by_name.insert(format!("{p}.wq"), normal(&mut rng, vec![h, qd], s_h));
            let wk = normal(&mut rng, vec![h, kv], s_h);
            let wv = normal(&mut rng, vec![h, kv], s_h);
            // Fused K+V weight = column concat (must match exactly so the
            // fused and unfused flows agree bit-for-bit).
            let mut wkv_data = Vec::with_capacity(h * 2 * kv);
            let wk_d = wk.as_f32().unwrap();
            let wv_d = wv.as_f32().unwrap();
            for r in 0..h {
                wkv_data.extend_from_slice(&wk_d[r * kv..(r + 1) * kv]);
                wkv_data.extend_from_slice(&wv_d[r * kv..(r + 1) * kv]);
            }
            by_name.insert(
                format!("{p}.wkv"),
                Tensor::f32(vec![h, 2 * kv], wkv_data).unwrap(),
            );
            by_name.insert(format!("{p}.wk"), wk);
            by_name.insert(format!("{p}.wv"), wv);
            by_name.insert(format!("{p}.wo"), normal(&mut rng, vec![qd, h], s_q));
            by_name.insert(format!("{p}.norm2"), norm_weight(&mut rng, h));
            by_name.insert(format!("{p}.wg"), normal(&mut rng, vec![h, inter], s_h));
            by_name.insert(format!("{p}.wu"), normal(&mut rng, vec![h, inter], s_h));
            by_name.insert(format!("{p}.wd"), normal(&mut rng, vec![inter, h], s_i));
        }
        by_name.insert("norm_f".into(), norm_weight(&mut rng, h));
        by_name.insert("w_lm".into(), normal(&mut rng, vec![h, v], s_h));

        let embedding = normal(&mut rng, vec![v, h], 1.0);
        let half = dims.head_dim / 2;
        let theta: f64 = 10_000.0;
        let inv: Vec<f32> = (0..half)
            .map(|i| (1.0 / theta.powf(i as f64 / half as f64)) as f32)
            .collect();
        let inv_freq = Tensor::f32(vec![half], inv).unwrap();

        ModelWeights { by_name, embedding, inv_freq, dims: *dims }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.by_name.get(name)
    }

    /// Total parameter count (sanity vs the config's nominal size).
    pub fn param_count(&self) -> usize {
        self.by_name.values().map(Tensor::numel).sum::<usize>() + self.embedding.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let dims = GraphDims::qwen_tiny();
        let a = ModelWeights::synthesize(&dims, 42);
        let b = ModelWeights::synthesize(&dims, 42);
        assert_eq!(
            a.get("l0.wq").unwrap().as_f32().unwrap(),
            b.get("l0.wq").unwrap().as_f32().unwrap()
        );
        let c = ModelWeights::synthesize(&dims, 43);
        assert_ne!(
            a.get("l0.wq").unwrap().as_f32().unwrap(),
            c.get("l0.wq").unwrap().as_f32().unwrap()
        );
    }

    #[test]
    fn wkv_is_column_concat_of_wk_wv() {
        let dims = GraphDims::qwen_tiny();
        let w = ModelWeights::synthesize(&dims, 1);
        let (h, kv) = (dims.hidden, dims.kv_dim());
        let wk = w.get("l0.wk").unwrap().as_f32().unwrap();
        let wv = w.get("l0.wv").unwrap().as_f32().unwrap();
        let wkv = w.get("l0.wkv").unwrap().as_f32().unwrap();
        for r in 0..h {
            assert_eq!(&wkv[r * 2 * kv..r * 2 * kv + kv], &wk[r * kv..(r + 1) * kv]);
            assert_eq!(&wkv[r * 2 * kv + kv..(r + 1) * 2 * kv], &wv[r * kv..(r + 1) * kv]);
        }
    }

    #[test]
    fn has_all_graph_inputs() {
        use crate::fx::builder::{build_decode_graph, FusionConfig};
        let dims = GraphDims::qwen_tiny();
        let w = ModelWeights::synthesize(&dims, 7);
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let g = build_decode_graph(&dims, fusion);
            for name in g.inputs.keys() {
                let step_input = name == "x"
                    || name.starts_with("pos")
                    || name == "inv_freq"
                    || name.ends_with("cache");
                assert!(
                    step_input || w.get(name).is_some(),
                    "missing weight for graph input '{name}'"
                );
            }
        }
    }

    #[test]
    fn tiny_param_count_plausible() {
        let dims = GraphDims::qwen_tiny();
        let w = ModelWeights::synthesize(&dims, 7);
        // ~4 layers of (64x64 + 64x64 + 64x64 + 2*64x176 + 176x64) + embeds
        let n = w.param_count();
        assert!(n > 200_000 && n < 400_000, "param count {n}");
    }
}

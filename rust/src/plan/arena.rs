//! Liveness-based arena-slot assignment.
//!
//! Every device-resident value in an [`super::ExecutionPlan`] lives in one
//! slot of a fixed arena of buffers. Slots are assigned by a linear scan
//! over the plan's step order: a value's slot is allocated at its defining
//! step and returned to the free list after its last use, so values whose
//! live intervals do not overlap share a slot (the buffer-lifetime
//! aliasing the WebLLM-style runtimes use to keep a whole decode step in a
//! small fixed working set).
//!
//! Freeing happens strictly *after* the defs of the same step, so a kernel
//! can never be handed one of its own input buffers as an output — the
//! aliasing-safety invariant the plan tests assert.

use std::collections::HashMap;

/// Live interval of one storage root over plan steps. Steps are numbered
/// 1..=n; `def == 0` means "uploaded before the first step", and
/// `last_use == n + 1` marks graph outputs that must survive the whole
/// replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub def: usize,
    pub last_use: usize,
}

impl Interval {
    /// Whether two intervals can safely share a slot under the
    /// free-after-defs rule: one must end strictly before the other begins.
    pub fn disjoint(self, other: Interval) -> bool {
        self.last_use < other.def || other.last_use < self.def
    }
}

/// One root value's placement, kept on the plan for tests/diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct SlotAssignment {
    /// `ValueId.0` of the storage root.
    pub value: usize,
    pub slot: usize,
    pub size: usize,
    pub interval: Interval,
}

/// The arena layout: per-slot byte sizes plus the assignment table.
#[derive(Debug, Clone, Default)]
pub struct ArenaLayout {
    /// Byte size of each arena slot (one device buffer per entry).
    pub slot_sizes: Vec<usize>,
    /// Root value id -> slot index.
    pub value_slot: HashMap<usize, usize>,
    pub assignments: Vec<SlotAssignment>,
}

impl ArenaLayout {
    /// Total bytes the aliased arena holds.
    pub fn arena_bytes(&self) -> usize {
        self.slot_sizes.iter().sum()
    }

    /// Bytes a no-aliasing layout (one buffer per value) would need.
    pub fn unaliased_bytes(&self) -> usize {
        self.assignments.iter().map(|a| a.size).sum()
    }
}

/// Assign slots to `(value, size, interval)` roots. `n_steps` is the plan
/// step count (intervals use the 0..=n_steps+1 numbering above).
pub fn assign_slots(roots: &[(usize, usize, Interval)], n_steps: usize) -> ArenaLayout {
    let mut layout = ArenaLayout::default();
    // size -> free slot indices (LIFO keeps reuse clustered).
    let mut free: HashMap<usize, Vec<usize>> = HashMap::new();

    // Walk def points in step order (upload defs at 0, then steps 1..=n).
    for step in 0..=n_steps {
        for &(value, size, interval) in roots {
            if interval.def != step {
                continue;
            }
            let slot = match free.get_mut(&size).and_then(Vec::pop) {
                Some(s) => s,
                None => {
                    layout.slot_sizes.push(size);
                    layout.slot_sizes.len() - 1
                }
            };
            layout.value_slot.insert(value, slot);
            layout.assignments.push(SlotAssignment { value, slot, size, interval });
        }
        // Free AFTER this step's defs: a slot released at step i is only
        // reusable from step i + 1 on.
        for &(value, size, interval) in roots {
            if interval.last_use == step {
                let slot = layout.value_slot[&value];
                free.entry(size).or_default().push(slot);
            }
        }
    }
    layout
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(def: usize, last_use: usize) -> Interval {
        Interval { def, last_use }
    }

    #[test]
    fn non_overlapping_values_share_a_slot() {
        let roots = vec![(0, 64, iv(1, 2)), (1, 64, iv(3, 4)), (2, 64, iv(5, 6))];
        let l = assign_slots(&roots, 6);
        assert_eq!(l.slot_sizes, vec![64]);
        assert_eq!(l.value_slot[&0], l.value_slot[&1]);
        assert_eq!(l.value_slot[&1], l.value_slot[&2]);
        assert_eq!(l.arena_bytes(), 64);
        assert_eq!(l.unaliased_bytes(), 192);
    }

    #[test]
    fn overlapping_values_get_distinct_slots() {
        let roots = vec![(0, 64, iv(1, 3)), (1, 64, iv(2, 4))];
        let l = assign_slots(&roots, 4);
        assert_ne!(l.value_slot[&0], l.value_slot[&1]);
        assert_eq!(l.slot_sizes.len(), 2);
    }

    #[test]
    fn freed_at_def_step_is_not_reused_same_step() {
        // Value 1 is defined at the step where value 0 dies: they must NOT
        // share (an output would alias its own input).
        let roots = vec![(0, 32, iv(1, 2)), (1, 32, iv(2, 3))];
        let l = assign_slots(&roots, 3);
        assert_ne!(l.value_slot[&0], l.value_slot[&1]);
        // ...but a def one step later can reuse it.
        let roots2 = vec![(0, 32, iv(1, 2)), (1, 32, iv(3, 4))];
        let l2 = assign_slots(&roots2, 4);
        assert_eq!(l2.value_slot[&0], l2.value_slot[&1]);
    }

    #[test]
    fn different_sizes_never_share() {
        let roots = vec![(0, 32, iv(1, 1)), (1, 64, iv(2, 3))];
        let l = assign_slots(&roots, 3);
        assert_eq!(l.slot_sizes.len(), 2);
    }

    #[test]
    fn assignments_respect_disjointness_invariant() {
        // Random-ish intervals; any pair sharing a slot must be disjoint.
        let mut roots = Vec::new();
        let mut s: u64 = 0xDEAD_BEEF;
        for v in 0..64usize {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let def = 1 + (s % 40) as usize;
            let len = (s >> 8) % 6;
            roots.push((v, 128, iv(def, def + len as usize)));
        }
        let l = assign_slots(&roots, 48);
        for a in &l.assignments {
            for b in &l.assignments {
                if a.value != b.value && a.slot == b.slot {
                    assert!(
                        a.interval.disjoint(b.interval),
                        "values {} and {} share slot {} with overlapping \
                         intervals {:?} / {:?}",
                        a.value,
                        b.value,
                        a.slot,
                        a.interval,
                        b.interval
                    );
                }
            }
        }
    }
}

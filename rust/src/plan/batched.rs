//! Batched-plan execution: one replay serves a whole serving round.
//!
//! A [`BatchedRunner`] wraps a [`PlanRunner`] compiled from the batched
//! decode graph ([`crate::fx::build_batched_decode_graph`]) at a fixed slot
//! `width`. Where the single-session runner binds ONE session's cache set,
//! the batched runner binds a **cache-set table**: the plan's persistent
//! list is slot-major (`s{j}.l{l}.{k,v}_cache`), so slot `j`'s slice is
//! exactly one session's layer-major [`DeviceKvCache`] — sessions plug
//! into slots without copies, and per-session cache buffers stay isolated
//! (the batched cache ops scatter through the `slot_idx` uniform into the
//! per-slot bindings; they never address another slot's buffers).
//!
//! Partial rounds (fewer active sessions than `width`) bind the runner's
//! own **padding set** in the empty slots and mask them via `slot_mask`,
//! so no recompile and no re-materialization happens as sessions retire or
//! admit mid-run — the ragged-round case the property tests pin.
//!
//! Arena liveness is sized for the widest batch by construction: the
//! batched graph's transient values are `[W, ...]`-shaped, so the plan's
//! lifetime-aliased arena already accommodates a full round.

use std::collections::HashMap;

use crate::tensor::Tensor;
use crate::webgpu::{BufferDesc, BufferId, BufferUsage, Device, KernelRunner};
use crate::{Error, Result};

use super::planner::ExecutionPlan;
use super::residency::DeviceKvCache;
use super::runner::{validate_paged_persistent, PlanRunner, ReplayDelta};

/// Batch-shape consistency checks for a plan compiled from a batched
/// decode graph: slot-major persistent layout with identical per-slot
/// specs, width-shaped step inputs, and a width-leading logits row.
pub fn validate_batched_plan(plan: &ExecutionPlan, width: usize) -> Result<()> {
    if width < 2 {
        return Err(Error::Graph(format!("batched plans need width >= 2, got {width}")));
    }
    if plan.persistent.is_empty() || plan.persistent.len() % width != 0 {
        return Err(Error::Graph(format!(
            "batched plan: {} persistent values not divisible into {width} slots",
            plan.persistent.len()
        )));
    }
    let per_slot = plan.persistent.len() / width;
    for j in 0..width {
        let prefix = format!("s{j}.");
        for k in 0..per_slot {
            let spec = &plan.persistent[j * per_slot + k];
            if !spec.name.starts_with(&prefix) {
                return Err(Error::Graph(format!(
                    "batched plan: persistent '{}' not slot-major (expected slot {j})",
                    spec.name
                )));
            }
            // Every slot must carry the same cache-set layout as slot 0,
            // so any session's set can occupy any slot.
            let base = &plan.persistent[k];
            if spec.shape != base.shape || spec.dtype != base.dtype || spec.size != base.size {
                return Err(Error::Graph(format!(
                    "batched plan: slot {j} spec '{}' differs from slot 0 '{}'",
                    spec.name, base.name
                )));
            }
        }
    }
    for (name, leading) in [("x", width), ("slot_mask", width), ("slot_idx", width)] {
        let up = plan
            .uploads
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| {
                Error::Graph(format!("batched plan: step input '{name}' missing"))
            })?;
        if up.shape.first().copied() != Some(leading) {
            return Err(Error::Graph(format!(
                "batched plan: step input '{name}' shape {:?} lacks leading width {leading}",
                up.shape
            )));
        }
    }
    match &plan.logits {
        Some(lg) if lg.shape.first().copied() == Some(width) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "batched plan: logits shape {:?} lacks leading width {width}",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("batched plan: no logits output".into())),
    }
    Ok(())
}

/// Consistency checks for a plan compiled from a PAGED batched decode
/// graph: the shared pool planes replace the slot-major cache-set table,
/// and per-slot block tables (a `[W * table_len]` step input) do the slot
/// routing instead of `slot_idx`.
pub fn validate_batched_plan_paged(plan: &ExecutionPlan, width: usize) -> Result<()> {
    if width < 2 {
        return Err(Error::Graph(format!("batched plans need width >= 2, got {width}")));
    }
    validate_paged_persistent(plan)?;
    for (name, leading) in [("x", width), ("slot_mask", width)] {
        let up = plan
            .uploads
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| {
                Error::Graph(format!("paged batched plan: step input '{name}' missing"))
            })?;
        if up.shape.first().copied() != Some(leading) {
            return Err(Error::Graph(format!(
                "paged batched plan: step input '{name}' shape {:?} lacks leading \
                 width {leading}",
                up.shape
            )));
        }
    }
    // One concatenated per-slot table: [W * table_len] i32 entries.
    let bt = plan
        .uploads
        .iter()
        .find(|u| u.name == "block_table")
        .ok_or_else(|| Error::Graph("paged batched plan: 'block_table' missing".into()))?;
    match bt.shape.first().copied() {
        Some(n) if n > 0 && n % width == 0 => {}
        _ => {
            return Err(Error::Graph(format!(
                "paged batched plan: block_table shape {:?} is not [W * table_len]",
                bt.shape
            )));
        }
    }
    match &plan.logits {
        Some(lg) if lg.shape.first().copied() == Some(width) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "paged batched plan: logits shape {:?} lacks leading width {width}",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("paged batched plan: no logits output".into())),
    }
    Ok(())
}

/// Replays a batched plan over a per-round cache-set table.
pub struct BatchedRunner {
    runner: PlanRunner,
    width: usize,
    per_slot: usize,
    /// Runner-owned padding cache set bound into empty (masked) slots —
    /// raw device buffers outside the pooled accounting, never written
    /// (masked slots skip cache scatters) and never read back.
    padding: Vec<BufferId>,
    /// Reusable flattened-table scratch (capacity width x per_slot):
    /// refilled per replay so the hot loop allocates nothing steady-state,
    /// matching the plan layer's allocation-free-replay discipline.
    flat: DeviceKvCache,
    /// Paged mode: the shared pool planes are the runner's default cache
    /// set (bound once at materialize) and replays take NO cache-set table
    /// — the uploaded block tables route slots instead.
    paged: bool,
    /// Batched rounds replayed.
    pub rounds: u64,
}

impl BatchedRunner {
    /// Validate the plan's batch shape, create the padding set, and
    /// materialize the inner runner (arena, logits ring, bind groups).
    pub fn materialize(device: &mut Device, plan: ExecutionPlan, width: usize) -> Result<Self> {
        validate_batched_plan(&plan, width)?;
        let per_slot = plan.persistent.len() / width;
        let usage = BufferUsage::STORAGE
            | BufferUsage::COPY_DST
            | BufferUsage::COPY_SRC
            | BufferUsage::MAP_READ;
        let mut padding = Vec::with_capacity(per_slot);
        for spec in &plan.persistent[..per_slot] {
            padding.push(device.create_buffer(BufferDesc {
                label: format!("batch-pad-{}", spec.name),
                size: spec.size,
                usage,
            })?);
        }
        let runner = PlanRunner::materialize(device, plan)?;
        let flat = DeviceKvCache {
            buffers: Vec::with_capacity(width * per_slot),
            resident_bytes: 0,
        };
        Ok(BatchedRunner { runner, width, per_slot, padding, flat, paged: false, rounds: 0 })
    }

    /// Materialize a PAGED batched runner: the plan's persistent list is
    /// the shared pool planes (`pool`), registered once here and installed
    /// as the runner's default cache set — so every replay binds the same
    /// persistent bind groups regardless of which sessions occupy the
    /// slots, and no padding set exists (masked slots carry `-1` block
    /// tables the kernels never dereference).
    pub fn materialize_paged(
        device: &mut Device,
        plan: ExecutionPlan,
        width: usize,
        pool: &DeviceKvCache,
    ) -> Result<Self> {
        validate_batched_plan_paged(&plan, width)?;
        let mut runner = PlanRunner::materialize(device, plan)?;
        runner.register_cache(device, pool)?;
        runner.set_default_cache(pool.clone())?;
        Ok(BatchedRunner {
            runner,
            width,
            per_slot: 0,
            padding: Vec::new(),
            flat: DeviceKvCache { buffers: Vec::new(), resident_bytes: 0 },
            paged: true,
            rounds: 0,
        })
    }

    /// True when this runner replays the paged plan (shared pool planes +
    /// block tables) instead of the per-session cache-set table.
    pub fn is_paged(&self) -> bool {
        self.paged
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Persistent values per slot (one session's cache-set length).
    pub fn per_slot(&self) -> usize {
        self.per_slot
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.runner.plan
    }

    pub fn inner(&self) -> &PlanRunner {
        &self.runner
    }

    pub fn inner_mut(&mut self) -> &mut PlanRunner {
        &mut self.runner
    }

    /// Distinct cache-set tables with registered bind groups.
    pub fn registered_tables(&self) -> usize {
        self.runner.registered_cache_sets()
    }

    /// True for buffers the batched runner owns (its logits ring and the
    /// padding set) — they must never be released into the pooled
    /// free lists.
    pub fn owns_buffer(&self, buf: BufferId) -> bool {
        self.runner.owns_buffer(buf) || self.padding.contains(&buf)
    }

    /// Refill the flattened-table scratch: each slot's session cache set
    /// (or the padding set for `None`) in the plan's slot-major persistent
    /// binding order. No allocation once the scratch capacity is warm.
    fn fill_flat(&mut self, table: &[Option<&DeviceKvCache>]) -> Result<()> {
        if table.len() > self.width {
            return Err(Error::Graph(format!(
                "cache-set table has {} slots, batched plan width is {}",
                table.len(),
                self.width
            )));
        }
        self.flat.buffers.clear();
        for j in 0..self.width {
            match table.get(j).copied().flatten() {
                Some(kv) => {
                    if kv.buffers.len() != self.per_slot {
                        return Err(Error::Graph(format!(
                            "slot {j}: session cache set has {} buffers, plan expects {}",
                            kv.buffers.len(),
                            self.per_slot
                        )));
                    }
                    self.flat.buffers.extend_from_slice(&kv.buffers);
                }
                None => self.flat.buffers.extend_from_slice(&self.padding),
            }
        }
        Ok(())
    }

    /// Replay the batched plan once: one dispatch per layer op covering
    /// every active slot in `table`. `inputs` are the packed step inputs
    /// (`x [W, H]`, per-slot pos/mask/idx uniforms, `inv_freq`);
    /// `ring_idx` selects this chunk's logits-ring buffer (chunks of one
    /// round pass distinct indices so every `[W, vocab]` row block
    /// survives until the round's single coalesced readback). The table's
    /// bind groups are registered on first sight and are pure cache hits
    /// thereafter (the pool's LIFO recycling keeps steady-state churn on
    /// the same tables). Returns (named outputs, the live logits buffer,
    /// cost deltas).
    pub fn replay(
        &mut self,
        device: &mut Device,
        runner: &dyn KernelRunner,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        table: &[Option<&DeviceKvCache>],
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let out = if self.paged {
            if !table.is_empty() {
                return Err(Error::Graph(
                    "paged batched plan takes no cache-set table (block tables \
                     route slots)"
                        .into(),
                ));
            }
            self.runner.replay(device, runner, inputs, ring_idx, None)?
        } else {
            self.fill_flat(table)?;
            self.runner.register_cache(device, &self.flat)?;
            self.runner
                .replay(device, runner, inputs, ring_idx, Some(&self.flat))?
        };
        self.rounds += 1;
        Ok(out)
    }
}

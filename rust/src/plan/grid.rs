//! Workgroup-grid computation: ceil-divide an output-element count into a
//! dispatch grid that respects `max_compute_workgroups_per_dimension`.
//!
//! The eager executor used to clamp the 1-D workgroup count with
//! `wg.min(65_535)`, silently under-dispatching any kernel with more than
//! 65_535 * 256 (~16.7M) output elements. This module replaces the clamp
//! with proper 2-D tiling: counts that exceed the per-dimension limit are
//! folded into a `(x, y, 1)` grid whose product covers every workgroup,
//! and counts too large even for a 2-D grid are a hard error instead of a
//! silent miscomputation.

use crate::{Error, Result};

/// Threads per workgroup — matches the WGSL convention used by every AOT
/// kernel (`@workgroup_size(256)`).
pub const WORKGROUP_SIZE: usize = 256;

/// Tile `out_elems` output elements (at [`WORKGROUP_SIZE`] threads per
/// workgroup) into a dispatch grid with every dimension `<= max_per_dim`.
///
/// Returns `(x, 1, 1)` whenever the flat count fits, otherwise the
/// smallest-row-count 2-D grid `(x, y, 1)` with `x * y >= workgroups`.
pub fn tile_workgroups(out_elems: usize, max_per_dim: u32) -> Result<(u32, u32, u32)> {
    let max = u64::from(max_per_dim.max(1));
    let groups = (out_elems.div_ceil(WORKGROUP_SIZE).max(1)) as u64;
    if groups <= max {
        return Ok((groups as u32, 1, 1));
    }
    // Minimal number of rows, then balance columns; y >= groups/max implies
    // x = ceil(groups / y) <= max.
    let y = groups.div_ceil(max);
    if y > max {
        return Err(Error::LimitExceeded(format!(
            "{groups} workgroups cannot tile into a 2-D grid with \
             max {max_per_dim} per dimension"
        )));
    }
    let x = groups.div_ceil(y);
    Ok((x as u32, y as u32, 1))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    const MAX: u32 = 65_535;

    #[test]
    fn small_counts_stay_one_dimensional() {
        assert_eq!(tile_workgroups(1, MAX).unwrap(), (1, 1, 1));
        assert_eq!(tile_workgroups(256, MAX).unwrap(), (1, 1, 1));
        assert_eq!(tile_workgroups(257, MAX).unwrap(), (2, 1, 1));
        assert_eq!(tile_workgroups(512 * 256, MAX).unwrap(), (512, 1, 1));
    }

    #[test]
    fn boundary_regression_no_silent_clamp() {
        // Exactly at the limit: still 1-D.
        let at = MAX as usize * WORKGROUP_SIZE;
        assert_eq!(tile_workgroups(at, MAX).unwrap(), (MAX, 1, 1));
        // One element past the limit: the old `wg.min(65_535)` clamp lost
        // a workgroup here; tiling must cover all 65_536.
        let (x, y, z) = tile_workgroups(at + 1, MAX).unwrap();
        assert_eq!(z, 1);
        assert!(x <= MAX && y <= MAX);
        assert!(
            (x as u64) * (y as u64) >= MAX as u64 + 1,
            "grid ({x},{y}) does not cover {} workgroups",
            MAX as u64 + 1
        );
        assert_eq!((x, y), (32_768, 2));
    }

    #[test]
    fn coverage_property_over_random_counts() {
        // xorshift-style sweep without pulling in the model RNG.
        let mut s: u64 = 0x9E37_79B9_7F4A_7C15;
        for _ in 0..200 {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let elems = (s % (1u64 << 34)) as usize + 1;
            let groups = elems.div_ceil(WORKGROUP_SIZE).max(1) as u64;
            let (x, y, z) = tile_workgroups(elems, MAX).unwrap();
            assert!(x >= 1 && y >= 1 && z == 1);
            assert!(x <= MAX && y <= MAX);
            assert!((x as u64) * (y as u64) >= groups, "elems {elems}");
            // Never more than one extra row's worth of waste.
            assert!((x as u64) * ((y as u64) - 1) < groups, "elems {elems}");
        }
    }

    #[test]
    fn impossible_grids_error_instead_of_clamping() {
        // max 4 per dim -> at most 16 workgroups; 17 needs an error-free
        // 2-D tile (5x4), 16*4+1 workgroups cannot fit.
        assert_eq!(tile_workgroups(17 * 256, 4).unwrap(), (5, 4, 1));
        assert!(tile_workgroups((4 * 4 + 1) * 256, 4).is_err());
    }
}

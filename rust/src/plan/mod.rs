//! # Compile-once execution plans
//!
//! The planned execution pipeline: `fx` graph → [`Planner`] →
//! [`ExecutionPlan`] → [`PlanRunner`] replay. This is the architecture
//! WebLLM-style runtimes use to beat the paper's per-operation wall: all
//! graph interpretation (HashMap lookups, shape checks, bind-group key
//! construction, buffer acquire/release, host round-trips for
//! activations) happens **once** at plan-build time; the decode loop
//! replays a flat array of pre-resolved dispatches.
//!
//! - [`grid`] — 2-D workgroup tiling (fixes the silent 65_535 clamp).
//! - [`pipelines`] — shared prepared-pipeline + layout pool.
//! - [`arena`] — liveness intervals + buffer-lifetime slot aliasing.
//! - [`residency`] — Transient / StepInput / Persistent value classes and
//!   the per-session KV-cache arena (session-owned device buffers over
//!   the bounded pool).
//! - [`planner`] — graph → plan compilation (value residency, alias
//!   resolution, binding emission).
//! - [`runner`] — arena materialization + the allocation-free replay
//!   hot loop with `dispatches_per_submit` encoder batching and
//!   per-session persistent bind groups.
//! - [`batched`] — batched-plan replay over a per-round *cache-set table*
//!   (one session cache set per slot, padding + `slot_mask` for partial
//!   rounds): one dispatch per layer op serves a whole serving round.
//! - [`prefill`] — chunked-prefill replay: one dispatch per layer op
//!   ingests a whole `[C, H]` prompt chunk of ONE session into its
//!   resident cache set (`valid_len` masks the ragged tail), so prompt
//!   ingestion stops paying per-token dispatch bills.
//! - [`unified`] — unified-round replay over the same cache-set table:
//!   `[W*C, H]` seq-x-batch steps where each slot carries `valid_len`
//!   tokens (prefill chunk, decode step, or padding), so a MIXED
//!   prefill/decode round is one dispatch per layer op.
//!
//! Eager execution stays available ([`crate::engine::GraphExecutor`]'s
//! default mode) precisely so `wdb plan-bench` can measure the
//! eager-vs-planned framework-overhead delta (table P1).

// Plan build and replay run inside serving rounds: failures must surface
// as typed `Error`s the recovery layer can classify, never as panics.
// New `unwrap()`/`expect()` sites fail clippy review.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod arena;
pub mod batched;
pub mod grid;
pub mod pipelines;
pub mod planner;
pub mod prefill;
pub mod residency;
pub mod runner;
pub mod unified;

pub use arena::{ArenaLayout, Interval, SlotAssignment};
pub use batched::{validate_batched_plan, validate_batched_plan_paged, BatchedRunner};
pub use prefill::{validate_prefill_plan, validate_prefill_plan_paged, PrefillRunner};
pub use unified::{validate_unified_plan, validate_unified_plan_paged, UnifiedRunner};
pub use grid::{tile_workgroups, WORKGROUP_SIZE};
pub use pipelines::{PipelinePool, PreparedKernel};
pub use planner::{
    Binding, DispatchStep, ExecutionPlan, GraphFingerprint, HostStep, LogitsSpec,
    PlanStats, Planner, Readback, SlotRef, Step, Upload,
};
pub use residency::{
    BlockArena, BlockArenaStats, CacheArena, CacheArenaStats, DeviceKvCache, PagedKv, PagedSlot,
    PersistentSpec, ResidencyClass,
};
pub use runner::{validate_paged_persistent, PlanRunner, ReplayDelta};

/// Default framework cost per replayed step (virtual ns): the plan walk's
/// residual per-dispatch bookkeeping — array indexing and a cached
/// bind-group id load — modeled after WebLLM-class runtimes that hoist
/// planning out of the decode loop, vs the ~71 µs/op the torch-webgpu
/// eager interpreter pays
/// ([`crate::engine::inference::TORCH_WEBGPU_FRAMEWORK_NS`]).
pub const PLANNED_FRAMEWORK_NS: u64 = 2_000;

/// Plan compilation knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlanConfig {
    /// How many dispatches one encoder carries per submit (the paper's
    /// encoder-batching axis, distinct from kernel fusion).
    pub dispatches_per_submit: usize,
    /// Framework cost charged per replayed step (virtual ns).
    pub framework_ns_per_step: u64,
    /// Logits ring depth — must cover the maximum number of sessions a
    /// scheduler round replays before its coalesced readback.
    pub logits_ring: usize,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            dispatches_per_submit: 16,
            framework_ns_per_step: PLANNED_FRAMEWORK_NS,
            logits_ring: 1,
        }
    }
}

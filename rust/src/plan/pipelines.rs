//! Shared prepared-pipeline + bind-group-layout pool.
//!
//! Pipelines compile once per kernel name (off the request path, the Dawn
//! pipeline-caching pattern) and are shared by the eager executor, the
//! planner, and every session the serving engine interleaves. Workgroup
//! grids are precomputed here through [`super::grid::tile_workgroups`], so
//! both execution modes inherit the 2-D tiling fix instead of the old
//! silent `wg.min(65_535)` clamp.

use std::collections::HashMap;

use crate::fx::graph::FxGraph;
use crate::runtime::registry::Registry;
use crate::webgpu::{
    BindGroupLayoutId, ComputePipelineId, Device, KernelIoSpec, ShaderModuleDesc,
};
use crate::Result;

use super::grid::tile_workgroups;

/// A prepared kernel: compiled-pipeline id + its layout + IO specs + the
/// precomputed dispatch grid.
#[derive(Debug, Clone)]
pub struct PreparedKernel {
    pub pipeline: ComputePipelineId,
    pub layout: BindGroupLayoutId,
    pub inputs: Vec<KernelIoSpec>,
    pub outputs: Vec<KernelIoSpec>,
    pub grid: (u32, u32, u32),
}

/// Prepared-pipeline cache keyed by kernel name, with bind-group layouts
/// shared across kernels of the same (inputs, outputs) arity.
#[derive(Default)]
pub struct PipelinePool {
    prepared: HashMap<String, PreparedKernel>,
    layouts: HashMap<(usize, usize), BindGroupLayoutId>,
}

impl PipelinePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create pipelines for every kernel a graph uses and compile the AOT
    /// modules. Idempotent per kernel name.
    pub fn prepare(
        &mut self,
        device: &mut Device,
        registry: &Registry,
        graph: &FxGraph,
    ) -> Result<()> {
        for name in graph.kernel_names() {
            if self.prepared.contains_key(&name) {
                continue;
            }
            registry.ensure_loaded(&name)?;
            let spec = registry.spec(&name)?;
            let key = (spec.inputs.len(), spec.outputs.len());
            let layout = match self.layouts.get(&key) {
                Some(&l) => l,
                None => {
                    let l = crate::webgpu::queue::kernel_layout(device, &name, key.0, key.1)?;
                    self.layouts.insert(key, l);
                    l
                }
            };
            let module = device.create_shader_module(ShaderModuleDesc {
                label: name.clone(),
                kernel: name.clone(),
                inputs: spec.inputs.clone(),
                outputs: spec.outputs.clone(),
            })?;
            let pipeline = device.create_compute_pipeline(&name, module, layout)?;
            let out_elems: usize = spec.outputs.iter().map(KernelIoSpec::numel).sum();
            let grid =
                tile_workgroups(out_elems, device.limits.max_compute_workgroups_per_dimension)?;
            self.prepared.insert(
                name.clone(),
                PreparedKernel {
                    pipeline,
                    layout,
                    inputs: spec.inputs.clone(),
                    outputs: spec.outputs.clone(),
                    grid,
                },
            );
        }
        Ok(())
    }

    pub fn get(&self, kernel: &str) -> Option<&PreparedKernel> {
        self.prepared.get(kernel)
    }

    pub fn prepared_count(&self) -> usize {
        self.prepared.len()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::fx::builder::{build_decode_graph, FusionConfig, GraphDims};
    use crate::webgpu::ImplementationProfile;

    #[test]
    fn prepares_every_graph_kernel_once() {
        let reg = Registry::builtin().unwrap();
        let mut device = Device::new(ImplementationProfile::zero_overhead());
        let g = build_decode_graph(&GraphDims::qwen_tiny(), FusionConfig::fused());
        let mut pool = PipelinePool::new();
        pool.prepare(&mut device, &reg, &g).unwrap();
        let n = pool.prepared_count();
        assert_eq!(n, g.kernel_names().len());
        // Re-preparing is a no-op.
        pool.prepare(&mut device, &reg, &g).unwrap();
        assert_eq!(pool.prepared_count(), n);
        let prep = pool.get("rmsnorm_64").expect("prepared");
        assert_eq!(prep.inputs.len(), 2);
        assert_eq!(prep.grid, (1, 1, 1)); // 64 elems -> 1 workgroup
    }
}

//! The Planner: compiles an [`FxGraph`] once into an [`ExecutionPlan`].
//!
//! Planning hoists everything the eager executor re-derives per token out
//! of the decode loop:
//!
//! - **Pre-resolved resources** — every step carries its pipeline, layout
//!   and fully-resolved buffer bindings; the hot loop never touches a
//!   HashMap or allocates.
//! - **Value residency** — kernel outputs stay in their device buffers and
//!   are bound directly by consumers. Pure shape ops (`ToHeads`,
//!   `FromHeads`, `SplitKv`) become *aliases*: byte windows over the
//!   producer's buffer, resolved at plan time into binding offsets, so
//!   they cost nothing at replay. Only `Halves` (the unfused rotary
//!   rotate-half split, a strided gather) materializes as a host step.
//! - **Buffer-lifetime aliasing** — intermediates are packed into a fixed
//!   arena by live-interval analysis ([`super::arena`]); non-overlapping
//!   values share slots.
//! - **Precomputed grids** — 2-D tiled workgroup counts from
//!   [`super::grid`].
//!
//! The plan is pure data (ids + offsets); [`super::PlanRunner`] turns it
//! into device buffers and cached bind groups and replays it per token.

use std::collections::HashMap;

use crate::fx::graph::FxGraph;
use crate::fx::node::{HostOp, OpKind, ValueId};
use crate::runtime::registry::Registry;
use crate::tensor::DType;
use crate::webgpu::{BindGroupLayoutId, BufferId, ComputePipelineId, Device};
use crate::{Error, Result};

use super::arena::{assign_slots, ArenaLayout, Interval};
use super::pipelines::PipelinePool;
use super::residency::{PersistentSpec, ResidencyClass};
use super::PlanConfig;

/// A resolved byte window in the arena.
#[derive(Debug, Clone, Copy)]
pub struct SlotRef {
    pub slot: usize,
    pub offset: usize,
    pub size: usize,
}

/// One resolved buffer binding of a dispatch step.
#[derive(Debug, Clone, Copy)]
pub enum Binding {
    /// Window over an arena slot.
    Arena(SlotRef),
    /// Window over a pinned weight buffer.
    Pinned { buffer: BufferId, offset: usize, size: usize },
    /// Window over a session-owned persistent buffer (KV cache): `idx`
    /// selects the buffer from the session's `DeviceKvCache`, substituted
    /// per session at bind-group-registration time. An in-place
    /// `cache_update` binds the same `idx` as both input and output.
    Persistent { idx: usize, offset: usize, size: usize },
    /// The logits output: substituted per replay with a ring buffer so the
    /// deferred synchronizing readback survives later replays.
    Ring,
}

/// One precompiled dispatch: everything `queue.submit` needs, resolved.
#[derive(Debug, Clone)]
pub struct DispatchStep {
    pub name: String,
    pub kernel: String,
    pub pipeline: ComputePipelineId,
    pub layout: BindGroupLayoutId,
    /// Inputs then outputs, dense binding order.
    pub bindings: Vec<Binding>,
    pub grid: (u32, u32, u32),
}

/// The one host op that cannot alias: `Halves` (strided rotate-half
/// split). Copies each source row's two halves into two fresh slots.
#[derive(Debug, Clone)]
pub struct HostStep {
    pub name: String,
    pub op: HostOp,
    pub src: SlotRef,
    pub rows: usize,
    pub row_bytes: usize,
    pub dst: [SlotRef; 2],
}

#[derive(Debug, Clone)]
pub enum Step {
    Dispatch(DispatchStep),
    Host(HostStep),
}

/// A per-replay input upload into its arena slot.
#[derive(Debug, Clone)]
pub struct Upload {
    pub name: String,
    pub dst: SlotRef,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// A per-replay output readback (peek — the synchronizing `map_read` stays
/// with the caller, exactly as in eager mode).
#[derive(Debug, Clone)]
pub struct Readback {
    pub name: String,
    pub src: SlotRef,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// The ring-backed deferred output (logits).
#[derive(Debug, Clone)]
pub struct LogitsSpec {
    pub name: String,
    pub size: usize,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Structural plan statistics (build costs live on the runner).
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanStats {
    pub kernel_steps: usize,
    pub host_steps: usize,
    /// Shape-op values resolved into zero-cost byte-window aliases.
    pub aliased_values: usize,
    pub arena_slots: usize,
    pub arena_bytes: usize,
    /// Bytes a no-aliasing layout (one buffer per value) would need.
    pub unaliased_bytes: usize,
    /// Values in the `Persistent` residency class (session-owned device
    /// buffers — KV caches; never uploaded or read back per step).
    pub persistent_values: usize,
    /// Values in the `StepInput` residency class (per-step host uploads).
    pub step_inputs: usize,
    /// Host bytes uploaded per replay (sum of the `StepInput` sizes) —
    /// the table P1 `upload_bytes` column.
    pub upload_bytes_per_step: usize,
    /// Device bytes of one session's persistent cache set.
    pub resident_bytes: usize,
}

/// Cheap identity of the graph a plan was compiled from — checked on
/// every planned run so replaying a stale plan for a different graph
/// fails loudly instead of silently returning the wrong outputs. Counts
/// alone are not enough (two graphs can differ only in kernel names /
/// wiring), so a structural FNV-1a hash over every node's op and value
/// ids is included.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphFingerprint {
    pub nodes: usize,
    pub values: usize,
    pub inputs: usize,
    pub outputs: usize,
    pub structure_hash: u64,
}

impl GraphFingerprint {
    pub fn of(graph: &FxGraph) -> Self {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        for node in &graph.nodes {
            match &node.op {
                OpKind::Kernel(k) => eat(k.as_bytes()),
                OpKind::InPlaceKernel(k) => {
                    eat(b"ip:");
                    eat(k.as_bytes());
                }
                OpKind::Host(HostOp::Embed) => eat(b"h:embed"),
                OpKind::Host(HostOp::SplitKv) => eat(b"h:split_kv"),
                OpKind::Host(HostOp::ToHeads { heads, head_dim }) => {
                    eat(b"h:to_heads");
                    eat(&(*heads as u64).to_le_bytes());
                    eat(&(*head_dim as u64).to_le_bytes());
                }
                OpKind::Host(HostOp::FromHeads) => eat(b"h:from_heads"),
                OpKind::Host(HostOp::Halves) => eat(b"h:halves"),
            }
            for v in node.inputs.iter().chain(node.outputs.iter()) {
                eat(&(v.0 as u64).to_le_bytes());
            }
        }
        GraphFingerprint {
            nodes: graph.nodes.len(),
            values: graph.n_values,
            inputs: graph.inputs.len(),
            outputs: graph.outputs.len(),
            structure_hash: h,
        }
    }
}

/// A compiled, replayable decode step. Pure data — resource ids and byte
/// offsets — valid for the device whose pipelines it references.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub steps: Vec<Step>,
    pub arena: ArenaLayout,
    pub uploads: Vec<Upload>,
    pub readbacks: Vec<Readback>,
    /// Persistent (session-owned, device-resident) values in declaration
    /// order; `Binding::Persistent { idx }` indexes this list, as does a
    /// session's `DeviceKvCache::buffers`.
    pub persistent: Vec<PersistentSpec>,
    /// Graph outputs that resolve to persistent state: they stay on the
    /// device (no readback) — callers read the session's cache set via the
    /// explicit spill path if they need host copies.
    pub resident_outputs: Vec<(String, usize)>,
    pub logits: Option<LogitsSpec>,
    /// Index into `steps` of the dispatch producing logits.
    pub logits_step: Option<usize>,
    pub dispatches_per_submit: usize,
    pub framework_ns_per_step: u64,
    pub logits_ring: usize,
    /// Identity of the compiled graph (checked per planned run).
    pub fingerprint: GraphFingerprint,
    pub stats: PlanStats,
}

impl ExecutionPlan {
    /// Residency class of a named graph input in this plan. `None` for
    /// pinned weights (engine-owned device buffers, outside the three
    /// session-facing classes) and for names the plan does not know.
    /// Non-input values are always [`ResidencyClass::Transient`] — they
    /// live in the plan's lifetime-aliased arena slots.
    pub fn input_residency(&self, name: &str) -> Option<ResidencyClass> {
        if self.persistent.iter().any(|p| p.name == name) {
            Some(ResidencyClass::Persistent)
        } else if self.uploads.iter().any(|u| u.name == name) {
            Some(ResidencyClass::StepInput)
        } else {
            None
        }
    }
}

#[derive(Debug, Clone)]
enum Kind {
    Unknown,
    Pinned(BufferId),
    /// Session-owned persistent buffer (index into `ExecutionPlan::persistent`).
    Persistent(usize),
    Root,
    Alias { root: usize, offset: usize },
}

#[derive(Debug, Clone)]
struct ValueMeta {
    kind: Kind,
    shape: Vec<usize>,
    dtype: DType,
    /// Byte size; 0 means "not yet typed".
    size: usize,
}

enum ProtoStep {
    Kernel(usize),
    Halves(usize),
}

/// Compiles graphs against a device + prepared-pipeline pool.
pub struct Planner<'r> {
    pub registry: &'r Registry,
}

impl<'r> Planner<'r> {
    pub fn new(registry: &'r Registry) -> Self {
        Planner { registry }
    }

    /// Compile `graph` into an [`ExecutionPlan`]. `pinned` maps weight
    /// values to their persistent device buffers (bound directly).
    pub fn compile(
        &self,
        device: &mut Device,
        pipelines: &mut PipelinePool,
        graph: &FxGraph,
        pinned: &HashMap<ValueId, BufferId>,
        cfg: &PlanConfig,
    ) -> Result<ExecutionPlan> {
        graph.validate()?;
        pipelines.prepare(device, self.registry, graph)?;

        let mut meta: Vec<ValueMeta> = (0..graph.n_values)
            .map(|_| ValueMeta {
                kind: Kind::Unknown,
                shape: Vec::new(),
                dtype: DType::F32,
                size: 0,
            })
            .collect();
        for &vid in graph.inputs.values() {
            meta[vid.0].kind = match pinned.get(&vid) {
                Some(&buf) => Kind::Pinned(buf),
                None => Kind::Root,
            };
        }
        // Persistent residency class: session-owned device buffers, bound
        // per session instead of uploaded per step (declaration order
        // defines the cache-set layout).
        for (idx, vid) in graph.persistent_values().iter().enumerate() {
            if pinned.contains_key(vid) {
                return Err(Error::Graph(format!(
                    "persistent input '{}' is also pinned",
                    graph.persistent[idx]
                )));
            }
            meta[vid.0].kind = Kind::Persistent(idx);
        }

        // Resolve a value to (root value index, byte offset within it).
        fn resolve(meta: &[ValueMeta], v: usize) -> (usize, usize) {
            match meta[v].kind {
                Kind::Alias { root, offset } => (root, offset),
                _ => (v, 0),
            }
        }

        let mut proto: Vec<ProtoStep> = Vec::with_capacity(graph.nodes.len());
        let mut aliased_values = 0usize;
        // Root value -> def step / last-use step (step numbers 1..=n; 0 is
        // the pre-step upload point).
        let mut defs: HashMap<usize, usize> = HashMap::new();
        let mut uses: HashMap<usize, usize> = HashMap::new();

        for (ni, node) in graph.nodes.iter().enumerate() {
            let step_no = proto.len() + 1;
            match &node.op {
                OpKind::Kernel(kname) | OpKind::InPlaceKernel(kname) => {
                    let prep = pipelines
                        .get(kname)
                        .ok_or_else(|| Error::Graph(format!("kernel '{kname}' not prepared")))?;
                    if node.inputs.len() != prep.inputs.len()
                        || node.outputs.len() != prep.outputs.len()
                    {
                        return Err(Error::Graph(format!(
                            "{}: node arity ({} in, {} out) != kernel spec ({}, {})",
                            node.name,
                            node.inputs.len(),
                            node.outputs.len(),
                            prep.inputs.len(),
                            prep.outputs.len()
                        )));
                    }
                    for (i, spec) in prep.inputs.iter().enumerate() {
                        let v = node.inputs[i].0;
                        if meta[v].size == 0 {
                            // First consumer types a graph input.
                            if matches!(meta[v].kind, Kind::Unknown) {
                                return Err(Error::Graph(format!(
                                    "{}: input {i} (value {v}) has no producer",
                                    node.name
                                )));
                            }
                            meta[v].shape = spec.shape.clone();
                            meta[v].dtype = spec.dtype;
                            meta[v].size = spec.size_bytes();
                        } else if meta[v].shape != spec.shape {
                            return Err(Error::Graph(format!(
                                "{}: input {i} shape {:?} != kernel spec {:?}",
                                node.name, meta[v].shape, spec.shape
                            )));
                        }
                        let (root, _) = resolve(&meta, v);
                        if !matches!(meta[root].kind, Kind::Pinned(_) | Kind::Persistent(_)) {
                            let u = uses.entry(root).or_insert(0);
                            *u = (*u).max(step_no);
                        }
                    }
                    if node.in_place() {
                        // Pairwise in-place: output j updates input j's
                        // storage in place, so each becomes an alias of its
                        // persistent root and consumers (sdpa) bind the
                        // session's cache buffer directly — nothing
                        // materializes. The single-output cache_update is
                        // the 1-pair case; the batched cache_update carries
                        // one pair per slot.
                        if node.outputs.len() > node.inputs.len() {
                            return Err(Error::Graph(format!(
                                "{}: in-place node needs one state input per output",
                                node.name
                            )));
                        }
                        for (j, spec) in prep.outputs.iter().enumerate() {
                            let state = node.inputs[j].0;
                            let (root, off) = resolve(&meta, state);
                            if !matches!(meta[root].kind, Kind::Persistent(_)) || off != 0 {
                                return Err(Error::Graph(format!(
                                    "{}: in-place state {j} must be a whole persistent value",
                                    node.name
                                )));
                            }
                            if spec.shape != meta[root].shape {
                                return Err(Error::Graph(format!(
                                    "{}: in-place output {j} shape {:?} != state shape {:?}",
                                    node.name, spec.shape, meta[root].shape
                                )));
                            }
                            meta[node.outputs[j].0] = ValueMeta {
                                kind: Kind::Alias { root, offset: 0 },
                                shape: spec.shape.clone(),
                                dtype: spec.dtype,
                                size: spec.size_bytes(),
                            };
                        }
                    } else {
                        for (j, spec) in prep.outputs.iter().enumerate() {
                            let v = node.outputs[j].0;
                            meta[v] = ValueMeta {
                                kind: Kind::Root,
                                shape: spec.shape.clone(),
                                dtype: spec.dtype,
                                size: spec.size_bytes(),
                            };
                            defs.insert(v, step_no);
                        }
                    }
                    proto.push(ProtoStep::Kernel(ni));
                }
                OpKind::Host(op) => match op {
                    HostOp::Embed => {
                        return Err(Error::Graph(
                            "Embed host op not graph-executable".into(),
                        ));
                    }
                    HostOp::SplitKv => {
                        let src = node.inputs[0].0;
                        let m = &meta[src];
                        if m.size == 0 || m.shape.len() != 2 || m.shape[1] % 2 != 0 {
                            return Err(Error::Graph(format!(
                                "{}: split_kv expects a typed [1, 2k] value, got {:?}",
                                node.name, m.shape
                            )));
                        }
                        let half_cols = m.shape[1] / 2;
                        let half_bytes = m.size / 2;
                        let dtype = m.dtype;
                        let (root, base) = resolve(&meta, src);
                        for (j, &out) in node.outputs.iter().enumerate() {
                            meta[out.0] = ValueMeta {
                                kind: Kind::Alias { root, offset: base + j * half_bytes },
                                shape: vec![1, half_cols],
                                dtype,
                                size: half_bytes,
                            };
                            aliased_values += 1;
                        }
                    }
                    HostOp::ToHeads { heads, head_dim } => {
                        let src = node.inputs[0].0;
                        let m = &meta[src];
                        let numel: usize = m.shape.iter().product();
                        if m.size == 0 || numel != heads * head_dim {
                            return Err(Error::Graph(format!(
                                "{}: to_heads({heads},{head_dim}) on shape {:?}",
                                node.name, m.shape
                            )));
                        }
                        let (dtype, size) = (m.dtype, m.size);
                        let (root, base) = resolve(&meta, src);
                        meta[node.outputs[0].0] = ValueMeta {
                            kind: Kind::Alias { root, offset: base },
                            shape: vec![*heads, *head_dim],
                            dtype,
                            size,
                        };
                        aliased_values += 1;
                    }
                    HostOp::FromHeads => {
                        let src = node.inputs[0].0;
                        let m = &meta[src];
                        if m.size == 0 {
                            return Err(Error::Graph(format!(
                                "{}: from_heads on untyped value",
                                node.name
                            )));
                        }
                        let numel: usize = m.shape.iter().product();
                        let (dtype, size) = (m.dtype, m.size);
                        let (root, base) = resolve(&meta, src);
                        meta[node.outputs[0].0] = ValueMeta {
                            kind: Kind::Alias { root, offset: base },
                            shape: vec![1, numel],
                            dtype,
                            size,
                        };
                        aliased_values += 1;
                    }
                    HostOp::Halves => {
                        let src = node.inputs[0].0;
                        let m = meta[src].clone();
                        if m.size == 0 || m.shape.len() != 2 || m.shape[1] % 2 != 0 {
                            return Err(Error::Graph(format!(
                                "{}: halves expects a typed [h, 2k] value, got {:?}",
                                node.name, m.shape
                            )));
                        }
                        let (rows, cols) = (m.shape[0], m.shape[1]);
                        let (root, _) = resolve(&meta, src);
                        if matches!(meta[root].kind, Kind::Pinned(_)) {
                            return Err(Error::Graph(format!(
                                "{}: halves of a pinned weight is unsupported",
                                node.name
                            )));
                        }
                        for &out in &node.outputs {
                            meta[out.0] = ValueMeta {
                                kind: Kind::Root,
                                shape: vec![rows, cols / 2],
                                dtype: m.dtype,
                                size: m.size / 2,
                            };
                            defs.insert(out.0, step_no);
                        }
                        let u = uses.entry(root).or_insert(0);
                        *u = (*u).max(step_no);
                        proto.push(ProtoStep::Halves(ni));
                    }
                },
            }
        }

        let n_steps = proto.len();

        // Graph outputs: logits is ring-backed (it must survive until the
        // caller's deferred map_read); everything else is read at replay
        // end and its slot extends to n_steps + 1.
        let logits_vid = graph.outputs.get("logits").map(|v| v.0);
        let mut logits_root: Option<usize> = None;
        if let Some(lv) = logits_vid {
            let (root, off) = resolve(&meta, lv);
            if off != 0 || !matches!(meta[root].kind, Kind::Root) || !defs.contains_key(&root) {
                return Err(Error::Graph(
                    "logits output must be a whole kernel-produced value".into(),
                ));
            }
            if uses.contains_key(&root) {
                return Err(Error::Graph(
                    "logits output consumed by a later step is unsupported".into(),
                ));
            }
            logits_root = Some(root);
        }
        let mut resident_outputs: Vec<(String, usize)> = Vec::new();
        for (name, &vid) in &graph.outputs {
            if Some(vid.0) == logits_vid {
                continue;
            }
            let m = &meta[vid.0];
            if m.size == 0 {
                return Err(Error::Graph(format!("output '{name}' never produced")));
            }
            let (root, _) = resolve(&meta, vid.0);
            if matches!(meta[root].kind, Kind::Pinned(_)) {
                return Err(Error::Graph(format!(
                    "output '{name}' aliases a pinned weight"
                )));
            }
            if let Kind::Persistent(idx) = meta[root].kind {
                // Device-resident output: lives in the session's cache
                // buffer, never read back on the hot path.
                resident_outputs.push((name.clone(), idx));
                continue;
            }
            let u = uses.entry(root).or_insert(0);
            *u = (*u).max(n_steps + 1);
        }
        resident_outputs.sort();

        // Liveness roots -> arena slots. Skip pinned values and the
        // ring-backed logits root.
        let mut roots: Vec<(usize, usize, Interval)> = Vec::new();
        for (v, m) in meta.iter().enumerate() {
            if !matches!(m.kind, Kind::Root) || m.size == 0 {
                continue;
            }
            if Some(v) == logits_root {
                continue;
            }
            let def = defs.get(&v).copied().unwrap_or(0);
            let last_use = uses.get(&v).copied().unwrap_or(def);
            roots.push((v, m.size, Interval { def, last_use }));
        }
        let arena = assign_slots(&roots, n_steps);

        // Resolve a value into a binding.
        let bind_value = |meta: &[ValueMeta],
                          arena: &ArenaLayout,
                          v: usize,
                          size: usize|
         -> Result<Binding> {
            let (root, offset) = resolve(meta, v);
            match meta[root].kind {
                Kind::Pinned(buffer) => Ok(Binding::Pinned { buffer, offset, size }),
                Kind::Persistent(idx) => Ok(Binding::Persistent { idx, offset, size }),
                Kind::Root => {
                    if Some(root) == logits_root {
                        return Ok(Binding::Ring);
                    }
                    let slot = *arena.value_slot.get(&root).ok_or_else(|| {
                        Error::Graph(format!("value {root} has no arena slot"))
                    })?;
                    Ok(Binding::Arena(SlotRef { slot, offset, size }))
                }
                _ => Err(Error::Graph(format!("value {v} resolves to non-storage"))),
            }
        };

        // Emit the final steps.
        let mut steps: Vec<Step> = Vec::with_capacity(proto.len());
        let mut logits_step: Option<usize> = None;
        for p in &proto {
            match *p {
                ProtoStep::Kernel(ni) => {
                    let node = &graph.nodes[ni];
                    let kname = match &node.op {
                        OpKind::Kernel(k) | OpKind::InPlaceKernel(k) => k.clone(),
                        OpKind::Host(_) => unreachable!("proto kernel step is a kernel node"),
                    };
                    let prep = pipelines.get(&kname).ok_or_else(|| {
                        Error::Internal(format!(
                            "kernel {kname} missing from prepared pipeline pool"
                        ))
                    })?;
                    let mut bindings = Vec::with_capacity(node.inputs.len() + node.outputs.len());
                    for (i, spec) in prep.inputs.iter().enumerate() {
                        bindings.push(bind_value(
                            &meta,
                            &arena,
                            node.inputs[i].0,
                            spec.size_bytes(),
                        )?);
                    }
                    for (j, spec) in prep.outputs.iter().enumerate() {
                        let b = bind_value(&meta, &arena, node.outputs[j].0, spec.size_bytes())?;
                        if matches!(b, Binding::Ring) {
                            logits_step = Some(steps.len());
                        }
                        bindings.push(b);
                    }
                    steps.push(Step::Dispatch(DispatchStep {
                        name: node.name.clone(),
                        kernel: kname,
                        pipeline: prep.pipeline,
                        layout: prep.layout,
                        bindings,
                        grid: prep.grid,
                    }));
                }
                ProtoStep::Halves(ni) => {
                    let node = &graph.nodes[ni];
                    let src_v = node.inputs[0].0;
                    let (root, offset) = resolve(&meta, src_v);
                    let src_meta = &meta[src_v];
                    let slot = *arena.value_slot.get(&root).ok_or_else(|| {
                        Error::Graph(format!("halves src value {root} has no arena slot"))
                    })?;
                    let src = SlotRef { slot, offset, size: src_meta.size };
                    let rows = src_meta.shape[0];
                    let row_bytes = src_meta.size / rows;
                    let mut dst = [SlotRef { slot: 0, offset: 0, size: 0 }; 2];
                    for (j, &out) in node.outputs.iter().enumerate() {
                        let oslot = *arena.value_slot.get(&out.0).ok_or_else(|| {
                            Error::Graph(format!("halves dst value {} has no slot", out.0))
                        })?;
                        dst[j] = SlotRef { slot: oslot, offset: 0, size: meta[out.0].size };
                    }
                    steps.push(Step::Host(HostStep {
                        name: node.name.clone(),
                        op: HostOp::Halves,
                        src,
                        rows,
                        row_bytes,
                        dst,
                    }));
                }
            }
        }

        // Uploads: non-pinned graph inputs, name-sorted for determinism.
        let mut input_names: Vec<&String> = graph.inputs.keys().collect();
        input_names.sort();
        let mut uploads = Vec::new();
        for name in input_names {
            let vid = graph.inputs[name];
            let m = &meta[vid.0];
            if matches!(m.kind, Kind::Pinned(_) | Kind::Persistent(_)) || m.size == 0 {
                continue; // pinned weight, resident cache, or never consumed
            }
            let slot = *arena.value_slot.get(&vid.0).ok_or_else(|| {
                Error::Graph(format!("input '{name}' has no arena slot"))
            })?;
            uploads.push(Upload {
                name: name.clone(),
                dst: SlotRef { slot, offset: 0, size: m.size },
                shape: m.shape.clone(),
                dtype: m.dtype,
            });
        }

        // Readbacks: every named output except the ring-backed logits.
        let mut out_names: Vec<&String> = graph.outputs.keys().collect();
        out_names.sort();
        let mut readbacks = Vec::new();
        let mut logits = None;
        for name in out_names {
            let vid = graph.outputs[name];
            let m = &meta[vid.0];
            if Some(vid.0) == logits_vid {
                logits = Some(LogitsSpec {
                    name: name.clone(),
                    size: m.size,
                    shape: m.shape.clone(),
                    dtype: m.dtype,
                });
                continue;
            }
            let (root, offset) = resolve(&meta, vid.0);
            if matches!(meta[root].kind, Kind::Persistent(_)) {
                continue; // device-resident, listed in resident_outputs
            }
            let slot = *arena.value_slot.get(&root).ok_or_else(|| {
                Error::Graph(format!("output '{name}' has no arena slot"))
            })?;
            readbacks.push(Readback {
                name: name.clone(),
                src: SlotRef { slot, offset, size: m.size },
                shape: m.shape.clone(),
                dtype: m.dtype,
            });
        }
        if logits_vid.is_some() && logits_step.is_none() {
            return Err(Error::Graph("logits step not located in plan".into()));
        }

        // Persistent specs, in the graph's declaration order (typed by
        // their first consumer above).
        let mut persistent = Vec::with_capacity(graph.persistent.len());
        for (idx, name) in graph.persistent.iter().enumerate() {
            let vid = graph.inputs[name];
            let m = &meta[vid.0];
            debug_assert!(matches!(m.kind, Kind::Persistent(i) if i == idx));
            if m.size == 0 {
                return Err(Error::Graph(format!(
                    "persistent input '{name}' never consumed (untyped)"
                )));
            }
            persistent.push(PersistentSpec {
                name: name.clone(),
                shape: m.shape.clone(),
                dtype: m.dtype,
                size: m.size,
            });
        }

        let stats = PlanStats {
            kernel_steps: steps
                .iter()
                .filter(|s| matches!(s, Step::Dispatch(_)))
                .count(),
            host_steps: steps.iter().filter(|s| matches!(s, Step::Host(_))).count(),
            aliased_values,
            arena_slots: arena.slot_sizes.len(),
            arena_bytes: arena.arena_bytes(),
            unaliased_bytes: arena.unaliased_bytes(),
            persistent_values: persistent.len(),
            step_inputs: uploads.len(),
            upload_bytes_per_step: uploads.iter().map(|u| u.dst.size).sum(),
            resident_bytes: persistent.iter().map(|p| p.size).sum(),
        };

        Ok(ExecutionPlan {
            steps,
            arena,
            uploads,
            readbacks,
            persistent,
            resident_outputs,
            logits,
            logits_step,
            dispatches_per_submit: cfg.dispatches_per_submit.max(1),
            framework_ns_per_step: cfg.framework_ns_per_step,
            logits_ring: cfg.logits_ring.max(1),
            fingerprint: GraphFingerprint::of(graph),
            stats,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::fx::builder::{build_decode_graph, FusionConfig, GraphDims};
    use crate::webgpu::ImplementationProfile;

    fn compile(fusion: FusionConfig) -> ExecutionPlan {
        let reg = Registry::builtin().unwrap();
        let mut device = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = PipelinePool::new();
        let g = build_decode_graph(&GraphDims::qwen_tiny(), fusion);
        Planner::new(&reg)
            .compile(&mut device, &mut pool, &g, &HashMap::new(), &PlanConfig::default())
            .unwrap()
    }

    #[test]
    fn fused_plan_has_one_step_per_dispatch_and_no_host_steps() {
        let g = build_decode_graph(&GraphDims::qwen_tiny(), FusionConfig::fused());
        let plan = compile(FusionConfig::fused());
        assert_eq!(plan.stats.kernel_steps, g.dispatch_count());
        // Fused graphs only carry alias-able shape ops (kv_split, heads).
        assert_eq!(plan.stats.host_steps, 0);
        assert!(plan.stats.aliased_values > 0);
        assert!(plan.logits.is_some() && plan.logits_step.is_some());
    }

    #[test]
    fn unfused_plan_materializes_only_halves() {
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let plan = compile(FusionConfig::unfused());
        assert_eq!(plan.stats.kernel_steps, g.dispatch_count());
        // One halves per rotary application: 2 per layer.
        assert_eq!(plan.stats.host_steps, 2 * dims.layers);
    }

    #[test]
    fn aliasing_packs_the_arena_below_one_buffer_per_value() {
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let plan = compile(fusion);
            assert!(
                plan.stats.arena_bytes < plan.stats.unaliased_bytes,
                "{fusion:?}: arena {} !< unaliased {}",
                plan.stats.arena_bytes,
                plan.stats.unaliased_bytes
            );
            assert!(plan.stats.arena_slots < plan.arena.assignments.len());
        }
    }

    #[test]
    fn caches_resident_uploads_step_inputs_only_logits_ring_backed() {
        // Pin every weight input the way the engine does, so uploads are
        // exactly the per-step values.
        let reg = Registry::builtin().unwrap();
        let mut device = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = PipelinePool::new();
        let dims = GraphDims::qwen_tiny();
        let g = build_decode_graph(&dims, FusionConfig::fused());
        let per_step = ["x", "pos_i", "pos_ip1", "pos_f", "inv_freq"];
        let mut pinned = HashMap::new();
        for (name, &vid) in &g.inputs {
            if per_step.contains(&name.as_str()) || name.ends_with("_cache") {
                continue;
            }
            let buf = device
                .create_buffer(crate::webgpu::BufferDesc {
                    label: format!("w-{name}"),
                    size: 1 << 20,
                    usage: crate::webgpu::BufferUsage::STORAGE
                        | crate::webgpu::BufferUsage::COPY_DST,
                })
                .unwrap();
            pinned.insert(vid, buf);
        }
        let plan = Planner::new(&reg)
            .compile(&mut device, &mut pool, &g, &pinned, &PlanConfig::default())
            .unwrap();
        // KV caches are persistent: no per-step readback, no per-step
        // upload — they live in session-owned buffers and the updated
        // cache outputs stay device-resident.
        assert_eq!(plan.readbacks.len(), 0);
        assert_eq!(plan.stats.persistent_values, 2 * dims.layers);
        assert_eq!(plan.resident_outputs.len(), 2 * dims.layers);
        assert_eq!(plan.persistent.len(), 2 * dims.layers);
        // Layer-major cache-set layout.
        assert_eq!(plan.persistent[0].name, "l0.k_cache");
        assert_eq!(plan.persistent[1].name, "l0.v_cache");
        for p in &plan.persistent {
            assert_eq!(p.shape, vec![dims.max_seq, dims.kv_heads, dims.head_dim]);
        }
        assert_eq!(
            plan.stats.resident_bytes,
            2 * dims.layers * dims.max_seq * dims.kv_heads * dims.head_dim * 4
        );
        let lg = plan.logits.as_ref().unwrap();
        assert_eq!(lg.shape, vec![1, dims.vocab]);
        assert_eq!(lg.size, dims.vocab * 4);
        // Uploads are ONLY the step inputs: x, 3 pos uniforms, inv_freq.
        assert_eq!(plan.uploads.len(), 5);
        assert_eq!(plan.stats.step_inputs, 5);
        // Per-token host traffic no longer scales with max_seq: token
        // embedding + uniforms + rope frequencies only.
        let expect_bytes = dims.hidden * 4 + 3 * 4 + (dims.head_dim / 2) * 4;
        assert_eq!(plan.stats.upload_bytes_per_step, expect_bytes);
        assert!(plan.stats.upload_bytes_per_step * 10 < plan.stats.resident_bytes);
    }

    #[test]
    fn input_residency_classifies_caches_and_step_inputs() {
        use crate::plan::residency::ResidencyClass;
        let plan = compile(FusionConfig::fused());
        assert_eq!(
            plan.input_residency("l0.k_cache"),
            Some(ResidencyClass::Persistent)
        );
        assert_eq!(plan.input_residency("x"), Some(ResidencyClass::StepInput));
        assert_eq!(plan.input_residency("pos_i"), Some(ResidencyClass::StepInput));
        assert_eq!(plan.input_residency("nope"), None);
    }

    #[test]
    fn batched_graph_compiles_with_slot_major_cache_table() {
        use crate::fx::builder::build_batched_decode_graph;
        use crate::plan::batched::validate_batched_plan;
        let width = 4usize;
        let reg = Registry::builtin().unwrap();
        let mut device = Device::new(ImplementationProfile::zero_overhead());
        // Batched cache ops bind 2W+5 storage buffers: the serving engine
        // requests raised limits (requiredLimits) before compiling.
        device.limits.max_bindings_per_group = 2 * width + 5;
        let mut pool = PipelinePool::new();
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let g = build_batched_decode_graph(&dims, fusion, width);
            let plan = Planner::new(&reg)
                .compile(&mut device, &mut pool, &g, &HashMap::new(), &PlanConfig::default())
                .unwrap();
            validate_batched_plan(&plan, width).unwrap();
            assert_eq!(plan.stats.kernel_steps, g.dispatch_count(), "{fusion:?}");
            // Slot-major cache-set table: W slots x 2L caches each, every
            // slot shaped exactly like a single session's set.
            assert_eq!(plan.persistent.len(), width * 2 * dims.layers);
            assert_eq!(plan.persistent[0].name, "s0.l0.k_cache");
            assert_eq!(plan.persistent[2 * dims.layers].name, "s1.l0.k_cache");
            for p in &plan.persistent {
                assert_eq!(p.shape, vec![dims.max_seq, dims.kv_heads, dims.head_dim]);
            }
            // Logits pack one row per slot; cache outputs stay resident.
            assert_eq!(
                plan.logits.as_ref().unwrap().shape,
                vec![width, dims.vocab]
            );
            assert_eq!(plan.resident_outputs.len(), width * 2 * dims.layers);
            // The wrong width is rejected (2L per slot won't divide).
            assert!(validate_batched_plan(&plan, 3).is_err());
        }
    }

    #[test]
    fn cache_update_binds_same_persistent_index_in_and_out() {
        let plan = compile(FusionConfig::fused());
        let mut checked = 0;
        for step in &plan.steps {
            let Step::Dispatch(d) = step else { continue };
            if !d.name.contains("cache_update") {
                continue;
            }
            // Bindings: [cache_in, row, pos, cache_out] — first and last
            // must hit the same session cache buffer.
            let Binding::Persistent { idx: i_in, offset: 0, .. } = d.bindings[0] else {
                panic!("{}: input 0 not persistent: {:?}", d.name, d.bindings[0]);
            };
            let Binding::Persistent { idx: i_out, offset: 0, .. } =
                d.bindings[d.bindings.len() - 1]
            else {
                panic!("{}: output not persistent", d.name);
            };
            assert_eq!(i_in, i_out, "{}: in-place update must alias", d.name);
            checked += 1;
        }
        assert_eq!(checked, 2 * GraphDims::qwen_tiny().layers);
    }
}

//! Chunked-prefill plan execution: one replay ingests a whole prompt chunk.
//!
//! A [`PrefillRunner`] wraps a [`PlanRunner`] compiled from the prefill
//! graph ([`crate::fx::build_prefill_graph`]) at a fixed sequence chunk
//! `C`. Its persistent cache layout is IDENTICAL to the single-session
//! decode plan's (layer-major `l{l}.{k,v}_cache`), so the session's
//! [`DeviceKvCache`] plugs into both plans — the prefill chunk scatters C
//! rows per layer per dispatch into the same device buffers the decode
//! replays then read, with no copies and no re-registration beyond the
//! runner's own per-cache-set bind groups.
//!
//! Ragged final chunks (fewer prompt tokens than `C`) replay the SAME
//! plan: the `valid_len` uniform masks the tail rows out of the cache
//! scatter and the causal attention, so no recompile and no second
//! pipeline set exist for short prompts — the property the prefill tests
//! pin alongside bit-identity with token-by-token ingestion.

use std::collections::HashMap;

use crate::tensor::Tensor;
use crate::webgpu::{BufferId, Device, KernelRunner};
use crate::{Error, Result};

use super::planner::ExecutionPlan;
use super::residency::DeviceKvCache;
use super::runner::{validate_paged_persistent, PlanRunner, ReplayDelta};

/// Chunk-shape consistency checks for a plan compiled from a prefill
/// graph: chunk-leading `x` upload, the pos_base/valid_len uniforms, a
/// resident cache set, and the single-row logits contract.
pub fn validate_prefill_plan(plan: &ExecutionPlan, chunk: usize) -> Result<()> {
    if chunk < 2 {
        return Err(Error::Graph(format!("prefill plans need chunk >= 2, got {chunk}")));
    }
    if plan.persistent.is_empty() {
        return Err(Error::Graph(
            "prefill plan: no persistent cache values (prefill scatters into a \
             resident session cache set)"
            .into(),
        ));
    }
    let x = plan
        .uploads
        .iter()
        .find(|u| u.name == "x")
        .ok_or_else(|| Error::Graph("prefill plan: step input 'x' missing".into()))?;
    if x.shape.first().copied() != Some(chunk) {
        return Err(Error::Graph(format!(
            "prefill plan: step input 'x' shape {:?} lacks leading chunk {chunk}",
            x.shape
        )));
    }
    for name in ["pos_f", "pos_base", "valid_len"] {
        if !plan.uploads.iter().any(|u| u.name == name) {
            return Err(Error::Graph(format!(
                "prefill plan: step input '{name}' missing"
            )));
        }
    }
    match &plan.logits {
        // Last-row tail: only the selected last row is read back, whatever
        // the chunk. Multi-row (speculative verify) tail: every chunk row
        // is scored, so the logits block is chunk-leading.
        Some(lg) if lg.shape.first().copied() == Some(1) => {}
        Some(lg) if lg.shape.first().copied() == Some(chunk) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "prefill plan: logits shape {:?} must be the selected last row \
                 [1, vocab] or the multi-row [chunk, vocab]",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("prefill plan: no logits output".into())),
    }
    Ok(())
}

/// Consistency checks for a plan compiled from a PAGED prefill graph: the
/// shared pool planes replace the per-session cache set, and ONE session's
/// block table (a `[table_len]` step input) routes the chunk's scatter.
pub fn validate_prefill_plan_paged(plan: &ExecutionPlan, chunk: usize) -> Result<()> {
    if chunk < 2 {
        return Err(Error::Graph(format!("prefill plans need chunk >= 2, got {chunk}")));
    }
    validate_paged_persistent(plan)?;
    let x = plan
        .uploads
        .iter()
        .find(|u| u.name == "x")
        .ok_or_else(|| Error::Graph("paged prefill plan: step input 'x' missing".into()))?;
    if x.shape.first().copied() != Some(chunk) {
        return Err(Error::Graph(format!(
            "paged prefill plan: step input 'x' shape {:?} lacks leading chunk {chunk}",
            x.shape
        )));
    }
    for name in ["pos_f", "pos_base", "valid_len"] {
        if !plan.uploads.iter().any(|u| u.name == name) {
            return Err(Error::Graph(format!(
                "paged prefill plan: step input '{name}' missing"
            )));
        }
    }
    match &plan.logits {
        Some(lg) if lg.shape.first().copied() == Some(1) => {}
        Some(lg) if lg.shape.first().copied() == Some(chunk) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "paged prefill plan: logits shape {:?} must be the selected last \
                 row [1, vocab] or the multi-row [chunk, vocab]",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("paged prefill plan: no logits output".into())),
    }
    Ok(())
}

/// Replays a prefill plan: one chunk of ONE session's prompt per replay.
pub struct PrefillRunner {
    runner: PlanRunner,
    chunk: usize,
    /// Prefill chunk replays executed.
    pub chunks: u64,
}

impl PrefillRunner {
    /// Validate the plan's chunk shape and materialize the inner runner
    /// (arena, logits ring, bind groups).
    pub fn materialize(device: &mut Device, plan: ExecutionPlan, chunk: usize) -> Result<Self> {
        validate_prefill_plan(&plan, chunk)?;
        let runner = PlanRunner::materialize(device, plan)?;
        Ok(PrefillRunner { runner, chunk, chunks: 0 })
    }

    /// Materialize a PAGED prefill runner: the plan's persistent list is
    /// the shared pool planes (`pool`), registered once here and installed
    /// as the runner's default cache set — replays pass `kv: None` and the
    /// uploaded block table routes the chunk into the session's blocks.
    pub fn materialize_paged(
        device: &mut Device,
        plan: ExecutionPlan,
        chunk: usize,
        pool: &DeviceKvCache,
    ) -> Result<Self> {
        validate_prefill_plan_paged(&plan, chunk)?;
        let mut runner = PlanRunner::materialize(device, plan)?;
        runner.register_cache(device, pool)?;
        runner.set_default_cache(pool.clone())?;
        Ok(PrefillRunner { runner, chunk, chunks: 0 })
    }

    /// Prompt positions one replay ingests (the ragged final chunk passes
    /// a smaller `valid_len` instead of recompiling).
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.runner.plan
    }

    pub fn inner(&self) -> &PlanRunner {
        &self.runner
    }

    pub fn inner_mut(&mut self) -> &mut PlanRunner {
        &mut self.runner
    }

    /// Wire a session's cache set into the prefill plan's persistent
    /// steps. Idempotent per buffer set, exactly like the decode runner's
    /// [`PlanRunner::register_cache`] — recycled sets are pure cache hits.
    pub fn register_cache(&mut self, device: &mut Device, kv: &DeviceKvCache) -> Result<()> {
        self.runner.register_cache(device, kv)
    }

    /// True for buffers the prefill runner owns (its logits ring) — they
    /// must never be released into the pooled free lists.
    pub fn owns_buffer(&self, buf: BufferId) -> bool {
        self.runner.owns_buffer(buf)
    }

    /// Replay one prompt chunk: `inputs` are the packed step inputs
    /// (`x [C, H]`, `pos_f [C]`, `pos_base`/`valid_len` uniforms,
    /// `inv_freq`); `kv` is the session's resident cache set; `ring_idx`
    /// selects the logits-ring buffer (each prefill session of a round
    /// passes its own index so a final chunk's logits survive until the
    /// round's coalesced readback). Returns (named outputs, the live
    /// logits buffer — only worth mapping for FINAL chunks — and cost
    /// deltas).
    pub fn replay(
        &mut self,
        device: &mut Device,
        runner: &dyn KernelRunner,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        kv: Option<&DeviceKvCache>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let out = self.runner.replay(device, runner, inputs, ring_idx, kv)?;
        self.chunks += 1;
        Ok(out)
    }
}

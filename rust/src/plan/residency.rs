//! Value residency: which storage class each graph value lives in, and the
//! per-session cache arena that backs the `Persistent` class.
//!
//! The planner classifies every value of a decode graph into one of three
//! residency classes ([`ResidencyClass`]):
//!
//! - **Transient** — intermediates produced and consumed inside one replay;
//!   they live in the plan's lifetime-aliased arena slots.
//! - **StepInput** — per-step host inputs (token embedding, position
//!   uniforms, rope frequencies): the only bytes that cross the bus per
//!   token once caches are resident.
//! - **Persistent** — session state that survives across decode steps (the
//!   KV caches): bound to *session-owned* device buffers and updated in
//!   place by `cache_update` dispatches, never uploaded or read back on the
//!   hot path.
//!
//! The [`CacheArena`] allocates one [`DeviceKvCache`] per session from the
//! shared bounded [`BufferPool`] — so cache memory honors the same byte cap
//! and high-water accounting as every other pooled allocation, and a
//! retired session's cache buffers are immediately reusable by the next
//! admit. Buffers are released in reverse acquisition order so the pool's
//! LIFO free lists hand the *same* buffers (in the same order) to the next
//! session, keeping the runner's per-cache-set bind groups cache-hot.

use crate::tensor::{DType, Tensor};
use crate::webgpu::{BufferId, BufferPool, Device};
use crate::{Error, Result};

/// Storage class of one graph value in a compiled plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidencyClass {
    /// Replay-local intermediate: lives in a lifetime-aliased arena slot.
    Transient,
    /// Per-step host upload (token embedding, position uniforms).
    StepInput,
    /// Session-owned device-resident state (KV caches).
    Persistent,
}

/// One persistent value's contract: its graph input name and typed layout.
/// Order within [`crate::plan::ExecutionPlan::persistent`] follows the
/// graph's declaration order (layer-major `l{i}.k_cache`, `l{i}.v_cache`
/// for the decode builder).
#[derive(Debug, Clone, PartialEq)]
pub struct PersistentSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub size: usize,
}

/// A session's device-resident cache set: one buffer per persistent value,
/// in plan order. Owned by the session (via `serve::KvCache`), allocated
/// and released through the [`CacheArena`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceKvCache {
    /// One device buffer per persistent spec, same order.
    pub buffers: Vec<BufferId>,
    /// Total device bytes held by this cache set.
    pub resident_bytes: usize,
}

impl DeviceKvCache {
    pub fn buffer(&self, idx: usize) -> Option<BufferId> {
        self.buffers.get(idx).copied()
    }
}

/// Counters for cache-set lifecycle (leak detection rides these plus the
/// shared pool's high-water stats).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheArenaStats {
    pub sets_allocated: u64,
    pub sets_released: u64,
    /// Device bytes per cache set (layers x 2 x max_seq x kv_heads x
    /// head_dim x 4 for the decode builder).
    pub resident_bytes_per_set: usize,
}

impl CacheArenaStats {
    /// Cache sets currently held by live sessions.
    pub fn sets_live(&self) -> u64 {
        self.sets_allocated - self.sets_released
    }
}

/// Per-session cache allocator over the shared bounded buffer pool.
#[derive(Debug, Clone)]
pub struct CacheArena {
    specs: Vec<PersistentSpec>,
    stats: CacheArenaStats,
}

impl CacheArena {
    pub fn new(specs: Vec<PersistentSpec>) -> Self {
        let resident: usize = specs.iter().map(|s| s.size).sum();
        CacheArena {
            specs,
            stats: CacheArenaStats { resident_bytes_per_set: resident, ..Default::default() },
        }
    }

    pub fn specs(&self) -> &[PersistentSpec] {
        &self.specs
    }

    pub fn stats(&self) -> CacheArenaStats {
        self.stats
    }

    /// Allocate a zeroed cache set for a new session. Buffers come from the
    /// shared pool (honoring its byte cap); recycled buffers are cleared
    /// device-side so no state leaks across sessions.
    pub fn allocate(&mut self, device: &mut Device, pool: &mut BufferPool) -> Result<DeviceKvCache> {
        let mut buffers = Vec::with_capacity(self.specs.len());
        for spec in &self.specs {
            // Acquire, then zero; the buffer joins the partial set before
            // the clear so BOTH failure modes unwind through it.
            let res = pool.acquire(device, spec.size).and_then(|b| {
                buffers.push(b);
                device.clear_buffer(b)
            });
            if let Err(e) = res {
                // Unwind the partial set so a failed admit leaks nothing —
                // in reverse, like a full release, so the pool's LIFO free
                // lists keep handing out the same buffer order (the
                // bind-group cache key).
                for (b, s) in buffers.iter().zip(&self.specs).rev() {
                    pool.release(s.size, *b);
                }
                return Err(e);
            }
        }
        self.stats.sets_allocated += 1;
        Ok(DeviceKvCache { buffers, resident_bytes: self.stats.resident_bytes_per_set })
    }

    /// Return a cache set to the pool. Reverse order keeps the pool's LIFO
    /// free lists aligned so the next allocate sees the same buffer order.
    /// Errors (releasing nothing) if the set does not match this arena's
    /// specs — a silent partial release would defeat the leak accounting.
    pub fn release(&mut self, pool: &mut BufferPool, cache: DeviceKvCache) -> Result<()> {
        if cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache set has {} buffers, arena expects {}",
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        for (buf, spec) in cache.buffers.iter().zip(&self.specs).rev() {
            pool.release(spec.size, *buf);
        }
        self.stats.sets_released += 1;
        Ok(())
    }

    /// Spill a cache set to host tensors (eviction), in spec order. A real
    /// device->host readback: the whole set is mapped behind ONE
    /// synchronization point (`map_read_many`), so the spill's sync +
    /// per-byte transfer cost lands in the virtual cost model instead of
    /// moving O(layers x max_seq) bytes for free. The device buffers stay
    /// allocated — pair with [`CacheArena::release`] to free them.
    pub fn spill_to_host(&self, device: &mut Device, cache: &DeviceKvCache) -> Result<Vec<Tensor>> {
        if cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache set has {} buffers, arena expects {}",
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        let all = device.map_read_many(&cache.buffers)?;
        let mut out = Vec::with_capacity(self.specs.len());
        for (bytes, spec) in all.iter().zip(&self.specs) {
            out.push(Tensor::from_le_bytes(spec.shape.clone(), spec.dtype, &bytes[..spec.size])?);
        }
        Ok(out)
    }

    /// Upload host tensors (spec order) into a cache set — the restore half
    /// of the evict-to-host spill path. Takes references so a resume does
    /// not deep-copy the whole host KV state just to upload it.
    pub fn upload_from_host(
        &self,
        device: &mut Device,
        cache: &DeviceKvCache,
        tensors: &[&Tensor],
    ) -> Result<()> {
        if tensors.len() != self.specs.len() || cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache restore: {} tensors / {} buffers vs {} specs",
                tensors.len(),
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        for ((buf, spec), t) in cache.buffers.iter().zip(&self.specs).zip(tensors) {
            if t.shape != spec.shape {
                return Err(Error::Graph(format!(
                    "cache restore '{}': host shape {:?} != spec {:?}",
                    spec.name, t.shape, spec.shape
                )));
            }
            device.write_buffer(*buf, 0, t.data.as_bytes())?;
        }
        Ok(())
    }
}

// ------------------------------------------------------ paged KV residency --

/// One logical KV block-group of a paged session: block id `j` covers
/// logical token rows `[j*kv_block, (j+1)*kv_block)` of EVERY pool plane
/// (all layers' K and V at once — one table entry serves the whole layer
/// stack, so residency decisions are per token range, never per layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PagedSlot {
    /// Backed by physical block-group `g` of the shared pool planes.
    Resident(u32),
    /// Paged out: the group's bytes parked on the host, plane-major
    /// (layer-major `k`, `v` per layer — the same order as the pool's
    /// persistent list), `kv_block * kv_heads * head_dim * 4` bytes per
    /// plane slice.
    Host(Vec<u8>),
}

/// A paged session's KV state: one [`PagedSlot`] per allocated logical
/// block-group, in block order. Replaces the contiguous [`DeviceKvCache`]
/// when the engine runs paged; the block table uploaded per replay is
/// exactly `slots` mapped to `Resident(g) -> g`, `Host(_) -> -1`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PagedKv {
    pub slots: Vec<PagedSlot>,
    /// Pager LRU stamp: the round counter of the last encode chunk this
    /// session participated in. Cold sessions (smallest stamp) spill first.
    pub last_touch: u64,
}

impl PagedKv {
    pub fn resident_groups(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, PagedSlot::Resident(_))).count()
    }

    pub fn spilled_groups(&self) -> usize {
        self.slots.len() - self.resident_groups()
    }

    pub fn resident_bytes(&self, group_bytes: usize) -> usize {
        self.resident_groups() * group_bytes
    }

    pub fn spilled_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                PagedSlot::Resident(_) => 0,
                PagedSlot::Host(b) => b.len(),
            })
            .sum()
    }
}

/// Paged-pool counters exported into the serving report.
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockArenaStats {
    pub groups_allocated: u64,
    pub groups_freed: u64,
    /// Physical block-groups currently granted.
    pub live_groups: usize,
    /// Peak of `live_groups` — the pool high-water the serve header prints.
    pub high_water_groups: usize,
    /// Host -> device block restores (hydrates count here too).
    pub page_ins: u64,
    /// Device -> host block spills (full evicts count here too).
    pub page_outs: u64,
}

/// Allocator of physical block-group ids over the shared pool planes.
///
/// Physical capacity is `POOL_ROWS / kv_block` groups — sized so one full
/// encode chunk's worst-case working set (MAX_BATCH_WIDTH sessions at
/// max_seq) always fits, which is why admission under paging never fails
/// on memory: the pager only ever has to *defer and spill*, not reject.
/// A LOGICAL budget (from `--pool-cap-kv` or the nominal contiguous-set
/// equivalent) bounds steady-state residency below physical capacity; the
/// engine's pre-chunk pager evicts LRU non-participant blocks back under
/// budget after each round, so oversubscribed serving degrades to paging
/// instead of erroring. Free ids are LIFO so twin runs grant identical
/// block ids.
#[derive(Debug, Clone)]
pub struct BlockArena {
    free: Vec<u32>,
    capacity: usize,
    budget_groups: usize,
    group_bytes: usize,
    stats: BlockArenaStats,
}

impl BlockArena {
    pub fn new(capacity: usize, budget_groups: usize, group_bytes: usize) -> Self {
        // Reverse initial order so the first pops grant 0, 1, 2, ...
        let free: Vec<u32> = (0..capacity as u32).rev().collect();
        BlockArena {
            free,
            capacity,
            budget_groups: budget_groups.min(capacity).max(1),
            group_bytes,
            stats: BlockArenaStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn budget_groups(&self) -> usize {
        self.budget_groups
    }

    pub fn group_bytes(&self) -> usize {
        self.group_bytes
    }

    pub fn live_groups(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Groups currently held beyond the logical budget (0 when under).
    pub fn over_budget(&self) -> usize {
        self.live_groups().saturating_sub(self.budget_groups)
    }

    pub fn stats(&self) -> BlockArenaStats {
        let mut s = self.stats;
        s.live_groups = self.live_groups();
        s
    }

    pub fn note_page_in(&mut self) {
        self.stats.page_ins += 1;
    }

    pub fn note_page_out(&mut self) {
        self.stats.page_outs += 1;
    }

    /// Grant a physical block-group id. Physical exhaustion is a hard
    /// error: the engine's pre-chunk pager must have spilled enough
    /// non-participants first (and capacity covers any single chunk's
    /// working set by construction, so hitting this is a pager bug).
    pub fn alloc(&mut self) -> Result<u32> {
        let g = self.free.pop().ok_or_else(|| {
            Error::LimitExceeded(format!(
                "paged KV pool physically exhausted ({} groups)",
                self.capacity
            ))
        })?;
        self.stats.groups_allocated += 1;
        self.stats.high_water_groups = self.stats.high_water_groups.max(self.live_groups());
        Ok(g)
    }

    /// Return a physical block-group id to the free list (LIFO).
    pub fn free_group(&mut self, g: u32) {
        debug_assert!((g as usize) < self.capacity && !self.free.contains(&g));
        self.free.push(g);
        self.stats.groups_freed += 1;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::webgpu::ImplementationProfile;

    fn arena(n: usize, size: usize) -> CacheArena {
        let specs = (0..n)
            .map(|i| PersistentSpec {
                name: format!("l{}.{}_cache", i / 2, if i % 2 == 0 { "k" } else { "v" }),
                shape: vec![size / 4],
                dtype: DType::F32,
                size,
            })
            .collect();
        CacheArena::new(specs)
    }

    #[test]
    fn allocate_release_reuses_same_buffers_in_order() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(4, 256);
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        let ids1 = set1.buffers.clone();
        a.release(&mut pool, set1).unwrap();
        let set2 = a.allocate(&mut d, &mut pool).unwrap();
        assert_eq!(set2.buffers, ids1, "reverse-release must preserve order");
        assert_eq!(pool.stats().created, 4, "second set fully recycled");
        assert_eq!(a.stats().sets_live(), 1);
    }

    #[test]
    fn recycled_buffers_are_zeroed() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(2, 64);
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        d.write_buffer(set1.buffers[0], 0, &[0xAB; 64]).unwrap();
        a.release(&mut pool, set1).unwrap();
        let set2 = a.allocate(&mut d, &mut pool).unwrap();
        let bytes = d.peek_buffer(set2.buffers[0]).unwrap();
        assert!(bytes.iter().all(|&b| b == 0), "stale session bytes leaked");
    }

    #[test]
    fn pool_cap_bounds_cache_sets_and_failed_allocate_leaks_nothing() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(Some(600));
        let mut a = arena(2, 256); // one set = 512 B
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        let err = a.allocate(&mut d, &mut pool);
        assert!(err.is_err(), "second set must exceed the 600 B cap");
        // The failed allocate returned its partial set to the pool.
        assert_eq!(pool.stats().outstanding_bytes, 512);
        a.release(&mut pool, set1).unwrap();
        assert_eq!(pool.stats().outstanding_bytes, 0);
        assert!(a.allocate(&mut d, &mut pool).is_ok(), "reuse within cap");
    }

    #[test]
    fn block_arena_grants_lifo_and_bounds_physical_capacity() {
        let mut a = BlockArena::new(4, 2, 1024);
        assert_eq!(a.budget_groups(), 2);
        let g0 = a.alloc().unwrap();
        let g1 = a.alloc().unwrap();
        assert_eq!((g0, g1), (0, 1), "first grants are 0, 1, ...");
        assert_eq!(a.over_budget(), 0);
        let g2 = a.alloc().unwrap();
        assert_eq!(a.over_budget(), 1, "third group exceeds the logical budget");
        a.free_group(g1);
        assert_eq!(a.alloc().unwrap(), 1, "freed ids are reused LIFO");
        let _g3 = a.alloc().unwrap();
        assert!(a.alloc().is_err(), "physical exhaustion is a hard error");
        let s = a.stats();
        assert_eq!(s.live_groups, 4);
        assert_eq!(s.high_water_groups, 4);
        assert_eq!(s.groups_allocated, 5);
        assert_eq!(s.groups_freed, 1);
        a.free_group(g2);
        a.free_group(g0);
        assert_eq!(a.stats().live_groups, 2);
    }

    #[test]
    fn paged_kv_accounts_resident_and_spilled_bytes() {
        let kv = PagedKv {
            slots: vec![
                PagedSlot::Host(vec![0u8; 128]),
                PagedSlot::Resident(3),
                PagedSlot::Resident(0),
            ],
            last_touch: 7,
        };
        assert_eq!(kv.resident_groups(), 2);
        assert_eq!(kv.spilled_groups(), 1);
        assert_eq!(kv.resident_bytes(128), 256);
        assert_eq!(kv.spilled_bytes(), 128);
    }

    #[test]
    fn spill_and_restore_round_trip() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(2, 64);
        let set = a.allocate(&mut d, &mut pool).unwrap();
        let t = Tensor::f32(vec![16], (0..16).map(|i| i as f32).collect()).unwrap();
        d.write_buffer(set.buffers[1], 0, t.data.as_bytes()).unwrap();
        let spilled = a.spill_to_host(&mut d, &set).unwrap();
        assert_eq!(spilled[1].as_f32().unwrap(), t.as_f32().unwrap());
        // Clear, then restore (by reference — no deep copy) and read back.
        d.clear_buffer(set.buffers[1]).unwrap();
        let refs: Vec<&Tensor> = spilled.iter().collect();
        a.upload_from_host(&mut d, &set, &refs).unwrap();
        let bytes = d.peek_buffer(set.buffers[1]).unwrap().to_vec();
        let back = Tensor::from_le_bytes(vec![16], DType::F32, &bytes).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }
}

//! Value residency: which storage class each graph value lives in, and the
//! per-session cache arena that backs the `Persistent` class.
//!
//! The planner classifies every value of a decode graph into one of three
//! residency classes ([`ResidencyClass`]):
//!
//! - **Transient** — intermediates produced and consumed inside one replay;
//!   they live in the plan's lifetime-aliased arena slots.
//! - **StepInput** — per-step host inputs (token embedding, position
//!   uniforms, rope frequencies): the only bytes that cross the bus per
//!   token once caches are resident.
//! - **Persistent** — session state that survives across decode steps (the
//!   KV caches): bound to *session-owned* device buffers and updated in
//!   place by `cache_update` dispatches, never uploaded or read back on the
//!   hot path.
//!
//! The [`CacheArena`] allocates one [`DeviceKvCache`] per session from the
//! shared bounded [`BufferPool`] — so cache memory honors the same byte cap
//! and high-water accounting as every other pooled allocation, and a
//! retired session's cache buffers are immediately reusable by the next
//! admit. Buffers are released in reverse acquisition order so the pool's
//! LIFO free lists hand the *same* buffers (in the same order) to the next
//! session, keeping the runner's per-cache-set bind groups cache-hot.

use crate::tensor::{DType, Tensor};
use crate::webgpu::{BufferId, BufferPool, Device};
use crate::{Error, Result};

/// Storage class of one graph value in a compiled plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidencyClass {
    /// Replay-local intermediate: lives in a lifetime-aliased arena slot.
    Transient,
    /// Per-step host upload (token embedding, position uniforms).
    StepInput,
    /// Session-owned device-resident state (KV caches).
    Persistent,
}

/// One persistent value's contract: its graph input name and typed layout.
/// Order within [`crate::plan::ExecutionPlan::persistent`] follows the
/// graph's declaration order (layer-major `l{i}.k_cache`, `l{i}.v_cache`
/// for the decode builder).
#[derive(Debug, Clone, PartialEq)]
pub struct PersistentSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub size: usize,
}

/// A session's device-resident cache set: one buffer per persistent value,
/// in plan order. Owned by the session (via `serve::KvCache`), allocated
/// and released through the [`CacheArena`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceKvCache {
    /// One device buffer per persistent spec, same order.
    pub buffers: Vec<BufferId>,
    /// Total device bytes held by this cache set.
    pub resident_bytes: usize,
}

impl DeviceKvCache {
    pub fn buffer(&self, idx: usize) -> Option<BufferId> {
        self.buffers.get(idx).copied()
    }
}

/// Counters for cache-set lifecycle (leak detection rides these plus the
/// shared pool's high-water stats).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheArenaStats {
    pub sets_allocated: u64,
    pub sets_released: u64,
    /// Device bytes per cache set (layers x 2 x max_seq x kv_heads x
    /// head_dim x 4 for the decode builder).
    pub resident_bytes_per_set: usize,
}

impl CacheArenaStats {
    /// Cache sets currently held by live sessions.
    pub fn sets_live(&self) -> u64 {
        self.sets_allocated - self.sets_released
    }
}

/// Per-session cache allocator over the shared bounded buffer pool.
#[derive(Debug, Clone)]
pub struct CacheArena {
    specs: Vec<PersistentSpec>,
    stats: CacheArenaStats,
}

impl CacheArena {
    pub fn new(specs: Vec<PersistentSpec>) -> Self {
        let resident: usize = specs.iter().map(|s| s.size).sum();
        CacheArena {
            specs,
            stats: CacheArenaStats { resident_bytes_per_set: resident, ..Default::default() },
        }
    }

    pub fn specs(&self) -> &[PersistentSpec] {
        &self.specs
    }

    pub fn stats(&self) -> CacheArenaStats {
        self.stats
    }

    /// Allocate a zeroed cache set for a new session. Buffers come from the
    /// shared pool (honoring its byte cap); recycled buffers are cleared
    /// device-side so no state leaks across sessions.
    pub fn allocate(&mut self, device: &mut Device, pool: &mut BufferPool) -> Result<DeviceKvCache> {
        let mut buffers = Vec::with_capacity(self.specs.len());
        for spec in &self.specs {
            // Acquire, then zero; the buffer joins the partial set before
            // the clear so BOTH failure modes unwind through it.
            let res = pool.acquire(device, spec.size).and_then(|b| {
                buffers.push(b);
                device.clear_buffer(b)
            });
            if let Err(e) = res {
                // Unwind the partial set so a failed admit leaks nothing —
                // in reverse, like a full release, so the pool's LIFO free
                // lists keep handing out the same buffer order (the
                // bind-group cache key).
                for (b, s) in buffers.iter().zip(&self.specs).rev() {
                    pool.release(s.size, *b);
                }
                return Err(e);
            }
        }
        self.stats.sets_allocated += 1;
        Ok(DeviceKvCache { buffers, resident_bytes: self.stats.resident_bytes_per_set })
    }

    /// Return a cache set to the pool. Reverse order keeps the pool's LIFO
    /// free lists aligned so the next allocate sees the same buffer order.
    /// Errors (releasing nothing) if the set does not match this arena's
    /// specs — a silent partial release would defeat the leak accounting.
    pub fn release(&mut self, pool: &mut BufferPool, cache: DeviceKvCache) -> Result<()> {
        if cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache set has {} buffers, arena expects {}",
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        for (buf, spec) in cache.buffers.iter().zip(&self.specs).rev() {
            pool.release(spec.size, *buf);
        }
        self.stats.sets_released += 1;
        Ok(())
    }

    /// Spill a cache set to host tensors (eviction), in spec order. A real
    /// device->host readback: the whole set is mapped behind ONE
    /// synchronization point (`map_read_many`), so the spill's sync +
    /// per-byte transfer cost lands in the virtual cost model instead of
    /// moving O(layers x max_seq) bytes for free. The device buffers stay
    /// allocated — pair with [`CacheArena::release`] to free them.
    pub fn spill_to_host(&self, device: &mut Device, cache: &DeviceKvCache) -> Result<Vec<Tensor>> {
        if cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache set has {} buffers, arena expects {}",
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        let all = device.map_read_many(&cache.buffers)?;
        let mut out = Vec::with_capacity(self.specs.len());
        for (bytes, spec) in all.iter().zip(&self.specs) {
            out.push(Tensor::from_le_bytes(spec.shape.clone(), spec.dtype, &bytes[..spec.size])?);
        }
        Ok(out)
    }

    /// Upload host tensors (spec order) into a cache set — the restore half
    /// of the evict-to-host spill path. Takes references so a resume does
    /// not deep-copy the whole host KV state just to upload it.
    pub fn upload_from_host(
        &self,
        device: &mut Device,
        cache: &DeviceKvCache,
        tensors: &[&Tensor],
    ) -> Result<()> {
        if tensors.len() != self.specs.len() || cache.buffers.len() != self.specs.len() {
            return Err(Error::Graph(format!(
                "cache restore: {} tensors / {} buffers vs {} specs",
                tensors.len(),
                cache.buffers.len(),
                self.specs.len()
            )));
        }
        for ((buf, spec), t) in cache.buffers.iter().zip(&self.specs).zip(tensors) {
            if t.shape != spec.shape {
                return Err(Error::Graph(format!(
                    "cache restore '{}': host shape {:?} != spec {:?}",
                    spec.name, t.shape, spec.shape
                )));
            }
            device.write_buffer(*buf, 0, t.data.as_bytes())?;
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::webgpu::ImplementationProfile;

    fn arena(n: usize, size: usize) -> CacheArena {
        let specs = (0..n)
            .map(|i| PersistentSpec {
                name: format!("l{}.{}_cache", i / 2, if i % 2 == 0 { "k" } else { "v" }),
                shape: vec![size / 4],
                dtype: DType::F32,
                size,
            })
            .collect();
        CacheArena::new(specs)
    }

    #[test]
    fn allocate_release_reuses_same_buffers_in_order() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(4, 256);
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        let ids1 = set1.buffers.clone();
        a.release(&mut pool, set1).unwrap();
        let set2 = a.allocate(&mut d, &mut pool).unwrap();
        assert_eq!(set2.buffers, ids1, "reverse-release must preserve order");
        assert_eq!(pool.stats().created, 4, "second set fully recycled");
        assert_eq!(a.stats().sets_live(), 1);
    }

    #[test]
    fn recycled_buffers_are_zeroed() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(2, 64);
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        d.write_buffer(set1.buffers[0], 0, &[0xAB; 64]).unwrap();
        a.release(&mut pool, set1).unwrap();
        let set2 = a.allocate(&mut d, &mut pool).unwrap();
        let bytes = d.peek_buffer(set2.buffers[0]).unwrap();
        assert!(bytes.iter().all(|&b| b == 0), "stale session bytes leaked");
    }

    #[test]
    fn pool_cap_bounds_cache_sets_and_failed_allocate_leaks_nothing() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(Some(600));
        let mut a = arena(2, 256); // one set = 512 B
        let set1 = a.allocate(&mut d, &mut pool).unwrap();
        let err = a.allocate(&mut d, &mut pool);
        assert!(err.is_err(), "second set must exceed the 600 B cap");
        // The failed allocate returned its partial set to the pool.
        assert_eq!(pool.stats().outstanding_bytes, 512);
        a.release(&mut pool, set1).unwrap();
        assert_eq!(pool.stats().outstanding_bytes, 0);
        assert!(a.allocate(&mut d, &mut pool).is_ok(), "reuse within cap");
    }

    #[test]
    fn spill_and_restore_round_trip() {
        let mut d = Device::new(ImplementationProfile::zero_overhead());
        let mut pool = BufferPool::new(None);
        let mut a = arena(2, 64);
        let set = a.allocate(&mut d, &mut pool).unwrap();
        let t = Tensor::f32(vec![16], (0..16).map(|i| i as f32).collect()).unwrap();
        d.write_buffer(set.buffers[1], 0, t.data.as_bytes()).unwrap();
        let spilled = a.spill_to_host(&mut d, &set).unwrap();
        assert_eq!(spilled[1].as_f32().unwrap(), t.as_f32().unwrap());
        // Clear, then restore (by reference — no deep copy) and read back.
        d.clear_buffer(set.buffers[1]).unwrap();
        let refs: Vec<&Tensor> = spilled.iter().collect();
        a.upload_from_host(&mut d, &set, &refs).unwrap();
        let bytes = d.peek_buffer(set.buffers[1]).unwrap().to_vec();
        let back = Tensor::from_le_bytes(vec![16], DType::F32, &bytes).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }
}

//! The PlanRunner: materializes an [`ExecutionPlan`] into device resources
//! once, then replays it per token as the allocation-free hot loop.
//!
//! Materialization (plan-build time, off the decode loop) creates the
//! arena buffers, the logits ring, and one bind group per dispatch step —
//! so replay never creates a resource, never hashes a cache key, and never
//! copies an activation through the host: it writes the per-step inputs,
//! walks a flat step array issuing `set_pipeline` / `set_bind_group` /
//! `dispatch`, and batches up to `dispatches_per_submit` dispatches into
//! one encoder per submit (the paper's encoder-batching axis). Framework
//! cost is charged once per step at the plan's (much smaller) replay rate,
//! making eager-vs-planned framework overhead a measurable delta.
//!
//! The logits output is ring-backed: concurrent sessions in one scheduler
//! round each replay into their own ring buffer, so the deferred
//! synchronizing readback (`map_read_many`) still sees every session's
//! logits after the round.

use std::collections::HashMap;

use crate::tensor::Tensor;
use crate::trace::{names as trace_names, TRACK_ENGINE};
use crate::webgpu::bindgroup::{BindGroupDesc, BindGroupEntry, BindGroupId};
use crate::webgpu::{
    BufferDesc, BufferId, BufferUsage, CommandEncoderId, Device, KernelRunner,
};
use crate::{Error, Result};

use super::planner::{Binding, ExecutionPlan, Step};
use super::residency::DeviceKvCache;

/// Per-replay cost deltas the executor folds into its own counters so
/// serving attribution keeps tiling the device timeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayDelta {
    pub framework_ns: u64,
    pub dispatches: u64,
    pub submits: u64,
}

/// Per-cache-set bind groups for the persistent steps: ordered buffer-set
/// key -> (step index -> bind group).
type SessionGroups = HashMap<Vec<BufferId>, HashMap<usize, BindGroupId>>;

/// Check that a plan's persistent list is the shared PAGED pool-plane
/// layout (layer-major `pool.l{l}.k_cache`, `pool.l{l}.v_cache`) — the
/// contract every paged plan variant (solo, batched, prefill, unified)
/// declares identically, so ONE pool set registers with all of them.
pub fn validate_paged_persistent(plan: &ExecutionPlan) -> Result<()> {
    if plan.persistent.is_empty() || plan.persistent.len() % 2 != 0 {
        return Err(Error::Graph(format!(
            "paged plan: {} persistent values are not layer-major K/V pool planes",
            plan.persistent.len()
        )));
    }
    for (i, spec) in plan.persistent.iter().enumerate() {
        let expect =
            format!("pool.l{}.{}_cache", i / 2, if i % 2 == 0 { "k" } else { "v" });
        if spec.name != expect {
            return Err(Error::Graph(format!(
                "paged plan: persistent '{}' at index {i}, expected '{expect}'",
                spec.name
            )));
        }
    }
    for name in ["block_table", "kv_block"] {
        if !plan.uploads.iter().any(|u| u.name == name) {
            return Err(Error::Graph(format!(
                "paged plan: step input '{name}' missing"
            )));
        }
    }
    if plan.uploads.iter().any(|u| u.name == "slot_idx") {
        return Err(Error::Graph(
            "paged plan must not carry 'slot_idx' (block tables route slots)".into(),
        ));
    }
    Ok(())
}

pub struct PlanRunner {
    pub plan: ExecutionPlan,
    /// One device buffer per arena slot.
    arena: Vec<BufferId>,
    /// Cached bind group per dispatch step (None for host steps and the
    /// ring-substituted logits step).
    groups: Vec<Option<BindGroupId>>,
    /// Ring buffers + their bind groups for the logits-producing step.
    logits_ring: Vec<BufferId>,
    logits_groups: Vec<BindGroupId>,
    /// Steps that bind persistent (session-owned) buffers — their bind
    /// groups depend on the session's cache set.
    persistent_steps: Vec<usize>,
    /// Per-cache-set bind groups for the persistent steps, keyed by the
    /// set's ordered buffer ids. A retired session's buffers return to the
    /// pool in order, so the next session's set usually hits this cache —
    /// steady-state replay creates no resources.
    session_groups: SessionGroups,
    /// Reused scratch for the `Halves` host step (unfused graphs only).
    scratch_a: Vec<u8>,
    scratch_b: Vec<u8>,
    /// Shared persistent set replays fall back to when the caller passes no
    /// per-session cache — the paged pool planes, which every paged replay
    /// binds regardless of which session is running (the block-table
    /// step-input does the per-session routing instead).
    default_kv: Option<DeviceKvCache>,
    /// Plan-build cost (compile + materialize), stamped by the caller.
    pub build_virtual_ns: u64,
    pub build_real_ns: u64,
    pub replays: u64,
}

fn flush(
    device: &mut Device,
    runner: &dyn KernelRunner,
    enc: &mut Option<CommandEncoderId>,
) -> Result<()> {
    if let Some(e) = enc.take() {
        device.end_compute_pass(e)?;
        let cb = device.finish(e)?;
        device.submit(&[cb], runner)?;
    }
    Ok(())
}

impl PlanRunner {
    /// Create the arena buffers, logits ring and per-step bind groups.
    /// Everything here is plan-build cost, paid once.
    pub fn materialize(device: &mut Device, plan: ExecutionPlan) -> Result<PlanRunner> {
        let usage = BufferUsage::STORAGE
            | BufferUsage::COPY_DST
            | BufferUsage::COPY_SRC
            | BufferUsage::MAP_READ;
        let mut arena = Vec::with_capacity(plan.arena.slot_sizes.len());
        for (i, &size) in plan.arena.slot_sizes.iter().enumerate() {
            arena.push(device.create_buffer(BufferDesc {
                label: format!("arena-{i}"),
                size,
                usage,
            })?);
        }
        let mut logits_ring = Vec::new();
        if let Some(lg) = &plan.logits {
            for r in 0..plan.logits_ring {
                logits_ring.push(device.create_buffer(BufferDesc {
                    label: format!("logits-ring-{r}"),
                    size: lg.size,
                    usage,
                })?);
            }
        }

        let entry_for = |arena: &[BufferId], b: &Binding, binding: usize| -> BindGroupEntry {
            match *b {
                Binding::Arena(s) => BindGroupEntry {
                    binding,
                    buffer: arena[s.slot],
                    offset: s.offset,
                    size: s.size,
                },
                Binding::Pinned { buffer, offset, size } => {
                    BindGroupEntry { binding, buffer, offset, size }
                }
                Binding::Persistent { .. } => {
                    unreachable!("persistent bindings are substituted per session cache set")
                }
                Binding::Ring => unreachable!("ring bindings are substituted per ring buffer"),
            }
        };

        let mut groups: Vec<Option<BindGroupId>> = Vec::with_capacity(plan.steps.len());
        let mut logits_groups = Vec::new();
        let mut persistent_steps = Vec::new();
        for (i, step) in plan.steps.iter().enumerate() {
            match step {
                Step::Dispatch(d) => {
                    let touches_persistent =
                        d.bindings.iter().any(|b| matches!(b, Binding::Persistent { .. }));
                    if touches_persistent {
                        if Some(i) == plan.logits_step {
                            return Err(Error::Graph(format!(
                                "step '{}' is both ring-backed and persistent",
                                d.name
                            )));
                        }
                        // Bind group deferred to register_cache (per session).
                        persistent_steps.push(i);
                        groups.push(None);
                    } else if Some(i) == plan.logits_step {
                        // One group per ring buffer, Ring slot substituted.
                        for &ring_buf in &logits_ring {
                            let entries = d
                                .bindings
                                .iter()
                                .enumerate()
                                .map(|(bi, b)| match b {
                                    Binding::Ring => {
                                        let size = plan
                                            .logits
                                            .as_ref()
                                            .map(|l| l.size)
                                            .unwrap_or(0);
                                        BindGroupEntry {
                                            binding: bi,
                                            buffer: ring_buf,
                                            offset: 0,
                                            size,
                                        }
                                    }
                                    other => entry_for(&arena, other, bi),
                                })
                                .collect();
                            logits_groups.push(device.create_bind_group(BindGroupDesc {
                                label: d.name.clone(),
                                layout: d.layout,
                                entries,
                            })?);
                        }
                        groups.push(None);
                    } else {
                        let entries = d
                            .bindings
                            .iter()
                            .enumerate()
                            .map(|(bi, b)| entry_for(&arena, b, bi))
                            .collect();
                        groups.push(Some(device.create_bind_group(BindGroupDesc {
                            label: d.name.clone(),
                            layout: d.layout,
                            entries,
                        })?));
                    }
                }
                Step::Host(_) => groups.push(None),
            }
        }

        Ok(PlanRunner {
            plan,
            arena,
            groups,
            logits_ring,
            logits_groups,
            persistent_steps,
            session_groups: HashMap::new(),
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            default_kv: None,
            build_virtual_ns: 0,
            build_real_ns: 0,
            replays: 0,
        })
    }

    /// Create (or reuse) the bind groups that wire a session's cache set
    /// into the persistent steps. Idempotent per buffer set — a recycled
    /// set (same buffers, same order) is a pure cache hit, so steady-state
    /// session churn creates no bind groups.
    pub fn register_cache(&mut self, device: &mut Device, kv: &DeviceKvCache) -> Result<()> {
        if kv.buffers.len() != self.plan.persistent.len() {
            return Err(Error::Graph(format!(
                "cache set has {} buffers, plan expects {} persistent values",
                kv.buffers.len(),
                self.plan.persistent.len()
            )));
        }
        for (buf, spec) in kv.buffers.iter().zip(&self.plan.persistent) {
            if device.buffer_size(*buf)? < spec.size {
                return Err(Error::Graph(format!(
                    "cache buffer for '{}' smaller than spec ({} B)",
                    spec.name, spec.size
                )));
            }
        }
        if self.session_groups.contains_key(&kv.buffers) {
            return Ok(());
        }
        let mut by_step = HashMap::with_capacity(self.persistent_steps.len());
        for &i in &self.persistent_steps {
            let Step::Dispatch(d) = &self.plan.steps[i] else {
                unreachable!("persistent steps are dispatches")
            };
            let entries = d
                .bindings
                .iter()
                .enumerate()
                .map(|(bi, b)| match *b {
                    Binding::Persistent { idx, offset, size } => BindGroupEntry {
                        binding: bi,
                        buffer: kv.buffers[idx],
                        offset,
                        size,
                    },
                    Binding::Arena(s) => BindGroupEntry {
                        binding: bi,
                        buffer: self.arena[s.slot],
                        offset: s.offset,
                        size: s.size,
                    },
                    Binding::Pinned { buffer, offset, size } => {
                        BindGroupEntry { binding: bi, buffer, offset, size }
                    }
                    Binding::Ring => unreachable!("checked at materialize"),
                })
                .collect();
            by_step.insert(
                i,
                device.create_bind_group(BindGroupDesc {
                    label: d.name.clone(),
                    layout: d.layout,
                    entries,
                })?,
            );
        }
        self.session_groups.insert(kv.buffers.clone(), by_step);
        Ok(())
    }

    /// Cache-set orderings with registered bind groups. Bounded: cache
    /// buffers come from the pool and are never destroyed, and reverse-
    /// order release keeps handing sessions the same orderings, so groups
    /// stay valid and the map does not grow under steady-state churn
    /// (asserted by the residency tests).
    pub fn registered_cache_sets(&self) -> usize {
        self.session_groups.len()
    }

    /// Install the shared pool set every replay binds when no per-session
    /// cache is passed (paged mode: one set of pool planes for all
    /// sessions). Must already be registered via
    /// [`PlanRunner::register_cache`].
    pub fn set_default_cache(&mut self, kv: DeviceKvCache) -> Result<()> {
        if !self.session_groups.contains_key(&kv.buffers) {
            return Err(Error::Graph(
                "default cache set not registered with the plan runner".into(),
            ));
        }
        self.default_kv = Some(kv);
        Ok(())
    }

    /// True for buffers the runner owns (the logits ring) — they must not
    /// be released into the executor's size-class pool.
    pub fn owns_buffer(&self, buf: BufferId) -> bool {
        self.logits_ring.contains(&buf)
    }

    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Replay the plan once. `ring_idx` selects the logits ring buffer
    /// (the serving engine passes the session's position in the round);
    /// `kv` is the session's device-resident cache set (required when the
    /// plan has persistent values, registered via
    /// [`PlanRunner::register_cache`]). Per step only the `StepInput`
    /// uploads cross the bus — K/V appends happen on-device through the
    /// in-place `cache_update` dispatches. Returns (named outputs, live
    /// logits buffer for the caller's deferred `map_read`, cost deltas).
    pub fn replay(
        &mut self,
        device: &mut Device,
        runner: &dyn KernelRunner,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        kv: Option<&DeviceKvCache>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        // REPLAY span wraps the whole replay; closed on both Ok and Err
        // paths so fault-injected failures keep the span stack balanced.
        let t0 = device.clock.now_ns();
        device.trace.begin(trace_names::REPLAY, TRACK_ENGINE, t0);
        let res = self.replay_inner(device, runner, inputs, ring_idx, kv);
        let t1 = device.clock.now_ns();
        device.trace.end(trace_names::REPLAY, TRACK_ENGINE, t1);
        res
    }

    fn replay_inner(
        &mut self,
        device: &mut Device,
        runner: &dyn KernelRunner,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        kv: Option<&DeviceKvCache>,
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        if self.plan.logits.is_some() && ring_idx >= self.logits_ring.len() {
            return Err(Error::Graph(format!(
                "ring index {ring_idx} >= logits ring size {}",
                self.logits_ring.len()
            )));
        }
        let session_groups = if self.plan.persistent.is_empty() {
            None
        } else {
            let kv = kv.or(self.default_kv.as_ref()).ok_or_else(|| {
                Error::Graph(format!(
                    "plan has {} persistent values but no session cache set was passed",
                    self.plan.persistent.len()
                ))
            })?;
            Some(self.session_groups.get(&kv.buffers).ok_or_else(|| {
                Error::Graph("session cache set not registered with the plan runner".into())
            })?)
        };
        let mut delta = ReplayDelta::default();

        for u in &self.plan.uploads {
            let t = inputs
                .get(&u.name)
                .ok_or_else(|| Error::Graph(format!("missing graph input '{}'", u.name)))?;
            if t.shape != u.shape {
                return Err(Error::Graph(format!(
                    "input '{}' shape {:?} != plan shape {:?}",
                    u.name, t.shape, u.shape
                )));
            }
            device.write_buffer(self.arena[u.dst.slot], u.dst.offset, t.data.as_bytes())?;
        }

        let mut enc: Option<CommandEncoderId> = None;
        let mut pending = 0usize;
        for (i, step) in self.plan.steps.iter().enumerate() {
            match step {
                Step::Dispatch(d) => {
                    let t_op = device.clock.now_ns();
                    // Planned framework cost: the replay loop's per-step
                    // bookkeeping, orders of magnitude below the eager
                    // interpreter's per-op cost.
                    let fw = device.drifted_cost(self.plan.framework_ns_per_step);
                    device.clock.advance_cpu(fw);
                    delta.framework_ns += fw;

                    let e = match enc {
                        Some(e) => e,
                        None => {
                            let e = device.create_command_encoder(&d.name);
                            device.begin_compute_pass(e)?;
                            enc = Some(e);
                            pending = 0;
                            e
                        }
                    };
                    device.set_pipeline(e, d.pipeline)?;
                    let group = if Some(i) == self.plan.logits_step {
                        self.logits_groups[ring_idx]
                    } else if let Some(g) = self.groups[i] {
                        g
                    } else {
                        *session_groups
                            .and_then(|m| m.get(&i))
                            .ok_or_else(|| {
                                Error::Graph(format!(
                                    "step {i} '{}' has no bind group for this session",
                                    d.name
                                ))
                            })?
                    };
                    device.set_bind_group(e, group)?;
                    device.dispatch_workgroups(e, d.grid.0, d.grid.1, d.grid.2)?;
                    if device.trace.on() {
                        // Retroactive per-op span carrying the fx node name:
                        // framework share + encode phases for this dispatch.
                        let op = device.trace.intern(&d.name);
                        let now = device.clock.now_ns();
                        device.trace.complete(op, TRACK_ENGINE, t_op, now - t_op, 0);
                    }
                    delta.dispatches += 1;
                    pending += 1;
                    if pending >= self.plan.dispatches_per_submit {
                        flush(device, runner, &mut enc)?;
                        delta.submits += 1;
                    }
                }
                Step::Host(h) => {
                    // A host step reads device bytes: pending dispatches
                    // must execute first, and its writes must not clobber
                    // aliased slots a recorded-but-unsubmitted dispatch
                    // still reads.
                    if enc.is_some() {
                        flush(device, runner, &mut enc)?;
                        delta.submits += 1;
                    }
                    let half = h.row_bytes / 2;
                    self.scratch_a.clear();
                    self.scratch_b.clear();
                    {
                        let bytes = device.peek_buffer(self.arena[h.src.slot])?;
                        let window = &bytes[h.src.offset..h.src.offset + h.src.size];
                        for row in window.chunks_exact(h.row_bytes) {
                            self.scratch_a.extend_from_slice(&row[..half]);
                            self.scratch_b.extend_from_slice(&row[half..]);
                        }
                    }
                    device.write_buffer(
                        self.arena[h.dst[0].slot],
                        h.dst[0].offset,
                        &self.scratch_a,
                    )?;
                    device.write_buffer(
                        self.arena[h.dst[1].slot],
                        h.dst[1].offset,
                        &self.scratch_b,
                    )?;
                }
            }
        }
        if enc.is_some() {
            flush(device, runner, &mut enc)?;
            delta.submits += 1;
        }

        let mut outs = HashMap::with_capacity(self.plan.readbacks.len() + 1);
        for rb in &self.plan.readbacks {
            let t = {
                let bytes = device.peek_buffer(self.arena[rb.src.slot])?;
                Tensor::from_le_bytes(
                    rb.shape.clone(),
                    rb.dtype,
                    &bytes[rb.src.offset..rb.src.offset + rb.src.size],
                )?
            };
            outs.insert(rb.name.clone(), t);
        }
        let mut logits_buf = None;
        if let Some(lg) = &self.plan.logits {
            let buf = self.logits_ring[ring_idx];
            let t = {
                let bytes = device.peek_buffer(buf)?;
                Tensor::from_le_bytes(lg.shape.clone(), lg.dtype, &bytes[..lg.size])?
            };
            outs.insert(lg.name.clone(), t);
            logits_buf = Some(buf);
        }
        self.replays += 1;
        Ok((outs, logits_buf, delta))
    }
}

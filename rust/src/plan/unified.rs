//! Unified-round plan execution: one replay serves a mixed
//! prefill/decode round.
//!
//! A [`UnifiedRunner`] wraps a [`PlanRunner`] compiled from the unified
//! round graph ([`crate::fx::build_unified_round_graph`]) at a fixed slot
//! `width` W and sequence chunk `C`. Every step input is `[W*C, ...]`
//! seq-x-batch shaped: slot `j` owns rows `j*C..(j+1)*C` and carries
//! `valid_len[j]` live tokens — a prefill member fills up to C rows, a
//! decode member exactly one, a padding slot zero. The persistent layout
//! is IDENTICAL to the batched decode plan's slot-major cache-set table
//! (`s{j}.l{l}.{k,v}_cache`), so the same per-session [`DeviceKvCache`]
//! sets plug into slots without copies, and the same padding-set +
//! `slot_mask` machinery covers partial rounds.
//!
//! This is the continuous-batching shape: prompts arriving mid-run join
//! the SAME replay the decoding sessions already occupy, so a mixed round
//! costs one dispatch per layer op instead of a prefill round plus a
//! decode round — the dispatch-overhead amortization the serve-bench
//! mixed-round gate enforces.

use std::collections::HashMap;

use crate::tensor::Tensor;
use crate::webgpu::{BufferDesc, BufferId, BufferUsage, Device, KernelRunner};
use crate::{Error, Result};

use super::planner::ExecutionPlan;
use super::residency::DeviceKvCache;
use super::runner::{validate_paged_persistent, PlanRunner, ReplayDelta};

/// Seq-x-batch consistency checks for a plan compiled from a unified
/// round graph: the batched slot-major persistent layout, `[W*C]`-leading
/// row inputs, `[W]`-leading per-slot uniforms, and a width-leading
/// logits block (one selected last row per slot).
pub fn validate_unified_plan(plan: &ExecutionPlan, width: usize, chunk: usize) -> Result<()> {
    if width < 2 {
        return Err(Error::Graph(format!("unified plans need width >= 2, got {width}")));
    }
    if chunk < 2 {
        return Err(Error::Graph(format!("unified plans need chunk >= 2, got {chunk}")));
    }
    if plan.persistent.is_empty() || plan.persistent.len() % width != 0 {
        return Err(Error::Graph(format!(
            "unified plan: {} persistent values not divisible into {width} slots",
            plan.persistent.len()
        )));
    }
    let per_slot = plan.persistent.len() / width;
    for j in 0..width {
        let prefix = format!("s{j}.");
        for k in 0..per_slot {
            let spec = &plan.persistent[j * per_slot + k];
            if !spec.name.starts_with(&prefix) {
                return Err(Error::Graph(format!(
                    "unified plan: persistent '{}' not slot-major (expected slot {j})",
                    spec.name
                )));
            }
            let base = &plan.persistent[k];
            if spec.shape != base.shape || spec.dtype != base.dtype || spec.size != base.size {
                return Err(Error::Graph(format!(
                    "unified plan: slot {j} spec '{}' differs from slot 0 '{}'",
                    spec.name, base.name
                )));
            }
        }
    }
    let rows = width * chunk;
    for (name, leading) in [
        ("x", rows),
        ("pos_f", rows),
        ("pos_base", width),
        ("valid_len", width),
        ("slot_mask", width),
        ("slot_idx", width),
    ] {
        let up = plan
            .uploads
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| {
                Error::Graph(format!("unified plan: step input '{name}' missing"))
            })?;
        if up.shape.first().copied() != Some(leading) {
            return Err(Error::Graph(format!(
                "unified plan: step input '{name}' shape {:?} lacks leading {leading}",
                up.shape
            )));
        }
    }
    match &plan.logits {
        // Last-row tail: one selected row per slot. Multi-row (speculative
        // verify) tail: every slot row is scored, so the logits block is
        // [W*C, vocab] with slot j's rows at j*C..j*C+valid_len[j].
        Some(lg) if lg.shape.first().copied() == Some(width) => {}
        Some(lg) if lg.shape.first().copied() == Some(rows) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "unified plan: logits shape {:?} lacks leading width {width} \
                 or multi-row {rows}",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("unified plan: no logits output".into())),
    }
    Ok(())
}

/// Consistency checks for a plan compiled from a PAGED unified round
/// graph: the shared pool planes replace the slot-major cache-set table,
/// per-slot block tables do the routing, and the seq-x-batch step-input
/// shapes are unchanged from the unpaged unified plan.
pub fn validate_unified_plan_paged(
    plan: &ExecutionPlan,
    width: usize,
    chunk: usize,
) -> Result<()> {
    if width < 2 {
        return Err(Error::Graph(format!("unified plans need width >= 2, got {width}")));
    }
    if chunk < 2 {
        return Err(Error::Graph(format!("unified plans need chunk >= 2, got {chunk}")));
    }
    validate_paged_persistent(plan)?;
    let rows = width * chunk;
    for (name, leading) in [
        ("x", rows),
        ("pos_f", rows),
        ("pos_base", width),
        ("valid_len", width),
        ("slot_mask", width),
    ] {
        let up = plan
            .uploads
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| {
                Error::Graph(format!("paged unified plan: step input '{name}' missing"))
            })?;
        if up.shape.first().copied() != Some(leading) {
            return Err(Error::Graph(format!(
                "paged unified plan: step input '{name}' shape {:?} lacks leading \
                 {leading}",
                up.shape
            )));
        }
    }
    let bt = plan
        .uploads
        .iter()
        .find(|u| u.name == "block_table")
        .ok_or_else(|| Error::Graph("paged unified plan: 'block_table' missing".into()))?;
    match bt.shape.first().copied() {
        Some(n) if n > 0 && n % width == 0 => {}
        _ => {
            return Err(Error::Graph(format!(
                "paged unified plan: block_table shape {:?} is not [W * table_len]",
                bt.shape
            )));
        }
    }
    match &plan.logits {
        Some(lg) if lg.shape.first().copied() == Some(width) => {}
        Some(lg) if lg.shape.first().copied() == Some(rows) => {}
        Some(lg) => {
            return Err(Error::Graph(format!(
                "paged unified plan: logits shape {:?} lacks leading width {width} \
                 or multi-row {rows}",
                lg.shape
            )));
        }
        None => return Err(Error::Graph("paged unified plan: no logits output".into())),
    }
    Ok(())
}

/// Replays a unified seq-x-batch plan over a per-round cache-set table.
pub struct UnifiedRunner {
    runner: PlanRunner,
    width: usize,
    chunk: usize,
    per_slot: usize,
    /// Runner-owned padding cache set bound into empty (masked) slots —
    /// raw device buffers outside the pooled accounting, never written
    /// (masked slots skip cache scatters) and never read back.
    padding: Vec<BufferId>,
    /// Reusable flattened-table scratch (capacity width x per_slot),
    /// refilled per replay so the hot loop allocates nothing steady-state.
    flat: DeviceKvCache,
    /// Paged mode: the shared pool planes are the runner's default cache
    /// set (bound once at materialize) and replays take NO cache-set table
    /// — the uploaded block tables route slots instead.
    paged: bool,
    /// Unified rounds replayed.
    pub rounds: u64,
}

impl UnifiedRunner {
    /// Validate the plan's seq-x-batch shape, create the padding set, and
    /// materialize the inner runner (arena, logits ring, bind groups).
    pub fn materialize(
        device: &mut Device,
        plan: ExecutionPlan,
        width: usize,
        chunk: usize,
    ) -> Result<Self> {
        validate_unified_plan(&plan, width, chunk)?;
        let per_slot = plan.persistent.len() / width;
        let usage = BufferUsage::STORAGE
            | BufferUsage::COPY_DST
            | BufferUsage::COPY_SRC
            | BufferUsage::MAP_READ;
        let mut padding = Vec::with_capacity(per_slot);
        for spec in &plan.persistent[..per_slot] {
            padding.push(device.create_buffer(BufferDesc {
                label: format!("unified-pad-{}", spec.name),
                size: spec.size,
                usage,
            })?);
        }
        let runner = PlanRunner::materialize(device, plan)?;
        let flat = DeviceKvCache {
            buffers: Vec::with_capacity(width * per_slot),
            resident_bytes: 0,
        };
        Ok(UnifiedRunner { runner, width, chunk, per_slot, padding, flat, paged: false, rounds: 0 })
    }

    /// Materialize a PAGED unified runner: the plan's persistent list is
    /// the shared pool planes (`pool`), registered once here and installed
    /// as the runner's default cache set, so mixed prefill/decode rounds
    /// replay against ONE persistent bind-group set whatever sessions
    /// occupy the slots. No padding set exists — masked slots carry `-1`
    /// block tables the kernels never dereference.
    pub fn materialize_paged(
        device: &mut Device,
        plan: ExecutionPlan,
        width: usize,
        chunk: usize,
        pool: &DeviceKvCache,
    ) -> Result<Self> {
        validate_unified_plan_paged(&plan, width, chunk)?;
        let mut runner = PlanRunner::materialize(device, plan)?;
        runner.register_cache(device, pool)?;
        runner.set_default_cache(pool.clone())?;
        Ok(UnifiedRunner {
            runner,
            width,
            chunk,
            per_slot: 0,
            padding: Vec::new(),
            flat: DeviceKvCache { buffers: Vec::new(), resident_bytes: 0 },
            paged: true,
            rounds: 0,
        })
    }

    /// True when this runner replays the paged plan (shared pool planes +
    /// block tables) instead of the per-session cache-set table.
    pub fn is_paged(&self) -> bool {
        self.paged
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Sequence positions one slot can ingest per round (prefill members
    /// pack up to `chunk` prompt rows; decode members use one).
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Persistent values per slot (one session's cache-set length).
    pub fn per_slot(&self) -> usize {
        self.per_slot
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.runner.plan
    }

    pub fn inner(&self) -> &PlanRunner {
        &self.runner
    }

    pub fn inner_mut(&mut self) -> &mut PlanRunner {
        &mut self.runner
    }

    /// Distinct cache-set tables with registered bind groups.
    pub fn registered_tables(&self) -> usize {
        self.runner.registered_cache_sets()
    }

    /// True for buffers the unified runner owns (its logits ring and the
    /// padding set) — they must never be released into the pooled
    /// free lists.
    pub fn owns_buffer(&self, buf: BufferId) -> bool {
        self.runner.owns_buffer(buf) || self.padding.contains(&buf)
    }

    /// Refill the flattened-table scratch: each slot's session cache set
    /// (or the padding set for `None`) in the plan's slot-major persistent
    /// binding order. No allocation once the scratch capacity is warm.
    fn fill_flat(&mut self, table: &[Option<&DeviceKvCache>]) -> Result<()> {
        if table.len() > self.width {
            return Err(Error::Graph(format!(
                "cache-set table has {} slots, unified plan width is {}",
                table.len(),
                self.width
            )));
        }
        self.flat.buffers.clear();
        for j in 0..self.width {
            match table.get(j).copied().flatten() {
                Some(kv) => {
                    if kv.buffers.len() != self.per_slot {
                        return Err(Error::Graph(format!(
                            "slot {j}: session cache set has {} buffers, plan expects {}",
                            kv.buffers.len(),
                            self.per_slot
                        )));
                    }
                    self.flat.buffers.extend_from_slice(&kv.buffers);
                }
                None => self.flat.buffers.extend_from_slice(&self.padding),
            }
        }
        Ok(())
    }

    /// Replay the unified plan once: one dispatch per layer op covering
    /// every active slot's prefill chunk or decode step in `table`.
    /// `inputs` are the packed step inputs (`x [W*C, H]`, `pos_f [W*C]`,
    /// per-slot `pos_base`/`valid_len`/`slot_mask`/`slot_idx` uniforms,
    /// `inv_freq`); `ring_idx` selects this chunk-of-slots' logits-ring
    /// buffer (chunks of one round pass distinct indices so every
    /// `[W, vocab]` block survives until the round's single coalesced
    /// readback). The table's bind groups are registered on first sight
    /// and are pure cache hits thereafter. Returns (named outputs, the
    /// live logits buffer, cost deltas).
    pub fn replay(
        &mut self,
        device: &mut Device,
        runner: &dyn KernelRunner,
        inputs: &HashMap<String, Tensor>,
        ring_idx: usize,
        table: &[Option<&DeviceKvCache>],
    ) -> Result<(HashMap<String, Tensor>, Option<BufferId>, ReplayDelta)> {
        let out = if self.paged {
            if !table.is_empty() {
                return Err(Error::Graph(
                    "paged unified plan takes no cache-set table (block tables \
                     route slots)"
                        .into(),
                ));
            }
            self.runner.replay(device, runner, inputs, ring_idx, None)?
        } else {
            self.fill_flat(table)?;
            self.runner.register_cache(device, &self.flat)?;
            self.runner
                .replay(device, runner, inputs, ring_idx, Some(&self.flat))?
        };
        self.rounds += 1;
        Ok(out)
    }
}

//! Dispatch profiler — the paper's C++ `dispatch_profiler.cpp` analogue.
//!
//! Two measurement modes on a trivial kernel:
//!
//! - **single-op**: submit one dispatch, then synchronize (`poll_wait`),
//!   N times. This conflates sync into every dispatch — the naive
//!   methodology the paper shows overestimates by ~20x.
//! - **sequential**: submit N dispatches, synchronize once at the end —
//!   the paper's methodology, isolating true per-dispatch cost.
//!
//! Plus the per-phase timeline breakdown (Table 20).

use crate::webgpu::queue::{kernel_layout, run_kernel_dispatch};
use crate::webgpu::{
    BufferDesc, BufferUsage, Device, ImplementationProfile, KernelIoSpec,
    NullRunner, PhaseTimeline, ShaderModuleDesc, DISPATCH_PHASES,
};
use crate::tensor::DType;
use crate::Result;

/// Result of one dispatch-overhead measurement.
#[derive(Debug, Clone)]
pub struct DispatchMeasurement {
    pub profile_name: String,
    pub n_dispatches: usize,
    /// Virtual per-dispatch cost, single-op mode (us).
    pub single_op_us: f64,
    /// Virtual per-dispatch cost, sequential mode (us).
    pub sequential_us: f64,
    /// Real (host wall) per-dispatch cost of our substrate, sequential (us).
    pub real_sequential_us: f64,
    /// Per-phase virtual breakdown from the sequential run.
    pub timeline: PhaseTimeline,
}

impl DispatchMeasurement {
    pub fn overestimate_ratio(&self) -> f64 {
        self.single_op_us / self.sequential_us
    }
}

/// Run both measurement modes for `profile` with `n` dispatches each.
/// Uses a NullRunner (trivial kernel), matching the paper's microbenchmark.
pub fn measure_dispatch_overhead(
    profile: ImplementationProfile,
    n: usize,
) -> Result<DispatchMeasurement> {
    let name = profile.name.to_string();

    // --- sequential: n dispatches, one sync at the end ---
    let mut dev = Device::new(profile.clone());
    let (pipeline, layout, in_buf, out_buf) = setup_trivial(&mut dev)?;
    let runner = NullRunner;
    let t0 = dev.clock.now_ns();
    let w0 = std::time::Instant::now();
    for _ in 0..n {
        run_kernel_dispatch(&mut dev, pipeline, layout, &[in_buf], &[out_buf], (1, 1, 1), &runner)?;
    }
    dev.poll_wait();
    let seq_total = dev.clock.now_ns() - t0;
    let real_seq = w0.elapsed().as_nanos() as u64;
    // Subtract the single trailing sync to isolate dispatch cost.
    let seq_sync = dev.timeline.sync_virtual_ns;
    let sequential_us = (seq_total.saturating_sub(seq_sync)) as f64 / n as f64 / 1e3;
    let timeline = dev.timeline.clone();

    // --- single-op: sync after every dispatch ---
    let mut dev = Device::new(profile);
    let (pipeline, layout, in_buf, out_buf) = setup_trivial(&mut dev)?;
    let t0 = dev.clock.now_ns();
    for _ in 0..n {
        run_kernel_dispatch(&mut dev, pipeline, layout, &[in_buf], &[out_buf], (1, 1, 1), &runner)?;
        dev.poll_wait();
    }
    let single_total = dev.clock.now_ns() - t0;
    let single_op_us = single_total as f64 / n as f64 / 1e3;

    Ok(DispatchMeasurement {
        profile_name: name,
        n_dispatches: n,
        single_op_us,
        sequential_us,
        real_sequential_us: real_seq as f64 / n as f64 / 1e3,
        timeline,
    })
}

fn setup_trivial(
    dev: &mut Device,
) -> Result<(
    crate::webgpu::ComputePipelineId,
    crate::webgpu::BindGroupLayoutId,
    crate::webgpu::BufferId,
    crate::webgpu::BufferId,
)> {
    let spec = KernelIoSpec { shape: vec![64], dtype: DType::F32 };
    let module = dev.create_shader_module(ShaderModuleDesc {
        label: "trivial".into(),
        kernel: "trivial".into(),
        inputs: vec![spec.clone()],
        outputs: vec![spec],
    })?;
    let layout = kernel_layout(dev, "trivial", 1, 1)?;
    let pipeline = dev.create_compute_pipeline("trivial", module, layout)?;
    let in_buf = dev.create_buffer(BufferDesc {
        label: "in".into(),
        size: 256,
        usage: BufferUsage::STORAGE | BufferUsage::COPY_DST,
    })?;
    let out_buf = dev.create_buffer(BufferDesc {
        label: "out".into(),
        size: 256,
        usage: BufferUsage::STORAGE | BufferUsage::MAP_READ,
    })?;
    Ok((pipeline, layout, in_buf, out_buf))
}

/// Per-phase rows for Table 20 (name, total us, per-dispatch us).
pub fn timeline_rows(t: &PhaseTimeline) -> Vec<(String, f64, f64)> {
    let n = t.dispatches().max(1) as f64;
    DISPATCH_PHASES
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let total_us = t.virtual_ns[i] as f64 / 1e3;
            (name.to_string(), total_us, total_us / n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_matches_profile_calibration() {
        let p = ImplementationProfile::dawn_vulkan_rtx5090();
        let m = measure_dispatch_overhead(p, 200).unwrap();
        assert!((m.sequential_us - 23.8).abs() < 1.5, "seq {}", m.sequential_us);
        assert!((m.single_op_us - 496.8).abs() < 25.0, "single {}", m.single_op_us);
        let r = m.overestimate_ratio();
        assert!((15.0..30.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn wgpu_has_no_conflation() {
        let p = ImplementationProfile::wgpu_vulkan_rtx5090();
        let m = measure_dispatch_overhead(p, 100).unwrap();
        assert!((m.overestimate_ratio() - 1.0).abs() < 0.1);
    }

    #[test]
    fn firefox_floor_visible_in_sequential() {
        let p = ImplementationProfile::firefox_metal_m2();
        let m = measure_dispatch_overhead(p, 50).unwrap();
        assert!((m.sequential_us - 1038.7).abs() < 60.0, "{}", m.sequential_us);
    }

    #[test]
    fn timeline_submit_dominates() {
        let p = ImplementationProfile::wgpu_vulkan_rtx5090();
        let m = measure_dispatch_overhead(p, 100).unwrap();
        let rows = timeline_rows(&m.timeline);
        let submit = rows.iter().find(|(n, _, _)| n == "submit").unwrap();
        let total: f64 = rows.iter().map(|(_, t, _)| t).sum();
        let frac = submit.1 / total;
        assert!((0.3..0.5).contains(&frac), "submit fraction {frac}");
    }

    #[test]
    fn real_substrate_overhead_is_small() {
        // Our real validation/encoding work should be well under the
        // calibrated virtual costs (DESIGN.md §7 self-consistency check).
        let p = ImplementationProfile::dawn_vulkan_rtx5090();
        let m = measure_dispatch_overhead(p, 200).unwrap();
        assert!(
            m.real_sequential_us < m.sequential_us,
            "substrate real cost {} us exceeds simulated {} us",
            m.real_sequential_us,
            m.sequential_us
        );
    }
}

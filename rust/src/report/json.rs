//! Minimal JSON implementation (parse + serialize).
//!
//! The offline build environment has no `serde_json`, so the manifest loader
//! and the results writer use this in-tree parser. It supports the full JSON
//! grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Convenience: `obj.req("key")?` with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }
}

// ----------------------------------------------------------------- parse ---
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

// ------------------------------------------------------------- serialize ---
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, 0, true);
    s
}

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, 0, false);
    s
}

fn write_value(out: &mut String, v: &Value, indent: usize, pretty: bool) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_value(out, item, indent + 1, pretty);
            }
            if pretty {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_string(out, k);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(out, val, indent + 1, pretty);
            }
            if pretty {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------- builders ----
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert_eq!(v.get("d").unwrap(), &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"kernels": [{"name": "rmsnorm_64", "flops": 0, "shape": [1, 64]}], "v": 1.5}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        let v = parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(1.5)), "1.5");
    }

    #[test]
    fn req_reports_missing_key() {
        let v = parse(r#"{"x": 1}"#).unwrap();
        assert!(v.req("x").is_ok());
        assert!(v.req("y").is_err());
    }
}

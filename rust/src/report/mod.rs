//! Reporting: in-tree JSON, markdown table rendering, results persistence.

pub mod json;
pub mod table;

pub use table::TableDoc;

use std::path::Path;

use crate::Result;

/// Write a JSON value under `results/<name>.json` (mirrors the paper repo's
/// `benchmarks/results_*.json` layout).
pub fn write_results(dir: &Path, name: &str, v: &json::Value) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json::to_string_pretty(v))?;
    Ok(path)
}

//! Markdown table rendering — every regenerated paper table goes through
//! this so `wdb table N` output is diffable and consistent.

#[derive(Debug, Clone)]
pub struct TableDoc {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl TableDoc {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        TableDoc {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "table {}: row width {} != {} columns",
            self.id,
            cells.len(),
            self.columns.len()
        );
        self.rows.push(cells);
        self
    }

    /// A full-width separator row (the paper groups rows within tables).
    pub fn section(&mut self, label: &str) -> &mut Self {
        let mut cells = vec![format!("**{label}**")];
        cells.extend(std::iter::repeat(String::new()).take(self.columns.len() - 1));
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, text: &str) -> &mut Self {
        self.notes.push(text.to_string());
        self
    }

    /// Version of the table-JSON layout consumed by the CI trend
    /// artifacts. Bumped to 2 when table S1 gained the `disp/round`
    /// column and serving runs became mode-labelled with their batch
    /// width; bumped to 3 when chunked prefill added S1's
    /// `prefill disp/tok` column and S2's `(prefill ms)` /
    /// `(first decode ms)` TTFT-split rows; bumped to 4 when speculative
    /// decode added S1's `tok/round` + `accept` columns and `+spec(k=N)`
    /// mode labels; bumped to 5 when fault-injected serving added S1's
    /// `faults` + `recov` columns and `+faults(seed=N)` mode labels;
    /// bumped to 6 when paged KV residency added S1/P1's
    /// `blocks (res/spilled)` + `KV (B/tok)` columns and `+paged(b=N)`
    /// mode labels; bumped to 7 when the observability layer added S2's
    /// histogram-backed `(ttft p50/p99 ms)` + `(itl p50/p99 ms)` rows and
    /// the `wdb trace-summary` T1 table — downstream trend tooling keys
    /// on this to re-align columns.
    pub const SCHEMA_VERSION: u32 = 7;

    /// JSON form for `report::write_results`
    /// (schema/id/title/columns/rows/notes), matching the layout
    /// `wdb all-tables` dumps.
    pub fn to_json(&self) -> super::json::Value {
        use super::json::{self, Value};
        let rows = self
            .rows
            .iter()
            .map(|r| Value::Arr(r.iter().map(|c| json::s(c)).collect()))
            .collect();
        json::obj(vec![
            ("schema", json::num(Self::SCHEMA_VERSION as f64)),
            ("id", json::s(&self.id)),
            ("title", json::s(&self.title)),
            (
                "columns",
                Value::Arr(self.columns.iter().map(|c| json::s(c)).collect()),
            ),
            ("rows", Value::Arr(rows)),
            (
                "notes",
                Value::Arr(self.notes.iter().map(|c| json::s(c)).collect()),
            ),
        ])
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("### {}: {}\n\n", self.id, self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }
}

/// Format helpers used across tables.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn us(x_ns: f64) -> String {
    format!("{:.1}", x_ns / 1e3)
}

pub fn ms(x_ns: f64) -> String {
    format!("{:.1}", x_ns / 1e6)
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = TableDoc::new("T0", "demo", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        t.section("group");
        t.row(vec!["yyyy".into(), "2".into()]);
        t.note("a note");
        let md = t.to_markdown();
        assert!(md.contains("### T0: demo"));
        assert!(md.contains("| yyyy"));
        assert!(md.contains("> a note"));
        // column alignment: header and rows share widths
        assert!(md.lines().filter(|l| l.starts_with('|')).count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = TableDoc::new("T0", "demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_carries_schema_version() {
        let mut t = TableDoc::new("T0", "demo", &["a"]);
        t.row(vec!["x".into()]);
        let v = t.to_json();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_f64()),
            Some(TableDoc::SCHEMA_VERSION as f64)
        );
        assert_eq!(TableDoc::SCHEMA_VERSION, 7);
    }

    #[test]
    fn formatters() {
        assert_eq!(us(23_800.0), "23.8");
        assert_eq!(ms(41_600_000.0), "41.6");
        assert_eq!(ratio(1.4), "1.40x");
        assert_eq!(pct(0.53), "53.0%");
    }
}

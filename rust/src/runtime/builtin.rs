//! Built-in kernel manifest — the in-crate mirror of the registry that
//! `python/compile/aot.py` exports to `artifacts/manifest.json`.
//!
//! When no artifacts directory exists (the offline/default configuration),
//! [`super::Registry::builtin`] loads these specs and executes them through
//! the host reference interpreter, so the full engine/serving/test stack
//! runs hermetically. Shapes, tags and FLOP counts match `aot.py`
//! entry-for-entry (the `decode_step_tiny` whole-graph module is omitted:
//! nothing on the Rust side executes it).

use std::collections::HashMap;

use crate::fx::builder::GraphDims;
use crate::tensor::DType;
use crate::webgpu::KernelIoSpec;

use super::registry::{KernelSpec, ManifestConfig};

fn io(shape: &[usize]) -> KernelIoSpec {
    KernelIoSpec { shape: shape.to_vec(), dtype: DType::F32 }
}

fn io_i32(shape: &[usize]) -> KernelIoSpec {
    KernelIoSpec { shape: shape.to_vec(), dtype: DType::I32 }
}

fn matmul_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

struct Builder {
    kernels: HashMap<String, KernelSpec>,
}

impl Builder {
    fn add(
        &mut self,
        name: &str,
        inputs: Vec<KernelIoSpec>,
        outputs: Vec<KernelIoSpec>,
        tags: &[&str],
        flops: f64,
        notes: &str,
    ) {
        self.kernels.insert(
            name.to_string(),
            KernelSpec {
                name: name.to_string(),
                file: format!("k_{name}.hlo.txt"),
                inputs,
                outputs,
                tags: tags.iter().map(|s| s.to_string()).collect(),
                flops,
                notes: notes.to_string(),
            },
        );
    }
}

/// Every kernel the tiny decode graphs, the engine, and the bench suite
/// reference, keyed by name.
pub fn builtin_kernels() -> HashMap<String, KernelSpec> {
    let t = GraphDims::qwen_tiny();
    let (h, qd, kv, inter, v, s) =
        (t.hidden, t.q_dim(), t.kv_dim(), t.intermediate, t.vocab, t.max_seq);
    let (nh, kvh, d) = (t.heads, t.kv_heads, t.head_dim);
    let half = d / 2;
    let mut b = Builder { kernels: HashMap::new() };

    // ---- tiny-config decode kernels (one per distinct op x shape) ----
    b.add(&format!("matmul_{h}_{qd}"), vec![io(&[1, h]), io(&[h, qd])], vec![io(&[1, qd])],
          &["tiny", "matmul"], matmul_flops(1, h, qd), "q/o projection");
    b.add(&format!("matmul_{h}_{kv}"), vec![io(&[1, h]), io(&[h, kv])], vec![io(&[1, kv])],
          &["tiny", "matmul"], matmul_flops(1, h, kv), "separate k or v projection (unfused flow)");
    b.add(&format!("matmul_{h}_{inter}"), vec![io(&[1, h]), io(&[h, inter])], vec![io(&[1, inter])],
          &["tiny", "matmul"], matmul_flops(1, h, inter), "gate/up projection (unfused flow)");
    b.add(&format!("matmul_{inter}_{h}"), vec![io(&[1, inter]), io(&[inter, h])], vec![io(&[1, h])],
          &["tiny", "matmul"], matmul_flops(1, inter, h), "down projection");
    b.add(&format!("matmul_{h}_{v}"), vec![io(&[1, h]), io(&[h, v])], vec![io(&[1, v])],
          &["tiny", "matmul"], matmul_flops(1, h, v), "lm head");
    b.add(&format!("kv_fused_{h}_{}", 2 * kv), vec![io(&[1, h]), io(&[h, 2 * kv])],
          vec![io(&[1, 2 * kv])], &["tiny", "fused"], matmul_flops(1, h, 2 * kv),
          "K+V fusion (2 dispatches -> 1)");

    b.add(&format!("rmsnorm_{h}"), vec![io(&[1, h]), io(&[h])], vec![io(&[1, h])],
          &["tiny", "fused", "rmsnorm"], 0.0, "fused RMSNorm (6 -> 1)");
    b.add(&format!("rms_pow_{h}"), vec![io(&[1, h])], vec![io(&[1, h])], &["tiny", "rmsnorm"], 0.0, "");
    b.add(&format!("rms_mean_{h}"), vec![io(&[1, h])], vec![io(&[1, 1])], &["tiny", "rmsnorm"], 0.0, "");
    b.add("rms_add_eps_1", vec![io(&[1, 1])], vec![io(&[1, 1])], &["tiny", "rmsnorm"], 0.0, "");
    b.add("rms_rsqrt_1", vec![io(&[1, 1])], vec![io(&[1, 1])], &["tiny", "rmsnorm"], 0.0, "");
    b.add(&format!("rms_mul_x_{h}"), vec![io(&[1, h]), io(&[1, 1])], vec![io(&[1, h])],
          &["tiny", "rmsnorm"], 0.0, "");
    b.add(&format!("rms_mul_w_{h}"), vec![io(&[1, h]), io(&[h])], vec![io(&[1, h])],
          &["tiny", "rmsnorm"], 0.0, "");

    b.add(&format!("rope_cos_sin_{d}"), vec![io(&[1]), io(&[half])],
          vec![io(&[d]), io(&[d])], &["tiny", "rotary"], 0.0, "");
    b.add(&format!("rotary_{nh}_{d}"), vec![io(&[nh, d]), io(&[d]), io(&[d])],
          vec![io(&[nh, d])], &["tiny", "rotary", "fused"], 0.0, "");
    b.add(&format!("rotary_{kvh}_{d}"), vec![io(&[kvh, d]), io(&[d]), io(&[d])],
          vec![io(&[kvh, d])], &["tiny", "rotary", "fused"], 0.0, "");
    // unfused rotary pieces
    b.add(&format!("neg_{nh}_{half}"), vec![io(&[nh, half])], vec![io(&[nh, half])],
          &["tiny", "rotary"], 0.0, "");
    b.add(&format!("neg_{kvh}_{half}"), vec![io(&[kvh, half])], vec![io(&[kvh, half])],
          &["tiny", "rotary"], 0.0, "");
    b.add(&format!("concat_{nh}_{half}"), vec![io(&[nh, half]), io(&[nh, half])],
          vec![io(&[nh, d])], &["tiny", "rotary"], 0.0, "");
    b.add(&format!("concat_{kvh}_{half}"), vec![io(&[kvh, half]), io(&[kvh, half])],
          vec![io(&[kvh, d])], &["tiny", "rotary"], 0.0, "");
    b.add(&format!("mul_vec_{nh}_{d}"), vec![io(&[nh, d]), io(&[d])], vec![io(&[nh, d])],
          &["tiny", "rotary"], 0.0, "");
    b.add(&format!("mul_vec_{kvh}_{d}"), vec![io(&[kvh, d]), io(&[d])], vec![io(&[kvh, d])],
          &["tiny", "rotary"], 0.0, "");
    b.add(&format!("add_{nh}_{d}"), vec![io(&[nh, d]), io(&[nh, d])], vec![io(&[nh, d])],
          &["tiny", "rotary"], 0.0, "");
    b.add(&format!("add_{kvh}_{d}"), vec![io(&[kvh, d]), io(&[kvh, d])], vec![io(&[kvh, d])],
          &["tiny", "rotary"], 0.0, "");

    b.add("cache_update_tiny",
          vec![io(&[s, kvh, d]), io(&[kvh, d]), io_i32(&[1])],
          vec![io(&[s, kvh, d])], &["tiny", "cache"], 0.0, "");
    b.add("sdpa_tiny",
          vec![io(&[nh, d]), io(&[s, kvh, d]), io(&[s, kvh, d]), io_i32(&[1])],
          vec![io(&[nh, d])], &["tiny", "attention"],
          2.0 * nh as f64 * d as f64 * s as f64 * 2.0, "");

    // ---- paged KV variants: caches live in ONE shared pool plane per
    // (layer, K/V) of POOL_ROWS = MAX_BATCH_WIDTH x max_seq rows; logical
    // position p of a slot resolves through a per-slot block table as
    // table[p / kv_block] * kv_block + p % kv_block (two-level lookup).
    // Inputs append the block table (fixed stride max_seq / KV_BLOCK_MIN,
    // -1 = unallocated) and the kv_block scalar; the per-slot cache-set
    // bindings and slot_idx collapse into the single plane + table.
    let pr = crate::fx::builder::MAX_BATCH_WIDTH * s;
    let btl = s / crate::fx::builder::KV_BLOCK_MIN;
    b.add("cache_update_paged_tiny",
          vec![io(&[pr, kvh, d]), io(&[kvh, d]), io_i32(&[1]), io_i32(&[btl]), io_i32(&[1])],
          vec![io(&[pr, kvh, d])], &["tiny", "cache", "paged"], 0.0,
          "two-level in-place scatter: row pos lands at table[pos/b]*b + pos%b");
    b.add("sdpa_paged_tiny",
          vec![io(&[nh, d]), io(&[pr, kvh, d]), io(&[pr, kvh, d]), io_i32(&[1]),
               io_i32(&[btl]), io_i32(&[1])],
          vec![io(&[nh, d])], &["tiny", "attention", "paged"],
          2.0 * nh as f64 * d as f64 * s as f64 * 2.0,
          "GQA gathering logical rows 0..pos+1 through the block table");

    b.add(&format!("silu_{inter}"), vec![io(&[1, inter])], vec![io(&[1, inter])],
          &["tiny", "mlp"], 0.0, "");
    b.add(&format!("mul_{inter}"), vec![io(&[1, inter]), io(&[1, inter])], vec![io(&[1, inter])],
          &["tiny", "mlp"], 0.0, "");
    b.add(&format!("add_{h}"), vec![io(&[1, h]), io(&[1, h])], vec![io(&[1, h])],
          &["tiny"], 0.0, "");
    b.add("gate_up_silu_tiny", vec![io(&[1, h]), io(&[h, inter]), io(&[h, inter])],
          vec![io(&[1, inter])], &["tiny", "fused", "mlp"],
          2.0 * matmul_flops(1, h, inter), "MLP gate+up+silu fusion (3 -> 1)");

    // ---- batched (multi-slot) decode kernels: one dispatch per layer op
    // covering up to W session slots (Appendix F's amortization). Cache ops
    // bind W per-slot cache buffers plus per-slot pos/mask/slot-index
    // uniforms; everything else is the row-extended single-session shape.
    // Registered for every width the batched serving path may request.
    for w in 2..=crate::fx::builder::MAX_BATCH_WIDTH {
        let bt = &["tiny", "batch"];
        b.add(&format!("matmul_b{w}_{h}_{qd}"), vec![io(&[w, h]), io(&[h, qd])],
              vec![io(&[w, qd])], bt, matmul_flops(w, h, qd), "batched q/o projection");
        b.add(&format!("matmul_b{w}_{h}_{kv}"), vec![io(&[w, h]), io(&[h, kv])],
              vec![io(&[w, kv])], bt, matmul_flops(w, h, kv), "batched separate k/v projection");
        b.add(&format!("matmul_b{w}_{h}_{inter}"), vec![io(&[w, h]), io(&[h, inter])],
              vec![io(&[w, inter])], bt, matmul_flops(w, h, inter), "batched gate/up projection");
        b.add(&format!("matmul_b{w}_{inter}_{h}"), vec![io(&[w, inter]), io(&[inter, h])],
              vec![io(&[w, h])], bt, matmul_flops(w, inter, h), "batched down projection");
        b.add(&format!("matmul_b{w}_{h}_{v}"), vec![io(&[w, h]), io(&[h, v])],
              vec![io(&[w, v])], bt, matmul_flops(w, h, v), "batched lm head");
        b.add(&format!("kv_fused_b{w}_{h}_{}", 2 * kv), vec![io(&[w, h]), io(&[h, 2 * kv])],
              vec![io(&[w, kv]), io(&[w, kv])], bt, matmul_flops(w, h, 2 * kv),
              "batched K+V fusion: strided row split emits two outputs");

        b.add(&format!("rmsnorm_b{w}_{h}"), vec![io(&[w, h]), io(&[h])], vec![io(&[w, h])],
              bt, 0.0, "batched fused RMSNorm");
        b.add(&format!("rms_pow_b{w}_{h}"), vec![io(&[w, h])], vec![io(&[w, h])], bt, 0.0, "");
        b.add(&format!("rms_mean_b{w}_{h}"), vec![io(&[w, h])], vec![io(&[w, 1])], bt, 0.0, "");
        b.add(&format!("rms_add_eps_b{w}"), vec![io(&[w, 1])], vec![io(&[w, 1])], bt, 0.0, "");
        b.add(&format!("rms_rsqrt_b{w}"), vec![io(&[w, 1])], vec![io(&[w, 1])], bt, 0.0, "");
        b.add(&format!("rms_mul_x_b{w}_{h}"), vec![io(&[w, h]), io(&[w, 1])],
              vec![io(&[w, h])], bt, 0.0, "");
        b.add(&format!("rms_mul_w_b{w}_{h}"), vec![io(&[w, h]), io(&[h])],
              vec![io(&[w, h])], bt, 0.0, "");

        b.add(&format!("rope_cos_sin_b{w}_{d}"), vec![io(&[w]), io(&[half])],
              vec![io(&[w, d]), io(&[w, d])], bt, 0.0, "per-slot rope table");
        b.add(&format!("rotary_b{w}_{nh}_{d}"), vec![io(&[w, nh * d]), io(&[w, d]), io(&[w, d])],
              vec![io(&[w, nh * d])], bt, 0.0, "batched fused rotary (q heads)");
        b.add(&format!("rotary_b{w}_{kvh}_{d}"), vec![io(&[w, kvh * d]), io(&[w, d]), io(&[w, d])],
              vec![io(&[w, kvh * d])], bt, 0.0, "batched fused rotary (kv heads)");

        // Gather/scatter cache ops: W per-slot cache states + packed rows
        // + per-slot pos/mask/cache-set-index uniforms.
        let mut cu_in: Vec<KernelIoSpec> = (0..w).map(|_| io(&[s, kvh, d])).collect();
        cu_in.extend([io(&[w, kvh * d]), io_i32(&[w]), io_i32(&[w]), io_i32(&[w])]);
        let cu_out: Vec<KernelIoSpec> = (0..w).map(|_| io(&[s, kvh, d])).collect();
        b.add(&format!("cache_update_b{w}_tiny"), cu_in, cu_out, &["tiny", "batch", "cache"],
              0.0, "in-place per-slot cache scatter (output j updates state j)");

        let mut sd_in: Vec<KernelIoSpec> = vec![io(&[w, nh * d])];
        sd_in.extend((0..2 * w).map(|_| io(&[s, kvh, d])));
        sd_in.extend([io_i32(&[w]), io_i32(&[w]), io_i32(&[w])]);
        b.add(&format!("sdpa_b{w}_tiny"), sd_in, vec![io(&[w, nh * d])],
              &["tiny", "batch", "attention"],
              2.0 * (w * nh) as f64 * d as f64 * s as f64 * 2.0,
              "batched GQA gathering per-slot caches");

        // Paged twins: one shared pool plane + per-slot block tables
        // replace the W per-slot cache states and the slot-index uniform.
        b.add(&format!("cache_update_paged_b{w}_tiny"),
              vec![io(&[pr, kvh, d]), io(&[w, kvh * d]), io_i32(&[w]), io_i32(&[w]),
                   io_i32(&[w * btl]), io_i32(&[1])],
              vec![io(&[pr, kvh, d])], &["tiny", "batch", "cache", "paged"], 0.0,
              "two-level per-slot scatter through W block tables");
        b.add(&format!("sdpa_paged_b{w}_tiny"),
              vec![io(&[w, nh * d]), io(&[pr, kvh, d]), io(&[pr, kvh, d]), io_i32(&[w]),
                   io_i32(&[w]), io_i32(&[w * btl]), io_i32(&[1])],
              vec![io(&[w, nh * d])], &["tiny", "batch", "attention", "paged"],
              2.0 * (w * nh) as f64 * d as f64 * s as f64 * 2.0,
              "batched GQA gathering each slot's rows through its block table");

        b.add(&format!("gate_up_silu_b{w}_tiny"), vec![io(&[w, h]), io(&[h, inter]), io(&[h, inter])],
              vec![io(&[w, inter])], &["tiny", "batch", "mlp"],
              2.0 * matmul_flops(w, h, inter), "batched MLP gate+up+silu fusion");
        b.add(&format!("silu_b{w}_{inter}"), vec![io(&[w, inter])], vec![io(&[w, inter])],
              bt, 0.0, "");
        b.add(&format!("mul_b{w}_{inter}"), vec![io(&[w, inter]), io(&[w, inter])],
              vec![io(&[w, inter])], bt, 0.0, "");
        b.add(&format!("add_b{w}_{h}"), vec![io(&[w, h]), io(&[w, h])], vec![io(&[w, h])],
              bt, 0.0, "");
    }

    // ---- chunked-prefill kernels: one dispatch per layer op covering up
    // to C consecutive prompt positions of ONE session (the seq-dim twin
    // of the batched amortization). Cache ops scatter C rows in place at
    // pos_base..; sdpa_prefill is the causal multi-token attention (row i
    // attends cache 0..pos_base+i+1); chunk_last_row selects the final
    // valid row so the logits contract stays [1, vocab]. Rows >= valid_len
    // (the ragged tail) are masked, so short final chunks reuse the same
    // pipelines. Registered for every chunk size the prefill scheduler
    // may request (PREFILL_CHUNKS).
    for c in crate::fx::builder::PREFILL_CHUNKS {
        let ct = &["tiny", "prefill"];
        b.add(&format!("matmul_c{c}_{h}_{qd}"), vec![io(&[c, h]), io(&[h, qd])],
              vec![io(&[c, qd])], ct, matmul_flops(c, h, qd), "chunked q/o projection");
        b.add(&format!("matmul_c{c}_{h}_{kv}"), vec![io(&[c, h]), io(&[h, kv])],
              vec![io(&[c, kv])], ct, matmul_flops(c, h, kv), "chunked separate k/v projection");
        b.add(&format!("matmul_c{c}_{h}_{inter}"), vec![io(&[c, h]), io(&[h, inter])],
              vec![io(&[c, inter])], ct, matmul_flops(c, h, inter), "chunked gate/up projection");
        b.add(&format!("matmul_c{c}_{inter}_{h}"), vec![io(&[c, inter]), io(&[inter, h])],
              vec![io(&[c, h])], ct, matmul_flops(c, inter, h), "chunked down projection");
        b.add(&format!("kv_fused_c{c}_{h}_{}", 2 * kv), vec![io(&[c, h]), io(&[h, 2 * kv])],
              vec![io(&[c, kv]), io(&[c, kv])], ct, matmul_flops(c, h, 2 * kv),
              "chunked K+V fusion: strided row split emits two outputs");

        b.add(&format!("rmsnorm_c{c}_{h}"), vec![io(&[c, h]), io(&[h])], vec![io(&[c, h])],
              ct, 0.0, "chunked fused RMSNorm");
        b.add(&format!("rms_pow_c{c}_{h}"), vec![io(&[c, h])], vec![io(&[c, h])], ct, 0.0, "");
        b.add(&format!("rms_mean_c{c}_{h}"), vec![io(&[c, h])], vec![io(&[c, 1])], ct, 0.0, "");
        b.add(&format!("rms_add_eps_c{c}"), vec![io(&[c, 1])], vec![io(&[c, 1])], ct, 0.0, "");
        b.add(&format!("rms_rsqrt_c{c}"), vec![io(&[c, 1])], vec![io(&[c, 1])], ct, 0.0, "");
        b.add(&format!("rms_mul_x_c{c}_{h}"), vec![io(&[c, h]), io(&[c, 1])],
              vec![io(&[c, h])], ct, 0.0, "");
        b.add(&format!("rms_mul_w_c{c}_{h}"), vec![io(&[c, h]), io(&[h])],
              vec![io(&[c, h])], ct, 0.0, "");

        b.add(&format!("rope_cos_sin_c{c}_{d}"), vec![io(&[c]), io(&[half])],
              vec![io(&[c, d]), io(&[c, d])], ct, 0.0, "per-position rope table");
        b.add(&format!("rotary_c{c}_{nh}_{d}"), vec![io(&[c, nh * d]), io(&[c, d]), io(&[c, d])],
              vec![io(&[c, nh * d])], ct, 0.0, "chunked fused rotary (q heads)");
        b.add(&format!("rotary_c{c}_{kvh}_{d}"), vec![io(&[c, kvh * d]), io(&[c, d]), io(&[c, d])],
              vec![io(&[c, kvh * d])], ct, 0.0, "chunked fused rotary (kv heads)");

        b.add(&format!("cache_update_c{c}_tiny"),
              vec![io(&[s, kvh, d]), io(&[c, kvh * d]), io_i32(&[1]), io_i32(&[1])],
              vec![io(&[s, kvh, d])], &["tiny", "prefill", "cache"], 0.0,
              "in-place multi-row cache scatter (rows 0..valid_len at pos_base..)");
        b.add(&format!("sdpa_prefill_c{c}_tiny"),
              vec![io(&[c, nh * d]), io(&[s, kvh, d]), io(&[s, kvh, d]),
                   io_i32(&[1]), io_i32(&[1])],
              vec![io(&[c, nh * d])], &["tiny", "prefill", "attention"],
              2.0 * (c * nh) as f64 * d as f64 * s as f64 * 2.0,
              "causal multi-token GQA: row i attends cache 0..pos_base+i+1");

        // Paged twins: shared pool plane + one block table for the single
        // prefilling session.
        b.add(&format!("cache_update_paged_c{c}_tiny"),
              vec![io(&[pr, kvh, d]), io(&[c, kvh * d]), io_i32(&[1]), io_i32(&[1]),
                   io_i32(&[btl]), io_i32(&[1])],
              vec![io(&[pr, kvh, d])], &["tiny", "prefill", "cache", "paged"], 0.0,
              "two-level multi-row scatter (rows 0..valid_len at pos_base..)");
        b.add(&format!("sdpa_prefill_paged_c{c}_tiny"),
              vec![io(&[c, nh * d]), io(&[pr, kvh, d]), io(&[pr, kvh, d]),
                   io_i32(&[1]), io_i32(&[1]), io_i32(&[btl]), io_i32(&[1])],
              vec![io(&[c, nh * d])], &["tiny", "prefill", "attention", "paged"],
              2.0 * (c * nh) as f64 * d as f64 * s as f64 * 2.0,
              "causal multi-token GQA gathering rows through the block table");

        b.add(&format!("gate_up_silu_c{c}_tiny"),
              vec![io(&[c, h]), io(&[h, inter]), io(&[h, inter])],
              vec![io(&[c, inter])], &["tiny", "prefill", "mlp"],
              2.0 * matmul_flops(c, h, inter), "chunked MLP gate+up+silu fusion");
        b.add(&format!("silu_c{c}_{inter}"), vec![io(&[c, inter])], vec![io(&[c, inter])],
              ct, 0.0, "");
        b.add(&format!("mul_c{c}_{inter}"), vec![io(&[c, inter]), io(&[c, inter])],
              vec![io(&[c, inter])], ct, 0.0, "");
        b.add(&format!("add_c{c}_{h}"), vec![io(&[c, h]), io(&[c, h])], vec![io(&[c, h])],
              ct, 0.0, "");
        b.add(&format!("chunk_last_row_c{c}_{h}"), vec![io(&[c, h]), io_i32(&[1])],
              vec![io(&[1, h])], ct, 0.0, "select row valid_len-1 for the lm head");
        b.add(&format!("chunk_rows_c{c}_{h}"), vec![io(&[c, h]), io_i32(&[1])],
              vec![io(&[c, h])], ct, 0.0,
              "keep rows 0..valid_len, zero the ragged tail (speculative verify)");
        b.add(&format!("matmul_c{c}_{h}_{v}"), vec![io(&[c, h]), io(&[h, v])],
              vec![io(&[c, v])], ct, matmul_flops(c, h, v),
              "chunked lm head: logits for every verified row");
    }

    // ---- unified (seq x batch) round kernels: one dispatch per layer op
    // covering up to W session slots x C sequence positions — the merge of
    // the batched-decode and chunked-prefill amortizations (continuous
    // batching). Slot j owns rows j*C..(j+1)*C and carries valid_len[j]
    // live tokens at cache rows pos_base[j]..; a decode slot is a
    // valid_len = 1 chunk, a padding slot valid_len = 0. Cache ops bind W
    // per-slot cache buffers plus the four per-slot uniforms
    // (pos_base/valid_len/slot_mask/slot_idx); slot_last_row selects each
    // slot's final valid row so the tail keeps the batched [W, vocab]
    // logits contract. Registered for every width x chunk the unified
    // serving path may request.
    for w in 2..=crate::fx::builder::MAX_BATCH_WIDTH {
        for c in crate::fx::builder::PREFILL_CHUNKS {
            let r = w * c;
            let ut = &["tiny", "unified"];
            b.add(&format!("matmul_b{w}c{c}_{h}_{qd}"), vec![io(&[r, h]), io(&[h, qd])],
                  vec![io(&[r, qd])], ut, matmul_flops(r, h, qd), "unified q/o projection");
            b.add(&format!("matmul_b{w}c{c}_{h}_{kv}"), vec![io(&[r, h]), io(&[h, kv])],
                  vec![io(&[r, kv])], ut, matmul_flops(r, h, kv),
                  "unified separate k/v projection");
            b.add(&format!("matmul_b{w}c{c}_{h}_{inter}"), vec![io(&[r, h]), io(&[h, inter])],
                  vec![io(&[r, inter])], ut, matmul_flops(r, h, inter),
                  "unified gate/up projection");
            b.add(&format!("matmul_b{w}c{c}_{inter}_{h}"), vec![io(&[r, inter]), io(&[inter, h])],
                  vec![io(&[r, h])], ut, matmul_flops(r, inter, h), "unified down projection");
            b.add(&format!("kv_fused_b{w}c{c}_{h}_{}", 2 * kv),
                  vec![io(&[r, h]), io(&[h, 2 * kv])],
                  vec![io(&[r, kv]), io(&[r, kv])], ut, matmul_flops(r, h, 2 * kv),
                  "unified K+V fusion: strided row split emits two outputs");

            b.add(&format!("rmsnorm_b{w}c{c}_{h}"), vec![io(&[r, h]), io(&[h])],
                  vec![io(&[r, h])], ut, 0.0, "unified fused RMSNorm");
            b.add(&format!("rms_pow_b{w}c{c}_{h}"), vec![io(&[r, h])], vec![io(&[r, h])],
                  ut, 0.0, "");
            b.add(&format!("rms_mean_b{w}c{c}_{h}"), vec![io(&[r, h])], vec![io(&[r, 1])],
                  ut, 0.0, "");
            b.add(&format!("rms_add_eps_b{w}c{c}"), vec![io(&[r, 1])], vec![io(&[r, 1])],
                  ut, 0.0, "");
            b.add(&format!("rms_rsqrt_b{w}c{c}"), vec![io(&[r, 1])], vec![io(&[r, 1])],
                  ut, 0.0, "");
            b.add(&format!("rms_mul_x_b{w}c{c}_{h}"), vec![io(&[r, h]), io(&[r, 1])],
                  vec![io(&[r, h])], ut, 0.0, "");
            b.add(&format!("rms_mul_w_b{w}c{c}_{h}"), vec![io(&[r, h]), io(&[h])],
                  vec![io(&[r, h])], ut, 0.0, "");

            b.add(&format!("rope_cos_sin_b{w}c{c}_{d}"), vec![io(&[r]), io(&[half])],
                  vec![io(&[r, d]), io(&[r, d])], ut, 0.0, "per-row rope table");
            b.add(&format!("rotary_b{w}c{c}_{nh}_{d}"),
                  vec![io(&[r, nh * d]), io(&[r, d]), io(&[r, d])],
                  vec![io(&[r, nh * d])], ut, 0.0, "unified fused rotary (q heads)");
            b.add(&format!("rotary_b{w}c{c}_{kvh}_{d}"),
                  vec![io(&[r, kvh * d]), io(&[r, d]), io(&[r, d])],
                  vec![io(&[r, kvh * d])], ut, 0.0, "unified fused rotary (kv heads)");

            // Gather/scatter cache ops: W per-slot cache states + packed
            // rows + per-slot base/valid/mask/cache-set-index uniforms.
            let mut cu_in: Vec<KernelIoSpec> = (0..w).map(|_| io(&[s, kvh, d])).collect();
            cu_in.extend([
                io(&[r, kvh * d]),
                io_i32(&[w]),
                io_i32(&[w]),
                io_i32(&[w]),
                io_i32(&[w]),
            ]);
            let cu_out: Vec<KernelIoSpec> = (0..w).map(|_| io(&[s, kvh, d])).collect();
            b.add(&format!("cache_update_b{w}c{c}_tiny"), cu_in, cu_out,
                  &["tiny", "unified", "cache"], 0.0,
                  "in-place per-slot multi-row scatter (output j updates state j)");

            let mut sd_in: Vec<KernelIoSpec> = vec![io(&[r, nh * d])];
            sd_in.extend((0..2 * w).map(|_| io(&[s, kvh, d])));
            sd_in.extend([io_i32(&[w]), io_i32(&[w]), io_i32(&[w]), io_i32(&[w])]);
            b.add(&format!("sdpa_b{w}c{c}_tiny"), sd_in, vec![io(&[r, nh * d])],
                  &["tiny", "unified", "attention"],
                  2.0 * (r * nh) as f64 * d as f64 * s as f64 * 2.0,
                  "causal per-slot GQA: slot j row i attends cache 0..pos_base[j]+i+1");

            // Paged twins: shared pool planes + W block tables replace the
            // per-slot cache states and the cache-set-index uniform.
            b.add(&format!("cache_update_paged_b{w}c{c}_tiny"),
                  vec![io(&[pr, kvh, d]), io(&[r, kvh * d]), io_i32(&[w]), io_i32(&[w]),
                       io_i32(&[w]), io_i32(&[w * btl]), io_i32(&[1])],
                  vec![io(&[pr, kvh, d])], &["tiny", "unified", "cache", "paged"], 0.0,
                  "two-level per-slot multi-row scatter through W block tables");
            b.add(&format!("sdpa_paged_b{w}c{c}_tiny"),
                  vec![io(&[r, nh * d]), io(&[pr, kvh, d]), io(&[pr, kvh, d]),
                       io_i32(&[w]), io_i32(&[w]), io_i32(&[w]), io_i32(&[w * btl]),
                       io_i32(&[1])],
                  vec![io(&[r, nh * d])], &["tiny", "unified", "attention", "paged"],
                  2.0 * (r * nh) as f64 * d as f64 * s as f64 * 2.0,
                  "causal per-slot GQA gathering rows through W block tables");

            b.add(&format!("gate_up_silu_b{w}c{c}_tiny"),
                  vec![io(&[r, h]), io(&[h, inter]), io(&[h, inter])],
                  vec![io(&[r, inter])], &["tiny", "unified", "mlp"],
                  2.0 * matmul_flops(r, h, inter), "unified MLP gate+up+silu fusion");
            b.add(&format!("silu_b{w}c{c}_{inter}"), vec![io(&[r, inter])],
                  vec![io(&[r, inter])], ut, 0.0, "");
            b.add(&format!("mul_b{w}c{c}_{inter}"), vec![io(&[r, inter]), io(&[r, inter])],
                  vec![io(&[r, inter])], ut, 0.0, "");
            b.add(&format!("add_b{w}c{c}_{h}"), vec![io(&[r, h]), io(&[r, h])],
                  vec![io(&[r, h])], ut, 0.0, "");
            b.add(&format!("slot_last_row_b{w}c{c}_{h}"),
                  vec![io(&[r, h]), io_i32(&[w]), io_i32(&[w])],
                  vec![io(&[w, h])], ut, 0.0,
                  "select each slot's row valid_len-1 (zeros for masked/empty slots)");
            b.add(&format!("slot_rows_b{w}c{c}_{h}"),
                  vec![io(&[r, h]), io_i32(&[w]), io_i32(&[w])],
                  vec![io(&[r, h])], ut, 0.0,
                  "keep each slot's rows 0..valid_len[j], zero ragged tails and masked slots");
            b.add(&format!("matmul_b{w}c{c}_{h}_{v}"), vec![io(&[r, h]), io(&[h, v])],
                  vec![io(&[r, v])], ut, matmul_flops(r, h, v),
                  "unified lm head: logits for every verified row");
        }
    }

    b.add(&format!("argmax_{v}"), vec![io(&[1, v])], vec![io_i32(&[1])],
          &["tiny", "argmax"], 0.0, "");
    b.add(&format!("softmax_{v}"), vec![io(&[1, v])], vec![io(&[1, v])],
          &["tiny", "softmax"], 0.0, "");
    b.add(&format!("softmax_naive_{v}"), vec![io(&[1, v])], vec![io(&[1, v])],
          &["tiny", "softmax"], 0.0, "");
    b.add("mega_mlp_tiny",
          vec![io(&[1, h]), io(&[h]), io(&[h, inter]), io(&[h, inter]), io(&[inter, h])],
          vec![io(&[1, h])], &["tiny", "mega"],
          2.0 * matmul_flops(1, h, inter) + matmul_flops(1, inter, h),
          "Appendix C mega-kernel at tiny dims");

    // ---- bench kernels at paper dimensions (Tables 7/8/11/12/16/19) ----
    let bdims = GraphDims::qwen25_05b();
    let (bh, bi, bv) = (bdims.hidden, bdims.intermediate, bdims.vocab);
    b.add("matmul_896_896_4864", vec![io(&[bh, bh]), io(&[bh, bi])], vec![io(&[bh, bi])],
          &["bench", "matmul"], matmul_flops(bh, bh, bi), "Table 8/12 MLP up projection");
    b.add("matmul_896_4864_896", vec![io(&[bh, bi]), io(&[bi, bh])], vec![io(&[bh, bh])],
          &["bench", "matmul"], matmul_flops(bh, bi, bh), "Table 8/12 MLP down projection");
    b.add("matmul_256_256_256", vec![io(&[256, 256]), io(&[256, 256])], vec![io(&[256, 256])],
          &["bench", "matmul"], matmul_flops(256, 256, 256), "Table 8/12 toy matmul");
    b.add("matmul_naive_256", vec![io(&[256, 256]), io(&[256, 256])], vec![io(&[256, 256])],
          &["bench", "matmul"], matmul_flops(256, 256, 256), "untiled baseline");

    b.add("rmsnorm_896", vec![io(&[1, bh]), io(&[bh])], vec![io(&[1, bh])],
          &["bench", "rmsnorm"], 0.0, "Table 7 fused RMSNorm at 0.5B hidden");
    b.add("rms_pow_896", vec![io(&[1, bh])], vec![io(&[1, bh])], &["bench", "rmsnorm"], 0.0, "");
    b.add("rms_mean_896", vec![io(&[1, bh])], vec![io(&[1, 1])], &["bench", "rmsnorm"], 0.0, "");
    b.add("rms_mul_x_896", vec![io(&[1, bh]), io(&[1, 1])], vec![io(&[1, bh])],
          &["bench", "rmsnorm"], 0.0, "");
    b.add("rms_mul_w_896", vec![io(&[1, bh]), io(&[bh])], vec![io(&[1, bh])],
          &["bench", "rmsnorm"], 0.0, "");

    b.add("matmul_1_896_4864", vec![io(&[1, bh]), io(&[bh, bi])], vec![io(&[1, bi])],
          &["bench", "mlp"], matmul_flops(1, bh, bi), "decode-shape up/gate projection");
    b.add("matmul_1_4864_896", vec![io(&[1, bi]), io(&[bi, bh])], vec![io(&[1, bh])],
          &["bench", "mlp"], matmul_flops(1, bi, bh), "decode-shape down projection");
    b.add("gate_up_silu_05b", vec![io(&[1, bh]), io(&[bh, bi]), io(&[bh, bi])],
          vec![io(&[1, bi])], &["bench", "mlp", "fused"], 2.0 * matmul_flops(1, bh, bi),
          "Table 19 tiled strategy stage 1");
    b.add("silu_4864", vec![io(&[1, bi])], vec![io(&[1, bi])], &["bench", "mlp"], 0.0, "");
    b.add("mul_4864", vec![io(&[1, bi]), io(&[1, bi])], vec![io(&[1, bi])],
          &["bench", "mlp"], 0.0, "");
    b.add("add_896", vec![io(&[1, bh]), io(&[1, bh])], vec![io(&[1, bh])],
          &["bench", "mlp"], 0.0, "");
    b.add("mega_mlp_05b",
          vec![io(&[1, bh]), io(&[bh]), io(&[bh, bi]), io(&[bh, bi]), io(&[bi, bh])],
          vec![io(&[1, bh])], &["bench", "mega"],
          2.0 * matmul_flops(1, bh, bi) + matmul_flops(1, bi, bh),
          "Table 11 mega-kernel at 0.5B dims");

    // Batched decode shapes for the empirical crossover sweep (Appendix F).
    for bsz in [1usize, 4, 8, 16, 32, 64] {
        b.add(&format!("matmul_b{bsz}_896_4864"),
              vec![io(&[bsz, bh]), io(&[bh, bi])], vec![io(&[bsz, bi])],
              &["bench", "batch"], matmul_flops(bsz, bh, bi),
              "MLP up projection (crossover sweep)");
    }

    b.add(&format!("softmax_{bv}"), vec![io(&[1, bv])], vec![io(&[1, bv])],
          &["bench", "softmax"], 0.0, "Table 16 optimized softmax at vocab");
    b.add(&format!("softmax_naive_{bv}"), vec![io(&[1, bv])], vec![io(&[1, bv])],
          &["bench", "softmax"], 0.0, "Table 16 naive softmax at vocab");
    b.add(&format!("argmax_{bv}"), vec![io(&[1, bv])], vec![io_i32(&[1])],
          &["bench", "argmax"], 0.0, "Table 15 device-side argmax at vocab");

    b.kernels
}

fn config_from_dims(name: &str, d: &GraphDims) -> ManifestConfig {
    ManifestConfig {
        name: name.to_string(),
        hidden: d.hidden,
        layers: d.layers,
        heads: d.heads,
        kv_heads: d.kv_heads,
        head_dim: d.head_dim,
        intermediate: d.intermediate,
        vocab: d.vocab,
        max_seq: d.max_seq,
        rope_theta: 10_000.0,
        rms_eps: 1e-6,
    }
}

/// Model configs mirroring the manifest's `configs` section.
pub fn builtin_configs() -> HashMap<String, ManifestConfig> {
    let mut m = HashMap::new();
    m.insert("qwen-tiny".to_string(), config_from_dims("qwen-tiny", &GraphDims::qwen_tiny()));
    m.insert(
        "qwen2.5-0.5b".to_string(),
        config_from_dims("qwen2.5-0.5b", &GraphDims::qwen25_05b()),
    );
    m.insert(
        "qwen2.5-1.5b".to_string(),
        config_from_dims("qwen2.5-1.5b", &GraphDims::qwen25_15b()),
    );
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::builder::{build_decode_graph, FusionConfig};

    #[test]
    fn builtin_covers_every_tiny_graph_kernel() {
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for fusion in [
            FusionConfig::unfused(),
            FusionConfig::rmsnorm_only(),
            FusionConfig::rmsnorm_mlp(),
            FusionConfig::rmsnorm_mlp_kv(),
            FusionConfig::fused(),
        ] {
            let g = build_decode_graph(&dims, fusion);
            for name in g.kernel_names() {
                assert!(kernels.contains_key(&name), "missing kernel '{name}'");
            }
        }
    }

    #[test]
    fn builtin_has_engine_and_bench_side_kernels() {
        let kernels = builtin_kernels();
        for name in [
            "argmax_512", "softmax_512", "rmsnorm_896", "matmul_896_896_4864",
            "matmul_naive_256", "softmax_151936", "softmax_naive_151936",
            "argmax_151936", "matmul_b8_896_4864", "mega_mlp_tiny",
        ] {
            assert!(kernels.contains_key(name), "missing '{name}'");
        }
    }

    #[test]
    fn builtin_covers_every_batched_graph_kernel_at_every_width() {
        use crate::fx::builder::{build_batched_decode_graph, MAX_BATCH_WIDTH};
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for w in 2..=MAX_BATCH_WIDTH {
            for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                let g = build_batched_decode_graph(&dims, fusion, w);
                for name in g.kernel_names() {
                    assert!(kernels.contains_key(&name), "w={w}: missing kernel '{name}'");
                }
            }
        }
        // Gather/scatter arities: W states + rows + 3 per-slot uniforms in,
        // W states out; sdpa gathers 2W caches.
        let cu = &kernels["cache_update_b4_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (4 + 4, 4));
        let sd = &kernels["sdpa_b4_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (1 + 8 + 3, 1));
        let kvf = &kernels["kv_fused_b2_64_64"];
        assert_eq!(kvf.outputs.len(), 2);
    }

    #[test]
    fn builtin_covers_every_prefill_graph_kernel_at_every_chunk() {
        use crate::fx::builder::{build_prefill_graph, PREFILL_CHUNKS};
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for c in PREFILL_CHUNKS {
            for fusion in [
                FusionConfig::unfused(),
                FusionConfig::rmsnorm_only(),
                FusionConfig::rmsnorm_mlp(),
                FusionConfig::rmsnorm_mlp_kv(),
                FusionConfig::fused(),
            ] {
                let g = build_prefill_graph(&dims, fusion, c);
                for name in g.kernel_names() {
                    assert!(kernels.contains_key(&name), "c={c}: missing kernel '{name}'");
                }
            }
        }
        // Prefill cache/attention arities: state + rows + base + valid in,
        // updated state out; sdpa carries the two scalar uniforms.
        let cu = &kernels["cache_update_c16_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (4, 1));
        let sd = &kernels["sdpa_prefill_c16_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (5, 1));
        let lr = &kernels["chunk_last_row_c16_64"];
        assert_eq!(lr.outputs[0].shape, vec![1, 64]);
    }

    #[test]
    fn builtin_covers_every_unified_graph_kernel_at_every_width_and_chunk() {
        use crate::fx::builder::{build_unified_round_graph, MAX_BATCH_WIDTH, PREFILL_CHUNKS};
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for w in 2..=MAX_BATCH_WIDTH {
            for c in PREFILL_CHUNKS {
                for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                    let g = build_unified_round_graph(&dims, fusion, w, c);
                    for name in g.kernel_names() {
                        assert!(
                            kernels.contains_key(&name),
                            "w={w} c={c}: missing kernel '{name}'"
                        );
                    }
                }
            }
        }
        // Gather/scatter arities: W states + rows + 4 per-slot uniforms in,
        // W states out; sdpa gathers 2W caches + 4 uniforms.
        let cu = &kernels["cache_update_b4c16_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (4 + 5, 4));
        let sd = &kernels["sdpa_b4c16_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (1 + 8 + 4, 1));
        // slot_last_row keeps the batched [W, H] tail contract.
        let lr = &kernels["slot_last_row_b4c16_64"];
        assert_eq!(lr.inputs.len(), 3);
        assert_eq!(lr.outputs[0].shape, vec![4, 64]);
    }

    #[test]
    fn builtin_covers_every_multi_row_graph_kernel() {
        use crate::fx::builder::{
            build_prefill_graph_multi_row, build_unified_round_graph_multi_row, MAX_BATCH_WIDTH,
            PREFILL_CHUNKS,
        };
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for c in PREFILL_CHUNKS {
            for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                let g = build_prefill_graph_multi_row(&dims, fusion, c);
                for name in g.kernel_names() {
                    assert!(kernels.contains_key(&name), "c={c}: missing kernel '{name}'");
                }
            }
            for w in 2..=MAX_BATCH_WIDTH {
                for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
                    let g = build_unified_round_graph_multi_row(&dims, fusion, w, c);
                    for name in g.kernel_names() {
                        assert!(
                            kernels.contains_key(&name),
                            "w={w} c={c}: missing kernel '{name}'"
                        );
                    }
                }
            }
        }
        // Multi-row tails keep every verify row: [C, H] / [W*C, H] out of the
        // row-keep kernels, [C, V] / [W*C, V] out of the widened lm heads.
        let cr = &kernels["chunk_rows_c16_64"];
        assert_eq!(cr.outputs[0].shape, vec![16, 64]);
        let sr = &kernels["slot_rows_b4c16_64"];
        assert_eq!(sr.inputs.len(), 3);
        assert_eq!(sr.outputs[0].shape, vec![4 * 16, 64]);
        let lm = &kernels["matmul_c16_64_512"];
        assert_eq!(lm.outputs[0].shape, vec![16, 512]);
        let blm = &kernels["matmul_b4c16_64_512"];
        assert_eq!(blm.outputs[0].shape, vec![4 * 16, 512]);
    }

    #[test]
    fn builtin_covers_every_paged_graph_kernel() {
        use crate::fx::builder::{
            build_batched_decode_graph_paged, build_decode_graph_paged,
            build_prefill_graph_multi_row_paged, build_prefill_graph_paged,
            build_unified_round_graph_multi_row_paged, build_unified_round_graph_paged,
            MAX_BATCH_WIDTH, PREFILL_CHUNKS,
        };
        let kernels = builtin_kernels();
        let dims = GraphDims::qwen_tiny();
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            let g = build_decode_graph_paged(&dims, fusion);
            for name in g.kernel_names() {
                assert!(kernels.contains_key(&name), "decode: missing kernel '{name}'");
            }
            for w in 2..=MAX_BATCH_WIDTH {
                let g = build_batched_decode_graph_paged(&dims, fusion, w);
                for name in g.kernel_names() {
                    assert!(kernels.contains_key(&name), "w={w}: missing kernel '{name}'");
                }
            }
            for c in PREFILL_CHUNKS {
                for g in [
                    build_prefill_graph_paged(&dims, fusion, c),
                    build_prefill_graph_multi_row_paged(&dims, fusion, c),
                ] {
                    for name in g.kernel_names() {
                        assert!(kernels.contains_key(&name), "c={c}: missing kernel '{name}'");
                    }
                }
                for w in 2..=MAX_BATCH_WIDTH {
                    for g in [
                        build_unified_round_graph_paged(&dims, fusion, w, c),
                        build_unified_round_graph_multi_row_paged(&dims, fusion, w, c),
                    ] {
                        for name in g.kernel_names() {
                            assert!(
                                kernels.contains_key(&name),
                                "w={w} c={c}: missing kernel '{name}'"
                            );
                        }
                    }
                }
            }
        }
        // Paged cache/attention arities: ONE pool plane in/out regardless of
        // width — the block table + kv_block uniforms replace slot_idx and
        // the per-slot state fan-in/fan-out.
        let cu = &kernels["cache_update_paged_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (5, 1));
        let sd = &kernels["sdpa_paged_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (6, 1));
        let cu = &kernels["cache_update_paged_b4_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (6, 1));
        let sd = &kernels["sdpa_paged_b4_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (7, 1));
        let cu = &kernels["cache_update_paged_c16_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (6, 1));
        let sd = &kernels["sdpa_prefill_paged_c16_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (7, 1));
        let cu = &kernels["cache_update_paged_b4c16_tiny"];
        assert_eq!((cu.inputs.len(), cu.outputs.len()), (7, 1));
        let sd = &kernels["sdpa_paged_b4c16_tiny"];
        assert_eq!((sd.inputs.len(), sd.outputs.len()), (8, 1));
        // Pool planes are MAX_BATCH_WIDTH sessions' worth of rows.
        assert_eq!(
            kernels["cache_update_paged_tiny"].inputs[0].shape,
            vec![MAX_BATCH_WIDTH * dims.max_seq, dims.kv_heads, dims.head_dim]
        );
    }

    #[test]
    fn builtin_configs_cover_models() {
        let c = builtin_configs();
        assert_eq!(c["qwen-tiny"].hidden, 64);
        assert_eq!(c["qwen2.5-0.5b"].layers, 24);
        assert_eq!(c["qwen2.5-1.5b"].hidden, 1536);
    }
}

//! Artifact discovery plus (feature-gated) the PJRT CPU client.
//!
//! The `xla`-crate-backed [`PjrtRuntime`] only builds with the `pjrt`
//! feature: the offline environment cannot link xla_extension, so the
//! default build executes kernels through the pure-Rust
//! [`super::reference::ReferenceRuntime`] instead (same kernel names, same
//! numerics contract). Everything here that touches `xla` is `cfg`-gated;
//! [`ArtifactPaths`] is shared by both backends.

use std::path::PathBuf;

use crate::{Error, Result};

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::Path;
    use std::time::Instant;

    use crate::tensor::{DType, Tensor};
    use crate::{Error, Result};

    /// PJRT client + per-kernel compiled-executable cache.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        /// kernel name -> compiled executable (compile once, execute many).
        cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
        /// Cumulative compile time (reported in EXPERIMENTS.md; compile
        /// happens off the request path, at engine startup or first use).
        pub compile_ns: RefCell<u64>,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            Ok(PjrtRuntime {
                client,
                cache: RefCell::new(HashMap::new()),
                compile_ns: RefCell::new(0),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an HLO-text file and cache under `name`.
        pub fn load_hlo_text(&self, name: &str, path: &Path) -> Result<()> {
            if self.cache.borrow().contains_key(name) {
                return Ok(());
            }
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                Error::Artifact(format!("parse {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| {
                Error::Runtime(format!("compile {name}: {e}"))
            })?;
            *self.compile_ns.borrow_mut() += t0.elapsed().as_nanos() as u64;
            self.cache.borrow_mut().insert(name.to_string(), exe);
            Ok(())
        }

        pub fn is_loaded(&self, name: &str) -> bool {
            self.cache.borrow().contains_key(name)
        }

        pub fn loaded_count(&self) -> usize {
            self.cache.borrow().len()
        }

        /// Execute a cached kernel. Inputs are host tensors; outputs come
        /// back as host tensors (AOT modules lower with return_tuple=True).
        /// Returns (outputs, wall ns of the execute+readback).
        pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<(Vec<Tensor>, u64)> {
            let cache = self.cache.borrow();
            let exe = cache
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("kernel '{name}' not loaded")))?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(tensor_to_literal)
                .collect::<Result<_>>()?;
            let t0 = Instant::now();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
            let root = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("readback {name}: {e}")))?;
            let parts = root
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
            let ns = t0.elapsed().as_nanos() as u64;
            let outs = parts
                .iter()
                .map(literal_to_tensor)
                .collect::<Result<Vec<_>>>()?;
            Ok((outs, ns))
        }
    }

    pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
        let ty = match t.dtype() {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, t.data.as_bytes())
            .map_err(|e| Error::Xla(e.to_string()))
    }

    pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
        let shape = l.array_shape().map_err(|e| Error::Xla(e.to_string()))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let v = l.to_vec::<f32>().map_err(|e| Error::Xla(e.to_string()))?;
                Tensor::f32(dims, v)
            }
            xla::ElementType::S32 => {
                let v = l.to_vec::<i32>().map_err(|e| Error::Xla(e.to_string()))?;
                Tensor::i32(dims, v)
            }
            other => Err(Error::Runtime(format!("unsupported element type {other:?}"))),
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{literal_to_tensor, tensor_to_literal, PjrtRuntime};

#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub dir: PathBuf,
}

impl ArtifactPaths {
    /// Locate the artifacts directory: $WDB_ARTIFACTS, ./artifacts, or the
    /// repo-root artifacts relative to the executable.
    pub fn discover() -> Result<Self> {
        if let Ok(p) = std::env::var("WDB_ARTIFACTS") {
            let dir = PathBuf::from(p);
            if dir.join("manifest.json").exists() {
                return Ok(ArtifactPaths { dir });
            }
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let dir = PathBuf::from(cand);
            if dir.join("manifest.json").exists() {
                return Ok(ArtifactPaths { dir });
            }
        }
        Err(Error::Artifact(
            "artifacts/manifest.json not found — run `make artifacts` \
             (or set WDB_ARTIFACTS)"
                .into(),
        ))
    }
}

//! Host-side ops: the FX node categories that do NOT become WebGPU
//! dispatches (the paper's 241 shape ops plus embedding/index glue — §4.3
//! "shape operations don't require them").
//!
//! In torch-webgpu these run on CPU against tensor metadata; here they run
//! on host tensors between dispatches. They carry no virtual-clock cost
//! beyond the engine's per-op framework overhead.

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Embedding lookup: `table[token] -> [1, H]` (Table 10 "Other").
pub fn embed(table: &Tensor, token: usize) -> Result<Tensor> {
    if table.shape.len() != 2 {
        return Err(Error::Shape(format!("embed table must be 2-D, got {:?}", table.shape)));
    }
    let (vocab, hidden) = (table.shape[0], table.shape[1]);
    if token >= vocab {
        return Err(Error::Shape(format!("token {token} >= vocab {vocab}")));
    }
    let data = table.as_f32()?[token * hidden..(token + 1) * hidden].to_vec();
    Tensor::f32(vec![1, hidden], data)
}

/// Split a fused K+V projection output `[1, 2*KV]` into (K, V) `[1, KV]`.
pub fn split_kv(kv: &Tensor) -> Result<(Tensor, Tensor)> {
    if kv.shape.len() != 2 || kv.shape[1] % 2 != 0 {
        return Err(Error::Shape(format!("split_kv expects [1, 2k], got {:?}", kv.shape)));
    }
    let half = kv.shape[1] / 2;
    Ok((kv.slice_last_2d(0, half)?, kv.slice_last_2d(half, kv.shape[1])?))
}

/// `x.reshape(heads, head_dim)` — pure metadata.
pub fn to_heads(x: &Tensor, heads: usize, head_dim: usize) -> Result<Tensor> {
    x.reshape(vec![heads, head_dim])
}

/// `x.reshape(1, heads*head_dim)` — pure metadata.
pub fn from_heads(x: &Tensor) -> Result<Tensor> {
    let n = x.numel();
    x.reshape(vec![1, n])
}

/// First/second half split along the last axis (unfused rotary rotate-half).
pub fn halves(x: &Tensor) -> Result<(Tensor, Tensor)> {
    if x.shape.len() != 2 || x.shape[1] % 2 != 0 {
        return Err(Error::Shape(format!("halves expects [h, 2k], got {:?}", x.shape)));
    }
    let half = x.shape[1] / 2;
    Ok((x.slice_last_2d(0, half)?, x.slice_last_2d(half, x.shape[1])?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, n: usize) -> Tensor {
        Tensor::f32(shape, (0..n).map(|x| x as f32).collect()).unwrap()
    }

    #[test]
    fn embed_picks_row() {
        let table = t(vec![4, 3], 12);
        let e = embed(&table, 2).unwrap();
        assert_eq!(e.shape, vec![1, 3]);
        assert_eq!(e.as_f32().unwrap(), &[6.0, 7.0, 8.0]);
        assert!(embed(&table, 4).is_err());
    }

    #[test]
    fn split_kv_halves() {
        let kv = t(vec![1, 6], 6);
        let (k, v) = split_kv(&kv).unwrap();
        assert_eq!(k.as_f32().unwrap(), &[0.0, 1.0, 2.0]);
        assert_eq!(v.as_f32().unwrap(), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn head_reshapes() {
        let x = t(vec![1, 8], 8);
        let h = to_heads(&x, 2, 4).unwrap();
        assert_eq!(h.shape, vec![2, 4]);
        let back = from_heads(&h).unwrap();
        assert_eq!(back.shape, vec![1, 8]);
    }

    #[test]
    fn halves_split() {
        let x = t(vec![2, 4], 8);
        let (a, b) = halves(&x).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[0.0, 1.0, 4.0, 5.0]);
        assert_eq!(b.as_f32().unwrap(), &[2.0, 3.0, 6.0, 7.0]);
    }
}

//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Flow (see /opt/xla-example/load_hlo and DESIGN.md §2):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::cpu().compile` (once, cached) -> `execute` per dispatch.
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

pub mod client;
pub mod hostops;
pub mod registry;

pub use client::PjrtRuntime;
pub use registry::{KernelSpec, Registry};

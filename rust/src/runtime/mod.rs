//! Kernel runtime: specs + execution backends.
//!
//! Default backend is the pure-Rust host reference interpreter
//! (`reference`), driven by the built-in manifest (`builtin`) or an
//! on-disk `artifacts/manifest.json`. The PJRT path — `HloModuleProto::
//! from_text_file` -> `XlaComputation::from_proto` -> `PjRtClient::cpu()
//! .compile` (once, cached) -> `execute` per dispatch — builds only with
//! `--features pjrt`, because the `xla` crate links xla_extension, which
//! the offline environment does not provide.

pub mod builtin;
pub mod client;
pub mod hostops;
pub mod reference;
pub mod registry;

pub use client::ArtifactPaths;
#[cfg(feature = "pjrt")]
pub use client::PjrtRuntime;
pub use reference::ReferenceRuntime;
pub use registry::{KernelRuntime, KernelSpec, Registry};

//! Host reference runtime: a pure-Rust interpreter for every kernel the
//! AOT registry exports (`python/compile/aot.py`), keyed by kernel name.
//!
//! This is the default execution backend: the offline environment cannot
//! link the `xla` crate's PJRT client, so dispatches land here instead.
//! Each implementation mirrors the jnp oracle in
//! `python/compile/kernels/ref.py` operation-for-operation, and —
//! critically for the fusion and serving equivalence tests — the fused
//! kernels are written as the exact float32 composition of their unfused
//! counterparts, so fused and unfused flows produce bit-identical token
//! streams.

use std::cell::RefCell;
use std::collections::HashSet;
use std::time::Instant;

use crate::tensor::Tensor;
use crate::{Error, Result};

use super::registry::KernelSpec;

const RMS_EPS: f32 = 1e-6;

/// Always-available kernel interpreter with PJRT-compatible bookkeeping
/// (loaded-set tracking so `ensure_loaded`/`preload` behave identically).
#[derive(Debug, Default)]
pub struct ReferenceRuntime {
    loaded: RefCell<HashSet<String>>,
}

impl ReferenceRuntime {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn platform(&self) -> String {
        "host-reference".to_string()
    }

    pub fn mark_loaded(&self, name: &str) {
        self.loaded.borrow_mut().insert(name.to_string());
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.borrow().contains(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.loaded.borrow().len()
    }

    /// Execute a kernel by name. Returns (outputs, wall ns).
    pub fn execute(&self, spec: &KernelSpec, inputs: &[Tensor]) -> Result<(Vec<Tensor>, u64)> {
        self.mark_loaded(&spec.name);
        let t0 = Instant::now();
        let outs = execute_kernel(spec, inputs)?;
        let ns = (t0.elapsed().as_nanos() as u64).max(1);
        Ok((outs, ns))
    }
}

fn f32s<'a>(t: &'a Tensor, what: &str) -> Result<&'a [f32]> {
    t.as_f32()
        .map_err(|_| Error::Runtime(format!("{what}: expected f32 input")))
}

fn scalar_pos(t: &Tensor) -> Result<usize> {
    let v = t
        .as_i32()
        .map_err(|_| Error::Runtime("position input must be i32".into()))?;
    Ok(v[0].max(0) as usize)
}

// ---------------------------------------------------------------- helpers --

fn matmul(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    if x.shape.len() != 2 || w.shape.len() != 2 || x.shape[1] != w.shape[0] {
        return Err(Error::Shape(format!(
            "matmul {:?} x {:?}",
            x.shape, w.shape
        )));
    }
    let (m, k, n) = (x.shape[0], x.shape[1], w.shape[1]);
    let (xd, wd) = (f32s(x, "matmul")?, f32s(w, "matmul")?);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let row = &xd[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in row.iter().enumerate() {
            let wrow = &wd[kk * n..(kk + 1) * n];
            for (j, &wv) in wrow.iter().enumerate() {
                orow[j] += xv * wv;
            }
        }
    }
    Tensor::f32(vec![m, n], out)
}

fn unary(x: &Tensor, f: impl Fn(f32) -> f32) -> Result<Tensor> {
    let v: Vec<f32> = f32s(x, "unary")?.iter().map(|&a| f(a)).collect();
    Tensor::f32(x.shape.clone(), v)
}

fn binary_same(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!(
            "elementwise {:?} vs {:?}",
            a.shape, b.shape
        )));
    }
    let (ad, bd) = (f32s(a, "binary")?, f32s(b, "binary")?);
    let v: Vec<f32> = ad.iter().zip(bd).map(|(&x, &y)| f(x, y)).collect();
    Tensor::f32(a.shape.clone(), v)
}

/// `x * v` where `v` broadcasts over the last axis (rms_mul_w / mul_vec).
fn mul_lastdim(x: &Tensor, v: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("mul_vec: 0-d".into()))?;
    if v.numel() != d {
        return Err(Error::Shape(format!(
            "mul_vec: {:?} * {:?}",
            x.shape, v.shape
        )));
    }
    let (xd, vd) = (f32s(x, "mul_vec")?, f32s(v, "mul_vec")?);
    let out: Vec<f32> = xd.iter().enumerate().map(|(i, &a)| a * vd[i % d]).collect();
    Tensor::f32(x.shape.clone(), out)
}

/// `x * r` where `r` is a single scalar (rms_mul_x).
fn mul_scalar_t(x: &Tensor, r: &Tensor) -> Result<Tensor> {
    let s = f32s(r, "mul_scalar")?[0];
    unary(x, |a| a * s)
}

fn silu(x: f32) -> f32 {
    x * (1.0 / (1.0 + (-x).exp()))
}

/// Row-wise softmax over the last axis with max subtraction (the
/// "parallel" variant); `naive` skips nothing numerically here — the naive
/// shader differs in memory traffic, not math — so both share this body.
fn softmax_rows(x: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("softmax: 0-d".into()))?;
    let xd = f32s(x, "softmax")?;
    let mut out = vec![0f32; xd.len()];
    for r in 0..xd.len() / d {
        let row = &xd[r * d..(r + 1) * d];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[r * d + j] = e;
            sum += e;
        }
        for j in 0..d {
            out[r * d + j] /= sum;
        }
    }
    Tensor::f32(x.shape.clone(), out)
}

/// Fused RMSNorm, written as the exact composition of the 6-dispatch
/// decomposition (pow, mean, +eps, rsqrt, mul_x, mul_w) so fused and
/// unfused flows agree bit-for-bit.
fn rmsnorm(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let x2 = unary(x, |a| a * a)?;
    let m = rms_mean(&x2)?;
    let me = unary(&m, |a| a + RMS_EPS)?;
    let r = unary(&me, |a| 1.0 / a.sqrt())?;
    let xn = mul_scalar_t(x, &r)?;
    mul_lastdim(&xn, w)
}

fn rms_mean(x2: &Tensor) -> Result<Tensor> {
    let d = *x2.shape.last().ok_or_else(|| Error::Shape("rms_mean: 0-d".into()))?;
    let xd = f32s(x2, "rms_mean")?;
    let rows = xd.len() / d;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let sum: f32 = xd[r * d..(r + 1) * d].iter().sum();
        out.push(sum / d as f32);
    }
    let mut shape = x2.shape.clone();
    *shape.last_mut().unwrap() = 1;
    Tensor::f32(shape, out)
}

/// rotate_half: concat(-x2, x1) over the last axis of a 2-D tensor.
fn rotate_half(x: &Tensor) -> Result<Tensor> {
    let (h, d) = (x.shape[0], x.shape[1]);
    let half = d / 2;
    let xd = f32s(x, "rotate_half")?;
    let mut out = vec![0f32; h * d];
    for i in 0..h {
        for j in 0..half {
            out[i * d + j] = -xd[i * d + half + j];
            out[i * d + half + j] = xd[i * d + j];
        }
    }
    Tensor::f32(vec![h, d], out)
}

/// Fused rotary — exact composition of the unfused neg/concat/mul/mul/add
/// chain: a = x*cos, b = rotate_half(x)*sin, out = a + b.
fn rotary(x: &Tensor, cos: &Tensor, sin: &Tensor) -> Result<Tensor> {
    let rot = rotate_half(x)?;
    let a = mul_lastdim(x, cos)?;
    let b = mul_lastdim(&rot, sin)?;
    binary_same(&a, &b, |p, q| p + q)
}

fn rope_cos_sin(pos: &Tensor, inv_freq: &Tensor) -> Result<Vec<Tensor>> {
    let p = f32s(pos, "rope")?[0];
    let inv = f32s(inv_freq, "rope")?;
    let half = inv.len();
    let mut cos = vec![0f32; 2 * half];
    let mut sin = vec![0f32; 2 * half];
    for (j, &iv) in inv.iter().enumerate() {
        let f = p * iv;
        let (c, s) = (f.cos(), f.sin());
        cos[j] = c;
        cos[half + j] = c;
        sin[j] = s;
        sin[half + j] = s;
    }
    Ok(vec![
        Tensor::f32(vec![2 * half], cos)?,
        Tensor::f32(vec![2 * half], sin)?,
    ])
}

/// Write `new_row` ([KVH, D]) at `cache[pos]` ([S, KVH, D]).
fn cache_update(cache: &Tensor, new_row: &Tensor, pos: usize) -> Result<Tensor> {
    if cache.shape.len() != 3 || new_row.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update {:?} <- {:?}",
            cache.shape, new_row.shape
        )));
    }
    let (s, kvh, d) = (cache.shape[0], cache.shape[1], cache.shape[2]);
    if pos >= s || new_row.shape != [kvh, d] {
        return Err(Error::Shape(format!(
            "cache_update: pos {pos} / row {:?} vs cache {:?}",
            new_row.shape, cache.shape
        )));
    }
    let mut out = f32s(cache, "cache_update")?.to_vec();
    let row = f32s(new_row, "cache_update")?;
    out[pos * kvh * d..(pos + 1) * kvh * d].copy_from_slice(row);
    Tensor::f32(vec![s, kvh, d], out)
}

/// Grouped-query attention over a fixed-capacity masked KV cache
/// (`ref.sdpa_gqa`): positions `0..pos` are valid.
fn sdpa_gqa(q: &Tensor, k: &Tensor, v: &Tensor, pos: usize) -> Result<Tensor> {
    if q.shape.len() != 2 || k.shape.len() != 3 || v.shape != k.shape {
        return Err(Error::Shape(format!(
            "sdpa q {:?} k {:?} v {:?}",
            q.shape, k.shape, v.shape
        )));
    }
    let (heads, dim) = (q.shape[0], q.shape[1]);
    let (seq, kvh, kd) = (k.shape[0], k.shape[1], k.shape[2]);
    if kd != dim || kvh == 0 || heads % kvh != 0 {
        return Err(Error::Shape(format!(
            "sdpa head layout: {heads} q heads over {kvh} kv heads, dim {dim}/{kd}"
        )));
    }
    let group = heads / kvh;
    let scale = 1.0 / (dim as f32).sqrt();
    let valid = pos.min(seq).max(1);
    let (qd, kdat, vdat) = (f32s(q, "sdpa")?, f32s(k, "sdpa")?, f32s(v, "sdpa")?);
    let mut out = vec![0f32; heads * dim];
    let mut scores = vec![0f32; valid];
    for h in 0..heads {
        let kv_h = h / group;
        let qrow = &qd[h * dim..(h + 1) * dim];
        for (s, score) in scores.iter_mut().enumerate() {
            let krow = &kdat[(s * kvh + kv_h) * dim..(s * kvh + kv_h + 1) * dim];
            let mut dot = 0f32;
            for (a, b) in qrow.iter().zip(krow) {
                dot += a * b;
            }
            *score = dot * scale;
        }
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        let mut probs = vec![0f32; valid];
        for (s, &sc) in scores.iter().enumerate() {
            let e = (sc - m).exp();
            probs[s] = e;
            sum += e;
        }
        let orow = &mut out[h * dim..(h + 1) * dim];
        for (s, &p) in probs.iter().enumerate() {
            let w = p / sum;
            let vrow = &vdat[(s * kvh + kv_h) * dim..(s * kvh + kv_h + 1) * dim];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
    }
    Tensor::f32(vec![heads, dim], out)
}

/// Fused MLP stage — exact composition of matmul/matmul/silu/mul.
fn gate_up_silu(x: &Tensor, wg: &Tensor, wu: &Tensor) -> Result<Tensor> {
    let g = matmul(x, wg)?;
    let u = matmul(x, wu)?;
    let s = unary(&g, silu)?;
    binary_same(&s, &u, |a, b| a * b)
}

fn argmax_rows(x: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("argmax: 0-d".into()))?;
    let xd = f32s(x, "argmax")?;
    let rows = xd.len() / d;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &xd[r * d..(r + 1) * d];
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bestv {
                bestv = v;
                best = j;
            }
        }
        out.push(best as i32);
    }
    Tensor::i32(vec![rows], out)
}

fn mega_mlp(
    x: &Tensor,
    rms_w: &Tensor,
    wg: &Tensor,
    wu: &Tensor,
    wd: &Tensor,
) -> Result<Tensor> {
    let h = rmsnorm(x, rms_w)?;
    let act = gate_up_silu(&h, wg, wu)?;
    let down = matmul(&act, wd)?;
    binary_same(x, &down, |a, b| a + b)
}

/// Concatenate two 2-D tensors along the last axis.
fn concat_last(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[0] != b.shape[0] {
        return Err(Error::Shape(format!(
            "concat {:?} ++ {:?}",
            a.shape, b.shape
        )));
    }
    let (rows, ca, cb) = (a.shape[0], a.shape[1], b.shape[1]);
    let (ad, bd) = (f32s(a, "concat")?, f32s(b, "concat")?);
    let mut out = Vec::with_capacity(rows * (ca + cb));
    for r in 0..rows {
        out.extend_from_slice(&ad[r * ca..(r + 1) * ca]);
        out.extend_from_slice(&bd[r * cb..(r + 1) * cb]);
    }
    Tensor::f32(vec![rows, ca + cb], out)
}

// --------------------------------------------------------------- dispatch --

fn need(inputs: &[Tensor], n: usize, name: &str) -> Result<()> {
    if inputs.len() != n {
        return Err(Error::Runtime(format!(
            "kernel {name}: needs {n} inputs, got {}",
            inputs.len()
        )));
    }
    Ok(())
}

/// Interpret `spec.name` and produce outputs matching `spec.outputs`.
pub fn execute_kernel(spec: &KernelSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let name = spec.name.as_str();
    // Ordering matters: check longer/more-specific prefixes before shorter
    // ones (e.g. "matmul" before "mul_", "rms_mul_x" before "rms_mul_w",
    // "softmax_naive" before "softmax").
    let outs: Vec<Tensor> = if name.starts_with("matmul") || name.starts_with("kv_fused") {
        need(inputs, 2, name)?;
        vec![matmul(&inputs[0], &inputs[1])?]
    } else if name.starts_with("gate_up_silu") {
        need(inputs, 3, name)?;
        vec![gate_up_silu(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("mega_mlp") {
        need(inputs, 5, name)?;
        vec![mega_mlp(&inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4])?]
    } else if name.starts_with("rmsnorm") {
        need(inputs, 2, name)?;
        vec![rmsnorm(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rms_pow") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| a * a)?]
    } else if name.starts_with("rms_mean") {
        need(inputs, 1, name)?;
        vec![rms_mean(&inputs[0])?]
    } else if name.starts_with("rms_add_eps") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| a + RMS_EPS)?]
    } else if name.starts_with("rms_rsqrt") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| 1.0 / a.sqrt())?]
    } else if name.starts_with("rms_mul_x") {
        need(inputs, 2, name)?;
        vec![mul_scalar_t(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rms_mul_w") || name.starts_with("mul_vec") {
        need(inputs, 2, name)?;
        vec![mul_lastdim(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rope_cos_sin") {
        need(inputs, 2, name)?;
        rope_cos_sin(&inputs[0], &inputs[1])?
    } else if name.starts_with("rotary") {
        need(inputs, 3, name)?;
        vec![rotary(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("neg") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| -a)?]
    } else if name.starts_with("concat") {
        need(inputs, 2, name)?;
        vec![concat_last(&inputs[0], &inputs[1])?]
    } else if name.starts_with("cache_update") {
        need(inputs, 3, name)?;
        let pos = scalar_pos(&inputs[2])?;
        vec![cache_update(&inputs[0], &inputs[1], pos)?]
    } else if name.starts_with("sdpa") {
        need(inputs, 4, name)?;
        let pos = scalar_pos(&inputs[3])?;
        vec![sdpa_gqa(&inputs[0], &inputs[1], &inputs[2], pos)?]
    } else if name.starts_with("silu") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], silu)?]
    } else if name.starts_with("softmax") {
        // covers softmax_naive_* too — same math, different memory traffic
        need(inputs, 1, name)?;
        vec![softmax_rows(&inputs[0])?]
    } else if name.starts_with("argmax") {
        need(inputs, 1, name)?;
        vec![argmax_rows(&inputs[0])?]
    } else if name.starts_with("add") {
        need(inputs, 2, name)?;
        vec![binary_same(&inputs[0], &inputs[1], |a, b| a + b)?]
    } else if name.starts_with("mul") {
        need(inputs, 2, name)?;
        vec![binary_same(&inputs[0], &inputs[1], |a, b| a * b)?]
    } else {
        return Err(Error::Runtime(format!(
            "reference runtime has no implementation for kernel '{name}'"
        )));
    };

    // Enforce the manifest's output contract (the PJRT path gets this from
    // the lowered module; here we check explicitly).
    if outs.len() != spec.outputs.len() {
        return Err(Error::Runtime(format!(
            "kernel {name}: produced {} outputs, manifest says {}",
            outs.len(),
            spec.outputs.len()
        )));
    }
    for (i, (o, s)) in outs.iter().zip(&spec.outputs).enumerate() {
        if o.shape != s.shape || o.dtype() != s.dtype {
            return Err(Error::Runtime(format!(
                "kernel {name}: output {i} is {:?}/{}, manifest wants {:?}/{}",
                o.shape,
                o.dtype(),
                s.shape,
                s.dtype
            )));
        }
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::webgpu::KernelIoSpec;

    fn spec(name: &str, outputs: Vec<KernelIoSpec>) -> KernelSpec {
        KernelSpec {
            name: name.into(),
            file: String::new(),
            inputs: vec![],
            outputs,
            tags: vec![],
            flops: 0.0,
            notes: String::new(),
        }
    }

    fn io(shape: Vec<usize>, dtype: DType) -> KernelIoSpec {
        KernelIoSpec { shape, dtype }
    }

    #[test]
    fn matmul_identity() {
        let x = Tensor::f32(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let eye = Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let s = spec("matmul_2_2", vec![io(vec![1, 2], DType::F32)]);
        let out = execute_kernel(&s, &[x, eye]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[3.0, 4.0]);
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x = Tensor::f32(vec![1, 4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        let w = Tensor::f32(vec![4], vec![1.0; 4]).unwrap();
        let s = spec("rmsnorm_4", vec![io(vec![1, 4], DType::F32)]);
        let out = execute_kernel(&s, &[x, w]).unwrap();
        let v = out[0].as_f32().unwrap();
        let rms: f32 = (v.iter().map(|a| a * a).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
    }

    #[test]
    fn fused_rmsnorm_matches_decomposition_bitwise() {
        let x = Tensor::f32(vec![1, 8], (0..8).map(|i| i as f32 * 0.37 - 1.1).collect()).unwrap();
        let w = Tensor::f32(vec![8], (0..8).map(|i| 0.5 + i as f32 * 0.1).collect()).unwrap();
        let fused = rmsnorm(&x, &w).unwrap();
        let x2 = unary(&x, |a| a * a).unwrap();
        let m = rms_mean(&x2).unwrap();
        let me = unary(&m, |a| a + RMS_EPS).unwrap();
        let r = unary(&me, |a| 1.0 / a.sqrt()).unwrap();
        let xn = mul_scalar_t(&x, &r).unwrap();
        let dec = mul_lastdim(&xn, &w).unwrap();
        assert_eq!(fused.as_f32().unwrap(), dec.as_f32().unwrap());
    }

    #[test]
    fn rotary_matches_unfused_chain_bitwise() {
        let x = Tensor::f32(vec![2, 4], (0..8).map(|i| (i as f32).sin()).collect()).unwrap();
        let cos = Tensor::f32(vec![4], vec![0.9, 0.8, 0.9, 0.8]).unwrap();
        let sin = Tensor::f32(vec![4], vec![0.1, 0.2, 0.1, 0.2]).unwrap();
        let fused = rotary(&x, &cos, &sin).unwrap();
        // unfused: halves -> neg -> concat -> mul_vec x2 -> add
        let half = 2;
        let xd = x.as_f32().unwrap();
        let mut x1 = Vec::new();
        let mut x2 = Vec::new();
        for r in 0..2 {
            x1.extend_from_slice(&xd[r * 4..r * 4 + half]);
            x2.extend_from_slice(&xd[r * 4 + half..r * 4 + 4]);
        }
        let x1 = Tensor::f32(vec![2, 2], x1).unwrap();
        let x2 = Tensor::f32(vec![2, 2], x2).unwrap();
        let x2n = unary(&x2, |a| -a).unwrap();
        let rot = concat_last(&x2n, &x1).unwrap();
        let a = mul_lastdim(&x, &cos).unwrap();
        let b = mul_lastdim(&rot, &sin).unwrap();
        let dec = binary_same(&a, &b, |p, q| p + q).unwrap();
        assert_eq!(fused.as_f32().unwrap(), dec.as_f32().unwrap());
    }

    #[test]
    fn sdpa_single_position_returns_value_row() {
        // With one valid cache row, attention output == that row's V.
        let q = Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let mut k = vec![0f32; 4 * 1 * 2];
        let mut v = vec![0f32; 4 * 1 * 2];
        k[0] = 1.0;
        k[1] = 2.0;
        v[0] = 5.0;
        v[1] = -3.0;
        let k = Tensor::f32(vec![4, 1, 2], k).unwrap();
        let v = Tensor::f32(vec![4, 1, 2], v).unwrap();
        let out = sdpa_gqa(&q, &k, &v, 1).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[5.0, -3.0, 5.0, -3.0]);
    }

    #[test]
    fn cache_update_writes_row() {
        let cache = Tensor::zeros_f32(vec![3, 1, 2]);
        let row = Tensor::f32(vec![1, 2], vec![7.0, 8.0]).unwrap();
        let out = cache_update(&cache, &row, 1).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[0.0, 0.0, 7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn argmax_first_max_wins() {
        let x = Tensor::f32(vec![1, 4], vec![1.0, 9.0, 9.0, 0.0]).unwrap();
        let s = spec("argmax_4", vec![io(vec![1], DType::I32)]);
        let out = execute_kernel(&s, &[x]).unwrap();
        assert_eq!(out[0].as_i32().unwrap(), &[1]);
    }

    #[test]
    fn softmax_rows_normalize() {
        let x = Tensor::f32(vec![2, 3], vec![0.0, 1.0, 2.0, -5.0, 0.0, 5.0]).unwrap();
        let out = softmax_rows(&x).unwrap();
        let v = out.as_f32().unwrap();
        for r in 0..2 {
            let sum: f32 = v[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn unknown_kernel_rejected() {
        let s = spec("warp_drive_9000", vec![]);
        assert!(execute_kernel(&s, &[]).is_err());
    }
}

//! Host reference runtime: a pure-Rust interpreter for every kernel the
//! AOT registry exports (`python/compile/aot.py`), keyed by kernel name.
//!
//! This is the default execution backend: the offline environment cannot
//! link the `xla` crate's PJRT client, so dispatches land here instead.
//! Each implementation mirrors the jnp oracle in
//! `python/compile/kernels/ref.py` operation-for-operation, and —
//! critically for the fusion and serving equivalence tests — the fused
//! kernels are written as the exact float32 composition of their unfused
//! counterparts, so fused and unfused flows produce bit-identical token
//! streams.

use std::cell::RefCell;
use std::collections::HashSet;
use std::time::Instant;

use crate::tensor::Tensor;
use crate::{Error, Result};

use super::registry::KernelSpec;

const RMS_EPS: f32 = 1e-6;

/// Always-available kernel interpreter with PJRT-compatible bookkeeping
/// (loaded-set tracking so `ensure_loaded`/`preload` behave identically).
#[derive(Debug, Default)]
pub struct ReferenceRuntime {
    loaded: RefCell<HashSet<String>>,
}

impl ReferenceRuntime {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn platform(&self) -> String {
        "host-reference".to_string()
    }

    pub fn mark_loaded(&self, name: &str) {
        self.loaded.borrow_mut().insert(name.to_string());
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.borrow().contains(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.loaded.borrow().len()
    }

    /// Execute a kernel by name. Returns (outputs, wall ns).
    pub fn execute(&self, spec: &KernelSpec, inputs: &[Tensor]) -> Result<(Vec<Tensor>, u64)> {
        self.mark_loaded(&spec.name);
        let t0 = Instant::now();
        let outs = execute_kernel(spec, inputs)?;
        let ns = (t0.elapsed().as_nanos() as u64).max(1);
        Ok((outs, ns))
    }
}

fn f32s<'a>(t: &'a Tensor, what: &str) -> Result<&'a [f32]> {
    t.as_f32()
        .map_err(|_| Error::Runtime(format!("{what}: expected f32 input")))
}

fn scalar_pos(t: &Tensor) -> Result<usize> {
    let v = t
        .as_i32()
        .map_err(|_| Error::Runtime("position input must be i32".into()))?;
    Ok(v[0].max(0) as usize)
}

// ---------------------------------------------------------------- helpers --

fn matmul(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    if x.shape.len() != 2 || w.shape.len() != 2 || x.shape[1] != w.shape[0] {
        return Err(Error::Shape(format!(
            "matmul {:?} x {:?}",
            x.shape, w.shape
        )));
    }
    let (m, k, n) = (x.shape[0], x.shape[1], w.shape[1]);
    let (xd, wd) = (f32s(x, "matmul")?, f32s(w, "matmul")?);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let row = &xd[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in row.iter().enumerate() {
            let wrow = &wd[kk * n..(kk + 1) * n];
            for (j, &wv) in wrow.iter().enumerate() {
                orow[j] += xv * wv;
            }
        }
    }
    Tensor::f32(vec![m, n], out)
}

fn unary(x: &Tensor, f: impl Fn(f32) -> f32) -> Result<Tensor> {
    let v: Vec<f32> = f32s(x, "unary")?.iter().map(|&a| f(a)).collect();
    Tensor::f32(x.shape.clone(), v)
}

fn binary_same(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!(
            "elementwise {:?} vs {:?}",
            a.shape, b.shape
        )));
    }
    let (ad, bd) = (f32s(a, "binary")?, f32s(b, "binary")?);
    let v: Vec<f32> = ad.iter().zip(bd).map(|(&x, &y)| f(x, y)).collect();
    Tensor::f32(a.shape.clone(), v)
}

/// `x * v` where `v` broadcasts over the last axis (rms_mul_w / mul_vec).
fn mul_lastdim(x: &Tensor, v: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("mul_vec: 0-d".into()))?;
    if v.numel() != d {
        return Err(Error::Shape(format!(
            "mul_vec: {:?} * {:?}",
            x.shape, v.shape
        )));
    }
    let (xd, vd) = (f32s(x, "mul_vec")?, f32s(v, "mul_vec")?);
    let out: Vec<f32> = xd.iter().enumerate().map(|(i, &a)| a * vd[i % d]).collect();
    Tensor::f32(x.shape.clone(), out)
}

/// `x * r` where `r` holds one scalar per ROW of `x` (rms_mul_x). The
/// single-session kernel is the rows == 1 case — numerically identical to
/// the old whole-tensor scalar multiply — and the batched `[W, 1]` scale
/// applies each slot's rsqrt to its own row only.
fn mul_row_scalar(x: &Tensor, r: &Tensor) -> Result<Tensor> {
    let rows = *x.shape.first().ok_or_else(|| Error::Shape("mul_scalar: 0-d".into()))?;
    if r.numel() != rows || rows == 0 {
        return Err(Error::Shape(format!(
            "mul_scalar: {:?} rows vs {:?} scales",
            x.shape, r.shape
        )));
    }
    let (xd, rd) = (f32s(x, "mul_scalar")?, f32s(r, "mul_scalar")?);
    let d = xd.len() / rows;
    let out: Vec<f32> = xd
        .iter()
        .enumerate()
        .map(|(i, &a)| a * rd[i / d])
        .collect();
    Tensor::f32(x.shape.clone(), out)
}

fn silu(x: f32) -> f32 {
    x * (1.0 / (1.0 + (-x).exp()))
}

/// Row-wise softmax over the last axis with max subtraction (the
/// "parallel" variant); `naive` skips nothing numerically here — the naive
/// shader differs in memory traffic, not math — so both share this body.
fn softmax_rows(x: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("softmax: 0-d".into()))?;
    let xd = f32s(x, "softmax")?;
    let mut out = vec![0f32; xd.len()];
    for r in 0..xd.len() / d {
        let row = &xd[r * d..(r + 1) * d];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[r * d + j] = e;
            sum += e;
        }
        for j in 0..d {
            out[r * d + j] /= sum;
        }
    }
    Tensor::f32(x.shape.clone(), out)
}

/// Fused RMSNorm, written as the exact composition of the 6-dispatch
/// decomposition (pow, mean, +eps, rsqrt, mul_x, mul_w) so fused and
/// unfused flows agree bit-for-bit. Every component is row-wise, so the
/// batched `[W, H]` kernel is bit-identical to looping the single-row one.
fn rmsnorm(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let x2 = unary(x, |a| a * a)?;
    let m = rms_mean(&x2)?;
    let me = unary(&m, |a| a + RMS_EPS)?;
    let r = unary(&me, |a| 1.0 / a.sqrt())?;
    let xn = mul_row_scalar(x, &r)?;
    mul_lastdim(&xn, w)
}

fn rms_mean(x2: &Tensor) -> Result<Tensor> {
    let d = *x2.shape.last().ok_or_else(|| Error::Shape("rms_mean: 0-d".into()))?;
    let xd = f32s(x2, "rms_mean")?;
    let rows = xd.len() / d;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let sum: f32 = xd[r * d..(r + 1) * d].iter().sum();
        out.push(sum / d as f32);
    }
    let mut shape = x2.shape.clone();
    *shape.last_mut().unwrap() = 1;
    Tensor::f32(shape, out)
}

/// rotate_half: concat(-x2, x1) over the last axis of a 2-D tensor.
fn rotate_half(x: &Tensor) -> Result<Tensor> {
    let (h, d) = (x.shape[0], x.shape[1]);
    let half = d / 2;
    let xd = f32s(x, "rotate_half")?;
    let mut out = vec![0f32; h * d];
    for i in 0..h {
        for j in 0..half {
            out[i * d + j] = -xd[i * d + half + j];
            out[i * d + half + j] = xd[i * d + j];
        }
    }
    Tensor::f32(vec![h, d], out)
}

/// Fused rotary — exact composition of the unfused neg/concat/mul/mul/add
/// chain: a = x*cos, b = rotate_half(x)*sin, out = a + b.
fn rotary(x: &Tensor, cos: &Tensor, sin: &Tensor) -> Result<Tensor> {
    let rot = rotate_half(x)?;
    let a = mul_lastdim(x, cos)?;
    let b = mul_lastdim(&rot, sin)?;
    binary_same(&a, &b, |p, q| p + q)
}

fn rope_cos_sin(pos: &Tensor, inv_freq: &Tensor) -> Result<Vec<Tensor>> {
    let p = f32s(pos, "rope")?[0];
    let inv = f32s(inv_freq, "rope")?;
    let half = inv.len();
    let mut cos = vec![0f32; 2 * half];
    let mut sin = vec![0f32; 2 * half];
    for (j, &iv) in inv.iter().enumerate() {
        let f = p * iv;
        let (c, s) = (f.cos(), f.sin());
        cos[j] = c;
        cos[half + j] = c;
        sin[j] = s;
        sin[half + j] = s;
    }
    Ok(vec![
        Tensor::f32(vec![2 * half], cos)?,
        Tensor::f32(vec![2 * half], sin)?,
    ])
}

/// Write `new_row` ([KVH, D]) at `cache[pos]` ([S, KVH, D]).
fn cache_update(cache: &Tensor, new_row: &Tensor, pos: usize) -> Result<Tensor> {
    if cache.shape.len() != 3 || new_row.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update {:?} <- {:?}",
            cache.shape, new_row.shape
        )));
    }
    let (s, kvh, d) = (cache.shape[0], cache.shape[1], cache.shape[2]);
    if pos >= s || new_row.shape != [kvh, d] {
        return Err(Error::Shape(format!(
            "cache_update: pos {pos} / row {:?} vs cache {:?}",
            new_row.shape, cache.shape
        )));
    }
    let mut out = f32s(cache, "cache_update")?.to_vec();
    let row = f32s(new_row, "cache_update")?;
    out[pos * kvh * d..(pos + 1) * kvh * d].copy_from_slice(row);
    Tensor::f32(vec![s, kvh, d], out)
}

/// Grouped-query attention over a fixed-capacity masked KV cache
/// (`ref.sdpa_gqa`): positions `0..pos` are valid.
fn sdpa_gqa(q: &Tensor, k: &Tensor, v: &Tensor, pos: usize) -> Result<Tensor> {
    if q.shape.len() != 2 || k.shape.len() != 3 || v.shape != k.shape {
        return Err(Error::Shape(format!(
            "sdpa q {:?} k {:?} v {:?}",
            q.shape, k.shape, v.shape
        )));
    }
    let (heads, dim) = (q.shape[0], q.shape[1]);
    let (seq, kvh, kd) = (k.shape[0], k.shape[1], k.shape[2]);
    if kd != dim || kvh == 0 || heads % kvh != 0 {
        return Err(Error::Shape(format!(
            "sdpa head layout: {heads} q heads over {kvh} kv heads, dim {dim}/{kd}"
        )));
    }
    let group = heads / kvh;
    let scale = 1.0 / (dim as f32).sqrt();
    let valid = pos.min(seq).max(1);
    let (qd, kdat, vdat) = (f32s(q, "sdpa")?, f32s(k, "sdpa")?, f32s(v, "sdpa")?);
    let mut out = vec![0f32; heads * dim];
    let mut scores = vec![0f32; valid];
    for h in 0..heads {
        let kv_h = h / group;
        let qrow = &qd[h * dim..(h + 1) * dim];
        for (s, score) in scores.iter_mut().enumerate() {
            let krow = &kdat[(s * kvh + kv_h) * dim..(s * kvh + kv_h + 1) * dim];
            let mut dot = 0f32;
            for (a, b) in qrow.iter().zip(krow) {
                dot += a * b;
            }
            *score = dot * scale;
        }
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        let mut probs = vec![0f32; valid];
        for (s, &sc) in scores.iter().enumerate() {
            let e = (sc - m).exp();
            probs[s] = e;
            sum += e;
        }
        let orow = &mut out[h * dim..(h + 1) * dim];
        for (s, &p) in probs.iter().enumerate() {
            let w = p / sum;
            let vrow = &vdat[(s * kvh + kv_h) * dim..(s * kvh + kv_h + 1) * dim];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
    }
    Tensor::f32(vec![heads, dim], out)
}

/// Fused MLP stage — exact composition of matmul/matmul/silu/mul.
fn gate_up_silu(x: &Tensor, wg: &Tensor, wu: &Tensor) -> Result<Tensor> {
    let g = matmul(x, wg)?;
    let u = matmul(x, wu)?;
    let s = unary(&g, silu)?;
    binary_same(&s, &u, |a, b| a * b)
}

fn argmax_rows(x: &Tensor) -> Result<Tensor> {
    let d = *x.shape.last().ok_or_else(|| Error::Shape("argmax: 0-d".into()))?;
    let xd = f32s(x, "argmax")?;
    let rows = xd.len() / d;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &xd[r * d..(r + 1) * d];
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bestv {
                bestv = v;
                best = j;
            }
        }
        out.push(best as i32);
    }
    Tensor::i32(vec![rows], out)
}

fn mega_mlp(
    x: &Tensor,
    rms_w: &Tensor,
    wg: &Tensor,
    wu: &Tensor,
    wd: &Tensor,
) -> Result<Tensor> {
    let h = rmsnorm(x, rms_w)?;
    let act = gate_up_silu(&h, wg, wu)?;
    let down = matmul(&act, wd)?;
    binary_same(x, &down, |a, b| a + b)
}

/// Concatenate two 2-D tensors along the last axis.
fn concat_last(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[0] != b.shape[0] {
        return Err(Error::Shape(format!(
            "concat {:?} ++ {:?}",
            a.shape, b.shape
        )));
    }
    let (rows, ca, cb) = (a.shape[0], a.shape[1], b.shape[1]);
    let (ad, bd) = (f32s(a, "concat")?, f32s(b, "concat")?);
    let mut out = Vec::with_capacity(rows * (ca + cb));
    for r in 0..rows {
        out.extend_from_slice(&ad[r * ca..(r + 1) * ca]);
        out.extend_from_slice(&bd[r * cb..(r + 1) * cb]);
    }
    Tensor::f32(vec![rows, ca + cb], out)
}

// ------------------------------------------------- batched (slot) kernels --
//
// The `*_b{W}_*` kernels execute one dispatch over W session slots. Each
// is written as a per-slot loop over the corresponding single-session
// implementation, so batched decode is BIT-IDENTICAL to interleaving the
// single-session kernels — the property the batched serving round's
// equivalence tests pin. Cache ops gather/scatter across W separately
// bound per-slot cache buffers through the `slot_idx` uniform, with
// `slot_mask = 0` rows skipped entirely (partial rounds).

/// Slice row `b` of a `[W, D]` tensor into `shape` (numel D).
fn slot_row(x: &Tensor, b: usize, shape: Vec<usize>) -> Result<Tensor> {
    let d: usize = shape.iter().product();
    let xd = f32s(x, "slot_row")?;
    if (b + 1) * d > xd.len() {
        return Err(Error::Shape(format!(
            "slot_row: row {b} of {:?} as {shape:?}",
            x.shape
        )));
    }
    Tensor::f32(shape, xd[b * d..(b + 1) * d].to_vec())
}

fn i32_slots<'a>(t: &'a Tensor, w: usize, what: &str) -> Result<&'a [i32]> {
    let v = t
        .as_i32()
        .map_err(|_| Error::Runtime(format!("{what}: expected i32 per-slot uniform")))?;
    if v.len() != w {
        return Err(Error::Shape(format!("{what}: {} uniforms for {w} slots", v.len())));
    }
    Ok(v)
}

/// Batched K+V projection: one matmul against the concatenated weight,
/// rows split per slot into the K and V outputs (the `[W, 2KV]` split is
/// strided, so the kernel emits two outputs instead of a host alias).
fn kv_fused_batched(x: &Tensor, wkv: &Tensor) -> Result<Vec<Tensor>> {
    let m = matmul(x, wkv)?;
    let (rows, two_kv) = (m.shape[0], m.shape[1]);
    if two_kv % 2 != 0 {
        return Err(Error::Shape(format!("kv_fused_b: odd columns {two_kv}")));
    }
    let kvc = two_kv / 2;
    let md = f32s(&m, "kv_fused_b")?;
    let mut k = Vec::with_capacity(rows * kvc);
    let mut v = Vec::with_capacity(rows * kvc);
    for r in 0..rows {
        k.extend_from_slice(&md[r * two_kv..r * two_kv + kvc]);
        v.extend_from_slice(&md[r * two_kv + kvc..(r + 1) * two_kv]);
    }
    Ok(vec![
        Tensor::f32(vec![rows, kvc], k)?,
        Tensor::f32(vec![rows, kvc], v)?,
    ])
}

/// Batched rope table: each slot's cos/sin row at its own position.
fn rope_cos_sin_batched(pos: &Tensor, inv_freq: &Tensor) -> Result<Vec<Tensor>> {
    let ps = f32s(pos, "rope_b")?;
    let w = ps.len();
    let d = 2 * inv_freq.numel();
    let mut cos = Vec::with_capacity(w * d);
    let mut sin = Vec::with_capacity(w * d);
    for &p in ps {
        let cs = rope_cos_sin(&Tensor::scalar_f32(p), inv_freq)?;
        cos.extend_from_slice(f32s(&cs[0], "rope_b")?);
        sin.extend_from_slice(f32s(&cs[1], "rope_b")?);
    }
    Ok(vec![
        Tensor::f32(vec![w, d], cos)?,
        Tensor::f32(vec![w, d], sin)?,
    ])
}

/// Batched rotary: `x` is `[W, heads*d]`, cos/sin are `[W, d]` (per-slot
/// rows); each slot's heads rotate with that slot's table.
fn rotary_batched(x: &Tensor, cos: &Tensor, sin: &Tensor) -> Result<Tensor> {
    if x.shape.len() != 2 || cos.shape.len() != 2 || sin.shape != cos.shape {
        return Err(Error::Shape(format!(
            "rotary_b: x {:?} cos {:?} sin {:?}",
            x.shape, cos.shape, sin.shape
        )));
    }
    let (w, d) = (cos.shape[0], cos.shape[1]);
    if x.shape[0] != w || d == 0 || x.shape[1] % d != 0 {
        return Err(Error::Shape(format!(
            "rotary_b: x {:?} vs table {:?}",
            x.shape, cos.shape
        )));
    }
    let heads = x.shape[1] / d;
    let mut out = Vec::with_capacity(w * heads * d);
    for b in 0..w {
        let xb = slot_row(x, b, vec![heads, d])?;
        let cb = slot_row(cos, b, vec![d])?;
        let sb = slot_row(sin, b, vec![d])?;
        out.extend_from_slice(f32s(&rotary(&xb, &cb, &sb)?, "rotary_b")?);
    }
    Tensor::f32(vec![w, heads * d], out)
}

/// Batched in-place cache append: inputs are the W per-slot cache states,
/// then `rows [W, KVH*D]`, `pos [W]`, `slot_mask [W]`, `slot_idx [W]`.
/// Output j is slot j's (possibly unchanged) state; batch row b scatters
/// its row into cache set `slot_idx[b]` at `pos[b]` unless masked.
fn cache_update_batched(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() < 5 {
        return Err(Error::Runtime(format!(
            "cache_update_b: needs >= 5 inputs, got {}",
            inputs.len()
        )));
    }
    let w = inputs.len() - 4;
    let caches = &inputs[..w];
    let rows = &inputs[w];
    let pos = i32_slots(&inputs[w + 1], w, "cache_update_b pos")?;
    let mask = i32_slots(&inputs[w + 2], w, "cache_update_b mask")?;
    let slots = i32_slots(&inputs[w + 3], w, "cache_update_b slot_idx")?;
    if caches[0].shape.len() != 3 {
        return Err(Error::Shape(format!(
            "cache_update_b: cache shape {:?}",
            caches[0].shape
        )));
    }
    let (kvh, d) = (caches[0].shape[1], caches[0].shape[2]);
    if rows.shape != [w, kvh * d] {
        return Err(Error::Shape(format!(
            "cache_update_b: rows {:?} for {w} slots of [{kvh}, {d}]",
            rows.shape
        )));
    }
    let mut outs: Vec<Tensor> = caches.to_vec();
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = slots[b];
        if t < 0 || t as usize >= w {
            return Err(Error::Shape(format!(
                "cache_update_b: slot_idx[{b}] = {t} out of {w} slots"
            )));
        }
        let row = slot_row(rows, b, vec![kvh, d])?;
        outs[t as usize] = cache_update(&outs[t as usize], &row, pos[b].max(0) as usize)?;
    }
    Ok(outs)
}

/// Batched grouped-query attention: inputs are `q [W, NH*D]`, the W
/// per-slot K caches, the W per-slot V caches, then `pos_ip1 [W]`,
/// `slot_mask [W]`, `slot_idx [W]`. Batch row b attends over cache set
/// `slot_idx[b]`; masked rows produce zeros (their logits are never read).
fn sdpa_batched(inputs: &[Tensor]) -> Result<Tensor> {
    if inputs.len() < 7 || (inputs.len() - 4) % 2 != 0 {
        return Err(Error::Runtime(format!(
            "sdpa_b: bad input count {}",
            inputs.len()
        )));
    }
    let w = (inputs.len() - 4) / 2;
    let q = &inputs[0];
    let ks = &inputs[1..1 + w];
    let vs = &inputs[1 + w..1 + 2 * w];
    let pos = i32_slots(&inputs[1 + 2 * w], w, "sdpa_b pos")?;
    let mask = i32_slots(&inputs[2 + 2 * w], w, "sdpa_b mask")?;
    let slots = i32_slots(&inputs[3 + 2 * w], w, "sdpa_b slot_idx")?;
    if q.shape.len() != 2 || q.shape[0] != w || ks[0].shape.len() != 3 {
        return Err(Error::Shape(format!(
            "sdpa_b: q {:?} for {w} slots, k {:?}",
            q.shape, ks[0].shape
        )));
    }
    let qcols = q.shape[1];
    let d = ks[0].shape[2];
    if d == 0 || qcols % d != 0 {
        return Err(Error::Shape(format!("sdpa_b: q cols {qcols} vs head dim {d}")));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; w * qcols];
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = slots[b];
        if t < 0 || t as usize >= w {
            return Err(Error::Shape(format!(
                "sdpa_b: slot_idx[{b}] = {t} out of {w} slots"
            )));
        }
        let qb = slot_row(q, b, vec![heads, d])?;
        let o = sdpa_gqa(&qb, &ks[t as usize], &vs[t as usize], pos[b].max(0) as usize)?;
        out[b * qcols..(b + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_b")?);
    }
    Tensor::f32(vec![w, qcols], out)
}

// ------------------------------------------------ chunked-prefill kernels --
//
// The `*_c{C}_*` kernels execute one dispatch over C consecutive prompt
// positions of ONE session. The cache scatter and causal attention are
// written as per-row loops over the single-token kernels, so a chunked
// prefill is BIT-IDENTICAL to feeding the same tokens one decode step at a
// time — the equivalence `rust/tests/prefill.rs` pins. Rows at or beyond
// `valid_len` (the ragged tail of a short final chunk) are skipped by the
// scatter and zeroed by the attention; their lanes never reach the cache
// or the selected logits row. Row-wise chunk kernels (matmul_c*,
// rmsnorm_c*, rms_*_c*, silu_c*, mul_c*, add_c*, gate_up_silu_c*,
// rotary_c*, rope_cos_sin_c*, kv_fused_c*) reuse the shared row-safe
// implementations.

/// Chunked in-place cache scatter: writes rows `0..valid_len` of
/// `rows [C, KVH*D]` at cache positions `pos_base..` — exactly a loop of
/// the single-token `cache_update`.
fn cache_update_prefill(inputs: &[Tensor]) -> Result<Tensor> {
    let cache = &inputs[0];
    let rows = &inputs[1];
    let base = scalar_pos(&inputs[2])?;
    let valid = scalar_pos(&inputs[3])?;
    if cache.shape.len() != 3 || rows.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update_c: cache {:?} rows {:?}",
            cache.shape, rows.shape
        )));
    }
    let (kvh, d) = (cache.shape[1], cache.shape[2]);
    if rows.shape[1] != kvh * d || valid > rows.shape[0] {
        return Err(Error::Shape(format!(
            "cache_update_c: {valid} valid rows of {:?} into [{kvh}, {d}]",
            rows.shape
        )));
    }
    let mut out = cache.clone();
    for i in 0..valid {
        let row = slot_row(rows, i, vec![kvh, d])?;
        out = cache_update(&out, &row, base + i)?;
    }
    Ok(out)
}

/// Causal multi-token prefill attention: chunk row `i` attends cache
/// positions `0..pos_base+i+1` (the scatter has already written this
/// chunk's rows), bit-identical per row to the single-token sdpa at that
/// position. Rows `>= valid_len` produce zeros (never read).
fn sdpa_prefill(inputs: &[Tensor]) -> Result<Tensor> {
    let (q, k, v) = (&inputs[0], &inputs[1], &inputs[2]);
    let base = scalar_pos(&inputs[3])?;
    let valid = scalar_pos(&inputs[4])?;
    if q.shape.len() != 2 || k.shape.len() != 3 || v.shape != k.shape {
        return Err(Error::Shape(format!(
            "sdpa_prefill: q {:?} k {:?} v {:?}",
            q.shape, k.shape, v.shape
        )));
    }
    let (c, qcols) = (q.shape[0], q.shape[1]);
    let d = k.shape[2];
    if d == 0 || qcols % d != 0 || valid > c {
        return Err(Error::Shape(format!(
            "sdpa_prefill: q {:?} vs head dim {d}, valid {valid}",
            q.shape
        )));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; c * qcols];
    for i in 0..valid {
        let qi = slot_row(q, i, vec![heads, d])?;
        let o = sdpa_gqa(&qi, k, v, base + i + 1)?;
        out[i * qcols..(i + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_prefill")?);
    }
    Tensor::f32(vec![c, qcols], out)
}

/// Select row `valid_len - 1` of `x [C, H]` as `[1, H]` (the last prompt
/// position's hidden state, fed to the final norm + lm head).
fn chunk_last_row(x: &Tensor, valid_len: &Tensor) -> Result<Tensor> {
    let valid = scalar_pos(valid_len)?;
    if x.shape.len() != 2 || valid == 0 || valid > x.shape[0] {
        return Err(Error::Shape(format!(
            "chunk_last_row: row {valid}-1 of {:?}",
            x.shape
        )));
    }
    slot_row(x, valid - 1, vec![1, x.shape[1]])
}

/// Keep rows `0..valid_len` of `x [C, H]`, zeroing the ragged tail — the
/// multi-row (speculative verify) twin of [`chunk_last_row`]: every kept
/// row reaches the final norm + lm head, so one replay scores `valid_len`
/// drafted positions. Kept rows are bit-copies, so row `v-1` of the
/// output at any prefix length `v <= valid_len` equals what
/// `chunk_last_row` would select with `valid_len = v`.
fn chunk_rows(x: &Tensor, valid_len: &Tensor) -> Result<Tensor> {
    let valid = scalar_pos(valid_len)?;
    if x.shape.len() != 2 || valid == 0 || valid > x.shape[0] {
        return Err(Error::Shape(format!(
            "chunk_rows: rows 0..{valid} of {:?}",
            x.shape
        )));
    }
    let (c, h) = (x.shape[0], x.shape[1]);
    let src = f32s(x, "chunk_rows")?;
    let mut out = vec![0f32; c * h];
    out[..valid * h].copy_from_slice(&src[..valid * h]);
    Tensor::f32(vec![c, h], out)
}

// ------------------------------------------------ unified (seq x batch) --
//
// The `*_b{W}c{C}*` kernels execute one dispatch over W session slots x C
// sequence positions: slot j owns rows j*C..(j+1)*C and carries
// valid_len[j] live tokens at cache rows pos_base[j].. — a decode slot is
// a valid_len = 1 chunk, a padding slot valid_len = 0. The cache scatter
// and causal attention are written as per-slot-per-row loops over the
// single-token kernels, so a unified round is BIT-IDENTICAL to running
// each slot's prefill chunk or decode step separately — the property the
// differential schedule suite (`rust/tests/schedules.rs`) pins. Row-wise
// unified kernels (matmul_b*c*, rmsnorm_b*c*, rms_*_b*c*, silu, mul, add,
// gate_up_silu, kv_fused, rope_cos_sin, rotary) reuse the shared row-safe
// implementations via the batched branches.

/// True when `name`'s first `_`-delimited segment after `prefix` embeds a
/// 'c' — i.e. the kernel is the unified `*_b{W}c{C}_*` form rather than the
/// batched `*_b{W}_*` form ("cache_update_b4c16_tiny" -> "4c16" -> true;
/// "cache_update_b4_tiny" -> "4" -> false).
fn unified_width_segment(name: &str, prefix: &str) -> bool {
    name.strip_prefix(prefix)
        .and_then(|rest| rest.split('_').next())
        .map(|seg| seg.contains('c'))
        .unwrap_or(false)
}

/// Unified in-place cache scatter: inputs are the W per-slot cache states,
/// then `rows [W*C, KVH*D]`, `pos_base [W]`, `valid_len [W]`,
/// `slot_mask [W]`, `slot_idx [W]`. Output j is slot j's (possibly
/// unchanged) state; slot b scatters its rows `b*C..b*C+valid_len[b]` into
/// cache set `slot_idx[b]` at positions `pos_base[b]..` unless masked.
fn cache_update_unified(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() < 6 {
        return Err(Error::Runtime(format!(
            "cache_update_bc: needs >= 6 inputs, got {}",
            inputs.len()
        )));
    }
    let w = inputs.len() - 5;
    let caches = &inputs[..w];
    let rows = &inputs[w];
    let base = i32_slots(&inputs[w + 1], w, "cache_update_bc pos_base")?;
    let valid = i32_slots(&inputs[w + 2], w, "cache_update_bc valid_len")?;
    let mask = i32_slots(&inputs[w + 3], w, "cache_update_bc mask")?;
    let slots = i32_slots(&inputs[w + 4], w, "cache_update_bc slot_idx")?;
    if caches[0].shape.len() != 3 || rows.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update_bc: cache {:?} rows {:?}",
            caches[0].shape, rows.shape
        )));
    }
    let (kvh, d) = (caches[0].shape[1], caches[0].shape[2]);
    if rows.shape[1] != kvh * d || rows.shape[0] % w != 0 {
        return Err(Error::Shape(format!(
            "cache_update_bc: rows {:?} for {w} slots of [{kvh}, {d}]",
            rows.shape
        )));
    }
    let c = rows.shape[0] / w;
    let mut outs: Vec<Tensor> = caches.to_vec();
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = slots[b];
        if t < 0 || t as usize >= w {
            return Err(Error::Shape(format!(
                "cache_update_bc: slot_idx[{b}] = {t} out of {w} slots"
            )));
        }
        let vl = valid[b].max(0) as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "cache_update_bc: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        let b0 = base[b].max(0) as usize;
        for i in 0..vl {
            let row = slot_row(rows, b * c + i, vec![kvh, d])?;
            outs[t as usize] = cache_update(&outs[t as usize], &row, b0 + i)?;
        }
    }
    Ok(outs)
}

/// Unified causal grouped-query attention: inputs are `q [W*C, NH*D]`, the
/// W per-slot K caches, the W per-slot V caches, then `pos_base [W]`,
/// `valid_len [W]`, `slot_mask [W]`, `slot_idx [W]`. Slot b row i attends
/// cache set `slot_idx[b]` positions `0..pos_base[b]+i+1` (the scatter has
/// already written this round's rows); masked slots and ragged-tail rows
/// produce zeros (their logits are never read).
fn sdpa_unified(inputs: &[Tensor]) -> Result<Tensor> {
    if inputs.len() < 7 || (inputs.len() - 5) % 2 != 0 {
        return Err(Error::Runtime(format!(
            "sdpa_bc: bad input count {}",
            inputs.len()
        )));
    }
    let w = (inputs.len() - 5) / 2;
    let q = &inputs[0];
    let ks = &inputs[1..1 + w];
    let vs = &inputs[1 + w..1 + 2 * w];
    let base = i32_slots(&inputs[1 + 2 * w], w, "sdpa_bc pos_base")?;
    let valid = i32_slots(&inputs[2 + 2 * w], w, "sdpa_bc valid_len")?;
    let mask = i32_slots(&inputs[3 + 2 * w], w, "sdpa_bc mask")?;
    let slots = i32_slots(&inputs[4 + 2 * w], w, "sdpa_bc slot_idx")?;
    if q.shape.len() != 2 || q.shape[0] % w != 0 || ks[0].shape.len() != 3 {
        return Err(Error::Shape(format!(
            "sdpa_bc: q {:?} for {w} slots, k {:?}",
            q.shape, ks[0].shape
        )));
    }
    let (c, qcols) = (q.shape[0] / w, q.shape[1]);
    let d = ks[0].shape[2];
    if d == 0 || qcols % d != 0 {
        return Err(Error::Shape(format!("sdpa_bc: q cols {qcols} vs head dim {d}")));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; w * c * qcols];
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = slots[b];
        if t < 0 || t as usize >= w {
            return Err(Error::Shape(format!(
                "sdpa_bc: slot_idx[{b}] = {t} out of {w} slots"
            )));
        }
        let vl = valid[b].max(0) as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "sdpa_bc: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        let b0 = base[b].max(0) as usize;
        for i in 0..vl {
            let r = b * c + i;
            let qi = slot_row(q, r, vec![heads, d])?;
            let o = sdpa_gqa(&qi, &ks[t as usize], &vs[t as usize], b0 + i + 1)?;
            out[r * qcols..(r + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_bc")?);
        }
    }
    Tensor::f32(vec![w * c, qcols], out)
}

/// Select each slot's row `valid_len[j] - 1` of `x [W*C, H]` as `[W, H]`
/// (the last live position's hidden state per slot, fed to the batched
/// final norm + lm head). Masked and empty (`valid_len = 0`) slots yield
/// zero rows — their logits-ring lanes are never read.
fn slot_last_row(x: &Tensor, valid_len: &Tensor, slot_mask: &Tensor) -> Result<Tensor> {
    let w = valid_len.numel();
    if x.shape.len() != 2 || w == 0 || x.shape[0] % w != 0 {
        return Err(Error::Shape(format!(
            "slot_last_row: x {:?} for {w} slots",
            x.shape
        )));
    }
    let (c, h) = (x.shape[0] / w, x.shape[1]);
    let valid = i32_slots(valid_len, w, "slot_last_row valid_len")?;
    let mask = i32_slots(slot_mask, w, "slot_last_row mask")?;
    let mut out = vec![0f32; w * h];
    for b in 0..w {
        if mask[b] == 0 || valid[b] <= 0 {
            continue;
        }
        let vl = valid[b] as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "slot_last_row: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        let row = slot_row(x, b * c + vl - 1, vec![h])?;
        out[b * h..(b + 1) * h].copy_from_slice(f32s(&row, "slot_last_row")?);
    }
    Tensor::f32(vec![w, h], out)
}

/// Keep each slot's rows `j*C..j*C+valid_len[j]` of `x [W*C, H]`, zeroing
/// ragged tails and masked/empty slots — the multi-row (speculative
/// verify) twin of [`slot_last_row`]: every kept row reaches the unified
/// final norm + lm head, so slot `j`'s drafted positions land at logits
/// rows `j*C..j*C+valid_len[j]`.
fn slot_rows(x: &Tensor, valid_len: &Tensor, slot_mask: &Tensor) -> Result<Tensor> {
    let w = valid_len.numel();
    if x.shape.len() != 2 || w == 0 || x.shape[0] % w != 0 {
        return Err(Error::Shape(format!("slot_rows: x {:?} for {w} slots", x.shape)));
    }
    let (c, h) = (x.shape[0] / w, x.shape[1]);
    let valid = i32_slots(valid_len, w, "slot_rows valid_len")?;
    let mask = i32_slots(slot_mask, w, "slot_rows mask")?;
    let src = f32s(x, "slot_rows")?;
    let mut out = vec![0f32; w * c * h];
    for b in 0..w {
        if mask[b] == 0 || valid[b] <= 0 {
            continue;
        }
        let vl = valid[b] as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "slot_rows: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        let lo = b * c * h;
        out[lo..lo + vl * h].copy_from_slice(&src[lo..lo + vl * h]);
    }
    Tensor::f32(vec![w * c, h], out)
}

// ------------------------------------------------------- paged KV kernels --
//
// The `*_paged_*` kernels run against ONE shared pool plane per (layer,
// K/V) instead of per-session contiguous caches: logical cache position
// `p` of a slot resolves through its block table as
// `table[p / kv_block] * kv_block + p % kv_block`. Every interpreter
// GATHERS the logical prefix into a contiguous tensor and reuses the
// EXISTING single/chunked sdpa row loops (identical f32 loop order), and
// SCATTERS written rows at their physical offsets — so paged serving is
// BIT-IDENTICAL to the contiguous kernels, the property the paged arm of
// `rust/tests/schedules.rs` pins. Masked slots and `valid_len = 0` slots
// are skipped entirely (their table rows may be unallocated); a resolved
// `-1` table entry inside a live range is a hard error, never a read.

/// Decode the `(block_table, kv_block)` uniform pair: returns the raw
/// table entries and the block size.
fn paged_params<'a>(
    table: &'a Tensor,
    kvb: &Tensor,
    what: &str,
) -> Result<(&'a [i32], usize)> {
    let t = table
        .as_i32()
        .map_err(|_| Error::Runtime(format!("{what}: expected i32 block table")))?;
    let b = kvb
        .as_i32()
        .map_err(|_| Error::Runtime(format!("{what}: expected i32 kv_block")))?;
    if b.len() != 1 || b[0] <= 0 {
        return Err(Error::Shape(format!("{what}: bad kv_block uniform {b:?}")));
    }
    Ok((t, b[0] as usize))
}

/// Resolve logical cache position `p` to a physical pool row through
/// `table` (block granularity `blk`), bounds-checked against `pool_rows`.
fn paged_row(table: &[i32], blk: usize, p: usize, pool_rows: usize, what: &str) -> Result<usize> {
    let g = *table.get(p / blk).ok_or_else(|| {
        Error::Shape(format!("{what}: position {p} past block table ({} entries)", table.len()))
    })?;
    if g < 0 {
        return Err(Error::Validation(format!(
            "{what}: position {p} resolves to unallocated block {}",
            p / blk
        )));
    }
    let phys = g as usize * blk + p % blk;
    if phys >= pool_rows {
        return Err(Error::Shape(format!(
            "{what}: physical row {phys} past pool ({pool_rows} rows)"
        )));
    }
    Ok(phys)
}

/// Gather logical rows `0..n` of a pool plane into a contiguous
/// `[n, kvh, d]` tensor — the exact prefix a contiguous cache would hold.
fn gather_paged(
    pool: &Tensor,
    table: &[i32],
    blk: usize,
    n: usize,
    what: &str,
) -> Result<Tensor> {
    if pool.shape.len() != 3 {
        return Err(Error::Shape(format!("{what}: pool plane {:?}", pool.shape)));
    }
    let (pr, kvh, d) = (pool.shape[0], pool.shape[1], pool.shape[2]);
    let src = f32s(pool, what)?;
    let stride = kvh * d;
    let mut out = vec![0f32; n * stride];
    for p in 0..n {
        let phys = paged_row(table, blk, p, pr, what)?;
        out[p * stride..(p + 1) * stride]
            .copy_from_slice(&src[phys * stride..(phys + 1) * stride]);
    }
    Tensor::f32(vec![n, kvh, d], out)
}

/// Single-token paged cache append: `[pool, row, pos, table, kv_block]`;
/// the row lands at the physical row `pos` resolves to.
fn cache_update_paged(inputs: &[Tensor]) -> Result<Tensor> {
    let (pool, xrow) = (&inputs[0], &inputs[1]);
    let pos = scalar_pos(&inputs[2])?;
    let (table, blk) = paged_params(&inputs[3], &inputs[4], "cache_update_paged")?;
    if pool.shape.len() != 3 {
        return Err(Error::Shape(format!(
            "cache_update_paged: pool {:?}",
            pool.shape
        )));
    }
    let phys = paged_row(table, blk, pos, pool.shape[0], "cache_update_paged")?;
    cache_update(pool, xrow, phys)
}

/// Single-token paged attention: `[q, k_pool, v_pool, pos_ip1, table,
/// kv_block]`; gathers the logical prefix and reuses the contiguous GQA.
fn sdpa_paged(inputs: &[Tensor]) -> Result<Tensor> {
    let (q, kp, vp) = (&inputs[0], &inputs[1], &inputs[2]);
    let pos = scalar_pos(&inputs[3])?;
    let (table, blk) = paged_params(&inputs[4], &inputs[5], "sdpa_paged")?;
    let n = pos.max(1);
    let k = gather_paged(kp, table, blk, n, "sdpa_paged")?;
    let v = gather_paged(vp, table, blk, n, "sdpa_paged")?;
    sdpa_gqa(q, &k, &v, pos)
}

/// Batched paged cache append: `[pool, rows [W, KVH*D], pos [W],
/// slot_mask [W], table [W*stride], kv_block]`. Slot b scatters its row
/// through its table row unless masked.
fn cache_update_paged_batched(inputs: &[Tensor]) -> Result<Tensor> {
    let (pool, rows) = (&inputs[0], &inputs[1]);
    if pool.shape.len() != 3 || rows.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update_paged_b: pool {:?} rows {:?}",
            pool.shape, rows.shape
        )));
    }
    let w = rows.shape[0];
    let pos = i32_slots(&inputs[2], w, "cache_update_paged_b pos")?;
    let mask = i32_slots(&inputs[3], w, "cache_update_paged_b mask")?;
    let (table, blk) = paged_params(&inputs[4], &inputs[5], "cache_update_paged_b")?;
    if w == 0 || table.len() % w != 0 {
        return Err(Error::Shape(format!(
            "cache_update_paged_b: {} table entries over {w} slots",
            table.len()
        )));
    }
    let tstride = table.len() / w;
    let (pr, kvh, d) = (pool.shape[0], pool.shape[1], pool.shape[2]);
    if rows.shape[1] != kvh * d {
        return Err(Error::Shape(format!(
            "cache_update_paged_b: rows {:?} for [{kvh}, {d}]",
            rows.shape
        )));
    }
    let stride = kvh * d;
    let mut out = f32s(pool, "cache_update_paged_b")?.to_vec();
    let src = f32s(rows, "cache_update_paged_b")?;
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = &table[b * tstride..(b + 1) * tstride];
        let phys = paged_row(t, blk, pos[b].max(0) as usize, pr, "cache_update_paged_b")?;
        out[phys * stride..(phys + 1) * stride]
            .copy_from_slice(&src[b * stride..(b + 1) * stride]);
    }
    Tensor::f32(pool.shape.clone(), out)
}

/// Batched paged attention: `[q [W, NH*D], k_pool, v_pool, pos_ip1 [W],
/// slot_mask [W], table [W*stride], kv_block]`. Slot b gathers its logical
/// prefix through its table row; masked rows produce zeros.
fn sdpa_paged_batched(inputs: &[Tensor]) -> Result<Tensor> {
    let (q, kp, vp) = (&inputs[0], &inputs[1], &inputs[2]);
    if q.shape.len() != 2 || kp.shape.len() != 3 {
        return Err(Error::Shape(format!(
            "sdpa_paged_b: q {:?} k {:?}",
            q.shape, kp.shape
        )));
    }
    let w = q.shape[0];
    let pos = i32_slots(&inputs[3], w, "sdpa_paged_b pos")?;
    let mask = i32_slots(&inputs[4], w, "sdpa_paged_b mask")?;
    let (table, blk) = paged_params(&inputs[5], &inputs[6], "sdpa_paged_b")?;
    if w == 0 || table.len() % w != 0 {
        return Err(Error::Shape(format!(
            "sdpa_paged_b: {} table entries over {w} slots",
            table.len()
        )));
    }
    let tstride = table.len() / w;
    let qcols = q.shape[1];
    let d = kp.shape[2];
    if d == 0 || qcols % d != 0 {
        return Err(Error::Shape(format!(
            "sdpa_paged_b: q cols {qcols} vs head dim {d}"
        )));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; w * qcols];
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let t = &table[b * tstride..(b + 1) * tstride];
        let p = pos[b].max(0) as usize;
        let n = p.max(1);
        let k = gather_paged(kp, t, blk, n, "sdpa_paged_b")?;
        let v = gather_paged(vp, t, blk, n, "sdpa_paged_b")?;
        let qb = slot_row(q, b, vec![heads, d])?;
        let o = sdpa_gqa(&qb, &k, &v, p)?;
        out[b * qcols..(b + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_paged_b")?);
    }
    Tensor::f32(vec![w, qcols], out)
}

/// Chunked paged cache scatter: `[pool, rows [C, KVH*D], pos_base,
/// valid_len, table, kv_block]`; rows `0..valid_len` land at the physical
/// rows `pos_base..` resolve to.
fn cache_update_paged_prefill(inputs: &[Tensor]) -> Result<Tensor> {
    let (pool, rows) = (&inputs[0], &inputs[1]);
    let base = scalar_pos(&inputs[2])?;
    let valid = scalar_pos(&inputs[3])?;
    let (table, blk) = paged_params(&inputs[4], &inputs[5], "cache_update_paged_c")?;
    if pool.shape.len() != 3 || rows.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update_paged_c: pool {:?} rows {:?}",
            pool.shape, rows.shape
        )));
    }
    let (pr, kvh, d) = (pool.shape[0], pool.shape[1], pool.shape[2]);
    if rows.shape[1] != kvh * d || valid > rows.shape[0] {
        return Err(Error::Shape(format!(
            "cache_update_paged_c: {valid} valid rows of {:?} into [{kvh}, {d}]",
            rows.shape
        )));
    }
    let stride = kvh * d;
    let mut out = f32s(pool, "cache_update_paged_c")?.to_vec();
    let src = f32s(rows, "cache_update_paged_c")?;
    for i in 0..valid {
        let phys = paged_row(table, blk, base + i, pr, "cache_update_paged_c")?;
        out[phys * stride..(phys + 1) * stride]
            .copy_from_slice(&src[i * stride..(i + 1) * stride]);
    }
    Tensor::f32(pool.shape.clone(), out)
}

/// Chunked paged causal attention: `[q [C, NH*D], k_pool, v_pool,
/// pos_base, valid_len, table, kv_block]`. The logical prefix
/// `0..pos_base+valid_len` is gathered ONCE, then each row reuses the
/// contiguous GQA at its own position (which only reads rows `0..pos`).
fn sdpa_prefill_paged(inputs: &[Tensor]) -> Result<Tensor> {
    let (q, kp, vp) = (&inputs[0], &inputs[1], &inputs[2]);
    let base = scalar_pos(&inputs[3])?;
    let valid = scalar_pos(&inputs[4])?;
    let (table, blk) = paged_params(&inputs[5], &inputs[6], "sdpa_prefill_paged")?;
    if q.shape.len() != 2 || kp.shape.len() != 3 {
        return Err(Error::Shape(format!(
            "sdpa_prefill_paged: q {:?} k {:?}",
            q.shape, kp.shape
        )));
    }
    let (c, qcols) = (q.shape[0], q.shape[1]);
    let d = kp.shape[2];
    if d == 0 || qcols % d != 0 || valid > c {
        return Err(Error::Shape(format!(
            "sdpa_prefill_paged: q {:?} vs head dim {d}, valid {valid}",
            q.shape
        )));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; c * qcols];
    if valid > 0 {
        let n = base + valid;
        let k = gather_paged(kp, table, blk, n, "sdpa_prefill_paged")?;
        let v = gather_paged(vp, table, blk, n, "sdpa_prefill_paged")?;
        for i in 0..valid {
            let qi = slot_row(q, i, vec![heads, d])?;
            let o = sdpa_gqa(&qi, &k, &v, base + i + 1)?;
            out[i * qcols..(i + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_prefill_paged")?);
        }
    }
    Tensor::f32(vec![c, qcols], out)
}

/// Unified paged cache scatter: `[pool, rows [W*C, KVH*D], pos_base [W],
/// valid_len [W], slot_mask [W], table [W*stride], kv_block]`. Slot b
/// scatters rows `b*C..b*C+valid_len[b]` through its table row at
/// positions `pos_base[b]..` unless masked.
fn cache_update_paged_unified(inputs: &[Tensor]) -> Result<Tensor> {
    let (pool, rows) = (&inputs[0], &inputs[1]);
    if pool.shape.len() != 3 || rows.shape.len() != 2 {
        return Err(Error::Shape(format!(
            "cache_update_paged_bc: pool {:?} rows {:?}",
            pool.shape, rows.shape
        )));
    }
    let base_t = &inputs[2];
    let w = base_t.numel();
    let base = i32_slots(base_t, w, "cache_update_paged_bc pos_base")?;
    let valid = i32_slots(&inputs[3], w, "cache_update_paged_bc valid_len")?;
    let mask = i32_slots(&inputs[4], w, "cache_update_paged_bc mask")?;
    let (table, blk) = paged_params(&inputs[5], &inputs[6], "cache_update_paged_bc")?;
    if w == 0 || table.len() % w != 0 || rows.shape[0] % w != 0 {
        return Err(Error::Shape(format!(
            "cache_update_paged_bc: rows {:?} / {} table entries over {w} slots",
            rows.shape,
            table.len()
        )));
    }
    let tstride = table.len() / w;
    let c = rows.shape[0] / w;
    let (pr, kvh, d) = (pool.shape[0], pool.shape[1], pool.shape[2]);
    if rows.shape[1] != kvh * d {
        return Err(Error::Shape(format!(
            "cache_update_paged_bc: rows {:?} for [{kvh}, {d}]",
            rows.shape
        )));
    }
    let stride = kvh * d;
    let mut out = f32s(pool, "cache_update_paged_bc")?.to_vec();
    let src = f32s(rows, "cache_update_paged_bc")?;
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let vl = valid[b].max(0) as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "cache_update_paged_bc: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        let t = &table[b * tstride..(b + 1) * tstride];
        let b0 = base[b].max(0) as usize;
        for i in 0..vl {
            let phys = paged_row(t, blk, b0 + i, pr, "cache_update_paged_bc")?;
            let r = b * c + i;
            out[phys * stride..(phys + 1) * stride]
                .copy_from_slice(&src[r * stride..(r + 1) * stride]);
        }
    }
    Tensor::f32(pool.shape.clone(), out)
}

/// Unified paged causal attention: `[q [W*C, NH*D], k_pool, v_pool,
/// pos_base [W], valid_len [W], slot_mask [W], table [W*stride],
/// kv_block]`. Each live slot gathers its prefix ONCE; masked slots and
/// ragged-tail rows produce zeros.
fn sdpa_paged_unified(inputs: &[Tensor]) -> Result<Tensor> {
    let (q, kp, vp) = (&inputs[0], &inputs[1], &inputs[2]);
    if q.shape.len() != 2 || kp.shape.len() != 3 {
        return Err(Error::Shape(format!(
            "sdpa_paged_bc: q {:?} k {:?}",
            q.shape, kp.shape
        )));
    }
    let base_t = &inputs[3];
    let w = base_t.numel();
    let base = i32_slots(base_t, w, "sdpa_paged_bc pos_base")?;
    let valid = i32_slots(&inputs[4], w, "sdpa_paged_bc valid_len")?;
    let mask = i32_slots(&inputs[5], w, "sdpa_paged_bc mask")?;
    let (table, blk) = paged_params(&inputs[6], &inputs[7], "sdpa_paged_bc")?;
    if w == 0 || table.len() % w != 0 || q.shape[0] % w != 0 {
        return Err(Error::Shape(format!(
            "sdpa_paged_bc: q {:?} / {} table entries over {w} slots",
            q.shape,
            table.len()
        )));
    }
    let tstride = table.len() / w;
    let (c, qcols) = (q.shape[0] / w, q.shape[1]);
    let d = kp.shape[2];
    if d == 0 || qcols % d != 0 {
        return Err(Error::Shape(format!(
            "sdpa_paged_bc: q cols {qcols} vs head dim {d}"
        )));
    }
    let heads = qcols / d;
    let mut out = vec![0f32; w * c * qcols];
    for b in 0..w {
        if mask[b] == 0 {
            continue;
        }
        let vl = valid[b].max(0) as usize;
        if vl > c {
            return Err(Error::Shape(format!(
                "sdpa_paged_bc: valid_len[{b}] = {vl} exceeds chunk {c}"
            )));
        }
        if vl == 0 {
            continue;
        }
        let t = &table[b * tstride..(b + 1) * tstride];
        let b0 = base[b].max(0) as usize;
        let n = b0 + vl;
        let k = gather_paged(kp, t, blk, n, "sdpa_paged_bc")?;
        let v = gather_paged(vp, t, blk, n, "sdpa_paged_bc")?;
        for i in 0..vl {
            let r = b * c + i;
            let qi = slot_row(q, r, vec![heads, d])?;
            let o = sdpa_gqa(&qi, &k, &v, b0 + i + 1)?;
            out[r * qcols..(r + 1) * qcols].copy_from_slice(f32s(&o, "sdpa_paged_bc")?);
        }
    }
    Tensor::f32(vec![w * c, qcols], out)
}

// --------------------------------------------------------------- dispatch --

fn need(inputs: &[Tensor], n: usize, name: &str) -> Result<()> {
    if inputs.len() != n {
        return Err(Error::Runtime(format!(
            "kernel {name}: needs {n} inputs, got {}",
            inputs.len()
        )));
    }
    Ok(())
}

/// Interpret `spec.name` and produce outputs matching `spec.outputs`.
pub fn execute_kernel(spec: &KernelSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let name = spec.name.as_str();
    // Ordering matters: check longer/more-specific prefixes before shorter
    // ones (e.g. "matmul" before "mul_", "rms_mul_x" before "rms_mul_w",
    // "softmax_naive" before "softmax") — and the batched `*_b{W}` /
    // chunked-prefill `*_c{C}` forms whose input layout differs from their
    // single-token counterparts before those counterparts. Row-wise
    // batched/chunked kernels (matmul_{b,c}*, rmsnorm_{b,c}*,
    // rms_*_{b,c}*, silu_*, mul_*, add_*) need no special casing: the
    // shared implementations are row-safe. The chunked kv/rope/rotary
    // forms reuse the batched per-row bodies — same math, per sequence
    // position instead of per slot.
    // Paged forms first: "sdpa_prefill_paged" shares the "sdpa_prefill"
    // prefix and "cache_update_paged*" shares "cache_update", so the paged
    // group must win before the contiguous checks run.
    let outs: Vec<Tensor> = if name.starts_with("cache_update_paged_b") {
        if unified_width_segment(name, "cache_update_paged_b") {
            need(inputs, 7, name)?;
            vec![cache_update_paged_unified(inputs)?]
        } else {
            need(inputs, 6, name)?;
            vec![cache_update_paged_batched(inputs)?]
        }
    } else if name.starts_with("cache_update_paged_c") {
        need(inputs, 6, name)?;
        vec![cache_update_paged_prefill(inputs)?]
    } else if name.starts_with("cache_update_paged") {
        need(inputs, 5, name)?;
        vec![cache_update_paged(inputs)?]
    } else if name.starts_with("sdpa_prefill_paged") {
        need(inputs, 7, name)?;
        vec![sdpa_prefill_paged(inputs)?]
    } else if name.starts_with("sdpa_paged_b") {
        if unified_width_segment(name, "sdpa_paged_b") {
            need(inputs, 8, name)?;
            vec![sdpa_paged_unified(inputs)?]
        } else {
            need(inputs, 7, name)?;
            vec![sdpa_paged_batched(inputs)?]
        }
    } else if name.starts_with("sdpa_paged") {
        need(inputs, 6, name)?;
        vec![sdpa_paged(inputs)?]
    } else if name.starts_with("kv_fused_b") || name.starts_with("kv_fused_c") {
        need(inputs, 2, name)?;
        kv_fused_batched(&inputs[0], &inputs[1])?
    } else if name.starts_with("rope_cos_sin_b") || name.starts_with("rope_cos_sin_c") {
        need(inputs, 2, name)?;
        rope_cos_sin_batched(&inputs[0], &inputs[1])?
    } else if name.starts_with("rotary_b") || name.starts_with("rotary_c") {
        need(inputs, 3, name)?;
        vec![rotary_batched(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("cache_update_b") {
        // `cache_update_b{W}c{C}_*` (unified seq x batch) vs
        // `cache_update_b{W}_*` (batched decode): the width segment of the
        // unified form carries an embedded 'c'.
        if unified_width_segment(name, "cache_update_b") {
            cache_update_unified(inputs)?
        } else {
            cache_update_batched(inputs)?
        }
    } else if name.starts_with("cache_update_c") {
        need(inputs, 4, name)?;
        vec![cache_update_prefill(inputs)?]
    } else if name.starts_with("sdpa_prefill") {
        need(inputs, 5, name)?;
        vec![sdpa_prefill(inputs)?]
    } else if name.starts_with("sdpa_b") {
        if unified_width_segment(name, "sdpa_b") {
            vec![sdpa_unified(inputs)?]
        } else {
            vec![sdpa_batched(inputs)?]
        }
    } else if name.starts_with("chunk_last_row") {
        need(inputs, 2, name)?;
        vec![chunk_last_row(&inputs[0], &inputs[1])?]
    } else if name.starts_with("chunk_rows") {
        need(inputs, 2, name)?;
        vec![chunk_rows(&inputs[0], &inputs[1])?]
    } else if name.starts_with("slot_last_row") {
        need(inputs, 3, name)?;
        vec![slot_last_row(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("slot_rows") {
        need(inputs, 3, name)?;
        vec![slot_rows(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("matmul") || name.starts_with("kv_fused") {
        need(inputs, 2, name)?;
        vec![matmul(&inputs[0], &inputs[1])?]
    } else if name.starts_with("gate_up_silu") {
        need(inputs, 3, name)?;
        vec![gate_up_silu(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("mega_mlp") {
        need(inputs, 5, name)?;
        vec![mega_mlp(&inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4])?]
    } else if name.starts_with("rmsnorm") {
        need(inputs, 2, name)?;
        vec![rmsnorm(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rms_pow") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| a * a)?]
    } else if name.starts_with("rms_mean") {
        need(inputs, 1, name)?;
        vec![rms_mean(&inputs[0])?]
    } else if name.starts_with("rms_add_eps") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| a + RMS_EPS)?]
    } else if name.starts_with("rms_rsqrt") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| 1.0 / a.sqrt())?]
    } else if name.starts_with("rms_mul_x") {
        need(inputs, 2, name)?;
        vec![mul_row_scalar(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rms_mul_w") || name.starts_with("mul_vec") {
        need(inputs, 2, name)?;
        vec![mul_lastdim(&inputs[0], &inputs[1])?]
    } else if name.starts_with("rope_cos_sin") {
        need(inputs, 2, name)?;
        rope_cos_sin(&inputs[0], &inputs[1])?
    } else if name.starts_with("rotary") {
        need(inputs, 3, name)?;
        vec![rotary(&inputs[0], &inputs[1], &inputs[2])?]
    } else if name.starts_with("neg") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], |a| -a)?]
    } else if name.starts_with("concat") {
        need(inputs, 2, name)?;
        vec![concat_last(&inputs[0], &inputs[1])?]
    } else if name.starts_with("cache_update") {
        need(inputs, 3, name)?;
        let pos = scalar_pos(&inputs[2])?;
        vec![cache_update(&inputs[0], &inputs[1], pos)?]
    } else if name.starts_with("sdpa") {
        need(inputs, 4, name)?;
        let pos = scalar_pos(&inputs[3])?;
        vec![sdpa_gqa(&inputs[0], &inputs[1], &inputs[2], pos)?]
    } else if name.starts_with("silu") {
        need(inputs, 1, name)?;
        vec![unary(&inputs[0], silu)?]
    } else if name.starts_with("softmax") {
        // covers softmax_naive_* too — same math, different memory traffic
        need(inputs, 1, name)?;
        vec![softmax_rows(&inputs[0])?]
    } else if name.starts_with("argmax") {
        need(inputs, 1, name)?;
        vec![argmax_rows(&inputs[0])?]
    } else if name.starts_with("add") {
        need(inputs, 2, name)?;
        vec![binary_same(&inputs[0], &inputs[1], |a, b| a + b)?]
    } else if name.starts_with("mul") {
        need(inputs, 2, name)?;
        vec![binary_same(&inputs[0], &inputs[1], |a, b| a * b)?]
    } else {
        return Err(Error::Runtime(format!(
            "reference runtime has no implementation for kernel '{name}'"
        )));
    };

    // Enforce the manifest's output contract (the PJRT path gets this from
    // the lowered module; here we check explicitly).
    if outs.len() != spec.outputs.len() {
        return Err(Error::Runtime(format!(
            "kernel {name}: produced {} outputs, manifest says {}",
            outs.len(),
            spec.outputs.len()
        )));
    }
    for (i, (o, s)) in outs.iter().zip(&spec.outputs).enumerate() {
        if o.shape != s.shape || o.dtype() != s.dtype {
            return Err(Error::Runtime(format!(
                "kernel {name}: output {i} is {:?}/{}, manifest wants {:?}/{}",
                o.shape,
                o.dtype(),
                s.shape,
                s.dtype
            )));
        }
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::webgpu::KernelIoSpec;

    fn spec(name: &str, outputs: Vec<KernelIoSpec>) -> KernelSpec {
        KernelSpec {
            name: name.into(),
            file: String::new(),
            inputs: vec![],
            outputs,
            tags: vec![],
            flops: 0.0,
            notes: String::new(),
        }
    }

    fn io(shape: Vec<usize>, dtype: DType) -> KernelIoSpec {
        KernelIoSpec { shape, dtype }
    }

    #[test]
    fn matmul_identity() {
        let x = Tensor::f32(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let eye = Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let s = spec("matmul_2_2", vec![io(vec![1, 2], DType::F32)]);
        let out = execute_kernel(&s, &[x, eye]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[3.0, 4.0]);
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x = Tensor::f32(vec![1, 4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        let w = Tensor::f32(vec![4], vec![1.0; 4]).unwrap();
        let s = spec("rmsnorm_4", vec![io(vec![1, 4], DType::F32)]);
        let out = execute_kernel(&s, &[x, w]).unwrap();
        let v = out[0].as_f32().unwrap();
        let rms: f32 = (v.iter().map(|a| a * a).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
    }

    #[test]
    fn fused_rmsnorm_matches_decomposition_bitwise() {
        let x = Tensor::f32(vec![1, 8], (0..8).map(|i| i as f32 * 0.37 - 1.1).collect()).unwrap();
        let w = Tensor::f32(vec![8], (0..8).map(|i| 0.5 + i as f32 * 0.1).collect()).unwrap();
        let fused = rmsnorm(&x, &w).unwrap();
        let x2 = unary(&x, |a| a * a).unwrap();
        let m = rms_mean(&x2).unwrap();
        let me = unary(&m, |a| a + RMS_EPS).unwrap();
        let r = unary(&me, |a| 1.0 / a.sqrt()).unwrap();
        let xn = mul_row_scalar(&x, &r).unwrap();
        let dec = mul_lastdim(&xn, &w).unwrap();
        assert_eq!(fused.as_f32().unwrap(), dec.as_f32().unwrap());
    }

    #[test]
    fn rotary_matches_unfused_chain_bitwise() {
        let x = Tensor::f32(vec![2, 4], (0..8).map(|i| (i as f32).sin()).collect()).unwrap();
        let cos = Tensor::f32(vec![4], vec![0.9, 0.8, 0.9, 0.8]).unwrap();
        let sin = Tensor::f32(vec![4], vec![0.1, 0.2, 0.1, 0.2]).unwrap();
        let fused = rotary(&x, &cos, &sin).unwrap();
        // unfused: halves -> neg -> concat -> mul_vec x2 -> add
        let half = 2;
        let xd = x.as_f32().unwrap();
        let mut x1 = Vec::new();
        let mut x2 = Vec::new();
        for r in 0..2 {
            x1.extend_from_slice(&xd[r * 4..r * 4 + half]);
            x2.extend_from_slice(&xd[r * 4 + half..r * 4 + 4]);
        }
        let x1 = Tensor::f32(vec![2, 2], x1).unwrap();
        let x2 = Tensor::f32(vec![2, 2], x2).unwrap();
        let x2n = unary(&x2, |a| -a).unwrap();
        let rot = concat_last(&x2n, &x1).unwrap();
        let a = mul_lastdim(&x, &cos).unwrap();
        let b = mul_lastdim(&rot, &sin).unwrap();
        let dec = binary_same(&a, &b, |p, q| p + q).unwrap();
        assert_eq!(fused.as_f32().unwrap(), dec.as_f32().unwrap());
    }

    #[test]
    fn sdpa_single_position_returns_value_row() {
        // With one valid cache row, attention output == that row's V.
        let q = Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let mut k = vec![0f32; 4 * 1 * 2];
        let mut v = vec![0f32; 4 * 1 * 2];
        k[0] = 1.0;
        k[1] = 2.0;
        v[0] = 5.0;
        v[1] = -3.0;
        let k = Tensor::f32(vec![4, 1, 2], k).unwrap();
        let v = Tensor::f32(vec![4, 1, 2], v).unwrap();
        let out = sdpa_gqa(&q, &k, &v, 1).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[5.0, -3.0, 5.0, -3.0]);
    }

    #[test]
    fn cache_update_writes_row() {
        let cache = Tensor::zeros_f32(vec![3, 1, 2]);
        let row = Tensor::f32(vec![1, 2], vec![7.0, 8.0]).unwrap();
        let out = cache_update(&cache, &row, 1).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[0.0, 0.0, 7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn argmax_first_max_wins() {
        let x = Tensor::f32(vec![1, 4], vec![1.0, 9.0, 9.0, 0.0]).unwrap();
        let s = spec("argmax_4", vec![io(vec![1], DType::I32)]);
        let out = execute_kernel(&s, &[x]).unwrap();
        assert_eq!(out[0].as_i32().unwrap(), &[1]);
    }

    #[test]
    fn softmax_rows_normalize() {
        let x = Tensor::f32(vec![2, 3], vec![0.0, 1.0, 2.0, -5.0, 0.0, 5.0]).unwrap();
        let out = softmax_rows(&x).unwrap();
        let v = out.as_f32().unwrap();
        for r in 0..2 {
            let sum: f32 = v[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn unknown_kernel_rejected() {
        let s = spec("warp_drive_9000", vec![]);
        assert!(execute_kernel(&s, &[]).is_err());
    }

    // ---- batched kernels: numerics-checked against looping the
    // single-session kernels, bit-for-bit ----

    fn ramp(shape: Vec<usize>, scale: f32, offset: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::f32(shape, (0..n).map(|i| (i as f32) * scale + offset).collect()).unwrap()
    }

    #[test]
    fn batched_rmsnorm_rows_match_single_rows_bitwise() {
        let w = 3;
        let h = 8;
        let x = ramp(vec![w, h], 0.13, -0.7);
        let g = ramp(vec![h], 0.05, 0.4);
        let batched = rmsnorm(&x, &g).unwrap();
        for b in 0..w {
            let xb = slot_row(&x, b, vec![1, h]).unwrap();
            let single = rmsnorm(&xb, &g).unwrap();
            assert_eq!(
                &batched.as_f32().unwrap()[b * h..(b + 1) * h],
                single.as_f32().unwrap(),
                "row {b}"
            );
        }
    }

    #[test]
    fn batched_rotary_and_rope_match_single_loop_bitwise() {
        let (w, heads, d) = (4usize, 2usize, 8usize);
        let pos = Tensor::f32(vec![w], vec![0.0, 3.0, 7.0, 1.0]).unwrap();
        let inv = ramp(vec![d / 2], 0.21, 0.05);
        let cs = rope_cos_sin_batched(&pos, &inv).unwrap();
        let x = ramp(vec![w, heads * d], 0.07, -1.2);
        let out = rotary_batched(&x, &cs[0], &cs[1]).unwrap();
        for b in 0..w {
            let p = pos.as_f32().unwrap()[b];
            let single_cs = rope_cos_sin(&Tensor::scalar_f32(p), &inv).unwrap();
            assert_eq!(
                slot_row(&cs[0], b, vec![d]).unwrap().as_f32().unwrap(),
                single_cs[0].as_f32().unwrap(),
                "cos row {b}"
            );
            let xb = slot_row(&x, b, vec![heads, d]).unwrap();
            let single = rotary(&xb, &single_cs[0], &single_cs[1]).unwrap();
            assert_eq!(
                &out.as_f32().unwrap()[b * heads * d..(b + 1) * heads * d],
                single.as_f32().unwrap(),
                "rotary row {b}"
            );
        }
    }

    #[test]
    fn batched_kv_fused_matches_matmul_then_split_bitwise() {
        let (w, h, kv) = (3usize, 4usize, 3usize);
        let x = ramp(vec![w, h], 0.31, -0.2);
        let wkv = ramp(vec![h, 2 * kv], 0.11, 0.9);
        let outs = kv_fused_batched(&x, &wkv).unwrap();
        for b in 0..w {
            let xb = slot_row(&x, b, vec![1, h]).unwrap();
            let m = matmul(&xb, &wkv).unwrap();
            let md = m.as_f32().unwrap();
            assert_eq!(&outs[0].as_f32().unwrap()[b * kv..(b + 1) * kv], &md[..kv]);
            assert_eq!(&outs[1].as_f32().unwrap()[b * kv..(b + 1) * kv], &md[kv..]);
        }
    }

    #[test]
    fn batched_cache_update_scatters_and_masks_per_slot() {
        let (w, s, kvh, d) = (3usize, 4usize, 1usize, 2usize);
        let caches: Vec<Tensor> = (0..w)
            .map(|j| ramp(vec![s, kvh, d], 0.0, j as f32 + 1.0))
            .collect();
        let rows = ramp(vec![w, kvh * d], 1.0, 100.0);
        let pos = Tensor::i32(vec![w], vec![1, 2, 3]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 0, 1]).unwrap();
        let idx = Tensor::i32(vec![w], vec![0, 1, 2]).unwrap();
        let mut inputs = caches.clone();
        inputs.extend([rows.clone(), pos, mask, idx]);
        let outs = cache_update_batched(&inputs).unwrap();
        // Active slots match the single-session kernel exactly.
        let r0 = slot_row(&rows, 0, vec![kvh, d]).unwrap();
        assert_eq!(
            outs[0].as_f32().unwrap(),
            cache_update(&caches[0], &r0, 1).unwrap().as_f32().unwrap()
        );
        let r2 = slot_row(&rows, 2, vec![kvh, d]).unwrap();
        assert_eq!(
            outs[2].as_f32().unwrap(),
            cache_update(&caches[2], &r2, 3).unwrap().as_f32().unwrap()
        );
        // The masked slot's state is bit-identical to its input.
        assert_eq!(outs[1].as_f32().unwrap(), caches[1].as_f32().unwrap());
    }

    #[test]
    fn batched_cache_update_follows_slot_idx_permutation() {
        // Row b lands in cache set slot_idx[b]: a swapped index routes
        // row 0 into slot 1 and row 1 into slot 0.
        let (w, s, kvh, d) = (2usize, 2usize, 1usize, 2usize);
        let caches: Vec<Tensor> = (0..w).map(|_| Tensor::zeros_f32(vec![s, kvh, d])).collect();
        let rows = Tensor::f32(vec![w, kvh * d], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let pos = Tensor::i32(vec![w], vec![0, 0]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1]).unwrap();
        let idx = Tensor::i32(vec![w], vec![1, 0]).unwrap();
        let mut inputs = caches;
        inputs.extend([rows, pos, mask, idx]);
        let outs = cache_update_batched(&inputs).unwrap();
        assert_eq!(&outs[1].as_f32().unwrap()[..2], &[1.0, 2.0]);
        assert_eq!(&outs[0].as_f32().unwrap()[..2], &[3.0, 4.0]);
        // Out-of-range index fails loudly.
        let mut bad = outs.clone();
        bad.extend([
            Tensor::f32(vec![w, kvh * d], vec![0.0; 4]).unwrap(),
            Tensor::i32(vec![w], vec![0, 0]).unwrap(),
            Tensor::i32(vec![w], vec![1, 1]).unwrap(),
            Tensor::i32(vec![w], vec![0, 9]).unwrap(),
        ]);
        assert!(cache_update_batched(&bad).is_err());
    }

    // ---- chunked-prefill kernels: bit-identical to looping the
    // single-token kernels over the chunk's positions ----

    #[test]
    fn prefill_cache_scatter_matches_single_update_loop_bitwise() {
        let (c, s, kvh, d) = (4usize, 8usize, 2usize, 3usize);
        let cache = ramp(vec![s, kvh, d], 0.01, -0.3);
        let rows = ramp(vec![c, kvh * d], 0.2, 10.0);
        let base = 2usize;
        let valid = 3usize; // ragged tail: row 3 must not land
        let inputs = [
            cache.clone(),
            rows.clone(),
            Tensor::scalar_i32(base as i32),
            Tensor::scalar_i32(valid as i32),
        ];
        let out = cache_update_prefill(&inputs).unwrap();
        // Loop of single-token updates over the valid rows.
        let mut expect = cache.clone();
        for i in 0..valid {
            let row = slot_row(&rows, i, vec![kvh, d]).unwrap();
            expect = cache_update(&expect, &row, base + i).unwrap();
        }
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
        // The ragged row's target position stays untouched.
        let tail = (base + valid) * kvh * d;
        assert_eq!(
            &out.as_f32().unwrap()[tail..tail + kvh * d],
            &cache.as_f32().unwrap()[tail..tail + kvh * d]
        );
        // Overflowing the cache fails loudly.
        let bad = [
            cache.clone(),
            rows,
            Tensor::scalar_i32((s - 1) as i32),
            Tensor::scalar_i32(3),
        ];
        assert!(cache_update_prefill(&bad).is_err());
    }

    #[test]
    fn prefill_sdpa_matches_single_position_loop_and_zeroes_tail() {
        let (c, s, heads, kvh, d) = (4usize, 8usize, 2usize, 1usize, 2usize);
        let base = 3usize;
        let valid = 3usize;
        let q = ramp(vec![c, heads * d], 0.17, -0.4);
        let k = ramp(vec![s, kvh, d], 0.09, 0.5);
        let v = ramp(vec![s, kvh, d], 0.05, -0.8);
        let inputs = [
            q.clone(),
            k.clone(),
            v.clone(),
            Tensor::scalar_i32(base as i32),
            Tensor::scalar_i32(valid as i32),
        ];
        let out = sdpa_prefill(&inputs).unwrap();
        for i in 0..valid {
            let qi = slot_row(&q, i, vec![heads, d]).unwrap();
            // Row i's causal window: cache history + the preceding
            // in-chunk rows — exactly the single-token sdpa at base+i.
            let single = sdpa_gqa(&qi, &k, &v, base + i + 1).unwrap();
            assert_eq!(
                &out.as_f32().unwrap()[i * heads * d..(i + 1) * heads * d],
                single.as_f32().unwrap(),
                "row {i}"
            );
        }
        assert!(
            out.as_f32().unwrap()[valid * heads * d..].iter().all(|&x| x == 0.0),
            "ragged tail rows must produce zeros"
        );
    }

    #[test]
    fn chunk_last_row_selects_final_valid_row() {
        let x = ramp(vec![4, 3], 1.0, 0.0);
        let out = chunk_last_row(&x, &Tensor::scalar_i32(2)).unwrap();
        assert_eq!(out.shape, vec![1, 3]);
        assert_eq!(out.as_f32().unwrap(), &[3.0, 4.0, 5.0]); // row 1
        assert!(chunk_last_row(&x, &Tensor::scalar_i32(0)).is_err());
        assert!(chunk_last_row(&x, &Tensor::scalar_i32(5)).is_err());
    }

    /// The multi-row selection is bit-identical to looping chunk_last_row
    /// over every prefix length 1..=valid_len: row v-1 of chunk_rows(x, k)
    /// equals chunk_last_row(x, v) for all v <= k, and the ragged tail is
    /// zeroed.
    #[test]
    fn chunk_rows_matches_chunk_last_row_prefix_loop_bitwise() {
        let (c, h) = (6usize, 5usize);
        let x = ramp(vec![c, h], 0.31, -2.0);
        for valid in 1..=c {
            let rows = chunk_rows(&x, &Tensor::scalar_i32(valid as i32)).unwrap();
            assert_eq!(rows.shape, vec![c, h]);
            let rd = rows.as_f32().unwrap();
            for v in 1..=valid {
                let last = chunk_last_row(&x, &Tensor::scalar_i32(v as i32)).unwrap();
                assert_eq!(
                    &rd[(v - 1) * h..v * h],
                    last.as_f32().unwrap(),
                    "valid {valid} prefix {v}"
                );
            }
            assert!(rd[valid * h..].iter().all(|&e| e == 0.0), "ragged tail valid {valid}");
        }
        assert!(chunk_rows(&x, &Tensor::scalar_i32(0)).is_err());
        assert!(chunk_rows(&x, &Tensor::scalar_i32(c as i32 + 1)).is_err());
    }

    /// The multi-row lm head composes row-wise: matmul over the chunk_rows
    /// output scores each kept row exactly as the single-row tail would
    /// (chunk_last_row -> matmul at each prefix length).
    #[test]
    fn multi_row_lm_head_matches_single_row_tail_per_prefix_bitwise() {
        let (c, h, v) = (4usize, 3usize, 6usize);
        let x = ramp(vec![c, h], 0.17, 0.9);
        let w_lm = ramp(vec![h, v], -0.08, 1.1);
        let valid = 3usize;
        let rows = chunk_rows(&x, &Tensor::scalar_i32(valid as i32)).unwrap();
        let logits = matmul(&rows, &w_lm).unwrap();
        assert_eq!(logits.shape, vec![c, v]);
        let ld = logits.as_f32().unwrap();
        for p in 1..=valid {
            let last = chunk_last_row(&x, &Tensor::scalar_i32(p as i32)).unwrap();
            let single = matmul(&last, &w_lm).unwrap();
            assert_eq!(&ld[(p - 1) * v..p * v], single.as_f32().unwrap(), "prefix {p}");
        }
    }

    // ---- unified (seq x batch) kernels: bit-identical to looping the
    // chunked-prefill / single-token kernels per slot ----

    #[test]
    fn unified_cache_scatter_matches_per_slot_prefill_loop_bitwise() {
        let (w, c, s, kvh, d) = (3usize, 4usize, 16usize, 2usize, 3usize);
        let caches: Vec<Tensor> = (0..w)
            .map(|j| ramp(vec![s, kvh, d], 0.01, j as f32 - 0.3))
            .collect();
        let rows = ramp(vec![w * c, kvh * d], 0.2, 10.0);
        // Slot 0: full prefill chunk. Slot 1: masked padding. Slot 2:
        // decode step (valid_len = 1) routed into cache set 1.
        let base = Tensor::i32(vec![w], vec![2, 0, 7]).unwrap();
        let valid = Tensor::i32(vec![w], vec![4, 0, 1]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 0, 1]).unwrap();
        let idx = Tensor::i32(vec![w], vec![0, 2, 1]).unwrap();
        let mut inputs = caches.clone();
        inputs.extend([rows.clone(), base, valid, mask, idx]);
        let outs = cache_update_unified(&inputs).unwrap();
        assert_eq!(outs.len(), w);
        // Slot 0 == looping cache_update over its 4 rows from position 2.
        let mut expect0 = caches[0].clone();
        for i in 0..4 {
            let row = slot_row(&rows, i, vec![kvh, d]).unwrap();
            expect0 = cache_update(&expect0, &row, 2 + i).unwrap();
        }
        assert_eq!(outs[0].as_f32().unwrap(), expect0.as_f32().unwrap());
        // Slot 2's single decode row == one cache_update at position 7 on
        // cache set 1.
        let row2 = slot_row(&rows, 2 * c, vec![kvh, d]).unwrap();
        let expect1 = cache_update(&caches[1], &row2, 7).unwrap();
        assert_eq!(outs[1].as_f32().unwrap(), expect1.as_f32().unwrap());
        // The masked padding slot's cache set is bit-identical untouched.
        assert_eq!(outs[2].as_f32().unwrap(), caches[2].as_f32().unwrap());
        // valid_len beyond the chunk fails loudly.
        let mut bad = caches.clone();
        bad.extend([
            rows,
            Tensor::i32(vec![w], vec![0, 0, 0]).unwrap(),
            Tensor::i32(vec![w], vec![(c + 1) as i32, 0, 0]).unwrap(),
            Tensor::i32(vec![w], vec![1, 0, 0]).unwrap(),
            Tensor::i32(vec![w], vec![0, 1, 2]).unwrap(),
        ]);
        assert!(cache_update_unified(&bad).is_err());
    }

    #[test]
    fn unified_sdpa_matches_per_slot_row_loop_and_zeroes_tail() {
        let (w, c, s, heads, kvh, d) = (3usize, 4usize, 16usize, 2usize, 1usize, 2usize);
        let q = ramp(vec![w * c, heads * d], 0.17, -0.4);
        let ks: Vec<Tensor> = (0..w).map(|j| ramp(vec![s, kvh, d], 0.09, j as f32)).collect();
        let vs: Vec<Tensor> = (0..w).map(|j| ramp(vec![s, kvh, d], 0.05, -(j as f32))).collect();
        // Slot 0: ragged prefill (3 of 4 rows). Slot 1: decode step against
        // cache set 2. Slot 2: masked padding.
        let base = Tensor::i32(vec![w], vec![3, 6, 0]).unwrap();
        let valid = Tensor::i32(vec![w], vec![3, 1, 0]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1, 0]).unwrap();
        let idx = Tensor::i32(vec![w], vec![0, 2, 1]).unwrap();
        let mut inputs = vec![q.clone()];
        inputs.extend(ks.iter().cloned());
        inputs.extend(vs.iter().cloned());
        inputs.extend([base, valid, mask, idx]);
        let out = sdpa_unified(&inputs).unwrap();
        assert_eq!(out.shape, vec![w * c, heads * d]);
        let od = out.as_f32().unwrap();
        // Slot 0 rows 0..3 == single-token sdpa at positions base+i.
        for i in 0..3 {
            let qi = slot_row(&q, i, vec![heads, d]).unwrap();
            let single = sdpa_gqa(&qi, &ks[0], &vs[0], 3 + i + 1).unwrap();
            assert_eq!(
                &od[i * heads * d..(i + 1) * heads * d],
                single.as_f32().unwrap(),
                "slot 0 row {i}"
            );
        }
        // Slot 0's ragged row 3 is zero.
        assert!(od[3 * heads * d..4 * heads * d].iter().all(|&x| x == 0.0));
        // Slot 1 row 0 == decode-step sdpa against cache set 2.
        let q1 = slot_row(&q, c, vec![heads, d]).unwrap();
        let single = sdpa_gqa(&q1, &ks[2], &vs[2], 7).unwrap();
        assert_eq!(
            &od[c * heads * d..(c + 1) * heads * d],
            single.as_f32().unwrap()
        );
        assert!(od[(c + 1) * heads * d..2 * c * heads * d].iter().all(|&x| x == 0.0));
        // The masked padding slot's rows are all zeros.
        assert!(od[2 * c * heads * d..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slot_last_row_selects_per_slot_and_zeroes_empty_slots() {
        let (w, c, h) = (3usize, 4usize, 3usize);
        let x = ramp(vec![w * c, h], 1.0, 0.0);
        let valid = Tensor::i32(vec![w], vec![4, 1, 0]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1, 0]).unwrap();
        let out = slot_last_row(&x, &valid, &mask).unwrap();
        assert_eq!(out.shape, vec![w, h]);
        let od = out.as_f32().unwrap();
        let xd = x.as_f32().unwrap();
        // Slot 0: row 3 (its last valid). Slot 1: row c*1 + 0 (decode).
        assert_eq!(&od[..h], &xd[3 * h..4 * h]);
        assert_eq!(&od[h..2 * h], &xd[c * h..(c * h + h)]);
        // Padding slot (valid_len = 0, masked) yields zeros — NOT an error,
        // unlike chunk_last_row.
        assert!(od[2 * h..].iter().all(|&x| x == 0.0));
        // valid_len beyond the chunk still fails loudly.
        let bad_valid = Tensor::i32(vec![w], vec![5, 1, 0]).unwrap();
        assert!(slot_last_row(&x, &bad_valid, &mask).is_err());
    }

    /// Per-slot multi-row selection is bit-identical to looping
    /// slot_last_row over every per-slot prefix length, with ragged tails
    /// AND masked slots zeroed.
    #[test]
    fn slot_rows_matches_slot_last_row_prefix_loop_bitwise() {
        let (w, c, h) = (3usize, 4usize, 3usize);
        let x = ramp(vec![w * c, h], 1.0, 0.0);
        // Slot 0: full spec chunk. Slot 1: decode (valid 1). Slot 2: masked.
        let valid = Tensor::i32(vec![w], vec![3, 1, 2]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1, 0]).unwrap();
        let out = slot_rows(&x, &valid, &mask).unwrap();
        assert_eq!(out.shape, vec![w * c, h]);
        let od = out.as_f32().unwrap();
        // Every live slot's row v-1 equals slot_last_row at prefix v.
        for (b, vl) in [(0usize, 3usize), (1, 1)] {
            for v in 1..=vl {
                let mut pv = vec![0i32; w];
                pv[b] = v as i32;
                let prefix_valid = Tensor::i32(vec![w], pv).unwrap();
                let last = slot_last_row(&x, &prefix_valid, &mask).unwrap();
                let ld = last.as_f32().unwrap();
                assert_eq!(
                    &od[(b * c + v - 1) * h..(b * c + v) * h],
                    &ld[b * h..(b + 1) * h],
                    "slot {b} prefix {v}"
                );
            }
            // Ragged tail rows are zeroed.
            assert!(
                od[(b * c + vl) * h..(b + 1) * c * h].iter().all(|&e| e == 0.0),
                "slot {b} tail"
            );
        }
        // Masked slot 2 is fully zeroed despite valid_len = 2.
        assert!(od[2 * c * h..].iter().all(|&e| e == 0.0), "masked slot");
        // valid_len beyond the chunk still fails loudly.
        let bad_valid = Tensor::i32(vec![w], vec![5, 1, 0]).unwrap();
        assert!(slot_rows(&x, &bad_valid, &mask).is_err());
    }

    #[test]
    fn unified_dispatch_disambiguates_from_batched_by_name() {
        assert!(unified_width_segment("cache_update_b4c16_tiny", "cache_update_b"));
        assert!(!unified_width_segment("cache_update_b4_tiny", "cache_update_b"));
        assert!(unified_width_segment("sdpa_b8c32_tiny", "sdpa_b"));
        assert!(!unified_width_segment("sdpa_b8_tiny", "sdpa_b"));
    }

    #[test]
    fn batched_sdpa_matches_single_loop_and_zeroes_masked_rows() {
        let (w, heads, kvh, d, s) = (3usize, 2usize, 1usize, 2usize, 4usize);
        let q = ramp(vec![w, heads * d], 0.17, -0.4);
        let ks: Vec<Tensor> = (0..w).map(|j| ramp(vec![s, kvh, d], 0.09, j as f32)).collect();
        let vs: Vec<Tensor> = (0..w).map(|j| ramp(vec![s, kvh, d], 0.05, -(j as f32))).collect();
        let pos = Tensor::i32(vec![w], vec![2, 4, 1]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1, 0]).unwrap();
        let idx = Tensor::i32(vec![w], vec![0, 1, 2]).unwrap();
        let mut inputs = vec![q.clone()];
        inputs.extend(ks.iter().cloned());
        inputs.extend(vs.iter().cloned());
        inputs.extend([pos, mask, idx]);
        let out = sdpa_batched(&inputs).unwrap();
        for b in 0..2 {
            let qb = slot_row(&q, b, vec![heads, d]).unwrap();
            let p = [2usize, 4][b];
            let single = sdpa_gqa(&qb, &ks[b], &vs[b], p).unwrap();
            assert_eq!(
                &out.as_f32().unwrap()[b * heads * d..(b + 1) * heads * d],
                single.as_f32().unwrap(),
                "slot {b}"
            );
        }
        assert!(
            out.as_f32().unwrap()[2 * heads * d..].iter().all(|&x| x == 0.0),
            "masked slot must produce zeros"
        );
    }

    fn i1(v: i32) -> Tensor {
        Tensor::i32(vec![1], vec![v]).unwrap()
    }

    #[test]
    fn paged_decode_matches_contiguous_bitwise_through_scrambled_table() {
        // Pool of 8 rows, block = 2; the table maps logical blocks
        // [0, 1, 2] to scrambled physical blocks [3, 0, 2], block 3
        // unallocated. A decode loop must be bit-identical to the
        // contiguous kernels at every step.
        let (kvh, d, heads) = (1usize, 2usize, 2usize);
        let (pr, blk) = (8usize, 2usize);
        let table = Tensor::i32(vec![4], vec![3, 0, 2, -1]).unwrap();
        let kvb = i1(blk as i32);
        let mut ck = Tensor::f32(vec![6, kvh, d], vec![0.0; 6 * kvh * d]).unwrap();
        let mut pk = Tensor::f32(vec![pr, kvh, d], vec![0.0; pr * kvh * d]).unwrap();
        for p in 0..6usize {
            let row = ramp(vec![kvh, d], 0.11, p as f32);
            ck = cache_update(&ck, &row, p).unwrap();
            pk = cache_update_paged(&[
                pk.clone(), row, i1(p as i32), table.clone(), kvb.clone(),
            ]).unwrap();
            let q = ramp(vec![heads, d], 0.2, -0.3 - p as f32);
            let a = sdpa_gqa(&q, &ck, &ck, p + 1).unwrap();
            let b = sdpa_paged(&[
                q, pk.clone(), pk.clone(), i1((p + 1) as i32), table.clone(), kvb.clone(),
            ]).unwrap();
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "pos {p}");
        }
        // The logical prefix reassembles bitwise through the table.
        let g = gather_paged(&pk, table.as_i32().unwrap(), blk, 6, "test").unwrap();
        assert_eq!(g.as_f32().unwrap(), ck.as_f32().unwrap());
        // Writing or reading through an unallocated block fails loudly.
        let row = ramp(vec![kvh, d], 0.3, 0.0);
        assert!(cache_update_paged(&[
            pk.clone(), row, i1(6), table.clone(), kvb.clone(),
        ]).is_err());
        let q = ramp(vec![heads, d], 0.2, 0.1);
        assert!(sdpa_paged(&[
            q, pk.clone(), pk, i1(7), table, kvb,
        ]).is_err());
    }

    #[test]
    fn paged_prefill_matches_contiguous_bitwise() {
        let (kvh, d, heads, c) = (1usize, 2usize, 2usize, 4usize);
        let (pr, blk) = (8usize, 2usize);
        let table = Tensor::i32(vec![4], vec![2, 0, 3, 1]).unwrap();
        let kvb = i1(blk as i32);
        // Pre-existing history: rows 0 and 1 written single-token.
        let mut ck = Tensor::f32(vec![8, kvh, d], vec![0.0; 8 * kvh * d]).unwrap();
        let mut pk = Tensor::f32(vec![pr, kvh, d], vec![0.0; pr * kvh * d]).unwrap();
        for p in 0..2usize {
            let row = ramp(vec![kvh, d], 0.13, p as f32);
            ck = cache_update(&ck, &row, p).unwrap();
            pk = cache_update_paged(&[
                pk.clone(), row, i1(p as i32), table.clone(), kvb.clone(),
            ]).unwrap();
        }
        // Chunk of 4 with 3 valid rows scattered at base 2.
        let rows = ramp(vec![c, kvh * d], 0.07, 0.5);
        ck = cache_update_prefill(&[ck.clone(), rows.clone(), i1(2), i1(3)]).unwrap();
        pk = cache_update_paged_prefill(&[
            pk.clone(), rows, i1(2), i1(3), table.clone(), kvb.clone(),
        ]).unwrap();
        let g = gather_paged(&pk, table.as_i32().unwrap(), blk, 5, "test").unwrap();
        assert_eq!(g.as_f32().unwrap(), &ck.as_f32().unwrap()[..5 * kvh * d]);
        let q = ramp(vec![c, heads * d], 0.19, -0.8);
        let a = sdpa_prefill(&[q.clone(), ck.clone(), ck, i1(2), i1(3)]).unwrap();
        let b = sdpa_prefill_paged(&[q, pk.clone(), pk, i1(2), i1(3), table, kvb]).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }

    #[test]
    fn paged_batched_and_unified_match_contiguous_bitwise() {
        // Two slots with disjoint scrambled tables in one pool; masked
        // width-padding slots are skipped without touching their (-1)
        // tables.
        let (w, kvh, d, heads, c) = (2usize, 1usize, 2usize, 2usize, 3usize);
        let (pr, blk) = (16usize, 2usize);
        let tables = Tensor::i32(vec![2 * 4], vec![0, 1, -1, -1, 4, 2, -1, -1]).unwrap();
        let kvb = i1(blk as i32);
        // Contiguous twin state: one [4, kvh, d] cache per slot.
        let mut cs: Vec<Tensor> = (0..w)
            .map(|_| Tensor::f32(vec![4, kvh, d], vec![0.0; 4 * kvh * d]).unwrap())
            .collect();
        let mut pool = Tensor::f32(vec![pr, kvh, d], vec![0.0; pr * kvh * d]).unwrap();
        // Unified round: slot 0 prefills 3 rows at base 0, slot 1 two
        // rows at base 0 (ragged tail).
        let rows = ramp(vec![w * c, kvh * d], 0.07, 0.4);
        let base = Tensor::i32(vec![w], vec![0, 0]).unwrap();
        let valid = Tensor::i32(vec![w], vec![3, 2]).unwrap();
        let mask = Tensor::i32(vec![w], vec![1, 1]).unwrap();
        let idx = Tensor::i32(vec![w], vec![0, 1]).unwrap();
        let mut ins: Vec<Tensor> = cs.clone();
        ins.extend([rows.clone(), base.clone(), valid.clone(), mask.clone(), idx.clone()]);
        cs = cache_update_unified(&ins).unwrap();
        pool = cache_update_paged_unified(&[
            pool.clone(), rows, base.clone(), valid.clone(), mask.clone(),
            tables.clone(), kvb.clone(),
        ]).unwrap();
        let q = ramp(vec![w * c, heads * d], 0.21, -0.6);
        let mut ins: Vec<Tensor> = vec![q.clone()];
        ins.extend(cs.iter().cloned());
        ins.extend(cs.iter().cloned());
        ins.extend([base.clone(), valid.clone(), mask.clone(), idx.clone()]);
        let a = sdpa_unified(&ins).unwrap();
        let b = sdpa_paged_unified(&[
            q, pool.clone(), pool.clone(), base, valid, mask.clone(),
            tables.clone(), kvb.clone(),
        ]).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "unified round");

        // Batched decode round on top: slot 0 appends at pos 3, slot 1 at
        // pos 2.
        let drow = ramp(vec![w, kvh * d], 0.09, 1.2);
        let pos = Tensor::i32(vec![w], vec![3, 2]).unwrap();
        let mut ins: Vec<Tensor> = cs.clone();
        ins.extend([drow.clone(), pos.clone(), mask.clone(), idx.clone()]);
        cs = cache_update_batched(&ins).unwrap();
        pool = cache_update_paged_batched(&[
            pool.clone(), drow, pos, mask.clone(), tables.clone(), kvb.clone(),
        ]).unwrap();
        let q = ramp(vec![w, heads * d], 0.23, 0.9);
        let pos_ip1 = Tensor::i32(vec![w], vec![4, 3]).unwrap();
        let mut ins: Vec<Tensor> = vec![q.clone()];
        ins.extend(cs.iter().cloned());
        ins.extend(cs.iter().cloned());
        ins.extend([pos_ip1.clone(), mask.clone(), idx]);
        let a = sdpa_batched(&ins).unwrap();
        let b = sdpa_paged_batched(&[
            q, pool.clone(), pool, pos_ip1, mask, tables, kvb,
        ]).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "batched round");
    }

    #[test]
    fn paged_dispatch_disambiguates_by_name() {
        assert!(unified_width_segment("cache_update_paged_b4c16_tiny", "cache_update_paged_b"));
        assert!(!unified_width_segment("cache_update_paged_b4_tiny", "cache_update_paged_b"));
        assert!(unified_width_segment("sdpa_paged_b8c32_tiny", "sdpa_paged_b"));
        assert!(!unified_width_segment("sdpa_paged_b8_tiny", "sdpa_paged_b"));
    }
}

//! Kernel registry: kernel specs + an execution backend.
//!
//! Two backends implement [`KernelRunner`] behind one `Registry` API:
//!
//! - **Reference** (default): the pure-Rust interpreter in
//!   [`super::reference`], driven by either an on-disk `manifest.json` or
//!   the built-in manifest in [`super::builtin`]. Always available.
//! - **PJRT** (`--features pjrt`): lazy-compiled PJRT executables from the
//!   AOT HLO-text artifacts, as the paper's real-system mode.
//!
//! `Registry::open()` discovers artifacts and falls back to the built-in
//! manifest + reference interpreter when none exist, so the deterministic
//! suite runs hermetically offline.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::report::json;
use crate::tensor::{DType, Tensor};
use crate::webgpu::{KernelIoSpec, KernelRunner};
use crate::{Error, Result};

use super::client::ArtifactPaths;
#[cfg(feature = "pjrt")]
use super::client::PjrtRuntime;
use super::reference::ReferenceRuntime;

/// One AOT kernel's metadata from the manifest.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<KernelIoSpec>,
    pub outputs: Vec<KernelIoSpec>,
    pub tags: Vec<String>,
    pub flops: f64,
    pub notes: String,
}

/// Model dims parsed from the manifest's `configs` section.
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

/// The execution backend behind a [`Registry`].
pub enum KernelRuntime {
    /// Pure-Rust host interpreter (always available; the default).
    Reference(ReferenceRuntime),
    /// PJRT CPU client executing AOT HLO artifacts.
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtRuntime),
}

impl KernelRuntime {
    pub fn platform(&self) -> String {
        match self {
            KernelRuntime::Reference(r) => r.platform(),
            #[cfg(feature = "pjrt")]
            KernelRuntime::Pjrt(p) => p.platform(),
        }
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        match self {
            KernelRuntime::Reference(r) => r.is_loaded(name),
            #[cfg(feature = "pjrt")]
            KernelRuntime::Pjrt(p) => p.is_loaded(name),
        }
    }

    pub fn loaded_count(&self) -> usize {
        match self {
            KernelRuntime::Reference(r) => r.loaded_count(),
            #[cfg(feature = "pjrt")]
            KernelRuntime::Pjrt(p) => p.loaded_count(),
        }
    }
}

pub struct Registry {
    pub dir: PathBuf,
    pub runtime: KernelRuntime,
    pub kernels: HashMap<String, KernelSpec>,
    pub configs: HashMap<String, ManifestConfig>,
}

fn parse_io(v: &json::Value) -> Result<KernelIoSpec> {
    let shape = v
        .req("shape")?
        .as_arr()
        .ok_or_else(|| Error::Json("shape not an array".into()))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| Error::Json("bad dim".into())))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match v.req("dtype")?.as_str() {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => return Err(Error::Json(format!("bad dtype {other:?}"))),
    };
    Ok(KernelIoSpec { shape, dtype })
}

impl Registry {
    /// Open the artifact registry if one exists; otherwise fall back to the
    /// built-in manifest + host reference interpreter (the hermetic mode
    /// the tests and benches use — no `make artifacts` required).
    pub fn open() -> Result<Self> {
        match ArtifactPaths::discover() {
            Ok(p) => Self::open_at(p.dir),
            Err(_) => Self::builtin(),
        }
    }

    /// Registry over the built-in manifest, executed by the reference
    /// interpreter.
    pub fn builtin() -> Result<Self> {
        Ok(Registry {
            dir: PathBuf::from("<builtin>"),
            runtime: KernelRuntime::Reference(ReferenceRuntime::new()),
            kernels: super::builtin::builtin_kernels(),
            configs: super::builtin::builtin_configs(),
        })
    }

    pub fn open_at(dir: PathBuf) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!("read {}: {e}", manifest_path.display()))
        })?;
        let root = json::parse(&text)?;
        let mut kernels = HashMap::new();
        for k in root
            .req("kernels")?
            .as_arr()
            .ok_or_else(|| Error::Json("kernels not an array".into()))?
        {
            let spec = KernelSpec {
                name: k.req("name")?.as_str().unwrap_or_default().to_string(),
                file: k.req("file")?.as_str().unwrap_or_default().to_string(),
                inputs: k
                    .req("inputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
                outputs: k
                    .req("outputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
                tags: k
                    .get("tags")
                    .and_then(|t| t.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|s| s.as_str().map(str::to_string))
                            .collect()
                    })
                    .unwrap_or_default(),
                flops: k.get("flops").and_then(|f| f.as_f64()).unwrap_or(0.0),
                notes: k
                    .get("notes")
                    .and_then(|s| s.as_str())
                    .unwrap_or("")
                    .to_string(),
            };
            kernels.insert(spec.name.clone(), spec);
        }

        let mut configs = HashMap::new();
        if let Some(json::Value::Obj(cfgs)) = root.get("configs") {
            for (name, c) in cfgs {
                configs.insert(
                    name.clone(),
                    ManifestConfig {
                        name: name.clone(),
                        hidden: c.req("hidden")?.as_usize().unwrap_or(0),
                        layers: c.req("layers")?.as_usize().unwrap_or(0),
                        heads: c.req("heads")?.as_usize().unwrap_or(0),
                        kv_heads: c.req("kv_heads")?.as_usize().unwrap_or(0),
                        head_dim: c.req("head_dim")?.as_usize().unwrap_or(0),
                        intermediate: c.req("intermediate")?.as_usize().unwrap_or(0),
                        vocab: c.req("vocab")?.as_usize().unwrap_or(0),
                        max_seq: c.req("max_seq")?.as_usize().unwrap_or(0),
                        rope_theta: c
                            .get("rope_theta")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(10_000.0),
                        rms_eps: c
                            .get("rms_eps")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(1e-6),
                    },
                );
            }
        }

        #[cfg(feature = "pjrt")]
        let runtime = KernelRuntime::Pjrt(PjrtRuntime::cpu()?);
        #[cfg(not(feature = "pjrt"))]
        let runtime = KernelRuntime::Reference(ReferenceRuntime::new());
        Ok(Registry { dir, runtime, kernels, configs })
    }

    pub fn spec(&self, name: &str) -> Result<&KernelSpec> {
        self.kernels
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("kernel '{name}' not in manifest")))
    }

    pub fn config(&self, name: &str) -> Result<&ManifestConfig> {
        self.configs
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("config '{name}' not in manifest")))
    }

    /// Ensure a kernel is compiled/available (no-op if cached).
    pub fn ensure_loaded(&self, name: &str) -> Result<()> {
        if self.runtime.is_loaded(name) {
            return Ok(());
        }
        let spec = self.spec(name)?;
        match &self.runtime {
            KernelRuntime::Reference(r) => {
                r.mark_loaded(&spec.name);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            KernelRuntime::Pjrt(p) => p.load_hlo_text(name, &self.dir.join(&spec.file)),
        }
    }

    /// Eagerly compile every kernel carrying `tag` (e.g. "tiny" at engine
    /// startup, so compilation never lands on the request path).
    pub fn preload(&self, tag: &str) -> Result<usize> {
        let mut names: Vec<&String> = self
            .kernels
            .values()
            .filter(|k| k.tags.iter().any(|t| t == tag))
            .map(|k| &k.name)
            .collect();
        names.sort();
        for name in &names {
            self.ensure_loaded(name)?;
        }
        Ok(names.len())
    }

    /// Execute with spec-based input validation.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<(Vec<Tensor>, u64)> {
        let spec = self.spec(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "kernel {name}: {} inputs given, spec needs {}",
                inputs.len(),
                spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                return Err(Error::Runtime(format!(
                    "kernel {name}: input {i} is {:?}/{}, spec wants {:?}/{}",
                    t.shape,
                    t.dtype(),
                    s.shape,
                    s.dtype
                )));
            }
        }
        self.ensure_loaded(name)?;
        match &self.runtime {
            KernelRuntime::Reference(r) => r.execute(spec, inputs),
            #[cfg(feature = "pjrt")]
            KernelRuntime::Pjrt(p) => p.execute(name, inputs),
        }
    }
}

impl KernelRunner for Registry {
    fn run(
        &self,
        kernel: &str,
        inputs: &[Tensor],
        _out_specs: &[KernelIoSpec],
    ) -> Result<(Vec<Tensor>, u64, f64)> {
        let flops = self.spec(kernel).map(|s| s.flops).unwrap_or(0.0);
        let (outs, ns) = self.execute(kernel, inputs)?;
        Ok((outs, ns, flops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_executes_reference_kernels() {
        let reg = Registry::builtin().unwrap();
        assert_eq!(reg.runtime.platform(), "host-reference");
        let x = Tensor::f32(vec![1, 64], (0..64).map(|i| i as f32 / 64.0).collect()).unwrap();
        let w = Tensor::f32(vec![64], vec![1.0; 64]).unwrap();
        let (outs, ns) = reg.execute("rmsnorm_64", &[x, w]).unwrap();
        assert_eq!(outs[0].shape, vec![1, 64]);
        assert!(ns > 0);
        assert!(reg.runtime.is_loaded("rmsnorm_64"));
    }

    #[test]
    fn preload_marks_tagged_kernels() {
        let reg = Registry::builtin().unwrap();
        let n = reg.preload("tiny").unwrap();
        assert!(n > 20, "only {n} tiny kernels");
        assert_eq!(reg.runtime.loaded_count(), n);
    }

    #[test]
    fn open_at_missing_dir_errors_and_builtin_covers_fallback() {
        // (No env mutation here: set_var races the parallel test harness.)
        assert!(Registry::open_at(PathBuf::from("/nonexistent/for/test")).is_err());
        // The builtin registry open() falls back to has full coverage.
        let reg = Registry::builtin().unwrap();
        assert!(reg.kernels.contains_key("sdpa_tiny"));
        assert!(reg.configs.contains_key("qwen-tiny"));
    }
}

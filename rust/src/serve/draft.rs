//! Device-free n-gram self-drafting for speculative decode.
//!
//! The drafter proposes up to `k` next tokens for a session from nothing
//! but the session's OWN token history (prompt + generated so far): an
//! order-3-then-2 suffix match. If the last 3 tokens have occurred before,
//! the token that followed that occurrence is drafted; otherwise the last
//! 2; otherwise drafting stops. Each drafted token is appended to the
//! working context before drafting the next, so one call can propose a
//! whole k-token continuation of a repeating pattern.
//!
//! Design constraints, in order:
//!
//! - **Zero device work.** Drafting runs on the host between rounds; a
//!   wrong draft costs nothing but the dead verify rows it occupied
//!   (see `ARCHITECTURE.md`'s speculative lifecycle). The dispatch bill —
//!   the paper's dominant batch-1 cost — is paid per verify *round*, so
//!   any acceptance rate > 0 amortizes it across > 1 generated token.
//! - **Deterministic.** Proposals depend only on the history slice, so
//!   speculative scheduling replays byte-identically across runs — the
//!   differential schedule suite relies on this.
//! - **Allocation-light.** The per-call scratch is one Vec sized by the
//!   history plus k; the scan is a plain backward walk (the tiny-config
//!   histories serving benches produce are far too short for an index to
//!   pay off).
//!
//! Greedy decode over a repetitive workload (the bench's cycling prompt)
//! settles into short token cycles, which is exactly the structure an
//! n-gram self-drafter predicts — acceptance >= 0.6 on the repetitive
//! serve-bench workload is the tentpole gate.

/// Highest-order suffix the drafter matches before falling back.
const MAX_ORDER: usize = 3;
/// Lowest-order suffix worth matching: order-1 self-drafting degenerates
/// to "repeat the most recent bigram", which mispredicts far more than it
/// accepts on non-repetitive text and wastes verify rows.
const MIN_ORDER: usize = 2;

/// Propose up to `k` draft tokens continuing `history` (prompt followed by
/// every emitted token, most recent last). Returns fewer than `k` — often
/// zero — when no order-3 or order-2 suffix of the working context has a
/// prior occurrence: an honest "no idea" keeps the verify chunk small
/// instead of burning rows on noise.
pub fn draft_ngram(history: &[usize], k: usize) -> Vec<usize> {
    let mut ctx: Vec<usize> = Vec::with_capacity(history.len() + k);
    ctx.extend_from_slice(history);
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        match next_by_suffix(&ctx) {
            Some(t) => {
                ctx.push(t);
                out.push(t);
            }
            None => break,
        }
    }
    out
}

/// One-step prediction: the token that followed the most recent earlier
/// occurrence of the context's longest matching suffix (order 3 first,
/// then 2).
fn next_by_suffix(ctx: &[usize]) -> Option<usize> {
    for order in (MIN_ORDER..=MAX_ORDER).rev() {
        if ctx.len() < order + 1 {
            continue;
        }
        let suffix = &ctx[ctx.len() - order..];
        // Most recent prior occurrence wins: walk candidate start
        // positions backward, excluding the suffix's own position.
        for start in (0..ctx.len() - order).rev() {
            if &ctx[start..start + order] == suffix {
                return Some(ctx[start + order]);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeating_cycle_is_fully_drafted() {
        // History ends mid-cycle; the drafter should continue the cycle.
        let h = [5, 8, 2, 5, 8, 2, 5, 8];
        assert_eq!(draft_ngram(&h, 4), vec![2, 5, 8, 2]);
    }

    #[test]
    fn order3_wins_over_order2_on_ambiguous_bigrams() {
        // The bigram (1, 2) is followed by 9 early and 7 late; the
        // trigram (0, 1, 2) disambiguates to 9.
        let h = [0, 1, 2, 9, 4, 1, 2, 7, 0, 1, 2];
        assert_eq!(draft_ngram(&h, 1), vec![9]);
    }

    #[test]
    fn order2_fallback_fires_without_a_trigram_match() {
        // No earlier trigram ends (3, 4), but the bigram (3, 4) -> 6.
        let h = [3, 4, 6, 1, 3, 4];
        assert_eq!(draft_ngram(&h, 1), vec![6]);
    }

    #[test]
    fn most_recent_occurrence_wins_within_an_order() {
        // (1, 2) -> 5 early, (1, 2) -> 8 later: recency picks 8. Distinct
        // predecessors (0/9/4) keep every trigram suffix unique so the
        // order-2 path decides.
        let h = [0, 1, 2, 5, 9, 1, 2, 8, 4, 1, 2];
        assert_eq!(draft_ngram(&h, 1), vec![8]);
    }

    #[test]
    fn no_match_drafts_nothing() {
        assert_eq!(draft_ngram(&[1, 2, 3, 4, 5], 4), Vec::<usize>::new());
        assert_eq!(draft_ngram(&[], 4), Vec::<usize>::new());
        assert_eq!(draft_ngram(&[7], 4), Vec::<usize>::new());
        assert_eq!(draft_ngram(&[7, 7], 4), Vec::<usize>::new());
    }

    #[test]
    fn short_cycles_extend_through_drafted_tokens() {
        // After drafting one 7, the working context's suffix (7, 7)
        // matches again — drafted tokens feed later drafts.
        let h = [7, 7, 7];
        assert_eq!(draft_ngram(&h, 3), vec![7, 7, 7]);
    }

    #[test]
    fn k_zero_is_a_no_op() {
        assert_eq!(draft_ngram(&[1, 1, 1, 1], 0), Vec::<usize>::new());
    }

    #[test]
    fn deterministic_across_calls() {
        let h: Vec<usize> = (0..64).map(|i| (i * 5) % 9).collect();
        assert_eq!(draft_ngram(&h, 4), draft_ngram(&h, 4));
    }
}

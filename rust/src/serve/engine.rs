//! The multi-session serving engine.
//!
//! One `ServingEngine` owns the shared substrate — device, prepared
//! pipelines, bind-group layouts, buffer pool, pinned weights — and drives
//! up to `max_concurrent` sessions by interleaving decode steps round-
//! robin. Scheduling is continuous: new requests are admitted from the
//! FIFO backlog between rounds and finished sessions retire immediately,
//! releasing their pooled buffers to the next admit.
//!
//! The scheduler's throughput lever is fixed-cost amortization: every
//! session in a round encodes its decode step (dispatch-phase + framework
//! costs are per-dispatch and do NOT amortize — the paper's per-operation
//! wall), then ALL logits buffers are read back behind one synchronization
//! point (`Device::map_read_many`), so the backend's fixed map/sync cost
//! (~0.1 ms Vulkan, ~1.8 ms Metal per token at N=1) is paid once per round
//! instead of once per session.

use std::collections::{BTreeMap, HashMap};

use crate::engine::inference::EngineConfig;
use crate::engine::GraphExecutor;
use crate::fx::builder::{
    build_batched_decode_graph, build_batched_decode_graph_paged, build_decode_graph,
    build_decode_graph_paged, build_prefill_graph, build_prefill_graph_paged,
    build_unified_round_graph, build_unified_round_graph_multi_row,
    build_unified_round_graph_multi_row_paged, build_unified_round_graph_paged,
    paged_table_len, GraphDims, KV_BLOCKS, MAX_BATCH_WIDTH, PREFILL_CHUNKS,
};
use crate::fx::graph::FxGraph;
use crate::model::weights::ModelWeights;
use crate::plan::{DeviceKvCache, PagedKv, PagedSlot};
use crate::runtime::hostops;
use crate::runtime::registry::Registry;
use crate::tensor::{DType, Tensor};
use crate::webgpu::queue::{bind_buffers, kernel_layout};
use crate::webgpu::{
    BindGroupLayoutId, BufferId, ComputePipelineId, Device, FaultInjector, FaultPlan,
    ShaderModuleDesc,
};
use crate::{Error, Result};

use super::draft::draft_ngram;
use super::metrics::ServeReport;
use super::queue::RequestQueue;
use super::session::{KvCache, SessionSnapshot, SessionState};

/// Consecutive transient faults one session may accumulate before it is
/// abandoned (retired with whatever tokens it committed). Strictly above
/// the largest seeded fault plan (4 triggers), so every seeded schedule
/// recovers; only persistent hand-built plans exhaust it.
const MAX_SESSION_RETRIES: u32 = 6;

/// Bounded in-place retries for a synchronizing readback (the mapped
/// buffers keep their contents across an injected timeout, so the retry
/// re-issues the identical map). Covers a worst-case seeded plan of 4
/// consecutive map timeouts.
const MAX_MAP_RETRIES: u32 = 4;

/// Maximum quarantine backoff, in rounds a faulted session sits out.
const MAX_COOLDOWN: u32 = 2;

/// Serving configuration: the per-session engine config plus admission
/// control.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub engine: EngineConfig,
    /// Maximum sessions decoded concurrently; further requests queue.
    pub max_concurrent: usize,
}

/// Pre-created device-argmax pipeline (Appendix H variant), shared by all
/// sessions.
pub(crate) struct ArgmaxPrepared {
    #[allow(dead_code)] // kept for diagnostics/logging
    kernel: String,
    pipeline: ComputePipelineId,
    layout: BindGroupLayoutId,
}

/// An encoded-but-unfinished decode step: the logits tensor (host copy,
/// chained GPU-side without sync) and the live logits buffer awaiting its
/// synchronizing readback.
pub struct StepHandle {
    pub logits: Tensor,
    pub logits_buf: Option<BufferId>,
}

/// One encoded unit of a scheduler round awaiting the round's single
/// coalesced readback: the live logits buffer plus which sessions read
/// which vocab rows of it. A prefill final chunk and an interleaved decode
/// step own one row (`[1, vocab]`); a batched decode chunk owns one row
/// per packed session (`[W, vocab]`); a speculative verify member of a
/// multi-row unified chunk owns `1 + drafted` consecutive rows of the
/// `[W*C, vocab]` buffer.
struct EncodedChunk {
    buf: BufferId,
    owners: Vec<ChunkOwner>,
}

/// One readback participant of an [`EncodedChunk`].
struct ChunkOwner {
    /// Index into `active`.
    session: usize,
    /// First vocab-row index within the chunk's logits buffer.
    row: usize,
    /// Consecutive rows owned starting at `row` (1 except for speculative
    /// verifies, where it is `1 + drafted.len()`).
    rows: usize,
    /// Speculative verify state; `None` for plain single-token owners.
    spec: Option<SpecOwner>,
}

impl ChunkOwner {
    /// A plain one-row owner (every non-speculative readback).
    fn single(session: usize, row: usize) -> Self {
        ChunkOwner { session, row, rows: 1, spec: None }
    }
}

/// The deferred state a speculative verify needs at demux time: the
/// drafted tokens occupying rows `1..rows` (row 0 re-verifies the
/// committed input token) and the decode position of row 0, which is the
/// rewind base for the accept/rollback arithmetic (`pos = pos0 +
/// accepted_prefix_len`). Rejected rows' KV entries are simply dead: the
/// causal mask keeps later steps from attending past the rewound `pos`,
/// and resumed decoding overwrites them in place.
struct SpecOwner {
    drafted: Vec<usize>,
    pos0: usize,
}

pub struct ServingEngine<'r> {
    pub config: ServeConfig,
    pub dims: GraphDims,
    pub graph: FxGraph,
    /// The shared substrate: device + pipeline/layout/bind-group caches +
    /// buffer pool + pinned weights. Sessions own nothing GPU-side.
    pub executor: GraphExecutor<'r>,
    pub weights: ModelWeights,
    /// FIFO backlog (admission control).
    pub queue: RequestQueue,
    /// Sessions currently being interleaved, in admission order.
    pub active: Vec<SessionState>,
    /// Retired sessions, in completion order.
    pub finished: Vec<SessionState>,
    argmax: Option<ArgmaxPrepared>,
    /// Rotating logits-ring cursor for the public `encode_session` path:
    /// consecutive encodes get distinct ring buffers, so up to
    /// `max_concurrent` sessions can be encode/finish-interleaved through
    /// the public API in planned mode without clobbering a deferred
    /// logits readback. (`step_round` assigns indices by round position.)
    ring_cursor: usize,
    /// The batched decode graph (planned mode with `batch_width >= 2`):
    /// scheduler rounds with >= 2 active sessions replay its compiled plan
    /// — one dispatch per layer op per chunk of `batch_width` sessions —
    /// instead of interleaving per-session replays. `None` disables
    /// batching (eager mode, `--no-batch`, or max_concurrent == 1).
    pub batched_graph: Option<FxGraph>,
    /// Effective batched slot width (0 when batching is disabled).
    pub batch_width: usize,
    /// The chunked-prefill graph (planned mode with `prefill_chunk >= 2`):
    /// sessions still ingesting their prompt replay its compiled plan —
    /// one dispatch per layer op per chunk of up to `prefill_chunk`
    /// prompt tokens — instead of one decode step per prompt token.
    /// `None` disables chunking (eager mode, `--prefill-chunk 0`, or the
    /// device-argmax finish variant).
    pub prefill_graph: Option<FxGraph>,
    /// Effective prefill chunk size (0 when chunking is disabled).
    pub prefill_chunk: usize,
    /// The unified round graph (planned mode with `batch_width >= 2` AND
    /// `prefill_chunk >= 2` AND `unified` on, the serving default): EVERY
    /// scheduler round replays its compiled `[W*C, H]` seq-x-batch plan —
    /// prefill chunks and decode steps occupy slots of the SAME replay
    /// (decode = a `valid_len = 1` chunk), so a mixed round of prompts and
    /// generations is one dispatch per layer op. `None` falls back to the
    /// split scheduling (prefill rounds, then batched decode rounds).
    pub unified_graph: Option<FxGraph>,
    /// Speculative decode depth: up to `speculate` n-gram-drafted tokens
    /// per decode session are verified in ONE unified chunk replay
    /// (row 0 = the committed input, rows `1..=k` = the draft), with
    /// host-side greedy accept/rollback at readback. Engages only on the
    /// unified path and is clamped to `prefill_chunk - 1` (the draft must
    /// fit one chunk alongside its committed row). 0 = off.
    pub speculate: usize,
    /// Scheduler rounds completed (any path) — the denominator of the
    /// `dispatches_per_round` serving metric.
    pub rounds: u64,
    /// Transient-fault recoveries performed engine-wide: quarantined
    /// chunks, re-issued readbacks, and retried admissions.
    pub retries: u64,
    /// Retired sessions that survived >= 1 transient fault.
    pub recovered_sessions: u64,
    /// Sessions abandoned after exhausting their retry budget.
    pub failed_sessions: u64,
    /// Seed of the installed fault plan (`None` = no injection).
    pub fault_seed: Option<u64>,
    /// Paged-KV block size in tokens (0 = contiguous per-session cache
    /// sets, the pre-paging layout). When nonzero, every graph above was
    /// built with block-table indirection and sessions hold
    /// [`KvCache::Paged`] block tables instead of `DeviceKvCache` sets.
    pub kv_block: usize,
    /// Monotone LRU clock for the per-block pager: each residency
    /// pre-pass stamps the blocks it touches, and eviction victims are
    /// chosen oldest-stamp-first.
    pager_clock: u64,
    /// High-water mark of simultaneously KV-resident sessions (any block
    /// on device) — the session-density metric the paged layout exists
    /// to raise.
    pub resident_sessions_hw: usize,
}

impl<'r> ServingEngine<'r> {
    pub fn new(registry: &'r Registry, config: ServeConfig) -> Result<Self> {
        let ec = &config.engine;
        let mc = registry.config(&ec.model)?;
        let dims = ec.dims_override.unwrap_or_else(|| GraphDims::from_manifest(mc));
        // Paged KV residency engages only for planned execution: eager
        // mode interprets ops against host tensors and keeps the
        // contiguous layout (the paper's measurable baseline), and the
        // device-argmax finish variant predates session caches entirely.
        // When on, EVERY graph below is built with block-table
        // indirection — mixing paged and contiguous plans over one
        // executor would need two persistent layouts.
        let kv_block = if ec.paged
            && ec.exec == crate::engine::ExecMode::Planned
            && !ec.device_argmax
        {
            if !KV_BLOCKS.contains(&ec.kv_block) {
                return Err(Error::Graph(format!(
                    "kv block {} has no built-in kernel coverage (choose one \
                     of {KV_BLOCKS:?}, or --no-paged)",
                    ec.kv_block
                )));
            }
            if dims.max_seq % ec.kv_block != 0 {
                return Err(Error::Graph(format!(
                    "kv block {} does not divide the {} KV capacity rows",
                    ec.kv_block, dims.max_seq
                )));
            }
            ec.kv_block
        } else {
            0
        };
        let graph = if kv_block > 0 {
            build_decode_graph_paged(&dims, ec.fusion)
        } else {
            build_decode_graph(&dims, ec.fusion)
        };
        graph.validate()?;
        // Batched decode engages only for planned multi-session serving:
        // eager mode, single-session engines, and the device-argmax finish
        // variant (whose per-session argmax dispatch expects single-row
        // logits) keep the exact pre-batching paths — the paper's batch=1
        // pathology stays measurable, and nothing compiles a plan it will
        // never replay (or mislabels its report as batched).
        let batch_width = if ec.exec == crate::engine::ExecMode::Planned
            && config.max_concurrent >= 2
            && ec.batch_width >= 2
            && !ec.device_argmax
        {
            // Validate the REQUESTED width, before the max_concurrent
            // clamp: the same --batch-width must be accepted or rejected
            // independently of --concurrent.
            if ec.batch_width > MAX_BATCH_WIDTH {
                return Err(Error::Graph(format!(
                    "batch width {} exceeds built-in kernel coverage \
                     (<= {MAX_BATCH_WIDTH}); pass --no-batch or a smaller --batch-width",
                    ec.batch_width
                )));
            }
            ec.batch_width.min(config.max_concurrent)
        } else {
            0
        };
        let mut device = Device::new(ec.profile.clone());
        device.kernel_time_policy = ec.kernel_time_policy;
        // Install the span tracer before any instrumented path runs. The
        // tracer only READS the virtual clock — it never advances it and
        // never draws jitter — so Null/Ring/Chrome sinks produce
        // bit-identical token and KV streams.
        device.trace = crate::trace::Tracer::new(&ec.trace);
        if batch_width >= 2 {
            // The batched cache ops bind 2W per-slot cache buffers plus q
            // and 3 per-slot uniforms in one group — above the 8-binding
            // WebGPU default. Request raised limits up front, the
            // requestDevice({requiredLimits}) pattern real WebGPU engines
            // use (desktop adapters expose far higher storage-buffer
            // counts than the spec floor). The unified sdpa binds one more
            // uniform (pos_base + valid_len + slot_mask + slot_idx).
            let unified_eligible = ec.unified && ec.prefill_chunk >= 2;
            let need = 2 * batch_width + if unified_eligible { 6 } else { 5 };
            if device.limits.max_bindings_per_group < need {
                device.limits.max_bindings_per_group = need;
            }
        }
        let mut executor = GraphExecutor::new(device, registry, ec.framework_ns_per_op);
        // Under paging the byte cap governs KV residency (a block-group
        // budget on the shared pool, below) rather than the activation
        // pool: the planes are raw device buffers outside the BufferPool,
        // and capping activations at a KV-sized budget would starve the
        // plan arena the cap was never meant to bound.
        if kv_block == 0 {
            executor.pool.set_cap(ec.pool_cap_bytes);
        }
        executor.prepare(&graph)?;

        let argmax = if ec.device_argmax {
            let name = format!("argmax_{}", dims.vocab);
            registry.ensure_loaded(&name)?;
            let spec = registry.spec(&name)?;
            let layout = kernel_layout(&mut executor.device, &name, 1, 1)?;
            let module = executor.device.create_shader_module(ShaderModuleDesc {
                label: name.clone(),
                kernel: name.clone(),
                inputs: spec.inputs.clone(),
                outputs: spec.outputs.clone(),
            })?;
            let pipeline = executor.device.create_compute_pipeline(&name, module, layout)?;
            Some(ArgmaxPrepared { kernel: name, pipeline, layout })
        } else {
            None
        };

        let weights = ModelWeights::synthesize(&dims, ec.weight_seed);
        // PERF (§Perf L3): weights live in persistent device buffers —
        // uploaded once here, bound directly on every dispatch, shared by
        // every session.
        executor.pin_inputs(&graph, &weights.by_name)?;

        if ec.exec == crate::engine::ExecMode::Planned {
            // Compile-once plan, shared by every session. The logits ring
            // must cover one scheduler round (sessions replay before the
            // round's coalesced readback). Build cost is tracked on the
            // runner, separate from replay cost.
            executor.enable_plan(
                &graph,
                crate::plan::PlanConfig {
                    dispatches_per_submit: ec.dispatches_per_submit.max(1),
                    framework_ns_per_step: ec.planned_framework_ns_per_step,
                    logits_ring: config.max_concurrent.max(1),
                },
            )?;
        }

        // Shared block pool behind every paged plan: MAX_BATCH_WIDTH x
        // max_seq rows per K/V plane per layer, carved into
        // `max_seq / kv_block`-row groups handed out by a BlockArena.
        // `--pool-cap-kv` translates to a group budget at the SAME byte
        // cap the contiguous layout would spend on whole cache sets, so
        // paged-vs-contiguous density comparisons are equal-cap. The
        // budget is a soft LRU watermark (the pager spills past it);
        // physical pool rows are the hard wall.
        if kv_block > 0 {
            let group_bytes = 2 * dims.layers * kv_block * dims.kv_heads * dims.head_dim * 4;
            let budget_groups = match ec.pool_cap_bytes {
                Some(cap) => (cap / group_bytes).max(1),
                None => usize::MAX,
            };
            executor.enable_paged_pool(kv_block, budget_groups)?;
        }

        // Batched plan alongside the single-session one: rounds with >= 2
        // active sessions replay this graph once per chunk of batch_width
        // sessions; 1-active rounds (and the public encode/finish API) keep
        // the single-session path byte-for-byte. Weight bindings reuse the
        // buffers pinned above (matched by name) — one copy serves both
        // plans. The logits ring covers one whole round's chunks
        // (ceil(max_concurrent / width)), so every chunk's [W, vocab] row
        // block survives until the round's ONE coalesced readback — the
        // same fixed-sync amortization the interleaved path has.
        let batched_graph = if batch_width >= 2 {
            let bg = if kv_block > 0 {
                build_batched_decode_graph_paged(&dims, ec.fusion, batch_width)
            } else {
                build_batched_decode_graph(&dims, ec.fusion, batch_width)
            };
            bg.validate()?;
            let chunks_per_round =
                (config.max_concurrent + batch_width - 1) / batch_width;
            executor.enable_batched_plan(
                &bg,
                crate::plan::PlanConfig {
                    dispatches_per_submit: ec.dispatches_per_submit.max(1),
                    framework_ns_per_step: ec.planned_framework_ns_per_step,
                    logits_ring: chunks_per_round.max(1),
                },
                batch_width,
            )?;
            Some(bg)
        } else {
            None
        };

        // Chunked-prefill plan alongside the decode plans: sessions still
        // ingesting their prompt replay it once per round (one dispatch
        // per layer op per chunk of `prefill_chunk` prompt tokens) and
        // only FINAL chunks join the round's coalesced readback. Gated
        // like batching: planned mode only (eager keeps the paper's
        // per-token prompt pathology measurable) and not under
        // device-argmax (whose finish path owns its own readback). Its
        // persistent layout matches the decode plan's, so one session
        // cache set serves prefill chunks and decode replays alike.
        let prefill_chunk = if ec.exec == crate::engine::ExecMode::Planned
            && ec.prefill_chunk >= 2
            && !ec.device_argmax
        {
            if !PREFILL_CHUNKS.contains(&ec.prefill_chunk) {
                return Err(Error::Graph(format!(
                    "prefill chunk {} has no built-in kernel coverage (choose one \
                     of {PREFILL_CHUNKS:?}, or 0 to disable chunked prefill)",
                    ec.prefill_chunk
                )));
            }
            ec.prefill_chunk
        } else {
            0
        };
        let prefill_graph = if prefill_chunk >= 2 {
            let pg = if kv_block > 0 {
                build_prefill_graph_paged(&dims, ec.fusion, prefill_chunk)
            } else {
                build_prefill_graph(&dims, ec.fusion, prefill_chunk)
            };
            pg.validate()?;
            executor.enable_prefill_plan(
                &pg,
                crate::plan::PlanConfig {
                    dispatches_per_submit: ec.dispatches_per_submit.max(1),
                    framework_ns_per_step: ec.planned_framework_ns_per_step,
                    // Every prefill session of one round replays before
                    // the round's single readback.
                    logits_ring: config.max_concurrent.max(1),
                },
                prefill_chunk,
            )?;
            Some(pg)
        } else {
            None
        };

        // Unified continuous-batching plan on top of both: when the
        // batched AND chunked-prefill paths are in effect (and `unified`
        // is not turned off), EVERY round replays the `[W*C, H]`
        // seq-x-batch graph instead — prefill chunks and decode steps
        // share one dispatch per layer op, so prompts arriving mid-run no
        // longer cost a separate prefill round. The persistent layout is
        // the batched plan's slot-major cache-set table (checked at
        // enable time), so the same sticky slots and session cache sets
        // serve all three plans. The logits ring covers one round's
        // chunks-of-slots, exactly like the batched ring.
        // Speculative decode rides the unified path exclusively: the
        // draft rows ARE seq-dim chunk rows, so verifying k tokens reuses
        // the prefill machinery (scatter at pos_base.., causal mask over
        // valid_len rows) with a multi-row logits tail. Clamped so the
        // committed token + draft fit one chunk.
        let speculate = if batch_width >= 2 && prefill_chunk >= 2 && ec.unified {
            ec.speculate.min(prefill_chunk - 1)
        } else {
            0
        };
        let unified_graph = if batch_width >= 2 && prefill_chunk >= 2 && ec.unified {
            let ug = match (speculate >= 1, kv_block > 0) {
                // Multi-row tail: logits for EVERY valid row (`[W*C,
                // vocab]`), so a verify chunk reads all k+1 next-token
                // distributions from one replay. Same dispatch count —
                // the three tail kernels swap 1-for-1.
                (true, true) => build_unified_round_graph_multi_row_paged(
                    &dims,
                    ec.fusion,
                    batch_width,
                    prefill_chunk,
                ),
                (true, false) => build_unified_round_graph_multi_row(
                    &dims,
                    ec.fusion,
                    batch_width,
                    prefill_chunk,
                ),
                (false, true) => {
                    build_unified_round_graph_paged(&dims, ec.fusion, batch_width, prefill_chunk)
                }
                (false, false) => {
                    build_unified_round_graph(&dims, ec.fusion, batch_width, prefill_chunk)
                }
            };
            ug.validate()?;
            let chunks_per_round =
                (config.max_concurrent + batch_width - 1) / batch_width;
            executor.enable_unified_plan(
                &ug,
                crate::plan::PlanConfig {
                    dispatches_per_submit: ec.dispatches_per_submit.max(1),
                    framework_ns_per_step: ec.planned_framework_ns_per_step,
                    logits_ring: chunks_per_round.max(1),
                },
                batch_width,
                prefill_chunk,
            )?;
            Some(ug)
        } else {
            None
        };

        // Arm fault injection LAST: construction-time allocations (plan
        // arenas, pinned weights, logits rings) never fault, so every
        // injected opportunity lands in steady-state serving — the
        // reproducible-in-CI failure modes the recovery layer handles.
        if let Some(seed) = ec.fault_seed {
            executor
                .device
                .install_fault_injector(FaultInjector::new(FaultPlan::seeded(seed)));
        }

        Ok(ServingEngine {
            config,
            dims,
            graph,
            executor,
            weights,
            queue: RequestQueue::new(),
            active: Vec::new(),
            finished: Vec::new(),
            argmax,
            ring_cursor: 0,
            batched_graph,
            batch_width,
            prefill_graph,
            prefill_chunk,
            unified_graph,
            speculate,
            rounds: 0,
            retries: 0,
            recovered_sessions: 0,
            failed_sessions: 0,
            fault_seed: ec.fault_seed,
            kv_block,
            pager_clock: 0,
            resident_sessions_hw: 0,
        })
    }

    /// Install a hand-built fault plan (tests pin exact fault kind x
    /// phase matrices this way; `EngineConfig::fault_seed` covers the
    /// randomized differential arm).
    pub fn install_fault_plan(&mut self, plan: FaultPlan) {
        self.executor.device.install_fault_injector(FaultInjector::new(plan));
    }

    /// Reseed the virtual-cost jitter (independent benchmark runs).
    pub fn reseed(&mut self, seed: u64) {
        self.executor.device.reseed_jitter(seed);
    }

    /// Current virtual time.
    pub fn now_ns(&self) -> u64 {
        self.executor.device.clock.now_ns()
    }

    /// Enqueue a request. Never rejects for capacity — requests beyond
    /// `max_concurrent` wait in the FIFO backlog.
    pub fn submit(&mut self, prompt: &[usize], n_new: usize) -> Result<u64> {
        if prompt.is_empty() || n_new == 0 {
            return Err(Error::Graph("prompt and n_new must be non-empty".into()));
        }
        let steps = prompt.len() + n_new - 1;
        if steps > self.dims.max_seq {
            return Err(Error::Graph(format!(
                "request needs {steps} decode steps but KV capacity is {}",
                self.dims.max_seq
            )));
        }
        let now = self.now_ns();
        Ok(self.queue.push(prompt.to_vec(), n_new, now))
    }

    /// Lowest decode-slot index not held by an active session. Sticky
    /// slot assignment: a session pins its slot at admission and frees it
    /// only on retire, so ragged retirement never reshuffles the
    /// surviving sessions' rows in the batched cache-set table — and a
    /// replacement admission (which the pool hands the retiree's recycled
    /// buffer set) lands in the retiree's slot, keeping the table's
    /// bind-group key identical across churn.
    fn lowest_free_slot(&self) -> usize {
        let mut used = vec![false; self.config.max_concurrent.max(1)];
        for s in &self.active {
            if let Some(j) = s.slot {
                if j < used.len() {
                    used[j] = true;
                }
            }
        }
        used.iter().position(|&u| !u).unwrap_or(self.active.len())
    }

    /// Admit queued requests (FIFO) up to `max_concurrent`. Admission is
    /// cache-aware in planned mode: each admitted session claims its
    /// device-resident cache set up front, and when the bounded pool
    /// cannot back another set the request stays queued (deferred to a
    /// later round, when a retiring session returns its set) instead of
    /// poisoning the run mid-encode. If not even ONE session can be
    /// backed, the capacity error surfaces — otherwise the scheduler
    /// would spin forever on an unadmittable queue.
    pub fn admit(&mut self) -> Result<()> {
        while self.active.len() < self.config.max_concurrent && !self.queue.is_empty() {
            let slot = self.lowest_free_slot();
            // Paged mode never allocates at admission: sessions start with
            // an empty block table and the residency pre-pass grows it on
            // demand, paging colder blocks to the host under pressure.
            // Admission therefore DEFERS AND PAGES, NEVER FAILS — the
            // oversubscription contract the block pool exists to provide.
            let cache = if self.kv_block > 0 {
                None
            } else if self.executor.is_planned() {
                match self.executor.alloc_kv_cache() {
                    Ok(c) => Some(c),
                    // Transient pressure while sessions are running defers
                    // the admission (a retiring session will return its
                    // set, or the one-shot fault clears). Deferral never
                    // changes token streams — scheduling only shifts which
                    // round a session starts in.
                    Err(e) if e.is_transient() && !self.active.is_empty() => break,
                    // Genuine capacity with nothing running to free a set
                    // must surface — otherwise the scheduler would spin
                    // forever on an unadmittable queue.
                    Err(e @ Error::LimitExceeded(_)) => return Err(e),
                    // An injected one-shot allocation fault on an idle
                    // engine: the trigger is consumed, so one inline
                    // retry is exact recovery.
                    Err(e) if e.is_transient() => {
                        self.retries += 1;
                        Some(self.executor.alloc_kv_cache()?)
                    }
                    Err(e) => return Err(e),
                }
            } else {
                None
            };
            let req = self.queue.pop().ok_or_else(|| {
                Error::Internal("admission raced an empty queue".into())
            })?;
            let now = self.executor.device.clock.now_ns();
            let mut s = SessionState::new(
                req.id,
                req.prompt,
                req.n_new,
                &self.dims,
                req.enqueued_ns,
                now,
            );
            if let Some(c) = cache {
                s.kv = KvCache::Device(c);
            } else if self.kv_block > 0 {
                s.kv = KvCache::Paged(PagedKv::default());
            }
            s.slot = Some(slot);
            self.active.push(s);
        }
        Ok(())
    }

    /// Build a detached session (used by the single-request `Engine`
    /// wrapper, which owns its session instead of enrolling it).
    pub fn create_session(&self, prompt: Vec<usize>, n_new: usize, id: u64) -> SessionState {
        let now = self.executor.device.clock.now_ns();
        SessionState::new(id, prompt, n_new, &self.dims, now, now)
    }

    /// Encode one decode step for `s`: host embedding gather, then the full
    /// per-kernel dispatch stream through the shared executor. Does NOT
    /// synchronize — the logits buffer stays live in the returned handle.
    /// Reserve the next logits-ring index. Every encode path (public
    /// `encode_session` and `step_round`) draws from this one rotating
    /// cursor, so any window of up to `max_concurrent` consecutive
    /// encodes — however the caller mixes the two paths — gets distinct
    /// ring buffers for its deferred readbacks.
    fn next_ring(&mut self) -> usize {
        let ring = self.ring_cursor;
        self.ring_cursor = (ring + 1) % self.config.max_concurrent.max(1);
        ring
    }

    pub fn encode_session(
        &mut self,
        s: &mut SessionState,
        token: usize,
        was_prompt: bool,
    ) -> Result<StepHandle> {
        let ring = self.next_ring();
        let ServingEngine { executor, graph, dims, weights, pager_clock, kv_block, .. } =
            self;
        if *kv_block > 0 {
            // Detached sessions page against themselves only: the
            // single-request wrapper owns its session, so cross-session
            // LRU has no victims to consider.
            Self::ensure_resident(
                executor,
                std::slice::from_mut(s),
                dims,
                &[(0, (s.pos + 1).min(dims.max_seq))],
                pager_clock,
            )?;
        }
        Self::encode_inner(executor, graph, dims, weights, s, token, was_prompt, ring)
    }

    /// Finish one session's step on its own: one synchronizing readback
    /// (or the device-argmax dispatch), token selection, metrics.
    pub fn finish_session(&mut self, s: &mut SessionState, h: StepHandle) -> Result<usize> {
        let ServingEngine { executor, argmax, retries, .. } = self;
        Self::finish_inner(executor, argmax.as_ref(), s, h, retries)
    }

    /// Bounded in-place retry for a synchronizing readback. An injected
    /// map timeout leaves the mapped buffers' contents intact (nothing was
    /// consumed), so re-issuing the identical map is safe and yields
    /// identical bytes — the retry is invisible to the token stream.
    fn map_read_retry(
        device: &mut Device,
        bufs: &[BufferId],
        retries: &mut u64,
    ) -> Result<Vec<Vec<u8>>> {
        let mut attempt = 0u32;
        loop {
            match device.map_read_many(bufs) {
                Ok(b) => return Ok(b),
                Err(e) if e.is_transient() && attempt < MAX_MAP_RETRIES => {
                    attempt += 1;
                    *retries += 1;
                    let ts = device.clock.now_ns();
                    device.trace.instant(
                        crate::trace::names::RETRY,
                        crate::trace::TRACK_ENGINE,
                        ts,
                        u64::from(attempt),
                    );
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Promote a planned session to device residency (first encode or
    /// after an evict): allocate a session-owned cache set from the
    /// bounded pool; hydrate spilled host state when resuming
    /// mid-generation. One-time per-session cost, off the token loop.
    /// No-op for already-device-resident sessions. Shared by the
    /// single-session encode path and the batched round packer.
    fn promote_to_device(executor: &mut GraphExecutor<'r>, s: &mut SessionState) -> Result<()> {
        if s.kv.is_device() || s.kv.is_paged() {
            // Paged sessions are made resident block-by-block by the
            // pager pre-pass, never by whole-cache promotion.
            return Ok(());
        }
        let cache = executor.alloc_kv_cache()?;
        if s.pos > 0 {
            // Layer-major [K, V] flattening matches the plan's persistent
            // declaration order. References only — the host state is
            // uploaded, not copied.
            let res = match s.kv.as_host() {
                Some(host) => {
                    let tensors: Vec<&Tensor> =
                        host.iter().flat_map(|(k, v)| [k, v]).collect();
                    executor.hydrate_kv_cache(&cache, &tensors)
                }
                None => Err(Error::Graph(
                    "non-device KV cache must be host-resident".into(),
                )),
            };
            if let Err(e) = res {
                // A failed resume must not strand the freshly claimed
                // set (the hydrate error is the one worth surfacing).
                let _ = executor.release_kv_cache(cache);
                return Err(e);
            }
        }
        s.kv = KvCache::Device(cache);
        Ok(())
    }

    // ---------------------------------------------- paged KV residency ----

    /// Logical KV blocks covering `rows` session rows at block size `b`.
    fn blocks_for(rows: usize, b: usize) -> usize {
        (rows + b - 1) / b
    }

    /// Convert a session's whole-cache host state (a contiguous spill, or
    /// the empty placeholder a fresh session is born with) into the paged
    /// representation: per-block host slots holding the plane-major
    /// `[l0.k, l0.v, l1.k, l1.v, ...]` group image, blocks
    /// `0..blocks_for(kv_hw)`. No-op for sessions already paged.
    fn promote_to_paged(s: &mut SessionState, dims: &GraphDims, b: usize) -> Result<()> {
        if s.kv.is_paged() {
            return Ok(());
        }
        let Some(host) = s.kv.as_host() else {
            return Err(Error::Internal(format!(
                "paged mode: session {} holds a contiguous device cache",
                s.id
            )));
        };
        if host.is_empty() {
            if s.kv_hw > 0 {
                return Err(Error::Graph(format!(
                    "session {} lost its cache state mid-generation (pos {})",
                    s.id, s.pos
                )));
            }
            s.kv = KvCache::Paged(PagedKv::default());
            return Ok(());
        }
        let row_bytes = dims.kv_heads * dims.head_dim * 4;
        let slice = b * row_bytes;
        let nb = Self::blocks_for(s.kv_hw, b);
        let mut slots = Vec::with_capacity(nb);
        for j in 0..nb {
            let mut img = Vec::with_capacity(2 * dims.layers * slice);
            for (k, v) in host {
                for t in [k, v] {
                    let bytes = t.data.as_bytes();
                    img.extend_from_slice(&bytes[j * slice..(j + 1) * slice]);
                }
            }
            slots.push(PagedSlot::Host(img));
        }
        s.kv = KvCache::Paged(PagedKv { slots, last_touch: 0 });
        Ok(())
    }

    /// Serialize a session's block table for upload: `Resident(g) -> g`,
    /// spilled/unallocated -> `-1`. Always `stride` entries — the fixed
    /// `paged_table_len` layout every paged kernel indexes into.
    fn table_entries(pk: &PagedKv, stride: usize) -> Vec<i32> {
        let mut t = vec![-1i32; stride];
        for (j, slot) in pk.slots.iter().enumerate().take(stride) {
            if let PagedSlot::Resident(g) = slot {
                t[j] = *g as i32;
            }
        }
        t
    }

    /// How many of `active` hold device-resident KV state right now (a
    /// contiguous set, or >= 1 resident block) — the density the paged
    /// high-water mark tracks.
    fn count_resident(active: &[SessionState]) -> usize {
        active
            .iter()
            .filter(|s| {
                s.kv.is_device()
                    || s.kv.as_paged().map_or(false, |p| p.resident_groups() > 0)
            })
            .count()
    }

    /// The per-block pager (Phase A of every paged encode path): runs
    /// BEFORE a chunk packs its inputs and guarantees that every block a
    /// member's replay will touch — all blocks covering rows
    /// `[0, rows_end)` — is resident in the shared pool planes. Under
    /// pressure it pages the coldest non-member blocks out to host (LRU
    /// by pager stamp, ties by session id then LOWEST block index, so
    /// cold prompt-prefix blocks park before hot tails), honoring the
    /// logical group budget when candidates exist and physical capacity
    /// always. ONE coalesced readback covers all of a pass's page-outs.
    ///
    /// `sessions` is the victim universe as well as the member store:
    /// round paths pass the whole active set; the detached single-session
    /// path passes just that session (it can only evict itself).
    fn ensure_resident(
        executor: &mut GraphExecutor<'r>,
        sessions: &mut [SessionState],
        dims: &GraphDims,
        members: &[(usize, usize)],
        pager_clock: &mut u64,
    ) -> Result<()> {
        let Some(pool) = executor.paged_pool() else {
            return Err(Error::Internal("paged session without a paged pool".into()));
        };
        let b = pool.kv_block;
        let capacity = pool.arena.capacity();
        let budget = pool.arena.budget_groups();
        let live = pool.arena.live_groups();
        *pager_clock += 1;
        let stamp = *pager_clock;

        // Member needs: promote spilled members to the paged
        // representation, stamp them hot, count the groups to grant.
        let mut needed = 0usize;
        for &(i, rows_end) in members {
            let s = &mut sessions[i];
            Self::promote_to_paged(s, dims, b)?;
            let pk = s.kv.as_paged_mut().ok_or_else(|| {
                Error::Internal(format!("session {} failed paged promotion", s.id))
            })?;
            pk.last_touch = stamp;
            let nb = Self::blocks_for(rows_end, b);
            for j in 0..nb {
                match pk.slots.get(j) {
                    Some(PagedSlot::Resident(_)) => {}
                    _ => needed += 1,
                }
            }
            s.metrics.kv_blocks_hw = s.metrics.kv_blocks_hw.max(nb as u64);
        }

        // Eviction target: enough to fit physically (hard), plus enough
        // to respect the logical budget (soft — if every resident block
        // belongs to this chunk's members, we run over budget rather
        // than evict what the replay is about to touch).
        let phys_short = needed.saturating_sub(capacity - live);
        let over_budget = (live + needed).saturating_sub(budget);
        let want_evict = phys_short.max(over_budget);
        if want_evict > 0 {
            // Candidates: every resident block EXCEPT the members' needed
            // prefixes (blocks beyond a member's rows_end are evictable —
            // conservative speculative over-allocation from earlier
            // rounds can be reclaimed).
            let mut cands: Vec<(u64, u64, usize, usize, u32)> = Vec::new();
            for (i, s) in sessions.iter().enumerate() {
                let Some(pk) = s.kv.as_paged() else { continue };
                let prot = members
                    .iter()
                    .find(|&&(m, _)| m == i)
                    .map(|&(_, rows_end)| Self::blocks_for(rows_end, b))
                    .unwrap_or(0);
                for (j, slot) in pk.slots.iter().enumerate() {
                    if j < prot {
                        continue;
                    }
                    if let PagedSlot::Resident(g) = slot {
                        cands.push((pk.last_touch, s.id, j, i, *g));
                    }
                }
            }
            cands.sort_unstable();
            cands.truncate(want_evict);
            if cands.len() < phys_short {
                return Err(Error::LimitExceeded(format!(
                    "paged KV pool cannot fit this round: {needed} blocks needed, \
                     {} free, {} evictable",
                    capacity - live,
                    cands.len()
                )));
            }
            // ONE coalesced readback for the whole pass's page-outs. An
            // empty victim list (everything resident belongs to this
            // chunk) means the budget is soft-exceeded: proceed.
            let groups: Vec<u32> = cands.iter().map(|&(.., g)| g).collect();
            let images = if groups.is_empty() {
                Vec::new()
            } else {
                executor.read_paged_groups(&groups)?
            };
            for (&(_, _, j, i, g), img) in cands.iter().zip(images) {
                let s = &mut sessions[i];
                let pk = s.kv.as_paged_mut().ok_or_else(|| {
                    Error::Internal("pager victim lost its paged state".into())
                })?;
                pk.slots[j] = PagedSlot::Host(img);
                s.metrics.kv_blocks_spilled_hw =
                    s.metrics.kv_blocks_spilled_hw.max(pk.spilled_groups() as u64);
                let pool = executor.paged_pool_mut().ok_or_else(|| {
                    Error::Internal("paged pool vanished mid-pass".into())
                })?;
                pool.arena.free_group(g);
                pool.arena.note_page_out();
            }
            if !groups.is_empty() {
                let ts = executor.device.clock.now_ns();
                executor.device.trace.instant(
                    crate::trace::names::PAGE_OUT,
                    crate::trace::TRACK_PAGER,
                    ts,
                    groups.len() as u64,
                );
            }
        }

        // Grant + hydrate the members' missing blocks, in block order.
        for &(i, rows_end) in members {
            let nb = Self::blocks_for(rows_end, b);
            for j in 0..nb {
                let hydrate = match sessions[i].kv.as_paged().and_then(|pk| pk.slots.get(j))
                {
                    Some(PagedSlot::Resident(_)) => continue,
                    Some(PagedSlot::Host(_)) => true,
                    None => false,
                };
                let g = executor
                    .paged_pool_mut()
                    .ok_or_else(|| Error::Internal("paged pool vanished mid-pass".into()))?
                    .arena
                    .alloc()?;
                let pk = sessions[i].kv.as_paged_mut().ok_or_else(|| {
                    Error::Internal("pager member lost its paged state".into())
                })?;
                if hydrate {
                    let PagedSlot::Host(bytes) =
                        std::mem::replace(&mut pk.slots[j], PagedSlot::Resident(g))
                    else {
                        return Err(Error::Internal(
                            "paged slot changed kind mid-hydration".into(),
                        ));
                    };
                    if let Err(e) = executor.write_paged_group(g, &bytes) {
                        // Roll the slot back so a transient upload fault
                        // quarantines with the context intact on host.
                        let pk = sessions[i].kv.as_paged_mut().ok_or_else(|| {
                            Error::Internal("pager member lost its paged state".into())
                        })?;
                        pk.slots[j] = PagedSlot::Host(bytes);
                        if let Some(pool) = executor.paged_pool_mut() {
                            pool.arena.free_group(g);
                        }
                        return Err(e);
                    }
                    let pool = executor.paged_pool_mut().ok_or_else(|| {
                        Error::Internal("paged pool vanished mid-pass".into())
                    })?;
                    pool.arena.note_page_in();
                    let ts = executor.device.clock.now_ns();
                    executor.device.trace.instant(
                        crate::trace::names::PAGE_IN,
                        crate::trace::TRACK_PAGER,
                        ts,
                        1,
                    );
                } else {
                    // Fresh block: the replay's cache_update scatter writes
                    // it; no upload. Slots grow densely from the left.
                    debug_assert_eq!(j, pk.slots.len());
                    pk.slots.push(PagedSlot::Resident(g));
                }
            }
        }
        Ok(())
    }

    /// Run the pager for a round chunk's members (`(active index,
    /// rows_end)` pairs) and attribute its traffic — page-out readbacks,
    /// page-in uploads, timeline deltas — evenly across those members
    /// (remainder to the first), mirroring the chunk-cost split: victims
    /// pay nothing, because their parking is the members' pressure. Also
    /// advances the resident-density high-water mark. No-op in
    /// contiguous mode.
    fn pager_pass(&mut self, members: &[(usize, usize)]) -> Result<()> {
        if self.kv_block == 0 || members.is_empty() {
            return Ok(());
        }
        let ph0 = self.executor.device.timeline.virtual_ns;
        let k0 = self.executor.device.timeline.kernel_virtual_ns;
        let sy0 = self.executor.device.timeline.sync_virtual_ns;
        let fw0 = self.executor.framework_virtual_ns;
        let w0 = self.executor.device.stats.bytes_written;
        let c0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::PAGER,
            crate::trace::TRACK_PAGER,
            c0,
        );
        let res = {
            let ServingEngine { executor, active, dims, pager_clock, .. } = &mut *self;
            Self::ensure_resident(executor, active, dims, members, pager_clock)
        };
        // End the PAGER span on BOTH paths so a fault mid-pass leaves the
        // trace balanced.
        let c1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::PAGER,
            crate::trace::TRACK_PAGER,
            c1,
        );
        res?;
        let tl = self.executor.device.timeline.virtual_ns;
        let kernel_d = self.executor.device.timeline.kernel_virtual_ns - k0;
        let sync_d = self.executor.device.timeline.sync_virtual_ns - sy0;
        let fw_d = self.executor.framework_virtual_ns - fw0;
        let upload_d = self.executor.device.stats.bytes_written - w0;
        let encode_d = self.executor.device.clock.now_ns() - c0;
        let k = members.len() as u64;
        let rot = self.rounds;
        for (j, &(i, _)) in members.iter().enumerate() {
            let s = &mut self.active[i];
            for p in 0..8 {
                s.metrics.phase_virtual_ns[p] += share(tl[p] - ph0[p], k, j, rot);
            }
            s.metrics.kernel_virtual_ns += share(kernel_d, k, j, rot);
            s.metrics.sync_virtual_ns += share(sync_d, k, j, rot);
            s.metrics.framework_virtual_ns += share(fw_d, k, j, rot);
            s.metrics.upload_bytes += share(upload_d, k, j, rot);
            s.metrics.encode_virtual_ns += share(encode_d, k, j, rot);
        }
        let resident = Self::count_resident(&self.active);
        self.resident_sessions_hw = self.resident_sessions_hw.max(resident);
        Ok(())
    }

    /// Insert the per-replay paged step inputs — this session's block
    /// table (fixed `paged_table_len` stride) and the `kv_block` uniform —
    /// when the executor runs paged. No-op otherwise.
    fn insert_paged_inputs(
        executor: &GraphExecutor<'r>,
        dims: &GraphDims,
        s: &SessionState,
        inputs: &mut HashMap<String, Tensor>,
    ) -> Result<()> {
        let Some(pool) = executor.paged_pool() else {
            return Ok(());
        };
        let stride = paged_table_len(dims);
        let pk = s.kv.as_paged().ok_or_else(|| {
            Error::Internal(format!(
                "paged mode: session {} is not block-backed at encode",
                s.id
            ))
        })?;
        inputs.insert(
            "block_table".into(),
            Tensor::i32(vec![stride], Self::table_entries(pk, stride))?,
        );
        inputs.insert("kv_block".into(), Tensor::scalar_i32(pool.kv_block as i32));
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn encode_inner(
        executor: &mut GraphExecutor<'r>,
        graph: &FxGraph,
        dims: &GraphDims,
        weights: &ModelWeights,
        s: &mut SessionState,
        token: usize,
        was_prompt: bool,
        ring_idx: usize,
    ) -> Result<StepHandle> {
        if s.pos >= dims.max_seq {
            return Err(Error::Graph(format!(
                "KV cache capacity {} exhausted",
                dims.max_seq
            )));
        }
        let planned = executor.is_planned();
        // Upload accounting starts BEFORE promotion so a resume's cache
        // re-hydration (a full host->device cache upload) is charged to
        // this session's upload_bytes — parking and resuming every few
        // tokens must not report as resident-cache traffic savings.
        let w0 = executor.device.stats.bytes_written;
        if planned {
            Self::promote_to_device(executor, s)?;
        }

        // Attribution snapshots (virtual-clock deltas belong to this
        // session — the shared device accumulates across all of them).
        let ph0 = executor.device.timeline.virtual_ns;
        let k0 = executor.device.timeline.kernel_virtual_ns;
        let sy0 = executor.device.timeline.sync_virtual_ns;
        let fw0 = executor.framework_virtual_ns;
        let d0 = executor.dispatch_count;
        let c0 = executor.device.clock.now_ns();

        // Host embedding gather (Table 10 "Other": embedding).
        let x = hostops::embed(&weights.embedding, token)?;
        let mut inputs: HashMap<String, Tensor> = HashMap::new();
        inputs.insert("x".into(), x);
        inputs.insert("pos_i".into(), Tensor::scalar_i32(s.pos as i32));
        inputs.insert("pos_ip1".into(), Tensor::scalar_i32(s.pos as i32 + 1));
        inputs.insert("pos_f".into(), Tensor::scalar_f32(s.pos as f32));
        inputs.insert("inv_freq".into(), weights.inv_freq.clone());
        Self::insert_paged_inputs(executor, dims, s, &mut inputs)?;
        if !planned {
            // Lazily materialize zeroed host caches on the first eager
            // encode (sessions are born with the empty placeholder so
            // planned admits never pay the host allocation). Only valid at
            // pos 0: a mid-generation session whose cache state was
            // dropped must fail loudly, not decode against zeroed K/V.
            if matches!(&s.kv, KvCache::Host(h) if h.is_empty()) {
                if s.pos != 0 {
                    return Err(Error::Graph(format!(
                        "session {} lost its cache state mid-generation (pos {})",
                        s.id, s.pos
                    )));
                }
                s.kv = KvCache::host_zeroed(dims);
            }
            // Eager mode round-trips the caches host-side per step — the
            // O(layers x max_seq) traffic the paper's pathology pays.
            let host = s.kv.as_host().ok_or_else(|| {
                Error::Graph("eager session must keep host-resident caches".into())
            })?;
            for (l, (k, v)) in host.iter().enumerate() {
                inputs.insert(format!("l{l}.k_cache"), k.clone());
                inputs.insert(format!("l{l}.v_cache"), v.clone());
            }
        }
        // Weights are NOT passed per step: they were pinned into persistent
        // device buffers at engine construction (executor.pin_inputs).

        let (mut outs, logits_buf) =
            executor.run_with_session(graph, &inputs, ring_idx, s.kv.as_device())?;

        if planned {
            // K/V appends happened on-device (in-place cache_update): the
            // session's cache set already holds the next step's state.
            s.pos += 1;
            // Rows written high-water: the paged spill reconstructs rows
            // >= this mark as zeros (matching contiguous zeroed-at-alloc).
            s.kv_hw = s.kv_hw.max(s.pos);
        } else {
            // Update this session's host caches for its next step.
            let host = s.kv.as_host_mut().ok_or_else(|| {
                Error::Internal("eager session lost its host caches mid-encode".into())
            })?;
            for (l, kv) in host.iter_mut().enumerate() {
                let k = outs
                    .remove(&format!("l{l}.k_cache"))
                    .ok_or_else(|| Error::Graph(format!("missing l{l}.k_cache output")))?;
                let v = outs
                    .remove(&format!("l{l}.v_cache"))
                    .ok_or_else(|| Error::Graph(format!("missing l{l}.v_cache output")))?;
                *kv = (k, v);
            }
            s.pos += 1;
        }

        let logits = outs
            .remove("logits")
            .ok_or_else(|| Error::Graph("missing logits output".into()))?;

        s.metrics.steps += 1;
        s.metrics.upload_bytes += executor.device.stats.bytes_written - w0;
        let dp = executor.dispatch_count - d0;
        s.metrics.dispatches += dp;
        if was_prompt {
            s.metrics.prefill_steps += 1;
            s.metrics.prefill_dispatches += dp;
        }
        let tl = &executor.device.timeline;
        for i in 0..8 {
            s.metrics.phase_virtual_ns[i] += tl.virtual_ns[i] - ph0[i];
        }
        s.metrics.kernel_virtual_ns += tl.kernel_virtual_ns - k0;
        s.metrics.sync_virtual_ns += tl.sync_virtual_ns - sy0;
        s.metrics.framework_virtual_ns += executor.framework_virtual_ns - fw0;
        // Encode (planned: plan *replay*) CPU cost for this session — the
        // counterpart of the engine-level plan-build cost, so build vs
        // replay attribution is visible per session.
        s.metrics.encode_virtual_ns += executor.device.clock.now_ns() - c0;
        if was_prompt && !s.in_prefill() {
            // This encode consumed the final prompt token: TTFT splits
            // here into prompt ingestion vs first-token readback.
            s.metrics.prefill_end_ns = executor.device.clock.now_ns();
        }

        Ok(StepHandle { logits, logits_buf })
    }

    fn finish_inner(
        executor: &mut GraphExecutor<'r>,
        argmax: Option<&ArgmaxPrepared>,
        s: &mut SessionState,
        h: StepHandle,
        retries: &mut u64,
    ) -> Result<usize> {
        let ph0 = executor.device.timeline.virtual_ns;
        let sy0 = executor.device.timeline.sync_virtual_ns;
        let k0 = executor.device.timeline.kernel_virtual_ns;
        let d0 = executor.device.timeline.dispatches();
        let next = if let Some(prep) = argmax {
            // Device-side argmax: one more dispatch, then a 4-byte readback.
            let idx = Self::run_device_argmax(executor, prep, &h.logits)?;
            if let Some(buf) = h.logits_buf {
                executor.release_logits(buf)?;
            }
            idx
        } else if let Some(buf) = h.logits_buf {
            // Full-logits readback (map pays sync + per-byte transfer),
            // then host argmax — the production path.
            let res = Self::map_read_retry(&mut executor.device, &[buf], retries)
                .and_then(|v| {
                    v.into_iter().next().ok_or_else(|| {
                        Error::Internal("readback mapped no buffer".into())
                    })
                });
            let bytes = match res {
                Ok(b) => b,
                Err(e) => {
                    // Ring buffers are plan-owned (release is a no-op);
                    // pooled eager buffers must still be returned.
                    let _ = executor.release_logits(buf);
                    return Err(e);
                }
            };
            executor.release_logits(buf)?;
            argmax_bytes(&bytes)
        } else {
            h.logits.argmax_row()?
        };
        let tl = &executor.device.timeline;
        for i in 0..8 {
            s.metrics.phase_virtual_ns[i] += tl.virtual_ns[i] - ph0[i];
        }
        s.metrics.sync_virtual_ns += tl.sync_virtual_ns - sy0;
        // Device-argmax issues an extra dispatch outside the executor's
        // graph walk: attribute its kernel time + dispatch here so
        // per-session sums keep tiling the device timeline exactly.
        s.metrics.kernel_virtual_ns += tl.kernel_virtual_ns - k0;
        s.metrics.dispatches += tl.dispatches() - d0;
        let now = executor.device.clock.now_ns();
        s.note_token(next, now);
        let track = s
            .slot
            .map(crate::trace::slot_track)
            .unwrap_or(crate::trace::TRACK_ENGINE);
        executor.device.trace.instant(crate::trace::names::TOKEN, track, now, next as u64);
        Ok(next)
    }

    fn run_device_argmax(
        executor: &mut GraphExecutor<'r>,
        prep: &ArgmaxPrepared,
        logits: &Tensor,
    ) -> Result<usize> {
        use crate::webgpu::{BufferDesc, BufferUsage};
        let (pipeline, layout) = (prep.pipeline, prep.layout);
        let dev = &mut executor.device;
        let in_buf = dev.create_buffer(BufferDesc {
            label: "argmax-in".into(),
            size: logits.size_bytes(),
            usage: BufferUsage::STORAGE | BufferUsage::COPY_DST,
        })?;
        dev.write_buffer(in_buf, 0, logits.data.as_bytes())?;
        let out_buf = dev.create_buffer(BufferDesc {
            label: "argmax-out".into(),
            size: 4,
            usage: BufferUsage::STORAGE | BufferUsage::MAP_READ,
        })?;
        let group = bind_buffers(dev, "argmax", layout, &[in_buf], &[out_buf])?;
        let enc = dev.create_command_encoder("argmax");
        dev.begin_compute_pass(enc)?;
        dev.set_pipeline(enc, pipeline)?;
        dev.set_bind_group(enc, group)?;
        dev.dispatch_workgroups(enc, 1, 1, 1)?;
        dev.end_compute_pass(enc)?;
        let cb = dev.finish(enc)?;
        let registry = executor.registry();
        executor.device.submit(&[cb], registry)?;
        // Only 4 bytes cross the bus — the Appendix H point.
        let bytes = executor.device.map_read(out_buf)?;
        let idx = i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        executor.device.destroy_buffer(in_buf)?;
        executor.device.destroy_buffer(out_buf)?;
        Ok(idx)
    }

    /// One scheduler round: admit, step every active session once (a
    /// prefill-phase session's "step" ingests one PROMPT CHUNK), retire
    /// completed sessions. Returns the number of sessions stepped.
    ///
    /// With chunked prefill enabled (planned mode, `prefill_chunk >= 2`),
    /// sessions still consuming their prompt replay the PREFILL plan —
    /// one dispatch per layer op per chunk of up to `prefill_chunk`
    /// prompt tokens — while generating sessions decode through the
    /// batched (or single-session) path in the SAME round: prompt
    /// ingestion and decode interleave continuously, and one coalesced
    /// readback finishes both.
    ///
    /// With batching enabled (planned mode, `batch_width >= 2`) and >= 2
    /// active decode sessions, decode replays the BATCHED plan — sessions
    /// occupy their sticky slots and each layer op is ONE dispatch per
    /// chunk of `batch_width` slots instead of one per session. Rounds
    /// with a single active session (and the device-argmax finish
    /// variant, whose per-session argmax dispatch expects single-row
    /// logits) keep the interleaved path byte-for-byte.
    pub fn step_round(&mut self) -> Result<usize> {
        // ROUND span around the whole scheduler round. Begin/end fire on
        // both the Ok and Err paths so faulted rounds leave the trace
        // balanced, and the round-duration histogram feeds the report's
        // percentile rows regardless of sink.
        let t0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::ROUND,
            crate::trace::TRACK_ENGINE,
            t0,
        );
        let res = self.step_round_inner();
        let t1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::ROUND,
            crate::trace::TRACK_ENGINE,
            t1,
        );
        self.executor.device.trace.metrics.round_ns.record(t1 - t0);
        res
    }

    fn step_round_inner(&mut self) -> Result<usize> {
        self.sweep_failed()?;
        self.admit()?;
        let n = self.active.len();
        if n == 0 {
            return Ok(0);
        }
        // Quarantine backoff: a faulted session sits out `cooldown`
        // rounds (bounded — see MAX_COOLDOWN) while the rest of the
        // fleet keeps stepping. Sitting out never perturbs token
        // streams: per-session decode math is scheduling-independent.
        let mut eligible: Vec<usize> = Vec::with_capacity(n);
        for i in 0..n {
            if self.active[i].cooldown > 0 {
                self.active[i].cooldown -= 1;
            } else {
                eligible.push(i);
            }
        }
        if eligible.is_empty() {
            // Every session is cooling down; the round still advances
            // the backoff clocks decremented above.
            self.rounds += 1;
            return self.retire_finished();
        }
        if self.unified_graph.is_some() {
            // Unified continuous batching: EVERY round — all-prefill,
            // all-decode, mixed, even single-session — replays the
            // seq-x-batch plan once per chunk of `batch_width` slots.
            self.step_round_unified(&eligible)?;
            self.rounds += 1;
            return self.retire_finished();
        }
        // Quarantined sessions at the ladder's bottom rung (degrade >= 2)
        // step token-by-token even through their prompt, so they never
        // join a seq-dim prefill replay again.
        let prefill_idx: Vec<usize> = if self.prefill_graph.is_some() {
            eligible
                .iter()
                .copied()
                .filter(|&i| self.active[i].in_prefill() && self.active[i].degrade < 2)
                .collect()
        } else {
            Vec::new()
        };
        if !prefill_idx.is_empty() {
            self.step_round_prefill(&eligible, &prefill_idx)?;
        } else if eligible.len() >= 2
            && self.batched_graph.is_some()
            && self.argmax.is_none()
        {
            self.step_round_batched(&eligible)?;
        } else {
            self.step_round_interleaved(&eligible)?;
        }
        self.rounds += 1;
        self.retire_finished()
    }

    /// Quarantine the sessions implicated in a failed encode: roll each
    /// back to its pre-encode snapshot, spill its KV state to host (the
    /// checkpoint is exactly the last committed token — every device row
    /// the partial encode dirtied sits at a position >= the rolled-back
    /// `pos`, dead under the causal mask until the retry overwrites it
    /// with identical values), then schedule bounded backoff and one rung
    /// of the degradation ladder. Fault granularity is the encode unit: a
    /// fused chunk's fault cannot be attributed to one member, so all its
    /// members roll back — but the round's OTHER chunks complete. Fatal
    /// (device-scoped) errors propagate instead.
    fn quarantine(&mut self, snaps: &[(usize, SessionSnapshot)], e: Error) -> Result<()> {
        if !e.is_transient() {
            return Err(e);
        }
        let ServingEngine { executor, active, dims, retries, .. } = &mut *self;
        *retries += 1;
        for &(i, snap) in snaps {
            let s = &mut active[i];
            let ts = executor.device.clock.now_ns();
            let track = s
                .slot
                .map(crate::trace::slot_track)
                .unwrap_or(crate::trace::TRACK_ENGINE);
            executor.device.trace.instant(
                crate::trace::names::QUARANTINE,
                track,
                ts,
                s.id,
            );
            s.rollback(snap);
            // Checkpoint-by-spill: the evict-to-host path IS the snapshot
            // store — the session resumes from recycled pool buffers via
            // the ordinary promote/hydrate path. A fatal error during the
            // spill itself propagates.
            Self::evict_kv_to_host(executor, dims, s, retries)?;
            s.retries += 1;
            s.total_retries += 1;
            s.cooldown = (s.retries - 1).min(MAX_COOLDOWN);
            s.degrade = (s.degrade + 1).min(2);
            if s.retries > MAX_SESSION_RETRIES {
                s.failed = true;
            }
        }
        Ok(())
    }

    /// Retire sessions that exhausted their retry budget. They leave with
    /// whatever tokens they committed (every emitted token was read back
    /// before the fault — the stream is a consistent prefix), freeing
    /// their slot and cache set for the backlog.
    fn sweep_failed(&mut self) -> Result<()> {
        if !self.active.iter().any(|s| s.failed) {
            return Ok(());
        }
        let mut done: Vec<SessionState> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].failed {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        for s in done.iter_mut().rev() {
            self.release_session_cache(s)?;
        }
        self.failed_sessions += done.len() as u64;
        self.finished.extend(done);
        Ok(())
    }

    /// The pre-batching round body: per-session encodes, then a coalesced
    /// finish. Also the N = 1 round shape under batching. A session whose
    /// encode faults transiently is quarantined alone; the others' steps
    /// still finish this round.
    fn step_round_interleaved(&mut self, eligible: &[usize]) -> Result<()> {
        let mut handles: Vec<(usize, StepHandle)> = Vec::with_capacity(eligible.len());
        for &i in eligible {
            // In planned mode, each session in the round replays into its
            // own logits-ring buffer (reserved from the shared cursor) so
            // every logits row survives until the coalesced readback below.
            let ring = self.next_ring();
            let snap = self.active[i].snapshot();
            let res = self
                .pager_pass(&[(i, (self.active[i].pos + 1).min(self.dims.max_seq))])
                .and_then(|()| {
                    let ServingEngine { executor, graph, dims, weights, active, .. } =
                        &mut *self;
                    let s = &mut active[i];
                    match s.take_input() {
                        Some((token, was_prompt)) => Self::encode_inner(
                            executor, graph, dims, weights, s, token, was_prompt, ring,
                        ),
                        None => Err(Error::Internal(format!(
                            "session {} has no input token",
                            s.id
                        ))),
                    }
                });
            match res {
                Ok(h) => handles.push((i, h)),
                Err(e) => self.quarantine(&[(i, snap)], e)?,
            }
        }

        if self.argmax.is_some() {
            // Device-argmax path: per-session finish (each pays its own
            // 4-byte readback; Appendix H trades transfer for dispatches).
            for (i, h) in handles {
                let ServingEngine { executor, argmax, active, retries, .. } = &mut *self;
                Self::finish_inner(executor, argmax.as_ref(), &mut active[i], h, retries)?;
                self.active[i].retries = 0;
            }
        } else {
            // Coalesced finish: ONE synchronization covers every session's
            // logits readback — the amortized fixed cost.
            let mut buf_ids: Vec<BufferId> = Vec::with_capacity(handles.len());
            for (_, h) in &handles {
                if let Some(b) = h.logits_buf {
                    buf_ids.push(b);
                }
            }
            let sy0 = self.executor.device.timeline.sync_virtual_ns;
            let all_bytes = {
                let ServingEngine { executor, retries, .. } = &mut *self;
                match Self::map_read_retry(&mut executor.device, &buf_ids, retries) {
                    Ok(b) => b,
                    Err(e) => {
                        for &b in &buf_ids {
                            let _ = executor.release_logits(b);
                        }
                        return Err(e);
                    }
                }
            };
            let sync_cost = self.executor.device.timeline.sync_virtual_ns - sy0;
            // Split the shared sync exactly across participants (remainder
            // to the first) so per-session sums match the device timeline.
            let k = buf_ids.len() as u64;
            let rot = self.rounds;
            let mut j = 0usize;
            for (i, h) in &handles {
                if h.logits_buf.is_some() {
                    self.active[*i].metrics.sync_virtual_ns += share(sync_cost, k, j, rot);
                    j += 1;
                }
            }
            let now = self.executor.device.clock.now_ns();
            let mut bytes_iter = all_bytes.into_iter();
            for (i, h) in handles {
                let next = if let Some(b) = h.logits_buf {
                    let bytes = bytes_iter.next().ok_or_else(|| {
                        Error::Internal(
                            "coalesced readback mapped fewer buffers than requested".into(),
                        )
                    })?;
                    self.executor.release_logits(b)?;
                    argmax_bytes(&bytes)
                } else {
                    h.logits.argmax_row()?
                };
                let s = &mut self.active[i];
                s.retries = 0;
                s.note_token(next, now);
                let track = s
                    .slot
                    .map(crate::trace::slot_track)
                    .unwrap_or(crate::trace::TRACK_ENGINE);
                self.executor.device.trace.instant(
                    crate::trace::names::TOKEN,
                    track,
                    now,
                    next as u64,
                );
            }
        }
        Ok(())
    }

    /// The batched round body: healthy sessions decode through their
    /// sticky slots' batched chunks; quarantined (degraded) ones run solo
    /// single-token replays so a flaky session cannot keep faulting whole
    /// multi-session chunks. Then ONE round-level readback.
    fn step_round_batched(&mut self, eligible: &[usize]) -> Result<()> {
        let mut chunks: Vec<EncodedChunk> = Vec::new();
        let healthy: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| self.active[i].degrade == 0)
            .collect();
        if healthy.len() >= 2 {
            chunks.extend(self.encode_batched_chunks(&healthy)?);
        } else {
            for &i in &healthy {
                let snap = self.active[i].snapshot();
                match self.encode_decode_step(i) {
                    Ok(c) => chunks.push(c),
                    Err(e) => self.quarantine(&[(i, snap)], e)?,
                }
            }
        }
        for &i in eligible {
            if self.active[i].degrade == 0 {
                continue;
            }
            let snap = self.active[i].snapshot();
            match self.encode_decode_step(i) {
                Ok(c) => chunks.push(c),
                Err(e) => self.quarantine(&[(i, snap)], e)?,
            }
        }
        self.finish_round(chunks)
    }

    /// A round containing prefill-phase sessions: each ingests one
    /// `prefill_chunk`-sized slice of its prompt through the seq-dim
    /// prefill plan (ONE replay per session per round — C cache rows
    /// scattered per layer per dispatch), while generating sessions
    /// decode through the batched (or single-session) path in the same
    /// round — the continuous-batching shape. Only FINAL prompt chunks
    /// (the ones whose last-row logits select the first generated token)
    /// join the round's coalesced readback; intermediate chunks never
    /// synchronize, which is exactly where chunked prefill's TTFT win
    /// comes from.
    fn step_round_prefill(&mut self, eligible: &[usize], prefill_idx: &[usize]) -> Result<()> {
        let mut chunks: Vec<EncodedChunk> = Vec::new();
        for (k, &i) in prefill_idx.iter().enumerate() {
            let snap = self.active[i].snapshot();
            match self.encode_prefill_chunk(i, k) {
                Ok(Some(c)) => chunks.push(c),
                Ok(None) => {}
                Err(e) => self.quarantine(&[(i, snap)], e)?,
            }
        }
        // Everything else: decoding sessions, plus quarantined prompt
        // ingesters at the ladder's bottom rung (token-by-token prefill).
        let decode_idx: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|i| !prefill_idx.contains(i))
            .collect();
        if !decode_idx.is_empty() {
            let healthy: Vec<usize> = decode_idx
                .iter()
                .copied()
                .filter(|&i| self.active[i].degrade == 0)
                .collect();
            if healthy.len() >= 2 && self.batched_graph.is_some() {
                chunks.extend(self.encode_batched_chunks(&healthy)?);
            } else {
                for &i in &healthy {
                    let snap = self.active[i].snapshot();
                    match self.encode_decode_step(i) {
                        Ok(c) => chunks.push(c),
                        Err(e) => self.quarantine(&[(i, snap)], e)?,
                    }
                }
            }
            for &i in &decode_idx {
                if self.active[i].degrade == 0 {
                    continue;
                }
                let snap = self.active[i].snapshot();
                match self.encode_decode_step(i) {
                    Ok(c) => chunks.push(c),
                    Err(e) => self.quarantine(&[(i, snap)], e)?,
                }
            }
        }
        self.finish_round(chunks)
    }

    /// Encode ONE prompt chunk for active session `i`: consume up to
    /// `prefill_chunk` prompt tokens, upload the packed `[C, H]` rows +
    /// per-position angles + `pos_base`/`valid_len` uniforms (the ragged
    /// final chunk masks its tail — no recompile), and replay the prefill
    /// plan once into logits ring buffer `ring`. Every cost delta goes to
    /// the one session. Returns the chunk for the round's readback ONLY
    /// when it consumed the final prompt token.
    fn encode_prefill_chunk(&mut self, i: usize, ring: usize) -> Result<Option<EncodedChunk>> {
        let t0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t0,
        );
        let res = self.encode_prefill_chunk_inner(i, ring);
        let t1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t1,
        );
        // Per-slot step span over the whole chunk encode (the prefill
        // chunk has exactly one owner).
        if res.is_ok() && self.executor.device.trace.on() {
            if let Some(slot) = self.active[i].slot {
                self.executor.device.trace.complete(
                    crate::trace::names::SLOT_STEP,
                    crate::trace::slot_track(slot),
                    t0,
                    t1 - t0,
                    self.active[i].id,
                );
            }
        }
        res
    }

    fn encode_prefill_chunk_inner(
        &mut self,
        i: usize,
        ring: usize,
    ) -> Result<Option<EncodedChunk>> {
        let chunk = self.prefill_chunk;
        let (hidden, max_seq) = (self.dims.hidden, self.dims.max_seq);

        // Paged pre-pass: make every block this chunk's scatter touches
        // resident before anything packs.
        {
            let s = &self.active[i];
            let rows_end = (s.pos + s.peek_prompt_chunk(chunk).len()).min(max_seq);
            self.pager_pass(&[(i, rows_end)])?;
        }

        // Upload accounting starts BEFORE promotion so a resumed
        // session's cache re-hydration is charged to it (same convention
        // as the decode paths).
        let w0 = self.executor.device.stats.bytes_written;
        {
            let ServingEngine { executor, active, .. } = &mut *self;
            Self::promote_to_device(executor, &mut active[i])?;
        }
        let ph0 = self.executor.device.timeline.virtual_ns;
        let k0 = self.executor.device.timeline.kernel_virtual_ns;
        let sy0 = self.executor.device.timeline.sync_virtual_ns;
        let fw0 = self.executor.framework_virtual_ns;
        let d0 = self.executor.dispatch_count;
        let c0 = self.executor.device.clock.now_ns();

        let (inputs, take) = {
            let ServingEngine { weights, active, .. } = &mut *self;
            let s = &mut active[i];
            let range = s.peek_prompt_chunk(chunk);
            let take = range.len();
            debug_assert!(take >= 1, "prefill round scheduled an exhausted prompt");
            if s.pos + take > max_seq {
                return Err(Error::Graph(format!(
                    "KV cache capacity {max_seq} exhausted during prefill"
                )));
            }
            // Pack rows 0..take; the ragged tail stays zeroed — those
            // rows are masked by valid_len everywhere that matters.
            let mut xbuf = vec![0f32; chunk * hidden];
            let mut pos_f = vec![0f32; chunk];
            for (r, &t) in s.prompt[range.clone()].iter().enumerate() {
                let emb = hostops::embed(&weights.embedding, t)?;
                xbuf[r * hidden..(r + 1) * hidden].copy_from_slice(emb.as_f32()?);
                pos_f[r] = (s.pos + r) as f32;
            }
            let mut inputs: HashMap<String, Tensor> = HashMap::with_capacity(5);
            inputs.insert("x".into(), Tensor::f32(vec![chunk, hidden], xbuf)?);
            inputs.insert("pos_f".into(), Tensor::f32(vec![chunk], pos_f)?);
            inputs.insert("pos_base".into(), Tensor::scalar_i32(s.pos as i32));
            inputs.insert("valid_len".into(), Tensor::scalar_i32(take as i32));
            inputs.insert("inv_freq".into(), weights.inv_freq.clone());
            s.consume_prompt(take);
            (inputs, take)
        };

        let logits_buf = {
            let ServingEngine { executor, prefill_graph, active, .. } = &mut *self;
            let graph = prefill_graph
                .as_ref()
                .ok_or_else(|| Error::Internal("prefill plan missing".into()))?;
            let kv = active[i].kv.as_device();
            let (_outs, logits_buf, _delta) =
                executor.run_prefill(graph, &inputs, ring, kv)?;
            logits_buf
        };

        // ---- attribution: the whole chunk belongs to this session ----
        let tl = self.executor.device.timeline.virtual_ns;
        let kernel_d = self.executor.device.timeline.kernel_virtual_ns - k0;
        let sync_d = self.executor.device.timeline.sync_virtual_ns - sy0;
        let fw_d = self.executor.framework_virtual_ns - fw0;
        let disp_d = self.executor.dispatch_count - d0;
        let upload_d = self.executor.device.stats.bytes_written - w0;
        let now = self.executor.device.clock.now_ns();
        let s = &mut self.active[i];
        for p in 0..8 {
            s.metrics.phase_virtual_ns[p] += tl[p] - ph0[p];
        }
        s.metrics.kernel_virtual_ns += kernel_d;
        s.metrics.sync_virtual_ns += sync_d;
        s.metrics.framework_virtual_ns += fw_d;
        s.metrics.dispatches += disp_d;
        s.metrics.prefill_dispatches += disp_d;
        s.metrics.upload_bytes += upload_d;
        s.metrics.encode_virtual_ns += now - c0;
        // Step accounting stays token-granular: a C-token chunk is C
        // prompt steps, so per-step rates compare across ingestion modes.
        s.metrics.steps += take as u64;
        s.metrics.prefill_steps += take as u64;
        // The on-device scatter already wrote this chunk's K/V rows.
        s.pos += take;
        s.kv_hw = s.kv_hw.max(s.pos);
        let final_chunk = !s.in_prefill();
        if final_chunk {
            s.metrics.prefill_end_ns = now;
        }
        let buf = logits_buf.ok_or_else(|| {
            Error::Graph("prefill plan produced no logits buffer".into())
        })?;
        Ok(if final_chunk {
            Some(EncodedChunk { buf, owners: vec![ChunkOwner::single(i, 0)] })
        } else {
            None
        })
    }

    /// One planned single-session decode encode (a mixed round's decode
    /// side when the batched path does not apply), as a round chunk.
    fn encode_decode_step(&mut self, i: usize) -> Result<EncodedChunk> {
        let t0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t0,
        );
        let res = self.encode_decode_step_inner(i);
        let t1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t1,
        );
        if res.is_ok() && self.executor.device.trace.on() {
            if let Some(slot) = self.active[i].slot {
                self.executor.device.trace.complete(
                    crate::trace::names::SLOT_STEP,
                    crate::trace::slot_track(slot),
                    t0,
                    t1 - t0,
                    self.active[i].id,
                );
            }
        }
        res
    }

    fn encode_decode_step_inner(&mut self, i: usize) -> Result<EncodedChunk> {
        self.pager_pass(&[(i, (self.active[i].pos + 1).min(self.dims.max_seq))])?;
        let ring = self.next_ring();
        let h = {
            let ServingEngine { executor, graph, dims, weights, active, .. } = &mut *self;
            let s = &mut active[i];
            let (token, was_prompt) = s.take_input().ok_or_else(|| {
                Error::Graph(format!("session {} has no input token", s.id))
            })?;
            Self::encode_inner(executor, graph, dims, weights, s, token, was_prompt, ring)?
        };
        let buf = h.logits_buf.ok_or_else(|| {
            Error::Graph("planned decode produced no logits buffer".into())
        })?;
        Ok(EncodedChunk { buf, owners: vec![ChunkOwner::single(i, 0)] })
    }

    /// Pack the given active sessions into batched-plan replays by their
    /// STICKY slots: chunk `c` covers slots `[c*W, (c+1)*W)`; rows whose
    /// slot carries no decoding session this round (free slots, or
    /// sessions still in prefill) are masked against the padding set, and
    /// chunks with no session at all are skipped entirely. Uploads ONE
    /// concatenated token/position buffer per chunk, replays the batched
    /// plan per chunk (one dispatch per layer op, K/V appends scattered
    /// into each session's own cache set, each chunk into its own
    /// logits-ring buffer), splitting each chunk's shared costs evenly
    /// across its sessions so per-session sums keep tiling the engine
    /// totals.
    fn encode_batched_chunks(&mut self, idx: &[usize]) -> Result<Vec<EncodedChunk>> {
        let width = self.batch_width;
        // chunk number -> [(row within chunk, active index)], row-sorted.
        let mut by_chunk: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for &i in idx {
            let slot = self.active[i].slot.ok_or_else(|| {
                Error::Graph(format!(
                    "session {} has no decode slot (batched rounds need sticky slots)",
                    self.active[i].id
                ))
            })?;
            by_chunk.entry(slot / width).or_default().push((slot % width, i));
        }
        let mut chunks = Vec::with_capacity(by_chunk.len());
        for (chunk_no, mut members) in by_chunk {
            members.sort_unstable();
            // Fault isolation boundary: a transient fault inside one
            // chunk replay quarantines ONLY that chunk's members (rolled
            // back to their pre-pack snapshots); the round's other chunks
            // proceed.
            let snaps: Vec<(usize, SessionSnapshot)> = members
                .iter()
                .map(|&(_, i)| (i, self.active[i].snapshot()))
                .collect();
            match self.encode_batched_chunk(chunk_no, &members) {
                Ok(c) => chunks.push(c),
                Err(e) => self.quarantine(&snaps, e)?,
            }
        }
        Ok(chunks)
    }

    /// Pack and replay ONE batched chunk (see [`Self::encode_batched_chunks`]
    /// for the slot layout). Fallible as a unit: any error leaves only the
    /// chunk's own members dirty, all at dead (masked) cache rows.
    fn encode_batched_chunk(
        &mut self,
        chunk_no: usize,
        members: &[(usize, usize)],
    ) -> Result<EncodedChunk> {
        let t0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t0,
        );
        let res = self.encode_batched_chunk_inner(chunk_no, members);
        let t1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t1,
        );
        res
    }

    fn encode_batched_chunk_inner(
        &mut self,
        chunk_no: usize,
        members: &[(usize, usize)],
    ) -> Result<EncodedChunk> {
        let width = self.batch_width;
        let (hidden, max_seq) = (self.dims.hidden, self.dims.max_seq);
        // Paged pre-pass: one residency pass covers the whole chunk (its
        // page traffic splits across exactly these members).
        let needs: Vec<(usize, usize)> = members
            .iter()
            .map(|&(_, i)| (i, (self.active[i].pos + 1).min(max_seq)))
            .collect();
        self.pager_pass(&needs)?;
        // ---- pack: residency, input tokens, per-slot uniforms ----
        let mut xbuf = vec![0f32; width * hidden];
        let mut pos_i = vec![0i32; width];
        let mut pos_ip1 = vec![0i32; width];
        let mut pos_f = vec![0f32; width];
        let mut mask = vec![0i32; width];
        let slot_idx: Vec<i32> = (0..width as i32).collect();
        let mut was_prompt = vec![false; width];
        {
            let ServingEngine { executor, weights, active, .. } = &mut *self;
            for &(row, i) in members {
                let s = &mut active[i];
                if s.pos >= max_seq {
                    return Err(Error::Graph(format!(
                        "KV cache capacity {max_seq} exhausted"
                    )));
                }
                // Hydration of a resumed session is charged to it.
                let w0 = executor.device.stats.bytes_written;
                Self::promote_to_device(executor, s)?;
                s.metrics.upload_bytes += executor.device.stats.bytes_written - w0;
                let (token, wp) = s.take_input().ok_or_else(|| {
                    Error::Internal(format!("session {} has no input token", s.id))
                })?;
                was_prompt[row] = wp;
                let emb = hostops::embed(&weights.embedding, token)?;
                xbuf[row * hidden..(row + 1) * hidden].copy_from_slice(emb.as_f32()?);
                pos_i[row] = s.pos as i32;
                pos_ip1[row] = s.pos as i32 + 1;
                pos_f[row] = s.pos as f32;
                mask[row] = 1;
            }
        }
        let mut inputs: HashMap<String, Tensor> = HashMap::with_capacity(7);
        inputs.insert("x".into(), Tensor::f32(vec![width, hidden], xbuf)?);
        inputs.insert("pos_i".into(), Tensor::i32(vec![width], pos_i)?);
        inputs.insert("pos_ip1".into(), Tensor::i32(vec![width], pos_ip1)?);
        inputs.insert("pos_f".into(), Tensor::f32(vec![width], pos_f)?);
        inputs.insert("slot_mask".into(), Tensor::i32(vec![width], mask)?);
        inputs.insert("inv_freq".into(), self.weights.inv_freq.clone());
        if let Some(pool) = self.executor.paged_pool() {
            // Paged: per-row block tables replace slot-indexed cache sets
            // (the plan binds the shared pool planes; `slot_idx` is not a
            // declared input of the paged batched graph).
            let stride = paged_table_len(&self.dims);
            let mut tbl = vec![-1i32; width * stride];
            for &(row, i) in members {
                let pk = self.active[i].kv.as_paged().ok_or_else(|| {
                    Error::Internal(format!(
                        "paged mode: session {} is not block-backed at encode",
                        self.active[i].id
                    ))
                })?;
                tbl[row * stride..(row + 1) * stride]
                    .copy_from_slice(&Self::table_entries(pk, stride));
            }
            inputs.insert("block_table".into(), Tensor::i32(vec![width * stride], tbl)?);
            inputs.insert("kv_block".into(), Tensor::scalar_i32(pool.kv_block as i32));
        } else {
            inputs.insert("slot_idx".into(), Tensor::i32(vec![width], slot_idx)?);
        }

        // ---- one replay per chunk, shared-cost snapshots around it ----
        let ph0 = self.executor.device.timeline.virtual_ns;
        let k0 = self.executor.device.timeline.kernel_virtual_ns;
        let fw0 = self.executor.framework_virtual_ns;
        let d0 = self.executor.dispatch_count;
        let w0 = self.executor.device.stats.bytes_written;
        let c0 = self.executor.device.clock.now_ns();
        let logits_buf = {
            let ServingEngine { executor, batched_graph, active, .. } = &mut *self;
            let graph = batched_graph
                .as_ref()
                .ok_or_else(|| Error::Internal("batched plan missing".into()))?;
            // Paged chunks bind the shared pool planes (the uploaded
            // block tables do the routing) — the cache-set table is empty.
            let table: Vec<Option<&DeviceKvCache>> = if executor.paged_enabled() {
                Vec::new()
            } else {
                let mut t: Vec<Option<&DeviceKvCache>> = vec![None; width];
                for &(row, i) in members {
                    t[row] = active[i].kv.as_device();
                }
                t
            };
            let (_outs, logits_buf, _delta) =
                executor.run_batched(graph, &inputs, chunk_no, &table)?;
            logits_buf
        };

        // ---- split the chunk's shared costs across its sessions so
        // per-session sums keep tiling the engine totals ----
        let tl = self.executor.device.timeline.virtual_ns;
        let kernel_d = self.executor.device.timeline.kernel_virtual_ns - k0;
        let fw_d = self.executor.framework_virtual_ns - fw0;
        let disp_d = self.executor.dispatch_count - d0;
        let upload_d = self.executor.device.stats.bytes_written - w0;
        let encode_d = self.executor.device.clock.now_ns() - c0;
        let now_enc = self.executor.device.clock.now_ns();
        let k = members.len() as u64;
        let rot = self.rounds;
        for (j, &(row, i)) in members.iter().enumerate() {
            let s = &mut self.active[i];
            for p in 0..8 {
                s.metrics.phase_virtual_ns[p] += share(tl[p] - ph0[p], k, j, rot);
            }
            s.metrics.kernel_virtual_ns += share(kernel_d, k, j, rot);
            s.metrics.framework_virtual_ns += share(fw_d, k, j, rot);
            let dshare = share(disp_d, k, j, rot);
            s.metrics.dispatches += dshare;
            s.metrics.upload_bytes += share(upload_d, k, j, rot);
            s.metrics.encode_virtual_ns += share(encode_d, k, j, rot);
            s.metrics.steps += 1;
            if was_prompt[row] {
                s.metrics.prefill_steps += 1;
                s.metrics.prefill_dispatches += dshare;
                if !s.in_prefill() {
                    s.metrics.prefill_end_ns = now_enc;
                }
            }
            // The on-device scatter already appended this step's K/V.
            s.pos += 1;
            s.kv_hw = s.kv_hw.max(s.pos);
        }
        // Per-slot step spans: one retroactive Complete per member over
        // the chunk's replay window, on the member's slot track.
        if self.executor.device.trace.on() {
            for &(_, i) in members {
                if let Some(slot) = self.active[i].slot {
                    self.executor.device.trace.complete(
                        crate::trace::names::SLOT_STEP,
                        crate::trace::slot_track(slot),
                        c0,
                        encode_d,
                        self.active[i].id,
                    );
                }
            }
        }

        Ok(EncodedChunk {
            buf: logits_buf.ok_or_else(|| {
                Error::Graph("batched plan produced no logits buffer".into())
            })?,
            owners: members.iter().map(|&(row, i)| ChunkOwner::single(i, row)).collect(),
        })
    }

    /// The unified round body: every eligible session — still-ingesting
    /// prompts and generating sessions alike — steps through its sticky
    /// slot of ONE seq-x-batch replay per chunk of `batch_width` slots,
    /// then the round's single readback.
    ///
    /// Quarantined sessions ride the degradation ladder instead of the
    /// unified chunks: rung 1 replays SOLO (a prefill chunk for prompt
    /// ingesters, a single-token decode replay otherwise — the split
    /// scheduling shape), rung 2 goes token-by-token through the
    /// single-session plan even mid-prompt (the interleaved shape; for a
    /// decode-phase session rungs 1 and 2 coincide). Solo paths never
    /// speculate, and the ladder is sticky until the session retires —
    /// repeated faults cannot re-poison multi-session replays. Every rung
    /// computes the identical deterministic token stream; only dispatch
    /// amortization is sacrificed.
    fn step_round_unified(&mut self, eligible: &[usize]) -> Result<()> {
        let mut chunks: Vec<EncodedChunk> = Vec::new();
        let unified_idx: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| self.active[i].degrade == 0)
            .collect();
        if !unified_idx.is_empty() {
            chunks.extend(self.encode_unified_chunks(&unified_idx)?);
        }
        let mut prefill_ring = 0usize;
        for &i in eligible {
            if self.active[i].degrade == 0 {
                continue;
            }
            let snap = self.active[i].snapshot();
            let solo_prefill = self.active[i].degrade == 1 && self.active[i].in_prefill();
            let res = if solo_prefill {
                let ring = prefill_ring;
                prefill_ring += 1;
                self.encode_prefill_chunk(i, ring)
            } else {
                self.encode_decode_step(i).map(Some)
            };
            match res {
                Ok(Some(c)) => chunks.push(c),
                Ok(None) => {}
                Err(e) => self.quarantine(&[(i, snap)], e)?,
            }
        }
        self.finish_round(chunks)
    }

    /// Pack the given active sessions into unified-plan replays by their
    /// STICKY slots: chunk-of-slots `c` covers slots `[c*W, (c+1)*W)`;
    /// slot `j` owns rows `j*C..(j+1)*C` of the `[W*C, H]` step input. A
    /// prefill-phase member packs up to `prefill_chunk` prompt rows
    /// (`valid_len` = the ragged take); a decoding member packs exactly
    /// one row (`valid_len` = 1) — a decode step IS a one-token chunk;
    /// slots with no member this round are masked padding (`valid_len` =
    /// 0) against the padding set. ONE replay per chunk-of-slots covers
    /// them all — one dispatch per layer op for a MIXED prompt/decode
    /// round, the continuous-batching amortization the serve-bench
    /// mixed-round gate enforces. Shared costs split evenly across
    /// members; step accounting stays token-granular. Only decode members
    /// and FINAL prompt chunks join the round's coalesced readback
    /// (intermediate chunks never synchronize).
    fn encode_unified_chunks(&mut self, idx: &[usize]) -> Result<Vec<EncodedChunk>> {
        let width = self.batch_width;
        // chunk-of-slots number -> [(row within chunk, active index)].
        let mut by_chunk: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for &i in idx {
            let slot = self.active[i].slot.ok_or_else(|| {
                Error::Graph(format!(
                    "session {} has no decode slot (unified rounds need sticky slots)",
                    self.active[i].id
                ))
            })?;
            by_chunk.entry(slot / width).or_default().push((slot % width, i));
        }
        let mut chunks = Vec::with_capacity(by_chunk.len());
        for (chunk_no, mut members) in by_chunk {
            members.sort_unstable();
            // Fault isolation boundary: a transient fault inside one
            // chunk-of-slots replay quarantines ONLY that chunk's members
            // (rolled back to their pre-pack snapshots); the round's
            // other chunks proceed — a single session-scoped fault never
            // aborts a round with healthy sessions elsewhere in it.
            let snaps: Vec<(usize, SessionSnapshot)> = members
                .iter()
                .map(|&(_, i)| (i, self.active[i].snapshot()))
                .collect();
            match self.encode_unified_chunk(chunk_no, &members) {
                Ok(Some(c)) => chunks.push(c),
                Ok(None) => {}
                Err(e) => self.quarantine(&snaps, e)?,
            }
        }
        Ok(chunks)
    }

    /// Pack and replay ONE unified chunk-of-slots (see
    /// [`Self::encode_unified_chunks`] for the slot/row layout). Fallible
    /// as a unit: any error leaves only this chunk's members dirty, and
    /// only at dead (masked) cache rows at positions >= each member's
    /// rolled-back `pos`. Returns `None` for an all-intermediate chunk
    /// (nothing to read back this round).
    fn encode_unified_chunk(
        &mut self,
        chunk_no: usize,
        members: &[(usize, usize)],
    ) -> Result<Option<EncodedChunk>> {
        let t0 = self.executor.device.clock.now_ns();
        self.executor.device.trace.begin(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t0,
        );
        let res = self.encode_unified_chunk_inner(chunk_no, members);
        let t1 = self.executor.device.clock.now_ns();
        self.executor.device.trace.end(
            crate::trace::names::CHUNK,
            crate::trace::TRACK_ENGINE,
            t1,
        );
        res
    }

    fn encode_unified_chunk_inner(
        &mut self,
        chunk_no: usize,
        members: &[(usize, usize)],
    ) -> Result<Option<EncodedChunk>> {
        let width = self.batch_width;
        let chunk = self.prefill_chunk;
        let rows = width * chunk;
        let speculate = self.speculate;
        let (hidden, max_seq) = (self.dims.hidden, self.dims.max_seq);
        // Paged pre-pass: each member's upper row bound mirrors the pack
        // below — a prompt chunk's take, a verify slot's worst-case
        // `1 + k` draft rows (the n-gram draft may come up shorter; the
        // extra block, if any, is evictable next round), a decode row.
        let needs: Vec<(usize, usize)> = members
            .iter()
            .map(|&(_, i)| {
                let s = &self.active[i];
                let rows_end = if s.in_prefill() {
                    s.pos + s.peek_prompt_chunk(chunk).len()
                } else if speculate >= 1 {
                    let remaining = s.n_new.saturating_sub(s.tokens.len());
                    s.pos
                        + 1
                        + speculate
                            .min(remaining.saturating_sub(1))
                            .min(max_seq.saturating_sub(s.pos + 1))
                } else {
                    s.pos + 1
                };
                (i, rows_end.min(max_seq))
            })
            .collect();
        self.pager_pass(&needs)?;
        {
            // ---- pack: residency, prompt chunks / decode tokens,
            // per-slot uniforms ----
            let mut xbuf = vec![0f32; rows * hidden];
            let mut pos_f = vec![0f32; rows];
            let mut pos_base = vec![0i32; width];
            let mut valid_len = vec![0i32; width];
            let mut mask = vec![0i32; width];
            let slot_idx: Vec<i32> = (0..width as i32).collect();
            // Tokens each member advanced, whether they were prompt rows,
            // and whether a prompt member consumed its FINAL token.
            let mut taken = vec![0usize; width];
            // Rows the replay's scatter will have written per slot
            // (`pos_base + valid_len`) — the kv_hw commit below.
            let mut rows_written = vec![0usize; width];
            let mut was_prefill = vec![false; width];
            let mut final_prefill = vec![false; width];
            // Deferred accept/rollback state for speculative verify rows
            // (`taken` stays 0 for these: position and step advance wait
            // for the readback's greedy match).
            let mut spec_state: Vec<Option<SpecOwner>> =
                (0..width).map(|_| None).collect();
            {
                let ServingEngine { executor, weights, active, .. } = &mut *self;
                for &(row, i) in members {
                    let s = &mut active[i];
                    // Hydration of a resumed session is charged to it.
                    let w0 = executor.device.stats.bytes_written;
                    Self::promote_to_device(executor, s)?;
                    s.metrics.upload_bytes += executor.device.stats.bytes_written - w0;
                    if s.in_prefill() {
                        let range = s.peek_prompt_chunk(chunk);
                        let take = range.len();
                        if s.pos + take > max_seq {
                            return Err(Error::Graph(format!(
                                "KV cache capacity {max_seq} exhausted during prefill"
                            )));
                        }
                        for (r, &t) in s.prompt[range.clone()].iter().enumerate() {
                            let emb = hostops::embed(&weights.embedding, t)?;
                            let at = (row * chunk + r) * hidden;
                            xbuf[at..at + hidden].copy_from_slice(emb.as_f32()?);
                            pos_f[row * chunk + r] = (s.pos + r) as f32;
                        }
                        pos_base[row] = s.pos as i32;
                        valid_len[row] = take as i32;
                        mask[row] = 1;
                        s.consume_prompt(take);
                        taken[row] = take;
                        rows_written[row] = s.pos + take;
                        was_prefill[row] = true;
                        final_prefill[row] = !s.in_prefill();
                    } else {
                        if s.pos >= max_seq {
                            return Err(Error::Graph(format!(
                                "KV cache capacity {max_seq} exhausted"
                            )));
                        }
                        let (token, _) = s.take_input().ok_or_else(|| {
                            Error::Graph(format!("session {} has no input token", s.id))
                        })?;
                        if speculate >= 1 {
                            // Speculative verify: row 0 re-feeds the
                            // committed token, rows 1..=k feed the n-gram
                            // draft — this slot is a valid_len = 1 + k
                            // chunk whose one replay yields every draft
                            // row's next-token logits. The draft is
                            // clamped so the session never overshoots its
                            // request or the KV capacity; position/step
                            // advance is DEFERRED to the readback's
                            // accept/rollback (a rejected row rewinds).
                            let remaining = s.n_new - s.tokens.len();
                            let k_eff = speculate
                                .min(remaining.saturating_sub(1))
                                .min(max_seq - 1 - s.pos);
                            let mut hist =
                                Vec::with_capacity(s.prompt.len() + s.tokens.len());
                            hist.extend_from_slice(&s.prompt);
                            hist.extend_from_slice(&s.tokens);
                            let drafted = draft_ngram(&hist, k_eff);
                            let inputs = std::iter::once(&token).chain(drafted.iter());
                            for (r, &t) in inputs.enumerate() {
                                let emb = hostops::embed(&weights.embedding, t)?;
                                let at = (row * chunk + r) * hidden;
                                xbuf[at..at + hidden].copy_from_slice(emb.as_f32()?);
                                pos_f[row * chunk + r] = (s.pos + r) as f32;
                            }
                            pos_base[row] = s.pos as i32;
                            valid_len[row] = (1 + drafted.len()) as i32;
                            mask[row] = 1;
                            rows_written[row] = s.pos + 1 + drafted.len();
                            spec_state[row] = Some(SpecOwner { drafted, pos0: s.pos });
                        } else {
                            let emb = hostops::embed(&weights.embedding, token)?;
                            let at = row * chunk * hidden;
                            xbuf[at..at + hidden].copy_from_slice(emb.as_f32()?);
                            pos_f[row * chunk] = s.pos as f32;
                            pos_base[row] = s.pos as i32;
                            valid_len[row] = 1;
                            mask[row] = 1;
                            taken[row] = 1;
                            rows_written[row] = s.pos + 1;
                        }
                    }
                }
            }
            let mut inputs: HashMap<String, Tensor> = HashMap::with_capacity(7);
            inputs.insert("x".into(), Tensor::f32(vec![rows, hidden], xbuf)?);
            inputs.insert("pos_f".into(), Tensor::f32(vec![rows], pos_f)?);
            inputs.insert("pos_base".into(), Tensor::i32(vec![width], pos_base)?);
            inputs.insert("valid_len".into(), Tensor::i32(vec![width], valid_len)?);
            inputs.insert("slot_mask".into(), Tensor::i32(vec![width], mask)?);
            inputs.insert("inv_freq".into(), self.weights.inv_freq.clone());
            if let Some(pool) = self.executor.paged_pool() {
                // Paged: per-slot block tables replace slot-indexed cache
                // sets (`slot_idx` is not a declared input of the paged
                // unified graph).
                let stride = paged_table_len(&self.dims);
                let mut tbl = vec![-1i32; width * stride];
                for &(row, i) in members {
                    let pk = self.active[i].kv.as_paged().ok_or_else(|| {
                        Error::Internal(format!(
                            "paged mode: session {} is not block-backed at encode",
                            self.active[i].id
                        ))
                    })?;
                    tbl[row * stride..(row + 1) * stride]
                        .copy_from_slice(&Self::table_entries(pk, stride));
                }
                inputs
                    .insert("block_table".into(), Tensor::i32(vec![width * stride], tbl)?);
                inputs.insert("kv_block".into(), Tensor::scalar_i32(pool.kv_block as i32));
            } else {
                inputs.insert("slot_idx".into(), Tensor::i32(vec![width], slot_idx)?);
            }

            // ---- one replay per chunk-of-slots, shared-cost snapshots ----
            let ph0 = self.executor.device.timeline.virtual_ns;
            let k0 = self.executor.device.timeline.kernel_virtual_ns;
            let fw0 = self.executor.framework_virtual_ns;
            let d0 = self.executor.dispatch_count;
            let w0 = self.executor.device.stats.bytes_written;
            let c0 = self.executor.device.clock.now_ns();
            let logits_buf = {
                let ServingEngine { executor, unified_graph, active, .. } = &mut *self;
                let graph = unified_graph
                    .as_ref()
                    .ok_or_else(|| Error::Internal("unified plan missing".into()))?;
                // Paged chunks bind the shared pool planes; the uploaded
                // block tables do the routing.
                let table: Vec<Option<&DeviceKvCache>> = if executor.paged_enabled() {
                    Vec::new()
                } else {
                    let mut t: Vec<Option<&DeviceKvCache>> = vec![None; width];
                    for &(row, i) in members {
                        t[row] = active[i].kv.as_device();
                    }
                    t
                };
                let (_outs, logits_buf, _delta) =
                    executor.run_unified(graph, &inputs, chunk_no, &table)?;
                logits_buf
            };

            // ---- split the chunk's shared costs across its members so
            // per-session sums keep tiling the engine totals ----
            let tl = self.executor.device.timeline.virtual_ns;
            let kernel_d = self.executor.device.timeline.kernel_virtual_ns - k0;
            let fw_d = self.executor.framework_virtual_ns - fw0;
            let disp_d = self.executor.dispatch_count - d0;
            let upload_d = self.executor.device.stats.bytes_written - w0;
            let encode_d = self.executor.device.clock.now_ns() - c0;
            let now_enc = self.executor.device.clock.now_ns();
            let k = members.len() as u64;
            let rot = self.rounds;
            for (j, &(row, i)) in members.iter().enumerate() {
                let s = &mut self.active[i];
                for p in 0..8 {
                    s.metrics.phase_virtual_ns[p] += share(tl[p] - ph0[p], k, j, rot);
                }
                s.metrics.kernel_virtual_ns += share(kernel_d, k, j, rot);
                s.metrics.framework_virtual_ns += share(fw_d, k, j, rot);
                let dshare = share(disp_d, k, j, rot);
                s.metrics.dispatches += dshare;
                s.metrics.upload_bytes += share(upload_d, k, j, rot);
                s.metrics.encode_virtual_ns += share(encode_d, k, j, rot);
                // Step accounting stays token-granular: a C-token chunk
                // is C prompt steps, a decode step is one.
                s.metrics.steps += taken[row] as u64;
                if was_prefill[row] {
                    s.metrics.prefill_steps += taken[row] as u64;
                    s.metrics.prefill_dispatches += dshare;
                    if final_prefill[row] {
                        s.metrics.prefill_end_ns = now_enc;
                    }
                }
                // The on-device scatter already wrote this member's rows.
                s.pos += taken[row];
                // All valid rows were scattered — including draft rows a
                // later accept/rollback may rewind past. kv_hw tracks
                // WRITTEN rows, which rewinds never un-write (the unpaged
                // arm's contiguous buffer keeps those bytes too, so the
                // paged spill must preserve them for byte-identity).
                s.kv_hw = s.kv_hw.max(rows_written[row]);
            }
            // Per-slot step spans: one retroactive Complete per member
            // over the chunk's replay window, on the member's slot track.
            if self.executor.device.trace.on() {
                for &(_, i) in members {
                    if let Some(slot) = self.active[i].slot {
                        self.executor.device.trace.complete(
                            crate::trace::names::SLOT_STEP,
                            crate::trace::slot_track(slot),
                            c0,
                            encode_d,
                            self.active[i].id,
                        );
                    }
                }
            }

            // Readback membership: decode steps and FINAL prompt chunks
            // own their slot's logits rows; intermediate chunks (and
            // padding) never synchronize. The single-row contract packs
            // one vocab row per slot (`[W, vocab]`); the multi-row
            // (speculative) contract keeps EVERY chunk row (`[W*C,
            // vocab]`), so slot `j`'s rows start at `j * chunk`: prefill
            // finals read their last valid row, verifies read all
            // `1 + drafted` rows.
            let mut owners: Vec<ChunkOwner> = Vec::new();
            for &(row, i) in members {
                if was_prefill[row] && !final_prefill[row] {
                    continue;
                }
                owners.push(if let Some(spec) = spec_state[row].take() {
                    let owned = 1 + spec.drafted.len();
                    ChunkOwner {
                        session: i,
                        row: row * chunk,
                        rows: owned,
                        spec: Some(spec),
                    }
                } else if speculate >= 1 {
                    ChunkOwner::single(i, row * chunk + taken[row] - 1)
                } else {
                    ChunkOwner::single(i, row)
                });
            }
            if owners.is_empty() {
                // All-intermediate chunk: nothing reads back this round.
                return Ok(None);
            }
            Ok(Some(EncodedChunk {
                buf: logits_buf.ok_or_else(|| {
                    Error::Graph("unified plan produced no logits buffer".into())
                })?,
                owners,
            }))
        }
    }

    /// ONE synchronizing readback for the WHOLE round: every encoded
    /// chunk's logits buffer behind a single `map_read_many`, the shared
    /// sync cost split evenly across the round's readback participants
    /// (remainder to the first), then per-row argmax demux and token
    /// notes. A round with nothing to read back (only intermediate
    /// prefill chunks) skips synchronization entirely.
    fn finish_round(&mut self, chunks: Vec<EncodedChunk>) -> Result<()> {
        if chunks.is_empty() {
            return Ok(());
        }
        let bufs: Vec<BufferId> = chunks.iter().map(|c| c.buf).collect();
        let sy0 = self.executor.device.timeline.sync_virtual_ns;
        let all_bytes = {
            let ServingEngine { executor, retries, .. } = &mut *self;
            match Self::map_read_retry(&mut executor.device, &bufs, retries) {
                Ok(b) => b,
                Err(e) => {
                    // A readback that stays down past its retry budget is
                    // round-fatal: return the ring buffers and surface it.
                    for &b in &bufs {
                        let _ = executor.release_logits(b);
                    }
                    return Err(e);
                }
            }
        };
        let sync_d = self.executor.device.timeline.sync_virtual_ns - sy0;
        for &buf in &bufs {
            self.executor.release_logits(buf)?;
        }
        let now = self.executor.device.clock.now_ns();
        let row_bytes = self.dims.vocab * 4;
        let k_all: u64 = chunks.iter().map(|c| c.owners.len() as u64).sum();
        let rot = self.rounds;
        let mut j = 0usize;
        for (c, bytes) in chunks.iter().zip(&all_bytes) {
            for o in &c.owners {
                let s = &mut self.active[o.session];
                let track = s
                    .slot
                    .map(crate::trace::slot_track)
                    .unwrap_or(crate::trace::TRACK_ENGINE);
                // Tokens committed: the consecutive-fault streak is over
                // (the sticky degrade rung and total_retries remain).
                s.retries = 0;
                s.metrics.sync_virtual_ns += share(sync_d, k_all, j, rot);
                j += 1;
                let Some(spec) = &o.spec else {
                    let next =
                        argmax_bytes(&bytes[o.row * row_bytes..(o.row + 1) * row_bytes]);
                    s.note_token(next, now);
                    self.executor.device.trace.instant(
                        crate::trace::names::TOKEN,
                        track,
                        now,
                        next as u64,
                    );
                    continue;
                };
                // Speculative accept/rollback. Row r's argmax is what
                // greedy decode emits after consuming the row's input, so
                // row 0 is always real; row r's output counts only while
                // every drafted input before it matched the real stream —
                // the greedy-matched prefix. The deferred position advance
                // lands exactly past the accepted rows: rejected rows'
                // scattered KV entries sit beyond the rewound `pos`, never
                // attended (causal mask) and overwritten by later steps,
                // and the final emitted token becomes `last_token`, so the
                // next round naturally resubmits from the divergence.
                let outs: Vec<usize> = (0..o.rows)
                    .map(|r| {
                        let at = (o.row + r) * row_bytes;
                        argmax_bytes(&bytes[at..at + row_bytes])
                    })
                    .collect();
                let mut emitted = vec![outs[0]];
                for r in 1..o.rows {
                    if spec.drafted[r - 1] == emitted[r - 1] {
                        emitted.push(outs[r]);
                    } else {
                        break;
                    }
                }
                let remaining = s.n_new.saturating_sub(s.tokens.len());
                emitted.truncate(remaining.max(1));
                s.metrics.drafted += spec.drafted.len() as u64;
                s.metrics.accepted += (emitted.len() - 1) as u64;
                s.metrics.steps += emitted.len() as u64;
                s.pos = spec.pos0 + emitted.len();
                for &t in &emitted {
                    s.note_token(t, now);
                }
                for &t in &emitted {
                    self.executor.device.trace.instant(
                        crate::trace::names::TOKEN,
                        track,
                        now,
                        t as u64,
                    );
                }
            }
        }
        Ok(())
    }

    /// Retire finished sessions (continuous scheduling: their pooled
    /// buffers — including device-resident cache sets — are immediately
    /// reusable by the next admitted session). Returns the number of
    /// sessions that were stepped this round (pre-retire active count).
    ///
    /// Sessions leave in admission order (FIFO completion bookkeeping) but
    /// their cache sets are released in REVERSE admission order: the
    /// pool's LIFO free lists then hand the next admissions the same
    /// buffer sets in the same slot order, keeping both the per-set bind
    /// groups and the batched cache-set-TABLE bind groups cache-hot when
    /// a whole round retires together.
    fn retire_finished(&mut self) -> Result<usize> {
        // Density high-water BEFORE anything retires: every round ends
        // here (including all-cooldown rounds), so the mark sees each
        // round's full co-resident set — the >= 4x density the paged
        // gate asserts on against the contiguous arm.
        let resident = Self::count_resident(&self.active);
        self.resident_sessions_hw = self.resident_sessions_hw.max(resident);
        let n = self.active.len();
        let mut done: Vec<SessionState> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        for s in done.iter_mut().rev() {
            if s.total_retries > 0 && !s.failed {
                // Completed in full despite >= 1 transient fault — the
                // recovery ledger the fault gates assert on.
                self.recovered_sessions += 1;
            }
            self.release_session_cache(s)?;
        }
        self.finished.extend(done);
        Ok(n)
    }

    /// Return a session's device-resident KV state — a contiguous cache
    /// set, or its granted block groups — to the shared pool/arena. The
    /// session keeps its token history; its KV state is gone. Discards are
    /// not page-outs: nothing crosses back to host.
    pub fn release_session_cache(&mut self, s: &mut SessionState) -> Result<()> {
        match std::mem::replace(&mut s.kv, KvCache::Host(Vec::new())) {
            KvCache::Device(cache) => self.executor.release_kv_cache(cache)?,
            KvCache::Paged(pk) => Self::free_paged_groups(&mut self.executor, pk)?,
            KvCache::Host(_) => {}
        }
        Ok(())
    }

    /// Return every granted block group of a dropped paged session to the
    /// arena, silently (no page-out notes — the data is discarded, not
    /// parked).
    fn free_paged_groups(executor: &mut GraphExecutor<'r>, pk: PagedKv) -> Result<()> {
        let pool = executor.paged_pool_mut().ok_or_else(|| {
            Error::Internal("paged session without a paged pool".into())
        })?;
        for slot in pk.slots {
            if let PagedSlot::Resident(g) = slot {
                pool.arena.free_group(g);
            }
        }
        Ok(())
    }

    /// Fully reset a session for reuse: rewind the prompt cursor, clear
    /// the token history, drop the host cache state AND release any
    /// device-resident cache set back to the pool (the next encode
    /// re-materializes zeroed caches — recycled device buffers in planned
    /// mode, host tensors in eager). This is the complete version of
    /// [`SessionState::reset_host`] — host state alone is not enough once
    /// caches live on the device.
    pub fn reset_session(&mut self, s: &mut SessionState) -> Result<()> {
        match s.reset_host() {
            KvCache::Device(cache) => self.executor.release_kv_cache(cache)?,
            KvCache::Paged(pk) => Self::free_paged_groups(&mut self.executor, pk)?,
            KvCache::Host(_) => {}
        }
        Ok(())
    }

    /// Evict a session's KV state to host tensors mid-generation (the
    /// spill path): device buffers return to the pool, decode position and
    /// token history are preserved, and the next encode transparently
    /// re-allocates and re-hydrates. Lets a server park cold sessions
    /// without losing their context. No-op for host-resident sessions.
    pub fn evict_session_cache(&mut self, s: &mut SessionState) -> Result<()> {
        let ServingEngine { executor, dims, retries, .. } = self;
        Self::evict_kv_to_host(executor, dims, s, retries)
    }

    /// The spill body behind [`Self::evict_session_cache`], borrow-split so
    /// quarantine can call it on a session inside `self.active`. The spill
    /// readback rides the bounded transient-retry loop: a one-shot map
    /// timeout during checkpointing must not turn a recoverable fault into
    /// a run-fatal one.
    fn evict_kv_to_host(
        executor: &mut GraphExecutor<'r>,
        dims: &GraphDims,
        s: &mut SessionState,
        retries: &mut u64,
    ) -> Result<()> {
        if s.kv.is_paged() {
            return Self::evict_paged_to_host(executor, dims, s, retries);
        }
        // Spill FIRST, while the session still owns its set: a failed
        // readback leaves the session device-resident and fully usable,
        // leaking nothing.
        let spilled = match s.kv.as_device() {
            Some(cache) => {
                let mut attempt = 0u32;
                loop {
                    match executor.spill_kv_cache(cache) {
                        Ok(t) => break t,
                        Err(e) if e.is_transient() && attempt < MAX_MAP_RETRIES => {
                            attempt += 1;
                            *retries += 1;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
            None => return Ok(()),
        };
        let KvCache::Device(cache) = std::mem::replace(&mut s.kv, KvCache::Host(Vec::new()))
        else {
            return Err(Error::Internal(
                "device-resident session lost its cache between spill and release".into(),
            ));
        };
        // Spec order is layer-major [K, V]: re-pair per layer. The session
        // becomes host-resident BEFORE the release, so even a release
        // error leaves it consistent (context preserved).
        let mut host = Vec::with_capacity(spilled.len() / 2);
        let mut it = spilled.into_iter();
        while let (Some(k), Some(v)) = (it.next(), it.next()) {
            host.push((k, v));
        }
        s.kv = KvCache::Host(host);
        executor.release_kv_cache(cache)
    }

    /// The paged spill body: reconstruct the session's contiguous host
    /// tensors from its block images (one coalesced readback for all its
    /// resident groups, host slots copied in place), zero-filling rows
    /// `>= kv_hw` — bit-for-bit what the contiguous arm's zeroed-at-alloc
    /// tail holds — then free every granted group back to the arena. The
    /// session resumes via the ordinary pager promote path.
    fn evict_paged_to_host(
        executor: &mut GraphExecutor<'r>,
        dims: &GraphDims,
        s: &mut SessionState,
        retries: &mut u64,
    ) -> Result<()> {
        let Some(pool) = executor.paged_pool() else {
            return Err(Error::Internal("paged session without a paged pool".into()));
        };
        let b = pool.kv_block;
        let slice = pool.plane_slice_bytes;
        let row_bytes = slice / b;
        let planes = 2 * dims.layers;
        let hw = s.kv_hw;
        let pk_ref = s.kv.as_paged().ok_or_else(|| {
            Error::Internal("paged session lost its block state mid-spill".into())
        })?;
        // Blocks that hold real rows; anything past them (conservative
        // speculative over-allocation) is freed unread below.
        let nb = Self::blocks_for(hw, b).min(pk_ref.slots.len());
        let resident: Vec<(usize, u32)> = pk_ref.slots[..nb]
            .iter()
            .enumerate()
            .filter_map(|(j, slot)| match slot {
                PagedSlot::Resident(g) => Some((j, *g)),
                _ => None,
            })
            .collect();
        // Read FIRST, while the session still owns its groups: a failed
        // readback leaves it block-resident and fully usable. Same
        // bounded transient-retry loop as the contiguous spill.
        let groups: Vec<u32> = resident.iter().map(|&(_, g)| g).collect();
        let images = if groups.is_empty() {
            Vec::new()
        } else {
            let mut attempt = 0u32;
            loop {
                match executor.read_paged_groups(&groups) {
                    Ok(v) => break v,
                    Err(e) if e.is_transient() && attempt < MAX_MAP_RETRIES => {
                        attempt += 1;
                        *retries += 1;
                    }
                    Err(e) => return Err(e),
                }
            }
        };
        let mut plane_bytes: Vec<Vec<u8>> =
            (0..planes).map(|_| vec![0u8; dims.max_seq * row_bytes]).collect();
        let mut fill = |j: usize, img: &[u8], planes_out: &mut [Vec<u8>]| {
            let keep = (hw.min((j + 1) * b).saturating_sub(j * b)) * row_bytes;
            for (p, plane) in planes_out.iter_mut().enumerate() {
                let at = j * b * row_bytes;
                plane[at..at + keep].copy_from_slice(&img[p * slice..p * slice + keep]);
            }
        };
        for (&(j, _), img) in resident.iter().zip(&images) {
            fill(j, img, &mut plane_bytes);
        }
        for (j, slot) in pk_ref.slots[..nb].iter().enumerate() {
            if let PagedSlot::Host(bytes) = slot {
                fill(j, bytes, &mut plane_bytes);
            }
        }
        // Re-pair planes per layer in spec order [l0.k, l0.v, ...]; the
        // session becomes host-resident BEFORE the groups are freed, so
        // an arena inconsistency cannot strand its context.
        let shape = vec![dims.max_seq, dims.kv_heads, dims.head_dim];
        let mut host = Vec::with_capacity(dims.layers);
        let mut it = plane_bytes.into_iter();
        while let (Some(kb), Some(vb)) = (it.next(), it.next()) {
            host.push((
                Tensor::from_le_bytes(shape.clone(), DType::F32, &kb)?,
                Tensor::from_le_bytes(shape.clone(), DType::F32, &vb)?,
            ));
        }
        let KvCache::Paged(pk) = std::mem::replace(&mut s.kv, KvCache::Host(host)) else {
            return Err(Error::Internal(
                "paged session lost its block state between read and free".into(),
            ));
        };
        let pool = executor.paged_pool_mut().ok_or_else(|| {
            Error::Internal("paged pool vanished mid-spill".into())
        })?;
        for (j, slot) in pk.slots.into_iter().enumerate() {
            if let PagedSlot::Resident(g) = slot {
                pool.arena.free_group(g);
                if j < nb {
                    // Data-bearing blocks leaving the device are
                    // page-outs; never-written grants return silently.
                    pool.arena.note_page_out();
                }
            }
        }
        s.metrics.kv_blocks_spilled_hw = s.metrics.kv_blocks_spilled_hw.max(nb as u64);
        Ok(())
    }

    /// Drive every queued + active session to completion; report aggregates
    /// over the sessions completed by THIS call.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        if self.config.max_concurrent == 0 {
            return Err(Error::Graph("max_concurrent must be >= 1".into()));
        }
        let t0 = self.now_ns();
        let f0 = self.finished.len();
        let r0 = self.rounds;
        while !self.queue.is_empty() || !self.active.is_empty() {
            self.step_round()?;
        }
        let wall = self.now_ns() - t0;
        let mut report = ServeReport::from_sessions(&self.finished[f0..], wall);
        report.rounds = self.rounds - r0;
        // Engine-level attribution: one-time plan-build cost (planned
        // mode), cache residency, batching, and the pool's counters.
        if let Some(runner) = self.executor.plan_runner() {
            report.planned = true;
            report.plan_build_virtual_ns = runner.build_virtual_ns;
            report.plan_build_real_ns = runner.build_real_ns;
            report.resident_bytes = runner.plan.stats.resident_bytes as u64;
        }
        if self.batched_graph.is_some() {
            report.batch_width = self.batch_width;
            if let Some(br) = self.executor.batched_runner() {
                // The batched plan's build cost is one-time too; fold it
                // into the engine-level build attribution.
                report.plan_build_virtual_ns += br.inner().build_virtual_ns;
                report.plan_build_real_ns += br.inner().build_real_ns;
            }
        }
        if self.prefill_graph.is_some() {
            report.prefill_chunk = self.prefill_chunk;
            if let Some(pr) = self.executor.prefill_runner() {
                report.plan_build_virtual_ns += pr.inner().build_virtual_ns;
                report.plan_build_real_ns += pr.inner().build_real_ns;
            }
        }
        if self.unified_graph.is_some() {
            report.unified = true;
            report.speculate = self.speculate;
            if let Some(ur) = self.executor.unified_runner() {
                report.plan_build_virtual_ns += ur.inner().build_virtual_ns;
                report.plan_build_real_ns += ur.inner().build_real_ns;
            }
        }
        let ps = self.executor.pool.stats();
        report.pool_high_water_bytes = ps.high_water_bytes as u64;
        report.pool_buffers_created = ps.created;
        report.pool_evictions = ps.evictions;
        // Paged-residency ledger (zeroes in contiguous mode).
        if let Some(pool) = self.executor.paged_pool() {
            let st = pool.arena.stats();
            report.kv_block = self.kv_block;
            report.kv_group_bytes = pool.arena.group_bytes() as u64;
            report.kv_pool_high_water_groups = st.high_water_groups as u64;
            report.kv_page_ins = st.page_ins;
            report.kv_page_outs = st.page_outs;
        }
        report.resident_sessions_hw = self.resident_sessions_hw as u64;
        // Fault/recovery ledger (zeroes when no injector is installed).
        report.faults_injected = self.executor.device.faults_injected();
        report.retries = self.retries;
        report.recovered_sessions = self.recovered_sessions;
        report.failed_sessions = self.failed_sessions;
        report.fault_seed = self.fault_seed;
        // Tracer-side observability: engine-level histograms (recorded
        // regardless of sink) and the event ledger.
        report.round_hist = self.executor.device.trace.metrics.round_ns.clone();
        report.map_wait_hist = self.executor.device.trace.metrics.map_wait_ns.clone();
        report.trace_events = self.executor.device.trace.total_events();
        report.trace_dropped_events = self.executor.device.trace.dropped_events();
        Ok(report)
    }

    /// The device's span tracer (read access for export/inspection).
    pub fn tracer(&self) -> &crate::trace::Tracer {
        &self.executor.device.trace
    }

    /// Export the retained trace as a Chrome-trace JSON document. The
    /// `otherData` block carries the report's wall-clock so
    /// `wdb trace-summary` can prove the ROUND spans tile it exactly.
    pub fn export_chrome_trace(&self, report: &ServeReport) -> crate::report::json::Value {
        crate::trace::chrome::export(
            &self.executor.device.trace,
            &[
                ("wall_virtual_ns", report.wall_virtual_ns as f64),
                ("rounds", report.rounds as f64),
                ("total_events", self.executor.device.trace.total_events() as f64),
                ("dropped_events", self.executor.device.trace.dropped_events() as f64),
            ],
        )
    }

    /// Take ownership of the retired sessions (completion order).
    pub fn drain_finished(&mut self) -> Vec<SessionState> {
        std::mem::take(&mut self.finished)
    }
}

/// Split a shared per-chunk cost evenly across its `k` participants so
/// per-session sums keep tiling the engine totals exactly — the same
/// convention as the coalesced-sync split. The sub-`k` remainder rotates
/// with `rot` (the engine's round counter) instead of always landing on
/// the first member: over a run the extra nanoseconds spread round-robin
/// across positions, so position-0 sessions no longer accumulate a
/// systematic per-round bias.
fn share(total: u64, k: u64, j: usize, rot: u64) -> u64 {
    let base = total / k;
    let rem = total % k;
    debug_assert_eq!(base * k + rem, total);
    base + u64::from((j as u64 + rot) % k < rem)
}

#[cfg(test)]
mod share_tests {
    use super::share;

    #[test]
    fn share_tiles_exactly_for_every_rotation() {
        for total in [0u64, 1, 7, 8, 9, 1_000_003] {
            for k in 1u64..=9 {
                for rot in 0u64..=9 {
                    let sum: u64 =
                        (0..k as usize).map(|j| share(total, k, j, rot)).sum();
                    assert_eq!(sum, total, "total={total} k={k} rot={rot}");
                }
            }
        }
    }

    #[test]
    fn share_rotates_the_remainder() {
        // total=7, k=3: rem=1 lands on member (0 - rot) mod 3.
        assert_eq!(share(7, 3, 0, 0), 3);
        assert_eq!(share(7, 3, 1, 0), 2);
        assert_eq!(share(7, 3, 2, 0), 2);
        assert_eq!(share(7, 3, 0, 1), 2);
        assert_eq!(share(7, 3, 2, 1), 3);
        assert_eq!(share(7, 3, 1, 2), 3);
    }
}

/// Host argmax over a little-endian f32 byte buffer (the mapped logits
/// row); first maximum wins, matching `Tensor::argmax_row`.
pub fn argmax_bytes(bytes: &[u8]) -> usize {
    let mut best = 0usize;
    let mut bestv = f32::NEG_INFINITY;
    for (i, c) in bytes.chunks_exact(4).enumerate() {
        let x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        if x > bestv {
            bestv = x;
            best = i;
        }
    }
    best
}
